//! Quickstart: the kvq library in 60 seconds.
//!
//! Quantize a synthetic KV matrix, inspect the paper's three error
//! metrics, check the memory saving, and round-trip through the paged
//! cache manager. Run with:
//!
//! ```text
//! cargo run --release --example quickstart
//! ```

use kvq::kvcache::manager::{CacheConfig, KvCacheManager};
use kvq::kvcache::{MemoryModel, Precision, QuantPolicy};
use kvq::quant::{self, Fp32Matrix};
use kvq::util::stats::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // 1. A synthetic key matrix: 4096 cached tokens, head dim 256,
    //    values in U(-1, 1) like the paper's benchmarks.
    let k = Fp32Matrix::random_uniform(4096, 256, -1.0, 1.0, 42);
    println!("K: {}x{} ({})", k.rows, k.cols, fmt_bytes(k.size_bytes() as f64));

    // 2. Per-channel INT8 quantization (eq. 6 + eq. 7 in one call).
    let q = quant::quantize_fused(&k);
    println!(
        "quantized: {} (payload {:.2}x smaller)",
        fmt_bytes(q.size_bytes() as f64),
        q.compression_ratio()
    );

    // 3. The paper's three error metrics (§7.2, §7.3).
    let rec = quant::dequantize(&q);
    let queries = Fp32Matrix::random_uniform(64, 256, -1.0, 1.0, 7);
    println!("max abs error   : {:.5}  (paper: ≈0.00394 for U(-1,1))",
        quant::max_abs_error(&k, &rec));
    println!("L2 error        : {:.3}", quant::l2_error(&k, &rec));
    println!("attention error : {:.5}  (paper: <0.1 up to D=8192)",
        quant::attention_score_error(&queries, &k, &rec));

    // 4. What this buys at LLM scale — the Table-1 memory model.
    let fp32 = MemoryModel::table1_example();
    let int8 = MemoryModel { precision: Precision::Int8, ..fp32 };
    println!("\nTable-1 model (L=32 H=32 d=128 T=131072):");
    println!("  fp32 cache: {}", fmt_bytes(fp32.total_bytes() as f64));
    println!("  int8 cache: {} ({:.2}x)", fmt_bytes(int8.total_bytes() as f64),
        int8.compression_vs_fp32());

    // 5. The serving-side cache: paged, INT8, frozen prefill scales.
    let cfg = CacheConfig {
        layers: 2,
        heads: 4,
        head_dim: 64,
        max_seq: 128,
        block_size: 16,
        num_blocks: 256,
        scale_margin: 1.0,
    };
    let mut mgr =
        KvCacheManager::new(cfg, QuantPolicy::uniform(Precision::Int8, cfg.layers, cfg.heads));
    let id = mgr.new_sequence();
    let n = cfg.layers * cfg.heads * cfg.max_seq * cfg.head_dim;
    let kc = Fp32Matrix::random_normal(1, n, 1.0, 1).data;
    let vc = Fp32Matrix::random_normal(1, n, 1.0, 2).data;
    mgr.set_prefill(id, &kc, &vc, 100)?;
    println!(
        "\npaged cache: seq of 100 tokens -> {} blocks used, {:.1}% utilization",
        cfg.num_blocks - mgr.free_blocks(),
        mgr.utilization() * 100.0
    );
    mgr.free(id);
    println!("freed -> {} blocks free", mgr.free_blocks());
    Ok(())
}
