//! Error analysis: reproduce the paper's Figure-4 trends from the library.
//!
//! Sweeps head dimension D (the paper's √D attention-error law), matrix
//! size (L2 growth), and compares per-channel vs per-tensor and INT8 vs
//! INT4 — the numerical story of the paper in one binary.
//!
//! ```text
//! cargo run --release --example error_analysis
//! ```

use kvq::quant::{self, Fp32Matrix};
use kvq::util::harness::{cell_f, Table};

fn main() -> anyhow::Result<()> {
    // Fig 4 right: attention error ∝ sqrt(D).
    let mut t = Table::new(
        "Attention-score error vs head dimension (U(-1,1), T=2048, 64 queries)",
        &["D", "max_abs_err", "attn_err", "attn_err/sqrt(D)"],
    );
    for d in [64usize, 128, 256, 512, 1024, 2048] {
        let k = Fp32Matrix::random_uniform(2048, d, -1.0, 1.0, d as u64);
        let q = Fp32Matrix::random_uniform(64, d, -1.0, 1.0, 999);
        let rec = quant::dequantize(&quant::quantize_fused(&k));
        let attn = quant::attention_score_error(&q, &k, &rec);
        t.row(&[
            d.to_string(),
            cell_f(quant::max_abs_error(&k, &rec), 5),
            cell_f(attn, 5),
            cell_f(attn / (d as f64).sqrt(), 7),
        ]);
    }
    t.print();
    println!("→ attn_err/sqrt(D) is ~constant: the √D law of §7.3.");

    // Fig 4 left: max-abs constant, L2 grows with size.
    let mut t2 = Table::new(
        "Reconstruction error vs matrix size (D=256)",
        &["T", "elements", "max_abs_err", "l2_err"],
    );
    for tl in [512usize, 2048, 8192, 32768] {
        let k = Fp32Matrix::random_uniform(tl, 256, -1.0, 1.0, tl as u64);
        let rec = quant::dequantize(&quant::quantize_fused(&k));
        t2.row(&[
            tl.to_string(),
            (tl * 256).to_string(),
            cell_f(quant::max_abs_error(&k, &rec), 5),
            cell_f(quant::l2_error(&k, &rec), 3),
        ]);
    }
    t2.print();
    println!("→ max-abs pinned at ≈1/(2·127)=0.00394; L2 ∝ sqrt(elements).");

    // Distribution sensitivity: uniform vs normal vs outliers.
    let mut t3 = Table::new(
        "Error vs input distribution (T=4096, D=256)",
        &["distribution", "max_abs_err", "attn_err"],
    );
    for (name, seed, dist) in
        [("uniform", 1u64, 0), ("normal", 2, 1), ("normal+outliers", 3, 2)]
    {
        let mut k = match dist {
            0 => Fp32Matrix::random_uniform(4096, 256, -1.0, 1.0, seed),
            _ => Fp32Matrix::random_normal(4096, 256, 1.0, seed),
        };
        if dist == 2 {
            for i in (0..k.data.len()).step_by(997) {
                k.data[i] *= 50.0;
            }
        }
        let q = Fp32Matrix::random_uniform(64, 256, -1.0, 1.0, 42);
        let rec = quant::dequantize(&quant::quantize_fused(&k));
        t3.row(&[
            name.to_string(),
            cell_f(quant::max_abs_error(&k, &rec), 5),
            cell_f(quant::attention_score_error(&q, &k, &rec), 5),
        ]);
    }
    t3.print();
    println!("→ outliers inflate per-channel scales only in hit columns (vs global scale).");

    // INT4 extension (§8.1).
    let k = Fp32Matrix::random_uniform(4096, 256, -1.0, 1.0, 77);
    let r8 = quant::dequantize(&quant::quantize_fused(&k));
    let r4 = quant::int4::dequantize4(&quant::int4::quantize4(&k));
    println!(
        "\nINT4 vs INT8 max-abs error: {:.5} vs {:.5} ({:.1}x worse for 2x memory win)",
        quant::max_abs_error(&k, &r4),
        quant::max_abs_error(&k, &r8),
        quant::max_abs_error(&k, &r4) / quant::max_abs_error(&k, &r8)
    );
    Ok(())
}
