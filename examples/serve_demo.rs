//! **The end-to-end driver** (DESIGN.md §e2e): bring up the full serving
//! stack — AOT artifacts via PJRT, paged INT8 KV cache, continuous
//! batcher, HTTP front end — serve a batch of real HTTP requests, and
//! report latency/throughput, comparing INT8 against the FP32-cache
//! baseline engine behind the same router.
//!
//! ```text
//! cargo run --release --example serve_demo            # kvq-3m
//! cargo run --release --example serve_demo -- --model kvq-25m --requests 12
//! ```
//!
//! Requires `make artifacts`. Results are recorded in EXPERIMENTS.md §E2E.

use kvq::coordinator::batcher::BatcherConfig;
use kvq::coordinator::engine::{self, EngineConfig};
use kvq::coordinator::router::{RoutePolicy, Router};
use kvq::kvcache::{PolicySpec, Precision};
use kvq::model::runner::{DecodeKernel, PjrtBackend};
use kvq::runtime::Runtime;
use kvq::server::http::{http_request, HttpServer};
use kvq::server::KvqService;
use kvq::util::args::Args;
use kvq::util::json::Json;
use kvq::util::stats::Summary;
use std::rc::Rc;
use std::sync::Arc;
use std::time::Instant;

fn main() -> anyhow::Result<()> {
    let args = Args::parse();
    let model = args.str_or("model", "kvq-3m");
    let n_requests = args.usize_or("requests", 8);
    let max_new = args.usize_or("max-new", 32);

    println!("== kvq serve_demo: model={model}, {n_requests} HTTP requests, {max_new} tokens each ==\n");

    // Two engines behind one router: INT8 cache vs FP32 cache.
    let mut router = Router::new(RoutePolicy::RoundRobin);
    let mut handles = Vec::new();
    for precision in [Precision::Int8, Precision::Fp32] {
        let dir = kvq::runtime::default_artifact_dir();
        let m = model.clone();
        let (h, join) = engine::spawn(
            EngineConfig {
                quant_policy: PolicySpec::uniform(precision),
                batcher: BatcherConfig { max_prefills_per_step: 2, ..Default::default() },
                ..Default::default()
            },
            move || {
                let rt = Rc::new(Runtime::new(&dir)?);
                Ok(Box::new(PjrtBackend::new(rt, &m, 0xA11CE, DecodeKernel::PlainXla)?)
                    as Box<dyn kvq::model::LmBackend>)
            },
        );
        router.add_engine(precision.name(), h.clone());
        handles.push((h, join));
    }

    // HTTP server on an ephemeral port.
    let service = Arc::new(KvqService::new(Arc::new(router)));
    let server = HttpServer::bind(0)?;
    let port = server.local_port();
    let stop = server.shutdown_handle();
    let svc = service.clone();
    let server_thread = std::thread::spawn(move || server.serve(move |req| svc.handle(req)));
    println!("HTTP server on 127.0.0.1:{port}");

    let prompts = [
        "the key value cache grows linearly with sequence length",
        "quantization maps floating point values to integers",
        "per channel scales preserve precision across dimensions",
        "memory bandwidth dominates elementwise kernels",
        "vectorized loads improve effective throughput",
        "paged attention reduces memory fragmentation",
        "int8 compression yields four times smaller caches",
        "attention scores are robust to small key perturbations",
    ];

    let mut report = Vec::new();
    for engine_name in ["int8", "fp32"] {
        let t0 = Instant::now();
        let mut threads = Vec::new();
        for i in 0..n_requests {
            let prompt = prompts[i % prompts.len()].to_string();
            let en = engine_name.to_string();
            threads.push(std::thread::spawn(move || {
                let body = format!(
                    r#"{{"prompt":"{prompt}","max_new_tokens":{max_new},"engine":"{en}"}}"#
                );
                let t = Instant::now();
                let (status, resp) =
                    http_request(port, "POST", "/generate", Some(&body)).expect("http");
                (status, resp, t.elapsed().as_secs_f64())
            }));
        }
        let mut lat = Summary::new();
        let mut ttft = Summary::new();
        let mut tokens_total = 0usize;
        let mut sample_text = String::new();
        for th in threads {
            let (status, resp, secs) = th.join().unwrap();
            assert_eq!(status, 200, "bad response: {resp}");
            let j = Json::parse(&resp).expect("json");
            tokens_total += j.get("tokens").as_arr().map(|a| a.len()).unwrap_or(0);
            ttft.add(j.get("ttft_s").as_f64().unwrap_or(0.0));
            lat.add(secs);
            if sample_text.is_empty() {
                sample_text = j.get("text").as_str().unwrap_or("").chars().take(40).collect();
            }
        }
        let wall = t0.elapsed().as_secs_f64();
        let thpt = tokens_total as f64 / wall;
        println!(
            "\n[{engine_name}] {} tokens in {:.2}s -> {:.1} tok/s | \
             latency p50 {:.0}ms p99 {:.0}ms | ttft p50 {:.0}ms",
            tokens_total,
            wall,
            thpt,
            lat.percentile(50.0) * 1e3,
            lat.percentile(99.0) * 1e3,
            ttft.percentile(50.0) * 1e3,
        );
        println!("[{engine_name}] sample output: {sample_text:?}");
        report.push((engine_name, thpt, tokens_total));
    }

    // Metrics endpoint exercise.
    let (status, metrics) = http_request(port, "GET", "/metrics", None)?;
    assert_eq!(status, 200);
    let j = Json::parse(&metrics)?;
    println!("\n/metrics: {} engines reporting", j.get("engines").as_arr().unwrap().len());
    for e in j.get("engines").as_arr().unwrap() {
        println!(
            "  {}: steps={} finished={} tok/s={:.1} cache_util={:.2}",
            e.get("engine").as_str().unwrap_or("?"),
            e.get("engine_steps").as_usize().unwrap_or(0),
            e.get("requests_finished").as_usize().unwrap_or(0),
            e.get("tokens_per_sec").as_f64().unwrap_or(0.0),
            e.get("cache_utilization").as_f64().unwrap_or(0.0),
        );
    }

    println!(
        "\nINT8 vs FP32 throughput: {:.1} vs {:.1} tok/s (identical math modulo cache \
         precision; INT8 additionally holds a 4x smaller cache — see `kvq memory`)",
        report[0].1, report[1].1
    );

    stop.store(true, std::sync::atomic::Ordering::Relaxed);
    server_thread.join().ok();
    for (h, join) in handles {
        h.drain();
        join.join().ok();
    }
    println!("\nserve_demo complete ✓");
    Ok(())
}
