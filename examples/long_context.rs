//! Long-context scenario: what INT8 caching buys as sequences grow.
//!
//! Walks the Table-1 memory model across context lengths, then drives the
//! paged cache manager through a grow-until-full + admission-control
//! episode, including a prefix-shared fork (parallel sampling).
//!
//! ```text
//! cargo run --release --example long_context
//! ```

use kvq::kvcache::manager::{CacheConfig, KvCacheManager};
use kvq::kvcache::{MemoryModel, Precision, QuantPolicy};
use kvq::quant::Fp32Matrix;
use kvq::util::harness::Table;
use kvq::util::stats::fmt_bytes;

fn main() -> anyhow::Result<()> {
    // 1. Context-length sweep on the Table-1 model.
    let mut t = Table::new(
        "KV cache size vs context length (L=32 H=32 d=128)",
        &["T", "fp32", "fp16", "int8", "int4", "int8 fits 16GB?"],
    );
    for tl in [4096usize, 16384, 32768, 131_072, 524_288, 1_048_576] {
        let base = MemoryModel { seq_len: tl, ..MemoryModel::table1_example() };
        let int8 = MemoryModel { precision: Precision::Int8, ..base };
        let int4 = MemoryModel { precision: Precision::Int4, ..base };
        t.row(&[
            tl.to_string(),
            fmt_bytes(base.total_bytes() as f64),
            fmt_bytes((base.elements() * 2) as f64),
            fmt_bytes(int8.total_bytes() as f64),
            fmt_bytes(int4.total_bytes() as f64),
            if int8.total_bytes() <= 16 << 30 { "yes" } else { "no" }.into(),
        ]);
    }
    t.print();

    // 2. Live paged cache: admit sequences until the watermark bites.
    let cfg = CacheConfig {
        layers: 4,
        heads: 8,
        head_dim: 32,
        max_seq: 512,
        block_size: 16,
        num_blocks: 512,
        scale_margin: 1.0,
    };
    let mut mgr =
        KvCacheManager::new(cfg, QuantPolicy::uniform(Precision::Int8, cfg.layers, cfg.heads));
    println!(
        "\npool: {} blocks ({}), {} blocks per full sequence",
        cfg.num_blocks,
        fmt_bytes(mgr.storage_bytes() as f64),
        cfg.blocks_for_tokens(cfg.max_seq)
    );

    let n = cfg.layers * cfg.heads * cfg.max_seq * cfg.head_dim;
    let kc = Fp32Matrix::random_normal(1, n, 1.0, 1).data;
    let vc = Fp32Matrix::random_normal(1, n, 1.0, 2).data;
    let mut admitted = Vec::new();
    let prompt_len = 400;
    loop {
        if !mgr.can_admit(prompt_len) {
            println!(
                "admission stops at {} sequences ({:.0}% utilization) — backpressure engages",
                admitted.len(),
                mgr.utilization() * 100.0
            );
            break;
        }
        let id = mgr.new_sequence();
        mgr.set_prefill(id, &kc, &vc, prompt_len)?;
        admitted.push(id);
    }

    // 3. Prefix sharing: fork the first sequence 3 ways (costs ~0 blocks
    //    until the forks diverge).
    let free_before = mgr.free_blocks();
    let forks: Vec<_> = (0..3).map(|_| mgr.fork(admitted[0]).unwrap()).collect();
    println!(
        "forked 3 continuations off seq {}: {} blocks consumed (copy-on-write)",
        admitted[0],
        free_before - mgr.free_blocks()
    );
    // Diverge one fork: appends trigger COW on the tail block only.
    let row = vec![0.1f32; cfg.layers * cfg.heads * cfg.head_dim];
    mgr.append_row(forks[0], &row, &row)?;
    println!(
        "after 1 divergent token on fork 0: {} blocks consumed",
        free_before - mgr.free_blocks()
    );

    // 4. Finish a request -> blocks return -> next admission succeeds.
    mgr.free(admitted.pop().unwrap());
    println!(
        "freed one sequence -> can_admit({prompt_len}) = {}",
        mgr.can_admit(prompt_len)
    );
    for id in admitted {
        mgr.free(id);
    }
    for id in forks {
        mgr.free(id);
    }
    assert_eq!(mgr.free_blocks(), cfg.num_blocks);
    println!("all sequences freed; pool fully recovered ✓");
    Ok(())
}
