//! # kvq — INT8 KV-cache quantization serving stack
//!
//! Reproduction of *"GPU-Accelerated INT8 Quantization for KV Cache
//! Compression in Large Language Models"* as a three-layer Rust + JAX +
//! Pallas system (see DESIGN.md):
//!
//! * [`quant`] — the paper's core algorithm in pure Rust: per-channel
//!   scale computation, the four kernel-optimization strategies (naive,
//!   tiled, coarsened, vectorized), dequantization, and the paper's three
//!   error metrics. This doubles as the CPU baseline for every figure.
//! * [`runtime`] — PJRT bridge: loads the AOT-lowered Pallas/JAX artifacts
//!   (`artifacts/*.hlo.txt`) and executes them from the hot path.
//! * [`kvcache`] — paged KV-cache manager with first-class INT8 pages and
//!   the Table-1 memory model.
//! * [`coordinator`] — the serving framework: request router, continuous
//!   batcher, prefill/decode scheduler, engine loop, metrics.
//! * [`model`] — token-level LM runner (specs, synthetic weights, byte
//!   tokenizer, generation loop) over the AOT artifacts.
//! * [`server`] — std-only HTTP/1.1 front end.
//! * [`parallel`] — the shared thread-pool runtime: one `parallelism`
//!   knob (0 = auto, `KVQ_THREADS` override) feeding the parallel
//!   quantize/dequantize/gather/prefill hot paths; bit-deterministic at
//!   any worker count.
//! * [`bench`] — workload generators and the harness that regenerates
//!   every table and figure in the paper.
//! * [`config`] — typed configuration system (JSON + CLI overrides).
//! * [`util`] — from-scratch substrates (JSON, CLI args, RNG, stats,
//!   logging, property testing) — the offline environment provides no
//!   crates beyond `xla`/`anyhow` (DESIGN.md §3).

pub mod bench;
pub mod config;
pub mod coordinator;
pub mod kvcache;
pub mod model;
pub mod parallel;
pub mod quant;
pub mod runtime;
pub mod server;
pub mod util;

/// Symmetric INT8 quantization bound used throughout the paper: values are
/// clamped to `[-QMAX, QMAX]` (−128 is unused, keeping the grid symmetric).
pub const QMAX: f32 = 127.0;
