//! The HTTP service: router + tokenizer behind request handlers.

use super::api::{generate_response, metrics_response, ApiError, GenerateRequest};
use super::http::{HttpRequest, HttpResponse};
use crate::coordinator::request::{collect_response, FinishReason};
use crate::coordinator::router::SubmitOptions;
use crate::coordinator::Router;
use crate::model::ByteTokenizer;
use crate::util::json::{obj, Json};
use std::sync::Arc;

/// Suggested client retry delay on a 429 admission rejection.
const ADMISSION_RETRY_MS: u64 = 250;

/// Suggested client retry delay after a mid-request shard failure — a
/// little past the supervisor's first respawn backoff, so an immediate
/// retry usually lands on the respawned (or a surviving) shard.
const SHARD_FAILED_RETRY_MS: u64 = 100;

/// Shareable service state.
pub struct KvqService {
    pub router: Arc<Router>,
    pub tokenizer: ByteTokenizer,
    /// Effective serving configuration served at `GET /config`
    /// (see [`crate::server::api::config_response`]).
    pub info: Json,
}

impl KvqService {
    pub fn new(router: Arc<Router>) -> KvqService {
        KvqService { router, tokenizer: ByteTokenizer::new(), info: Json::Null }
    }

    /// Like [`KvqService::new`], with a `/config` payload.
    pub fn with_info(router: Arc<Router>, info: Json) -> KvqService {
        KvqService { router, tokenizer: ByteTokenizer::new(), info }
    }

    /// Top-level request dispatch.
    pub fn handle(&self, req: HttpRequest) -> HttpResponse {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => HttpResponse::json(200, &obj([("status", "ok".into())])),
            ("GET", "/metrics") => HttpResponse::json(200, &metrics_response(&self.router)),
            ("GET", "/config") => HttpResponse::json(200, &self.info),
            ("POST", "/generate") => self.generate(&req),
            ("GET", _) | ("POST", _) => ApiError::not_found("unknown endpoint").to_response(),
            _ => ApiError::method_not_allowed().to_response(),
        }
    }

    fn generate(&self, req: &HttpRequest) -> HttpResponse {
        let body = match req.body_str() {
            Ok(b) => b,
            Err(e) => return ApiError::bad_request(format!("{e}")).to_response(),
        };
        let greq = match GenerateRequest::parse(body) {
            Ok(r) => r,
            Err(e) => return ApiError::bad_request(format!("{e}")).to_response(),
        };
        let prompt = self.tokenizer.encode(&greq.prompt);
        let submit = match &greq.engine {
            Some(name) => self
                .router
                .submit_to(name, prompt, greq.max_new_tokens, greq.sampling())
                .map_err(|e| ApiError::bad_request(format!("{e}"))),
            None => self
                .router
                .submit_with(
                    prompt,
                    greq.max_new_tokens,
                    greq.sampling(),
                    SubmitOptions {
                        session: greq.session.clone(),
                        priority: greq.priority,
                        deadline_ms: greq.deadline_ms,
                        ..Default::default()
                    },
                )
                .map_err(ApiError::from_submit),
        };
        let (id, rx) = match submit {
            Ok(x) => x,
            Err(e) => return e.to_response(),
        };
        let (tokens, reason, ttft, elapsed) = collect_response(&rx);
        let reason_str = match &reason {
            FinishReason::Length => "length".to_string(),
            FinishReason::Stop => "stop".to_string(),
            FinishReason::CapacityExhausted => "capacity".to_string(),
            FinishReason::Rejected(c) => {
                return ApiError::admission_rejected(c.clone(), ADMISSION_RETRY_MS).to_response()
            }
            FinishReason::DeadlineExceeded => {
                return ApiError::deadline_exceeded(format!(
                    "deadline expired after {} token(s)",
                    tokens.len()
                ))
                .to_response()
            }
            FinishReason::ShardFailed => {
                return ApiError::shard_failed(SHARD_FAILED_RETRY_MS).to_response()
            }
            FinishReason::Stalled => {
                return ApiError::internal("stream stalled past the watchdog timeout")
                    .to_response()
            }
            // The engine saw our stream drop; for this synchronous path
            // that only happens on teardown races — report it honestly.
            FinishReason::Cancelled => {
                return ApiError::internal("stream cancelled").to_response()
            }
            FinishReason::Error(c) => return ApiError::internal(c.clone()).to_response(),
        };
        let text = self.tokenizer.decode(&tokens);
        HttpResponse::json(
            200,
            &generate_response(id, &text, &tokens, &reason_str, ttft, elapsed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::{Backend, ServeConfig};
    use crate::coordinator::engine::{self, EngineConfig};
    use crate::coordinator::router::{Affinity, RoutePolicy, RouterConfig};
    use crate::kvcache::{PolicySpec, Precision};
    use crate::model::runner::CpuBackend;
    use crate::model::weights::Weights;
    use crate::model::ModelSpec;
    use crate::server::api::SCHEMA_VERSION;

    fn service() -> (KvqService, crate::coordinator::EngineHandle, std::thread::JoinHandle<()>) {
        let (h, join) = engine::spawn(
            EngineConfig {
                quant_policy: PolicySpec::uniform(Precision::Int8),
                ..Default::default()
            },
            || {
                let spec = ModelSpec::test_tiny();
                let w = Weights::synthetic(&spec, 7);
                Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn crate::model::LmBackend>)
            },
        );
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("int8", h.clone());
        (KvqService::new(Arc::new(router)), h, join)
    }

    fn post(svc: &KvqService, path: &str, body: &str) -> HttpResponse {
        svc.handle(HttpRequest {
            method: "POST".into(),
            path: path.into(),
            headers: Default::default(),
            body: body.as_bytes().to_vec(),
        })
    }

    fn get(svc: &KvqService, path: &str) -> HttpResponse {
        svc.handle(HttpRequest {
            method: "GET".into(),
            path: path.into(),
            headers: Default::default(),
            body: vec![],
        })
    }

    #[test]
    fn health_and_metrics() {
        let (svc, h, join) = service();
        assert_eq!(get(&svc, "/health").status, 200);
        let m = get(&svc, "/metrics");
        assert_eq!(m.status, 200);
        let j = Json::parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(j.get("schema_version").as_usize(), Some(SCHEMA_VERSION as usize));
        // Per-shard namespacing + the legacy alias point at the same shape.
        assert_eq!(j.get("shards").at(0).get("engine").as_str(), Some("int8"));
        assert_eq!(j.get("shards").at(0).get("shard").as_usize(), Some(0));
        assert_eq!(j.get("engines").at(0).get("engine").as_str(), Some("int8"));
        // Aggregated totals surface at the top level for v1 consumers.
        assert!(j.get("requests_submitted").as_f64().is_some());
        // v3 prefix-trie gauges aggregate like every other numeric gauge.
        assert!(j.get("prefix_partial_hits").as_f64().is_some());
        assert!(j.get("prefix_saved_tokens").as_f64().is_some());
        assert!(j.get("prefix_trie_nodes").as_f64().is_some());
        // v4 physical/tier gauges do too, per shard and in the totals.
        assert!(j.get("pool_physical_bytes").as_f64().is_some());
        assert!(j.get("pool_fragmentation_bytes").as_f64().is_some());
        assert!(j.get("cache_physical_bytes_int8").as_f64().is_some());
        assert!(j.get("tier_hot_blocks").as_f64().is_some());
        assert!(j.get("tier_cold_blocks").as_f64().is_some());
        assert!(j.get("tier_demotions").as_f64().is_some());
        assert!(j.get("tier_promotions").as_f64().is_some());
        assert!(j.get("tier_prefetch_misses").as_f64().is_some());
        assert!(j.get("shards").at(0).get("tier_cold_blocks").as_f64().is_some());
        assert_eq!(j.get("router").get("shards").as_usize(), Some(1));
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn generate_roundtrip() {
        let (svc, h, join) = service();
        // vocab is 64 in test-tiny: use low-byte prompt chars (so ids < 64).
        let resp = post(&svc, "/generate", r#"{"prompt":"","max_new_tokens":3}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("finish_reason").as_str(), Some("length"));
        assert_eq!(j.get("tokens").as_arr().unwrap().len(), 3);
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn generate_accepts_session_and_priority() {
        let (svc, h, join) = service();
        let resp = post(
            &svc,
            "/generate",
            r#"{"prompt":"","max_new_tokens":2,"session":"u1","priority":"interactive"}"#,
        );
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let bad = post(&svc, "/generate", r#"{"prompt":"","priority":"vip"}"#);
        assert_eq!(bad.status, 400);
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn config_endpoint_serves_info() {
        let (mut svc, h, join) = service();
        let cfg = ServeConfig::builder().backend(Backend::CpuRef).build();
        svc.info = crate::server::api::config_response(&cfg, 0, 2);
        let resp = get(&svc, "/config");
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("schema_version").as_usize(), Some(SCHEMA_VERSION as usize));
        assert_eq!(j.get("parallelism").as_usize(), Some(2));
        assert_eq!(j.get("shards").as_usize(), Some(1));
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn bad_requests_are_4xx() {
        let (svc, h, join) = service();
        let r = post(&svc, "/generate", "not json");
        assert_eq!(r.status, 400);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(j.get("error").get("code").as_str(), Some("bad_request"));
        assert_eq!(post(&svc, "/generate", r#"{"nope":1}"#).status, 400);
        let r = get(&svc, "/bogus");
        assert_eq!(r.status, 404);
        let j = Json::parse(std::str::from_utf8(&r.body).unwrap()).unwrap();
        assert_eq!(j.get("error").get("code").as_str(), Some("not_found"));
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn oversized_request_is_429() {
        let (svc, h, join) = service();
        let long = "\u{1}".repeat(30);
        let resp = post(
            &svc,
            "/generate",
            &format!(r#"{{"prompt":"{long}","max_new_tokens":30}}"#),
        );
        assert_eq!(resp.status, 429);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("error").get("code").as_str(), Some("admission_rejected"));
        assert!(j.get("error").get("retry_after_ms").as_usize().is_some());
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn metrics_shape_is_sharded_with_config() {
        // Two shards behind an affine router: per-shard gauges are
        // namespaced, totals aggregate, router counters present.
        let mk = || {
            engine::spawn(
                EngineConfig {
                    quant_policy: PolicySpec::uniform(Precision::Int8),
                    ..Default::default()
                },
                || {
                    let spec = ModelSpec::test_tiny();
                    let w = Weights::synthetic(&spec, 7);
                    Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn crate::model::LmBackend>)
                },
            )
        };
        let (h0, j0) = mk();
        let (h1, j1) = mk();
        let mut router = Router::with_config(RouterConfig {
            policy: RoutePolicy::LeastLoaded,
            affinity: Affinity::Session,
            queue_depth: 4,
            overflow_depth: 8,
            default_deadline_ms: 0,
        });
        router.add_engine("shard0", h0.clone());
        router.add_engine("shard1", h1.clone());
        let svc = KvqService::new(Arc::new(router));
        let resp = post(
            &svc,
            "/generate",
            r#"{"prompt":"","max_new_tokens":2,"session":"pin"}"#,
        );
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let m = get(&svc, "/metrics");
        let j = Json::parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(j.get("shards").as_arr().unwrap().len(), 2);
        assert_eq!(j.get("shards").at(1).get("shard").as_usize(), Some(1));
        assert!(j.get("shards").at(0).get("pool_total_blocks").as_f64().is_some());
        assert!(j.get("shards").at(0).get("kernel_isa").as_str().is_some());
        assert_eq!(j.get("router").get("affinity").as_str(), Some("session"));
        assert_eq!(j.get("router").get("queue_depth").as_usize(), Some(4));
        assert_eq!(j.get("router").get("submitted").as_usize(), Some(1));
        // The one finished request shows in the aggregated totals.
        assert_eq!(j.get("requests_finished").as_f64(), Some(1.0));
        h0.drain();
        h1.drain();
        j0.join().unwrap();
        j1.join().unwrap();
    }
}
