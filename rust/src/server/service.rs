//! The HTTP service: router + tokenizer behind request handlers.

use super::api::{error_response, generate_response, GenerateRequest};
use super::http::{HttpRequest, HttpResponse};
use crate::coordinator::request::{collect_response, FinishReason};
use crate::coordinator::Router;
use crate::model::ByteTokenizer;
use crate::util::json::{obj, Json};
use std::sync::Arc;

/// Shareable service state.
pub struct KvqService {
    pub router: Arc<Router>,
    pub tokenizer: ByteTokenizer,
    /// Effective serving configuration served at `GET /config`
    /// (see [`crate::server::api::config_response`]).
    pub info: Json,
}

impl KvqService {
    pub fn new(router: Arc<Router>) -> KvqService {
        KvqService { router, tokenizer: ByteTokenizer::new(), info: Json::Null }
    }

    /// Like [`KvqService::new`], with a `/config` payload.
    pub fn with_info(router: Arc<Router>, info: Json) -> KvqService {
        KvqService { router, tokenizer: ByteTokenizer::new(), info }
    }

    /// Top-level request dispatch.
    pub fn handle(&self, req: HttpRequest) -> HttpResponse {
        match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => HttpResponse::json(200, &obj([("status", "ok".into())])),
            ("GET", "/metrics") => self.metrics(),
            ("GET", "/config") => HttpResponse::json(200, &self.info),
            ("POST", "/generate") => self.generate(&req),
            ("GET", _) | ("POST", _) => {
                HttpResponse::json(404, &error_response("unknown endpoint"))
            }
            _ => HttpResponse::json(405, &error_response("method not allowed")),
        }
    }

    fn metrics(&self) -> HttpResponse {
        let mut engines = Vec::new();
        for name in self.router.engine_names() {
            let snap = self.router.engine(name).unwrap().metrics.snapshot();
            let mut j = snap.to_json();
            if let Json::Obj(ref mut o) = j {
                o.insert("engine".into(), Json::Str(name.to_string()));
            }
            engines.push(j);
        }
        HttpResponse::json(200, &obj([("engines", Json::Arr(engines))]))
    }

    fn generate(&self, req: &HttpRequest) -> HttpResponse {
        let body = match req.body_str() {
            Ok(b) => b,
            Err(e) => return HttpResponse::json(400, &error_response(&format!("{e}"))),
        };
        let greq = match GenerateRequest::parse(body) {
            Ok(r) => r,
            Err(e) => return HttpResponse::json(400, &error_response(&format!("{e}"))),
        };
        let prompt = self.tokenizer.encode(&greq.prompt);
        let submit = match &greq.engine {
            Some(name) => self.router.submit_to(
                name,
                prompt,
                greq.max_new_tokens,
                greq.sampling(),
            ),
            None => self.router.submit(prompt, greq.max_new_tokens, greq.sampling()),
        };
        let (id, rx) = match submit {
            Ok(x) => x,
            Err(e) => return HttpResponse::json(400, &error_response(&format!("{e}"))),
        };
        let (tokens, reason, ttft, elapsed) = collect_response(&rx);
        let (status, reason_str) = match &reason {
            FinishReason::Length => (200, "length".to_string()),
            FinishReason::Stop => (200, "stop".to_string()),
            FinishReason::CapacityExhausted => (200, "capacity".to_string()),
            FinishReason::Rejected(c) => (429, format!("rejected: {c}")),
            FinishReason::Error(c) => (500, format!("error: {c}")),
        };
        let text = self.tokenizer.decode(&tokens);
        HttpResponse::json(
            status,
            &generate_response(id, &text, &tokens, &reason_str, ttft, elapsed),
        )
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::coordinator::engine::{self, EngineConfig};
    use crate::coordinator::router::RoutePolicy;
    use crate::kvcache::{PolicySpec, Precision};
    use crate::model::runner::CpuBackend;
    use crate::model::weights::Weights;
    use crate::model::ModelSpec;

    fn service() -> (KvqService, crate::coordinator::EngineHandle, std::thread::JoinHandle<()>) {
        let (h, join) = engine::spawn(
            EngineConfig {
                quant_policy: PolicySpec::uniform(Precision::Int8),
                ..Default::default()
            },
            || {
                let spec = ModelSpec::test_tiny();
                let w = Weights::synthetic(&spec, 7);
                Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn crate::model::LmBackend>)
            },
        );
        let mut router = Router::new(RoutePolicy::RoundRobin);
        router.add_engine("int8", h.clone());
        (KvqService::new(Arc::new(router)), h, join)
    }

    fn post(svc: &KvqService, path: &str, body: &str) -> HttpResponse {
        svc.handle(HttpRequest {
            method: "POST".into(),
            path: path.into(),
            headers: Default::default(),
            body: body.as_bytes().to_vec(),
        })
    }

    fn get(svc: &KvqService, path: &str) -> HttpResponse {
        svc.handle(HttpRequest {
            method: "GET".into(),
            path: path.into(),
            headers: Default::default(),
            body: vec![],
        })
    }

    #[test]
    fn health_and_metrics() {
        let (svc, h, join) = service();
        assert_eq!(get(&svc, "/health").status, 200);
        let m = get(&svc, "/metrics");
        assert_eq!(m.status, 200);
        let j = Json::parse(std::str::from_utf8(&m.body).unwrap()).unwrap();
        assert_eq!(j.get("engines").at(0).get("engine").as_str(), Some("int8"));
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn generate_roundtrip() {
        let (svc, h, join) = service();
        // vocab is 64 in test-tiny: use low-byte prompt chars (so ids < 64).
        let resp = post(&svc, "/generate", r#"{"prompt":"","max_new_tokens":3}"#);
        assert_eq!(resp.status, 200, "{}", String::from_utf8_lossy(&resp.body));
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("finish_reason").as_str(), Some("length"));
        assert_eq!(j.get("tokens").as_arr().unwrap().len(), 3);
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn config_endpoint_serves_info() {
        let (mut svc, h, join) = service();
        svc.info = crate::server::api::config_response(
            "test-tiny",
            "uniform:int8",
            "int8",
            "cpu",
            2,
            "optimistic",
            0,
            "vectorized",
            true,
            "auto",
            0,
        );
        let resp = get(&svc, "/config");
        assert_eq!(resp.status, 200);
        let j = Json::parse(std::str::from_utf8(&resp.body).unwrap()).unwrap();
        assert_eq!(j.get("parallelism").as_usize(), Some(2));
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn bad_requests_are_4xx() {
        let (svc, h, join) = service();
        assert_eq!(post(&svc, "/generate", "not json").status, 400);
        assert_eq!(post(&svc, "/generate", r#"{"nope":1}"#).status, 400);
        assert_eq!(get(&svc, "/bogus").status, 404);
        h.drain();
        join.join().unwrap();
    }

    #[test]
    fn oversized_request_is_429() {
        let (svc, h, join) = service();
        let long = "\u{1}".repeat(30);
        let resp = post(
            &svc,
            "/generate",
            &format!(r#"{{"prompt":"{long}","max_new_tokens":30}}"#),
        );
        assert_eq!(resp.status, 429);
        h.drain();
        join.join().unwrap();
    }
}
