//! JSON API shapes for the HTTP endpoints.

use crate::model::sample::SamplingParams;
use crate::util::json::{obj, Json};
use anyhow::{anyhow, Result};

/// POST /generate body.
#[derive(Debug, Clone, PartialEq)]
pub struct GenerateRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Optional engine name (A/B routing); None = router policy.
    pub engine: Option<String>,
}

impl GenerateRequest {
    pub fn parse(body: &str) -> Result<GenerateRequest> {
        let j = Json::parse(body).map_err(|e| anyhow!("bad json: {e}"))?;
        let prompt = j
            .get("prompt")
            .as_str()
            .ok_or_else(|| anyhow!("missing 'prompt' (string)"))?
            .to_string();
        Ok(GenerateRequest {
            prompt,
            max_new_tokens: j.get("max_new_tokens").as_usize().unwrap_or(16),
            temperature: j.get("temperature").as_f64().unwrap_or(0.0) as f32,
            top_k: j.get("top_k").as_usize().unwrap_or(0),
            seed: j.get("seed").as_usize().unwrap_or(0) as u64,
            engine: j.get("engine").as_str().map(String::from),
        })
    }

    pub fn sampling(&self) -> SamplingParams {
        SamplingParams { temperature: self.temperature, top_k: self.top_k, seed: self.seed }
    }
}

/// /generate response body.
pub fn generate_response(
    id: u64,
    text: &str,
    tokens: &[i32],
    finish: &str,
    ttft: f64,
    elapsed: f64,
) -> Json {
    obj([
        ("id", (id as usize).into()),
        ("text", text.into()),
        ("tokens", tokens.iter().map(|&t| Json::Num(t as f64)).collect::<Vec<_>>().into()),
        ("finish_reason", finish.into()),
        ("ttft_s", ttft.into()),
        ("elapsed_s", elapsed.into()),
    ])
}

pub fn error_response(msg: &str) -> Json {
    obj([("error", msg.into())])
}

/// `GET /config` body: the effective serving configuration — the cache
/// quantization policy (`quant_policy`; `precision` keeps the legacy
/// shorthand: the uniform precision name, or "mixed"), the resolved
/// `parallelism` worker count of the quantization runtime, the
/// scheduler's memory policy (`admission_mode`, `prefix_cache_blocks`),
/// and the decode data path (`attention_kernel` fused-kernel variant,
/// whether zero-copy `paged_decode` is active, and the `kernel_backend`
/// knob — the ISA it resolved to is served at `GET /metrics` as
/// `kernel_isa`).
#[allow(clippy::too_many_arguments)]
pub fn config_response(
    model: &str,
    quant_policy: &str,
    precision: &str,
    backend: &str,
    parallelism: usize,
    admission_mode: &str,
    prefix_cache_blocks: usize,
    attention_kernel: &str,
    paged_decode: bool,
    kernel_backend: &str,
    port: u16,
) -> Json {
    obj([
        ("model", model.into()),
        ("quant_policy", quant_policy.into()),
        ("precision", precision.into()),
        ("backend", backend.into()),
        ("parallelism", parallelism.into()),
        ("admission_mode", admission_mode.into()),
        ("prefix_cache_blocks", prefix_cache_blocks.into()),
        ("attention_kernel", attention_kernel.into()),
        ("paged_decode", Json::Bool(paged_decode)),
        ("kernel_backend", kernel_backend.into()),
        ("port", (port as usize).into()),
    ])
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_request() {
        let r = GenerateRequest::parse(r#"{"prompt":"hi"}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(r.temperature, 0.0);
        assert!(r.engine.is_none());
    }

    #[test]
    fn parses_full_request() {
        let r = GenerateRequest::parse(
            r#"{"prompt":"x","max_new_tokens":4,"temperature":0.7,
                "top_k":40,"seed":9,"engine":"fp32"}"#,
        )
        .unwrap();
        assert_eq!(r.max_new_tokens, 4);
        assert_eq!(r.top_k, 40);
        assert_eq!(r.engine.as_deref(), Some("fp32"));
        assert_eq!(r.sampling().seed, 9);
    }

    #[test]
    fn rejects_missing_prompt() {
        assert!(GenerateRequest::parse(r#"{"max_new_tokens":4}"#).is_err());
        assert!(GenerateRequest::parse("not json").is_err());
    }

    #[test]
    fn config_response_shape() {
        let j = config_response(
            "kvq-3m",
            "k8v4",
            "mixed",
            "cpu",
            4,
            "optimistic",
            512,
            "vectorized",
            true,
            "auto",
            8080,
        );
        assert_eq!(j.get("model").as_str(), Some("kvq-3m"));
        assert_eq!(j.get("quant_policy").as_str(), Some("k8v4"));
        assert_eq!(j.get("precision").as_str(), Some("mixed"));
        assert_eq!(j.get("parallelism").as_usize(), Some(4));
        assert_eq!(j.get("admission_mode").as_str(), Some("optimistic"));
        assert_eq!(j.get("prefix_cache_blocks").as_usize(), Some(512));
        assert_eq!(j.get("attention_kernel").as_str(), Some("vectorized"));
        assert_eq!(j.get("paged_decode").as_bool(), Some(true));
        assert_eq!(j.get("kernel_backend").as_str(), Some("auto"));
        assert_eq!(j.get("port").as_usize(), Some(8080));
    }

    #[test]
    fn response_shape() {
        let j = generate_response(3, "out", &[1, 2], "length", 0.1, 0.2);
        assert_eq!(j.get("id").as_usize(), Some(3));
        assert_eq!(j.get("tokens").at(1).as_f64(), Some(2.0));
        assert_eq!(j.get("finish_reason").as_str(), Some("length"));
    }
}
