//! JSON API shapes for the HTTP endpoints.
//!
//! Versioning: `GET /config` and `GET /metrics` carry
//! `schema_version` = [`SCHEMA_VERSION`]. v1 was the single-engine shape
//! (flat `engines` array, stringly `{"error": "..."}` bodies); v2 adds
//! per-shard namespacing (`shards[i].*` with aggregated top-level
//! totals; `engines` kept as a legacy alias), router counters, and typed
//! [`ApiError`] bodies (`error.code` / `error.message` /
//! `error.retry_after_ms`); v3 adds the prefix-trie gauges
//! (`prefix_partial_hits`, `prefix_saved_tokens`, `prefix_trie_nodes`),
//! per shard and summed into the top-level totals like every other
//! numeric gauge; v4 adds the tiered-cache surface — physical sub-pool
//! gauges (`pool_physical_bytes`, `pool_fragmentation_bytes`,
//! `cache_physical_bytes_{fp32,int8,int4}`; the logical `cache_bytes_*`
//! keys stay pinned), the cold-tier `tier_*` counters
//! (`tier_{hot,cold}_blocks`, `tier_{demotions,promotions}`,
//! `tier_prefetch_{hits,misses}`, timings, compression ratio), and the
//! `cold_tier_blocks` / `snapshot_path` / `prefetch_depth` knobs on
//! `GET /config`. Strictly additive over v3 — every v3 key keeps its
//! meaning (pinned by the v3→v4 compat test); v5 adds the fault-tolerance
//! surface — per-shard `watchdog_state` / `shard_restarts` and the
//! cancellation counters (`deadline_cancels`, `stall_cancels`,
//! `client_cancels`, `streams_failed`), top-level `shard_restarts` /
//! `watchdog_state` (worst shard) / `fault_injections`, the tier
//! hardening counters (`tier_snapshot_rejected`,
//! `tier_decompress_errors`), router `shard_restarts`, and the
//! `default_deadline_ms` / `stall_timeout_ms` / `fault_spec` knobs on
//! `GET /config`. Strictly additive over v4 (pinned by the v4→v5 compat
//! test).

use crate::config::ServeConfig;
use crate::coordinator::router::{Router, SubmitError};
use crate::model::sample::SamplingParams;
use crate::util::json::{obj, Json};
use anyhow::{anyhow, Result};

use super::http::HttpResponse;
use crate::coordinator::request::Priority;

/// Wire-schema version served on every structured GET payload.
pub const SCHEMA_VERSION: u64 = 5;

/// POST /generate body.
#[derive(Debug, Clone, PartialEq)]
pub struct GenerateRequest {
    pub prompt: String,
    pub max_new_tokens: usize,
    pub temperature: f32,
    pub top_k: usize,
    pub seed: u64,
    /// Optional engine name (A/B routing); None = router policy.
    pub engine: Option<String>,
    /// Session key for shard affinity (keeps a session's prefix-cache
    /// entries on one shard).
    pub session: Option<String>,
    /// Priority class (`batch|normal|interactive`); None = normal.
    pub priority: Option<Priority>,
    /// Per-request deadline in milliseconds; expired requests are
    /// cancelled mid-flight with a 408. `0` explicitly disables the
    /// server default; absent inherits `--default-deadline-ms`.
    pub deadline_ms: Option<u64>,
}

impl GenerateRequest {
    pub fn parse(body: &str) -> Result<GenerateRequest> {
        let j = Json::parse(body).map_err(|e| anyhow!("bad json: {e}"))?;
        let prompt = j
            .get("prompt")
            .as_str()
            .ok_or_else(|| anyhow!("missing 'prompt' (string)"))?
            .to_string();
        let priority = match j.get("priority").as_str() {
            Some(s) => Some(
                Priority::parse(s)
                    .ok_or_else(|| anyhow!("bad priority {s:?} (batch|normal|interactive)"))?,
            ),
            None => None,
        };
        Ok(GenerateRequest {
            prompt,
            max_new_tokens: j.get("max_new_tokens").as_usize().unwrap_or(16),
            temperature: j.get("temperature").as_f64().unwrap_or(0.0) as f32,
            top_k: j.get("top_k").as_usize().unwrap_or(0),
            seed: j.get("seed").as_usize().unwrap_or(0) as u64,
            engine: j.get("engine").as_str().map(String::from),
            session: j.get("session").as_str().map(String::from),
            priority,
            deadline_ms: j.get("deadline_ms").as_usize().map(|ms| ms as u64),
        })
    }

    pub fn sampling(&self) -> SamplingParams {
        SamplingParams { temperature: self.temperature, top_k: self.top_k, seed: self.seed }
    }
}

/// /generate response body.
pub fn generate_response(
    id: u64,
    text: &str,
    tokens: &[i32],
    finish: &str,
    ttft: f64,
    elapsed: f64,
) -> Json {
    obj([
        ("id", (id as usize).into()),
        ("text", text.into()),
        ("tokens", tokens.iter().map(|&t| Json::Num(t as f64)).collect::<Vec<_>>().into()),
        ("finish_reason", finish.into()),
        ("ttft_s", ttft.into()),
        ("elapsed_s", elapsed.into()),
    ])
}

/// Typed API error: machine-readable `code`, human `message`, and an
/// optional backpressure hint — replaces the v1 stringly bodies so
/// clients can branch on `error.code` instead of parsing prose.
#[derive(Debug, Clone, PartialEq)]
pub struct ApiError {
    pub status: u16,
    pub code: &'static str,
    pub message: String,
    pub retry_after_ms: Option<u64>,
}

impl ApiError {
    pub fn bad_request(msg: impl Into<String>) -> ApiError {
        ApiError { status: 400, code: "bad_request", message: msg.into(), retry_after_ms: None }
    }

    pub fn not_found(msg: impl Into<String>) -> ApiError {
        ApiError { status: 404, code: "not_found", message: msg.into(), retry_after_ms: None }
    }

    pub fn method_not_allowed() -> ApiError {
        ApiError {
            status: 405,
            code: "method_not_allowed",
            message: "method not allowed".into(),
            retry_after_ms: None,
        }
    }

    /// 429: the engine's admission control rejected the request under
    /// overload (it cannot ever fit, or queues are past the watermark).
    pub fn admission_rejected(cause: impl Into<String>, retry_after_ms: u64) -> ApiError {
        ApiError {
            status: 429,
            code: "admission_rejected",
            message: cause.into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    /// 408: the request's deadline expired before generation finished
    /// (queued past it, or cancelled mid-decode by the engine).
    pub fn deadline_exceeded(msg: impl Into<String>) -> ApiError {
        ApiError {
            status: 408,
            code: "deadline_exceeded",
            message: msg.into(),
            retry_after_ms: None,
        }
    }

    /// 503: the request's home shard died mid-flight (its stream was
    /// failed typed while the supervisor respawns the shard). Safe to
    /// retry: re-driven requests are byte-identical by construction.
    pub fn shard_failed(retry_after_ms: u64) -> ApiError {
        ApiError {
            status: 503,
            code: "shard_failed",
            message: "shard failed mid-request; retry".into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    /// 503: every shard queue and the overflow queue are full.
    pub fn saturated(retry_after_ms: u64) -> ApiError {
        ApiError {
            status: 503,
            code: "shard_saturated",
            message: "all shards saturated".into(),
            retry_after_ms: Some(retry_after_ms),
        }
    }

    pub fn unavailable(msg: impl Into<String>) -> ApiError {
        ApiError { status: 503, code: "unavailable", message: msg.into(), retry_after_ms: None }
    }

    pub fn internal(msg: impl Into<String>) -> ApiError {
        ApiError { status: 500, code: "internal", message: msg.into(), retry_after_ms: None }
    }

    pub fn from_submit(e: SubmitError) -> ApiError {
        match e {
            SubmitError::Invalid(m) => ApiError::bad_request(m),
            SubmitError::Saturated { retry_after_ms } => ApiError::saturated(retry_after_ms),
            SubmitError::Unavailable(m) => ApiError::unavailable(m),
        }
    }

    /// `{"error": {"code", "message", "retry_after_ms"?}}`.
    pub fn body(&self) -> Json {
        let mut fields = vec![
            ("code", self.code.into()),
            ("message", self.message.as_str().into()),
        ];
        if let Some(ms) = self.retry_after_ms {
            fields.push(("retry_after_ms", (ms as usize).into()));
        }
        obj([("error", obj(fields))])
    }

    pub fn to_response(&self) -> HttpResponse {
        HttpResponse::json(self.status, &self.body())
    }
}

/// `GET /config` body, rendered straight from the [`ServeConfig`] — the
/// effective serving configuration: the cache quantization policy
/// (`quant_policy`; `precision` keeps the legacy shorthand), the
/// resolved `parallelism` worker count, the scheduler's memory policy
/// (`admission_mode`, `prefix_cache_blocks`), the decode data path
/// (`attention_kernel`, `paged_decode`, `kernel_backend`,
/// `decode_batching` — the resolved
/// ISA is served at `GET /metrics` as `kernel_isa`), the sharded
/// front door (`shards`, `affinity`, `queue_depth`, `overflow_depth`),
/// and the tiered-cache knobs (`cold_tier_blocks` — `null` means
/// auto-sized to the hot pool; `snapshot_path` — `null` means no
/// persistence; `prefetch_depth`).
pub fn config_response(cfg: &ServeConfig, port: u16, threads: usize) -> Json {
    obj([
        ("schema_version", (SCHEMA_VERSION as usize).into()),
        ("model", cfg.model.as_str().into()),
        ("quant_policy", cfg.quant_policy.name().as_str().into()),
        ("precision", cfg.precision_label().into()),
        ("backend", cfg.backend.name().into()),
        ("parallelism", threads.into()),
        ("admission_mode", cfg.batcher.admission.mode.name().into()),
        ("prefix_cache_blocks", cfg.prefix_cache_blocks.into()),
        ("attention_kernel", cfg.attention_kernel.name().into()),
        ("paged_decode", Json::Bool(cfg.paged_decode)),
        ("kernel_backend", cfg.kernel_backend.name().into()),
        ("decode_batching", cfg.decode_batching.name().into()),
        ("shards", cfg.shards.into()),
        ("affinity", cfg.affinity.name().into()),
        ("queue_depth", cfg.queue_depth.into()),
        ("overflow_depth", cfg.overflow_depth.into()),
        ("cold_tier_blocks", cfg.cold_tier_blocks.map_or(Json::Null, |n| n.into())),
        ("snapshot_path", cfg.snapshot_path.as_deref().map_or(Json::Null, Json::from)),
        ("prefetch_depth", cfg.prefetch_depth.into()),
        ("default_deadline_ms", (cfg.default_deadline_ms as usize).into()),
        ("stall_timeout_ms", (cfg.stall_timeout_ms as usize).into()),
        ("fault_spec", cfg.fault_spec.as_deref().map_or(Json::Null, Json::from)),
        ("port", (port as usize).into()),
    ])
}

/// `GET /metrics` body: `shards[i].*` per-shard gauges (each shard's
/// pool, prefix-cache, preemption, and kernel gauges under its own
/// object, tagged with `shard` index and `engine` name), aggregated
/// top-level totals (so v1 single-engine consumers keep reading the
/// same keys), `router` dispatch counters, and the legacy `engines`
/// alias.
pub fn metrics_response(router: &Router) -> Json {
    use std::collections::BTreeMap;
    let mut shards = Vec::new();
    let mut totals: BTreeMap<String, f64> = BTreeMap::new();
    let mut kernel_isa = String::new();
    let states = router.shard_states();
    let mut worst_state = crate::coordinator::engine::ShardState::Ok;
    for (i, (name, handle)) in router.shards().iter().enumerate() {
        let snap = handle.metrics.snapshot();
        let mut j = snap.to_json();
        if let Json::Obj(ref mut o) = j {
            o.insert("engine".into(), Json::Str(name.clone()));
            o.insert("shard".into(), Json::Num(i as f64));
            if let Some((_, state, restarts)) = states.get(i) {
                o.insert("watchdog_state".into(), Json::Str(state.name().into()));
                // Num: sums into the top-level `shard_restarts` total.
                o.insert("shard_restarts".into(), Json::Num(*restarts as f64));
                if severity(*state) > severity(worst_state) {
                    worst_state = *state;
                }
            }
        }
        // Every numeric gauge sums into a same-named top-level total;
        // the ISA string stands for all shards (one process, one CPU).
        if let Json::Obj(ref o) = j {
            for (k, v) in o {
                match v {
                    Json::Num(n) if k != "shard" => {
                        *totals.entry(k.clone()).or_insert(0.0) += n;
                    }
                    Json::Str(s) if k == "kernel_isa" => kernel_isa = s.clone(),
                    _ => {}
                }
            }
        }
        shards.push(j);
    }
    let stats = router.stats();
    let rcfg = router.config();
    let router_j = obj([
        (
            "policy",
            match rcfg.policy {
                crate::coordinator::router::RoutePolicy::RoundRobin => "round_robin".into(),
                crate::coordinator::router::RoutePolicy::LeastLoaded => "least_loaded".into(),
            },
        ),
        ("affinity", rcfg.affinity.name().into()),
        ("queue_depth", rcfg.queue_depth.into()),
        ("overflow_depth", rcfg.overflow_depth.into()),
        ("shards", router.shard_count().into()),
        ("submitted", (stats.submitted as usize).into()),
        ("dispatched", (stats.dispatched as usize).into()),
        ("spillovers", (stats.spillovers as usize).into()),
        ("overflow_enqueued", (stats.overflow_enqueued as usize).into()),
        ("overflow_dispatched", (stats.overflow_dispatched as usize).into()),
        ("overflow_peak", (stats.overflow_peak as usize).into()),
        ("overflow_len", stats.overflow_len.into()),
        ("rejected_saturated", (stats.rejected_saturated as usize).into()),
        ("shard_restarts", (stats.shard_restarts as usize).into()),
    ]);
    let mut top: BTreeMap<String, Json> =
        totals.into_iter().map(|(k, v)| (k, Json::Num(v))).collect();
    top.insert("schema_version".into(), Json::Num(SCHEMA_VERSION as f64));
    top.insert("shards".into(), Json::Arr(shards.clone()));
    top.insert("engines".into(), Json::Arr(shards));
    top.insert("router".into(), router_j);
    // Worst shard health (dead > restarting > stalled > ok) and the
    // process-wide fault-injection gauge (0 when no spec is armed).
    top.insert("watchdog_state".into(), Json::Str(worst_state.name().into()));
    top.insert("fault_injections".into(), Json::Num(crate::util::fault::injections() as f64));
    // A shardless router still serves the key (totals only sum what the
    // shard loop inserted).
    top.entry("shard_restarts".into()).or_insert(Json::Num(0.0));
    if !kernel_isa.is_empty() {
        top.insert("kernel_isa".into(), Json::Str(kernel_isa));
    }
    Json::Obj(top)
}

/// Health-state severity for the worst-of rollup: a dead shard outranks
/// one mid-restart, which outranks a stalled-but-serving one.
fn severity(s: crate::coordinator::engine::ShardState) -> u8 {
    use crate::coordinator::engine::ShardState;
    match s {
        ShardState::Ok => 0,
        ShardState::Stalled => 1,
        ShardState::Restarting => 2,
        ShardState::Dead => 3,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_minimal_request() {
        let r = GenerateRequest::parse(r#"{"prompt":"hi"}"#).unwrap();
        assert_eq!(r.prompt, "hi");
        assert_eq!(r.max_new_tokens, 16);
        assert_eq!(r.temperature, 0.0);
        assert!(r.engine.is_none());
        assert!(r.session.is_none());
        assert!(r.priority.is_none());
        assert!(r.deadline_ms.is_none());
    }

    #[test]
    fn parses_full_request() {
        let r = GenerateRequest::parse(
            r#"{"prompt":"x","max_new_tokens":4,"temperature":0.7,
                "top_k":40,"seed":9,"engine":"fp32",
                "session":"user-17","priority":"interactive",
                "deadline_ms":1500}"#,
        )
        .unwrap();
        assert_eq!(r.max_new_tokens, 4);
        assert_eq!(r.top_k, 40);
        assert_eq!(r.engine.as_deref(), Some("fp32"));
        assert_eq!(r.session.as_deref(), Some("user-17"));
        assert_eq!(r.priority, Some(Priority::Interactive));
        assert_eq!(r.sampling().seed, 9);
        assert_eq!(r.deadline_ms, Some(1500));
        // Explicit 0 = "no deadline", distinct from absent = inherit.
        let r0 = GenerateRequest::parse(r#"{"prompt":"x","deadline_ms":0}"#).unwrap();
        assert_eq!(r0.deadline_ms, Some(0));
    }

    #[test]
    fn rejects_missing_prompt() {
        assert!(GenerateRequest::parse(r#"{"max_new_tokens":4}"#).is_err());
        assert!(GenerateRequest::parse("not json").is_err());
    }

    #[test]
    fn rejects_bad_priority() {
        assert!(GenerateRequest::parse(r#"{"prompt":"x","priority":"vip"}"#).is_err());
    }

    #[test]
    fn config_response_shape() {
        let cfg = ServeConfig::builder()
            .set("model", &Json::Str("kvq-3m".into()))
            .unwrap()
            .set("quant_policy", &Json::Str("k8v4".into()))
            .unwrap()
            .set("backend", &Json::Str("cpu".into()))
            .unwrap()
            .set("prefix_cache_blocks", &Json::Num(512.0))
            .unwrap()
            .shards(2)
            .queue_depth(8)
            .build();
        let j = config_response(&cfg, 8080, 4);
        assert_eq!(j.get("schema_version").as_usize(), Some(SCHEMA_VERSION as usize));
        assert_eq!(j.get("model").as_str(), Some("kvq-3m"));
        assert_eq!(j.get("quant_policy").as_str(), Some("k8v4"));
        assert_eq!(j.get("precision").as_str(), Some("mixed"));
        assert_eq!(j.get("backend").as_str(), Some("cpu"));
        assert_eq!(j.get("parallelism").as_usize(), Some(4));
        assert_eq!(j.get("admission_mode").as_str(), Some("optimistic"));
        assert_eq!(j.get("prefix_cache_blocks").as_usize(), Some(512));
        assert_eq!(j.get("attention_kernel").as_str(), Some("vectorized"));
        assert_eq!(j.get("paged_decode").as_bool(), Some(true));
        assert_eq!(j.get("kernel_backend").as_str(), Some("auto"));
        assert_eq!(j.get("decode_batching").as_str(), Some("auto"));
        assert_eq!(j.get("shards").as_usize(), Some(2));
        assert_eq!(j.get("affinity").as_str(), Some("session"));
        assert_eq!(j.get("queue_depth").as_usize(), Some(8));
        assert_eq!(j.get("port").as_usize(), Some(8080));
        // v4 tier knobs: unset capacity/path serve as null, depth always.
        assert!(matches!(j.get("cold_tier_blocks"), Json::Null));
        assert!(matches!(j.get("snapshot_path"), Json::Null));
        assert_eq!(j.get("prefetch_depth").as_usize(), Some(2));
        // v5 fault-tolerance knobs: defaults are off/null.
        assert_eq!(j.get("default_deadline_ms").as_usize(), Some(0));
        assert_eq!(j.get("stall_timeout_ms").as_usize(), Some(0));
        assert!(matches!(j.get("fault_spec"), Json::Null));
        let cfg2 = ServeConfig::builder()
            .set("cold_tier_blocks", &Json::Num(64.0))
            .unwrap()
            .set("snapshot_path", &Json::Str("/tmp/kvq.snap".into()))
            .unwrap()
            .build();
        let j2 = config_response(&cfg2, 8080, 1);
        assert_eq!(j2.get("cold_tier_blocks").as_usize(), Some(64));
        assert_eq!(j2.get("snapshot_path").as_str(), Some("/tmp/kvq.snap"));
    }

    #[test]
    fn schema_v5_is_additive_over_v4() {
        // Every bump is strictly additive: each prior version's metrics
        // keys keep their names and numeric types; new keys ride along.
        // A v3 or v4 consumer reading a v5 payload sees exactly what it
        // saw before (plus keys it ignores).
        assert_eq!(SCHEMA_VERSION, 5);
        let j = crate::coordinator::metrics::Metrics::new().snapshot().to_json();
        let v3_keys = [
            "uptime_s", "requests_submitted", "requests_finished", "requests_rejected",
            "requests_errored", "tokens_generated", "prefill_tokens", "engine_steps",
            "preemptions", "resumes", "recompute_tokens", "decode_steps", "gather_secs",
            "attend_secs", "cache_bytes_read", "mq_passes", "blocks_deduped",
            "cache_bytes_per_token", "decode_ns_per_token", "prefix_lookups", "prefix_hits",
            "prefix_partial_hits", "prefix_saved_tokens", "prefix_trie_nodes",
            "prefix_hit_rate", "tokens_per_sec", "ttft_p50_s", "ttft_p99_s", "tpot_p50_s",
            "tpot_p99_s", "e2e_p50_s", "e2e_p99_s", "step_p50_s", "cache_utilization",
            "pool_used_blocks", "pool_total_blocks", "pool_logical_blocks",
            "prefix_cache_blocks", "running", "running_peak", "waiting", "preempted",
            "cache_bytes_fp32", "cache_bytes_int8", "cache_bytes_int4",
        ];
        for k in v3_keys {
            assert!(j.get(k).as_f64().is_some(), "v3 numeric key {k} must survive v4");
        }
        assert!(j.get("quant_policy").as_str().is_some());
        assert!(j.get("kernel_isa").as_str().is_some());
        let v4_keys = [
            "pool_physical_bytes", "pool_fragmentation_bytes", "cache_physical_bytes_fp32",
            "cache_physical_bytes_int8", "cache_physical_bytes_int4", "tier_hot_blocks",
            "tier_cold_blocks", "tier_cold_entries", "tier_demotions", "tier_promotions",
            "tier_prefetch_hits", "tier_prefetch_misses", "tier_cold_evictions",
            "tier_preemptions_avoided",
            "tier_snapshot_loaded", "tier_cold_raw_bytes", "tier_cold_comp_bytes",
            "tier_compression_ratio", "tier_demote_secs", "tier_promote_secs",
            "tier_decompress_secs",
        ];
        for k in v4_keys {
            assert!(j.get(k).as_f64().is_some(), "v4 key {k} must be present and numeric");
        }
        let v5_keys = [
            "deadline_cancels", "stall_cancels", "client_cancels", "streams_failed",
            "tier_snapshot_rejected", "tier_decompress_errors",
        ];
        for k in v5_keys {
            assert!(j.get(k).as_f64().is_some(), "v5 key {k} must be present and numeric");
        }
    }

    #[test]
    fn supervision_metrics_are_served() {
        // Even a shardless router serves the v5 supervision keys: the
        // worst-of health rollup defaults to "ok", restarts to 0, and the
        // fault gauge reads the process-wide counter.
        let router = Router::new(crate::coordinator::router::RoutePolicy::RoundRobin);
        let j = metrics_response(&router);
        assert_eq!(j.get("schema_version").as_usize(), Some(5));
        assert_eq!(j.get("watchdog_state").as_str(), Some("ok"));
        assert_eq!(j.get("shard_restarts").as_usize(), Some(0));
        assert!(j.get("fault_injections").as_f64().is_some());
        assert_eq!(j.get("router").get("shard_restarts").as_usize(), Some(0));
    }

    #[test]
    fn error_bodies_are_typed() {
        let e = ApiError::admission_rejected("would never fit", 100);
        assert_eq!(e.status, 429);
        let j = e.body();
        assert_eq!(j.get("error").get("code").as_str(), Some("admission_rejected"));
        assert_eq!(j.get("error").get("message").as_str(), Some("would never fit"));
        assert_eq!(j.get("error").get("retry_after_ms").as_usize(), Some(100));

        let e = ApiError::from_submit(SubmitError::Saturated { retry_after_ms: 250 });
        assert_eq!(e.status, 503);
        assert_eq!(e.code, "shard_saturated");
        assert_eq!(e.retry_after_ms, Some(250));

        let e = ApiError::from_submit(SubmitError::Invalid("empty prompt".into()));
        assert_eq!(e.status, 400);
        assert_eq!(e.body().get("error").get("code").as_str(), Some("bad_request"));
        assert!(e.body().get("error").get("retry_after_ms").as_usize().is_none());

        let r = ApiError::not_found("unknown endpoint").to_response();
        assert_eq!(r.status, 404);

        let e = ApiError::deadline_exceeded("deadline expired after 3 tokens");
        assert_eq!(e.status, 408);
        assert_eq!(e.body().get("error").get("code").as_str(), Some("deadline_exceeded"));

        let e = ApiError::shard_failed(120);
        assert_eq!(e.status, 503);
        assert_eq!(e.code, "shard_failed");
        assert_eq!(e.retry_after_ms, Some(120));
    }

    #[test]
    fn response_shape() {
        let j = generate_response(3, "out", &[1, 2], "length", 0.1, 0.2);
        assert_eq!(j.get("id").as_usize(), Some(3));
        assert_eq!(j.get("tokens").at(1).as_f64(), Some(2.0));
        assert_eq!(j.get("finish_reason").as_str(), Some("length"));
    }
}
