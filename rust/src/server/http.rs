//! Minimal HTTP/1.1 server on std::net (hyper/axum substitute).
//!
//! Supports: GET/POST, headers, Content-Length bodies (no chunked
//! requests), keep-alive off (Connection: close on every response —
//! simple and correct). Thread-per-connection with a connection cap.

use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, AtomicUsize, Ordering};
use std::sync::Arc;

/// Parsed request.
#[derive(Debug, Clone)]
pub struct HttpRequest {
    pub method: String,
    pub path: String,
    pub headers: BTreeMap<String, String>,
    pub body: Vec<u8>,
}

impl HttpRequest {
    pub fn body_str(&self) -> Result<&str> {
        std::str::from_utf8(&self.body).context("non-utf8 body")
    }
}

/// Response under construction.
#[derive(Debug, Clone)]
pub struct HttpResponse {
    pub status: u16,
    pub content_type: String,
    pub body: Vec<u8>,
}

impl HttpResponse {
    pub fn json(status: u16, body: &crate::util::json::Json) -> HttpResponse {
        HttpResponse {
            status,
            content_type: "application/json".into(),
            body: body.to_string().into_bytes(),
        }
    }

    pub fn text(status: u16, body: &str) -> HttpResponse {
        HttpResponse { status, content_type: "text/plain".into(), body: body.as_bytes().to_vec() }
    }

    fn reason(&self) -> &'static str {
        match self.status {
            200 => "OK",
            400 => "Bad Request",
            404 => "Not Found",
            405 => "Method Not Allowed",
            408 => "Request Timeout",
            429 => "Too Many Requests",
            500 => "Internal Server Error",
            503 => "Service Unavailable",
            _ => "",
        }
    }

    fn write_to(&self, stream: &mut TcpStream) -> std::io::Result<()> {
        let head = format!(
            "HTTP/1.1 {} {}\r\nContent-Type: {}\r\nContent-Length: {}\r\nConnection: close\r\n\r\n",
            self.status,
            self.reason(),
            self.content_type,
            self.body.len()
        );
        stream.write_all(head.as_bytes())?;
        stream.write_all(&self.body)?;
        stream.flush()
    }
}

/// Parse one request from a stream (bounded body size).
pub fn parse_request(stream: &mut TcpStream, max_body: usize) -> Result<HttpRequest> {
    let mut reader = BufReader::new(stream.try_clone().context("clone stream")?);
    let mut line = String::new();
    reader.read_line(&mut line).context("request line")?;
    let mut parts = line.split_whitespace();
    let method = parts.next().ok_or_else(|| anyhow!("missing method"))?.to_string();
    let path = parts.next().ok_or_else(|| anyhow!("missing path"))?.to_string();
    let version = parts.next().unwrap_or("");
    if !version.starts_with("HTTP/1.") {
        bail!("unsupported version {version:?}");
    }

    let mut headers = BTreeMap::new();
    loop {
        let mut h = String::new();
        reader.read_line(&mut h).context("header line")?;
        let h = h.trim_end();
        if h.is_empty() {
            break;
        }
        if let Some((k, v)) = h.split_once(':') {
            headers.insert(k.trim().to_ascii_lowercase(), v.trim().to_string());
        }
    }

    let len: usize = headers.get("content-length").and_then(|v| v.parse().ok()).unwrap_or(0);
    if len > max_body {
        bail!("body too large: {len}");
    }
    let mut body = vec![0u8; len];
    if len > 0 {
        reader.read_exact(&mut body).context("body")?;
    }
    Ok(HttpRequest { method, path, headers, body })
}

/// The server: accepts connections and dispatches to a handler.
pub struct HttpServer {
    listener: TcpListener,
    max_connections: usize,
    max_body: usize,
    shutdown: Arc<AtomicBool>,
}

impl HttpServer {
    /// Bind to `127.0.0.1:port` (port 0 = ephemeral; see `local_port`).
    pub fn bind(port: u16) -> Result<HttpServer> {
        let listener =
            TcpListener::bind(("127.0.0.1", port)).with_context(|| format!("bind :{port}"))?;
        Ok(HttpServer {
            listener,
            max_connections: 64,
            max_body: 1 << 20,
            shutdown: Arc::new(AtomicBool::new(false)),
        })
    }

    pub fn local_port(&self) -> u16 {
        self.listener.local_addr().map(|a| a.port()).unwrap_or(0)
    }

    /// Handle used to stop `serve` from another thread.
    pub fn shutdown_handle(&self) -> Arc<AtomicBool> {
        self.shutdown.clone()
    }

    /// Serve until the shutdown flag flips. Handler runs per connection
    /// on its own thread (bounded by `max_connections`).
    pub fn serve<F>(&self, handler: F)
    where
        F: Fn(HttpRequest) -> HttpResponse + Send + Sync + 'static,
    {
        let handler = Arc::new(handler);
        let live = Arc::new(AtomicUsize::new(0));
        self.listener.set_nonblocking(true).ok();
        while !self.shutdown.load(Ordering::Relaxed) {
            match self.listener.accept() {
                Ok((mut stream, _addr)) => {
                    stream.set_nonblocking(false).ok();
                    if live.load(Ordering::Relaxed) >= self.max_connections {
                        let e = super::api::ApiError::unavailable("connection limit reached");
                        let _ = e.to_response().write_to(&mut stream);
                        continue;
                    }
                    let h = handler.clone();
                    let live2 = live.clone();
                    let max_body = self.max_body;
                    live.fetch_add(1, Ordering::Relaxed);
                    std::thread::spawn(move || {
                        let resp = match parse_request(&mut stream, max_body) {
                            Ok(req) => h(req),
                            Err(e) => {
                                super::api::ApiError::bad_request(format!("bad request: {e}"))
                                    .to_response()
                            }
                        };
                        let _ = resp.write_to(&mut stream);
                        live2.fetch_sub(1, Ordering::Relaxed);
                    });
                }
                Err(ref e) if e.kind() == std::io::ErrorKind::WouldBlock => {
                    std::thread::sleep(std::time::Duration::from_millis(5));
                }
                Err(e) => {
                    crate::warn!("accept error: {e}");
                }
            }
        }
    }
}

/// Tiny blocking HTTP client for tests/examples (same subset).
pub fn http_request(
    port: u16,
    method: &str,
    path: &str,
    body: Option<&str>,
) -> Result<(u16, String)> {
    let mut stream =
        TcpStream::connect(("127.0.0.1", port)).with_context(|| format!("connect :{port}"))?;
    let body = body.unwrap_or("");
    let req = format!(
        "{method} {path} HTTP/1.1\r\nHost: localhost\r\nContent-Length: {}\r\n\r\n{body}",
        body.len()
    );
    stream.write_all(req.as_bytes())?;
    let mut buf = String::new();
    BufReader::new(stream).read_to_string(&mut buf)?;
    let status: u16 = buf
        .split_whitespace()
        .nth(1)
        .and_then(|s| s.parse().ok())
        .ok_or_else(|| anyhow!("bad response: {buf:?}"))?;
    let payload = buf.split("\r\n\r\n").nth(1).unwrap_or("").to_string();
    Ok((status, payload))
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::Ordering;

    fn spawn_server<F>(handler: F) -> (u16, Arc<AtomicBool>, std::thread::JoinHandle<()>)
    where
        F: Fn(HttpRequest) -> HttpResponse + Send + Sync + 'static,
    {
        let server = HttpServer::bind(0).unwrap();
        let port = server.local_port();
        let stop = server.shutdown_handle();
        let join = std::thread::spawn(move || server.serve(handler));
        (port, stop, join)
    }

    #[test]
    fn serves_get_and_post() {
        let (port, stop, join) = spawn_server(|req| match (req.method.as_str(), req.path.as_str()) {
            ("GET", "/health") => HttpResponse::text(200, "ok"),
            ("POST", "/echo") => HttpResponse {
                status: 200,
                content_type: "text/plain".into(),
                body: req.body,
            },
            _ => HttpResponse::text(404, "nope"),
        });

        let (code, body) = http_request(port, "GET", "/health", None).unwrap();
        assert_eq!((code, body.as_str()), (200, "ok"));

        let (code, body) = http_request(port, "POST", "/echo", Some("payload123")).unwrap();
        assert_eq!((code, body.as_str()), (200, "payload123"));

        let (code, _) = http_request(port, "GET", "/missing", None).unwrap();
        assert_eq!(code, 404);

        stop.store(true, Ordering::Relaxed);
        join.join().unwrap();
    }

    #[test]
    fn rejects_malformed_requests() {
        let (port, stop, join) = spawn_server(|_req| HttpResponse::text(200, "ok"));
        let mut stream = TcpStream::connect(("127.0.0.1", port)).unwrap();
        stream.write_all(b"GARBAGE\r\n\r\n").unwrap();
        let mut buf = String::new();
        BufReader::new(stream).read_to_string(&mut buf).unwrap();
        assert!(buf.starts_with("HTTP/1.1 400"), "{buf}");
        // Typed error body, not prose.
        assert!(buf.contains("bad_request"), "{buf}");
        stop.store(true, Ordering::Relaxed);
        join.join().unwrap();
    }

    #[test]
    fn parallel_requests_are_served() {
        let (port, stop, join) = spawn_server(|_req| {
            std::thread::sleep(std::time::Duration::from_millis(20));
            HttpResponse::text(200, "slow")
        });
        let handles: Vec<_> = (0..4)
            .map(|_| {
                std::thread::spawn(move || http_request(port, "GET", "/x", None).unwrap().0)
            })
            .collect();
        for h in handles {
            assert_eq!(h.join().unwrap(), 200);
        }
        stop.store(true, Ordering::Relaxed);
        join.join().unwrap();
    }
}
