//! std-only HTTP front end for the serving stack.
//!
//! * [`http`] — minimal HTTP/1.1 server (request-line + headers +
//!   content-length bodies, thread-per-connection) over `std::net`.
//! * [`api`] — JSON request/response shapes for `/generate`, `/metrics`,
//!   `/health`.
//! * [`service`] — wires the router + tokenizer behind the HTTP handlers.

pub mod api;
pub mod http;
pub mod service;

pub use http::{HttpRequest, HttpResponse, HttpServer};
pub use service::KvqService;
