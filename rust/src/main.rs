//! `kvq` — the CLI entrypoint.
//!
//! Subcommands:
//!   serve      start the HTTP serving stack (INT8 KV cache by default)
//!   generate   one-shot generation from the command line
//!   quantize   quantize a synthetic matrix and report errors/timings
//!   memory     the Table-1 memory model calculator
//!   validate   run the artifact-vs-CPU cross checks
//!   report     print engine metrics from a running server

use anyhow::{bail, Result};
use kvq::config::{Backend, ServeConfig};
use kvq::coordinator::engine;
use kvq::coordinator::router::{RoutePolicy, Router, ShardSpawner};
use kvq::model::runner::{CpuBackend, PjrtBackend};
use kvq::model::weights::Weights;
use kvq::model::{ByteTokenizer, ModelSpec};
use kvq::runtime::Runtime;
use kvq::server::http::{http_request, HttpServer};
use kvq::server::KvqService;
use kvq::util::args::Args;
use std::rc::Rc;
use std::sync::Arc;

fn main() {
    let mut args = Args::parse();
    let cmd = args.subcommand().unwrap_or_else(|| "help".to_string());
    let code = match run(&cmd, args) {
        Ok(()) => 0,
        Err(e) => {
            eprintln!("error: {e:#}");
            1
        }
    };
    std::process::exit(code);
}

fn run(cmd: &str, args: Args) -> Result<()> {
    match cmd {
        "serve" => serve(args),
        "generate" => generate(args),
        "quantize" => quantize(args),
        "memory" => memory(args),
        "validate" => validate(args),
        "report" => report(args),
        "help" | "--help" | "-h" => {
            print!("{}", HELP);
            Ok(())
        }
        other => bail!("unknown command {other:?}; see `kvq help`"),
    }
}

const HELP: &str = "\
kvq — INT8 KV-cache quantization serving stack

USAGE: kvq <command> [flags]

COMMANDS:
  serve      start the HTTP server
             --model kvq-3m|kvq-25m --precision int8|fp32|int4 --port 8080
             --quant-policy uniform:int8|k8v4|sink8[:N]|<table.json>
               (per-(layer,head,K/V) precision policy; --precision P is
               shorthand for uniform:P. Mixed policies and int4 need
               --backend cpu with paged decode on)
             --backend pjrt|cpu --decode-kernel plain|pallas
             --threads N (0 = auto; parallel quantization runtime)
             --admission-mode optimistic|worst-case (preemptive vs
               conservative scheduling; default optimistic)
             --prefix-cache-blocks N (cross-request prompt sharing
               budget in cache blocks; 0 = off)
             --attention-kernel naive|tiled|coarsened|vectorized (fused
               paged-decode kernel variant; outputs identical)
             --paged-decode true|false (zero-copy block-native decode
               when the backend supports it; default true. int4 serving
               requires it + --backend cpu)
             --kernel-backend auto|scalar|simd (SIMD kernel backend for
               the fused attention + cache encode hot loops; auto picks
               AVX2/NEON at runtime, scalar reproduces legacy bytes.
               KVQ_KERNEL_BACKEND env overrides; selected ISA at
               GET /metrics \"kernel_isa\")
             --decode-batching auto|off (fused multi-query batched
               decode: dequantize each physical cache block once per
               wave and fan results to every query sharing it; outputs
               bit-identical to per-sequence. KVQ_DECODE_BATCHING env
               overrides)
             --shards N (engine shards, each with its own block pool +
               prefix cache + thread; default 1)
             --affinity session|prefix|none (home-shard routing; default
               session: hash of the session key, prompt-prefix fallback)
             --queue-depth N (per-shard admission bound; 0 = unbounded.
               Saturated home shards spill to the least-loaded shard,
               then to the router overflow queue)
             --overflow-depth N (router overflow capacity; beyond it,
               submissions get a typed 503; default 256)
             --default-deadline-ms N (default per-request deadline for
               requests that don't carry their own deadline_ms; expired
               streams finish with a typed 408 deadline_exceeded. 0 =
               no default)
             --stall-timeout-ms N (watchdog: a stream with no token
               progress for N ms is flagged, then cancelled with a
               typed stall error at 2N; 0 = off)
             --fault-spec json|file (deterministic fault injection for
               chaos testing, same rule grammar as the KVQ_FAULT env
               var; see util::fault. Injected shard panics are survived:
               the supervisor fails in-flight streams typed, respawns
               the shard, and keeps serving)
             --config file.json (flags override file)
  generate   one-shot generation
             --prompt 'text' --max-new 32 --temperature 0 --model kvq-3m
  quantize   quantize a synthetic (T, D) matrix, report errors + timings
             --tokens 4096 --dim 256 --variant vectorized|all
  memory     Table-1 memory calculator
             --layers 32 --heads 32 --head-dim 128 --seq-len 131072
  validate   cross-check artifacts vs the Rust CPU oracle
  report     fetch /metrics from a running server (--port 8080)
";

fn build_serve_config(args: &Args) -> Result<ServeConfig> {
    let mut cfg = match args.get("config") {
        Some(path) => ServeConfig::from_file(path)?,
        None => ServeConfig::default(),
    };
    cfg.apply_args(args)?;
    Ok(cfg)
}

/// Spawn an engine per the config (factory closures own the thread-local
/// PJRT state).
fn spawn_engine(
    cfg: &ServeConfig,
) -> (kvq::coordinator::EngineHandle, std::thread::JoinHandle<()>) {
    let ecfg = cfg.engine_config();
    match cfg.backend {
        Backend::Pjrt => {
            let model = cfg.model.clone();
            let dir = cfg.artifact_dir.clone();
            let seed = cfg.weight_seed;
            let kernel = cfg.decode_kernel;
            engine::spawn(ecfg, move || {
                let rt = Rc::new(Runtime::new(&dir)?);
                Ok(Box::new(PjrtBackend::new(rt, &model, seed, kernel)?)
                    as Box<dyn kvq::model::LmBackend>)
            })
        }
        Backend::CpuRef => {
            let model = cfg.model.clone();
            let dir = cfg.artifact_dir.clone();
            let seed = cfg.weight_seed;
            engine::spawn(ecfg, move || {
                let spec = load_spec(&dir, &model)?;
                let w = Weights::synthetic(&spec, seed);
                Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
            })
        }
    }
}

/// Reusable shard spawner for supervised serving: the router calls it
/// once at startup and again for every respawn after a shard death, so
/// it rebuilds backend state from cloned config on each incarnation
/// (including reloading `--snapshot-path` prefix snapshots, which
/// restores the warm prefix cache the dead incarnation persisted).
fn shard_spawner(cfg: &ServeConfig) -> ShardSpawner {
    let ecfg = cfg.engine_config();
    let model = cfg.model.clone();
    let dir = cfg.artifact_dir.clone();
    let seed = cfg.weight_seed;
    let kernel = cfg.decode_kernel;
    let backend = cfg.backend;
    Box::new(move |metrics, health| {
        let (model, dir) = (model.clone(), dir.clone());
        match backend {
            Backend::Pjrt => engine::spawn_with(
                ecfg.clone(),
                move || {
                    let rt = Rc::new(Runtime::new(&dir)?);
                    Ok(Box::new(PjrtBackend::new(rt, &model, seed, kernel)?)
                        as Box<dyn kvq::model::LmBackend>)
                },
                metrics,
                health,
            ),
            Backend::CpuRef => engine::spawn_with(
                ecfg.clone(),
                move || {
                    let spec = load_spec(&dir, &model)?;
                    let w = Weights::synthetic(&spec, seed);
                    Ok(Box::new(CpuBackend::new(spec, w)) as Box<dyn kvq::model::LmBackend>)
                },
                metrics,
                health,
            ),
        }
    })
}

/// Model spec from the manifest (so CPU mode matches artifact geometry),
/// falling back to test_tiny when artifacts are absent.
fn load_spec(dir: &str, model: &str) -> Result<ModelSpec> {
    let path = std::path::Path::new(dir).join("manifest.json");
    if path.exists() {
        let manifest = kvq::runtime::Manifest::load(dir)?;
        for m in &manifest.models {
            if m.get("name").as_str() == Some(model) {
                return ModelSpec::from_json(m);
            }
        }
        bail!("model {model:?} not in manifest");
    }
    Ok(ModelSpec::test_tiny())
}

fn serve(args: Args) -> Result<()> {
    let cfg = build_serve_config(&args)?;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    if let Some(spec) = &cfg.fault_spec {
        kvq::util::fault::install_spec(spec)?;
        println!("fault injection armed: {spec}");
    }
    // One engine per shard, each owning its own block pool, prefix
    // cache, and thread; the router front door spreads sessions across
    // them, parks overflow for the pump thread, and respawns any shard
    // whose engine thread dies (supervisor thread).
    let mut router = Router::with_config(cfg.router_config());
    for i in 0..cfg.shards.max(1) {
        let name = if cfg.shards <= 1 {
            cfg.quant_policy.engine_label()
        } else {
            format!("shard{i}")
        };
        router.add_supervised(&name, shard_spawner(&cfg));
    }
    let router = Arc::new(router);
    let _pump = router.spawn_pump();
    let _supervisor = router.spawn_supervisor();
    let threads = kvq::parallel::resolve(cfg.parallelism);
    let server = HttpServer::bind(cfg.port)?;
    // Build the /config payload after bind so it reports the actually
    // bound port (cfg.port may be 0 = ephemeral).
    let info = kvq::server::api::config_response(&cfg, server.local_port(), threads);
    let service = Arc::new(KvqService::with_info(router.clone(), info));
    println!(
        "kvq serving on http://127.0.0.1:{} (model={} policy={} backend={:?} shards={} threads={})",
        server.local_port(),
        cfg.model,
        cfg.quant_policy.name(),
        cfg.backend,
        router.shard_count(),
        threads
    );
    let svc = service.clone();
    server.serve(move |req| svc.handle(req));
    router.stop_supervisor();
    router.stop_pump();
    Ok(())
}

fn generate(args: Args) -> Result<()> {
    let cfg = build_serve_config(&args)?;
    let prompt_text = args.str_or("prompt", "Hello, world");
    let max_new = args.usize_or("max-new", 32);
    let temperature = args.f64_or("temperature", 0.0) as f32;
    let sampling = kvq::model::sample::SamplingParams {
        temperature,
        top_k: args.usize_or("top-k", 0),
        seed: args.u64_or("seed", 0),
    };
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let (handle, join) = spawn_engine(&cfg);
    let mut router = Router::new(RoutePolicy::RoundRobin);
    router.add_engine("main", handle.clone());

    let tok = ByteTokenizer::new();
    let (_, rx) = router.submit(tok.encode(&prompt_text), max_new, sampling)?;
    let (tokens, reason, ttft, elapsed) = kvq::coordinator::request::collect_response(&rx);
    println!("prompt : {prompt_text:?}");
    println!("output : {:?}", tok.decode(&tokens));
    println!(
        "tokens : {}  finish: {reason:?}  ttft: {:.1}ms  total: {:.1}ms  ({:.1} tok/s)",
        tokens.len(),
        ttft * 1e3,
        elapsed * 1e3,
        tokens.len() as f64 / elapsed.max(1e-9)
    );
    handle.drain();
    join.join().ok();
    Ok(())
}

fn quantize(args: Args) -> Result<()> {
    use kvq::quant::{self, Variant};
    let t = args.usize_or("tokens", 4096);
    let d = args.usize_or("dim", 256);
    let variant = args.str_or("variant", "all");
    let seed = args.u64_or("seed", 0xF00D);
    args.finish().map_err(|e| anyhow::anyhow!(e))?;

    let k = kvq::quant::Fp32Matrix::random_uniform(t, d, -1.0, 1.0, seed);
    let scales = quant::compute_scales(&k);
    let variants: Vec<Variant> = if variant == "all" {
        Variant::ALL.to_vec()
    } else {
        vec![Variant::from_name(&variant)
            .ok_or_else(|| anyhow::anyhow!("unknown variant {variant:?}"))?]
    };

    println!(
        "matrix {t}x{d} ({} elements, {:.1} MiB fp32)",
        t * d,
        (t * d * 4) as f64 / 1048576.0
    );
    let bencher = kvq::util::harness::Bencher::default();
    for v in variants {
        let mut out = kvq::quant::Int8Matrix::zeros(t, d);
        let m = bencher.measure(v.name(), || {
            quant::quantize::quantize_variant(v, &k, &scales, &mut out);
        });
        let rec = quant::dequantize(&out);
        println!(
            "  {:<11} {:>10}  max_err={:.5}  l2={:.3}  ratio={:.2}x",
            v.name(),
            kvq::util::stats::fmt_duration(m.median()),
            quant::max_abs_error(&k, &rec),
            quant::l2_error(&k, &rec),
            out.compression_ratio(),
        );
    }
    Ok(())
}

fn memory(args: Args) -> Result<()> {
    use kvq::kvcache::{MemoryModel, Precision};
    let m = MemoryModel {
        layers: args.usize_or("layers", 32),
        heads: args.usize_or("heads", 32),
        head_dim: args.usize_or("head-dim", 128),
        seq_len: args.usize_or("seq-len", 131_072),
        precision: Precision::parse(&args.str_or("precision", "fp32"))
            .ok_or_else(|| anyhow::anyhow!("bad precision"))?,
    };
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    println!("{}", m.describe());
    println!("  elements      : {}", m.elements());
    println!("  payload       : {}", kvq::util::stats::fmt_bytes(m.payload_bytes() as f64));
    println!(
        "  scale overhead: {}",
        kvq::util::stats::fmt_bytes(m.scale_overhead_bytes() as f64)
    );
    println!("  vs fp32       : {:.2}x smaller", m.compression_vs_fp32());
    Ok(())
}

fn validate(args: Args) -> Result<()> {
    let dir = args.str_or("artifacts", &kvq::runtime::default_artifact_dir());
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let rt = Rc::new(Runtime::new(&dir)?);

    // Kernel cross-check on the smallest shape.
    let (t, d, tag) = (2048usize, 128usize, "2048x128");
    let k = kvq::quant::Fp32Matrix::random_uniform(t, d, -1.0, 1.0, 0xC4EC);
    let scales = kvq::quant::compute_scales(&k);
    let mut cpu = kvq::quant::Int8Matrix::zeros(t, d);
    kvq::quant::quantize::quantize_naive(&k, &scales, &mut cpu);
    for v in kvq::quant::Variant::ALL {
        let out = rt.run(
            &format!("quantize_{}_{tag}", v.name()),
            &[
                kvq::runtime::HostTensor::f32(k.data.clone(), &[t, d]),
                kvq::runtime::HostTensor::f32(scales.clone(), &[d]),
            ],
        )?;
        let ok = out[0].as_i8()? == cpu.data.as_slice();
        println!("quantize_{:<11} vs CPU: {}", v.name(), if ok { "OK" } else { "MISMATCH" });
        if !ok {
            bail!("artifact mismatch for {}", v.name());
        }
    }

    // Model cross-check.
    let pjrt = PjrtBackend::new(
        rt.clone(),
        "kvq-3m",
        0xA11CE,
        kvq::model::runner::DecodeKernel::PlainXla,
    )?;
    let spec = pjrt.spec().clone();
    let cpu_model = CpuBackend::new(spec.clone(), Weights::synthetic(&spec, 0xA11CE));
    use kvq::model::LmBackend;
    let tokens: Vec<i32> = "validation".bytes().map(|b| b as i32).collect();
    let a = pjrt.prefill(&tokens, tokens.len())?;
    let b = cpu_model.prefill(&tokens, tokens.len())?;
    let diff = a
        .logits
        .iter()
        .zip(&b.logits)
        .map(|(x, y)| (x - y).abs())
        .fold(0.0f32, f32::max);
    println!("prefill kvq-3m PJRT vs CPU oracle: max|Δlogit| = {diff:.2e}");
    if diff > 5e-3 {
        bail!("model parity failure");
    }
    println!("validate: all checks passed");
    Ok(())
}

fn report(args: Args) -> Result<()> {
    let port = args.usize_or("port", 8080) as u16;
    args.finish().map_err(|e| anyhow::anyhow!(e))?;
    let (status, body) = http_request(port, "GET", "/metrics", None)?;
    if status != 200 {
        bail!("/metrics returned {status}");
    }
    println!("{body}");
    Ok(())
}
