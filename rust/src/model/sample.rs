//! Token sampling policies.

use crate::util::rng::Rng;

/// Sampling configuration carried by each request.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct SamplingParams {
    /// 0.0 = greedy.
    pub temperature: f32,
    /// 0 = no top-k restriction.
    pub top_k: usize,
    pub seed: u64,
}

impl Default for SamplingParams {
    fn default() -> Self {
        SamplingParams { temperature: 0.0, top_k: 0, seed: 0 }
    }
}

pub fn argmax(logits: &[f32]) -> i32 {
    logits
        .iter()
        .enumerate()
        .max_by(|a, b| a.1.total_cmp(b.1))
        .map(|(i, _)| i as i32)
        .unwrap_or(0)
}

/// Sample a token id. Greedy when temperature == 0.
pub fn sample(logits: &[f32], params: &SamplingParams, rng: &mut Rng) -> i32 {
    if params.temperature <= 0.0 {
        return argmax(logits);
    }
    // Collect candidate (index, logit) pairs, optionally top-k-restricted.
    let mut cands: Vec<(usize, f32)> = logits.iter().copied().enumerate().collect();
    if params.top_k > 0 && params.top_k < cands.len() {
        cands.sort_by(|a, b| b.1.total_cmp(&a.1));
        cands.truncate(params.top_k);
    }
    let inv_t = 1.0 / params.temperature;
    let mx = cands.iter().map(|c| c.1).fold(f32::NEG_INFINITY, f32::max);
    let mut weights: Vec<f32> = cands.iter().map(|c| ((c.1 - mx) * inv_t).exp()).collect();
    let total: f32 = weights.iter().sum();
    for w in &mut weights {
        *w /= total;
    }
    let mut u = rng.next_f32();
    for (c, w) in cands.iter().zip(&weights) {
        if u < *w {
            return c.0 as i32;
        }
        u -= w;
    }
    cands.last().map(|c| c.0 as i32).unwrap_or(0)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn greedy_picks_max() {
        let logits = vec![0.1, 3.0, -1.0, 2.9];
        assert_eq!(argmax(&logits), 1);
        let mut rng = Rng::new(0);
        assert_eq!(sample(&logits, &SamplingParams::default(), &mut rng), 1);
    }

    #[test]
    fn temperature_zero_is_deterministic() {
        let logits = vec![0.0, 1.0, 0.5];
        let p = SamplingParams { temperature: 0.0, top_k: 0, seed: 1 };
        let mut rng = Rng::new(9);
        for _ in 0..10 {
            assert_eq!(sample(&logits, &p, &mut rng), 1);
        }
    }

    #[test]
    fn top_k_restricts_support() {
        let logits = vec![10.0, 9.0, -50.0, -50.0];
        let p = SamplingParams { temperature: 1.0, top_k: 2, seed: 0 };
        let mut rng = Rng::new(3);
        for _ in 0..100 {
            let t = sample(&logits, &p, &mut rng);
            assert!(t == 0 || t == 1, "sampled outside top-k: {t}");
        }
    }

    #[test]
    fn high_temperature_spreads_mass() {
        let logits = vec![1.0, 0.0, 0.0, 0.0];
        let p = SamplingParams { temperature: 100.0, top_k: 0, seed: 0 };
        let mut rng = Rng::new(4);
        let mut seen = [false; 4];
        for _ in 0..200 {
            seen[sample(&logits, &p, &mut rng) as usize] = true;
        }
        assert!(seen.iter().all(|&s| s), "high temperature should reach all tokens");
    }

    #[test]
    fn sharp_distribution_prefers_max() {
        let logits = vec![5.0, 0.0];
        let p = SamplingParams { temperature: 0.5, top_k: 0, seed: 0 };
        let mut rng = Rng::new(5);
        let hits = (0..200).filter(|_| sample(&logits, &p, &mut rng) == 0).count();
        assert!(hits > 190, "{hits}/200");
    }
}
