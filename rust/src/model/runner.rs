//! The engine-facing model backend trait and its two implementations.
//!
//! * [`PjrtBackend`] — the production path: prefill/decode artifacts
//!   executed via PJRT, with model weights staged on the device once at
//!   construction (per-step inputs are the token/pos scalars and the
//!   gathered cache staging buffers).
//! * [`CpuBackend`] — the pure-Rust oracle ([`super::cpu_ref::CpuModel`])
//!   behind the same trait, used for tests and PJRT-free operation.

use super::cpu_ref::{BatchScratch, CpuModel};
use super::spec::ModelSpec;
use super::weights::Weights;
use crate::kvcache::manager::{CacheView, WaveView};
use crate::quant::simd::Isa;
use crate::quant::Variant;
use crate::runtime::{HostTensor, Runtime};
use anyhow::{anyhow, bail, Context, Result};
use std::rc::Rc;

/// Prefill output: last-position logits + FP32 caches `(L, H, S, d)`.
pub struct PrefillResult {
    pub logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Decode output: logits + the new token's K/V rows `(L, H, d)`.
pub struct DecodeResult {
    pub logits: Vec<f32>,
    pub k_new: Vec<f32>,
    pub v_new: Vec<f32>,
}

/// Output of one chunk of an incremental prefill: logits at the chunk's
/// last position + the chunk's K/V rows `(L, H, C, d)`.
pub struct PrefillChunkResult {
    pub logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// What the engine needs from a model implementation.
pub trait LmBackend {
    fn spec(&self) -> &ModelSpec;

    /// Forward over `tokens[..len]` (tokens may be shorter than max_seq;
    /// implementations pad).
    fn prefill(&self, tokens: &[i32], len: usize) -> Result<PrefillResult>;

    /// Can this backend prefill one block-sized chunk at a time
    /// ([`Self::prefill_chunk`]), attending over the quantized paged
    /// history? Required for partial prefix-cache hits (suffix prefill);
    /// backends without it fall back to whole-prompt prefill and
    /// exact-match-only prefix reuse.
    fn supports_chunked_prefill(&self) -> bool {
        false
    }

    /// Forward over one prompt chunk at positions `start..start +
    /// chunk.len()`, attending over the already-cached quantized rows
    /// `0..start` through `view` plus FP32 causal attention within the
    /// chunk. Logits are at the chunk's last position; K/V rows come back
    /// `(L, H, C, d)` for `KvCacheManager::append_prefill_chunk`. Only
    /// called when [`Self::supports_chunked_prefill`].
    fn prefill_chunk(
        &self,
        _chunk: &[i32],
        _start: usize,
        _view: &CacheView,
        _kernel: Variant,
        _isa: Isa,
    ) -> Result<PrefillChunkResult> {
        bail!("backend does not support chunked prefill")
    }

    /// Single-token decode over the INT8 cache (artifact layouts: `(L, H,
    /// S, d)` payloads, `(L, H, B, d)` per-block scales with `B =
    /// ceil(max_seq / block_size)`). `isa` is the resolved kernel backend
    /// for host-side attention kernels; device backends (PJRT) ignore it.
    #[allow(clippy::too_many_arguments)]
    fn decode_i8(
        &self,
        token: i32,
        pos: usize,
        kq: &[i8],
        k_scales: &[f32],
        vq: &[i8],
        v_scales: &[f32],
        isa: Isa,
    ) -> Result<DecodeResult>;

    /// Single-token decode over the FP32 cache (baseline path).
    fn decode_f32(
        &self,
        token: i32,
        pos: usize,
        k: &[f32],
        v: &[f32],
        isa: Isa,
    ) -> Result<DecodeResult>;

    /// Can this backend attend directly over the paged cache
    /// ([`Self::decode_paged`])? Backends that can't — the PJRT artifacts
    /// consume dense staging buffers — keep the gather-into-staging path.
    fn supports_paged_decode(&self) -> bool {
        false
    }

    /// Single-token decode over a zero-copy [`CacheView`] (no staging
    /// materialization). `kernel` selects the fused dequant-attention
    /// access pattern; outputs never depend on it (bit-identical
    /// variants). Only called when [`Self::supports_paged_decode`].
    fn decode_paged(
        &self,
        _token: i32,
        _pos: usize,
        _view: &CacheView,
        _kernel: Variant,
        _isa: Isa,
    ) -> Result<DecodeResult> {
        bail!("backend does not support paged decode")
    }

    /// Can this backend decode a whole wave through the fused multi-query
    /// path ([`Self::decode_paged_batch`])? Requires
    /// [`Self::supports_paged_decode`]; device backends (PJRT) keep the
    /// per-sequence artifact loop.
    fn supports_batched_decode(&self) -> bool {
        false
    }

    /// Fused multi-query decode over a wave-level [`WaveView`]: one
    /// result per `(token, pos)` query, byte-identical to per-query
    /// [`Self::decode_paged`] calls (same kernel variant, same `isa`).
    /// Only called when [`Self::supports_batched_decode`]. `scratch` is
    /// the caller-owned arena set, reused across waves.
    fn decode_paged_batch(
        &self,
        _queries: &[(i32, usize)],
        _wave: &WaveView,
        _kernel: Variant,
        _isa: Isa,
        _scratch: &mut BatchScratch,
    ) -> Result<Vec<DecodeResult>> {
        bail!("backend does not support batched decode")
    }
}

// ---------------------------------------------------------------------------
// CPU oracle backend.
// ---------------------------------------------------------------------------

pub struct CpuBackend {
    model: CpuModel,
}

impl CpuBackend {
    pub fn new(spec: ModelSpec, weights: Weights) -> CpuBackend {
        CpuBackend { model: CpuModel::new(spec, weights) }
    }
}

impl LmBackend for CpuBackend {
    fn spec(&self) -> &ModelSpec {
        &self.model.spec
    }

    fn prefill(&self, tokens: &[i32], len: usize) -> Result<PrefillResult> {
        let out = self.model.prefill(tokens, len);
        Ok(PrefillResult { logits: out.logits, k: out.k, v: out.v })
    }

    fn supports_chunked_prefill(&self) -> bool {
        true
    }

    fn prefill_chunk(
        &self,
        chunk: &[i32],
        start: usize,
        view: &CacheView,
        kernel: Variant,
        isa: Isa,
    ) -> Result<PrefillChunkResult> {
        let out = self.model.prefill_chunk(chunk, start, view, kernel, isa)?;
        Ok(PrefillChunkResult { logits: out.logits, k: out.k, v: out.v })
    }

    fn decode_i8(
        &self,
        token: i32,
        pos: usize,
        kq: &[i8],
        k_scales: &[f32],
        vq: &[i8],
        v_scales: &[f32],
        isa: Isa,
    ) -> Result<DecodeResult> {
        let (logits, k_new, v_new) =
            self.model.decode_i8(token, pos, kq, k_scales, vq, v_scales, isa);
        Ok(DecodeResult { logits, k_new, v_new })
    }

    fn decode_f32(
        &self,
        token: i32,
        pos: usize,
        k: &[f32],
        v: &[f32],
        isa: Isa,
    ) -> Result<DecodeResult> {
        let (logits, k_new, v_new) = self.model.decode_f32(token, pos, k, v, isa);
        Ok(DecodeResult { logits, k_new, v_new })
    }

    fn supports_paged_decode(&self) -> bool {
        true
    }

    fn decode_paged(
        &self,
        token: i32,
        pos: usize,
        view: &CacheView,
        kernel: Variant,
        isa: Isa,
    ) -> Result<DecodeResult> {
        let (logits, k_new, v_new) = self.model.decode_paged(token, pos, view, kernel, isa)?;
        Ok(DecodeResult { logits, k_new, v_new })
    }

    fn supports_batched_decode(&self) -> bool {
        true
    }

    fn decode_paged_batch(
        &self,
        queries: &[(i32, usize)],
        wave: &WaveView,
        kernel: Variant,
        isa: Isa,
        scratch: &mut BatchScratch,
    ) -> Result<Vec<DecodeResult>> {
        Ok(self
            .model
            .decode_paged_batch(queries, wave, kernel, isa, scratch)?
            .into_iter()
            .map(|(logits, k_new, v_new)| DecodeResult { logits, k_new, v_new })
            .collect())
    }
}

// ---------------------------------------------------------------------------
// PJRT backend.
// ---------------------------------------------------------------------------

/// Which decode artifact the PJRT backend uses for the INT8 path.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeKernel {
    /// `decode_<model>`: plain-XLA history attention.
    PlainXla,
    /// `decode_pallas_<model>`: fused Pallas dequant-attention kernel.
    Pallas,
}

pub struct PjrtBackend {
    rt: Rc<Runtime>,
    spec: ModelSpec,
    /// Weights staged on device, in artifact argument order.
    param_buffers: Vec<xla::PjRtBuffer>,
    decode_kernel: DecodeKernel,
    /// Available prefill bucket sizes (sorted ascending, ending with
    /// max_seq). Prompts run in the smallest bucket that fits, cutting
    /// the O(S²) prefill cost for short prompts.
    prefill_buckets: Vec<usize>,
}

impl PjrtBackend {
    /// Build a backend for `model` (e.g. "kvq-3m"), staging its synthetic
    /// weights on the device. Validates the param ABI against the manifest.
    pub fn new(
        rt: Rc<Runtime>,
        model: &str,
        seed: u64,
        decode_kernel: DecodeKernel,
    ) -> Result<PjrtBackend> {
        let mj = rt
            .manifest
            .models
            .iter()
            .find(|m| m.get("name").as_str() == Some(model))
            .ok_or_else(|| anyhow!("model {model:?} not in manifest"))?;
        let spec = ModelSpec::from_json(mj)?;
        // Cross-check the ABI recorded by aot.py.
        let entry = rt.manifest.entry(&format!("decode_{model}"))?;
        if let Some(params) = entry.meta.get("params").as_arr() {
            spec.check_abi(params).context("param ABI drift between aot.py and spec.rs")?;
        }
        let weights = Weights::synthetic(&spec, seed);
        let mut param_buffers = Vec::with_capacity(weights.params.len());
        for (p, shape) in weights.params.iter().zip(&weights.shapes) {
            param_buffers.push(rt.stage_f32(p, shape)?);
        }
        // Discover bucketed prefill artifacts (prefill_<model>_s<N>).
        let prefix = format!("prefill_{model}_s");
        let mut prefill_buckets: Vec<usize> = rt
            .manifest
            .entries
            .keys()
            .filter_map(|n| n.strip_prefix(&prefix).and_then(|s| s.parse().ok()))
            .collect();
        prefill_buckets.push(spec.max_seq);
        prefill_buckets.sort_unstable();
        prefill_buckets.dedup();
        crate::info!(
            "staged {} params ({:.1} MiB) for {model}; prefill buckets {:?}",
            param_buffers.len(),
            weights.total_bytes() as f64 / (1024.0 * 1024.0),
            prefill_buckets
        );
        Ok(PjrtBackend { rt, spec, param_buffers, decode_kernel, prefill_buckets })
    }

    fn run_with_params(&self, name: &str, extra: &[xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        let exe = self.rt.load(name)?;
        let mut args: Vec<&xla::PjRtBuffer> =
            Vec::with_capacity(self.param_buffers.len() + extra.len());
        args.extend(self.param_buffers.iter());
        args.extend(extra.iter());
        exe.run_b(&args)
    }
}

impl LmBackend for PjrtBackend {
    fn spec(&self) -> &ModelSpec {
        &self.spec
    }

    fn prefill(&self, tokens: &[i32], len: usize) -> Result<PrefillResult> {
        // Smallest bucket that fits the prompt (last bucket == max_seq).
        let s = *self
            .prefill_buckets
            .iter()
            .find(|&&b| b >= len)
            .unwrap_or(&self.spec.max_seq);
        let mut padded = vec![0i32; s];
        padded[..tokens.len().min(s)].copy_from_slice(&tokens[..tokens.len().min(s)]);
        let extra = vec![
            self.rt.stage_i32(&padded, &[s])?,
            self.rt.stage_i32(&[len as i32], &[])?,
        ];
        let name = if s == self.spec.max_seq {
            format!("prefill_{}", self.spec.name)
        } else {
            format!("prefill_{}_s{s}", self.spec.name)
        };
        let mut out = self.run_with_params(&name, &extra)?;
        if out.len() != 3 {
            anyhow::bail!("prefill returned {} outputs", out.len());
        }
        let v = out.pop().unwrap().into_f32()?;
        let k = out.pop().unwrap().into_f32()?;
        let logits = out.pop().unwrap().into_f32()?;
        Ok(PrefillResult { logits, k, v })
    }

    fn decode_i8(
        &self,
        token: i32,
        pos: usize,
        kq: &[i8],
        k_scales: &[f32],
        vq: &[i8],
        v_scales: &[f32],
        _isa: Isa,
    ) -> Result<DecodeResult> {
        let sp = &self.spec;
        let (l, h, s, d) = (sp.layers, sp.heads, sp.max_seq, sp.head_dim);
        let b = s.div_ceil(sp.block_size);
        let extra = vec![
            self.rt.stage_i32(&[token], &[])?,
            self.rt.stage_i32(&[pos as i32], &[])?,
            self.rt.stage_i8(kq, &[l, h, s, d])?,
            self.rt.stage_f32(k_scales, &[l, h, b, d])?,
            self.rt.stage_i8(vq, &[l, h, s, d])?,
            self.rt.stage_f32(v_scales, &[l, h, b, d])?,
        ];
        let name = match self.decode_kernel {
            DecodeKernel::PlainXla => format!("decode_{}", sp.name),
            DecodeKernel::Pallas => format!("decode_pallas_{}", sp.name),
        };
        let mut out = self.run_with_params(&name, &extra)?;
        if out.len() != 3 {
            anyhow::bail!("decode returned {} outputs", out.len());
        }
        let v_new = out.pop().unwrap().into_f32()?;
        let k_new = out.pop().unwrap().into_f32()?;
        let logits = out.pop().unwrap().into_f32()?;
        Ok(DecodeResult { logits, k_new, v_new })
    }

    fn decode_f32(
        &self,
        token: i32,
        pos: usize,
        k: &[f32],
        v: &[f32],
        _isa: Isa,
    ) -> Result<DecodeResult> {
        let sp = &self.spec;
        let (l, h, s, d) = (sp.layers, sp.heads, sp.max_seq, sp.head_dim);
        let extra = vec![
            self.rt.stage_i32(&[token], &[])?,
            self.rt.stage_i32(&[pos as i32], &[])?,
            self.rt.stage_f32(k, &[l, h, s, d])?,
            self.rt.stage_f32(v, &[l, h, s, d])?,
        ];
        let name = format!("decode_fp32_{}", sp.name);
        let mut out = self.run_with_params(&name, &extra)?;
        if out.len() != 3 {
            anyhow::bail!("decode_fp32 returned {} outputs", out.len());
        }
        let v_new = out.pop().unwrap().into_f32()?;
        let k_new = out.pop().unwrap().into_f32()?;
        let logits = out.pop().unwrap().into_f32()?;
        Ok(DecodeResult { logits, k_new, v_new })
    }
}

// PJRT-dependent tests live in rust/tests/engine_e2e.rs; CpuBackend is
// exercised through cpu_ref's own tests and the engine tests.
