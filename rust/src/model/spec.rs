//! Model architecture spec — the L2↔L3 ABI.
//!
//! Mirrors `python/compile/model.py::ModelSpec`. `param_specs()` must stay
//! in lockstep with the Python list (it defines the flat argument order of
//! the prefill/decode artifacts); the manifest's recorded ABI is used to
//! cross-check at load time.

use crate::util::json::Json;
use anyhow::{anyhow, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct ModelSpec {
    pub name: String,
    pub vocab: usize,
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub d_ff: usize,
    pub max_seq: usize,
    pub block_size: usize,
}

impl ModelSpec {
    pub fn d_model(&self) -> usize {
        self.heads * self.head_dim
    }

    /// Tiny spec for unit tests (matches python/tests/test_model.py).
    pub fn test_tiny() -> ModelSpec {
        ModelSpec {
            name: "test-tiny".into(),
            vocab: 64,
            layers: 2,
            heads: 2,
            head_dim: 16,
            d_ff: 64,
            max_seq: 32,
            block_size: 8,
        }
    }

    /// Parse from a manifest `models` entry (or an artifact entry's meta).
    pub fn from_json(j: &Json) -> Result<ModelSpec> {
        let get = |k: &str| {
            j.get(k).as_usize().ok_or_else(|| anyhow!("model spec missing field {k:?}"))
        };
        Ok(ModelSpec {
            name: j
                .get("name")
                .as_str()
                .or_else(|| j.get("model").as_str())
                .ok_or_else(|| anyhow!("model spec missing name"))?
                .to_string(),
            vocab: get("vocab")?,
            layers: get("layers")?,
            heads: get("heads")?,
            head_dim: get("head_dim")?,
            d_ff: get("d_ff")?,
            max_seq: get("max_seq")?,
            block_size: get("block_size")?,
        })
    }

    /// `(name, shape)` for every parameter, in artifact argument order.
    /// KEEP IN SYNC with python/compile/model.py::ModelSpec.param_specs.
    pub fn param_specs(&self) -> Vec<(String, Vec<usize>)> {
        let m = self.d_model();
        let f = self.d_ff;
        let mut out = vec![("embedding".to_string(), vec![self.vocab, m])];
        for i in 0..self.layers {
            out.push((format!("l{i}.ln1"), vec![m]));
            out.push((format!("l{i}.wq"), vec![m, m]));
            out.push((format!("l{i}.wk"), vec![m, m]));
            out.push((format!("l{i}.wv"), vec![m, m]));
            out.push((format!("l{i}.wo"), vec![m, m]));
            out.push((format!("l{i}.ln2"), vec![m]));
            out.push((format!("l{i}.w1"), vec![m, f]));
            out.push((format!("l{i}.w2"), vec![f, m]));
        }
        out.push(("ln_f".to_string(), vec![m]));
        out
    }

    pub fn param_count(&self) -> usize {
        self.param_specs().iter().map(|(_, s)| s.iter().product::<usize>()).sum()
    }

    /// Validate this spec against the manifest-recorded param ABI.
    pub fn check_abi(&self, manifest_params: &[Json]) -> Result<()> {
        let ours = self.param_specs();
        if ours.len() != manifest_params.len() {
            return Err(anyhow!(
                "param count mismatch: rust {} vs manifest {}",
                ours.len(),
                manifest_params.len()
            ));
        }
        for (i, ((name, shape), mj)) in ours.iter().zip(manifest_params).enumerate() {
            let mname = mj.get("name").as_str().unwrap_or("");
            let mshape: Vec<usize> = mj
                .get("shape")
                .as_arr()
                .unwrap_or(&[])
                .iter()
                .filter_map(|v| v.as_usize())
                .collect();
            if mname != name || &mshape != shape {
                return Err(anyhow!(
                    "param {i} ABI mismatch: rust {name}{shape:?} vs manifest {mname}{mshape:?}"
                ));
            }
        }
        Ok(())
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn param_specs_structure() {
        let s = ModelSpec::test_tiny();
        let p = s.param_specs();
        assert_eq!(p.len(), 1 + s.layers * 8 + 1);
        assert_eq!(p[0], ("embedding".to_string(), vec![64, 32]));
        assert_eq!(p.last().unwrap().0, "ln_f");
    }

    #[test]
    fn param_count_tiny() {
        let s = ModelSpec::test_tiny();
        // emb 64*32 + 2 layers * (32 + 4*32*32 + 32 + 32*64 + 64*32) + 32
        let expect = 64 * 32 + 2 * (32 + 4 * 32 * 32 + 32 + 2 * 32 * 64) + 32;
        assert_eq!(s.param_count(), expect);
    }

    #[test]
    fn from_json_parses_manifest_shape() {
        let j = Json::parse(
            r#"{"name":"kvq-3m","vocab":256,"layers":4,"heads":8,
                "head_dim":32,"d_ff":1024,"max_seq":512,"block_size":16}"#,
        )
        .unwrap();
        let s = ModelSpec::from_json(&j).unwrap();
        assert_eq!(s.d_model(), 256);
        assert_eq!(s.max_seq, 512);
    }

    #[test]
    fn abi_check_catches_drift() {
        let s = ModelSpec::test_tiny();
        let good: Vec<Json> = s
            .param_specs()
            .iter()
            .map(|(n, sh)| {
                Json::parse(&format!(
                    r#"{{"name":"{n}","shape":[{}]}}"#,
                    sh.iter().map(|d| d.to_string()).collect::<Vec<_>>().join(",")
                ))
                .unwrap()
            })
            .collect();
        assert!(s.check_abi(&good).is_ok());
        let mut bad = good.clone();
        bad[1] = Json::parse(r#"{"name":"l0.WRONG","shape":[32]}"#).unwrap();
        assert!(s.check_abi(&bad).is_err());
        assert!(s.check_abi(&good[..3]).is_err());
    }
}
