//! Deterministic synthetic weights.
//!
//! No network access → no real checkpoints; we generate seeded weights with
//! the standard 1/√fan_in scaling (norm gains = 1), which yields a model
//! whose activation statistics are realistic enough to exercise the entire
//! serving path (prefill → quantize → paged cache → dequant-attend →
//! logits). See DESIGN.md §Substitutions.

use super::spec::ModelSpec;
use crate::util::rng::Rng;

/// Flat parameter list in artifact argument order.
pub struct Weights {
    pub spec: ModelSpec,
    /// One Vec<f32> per parameter, matching `spec.param_specs()` order.
    pub params: Vec<Vec<f32>>,
    pub shapes: Vec<Vec<usize>>,
}

impl Weights {
    /// Generate seeded weights for a spec.
    pub fn synthetic(spec: &ModelSpec, seed: u64) -> Weights {
        let mut rng = Rng::new(seed);
        let mut params = Vec::new();
        let mut shapes = Vec::new();
        for (name, shape) in spec.param_specs() {
            let n: usize = shape.iter().product();
            let mut buf = vec![0.0f32; n];
            if name.ends_with("ln1") || name.ends_with("ln2") || name == "ln_f" {
                buf.fill(1.0);
            } else {
                let fan_in = if shape.len() > 1 { shape[0] } else { 1 };
                let sigma = 1.0 / (fan_in as f32).sqrt();
                let mut child = rng.fork(hash_name(&name));
                child.fill_normal(&mut buf, sigma);
            }
            params.push(buf);
            shapes.push(shape);
        }
        Weights { spec: spec.clone(), params, shapes }
    }

    pub fn param(&self, name: &str) -> &[f32] {
        let idx = self
            .spec
            .param_specs()
            .iter()
            .position(|(n, _)| n == name)
            .unwrap_or_else(|| panic!("unknown param {name}"));
        &self.params[idx]
    }

    pub fn total_bytes(&self) -> usize {
        self.params.iter().map(|p| p.len() * 4).sum()
    }
}

fn hash_name(name: &str) -> u64 {
    // FNV-1a — stable across runs/platforms.
    let mut h: u64 = 0xcbf29ce484222325;
    for b in name.as_bytes() {
        h ^= *b as u64;
        h = h.wrapping_mul(0x100000001b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let s = ModelSpec::test_tiny();
        let a = Weights::synthetic(&s, 42);
        let b = Weights::synthetic(&s, 42);
        assert_eq!(a.params, b.params);
        let c = Weights::synthetic(&s, 43);
        assert_ne!(a.params[0], c.params[0]);
    }

    #[test]
    fn shapes_match_spec() {
        let s = ModelSpec::test_tiny();
        let w = Weights::synthetic(&s, 1);
        for ((_, shape), p) in s.param_specs().iter().zip(&w.params) {
            assert_eq!(p.len(), shape.iter().product::<usize>());
        }
        assert_eq!(w.total_bytes(), s.param_count() * 4);
    }

    #[test]
    fn norms_are_ones_matrices_are_scaled() {
        let s = ModelSpec::test_tiny();
        let w = Weights::synthetic(&s, 7);
        assert!(w.param("ln_f").iter().all(|&v| v == 1.0));
        assert!(w.param("l0.ln1").iter().all(|&v| v == 1.0));
        // Matrix stddev ≈ 1/sqrt(fan_in).
        let wq = w.param("l0.wq");
        let m = s.d_model() as f32;
        let var: f32 = wq.iter().map(|v| v * v).sum::<f32>() / wq.len() as f32;
        let expect = 1.0 / m;
        assert!((var / expect - 1.0).abs() < 0.2, "var {var} vs {expect}");
    }

    #[test]
    fn param_lookup_by_name() {
        let s = ModelSpec::test_tiny();
        let w = Weights::synthetic(&s, 3);
        assert_eq!(w.param("embedding").len(), s.vocab * s.d_model());
    }
}
