//! Token-level language model over the AOT artifacts.
//!
//! * [`spec`] — architecture hyper-parameters (mirrors the Python
//!   `ModelSpec`; parsed from the artifact manifest, which records the
//!   param ABI).
//! * [`weights`] — deterministic synthetic weight generation (the repo has
//!   no network access for real checkpoints; DESIGN.md §Substitutions).
//! * [`tokenizer`] — byte-level tokenizer (vocab 256).
//! * [`cpu_ref`] — pure-Rust transformer oracle implementing exactly the
//!   same math as `python/compile/model.py`; used as a PJRT-free backend
//!   for engine tests and to cross-validate artifact numerics.
//! * [`runner`] — the [`runner::LmBackend`] trait + PJRT-backed
//!   implementation (params staged on device once, executed per step).
//! * [`sample`] — greedy / temperature / top-k sampling.

pub mod cpu_ref;
pub mod runner;
pub mod sample;
pub mod spec;
pub mod tokenizer;
pub mod weights;

pub use cpu_ref::{BatchScratch, CacheAccess, CpuModel, PagedCache, StagedF32Cache, StagedI8Cache};
pub use runner::{DecodeResult, LmBackend, PjrtBackend, PrefillChunkResult, PrefillResult};
pub use spec::ModelSpec;
pub use tokenizer::ByteTokenizer;
