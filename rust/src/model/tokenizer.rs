//! Byte-level tokenizer: token id == byte value (vocab 256).
//!
//! Deliberately trivial — the serving stack's correctness story lives in
//! the cache/quantization path, not tokenization — but implements the same
//! interface a real BPE tokenizer would slot into.

#[derive(Debug, Clone, Default)]
pub struct ByteTokenizer;

impl ByteTokenizer {
    pub fn new() -> ByteTokenizer {
        ByteTokenizer
    }

    pub fn vocab_size(&self) -> usize {
        256
    }

    pub fn encode(&self, text: &str) -> Vec<i32> {
        text.as_bytes().iter().map(|&b| b as i32).collect()
    }

    pub fn decode(&self, tokens: &[i32]) -> String {
        let bytes: Vec<u8> = tokens.iter().map(|&t| (t.clamp(0, 255)) as u8).collect();
        String::from_utf8_lossy(&bytes).into_owned()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn ascii_roundtrip() {
        let t = ByteTokenizer::new();
        let ids = t.encode("hello kvq!");
        assert_eq!(ids.len(), 10);
        assert_eq!(t.decode(&ids), "hello kvq!");
    }

    #[test]
    fn utf8_roundtrip() {
        let t = ByteTokenizer::new();
        let s = "héllo ≈ 世界";
        assert_eq!(t.decode(&t.encode(s)), s);
    }

    #[test]
    fn out_of_range_ids_clamp() {
        let t = ByteTokenizer::new();
        let s = t.decode(&[72, 105, 999, -5]);
        assert!(s.starts_with("Hi"));
    }

    #[test]
    fn all_bytes_are_valid_tokens() {
        let t = ByteTokenizer::new();
        for b in 0..=255i32 {
            assert!((0..t.vocab_size() as i32).contains(&b));
        }
    }
}
