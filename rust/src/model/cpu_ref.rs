//! Pure-Rust transformer oracle.
//!
//! Implements *exactly* the math of `python/compile/model.py` (pre-RMSNorm
//! GPT, tanh-GELU, RoPE, tied LM head, streaming-softmax decode over an
//! INT8 cache with frozen scales) so that:
//!
//! 1. the engine can run without PJRT (unit/integration tests, fallback),
//! 2. PJRT artifact numerics can be cross-validated from Rust
//!    (rust/tests/engine_e2e.rs asserts logits agreement),
//! 3. the serving benches have a host-compute baseline.
//!
//! Layouts match the artifacts: caches `(L, H, S, d)`, scales
//! `(L, H, B, d)` with one frozen grid per `block_size`-row block
//! (B = ceil(max_seq / block_size)), new rows `(L, H, d)`, all flattened
//! row-major.
//!
//! Decode reads its K/V history through the [`CacheAccess`] strategy
//! trait: [`StagedI8Cache`]/[`StagedF32Cache`] walk the dense artifact
//! layout (the legacy gather-into-staging path), [`PagedCache`] walks the
//! block pool **in place** through a zero-copy
//! [`crate::kvcache::manager::CacheView`] with dequantization fused into
//! the attention kernels ([`crate::quant::attn`]). All strategies are
//! bit-identical (see the trait docs), so the serving engine can attend
//! block-natively without any numerical drift vs the staged path.

use super::spec::ModelSpec;
use super::weights::Weights;
use crate::kvcache::manager::{CacheView, WaveView};
use crate::quant::simd::{self, Isa, MqMember};
use crate::quant::Variant;

/// y += x @ w, where x: (m,), w: (m, n) row-major, y: (n,).
fn matvec_acc(x: &[f32], w: &[f32], n: usize, y: &mut [f32]) {
    debug_assert_eq!(y.len(), n);
    debug_assert_eq!(w.len(), x.len() * n);
    for (i, &xi) in x.iter().enumerate() {
        if xi == 0.0 {
            continue;
        }
        let row = &w[i * n..(i + 1) * n];
        for (yj, wj) in y.iter_mut().zip(row) {
            *yj += xi * wj;
        }
    }
}

fn matvec(x: &[f32], w: &[f32], n: usize) -> Vec<f32> {
    let mut y = vec![0.0f32; n];
    matvec_acc(x, w, n, &mut y);
    y
}

fn rmsnorm(x: &[f32], w: &[f32]) -> Vec<f32> {
    let var = x.iter().map(|v| v * v).sum::<f32>() / x.len() as f32;
    let r = 1.0 / (var + 1e-5).sqrt();
    x.iter().zip(w).map(|(v, g)| v * r * g).collect()
}

fn gelu(x: f32) -> f32 {
    0.5 * x * (1.0 + (0.7978845608 * (x + 0.044715 * x * x * x)).tanh())
}

/// RoPE over one (d,)-sized head row at position `pos` (low/high halves).
fn rope(row: &mut [f32], pos: usize) {
    let d = row.len();
    let half = d / 2;
    for i in 0..half {
        let freq = (10000.0f32).powf(-(i as f32) / half as f32);
        let ang = pos as f32 * freq;
        let (sin, cos) = ang.sin_cos();
        let (a, b) = (row[i], row[half + i]);
        row[i] = a * cos - b * sin;
        row[half + i] = a * sin + b * cos;
    }
}

/// Outputs of a prefill pass: logits at position len-1 plus the full FP32
/// caches in artifact layout.
pub struct CpuPrefill {
    pub logits: Vec<f32>,
    /// (L, H, S, d) with rows >= len zeroed.
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// Outputs of one chunk of an incremental prefill: logits at the chunk's
/// last position plus the chunk's FP32 K/V rows, `(L, H, C, d)` where
/// `C = chunk.len()` — the shape `KvCacheManager::append_prefill_chunk`
/// consumes.
pub struct CpuPrefillChunk {
    pub logits: Vec<f32>,
    pub k: Vec<f32>,
    pub v: Vec<f32>,
}

/// The oracle model.
pub struct CpuModel {
    pub spec: ModelSpec,
    pub weights: Weights,
}

impl CpuModel {
    pub fn new(spec: ModelSpec, weights: Weights) -> CpuModel {
        CpuModel { spec, weights }
    }

    fn layer_param(&self, layer: usize, name: &str) -> &[f32] {
        self.weights.param(&format!("l{layer}.{name}"))
    }

    /// Full-sequence forward over `tokens[..len]`.
    pub fn prefill(&self, tokens: &[i32], len: usize) -> CpuPrefill {
        let sp = &self.spec;
        let (l, h, d, m, smax) = (sp.layers, sp.heads, sp.head_dim, sp.d_model(), sp.max_seq);
        assert!(len >= 1 && len <= smax && tokens.len() >= len);
        let emb = self.weights.param("embedding");

        // Residual stream for each position.
        let mut xs: Vec<Vec<f32>> = (0..len)
            .map(|t| {
                let id = tokens[t] as usize;
                emb[id * m..(id + 1) * m].to_vec()
            })
            .collect();

        let mut k_cache = vec![0.0f32; l * h * smax * d];
        let mut v_cache = vec![0.0f32; l * h * smax * d];

        for layer in 0..l {
            let (wq, wk, wv, wo) = (
                self.layer_param(layer, "wq"),
                self.layer_param(layer, "wk"),
                self.layer_param(layer, "wv"),
                self.layer_param(layer, "wo"),
            );
            let (ln1, ln2) = (self.layer_param(layer, "ln1"), self.layer_param(layer, "ln2"));
            let (w1, w2) = (self.layer_param(layer, "w1"), self.layer_param(layer, "w2"));

            // Projections for all positions (with RoPE on q, k).
            let mut qs = vec![vec![0.0f32; m]; len];
            for t in 0..len {
                let xn = rmsnorm(&xs[t], ln1);
                let q = matvec(&xn, wq, m);
                let k = matvec(&xn, wk, m);
                let v = matvec(&xn, wv, m);
                for head in 0..h {
                    let mut qh = q[head * d..(head + 1) * d].to_vec();
                    let mut kh = k[head * d..(head + 1) * d].to_vec();
                    rope(&mut qh, t);
                    rope(&mut kh, t);
                    qs[t][head * d..(head + 1) * d].copy_from_slice(&qh);
                    let base = ((layer * h + head) * smax + t) * d;
                    k_cache[base..base + d].copy_from_slice(&kh);
                    v_cache[base..base + d]
                        .copy_from_slice(&v[head * d..(head + 1) * d]);
                }
            }

            // Causal attention + MLP per position.
            for t in 0..len {
                let mut attn_out = vec![0.0f32; m];
                for head in 0..h {
                    let qh = &qs[t][head * d..(head + 1) * d];
                    // scores over 0..=t
                    let mut scores = Vec::with_capacity(t + 1);
                    for u in 0..=t {
                        let base = ((layer * h + head) * smax + u) * d;
                        let kh = &k_cache[base..base + d];
                        let dot: f32 = qh.iter().zip(kh).map(|(a, b)| a * b).sum();
                        scores.push(dot / (d as f32).sqrt());
                    }
                    let mx = scores.iter().copied().fold(f32::NEG_INFINITY, f32::max);
                    let mut denom = 0.0f32;
                    let mut acc = vec![0.0f32; d];
                    for (u, &sc) in scores.iter().enumerate() {
                        let w = (sc - mx).exp();
                        denom += w;
                        let base = ((layer * h + head) * smax + u) * d;
                        let vh = &v_cache[base..base + d];
                        for (a, b) in acc.iter_mut().zip(vh) {
                            *a += w * b;
                        }
                    }
                    for (o, a) in attn_out[head * d..(head + 1) * d].iter_mut().zip(&acc) {
                        *o = a / denom;
                    }
                }
                matvec_acc(&attn_out, wo, m, &mut xs[t]);
                let xn = rmsnorm(&xs[t], ln2);
                let hidden: Vec<f32> =
                    matvec(&xn, w1, sp.d_ff).into_iter().map(gelu).collect();
                matvec_acc(&hidden, w2, m, &mut xs[t]);
            }
        }

        // Final norm + tied LM head at the last valid position.
        let xf = rmsnorm(&xs[len - 1], self.weights.param("ln_f"));
        let logits = self.lm_head(&xf);
        CpuPrefill { logits, k: k_cache, v: v_cache }
    }

    /// Incremental prefill of one token-aligned chunk: a forward pass
    /// over positions `start..start + chunk.len()` that attends over the
    /// *quantized* history rows `0..start` through `view` (fused codec
    /// kernels, exactly the paged-decode access pattern) plus FP32 causal
    /// attention within the chunk itself.
    ///
    /// The canonical CPU serving prefill is the block-sized chunked
    /// composition of these calls (the engine always chunks, cache hit or
    /// not), so a suffix prefill over adopted prefix-cache blocks is
    /// byte-identical to an uncached run of the same prompt: the shared
    /// span's quantized bytes and scales are identical by construction,
    /// and this pass only ever reads history through that representation.
    ///
    /// Softmax per (position, head) is non-streaming and deterministic:
    /// one max over history + in-chunk scores, history weights/V first
    /// (ascending t, via the codec kernels), then in-chunk rows ascending.
    pub fn prefill_chunk(
        &self,
        chunk: &[i32],
        start: usize,
        view: &CacheView,
        variant: Variant,
        isa: Isa,
    ) -> anyhow::Result<CpuPrefillChunk> {
        let sp = &self.spec;
        let (l, h, d, m) = (sp.layers, sp.heads, sp.head_dim, sp.d_model());
        let cnt = chunk.len();
        anyhow::ensure!(cnt >= 1, "empty prefill chunk");
        anyhow::ensure!(
            start + cnt <= sp.max_seq,
            "chunk {start}..{} exceeds max_seq {}",
            start + cnt,
            sp.max_seq
        );
        anyhow::ensure!(
            view.len() == start,
            "chunk start {start} != cache len {}",
            view.len()
        );
        anyhow::ensure!(
            view.layers() == l && view.heads() == h && view.head_dim() == d,
            "cache geometry does not match model spec"
        );
        let emb = self.weights.param("embedding");
        let cache = PagedCache::new(view, variant, isa);
        let sqrt_d = (d as f32).sqrt();

        let mut xs: Vec<Vec<f32>> = chunk
            .iter()
            .map(|&t| emb[t as usize * m..(t as usize + 1) * m].to_vec())
            .collect();
        let mut k_out = vec![0.0f32; l * h * cnt * d];
        let mut v_out = vec![0.0f32; l * h * cnt * d];
        // O(start) history score/weight rows, reused across positions.
        let mut hist = vec![0.0f32; start];
        let mut wbuf = vec![0.0f32; start];

        for layer in 0..l {
            let (wq, wk, wv, wo) = (
                self.layer_param(layer, "wq"),
                self.layer_param(layer, "wk"),
                self.layer_param(layer, "wv"),
                self.layer_param(layer, "wo"),
            );
            let (ln1, ln2) = (self.layer_param(layer, "ln1"), self.layer_param(layer, "ln2"));
            let (w1, w2) = (self.layer_param(layer, "w1"), self.layer_param(layer, "w2"));

            // Projections for every chunk position (RoPE at absolute
            // positions start + t) — K rows stored roped, like prefill.
            let mut qs = vec![vec![0.0f32; m]; cnt];
            for t in 0..cnt {
                let xn = rmsnorm(&xs[t], ln1);
                let q = matvec(&xn, wq, m);
                let k = matvec(&xn, wk, m);
                let v = matvec(&xn, wv, m);
                for head in 0..h {
                    let mut qh = q[head * d..(head + 1) * d].to_vec();
                    let mut kh = k[head * d..(head + 1) * d].to_vec();
                    rope(&mut qh, start + t);
                    rope(&mut kh, start + t);
                    qs[t][head * d..(head + 1) * d].copy_from_slice(&qh);
                    let base = ((layer * h + head) * cnt + t) * d;
                    k_out[base..base + d].copy_from_slice(&kh);
                    v_out[base..base + d].copy_from_slice(&v[head * d..(head + 1) * d]);
                }
            }

            for t in 0..cnt {
                let mut attn_out = vec![0.0f32; m];
                for head in 0..h {
                    let qh = &qs[t][head * d..(head + 1) * d];
                    // Quantized history scores (rows 0..start).
                    cache.key_dots(layer, head, qh, &mut hist);
                    let mut mx = f32::NEG_INFINITY;
                    for sc in hist.iter_mut() {
                        *sc /= sqrt_d;
                        mx = mx.max(*sc);
                    }
                    // FP32 in-chunk causal scores (chunk rows 0..=t).
                    let mut loc = Vec::with_capacity(t + 1);
                    for u in 0..=t {
                        let base = ((layer * h + head) * cnt + u) * d;
                        let kh = &k_out[base..base + d];
                        let dot: f32 = qh.iter().zip(kh).map(|(a, b)| a * b).sum();
                        let sc = dot / sqrt_d;
                        mx = mx.max(sc);
                        loc.push(sc);
                    }
                    let mut denom = 0.0f32;
                    for (w, &sc) in wbuf.iter_mut().zip(hist.iter()) {
                        let e = (sc - mx).exp();
                        denom += e;
                        *w = e;
                    }
                    let mut acc = vec![0.0f32; d];
                    cache.value_accumulate(layer, head, &wbuf, &mut acc);
                    for (u, &sc) in loc.iter().enumerate() {
                        let w = (sc - mx).exp();
                        denom += w;
                        let base = ((layer * h + head) * cnt + u) * d;
                        for (a, b) in acc.iter_mut().zip(&v_out[base..base + d]) {
                            *a += w * b;
                        }
                    }
                    for (o, a) in attn_out[head * d..(head + 1) * d].iter_mut().zip(&acc) {
                        *o = a / denom;
                    }
                }
                matvec_acc(&attn_out, wo, m, &mut xs[t]);
                let xn = rmsnorm(&xs[t], ln2);
                let hidden: Vec<f32> = matvec(&xn, w1, sp.d_ff).into_iter().map(gelu).collect();
                matvec_acc(&hidden, w2, m, &mut xs[t]);
            }
        }

        let xf = rmsnorm(&xs[cnt - 1], self.weights.param("ln_f"));
        Ok(CpuPrefillChunk { logits: self.lm_head(&xf), k: k_out, v: v_out })
    }

    fn lm_head(&self, x: &[f32]) -> Vec<f32> {
        let sp = &self.spec;
        let m = sp.d_model();
        let emb = self.weights.param("embedding");
        (0..sp.vocab)
            .map(|vtok| {
                let row = &emb[vtok * m..(vtok + 1) * m];
                x.iter().zip(row).map(|(a, b)| a * b).sum()
            })
            .collect()
    }

    /// Single-token decode over an INT8 cache (artifact layouts; see
    /// module docs). `pos` = number of valid cache rows = this token's
    /// position. Returns (logits, k_new (L,H,d), v_new (L,H,d)).
    ///
    /// Thin adapter over [`Self::decode_cached`] with a dense staged
    /// cache; the paged path ([`Self::decode_paged`]) is bit-identical.
    pub fn decode_i8(
        &self,
        token: i32,
        pos: usize,
        kq: &[i8],
        k_scales: &[f32],
        vq: &[i8],
        v_scales: &[f32],
        isa: Isa,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let sp = &self.spec;
        let cache = StagedI8Cache {
            kq,
            k_scales,
            vq,
            v_scales,
            heads: sp.heads,
            max_seq: sp.max_seq,
            head_dim: sp.head_dim,
            block_size: sp.block_size,
            variant: Variant::Naive,
            isa,
        };
        self.decode_cached(token, pos, &cache)
    }

    /// Single-token decode over an FP32 cache.
    pub fn decode_f32(
        &self,
        token: i32,
        pos: usize,
        k: &[f32],
        v: &[f32],
        isa: Isa,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let sp = &self.spec;
        let cache = StagedF32Cache {
            k,
            v,
            heads: sp.heads,
            max_seq: sp.max_seq,
            head_dim: sp.head_dim,
            isa,
        };
        self.decode_cached(token, pos, &cache)
    }

    /// Single-token decode directly over the paged block pool — the
    /// zero-copy serving path. Attends in place via the fused
    /// [`crate::quant::attn`] kernels (`variant` selects the access
    /// pattern; outputs are bit-identical across variants and to the
    /// staged [`Self::decode_i8`] path for INT8 caches).
    pub fn decode_paged(
        &self,
        token: i32,
        pos: usize,
        view: &CacheView,
        variant: Variant,
        isa: Isa,
    ) -> anyhow::Result<(Vec<f32>, Vec<f32>, Vec<f32>)> {
        let sp = &self.spec;
        anyhow::ensure!(
            view.len() == pos,
            "paged decode pos {pos} != cache len {}",
            view.len()
        );
        anyhow::ensure!(
            view.layers() == sp.layers
                && view.heads() == sp.heads
                && view.head_dim() == sp.head_dim,
            "cache geometry does not match model spec"
        );
        Ok(self.decode_cached(token, pos, &PagedCache::new(view, variant, isa)))
    }

    /// Fused multi-query decode over a whole wave — the batched serving
    /// path. One transformer step for every `(token, pos)` query in
    /// `queries` (aligned with the wave view's member indices), with
    /// attention restructured into per-(layer, head) passes over the
    /// wave's deduped block groups: each physical block is dequantized
    /// **once** per (wave, layer, head) via the fused multi-query codec
    /// kernels, scores/accumulations fanned out to every member.
    ///
    /// Bit-identity contract: per member, every expression and its
    /// accumulation order match [`Self::decode_paged`] exactly — the mq
    /// kernels are per-member bit-identical to their single-query twins
    /// (same backend), and groups are walked ascending by logical block
    /// index, preserving each member's V-accumulation order. Batched
    /// decode therefore returns byte-identical (logits, k_new, v_new)
    /// tuples to W independent per-sequence calls (same `isa`, same
    /// threads) — pinned by `tests/parallel_consistency.rs`.
    ///
    /// All wave-level attention buffers (queries, score/weight rows,
    /// accumulators, member lists, codec scratch) live in the
    /// caller-owned [`BatchScratch`] (engine-owned, reused across waves),
    /// so the fused per-(layer, head) hot loop allocates nothing after
    /// warm-up; per-query outputs are allocated exactly as the
    /// per-sequence path allocates them.
    pub fn decode_paged_batch(
        &self,
        queries: &[(i32, usize)],
        wave: &WaveView,
        variant: Variant,
        isa: Isa,
        scratch: &mut BatchScratch,
    ) -> anyhow::Result<Vec<(Vec<f32>, Vec<f32>, Vec<f32>)>> {
        let sp = &self.spec;
        anyhow::ensure!(
            queries.len() == wave.width(),
            "batch width {} != wave width {}",
            queries.len(),
            wave.width()
        );
        anyhow::ensure!(
            wave.layers() == sp.layers
                && wave.heads() == sp.heads
                && wave.head_dim() == sp.head_dim,
            "cache geometry does not match model spec"
        );
        for (m, &(_, pos)) in queries.iter().enumerate() {
            anyhow::ensure!(
                wave.len(m) == pos,
                "batched decode pos {pos} != cache len {} for member {m}",
                wave.len(m)
            );
        }
        let (l, h, d, mdl) = (sp.layers, sp.heads, sp.head_dim, sp.d_model());
        let width = queries.len();
        let bs = wave.block_size();
        let stride = wave.max_len();
        scratch.ensure(width, d, stride);
        let emb = self.weights.param("embedding");
        let sqrt_d = (d as f32).sqrt();

        // Per-query state (O(width) small vectors, same shapes the
        // per-sequence path allocates per call).
        let mut xs: Vec<Vec<f32>> = queries
            .iter()
            .map(|&(tok, _)| emb[tok as usize * mdl..(tok as usize + 1) * mdl].to_vec())
            .collect();
        let mut k_news = vec![vec![0.0f32; l * h * d]; width];
        let mut v_news = vec![vec![0.0f32; l * h * d]; width];

        for layer in 0..l {
            let (wq, wk, wv, wo) = (
                self.layer_param(layer, "wq"),
                self.layer_param(layer, "wk"),
                self.layer_param(layer, "wv"),
                self.layer_param(layer, "wo"),
            );
            let (ln1, ln2) = (self.layer_param(layer, "ln1"), self.layer_param(layer, "ln2"));
            let (w1, w2) = (self.layer_param(layer, "w1"), self.layer_param(layer, "w2"));

            // Per-query projections — same expressions as the
            // per-sequence path.
            let mut qs: Vec<Vec<f32>> = Vec::with_capacity(width);
            let mut ks: Vec<Vec<f32>> = Vec::with_capacity(width);
            let mut vs: Vec<Vec<f32>> = Vec::with_capacity(width);
            for x in &xs {
                let xn = rmsnorm(x, ln1);
                qs.push(matvec(&xn, wq, mdl));
                ks.push(matvec(&xn, wk, mdl));
                vs.push(matvec(&xn, wv, mdl));
            }

            let mut attn_outs: Vec<Vec<f32>> = (0..width).map(|_| vec![0.0f32; mdl]).collect();
            for head in 0..h {
                // Rope each member's query into the shared arena and
                // stash its new K/V row (per-member expressions identical
                // to the per-sequence path).
                let mut khs: Vec<Vec<f32>> = Vec::with_capacity(width);
                for (m, &(_, pos)) in queries.iter().enumerate() {
                    let mut qh = qs[m][head * d..(head + 1) * d].to_vec();
                    let mut kh = ks[m][head * d..(head + 1) * d].to_vec();
                    rope(&mut qh, pos);
                    rope(&mut kh, pos);
                    let vh = &vs[m][head * d..(head + 1) * d];
                    k_news[m][(layer * h + head) * d..(layer * h + head + 1) * d]
                        .copy_from_slice(&kh);
                    v_news[m][(layer * h + head) * d..(layer * h + head + 1) * d]
                        .copy_from_slice(vh);
                    scratch.q[m * d..(m + 1) * d].copy_from_slice(&qh);
                    khs.push(kh);
                }

                // Grouped K score passes: one dequantization per deduped
                // physical block, fanned to every referencing member.
                // Member score offsets are `m·stride + bi·block_size` —
                // every block before the tail is full, so the offset is
                // exactly the member's per-sequence `t0` for that block.
                let codec_k = wave.head_codec(layer, 0, head);
                for g in wave.groups(layer, 0) {
                    let slab = wave.head_rows_raw(layer, 0, g, head);
                    let sc = wave.head_scales(g.members[0], layer, 0, g.bi, head);
                    scratch.members.clear();
                    scratch.members.extend(g.members.iter().map(|&m| MqMember {
                        inp: m * d,
                        out: m * stride + g.bi * bs,
                    }));
                    codec_k.dot_rows_mq(
                        isa,
                        variant,
                        d,
                        &scratch.q,
                        slab,
                        sc,
                        &scratch.members,
                        &mut scratch.codec,
                        &mut scratch.scores,
                    );
                }

                // Per-member softmax bookkeeping — identical expressions
                // and order to the per-sequence path.
                for (m, &(_, pos)) in queries.iter().enumerate() {
                    let scores = &mut scratch.scores[m * stride..m * stride + pos];
                    let mut mx = f32::NEG_INFINITY;
                    for sc in scores.iter_mut() {
                        *sc /= sqrt_d;
                        mx = mx.max(*sc);
                    }
                    let qh = &scratch.q[m * d..(m + 1) * d];
                    let s_cur: f32 =
                        qh.iter().zip(&khs[m]).map(|(a, b)| a * b).sum::<f32>() / sqrt_d;
                    mx = mx.max(s_cur);
                    let mut denom = 0.0f32;
                    let weights = &mut scratch.weights[m * stride..m * stride + pos];
                    for (w, &sc) in weights.iter_mut().zip(scores.iter()) {
                        let e = (sc - mx).exp();
                        denom += e;
                        *w = e;
                    }
                    scratch.stats[m] = (denom, (s_cur - mx).exp());
                }

                // Grouped V accumulation passes, ascending logical block
                // index — each member's blocks arrive in the same order
                // its per-sequence walk would visit them.
                scratch.acc[..width * d].fill(0.0);
                let codec_v = wave.head_codec(layer, 1, head);
                for g in wave.groups(layer, 1) {
                    let slab = wave.head_rows_raw(layer, 1, g, head);
                    let sc = wave.head_scales(g.members[0], layer, 1, g.bi, head);
                    scratch.members.clear();
                    scratch.members.extend(g.members.iter().map(|&m| MqMember {
                        inp: m * stride + g.bi * bs,
                        out: m * d,
                    }));
                    codec_v.accumulate_rows_mq(
                        isa,
                        variant,
                        d,
                        &scratch.weights,
                        slab,
                        sc,
                        &scratch.members,
                        &mut scratch.codec,
                        &mut scratch.acc,
                    );
                }

                for m in 0..width {
                    let (denom_hist, w_cur) = scratch.stats[m];
                    let denom = denom_hist + w_cur;
                    let vh = &vs[m][head * d..(head + 1) * d];
                    let acc = &mut scratch.acc[m * d..(m + 1) * d];
                    for (a, b) in acc.iter_mut().zip(vh) {
                        *a += w_cur * b;
                    }
                    for (o, a) in attn_outs[m][head * d..(head + 1) * d].iter_mut().zip(acc.iter())
                    {
                        *o = a / denom;
                    }
                }
            }

            for (m, x) in xs.iter_mut().enumerate() {
                matvec_acc(&attn_outs[m], wo, mdl, x);
                let xn = rmsnorm(x, ln2);
                let hidden: Vec<f32> = matvec(&xn, w1, sp.d_ff).into_iter().map(gelu).collect();
                matvec_acc(&hidden, w2, mdl, x);
            }
        }

        Ok(xs
            .into_iter()
            .zip(k_news)
            .zip(v_news)
            .map(|((x, kn), vn)| {
                let xf = rmsnorm(&x, self.weights.param("ln_f"));
                (self.lm_head(&xf), kn, vn)
            })
            .collect())
    }

    /// The decode core: one transformer step whose attention reads K/V
    /// history through a [`CacheAccess`] — dense staging and the paged
    /// pool run the *same* math here (same expressions, same order), so
    /// every access strategy is bit-identical.
    pub fn decode_cached(
        &self,
        token: i32,
        pos: usize,
        cache: &impl CacheAccess,
    ) -> (Vec<f32>, Vec<f32>, Vec<f32>) {
        let sp = &self.spec;
        let (l, h, d, m) = (sp.layers, sp.heads, sp.head_dim, sp.d_model());
        let emb = self.weights.param("embedding");
        let mut x = emb[token as usize * m..(token as usize + 1) * m].to_vec();
        let mut k_news = vec![0.0f32; l * h * d];
        let mut v_news = vec![0.0f32; l * h * d];
        // Per-token scratch: O(pos) score/weight rows + an O(d)
        // accumulator — the only per-step buffers the zero-copy path needs.
        let mut scores = vec![0.0f32; pos];
        let mut weights = vec![0.0f32; pos];

        for layer in 0..l {
            let (wq, wk, wv, wo) = (
                self.layer_param(layer, "wq"),
                self.layer_param(layer, "wk"),
                self.layer_param(layer, "wv"),
                self.layer_param(layer, "wo"),
            );
            let (ln1, ln2) = (self.layer_param(layer, "ln1"), self.layer_param(layer, "ln2"));
            let (w1, w2) = (self.layer_param(layer, "w1"), self.layer_param(layer, "w2"));

            let xn = rmsnorm(&x, ln1);
            let q = matvec(&xn, wq, m);
            let k_new = matvec(&xn, wk, m);
            let v_new = matvec(&xn, wv, m);

            let mut attn_out = vec![0.0f32; m];
            for head in 0..h {
                let mut qh = q[head * d..(head + 1) * d].to_vec();
                let mut kh = k_new[head * d..(head + 1) * d].to_vec();
                rope(&mut qh, pos);
                rope(&mut kh, pos);
                let vh = &v_new[head * d..(head + 1) * d];
                k_news[(layer * h + head) * d..(layer * h + head + 1) * d]
                    .copy_from_slice(&kh);
                v_news[(layer * h + head) * d..(layer * h + head + 1) * d]
                    .copy_from_slice(vh);

                // History scores (0..pos) + current token's score.
                cache.key_dots(layer, head, &qh, &mut scores);
                let sqrt_d = (d as f32).sqrt();
                let mut mx = f32::NEG_INFINITY;
                for sc in scores.iter_mut() {
                    *sc /= sqrt_d;
                    mx = mx.max(*sc);
                }
                let s_cur: f32 = qh.iter().zip(&kh).map(|(a, b)| a * b).sum::<f32>() / sqrt_d;
                mx = mx.max(s_cur);

                let mut denom = 0.0f32;
                for (w, &sc) in weights.iter_mut().zip(scores.iter()) {
                    let e = (sc - mx).exp();
                    denom += e;
                    *w = e;
                }
                let mut acc = vec![0.0f32; d];
                cache.value_accumulate(layer, head, &weights, &mut acc);
                let w_cur = (s_cur - mx).exp();
                denom += w_cur;
                for (a, b) in acc.iter_mut().zip(vh) {
                    *a += w_cur * b;
                }
                for (o, a) in attn_out[head * d..(head + 1) * d].iter_mut().zip(&acc) {
                    *o = a / denom;
                }
            }
            matvec_acc(&attn_out, wo, m, &mut x);
            let xn = rmsnorm(&x, ln2);
            let hidden: Vec<f32> = matvec(&xn, w1, sp.d_ff).into_iter().map(gelu).collect();
            matvec_acc(&hidden, w2, m, &mut x);
        }

        let xf = rmsnorm(&x, self.weights.param("ln_f"));
        (self.lm_head(&xf), k_news, v_news)
    }
}

/// Reusable wave-level arenas for [`CpuModel::decode_paged_batch`]. Owned
/// by the caller (the engine keeps one per its staging-slot reuse
/// pattern) and grown monotonically on first use, so steady-state batched
/// decode allocates nothing per (layer, head) pass.
///
/// Layout: `q`/`acc` hold one `head_dim` row per member; `scores`/
/// `weights` hold one `max_len`-strided score row per member (member
/// `m`'s score for history token `t` lives at `m·stride + t`, so a block
/// group at logical index `bi` writes at `m·stride + bi·block_size`).
#[derive(Default)]
pub struct BatchScratch {
    /// Roped per-member queries of the current (layer, head): `width·d`.
    q: Vec<f32>,
    /// Per-member raw/scaled score rows: `width·stride`.
    scores: Vec<f32>,
    /// Per-member softmax weight rows: `width·stride`.
    weights: Vec<f32>,
    /// Per-member V accumulators: `width·d`.
    acc: Vec<f32>,
    /// Per-member (history denom, current-token weight) of one head.
    stats: Vec<(f32, f32)>,
    /// Member list rebuilt per block group (offsets into the arenas).
    members: Vec<MqMember>,
    /// Row/slab scratch for the mq codec kernels (INT4 unpack, AVX2
    /// slab dequantization).
    codec: Vec<f32>,
}

impl BatchScratch {
    pub fn new() -> BatchScratch {
        BatchScratch::default()
    }

    /// Grow every arena to the wave's requirements (never shrinks).
    fn ensure(&mut self, width: usize, d: usize, stride: usize) {
        let grow = |v: &mut Vec<f32>, n: usize| {
            if v.len() < n {
                v.resize(n, 0.0);
            }
        };
        grow(&mut self.q, width * d);
        grow(&mut self.scores, width * stride);
        grow(&mut self.weights, width * stride);
        grow(&mut self.acc, width * d);
        if self.stats.len() < width {
            self.stats.resize(width, (0.0, 0.0));
        }
    }
}

// ---------------------------------------------------------------------------
// Cache access strategies.
// ---------------------------------------------------------------------------

/// How decode attention reads the K/V history.
///
/// Contract (bit-stability): `key_dots` fills `scores[t] = Σ_ch q[ch] ·
/// K̂[t,ch]` accumulated in ascending channel order, and
/// `value_accumulate` adds `acc[ch] += Σ_t w[t] · V̂[t,ch]` with tokens in
/// ascending order per channel, where the dequantized element is computed
/// as `q_val as f32 * scale`. Every implementation that honors this
/// produces identical bits, so staged and paged decode can be swapped
/// freely (asserted by `tests/parallel_consistency.rs`).
pub trait CacheAccess {
    /// Raw dot products of `q` against K rows `0..scores.len()` of
    /// (layer, head). No 1/√d scaling — the caller applies it.
    fn key_dots(&self, layer: usize, head: usize, q: &[f32], scores: &mut [f32]);

    /// `acc[ch] += Σ_t w[t] · V̂[t,ch]` over V rows `0..w.len()`.
    fn value_accumulate(&self, layer: usize, head: usize, w: &[f32], acc: &mut [f32]);
}

/// Dense staged INT8 cache in artifact layout: `kq`/`vq` are `(L, H, S,
/// d)`, scales `(L, H, B, d)` with one grid per `block_size`-row block —
/// what the gather path materializes and the PJRT decode artifacts
/// consume. Attention walks the slab in block-sized row chunks so each
/// chunk dequantizes through its own grid; the per-row kernel math is
/// unchanged, so the walk is bit-identical to the paged path's.
pub struct StagedI8Cache<'a> {
    pub kq: &'a [i8],
    pub k_scales: &'a [f32],
    pub vq: &'a [i8],
    pub v_scales: &'a [f32],
    pub heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    pub block_size: usize,
    pub variant: Variant,
    /// Resolved kernel backend (scalar variants or explicit SIMD).
    pub isa: Isa,
}

impl StagedI8Cache<'_> {
    /// Scale blocks per stream in the staged ABI.
    #[inline]
    fn scale_blocks(&self) -> usize {
        self.max_seq.div_ceil(self.block_size)
    }
}

impl CacheAccess for StagedI8Cache<'_> {
    fn key_dots(&self, layer: usize, head: usize, q: &[f32], scores: &mut [f32]) {
        let (h, s, d, bs) = (self.heads, self.max_seq, self.head_dim, self.block_size);
        let (base, sbase) = ((layer * h + head) * s * d, (layer * h + head) * self.scale_blocks() * d);
        let mut t0 = 0;
        while t0 < scores.len() {
            let rows = bs.min(scores.len() - t0);
            let slab = &self.kq[base + t0 * d..base + (t0 + rows) * d];
            let sc = &self.k_scales[sbase + (t0 / bs) * d..sbase + (t0 / bs + 1) * d];
            simd::dot_rows_i8(self.isa, self.variant, q, slab, sc, &mut scores[t0..t0 + rows]);
            t0 += rows;
        }
    }

    fn value_accumulate(&self, layer: usize, head: usize, w: &[f32], acc: &mut [f32]) {
        let (h, s, d, bs) = (self.heads, self.max_seq, self.head_dim, self.block_size);
        let (base, sbase) = ((layer * h + head) * s * d, (layer * h + head) * self.scale_blocks() * d);
        let mut t0 = 0;
        while t0 < w.len() {
            let rows = bs.min(w.len() - t0);
            let slab = &self.vq[base + t0 * d..base + (t0 + rows) * d];
            let sc = &self.v_scales[sbase + (t0 / bs) * d..sbase + (t0 / bs + 1) * d];
            simd::accumulate_rows_i8(self.isa, self.variant, &w[t0..t0 + rows], slab, sc, acc);
            t0 += rows;
        }
    }
}

/// Dense staged FP32 cache (baseline precision), artifact layout.
pub struct StagedF32Cache<'a> {
    pub k: &'a [f32],
    pub v: &'a [f32],
    pub heads: usize,
    pub max_seq: usize,
    pub head_dim: usize,
    /// Resolved kernel backend.
    pub isa: Isa,
}

impl CacheAccess for StagedF32Cache<'_> {
    fn key_dots(&self, layer: usize, head: usize, q: &[f32], scores: &mut [f32]) {
        let (h, s, d) = (self.heads, self.max_seq, self.head_dim);
        let base = (layer * h + head) * s * d;
        simd::dot_rows_f32(self.isa, q, &self.k[base..base + scores.len() * d], scores);
    }

    fn value_accumulate(&self, layer: usize, head: usize, w: &[f32], acc: &mut [f32]) {
        let (h, s, d) = (self.heads, self.max_seq, self.head_dim);
        let base = (layer * h + head) * s * d;
        simd::accumulate_rows_f32(self.isa, w, &self.v[base..base + w.len() * d], acc);
    }
}

/// Block-native paged cache: walks the pool's blocks in place through a
/// zero-copy [`CacheView`] — the serving decode hot path. Every
/// `(layer, head, K|V)` slab is read through its policy-assigned
/// [`crate::quant::Codec`]: INT8 and FP32 run the fused slab kernels per
/// (block, head); INT4 unpacks one row at a time into an O(d) scratch —
/// still O(len) traffic, never an O(max_seq) staging copy. Mixed
/// policies (`k8v4`, `sink8`, per-layer tables) need no special cases
/// here — precision is resolved per stream by the codec lookup.
pub struct PagedCache<'a> {
    view: &'a CacheView<'a>,
    variant: Variant,
    isa: Isa,
    /// O(d) row scratch for codecs that unpack before dotting (INT4),
    /// grown on first use and reused across every (layer, head) call.
    /// `CacheAccess` reads are `&self` on one thread, so a `RefCell`
    /// suffices.
    scratch: std::cell::RefCell<Vec<f32>>,
}

impl<'a> PagedCache<'a> {
    pub fn new(view: &'a CacheView<'a>, variant: Variant, isa: Isa) -> PagedCache<'a> {
        PagedCache { view, variant, isa, scratch: std::cell::RefCell::new(Vec::new()) }
    }
}

impl CacheAccess for PagedCache<'_> {
    fn key_dots(&self, layer: usize, head: usize, q: &[f32], scores: &mut [f32]) {
        let stream = self.view.stream(layer, 0);
        debug_assert_eq!(scores.len(), stream.len(), "score buffer vs history len");
        let codec = stream.head_codec(head);
        let mut scratch = self.scratch.borrow_mut();
        let mut t0 = 0;
        for bi in 0..stream.num_blocks() {
            let rows = stream.rows_in_block(bi);
            let slab = stream.head_rows_raw(bi, head);
            codec.dot_rows(
                self.isa,
                self.variant,
                q,
                slab,
                stream.head_scales(bi, head),
                &mut scratch,
                &mut scores[t0..t0 + rows],
            );
            t0 += rows;
        }
    }

    fn value_accumulate(&self, layer: usize, head: usize, w: &[f32], acc: &mut [f32]) {
        let stream = self.view.stream(layer, 1);
        let codec = stream.head_codec(head);
        let mut scratch = self.scratch.borrow_mut();
        let mut t0 = 0;
        for bi in 0..stream.num_blocks() {
            let rows = stream.rows_in_block(bi);
            let slab = stream.head_rows_raw(bi, head);
            codec.accumulate_rows(
                self.isa,
                self.variant,
                &w[t0..t0 + rows],
                slab,
                stream.head_scales(bi, head),
                &mut scratch,
                acc,
            );
            t0 += rows;
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::quantize::quantize_one;
    use crate::util::rng::Rng;

    fn model() -> CpuModel {
        let spec = ModelSpec::test_tiny();
        let w = Weights::synthetic(&spec, 42);
        CpuModel::new(spec, w)
    }

    /// Quantize a dense (L, H, S, d) cache into the staged ABI: per-block
    /// (L, H, B, d) scales, each grid frozen over its own block's rows —
    /// the same layout `KvCacheManager::set_prefill` + gather produce.
    fn quantize_cache(
        spec: &ModelSpec,
        cache: &[f32],
        len: usize,
    ) -> (Vec<i8>, Vec<f32>) {
        let (l, h, s, d, bs) =
            (spec.layers, spec.heads, spec.max_seq, spec.head_dim, spec.block_size);
        let nb = s.div_ceil(bs);
        let mut q = vec![0i8; l * h * s * d];
        let mut scales = vec![0.0f32; l * h * nb * d];
        for li in 0..l {
            for hi in 0..h {
                for bi in 0..nb {
                    let rows = (bi * bs)..len.min((bi + 1) * bs);
                    for ch in 0..d {
                        let mut m = 0.0f32;
                        for t in rows.clone() {
                            m = m.max(cache[((li * h + hi) * s + t) * d + ch].abs());
                        }
                        let sc = m / crate::QMAX;
                        scales[((li * h + hi) * nb + bi) * d + ch] = sc;
                        for t in rows.clone() {
                            let i = ((li * h + hi) * s + t) * d + ch;
                            q[i] = quantize_one(cache[i], sc);
                        }
                    }
                }
            }
        }
        (q, scales)
    }

    #[test]
    fn prefill_shapes_and_determinism() {
        let m = model();
        let tokens: Vec<i32> = (0..10).map(|i| i % 64).collect();
        let a = m.prefill(&tokens, 8);
        let b = m.prefill(&tokens, 8);
        assert_eq!(a.logits, b.logits);
        assert_eq!(a.logits.len(), m.spec.vocab);
        assert_eq!(a.k.len(), m.spec.layers * m.spec.heads * m.spec.max_seq * m.spec.head_dim);
        // Rows beyond len stay zero.
        let base = m.spec.max_seq - 1;
        for li in 0..m.spec.layers {
            let idx = ((li * m.spec.heads) * m.spec.max_seq + base) * m.spec.head_dim;
            assert_eq!(a.k[idx], 0.0);
        }
    }

    #[test]
    fn logits_are_finite_and_varied() {
        let m = model();
        let tokens: Vec<i32> = vec![1, 2, 3, 4, 5];
        let p = m.prefill(&tokens, 5);
        assert!(p.logits.iter().all(|v| v.is_finite()));
        let mx = p.logits.iter().cloned().fold(f32::MIN, f32::max);
        let mn = p.logits.iter().cloned().fold(f32::MAX, f32::min);
        assert!(mx > mn, "degenerate logits");
    }

    #[test]
    fn incremental_decode_matches_full_prefill() {
        // decode(token n | quantized cache of 0..n-1) ≈ prefill(0..n):
        // the Rust twin of python/tests/test_model.py.
        let m = model();
        let mut rng = Rng::new(5);
        let tokens: Vec<i32> = (0..12).map(|_| rng.below(64) as i32).collect();
        for n in [1usize, 4, 9] {
            let full = m.prefill(&tokens, n + 1);
            let pre = m.prefill(&tokens, n);
            let (kq, ks) = quantize_cache(&m.spec, &pre.k, n);
            let (vq, vs) = quantize_cache(&m.spec, &pre.v, n);
            let (logits, _, _) =
                m.decode_i8(tokens[n], n, &kq, &ks, &vq, &vs, simd::default_isa());
            let argmax_full =
                full.logits.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            let argmax_dec =
                logits.iter().enumerate().max_by(|a, b| a.1.total_cmp(b.1)).unwrap().0;
            assert_eq!(argmax_dec, argmax_full, "greedy token diverged at n={n}");
            let max_diff = logits
                .iter()
                .zip(&full.logits)
                .map(|(a, b)| (a - b).abs())
                .fold(0.0f32, f32::max);
            assert!(max_diff < 0.2, "logits diff {max_diff} at n={n}");
        }
    }

    #[test]
    fn decode_fp32_matches_prefill_tightly() {
        let m = model();
        let mut rng = Rng::new(6);
        let tokens: Vec<i32> = (0..8).map(|_| rng.below(64) as i32).collect();
        let n = 6;
        let full = m.prefill(&tokens, n + 1);
        let pre = m.prefill(&tokens, n);
        let (logits, _, _) = m.decode_f32(tokens[n], n, &pre.k, &pre.v, simd::default_isa());
        let max_diff = logits
            .iter()
            .zip(&full.logits)
            .map(|(a, b)| (a - b).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 2e-4, "fp32 decode should be near-exact, diff {max_diff}");
    }

    #[test]
    fn batched_decode_bit_identical_to_per_sequence_paged() {
        // The fused multi-query path vs W independent per-sequence calls,
        // over a COW-forked wave with mixed lengths, all four kernel
        // variants, scalar and the detected SIMD backend.
        use crate::kvcache::manager::{CacheConfig, KvCacheManager};
        use crate::kvcache::{Precision, QuantPolicy};
        let mdl = model();
        let sp = mdl.spec.clone();
        let c = CacheConfig {
            layers: sp.layers,
            heads: sp.heads,
            head_dim: sp.head_dim,
            max_seq: sp.max_seq,
            block_size: 4,
            num_blocks: 512,
            scale_margin: 1.0,
        };
        for precision in [Precision::Int8, Precision::Fp32, Precision::Int4] {
            let mut mgr =
                KvCacheManager::new(c, QuantPolicy::uniform(precision, c.layers, c.heads));
            let mut rng = Rng::new(11);
            let tokens: Vec<i32> = (0..10).map(|_| rng.below(64) as i32).collect();
            let n = 6; // 2 blocks per stream: one full, one partial
            let pre = mdl.prefill(&tokens, n);
            let a = mgr.new_sequence();
            mgr.set_prefill(a, &pre.k, &pre.v, n).unwrap();
            let b = mgr.fork(a).unwrap();
            // Diverge the fork by one appended row so the wave mixes
            // lengths and COWs the shared tail.
            let (_, kn, vn) = {
                let vb = mgr.view(b).unwrap();
                mdl.decode_paged(tokens[n], n, &vb, Variant::Naive, Isa::Scalar).unwrap()
            };
            mgr.append_row(b, &kn, &vn).unwrap();

            let queries = [(tokens[n], n), (tokens[n + 1], n + 1)];
            let ids = [a, b];
            let mut isas = vec![Isa::Scalar];
            if simd::detect() != Isa::Scalar {
                isas.push(simd::detect());
            }
            for isa in isas {
                for variant in Variant::ALL {
                    let expected: Vec<_> = ids
                        .iter()
                        .zip(&queries)
                        .map(|(&id, &(tok, pos))| {
                            let view = mgr.view(id).unwrap();
                            mdl.decode_paged(tok, pos, &view, variant, isa).unwrap()
                        })
                        .collect();
                    let wave = mgr.wave_view(&ids).unwrap();
                    assert!(wave.blocks_deduped() > 0, "wave must share the prefix block");
                    let mut scratch = BatchScratch::new();
                    let got = mdl
                        .decode_paged_batch(&queries, &wave, variant, isa, &mut scratch)
                        .unwrap();
                    let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                    for (m, (g, e)) in got.iter().zip(&expected).enumerate() {
                        assert_eq!(
                            bits(&g.0),
                            bits(&e.0),
                            "logits diverged: member {m} {precision:?} {variant:?} {isa:?}"
                        );
                        assert_eq!(bits(&g.1), bits(&e.1), "k_new diverged: member {m}");
                        assert_eq!(bits(&g.2), bits(&e.2), "v_new diverged: member {m}");
                    }
                }
            }
            mgr.free(a);
            mgr.free(b);
        }
    }

    #[test]
    fn chunked_prefill_tracks_whole_prompt_and_is_deterministic() {
        // Chunked prefill attends over the *quantized* history, so its
        // logits differ from the FP32 whole-prompt pass only within
        // quantization noise; and two chunked runs are bit-identical
        // (the byte-determinism the prefix cache's suffix prefill needs).
        use crate::kvcache::manager::{CacheConfig, KvCacheManager};
        use crate::kvcache::{Precision, QuantPolicy};
        let mdl = model();
        let sp = mdl.spec.clone();
        let c = CacheConfig {
            layers: sp.layers,
            heads: sp.heads,
            head_dim: sp.head_dim,
            max_seq: sp.max_seq,
            block_size: sp.block_size,
            num_blocks: 64,
            scale_margin: 1.0,
        };
        let mut rng = Rng::new(17);
        let tokens: Vec<i32> = (0..12).map(|_| rng.below(64) as i32).collect();
        let bs = c.block_size;
        let run = |mgr: &mut KvCacheManager| {
            let seq = mgr.new_sequence();
            let mut logits = Vec::new();
            let mut start = 0;
            while start < tokens.len() {
                let end = tokens.len().min(start + bs);
                let res = {
                    let view = mgr.view(seq).unwrap();
                    mdl.prefill_chunk(
                        &tokens[start..end],
                        start,
                        &view,
                        Variant::Naive,
                        Isa::Scalar,
                    )
                    .unwrap()
                };
                mgr.append_prefill_chunk(seq, &res.k, &res.v, end - start).unwrap();
                logits = res.logits;
                start = end;
            }
            (seq, logits)
        };
        let mut mgr =
            KvCacheManager::new(c, QuantPolicy::uniform(Precision::Int8, c.layers, c.heads));
        let (a, la) = run(&mut mgr);
        let (b, lb) = run(&mut mgr);
        let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&la), bits(&lb), "chunked prefill must be deterministic");
        let full = mdl.prefill(&tokens, tokens.len());
        let argmax = |x: &[f32]| {
            x.iter().enumerate().max_by(|p, q| p.1.total_cmp(q.1)).unwrap().0
        };
        assert_eq!(argmax(&la), argmax(&full.logits), "greedy token diverged");
        let max_diff = la
            .iter()
            .zip(&full.logits)
            .map(|(x, y)| (x - y).abs())
            .fold(0.0f32, f32::max);
        assert!(max_diff < 0.2, "chunked-vs-whole logits diff {max_diff}");
        mgr.free(a);
        mgr.free(b);
    }

    #[test]
    fn decode_emits_same_kv_row_as_prefill() {
        let m = model();
        let tokens: Vec<i32> = vec![3, 1, 4, 1, 5, 9, 2, 6];
        let n = 5;
        let full = m.prefill(&tokens, n + 1);
        let pre = m.prefill(&tokens, n);
        let (_, k_new, _) = m.decode_f32(tokens[n], n, &pre.k, &pre.v, simd::default_isa());
        // Layer-0 K row at position n matches (deeper layers see residual
        // differences only via cache precision — fp32 here, so all match).
        let sp = &m.spec;
        for li in 0..sp.layers {
            for hi in 0..sp.heads {
                for ch in 0..sp.head_dim {
                    let got = k_new[(li * sp.heads + hi) * sp.head_dim + ch];
                    let want =
                        full.k[((li * sp.heads + hi) * sp.max_seq + n) * sp.head_dim + ch];
                    assert!(
                        (got - want).abs() < 5e-4,
                        "layer {li} head {hi} ch {ch}: {got} vs {want}"
                    );
                }
            }
        }
    }
}
