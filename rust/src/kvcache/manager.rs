//! Engine-facing KV-cache manager.
//!
//! Owns one [`BlockPool`] shared by all sequences and all layers. Each
//! sequence has 2·L block tables (K and V per layer) plus frozen
//! per-channel scales computed at prefill time — **per block**: one f32
//! per layer × head × channel × {K,V} × block, frozen over each block's
//! own rows (FP32 streams carry them too — on the same grid the
//! integer paths freeze — but never read them). Scales travel with
//! blocks: a block's payload plus its scale grid is self-contained, which
//! is what makes token-aligned prefix sharing across *different* prompts
//! bit-identical by construction (see [`super::prefix`]).
//!
//! **Quantization policy.** Storage precision is a per-cache
//! [`QuantPolicy`] mapping `(layer, head, K|V side) → Precision`; every
//! write and read dispatches through the stream's
//! [`crate::quant::Codec`]. The uniform policies are bit-identical to
//! the old single-`Precision` paths (same codecs, same scale grids, same
//! block layouts); mixed policies (`k8v4`, `sink8`, JSON tables) differ
//! only in which codec each stream uses. The pool is segmented into
//! per-width **sub-pools**: each (layer, K|V) stream allocates from the
//! class matching its own padded block width, so an INT4 value stream no
//! longer pads to the FP32/INT8 width. Scheduler accounting moves from
//! flat block counts to spans ([`KvCacheManager::spans_free`] — one
//! block in every stream) and width-aware byte budgets
//! ([`KvCacheManager::bytes_for_tokens`], [`KvCacheManager::free_bytes`]),
//! while the byte accounting ([`CacheView::attention_bytes`],
//! [`KvCacheManager::payload_bytes_by_precision`]) reports true per-row
//! per-codec footprints and
//! [`KvCacheManager::physical_bytes_by_precision`] the block-granular
//! sub-pool bytes.
//!
//! **Mid-flight lifecycle.** Sequences are first-class preemption
//! citizens: [`KvCacheManager::free`] releases a sequence's blocks at any
//! point of its life (the coordinator preempts victims under pool
//! pressure and recomputes them on readmission), [`KvCacheManager::fork`]
//! shares all current blocks copy-on-write (cross-request prefix sharing
//! via [`super::prefix::PrefixCache`]), and [`Self::append_row`] is
//! atomic — it either appends the row or fails without mutating the
//! sequence, so a failed allocation can be retried after the coordinator
//! reclaims blocks (prefix-cache eviction, then preemption). Free
//! accounting is refcount-aware throughout: a block shared by N sequences
//! occupies one pool slot and is returned to the free list only by its
//! last holder.
//!
//! **Frozen-scale decode.** The paper quantizes a complete cache post-hoc
//! with per-channel scales (eq. 6). In streaming generation the column max
//! isn't known up front, so this manager freezes the scales measured over
//! the prompt (optionally inflated by `scale_margin`) and clamps later
//! tokens into them — the error of this policy vs full requantization is
//! measured by the ablation bench (`cargo bench --bench ablations`) and
//! bounded in practice by RoPE keeping per-channel K statistics stationary
//! (DESIGN.md §Hardware-Adaptation). Each stream's scale grid divisor is
//! its codec's [`crate::quant::Codec::qmax`] — no call site re-derives a
//! grid.
//!
//! **Parallelism.** Prefill scale-freezing/quantization and the decode
//! gathers are batched over the shared [`crate::parallel`] runtime
//! ([`KvCacheManager::set_parallelism`]); workers own disjoint streams,
//! blocks, or staging ranges, so the stored and gathered bytes are
//! identical at every worker count (asserted by
//! `tests/parallel_consistency.rs`).
//!
//! **Zero-copy reads.** [`KvCacheManager::view`] hands out a borrow-based
//! [`CacheView`] over a sequence's blocks and frozen scales so fused
//! decode attends over the paged layout *in place* — no per-token
//! materialization of the whole cache. The copying
//! `gather_i8`/`gather_f32` staging path is kept for the PJRT backend
//! (whose artifacts consume dense buffers) and for parity tests; it only
//! exists for streams whose policy is uniform int8/fp32 (the two dense
//! staging ABIs).

use super::policy::{QuantPolicy, StreamLayout};
use super::pool::{self, BlockId, BlockPool, BlockShape};
use super::table::BlockTable;
use super::Precision;
use crate::parallel::{self, SendPtr};
use crate::quant::simd::{self, Isa};
use anyhow::{anyhow, bail, Result};
use std::collections::HashMap;

/// Minimum elements of per-sequence work before the batched prefill /
/// gather paths fan out to the shared parallel runtime; below this the
/// scoped-thread overhead dominates. Overridable for tests/benches via
/// [`KvCacheManager::set_parallel_threshold`].
const PAR_MIN_ELEMS: usize = 1 << 15;

/// Sequence handle.
pub type SeqId = u64;

/// Geometry of the cached model (precision lives in the cache's
/// [`QuantPolicy`], not here).
#[derive(Debug, Clone, Copy)]
pub struct CacheConfig {
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    /// Maximum tokens per sequence (the decode artifact's S).
    pub max_seq: usize,
    /// Tokens per block.
    pub block_size: usize,
    /// Total blocks in the pool.
    pub num_blocks: usize,
    /// Scale inflation at prefill (headroom for out-of-range decode K/V).
    pub scale_margin: f32,
}

impl CacheConfig {
    /// Blocks required to hold `tokens` rows of one sequence across all
    /// layer/K/V streams.
    pub fn blocks_for_tokens(&self, tokens: usize) -> usize {
        BlockTable::blocks_for(tokens, self.block_size) * 2 * self.layers
    }

    /// Scale-grid slots per (layer, K|V) stream in the dense staged ABI:
    /// one `heads·head_dim` grid per block position up to `max_seq`.
    pub fn max_blocks_per_stream(&self) -> usize {
        self.max_seq.div_ceil(self.block_size)
    }
}

/// Per-sequence cache state.
pub struct SequenceCache {
    pub id: SeqId,
    pub len: usize,
    /// tables[layer][0]=K, tables[layer][1]=V.
    tables: Vec<[BlockTable; 2]>,
    /// Frozen per-channel, per-block scales:
    /// `[layer][kv][block·heads·head_dim + head·head_dim + ch]` — one
    /// `heads·head_dim` grid per allocated block, frozen over that
    /// block's own rows (eq. 6 at block granularity). Grows in lockstep
    /// with the block tables; appended decode rows at a block boundary
    /// inherit the previous block's grid.
    scales: Vec<[Vec<f32>; 2]>,
}

/// The manager.
pub struct KvCacheManager {
    cfg: CacheConfig,
    policy: QuantPolicy,
    /// Precomputed byte layout of each (layer, K|V) stream's blocks.
    layouts: Vec<[StreamLayout; 2]>,
    /// Per-token payload bytes by precision (`[fp32, int8, int4]`),
    /// precomputed — sequence-independent under a fixed policy.
    token_bytes_by_precision: [u64; 3],
    /// Pool width class of each (layer, K|V) stream — every allocation
    /// for a stream comes from its class's sub-pool.
    stream_class: Vec<[usize; 2]>,
    /// Streams per width class (`n_c`); converts per-class free blocks
    /// into whole-sequence spans (one span = one block in every stream).
    class_streams: Vec<usize>,
    /// Physical bytes of one span — Σ over streams of the stream's
    /// padded block width. The byte cost of `block_size` tokens.
    span_bytes: usize,
    /// The pre-sub-pool block width (widest stream, alignment-padded):
    /// `num_blocks × legacy_block_bytes` is the padded baseline the
    /// sub-pools are measured against.
    legacy_block_bytes: usize,
    pool: BlockPool,
    seqs: HashMap<SeqId, SequenceCache>,
    /// External holds per block (prefix-cache trie pins): references the
    /// pool refcounts carry beyond the live block tables. Lets
    /// [`Self::assert_refcounts_consistent`] verify exact accounting
    /// while the trie holds blocks that belong to no sequence.
    extern_pins: Vec<u32>,
    next_id: SeqId,
    /// Worker count for the batched prefill-quantize and gather paths
    /// (1 = serial; the default). Parallelism never changes output bits.
    threads: usize,
    /// Work-size floor before fanning out (see [`PAR_MIN_ELEMS`]).
    par_min: usize,
    /// Resolved kernel ISA for the row encode (cache-writer) paths.
    /// Encoded bytes are bit-identical across backends (the SIMD writers
    /// keep the scalar rounding semantics — `quant::simd` module docs),
    /// so this only affects speed, never stored content.
    isa: Isa,
}

impl KvCacheManager {
    pub fn new(cfg: CacheConfig, policy: QuantPolicy) -> KvCacheManager {
        assert_eq!(policy.layers(), cfg.layers, "policy/cache layer count mismatch");
        assert_eq!(policy.heads(), cfg.heads, "policy/cache head count mismatch");
        let shape =
            BlockShape { block_size: cfg.block_size, heads: cfg.heads, head_dim: cfg.head_dim };
        let layouts: Vec<[StreamLayout; 2]> = (0..cfg.layers)
            .map(|l| {
                [
                    policy.stream_layout(l, 0, cfg.block_size, cfg.head_dim),
                    policy.stream_layout(l, 1, cfg.block_size, cfg.head_dim),
                ]
            })
            .collect();
        // Per-precision sub-pools: group streams by their own padded
        // block width instead of padding everything to the widest stream.
        // Each class gets a share of `num_blocks` proportional to its
        // stream count (sequences consume blocks uniformly across
        // streams), remainder distributed in class order. Uniform
        // policies collapse to a single class of exactly `num_blocks`
        // legacy-width blocks — bit-for-bit the old flat pool.
        let legacy_block_bytes = policy.max_block_bytes(cfg.block_size, cfg.head_dim);
        let mut class_widths: Vec<usize> = Vec::new();
        let mut class_streams: Vec<usize> = Vec::new();
        let mut stream_class = vec![[0usize; 2]; cfg.layers];
        for (l, pair) in layouts.iter().enumerate() {
            for (kv, layout) in pair.iter().enumerate() {
                let w = layout.padded_block_bytes();
                let c = match class_widths.iter().position(|&cw| cw == w) {
                    Some(c) => {
                        class_streams[c] += 1;
                        c
                    }
                    None => {
                        class_widths.push(w);
                        class_streams.push(1);
                        class_widths.len() - 1
                    }
                };
                stream_class[l][kv] = c;
            }
        }
        let total_streams = 2 * cfg.layers;
        let mut counts: Vec<usize> =
            class_streams.iter().map(|&n| cfg.num_blocks * n / total_streams).collect();
        let mut leftover = cfg.num_blocks - counts.iter().sum::<usize>();
        let mut rr = 0;
        while leftover > 0 {
            counts[rr] += 1;
            leftover -= 1;
            rr = (rr + 1) % counts.len();
        }
        let specs: Vec<(usize, usize)> =
            counts.into_iter().zip(class_widths.iter().copied()).collect();
        let span_bytes = layouts
            .iter()
            .flat_map(|pair| pair.iter())
            .map(|l| l.padded_block_bytes())
            .sum();
        let token_bytes_by_precision = policy.payload_bytes_by_precision(cfg.head_dim, 1);
        KvCacheManager {
            pool: BlockPool::with_classes(shape, &specs),
            cfg,
            policy,
            layouts,
            token_bytes_by_precision,
            stream_class,
            class_streams,
            span_bytes,
            legacy_block_bytes,
            seqs: HashMap::new(),
            extern_pins: vec![0; cfg.num_blocks],
            next_id: 1,
            threads: 1,
            par_min: PAR_MIN_ELEMS,
            isa: simd::default_isa(),
        }
    }

    /// Set the worker count used by batched quantize/gather (0 = auto via
    /// the shared [`crate::parallel`] runtime knob).
    pub fn set_parallelism(&mut self, threads: usize) {
        self.threads = parallel::resolve(threads);
    }

    pub fn parallelism(&self) -> usize {
        self.threads
    }

    /// Set the resolved kernel ISA for the encode paths (the engine
    /// resolves its `kernel_backend` knob and pushes it here; direct
    /// constructions default to `KernelBackend::Auto` via
    /// [`simd::default_isa`]).
    pub fn set_kernel_isa(&mut self, isa: Isa) {
        self.isa = isa;
    }

    pub fn kernel_isa(&self) -> Isa {
        self.isa
    }

    /// Override the minimum work size before parallel fan-out (tests and
    /// benches use 0 to force the parallel path on small inputs).
    pub fn set_parallel_threshold(&mut self, elems: usize) {
        self.par_min = elems;
    }

    /// Worker count for a unit of `work` total elements.
    fn threads_for(&self, work: usize) -> usize {
        self.threads_capped(work, self.threads)
    }

    /// Like [`Self::threads_for`] with an explicit cap (callers already
    /// running inside a parallel region pass 1 to avoid nested fan-out).
    fn threads_capped(&self, work: usize, cap: usize) -> usize {
        if cap > 1 && work >= self.par_min {
            cap
        } else {
            1
        }
    }

    pub fn config(&self) -> &CacheConfig {
        &self.cfg
    }

    /// The cache's quantization policy.
    pub fn policy(&self) -> &QuantPolicy {
        &self.policy
    }

    pub fn free_blocks(&self) -> usize {
        self.pool.free_blocks()
    }

    /// Physically occupied blocks (shared blocks counted once).
    pub fn used_blocks(&self) -> usize {
        self.pool.used_blocks()
    }

    /// Total blocks in the pool.
    pub fn num_blocks(&self) -> usize {
        self.cfg.num_blocks
    }

    /// Blocks held by more than one sequence (prefix sharing / COW).
    pub fn shared_blocks(&self) -> usize {
        self.pool.shared_blocks()
    }

    /// Sum of per-sequence footprints (shared blocks counted per holder);
    /// `logical - used` is the memory prefix sharing is saving.
    pub fn logical_blocks(&self) -> usize {
        self.pool.logical_used_blocks()
    }

    pub fn utilization(&self) -> f64 {
        self.pool.utilization()
    }

    pub fn storage_bytes(&self) -> usize {
        self.pool.storage_bytes()
    }

    /// Logical payload bytes of all live sequences' valid rows, broken
    /// down by storage precision (`[fp32, int8, int4]`) — the
    /// `GET /metrics` per-precision cache occupancy. Per-row per-codec
    /// accounting; shared blocks are counted per holder (this is a
    /// logical measure, like `seq_blocks`). O(live sequences): the
    /// per-token split is precomputed at construction (it is
    /// sequence-independent), so the engine can book this gauge every
    /// step without rescanning the policy map.
    pub fn payload_bytes_by_precision(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for seq in self.seqs.values() {
            for (o, b) in out.iter_mut().zip(self.token_bytes_by_precision) {
                *o += b * seq.len as u64;
            }
        }
        out
    }

    /// Physical payload bytes of live sequences' **blocks**, broken down
    /// by storage precision (`[fp32, int8, int4]`) — sub-pool bytes with
    /// shared blocks counted **once** (block-granular, per-stream codec
    /// widths; per-block alignment padding is not attributed to any
    /// precision). This is what the pool physically holds; the logical
    /// per-holder row-granular gauge [`Self::payload_bytes_by_precision`]
    /// is pinned unchanged so dashboards don't silently shift.
    pub fn physical_bytes_by_precision(&self) -> [u64; 3] {
        let mut seen = std::collections::HashSet::new();
        let mut out = [0u64; 3];
        for seq in self.seqs.values() {
            for (layer, pair) in seq.tables.iter().enumerate() {
                for (kv, t) in pair.iter().enumerate() {
                    let by = self.layouts[layer][kv].block_bytes_by_precision();
                    for &b in t.blocks() {
                        if seen.insert(b) {
                            for (o, v) in out.iter_mut().zip(by) {
                                *o += v;
                            }
                        }
                    }
                }
            }
        }
        out
    }

    pub fn live_sequences(&self) -> usize {
        self.seqs.len()
    }

    /// Whole spans allocatable right now: one span = one block in every
    /// (layer, K|V) stream — `block_size` tokens of whole-sequence
    /// capacity. The admission unit under sub-pools: the binding class is
    /// whichever runs out first. Single-class pools reduce to
    /// `free_blocks / (2·layers)`, matching the legacy block arithmetic
    /// exactly.
    pub fn spans_free(&self) -> usize {
        (0..self.pool.num_classes())
            .map(|c| self.pool.class_free_blocks(c) / self.class_streams[c])
            .min()
            .unwrap_or(0)
    }

    /// Physical bytes of one span (one block in every stream, padded
    /// sub-pool widths) — the byte cost of `block_size` tokens.
    pub fn span_bytes(&self) -> usize {
        self.span_bytes
    }

    /// Physical bytes a sequence of `tokens` total length occupies —
    /// the byte-budget analogue of [`CacheConfig::blocks_for_tokens`].
    pub fn bytes_for_tokens(&self, tokens: usize) -> u64 {
        (BlockTable::blocks_for(tokens, self.cfg.block_size) * self.span_bytes) as u64
    }

    /// Bytes allocatable as whole spans right now (the usable free
    /// budget admission planning should compare against).
    pub fn free_bytes(&self) -> u64 {
        (self.spans_free() * self.span_bytes) as u64
    }

    /// Bytes sitting on free lists at their class widths, whether or not
    /// a whole span can be formed from them.
    pub fn raw_free_bytes(&self) -> u64 {
        self.pool.free_bytes_raw()
    }

    /// Free bytes not allocatable as whole spans: class imbalance (one
    /// sub-pool drained while others have room) plus the sub-span
    /// remainder. Surfaced at `GET /metrics` as
    /// `pool_fragmentation_bytes`.
    pub fn fragmentation_bytes(&self) -> u64 {
        self.raw_free_bytes() - self.free_bytes()
    }

    /// Physical bytes the pool's slabs occupy — Σ per-class
    /// `num_blocks × width`. Mixed policies keep this strictly below
    /// [`Self::padded_pool_bytes`].
    pub fn pool_physical_bytes(&self) -> u64 {
        self.pool.storage_bytes() as u64
    }

    /// The pre-sub-pool baseline: every block padded to the widest
    /// stream (`num_blocks × max_block_bytes`).
    pub fn padded_pool_bytes(&self) -> u64 {
        (self.cfg.num_blocks * self.legacy_block_bytes) as u64
    }

    /// Physical bytes of one block (its class width).
    pub fn block_bytes_of(&self, id: BlockId) -> usize {
        self.pool.block_bytes_of(id)
    }

    /// Width classes in the pool (1 under uniform policies).
    pub fn num_width_classes(&self) -> usize {
        self.pool.num_classes()
    }

    /// Can a sequence of `tokens` total length be admitted right now?
    /// Span-based: every class must be able to supply its share.
    pub fn can_admit(&self, tokens: usize) -> bool {
        BlockTable::blocks_for(tokens, self.cfg.block_size) <= self.spans_free()
    }

    /// Whole spans the empty pool can supply (the binding class bounds
    /// it; single-class pools reduce to `num_blocks / (2·layers)`).
    pub fn total_spans(&self) -> usize {
        (0..self.pool.num_classes())
            .map(|c| self.pool.class_num_blocks(c) / self.class_streams[c])
            .min()
            .unwrap_or(0)
    }

    /// Span-allocatable byte capacity of the whole pool — what admission
    /// planning treats as "the pool" under byte budgets.
    pub fn pool_capacity_bytes(&self) -> u64 {
        (self.total_spans() * self.span_bytes) as u64
    }

    /// Watermark headroom of `frac` of the pool, in bytes. Quantized to
    /// legacy block units (`num_blocks · frac` blocks at the average
    /// stream width) so uniform policies reproduce the block-count era's
    /// admission decisions bit-for-bit.
    pub fn headroom_bytes(&self, frac: f64) -> u64 {
        let blocks = (self.cfg.num_blocks as f64 * frac) as u64;
        blocks * self.span_bytes as u64 / (2 * self.cfg.layers) as u64
    }

    /// Physical bytes a sequence currently holds across every stream, at
    /// sub-pool widths (shared blocks counted at full cost — this is the
    /// holder's footprint, not its exclusive reclaim).
    pub fn seq_bytes(&self, id: SeqId) -> u64 {
        let Some(seq) = self.seqs.get(&id) else { return 0 };
        seq.tables
            .iter()
            .flat_map(|pair| pair.iter())
            .flat_map(|t| t.blocks())
            .map(|&b| self.pool.block_bytes_of(b) as u64)
            .sum()
    }

    pub fn new_sequence(&mut self) -> SeqId {
        let id = self.next_id;
        self.next_id += 1;
        let seq = SequenceCache {
            id,
            len: 0,
            tables: (0..self.cfg.layers).map(|_| [BlockTable::new(), BlockTable::new()]).collect(),
            scales: (0..self.cfg.layers).map(|_| [Vec::new(), Vec::new()]).collect(),
        };
        self.seqs.insert(id, seq);
        id
    }

    /// Fork a sequence: shares all current blocks copy-on-write (prefix
    /// sharing for e.g. parallel sampling from one prompt).
    pub fn fork(&mut self, src: SeqId) -> Result<SeqId> {
        let id = self.next_id;
        self.next_id += 1;
        let src_seq = self.seqs.get(&src).ok_or_else(|| anyhow!("fork of unknown seq {src}"))?;
        let tables: Vec<[BlockTable; 2]> = src_seq
            .tables
            .iter()
            .map(|pair| [pair[0].clone(), pair[1].clone()])
            .collect();
        let new = SequenceCache {
            id,
            len: src_seq.len,
            scales: src_seq.scales.clone(),
            tables,
        };
        for pair in &new.tables {
            for t in pair {
                for &b in t.blocks() {
                    self.pool.retain(b);
                }
            }
        }
        self.seqs.insert(id, new);
        Ok(id)
    }

    /// Release all blocks of a sequence — legal at any point of its life
    /// (mid-flight preemption included). Refcount-aware: blocks shared
    /// with other sequences stay resident; only last-holder blocks return
    /// to the free list.
    pub fn free(&mut self, id: SeqId) {
        if let Some(mut seq) = self.seqs.remove(&id) {
            for pair in &mut seq.tables {
                for t in pair {
                    for b in t.drain() {
                        self.pool.release(b);
                    }
                }
            }
        }
    }

    pub fn seq_len(&self, id: SeqId) -> Option<usize> {
        self.seqs.get(&id).map(|s| s.len)
    }

    /// Blocks this sequence holds across all streams (logical footprint —
    /// shared blocks count here even though they occupy one pool slot).
    pub fn seq_blocks(&self, id: SeqId) -> usize {
        self.seqs
            .get(&id)
            .map(|s| s.tables.iter().map(|pair| pair[0].len() + pair[1].len()).sum())
            .unwrap_or(0)
    }

    /// Blocks that would return to the free list if this sequence were
    /// freed right now: only its refcount-1 blocks. Shared blocks (prefix
    /// cache / forks) stay resident for their other holders, so preemption
    /// planning must not count them as reclaimable.
    pub fn seq_reclaimable_blocks(&self, id: SeqId) -> usize {
        self.seqs
            .get(&id)
            .map(|s| {
                s.tables
                    .iter()
                    .flat_map(|pair| pair.iter())
                    .flat_map(|t| t.blocks())
                    .filter(|&&b| self.pool.refcount(b) == 1)
                    .count()
            })
            .unwrap_or(0)
    }

    /// Blocks a one-row [`Self::append_row`] on this sequence will take
    /// from the free list: 2·L fresh blocks at a block boundary, otherwise
    /// one per shared tail block that copy-on-write must duplicate.
    pub fn append_need_blocks(&self, id: SeqId) -> usize {
        let Some(seq) = self.seqs.get(&id) else { return 0 };
        if seq.len % self.cfg.block_size == 0 {
            return 2 * self.cfg.layers;
        }
        let tail_idx = (seq.len - 1) / self.cfg.block_size;
        seq.tables
            .iter()
            .flat_map(|pair| pair.iter())
            .filter(|t| self.pool.refcount(t.blocks()[tail_idx]) > 1)
            .count()
    }

    /// Byte analogue of [`Self::seq_reclaimable_blocks`]: physical bytes
    /// freeing this sequence returns to the pool (refcount-1 blocks at
    /// their class widths).
    pub fn seq_reclaimable_bytes(&self, id: SeqId) -> u64 {
        self.seqs
            .get(&id)
            .map(|s| {
                s.tables
                    .iter()
                    .flat_map(|pair| pair.iter())
                    .flat_map(|t| t.blocks())
                    .filter(|&&b| self.pool.refcount(b) == 1)
                    .map(|&b| self.pool.block_bytes_of(b) as u64)
                    .sum()
            })
            .unwrap_or(0)
    }

    /// Byte analogue of [`Self::append_need_blocks`]: a boundary append
    /// opens one block per stream (a full span); mid-block it pays only
    /// for COW copies of shared tails, at their class widths.
    pub fn append_need_bytes(&self, id: SeqId) -> u64 {
        let Some(seq) = self.seqs.get(&id) else { return 0 };
        if seq.len % self.cfg.block_size == 0 {
            return self.span_bytes as u64;
        }
        let tail_idx = (seq.len - 1) / self.cfg.block_size;
        seq.tables
            .iter()
            .flat_map(|pair| pair.iter())
            .map(|t| t.blocks()[tail_idx])
            .filter(|&b| self.pool.refcount(b) > 1)
            .map(|b| self.pool.block_bytes_of(b) as u64)
            .sum()
    }

    /// Per-class block demand of a one-row append (fresh span at a
    /// boundary, COW copies of shared tails mid-block) — the atomicity
    /// precheck must clear every class, not just the pool total.
    fn append_need_by_class(&self, id: SeqId) -> Vec<usize> {
        let mut need = vec![0usize; self.pool.num_classes()];
        let Some(seq) = self.seqs.get(&id) else { return need };
        if seq.len % self.cfg.block_size == 0 {
            for pair in &self.stream_class {
                need[pair[0]] += 1;
                need[pair[1]] += 1;
            }
            return need;
        }
        let tail_idx = (seq.len - 1) / self.cfg.block_size;
        for pair in &seq.tables {
            for t in pair {
                let b = t.blocks()[tail_idx];
                if self.pool.refcount(b) > 1 {
                    need[pool::class_of(b)] += 1;
                }
            }
        }
        need
    }

    /// Verify pool refcounts exactly match the live block tables plus
    /// external pins: every used block is reachable, every reference is
    /// counted once, and nothing is leaked. O(blocks); debug/test aid,
    /// also run on drop.
    pub fn assert_refcounts_consistent(&self) {
        let mut counted = self.extern_pins.clone();
        for seq in self.seqs.values() {
            for pair in &seq.tables {
                for t in pair {
                    for &b in t.blocks() {
                        counted[self.pool.dense_index(b)] += 1;
                    }
                }
            }
        }
        for (i, id) in self.pool.all_ids().enumerate() {
            let rc = self.pool.refcount(id);
            let c = counted[i];
            assert_eq!(
                c, rc,
                "block {id}: {rc} pool refs vs {c} table+pin refs (leak or double-hold)"
            );
        }
    }

    /// Take an external hold on a block (prefix-cache trie ownership —
    /// the block belongs to no sequence while pinned). Balanced by
    /// [`Self::unpin_block`].
    pub fn pin_block(&mut self, id: BlockId) {
        self.pool.retain(id);
        self.extern_pins[self.pool.dense_index(id)] += 1;
    }

    /// Release an external hold taken by [`Self::pin_block`].
    pub fn unpin_block(&mut self, id: BlockId) {
        let di = self.pool.dense_index(id);
        assert!(self.extern_pins[di] > 0, "unpin of unpinned block {id}");
        self.extern_pins[di] -= 1;
        self.pool.release(id);
    }

    /// Pool refcount of a block (pins + table holds).
    pub fn block_refcount(&self, id: BlockId) -> u32 {
        self.pool.refcount(id)
    }

    /// Ordered blocks of one (layer, K|V) stream of a sequence.
    pub fn seq_stream_blocks(&self, id: SeqId, layer: usize, kv: usize) -> Result<&[BlockId]> {
        Ok(self
            .seqs
            .get(&id)
            .ok_or_else(|| anyhow!("unknown seq {id}"))?
            .tables[layer][kv]
            .blocks())
    }

    /// Build a sequence from externally-held blocks (prefix-cache
    /// adoption): per (layer, K|V) an ordered block list plus the
    /// matching per-block scale grids, exactly as
    /// [`SequenceCache::scales`] lays them out. Every block is retained —
    /// the caller keeps its own holds (trie pins) and the new sequence
    /// shares the payload copy-on-write, so a later append COWs the tail
    /// instead of mutating the cached bytes.
    pub fn adopt_sequence(
        &mut self,
        tables: Vec<[Vec<BlockId>; 2]>,
        scales: Vec<[Vec<f32>; 2]>,
        len: usize,
    ) -> Result<SeqId> {
        let (l, hd, bs) =
            (self.cfg.layers, self.cfg.heads * self.cfg.head_dim, self.cfg.block_size);
        if tables.len() != l || scales.len() != l {
            bail!("adopt_sequence: {} layer tables for {l}-layer cache", tables.len());
        }
        let nblocks = BlockTable::blocks_for(len, bs);
        for (pair_t, pair_s) in tables.iter().zip(&scales) {
            for kv in 0..2 {
                if pair_t[kv].len() != nblocks || pair_s[kv].len() != nblocks * hd {
                    bail!(
                        "adopt_sequence: stream has {} blocks / {} scales for len {len}",
                        pair_t[kv].len(),
                        pair_s[kv].len()
                    );
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut seq_tables = Vec::with_capacity(l);
        for pair in &tables {
            let mut bt = [BlockTable::new(), BlockTable::new()];
            for kv in 0..2 {
                for &b in &pair[kv] {
                    self.pool.retain(b);
                    bt[kv].push(b);
                }
            }
            seq_tables.push(bt);
        }
        self.seqs.insert(id, SequenceCache { id, len, tables: seq_tables, scales });
        Ok(id)
    }

    /// Like [`Self::adopt_sequence`] but the new sequence **takes over**
    /// the caller's existing hold on every block instead of adding one —
    /// the cold-tier promotion path, whose freshly restored blocks carry
    /// refcount 1 with no other owner. Validation is identical; on error
    /// the caller still owns the blocks.
    pub fn adopt_owned_sequence(
        &mut self,
        tables: Vec<[Vec<BlockId>; 2]>,
        scales: Vec<[Vec<f32>; 2]>,
        len: usize,
    ) -> Result<SeqId> {
        let (l, hd, bs) =
            (self.cfg.layers, self.cfg.heads * self.cfg.head_dim, self.cfg.block_size);
        if tables.len() != l || scales.len() != l {
            bail!("adopt_owned_sequence: {} layer tables for {l}-layer cache", tables.len());
        }
        let nblocks = BlockTable::blocks_for(len, bs);
        for (pair_t, pair_s) in tables.iter().zip(&scales) {
            for kv in 0..2 {
                if pair_t[kv].len() != nblocks || pair_s[kv].len() != nblocks * hd {
                    bail!(
                        "adopt_owned_sequence: stream has {} blocks / {} scales for len {len}",
                        pair_t[kv].len(),
                        pair_s[kv].len()
                    );
                }
            }
        }
        let id = self.next_id;
        self.next_id += 1;
        let mut seq_tables = Vec::with_capacity(l);
        for pair in &tables {
            let mut bt = [BlockTable::new(), BlockTable::new()];
            for kv in 0..2 {
                for &b in &pair[kv] {
                    bt[kv].push(b);
                }
            }
            seq_tables.push(bt);
        }
        self.seqs.insert(id, SequenceCache { id, len, tables: seq_tables, scales });
        Ok(id)
    }

    /// Raw payload bytes of one pool block (cold-tier demotion capture).
    pub fn block_payload(&self, id: BlockId) -> &[u8] {
        self.pool.block_raw(id)
    }

    /// Allocate a block in stream `(layer, kv)`'s width class and fill
    /// it with `bytes` (cold-tier promotion restore). The returned block
    /// carries refcount 1 owned by the caller.
    pub fn restore_block(&mut self, layer: usize, kv: usize, bytes: &[u8]) -> Result<BlockId> {
        let class = self.stream_class[layer][kv];
        let width = self.pool.class_block_bytes(class);
        if width != bytes.len() {
            bail!("restore_block: {} bytes for a {width}-byte class", bytes.len());
        }
        let b = self.pool.alloc_in(class)?;
        self.pool.block_mut_raw(b).copy_from_slice(bytes);
        Ok(b)
    }

    /// Release a caller-owned block hold (undoes [`Self::restore_block`]
    /// when a promotion aborts midway).
    pub fn release_block(&mut self, id: BlockId) {
        self.pool.release(id);
    }

    /// Byte layout of one (layer, K|V) stream under the cache's policy.
    pub fn stream_layout(&self, layer: usize, kv: usize) -> &StreamLayout {
        &self.layouts[layer][kv]
    }

    /// Frozen per-block scales of one (layer, K|V) stream, length
    /// `allocated_blocks · heads · head_dim` (block-major; block `b`'s
    /// grid at `b·H·d..(b+1)·H·d`).
    pub fn scales(&self, id: SeqId, layer: usize, kv: usize) -> Result<&[f32]> {
        Ok(&self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?.scales[layer][kv])
    }

    /// Write the prefill K/V for a sequence and freeze its scales.
    ///
    /// `k`/`v` are the prefill artifact outputs, layout `(L, H, S, d)`
    /// flattened with only the first `len` token rows valid, where S is
    /// inferred from the tensor size (bucketed prefill artifacts emit
    /// S < max_seq; see EXPERIMENTS.md §Perf).
    ///
    /// Both the scale freeze and the block quantize/copy are batched and
    /// run on the shared parallel runtime for long prompts (disjoint
    /// streams / blocks per worker — output bits never depend on the
    /// worker count).
    pub fn set_prefill(&mut self, id: SeqId, k: &[f32], v: &[f32], len: usize) -> Result<()> {
        let (l, h, d) = (self.cfg.layers, self.cfg.heads, self.cfg.head_dim);
        if k.len() % (l * h * d) != 0 || v.len() != k.len() {
            bail!("prefill tensor size mismatch: {} not a multiple of {}", k.len(), l * h * d);
        }
        let s = k.len() / (l * h * d); // source sequence stride (bucket)
        if len > s || len > self.cfg.max_seq {
            bail!("prefill len {len} > stride {s} or max_seq {}", self.cfg.max_seq);
        }
        if self.policy.uses(Precision::Int4) && d % 2 != 0 {
            bail!("int4 streams require an even head_dim (rows must be nibble-aligned)");
        }
        {
            let seq = self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?;
            if seq.len != 0 {
                bail!("set_prefill on non-empty sequence {id}");
            }
        }
        // Freeze scales per block: for every block, per (layer, kv, head,
        // channel) abs-max over the block's OWN rows, divided by each head
        // codec's symmetric bound (127 for FP32/INT8, 7 for INT4 —
        // `Codec::qmax` owns the grid), inflated by the margin. One worker
        // per (layer, K|V) stream. Identical expressions to the chunked
        // [`Self::append_prefill_chunk`] freeze, so a whole-prompt prefill
        // and a block-chunked one store bit-identical grids.
        let margin = self.cfg.scale_margin;
        let bs = self.cfg.block_size;
        let nblocks = BlockTable::blocks_for(len, bs);
        let threads = self.threads_for(2 * l * h * d * len);
        let streams: Vec<(usize, usize)> =
            (0..l).flat_map(|layer| [(layer, 0), (layer, 1)]).collect();
        let layouts = &self.layouts;
        let frozen: Vec<Vec<f32>> = parallel::parallel_map(&streams, threads, |&(layer, kv)| {
            let data = if kv == 0 { k } else { v };
            let layout = &layouts[layer][kv];
            let mut sc = vec![0.0f32; nblocks * h * d];
            for bi in 0..nblocks {
                let rows_here = bs.min(len - bi * bs);
                for head in 0..h {
                    let qdiv = layout.head_codec(head).qmax();
                    let base = ((layer * h) + head) * s * d;
                    for ch in 0..d {
                        let mut m = 0.0f32;
                        for r in 0..rows_here {
                            let val = data[base + (bi * bs + r) * d + ch].abs();
                            if val > m {
                                m = val;
                            }
                        }
                        sc[bi * h * d + head * d + ch] = m * margin / qdiv;
                    }
                }
            }
            sc
        });
        {
            let seq = self.seqs.get_mut(&id).unwrap();
            for (&(layer, kv), sc) in streams.iter().zip(frozen) {
                seq.scales[layer][kv] = sc;
            }
        }
        // Allocate blocks (each stream from its width class) and write
        // the rows, one worker per block.
        for layer in 0..l {
            for kv in 0..2 {
                let class = self.stream_class[layer][kv];
                for _ in 0..nblocks {
                    let b = self.pool.alloc_in(class)?;
                    self.seqs.get_mut(&id).unwrap().tables[layer][kv].push(b);
                }
            }
        }
        self.prefill_write(id, k, v, s, len, threads);
        self.seqs.get_mut(&id).unwrap().len = len;
        Ok(())
    }

    /// Batched prefill write: encode all `len` rows of every (layer, K|V)
    /// stream directly into their blocks through each head's codec
    /// (quantize for INT8/INT4, bit-exact copy for FP32). Freshly
    /// allocated blocks are unique (refcount 1), so per-block writes are
    /// disjoint and fan out across workers.
    fn prefill_write(
        &mut self,
        id: SeqId,
        k: &[f32],
        v: &[f32],
        s: usize,
        len: usize,
        threads: usize,
    ) {
        let (l, h, d, bs) =
            (self.cfg.layers, self.cfg.heads, self.cfg.head_dim, self.cfg.block_size);
        let nblocks = BlockTable::blocks_for(len, bs);
        let isa = self.isa;
        for layer in 0..l {
            for (kv, data) in [k, v].into_iter().enumerate() {
                let layout = self.layouts[layer][kv].clone();
                let scales = self.seqs[&id].scales[layer][kv].clone();
                let blocks = self.seqs[&id].tables[layer][kv].blocks()[..nblocks].to_vec();
                let ptrs: Vec<SendPtr<u8>> =
                    self.pool.block_raw_ptrs(&blocks).into_iter().map(SendPtr::new).collect();
                let payload = layout.block_bytes;
                parallel::parallel_chunks(nblocks, 1, threads, |blo, bhi| {
                    for bi in blo..bhi {
                        let rows_here = bs.min(len - bi * bs);
                        // SAFETY: distinct block ids → disjoint payloads.
                        let blk =
                            unsafe { std::slice::from_raw_parts_mut(ptrs[bi].add(0), payload) };
                        let block_sc = &scales[bi * h * d..(bi + 1) * h * d];
                        for head in 0..h {
                            let codec = layout.head_codec(head);
                            let base = ((layer * h) + head) * s * d;
                            let sc = &block_sc[head * d..(head + 1) * d];
                            for r in 0..rows_here {
                                let pos = bi * bs + r;
                                let src = &data[base + pos * d..base + (pos + 1) * d];
                                codec.encode_row(isa, src, sc, &mut blk[layout.row_range(head, r)]);
                            }
                        }
                    }
                });
            }
        }
    }

    /// Append one prefill chunk of at most `block_size` rows starting at
    /// the sequence's current (block-aligned) length: freezes the new
    /// block's scale grid over the chunk's own rows and encodes them —
    /// the chunked twin of [`Self::set_prefill`], used by the engine's
    /// block-granular prefill so a suffix prefill after a partial prefix
    /// hit stores exactly the bytes a from-scratch prefill would.
    ///
    /// `k`/`v` are chunk tensors, layout `(L, H, C, d)` flattened with the
    /// first `chunk_len` rows valid (C inferred from the tensor size).
    /// Atomic: allocates 2·L blocks up front or fails without mutating
    /// the sequence.
    pub fn append_prefill_chunk(
        &mut self,
        id: SeqId,
        k: &[f32],
        v: &[f32],
        chunk_len: usize,
    ) -> Result<()> {
        let (l, h, d, bs) =
            (self.cfg.layers, self.cfg.heads, self.cfg.head_dim, self.cfg.block_size);
        if k.len() % (l * h * d) != 0 || v.len() != k.len() {
            bail!("chunk tensor size mismatch: {} not a multiple of {}", k.len(), l * h * d);
        }
        let c = k.len() / (l * h * d); // chunk row stride
        if chunk_len == 0 || chunk_len > c || chunk_len > bs {
            bail!("chunk len {chunk_len} out of range (stride {c}, block_size {bs})");
        }
        let start = {
            let seq = self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?;
            if seq.len % bs != 0 {
                bail!("append_prefill_chunk at non-aligned len {}", seq.len);
            }
            if seq.len + chunk_len > self.cfg.max_seq {
                bail!("chunk overflows max_seq {}", self.cfg.max_seq);
            }
            seq.len
        };
        // Span-aware precheck: one fresh block per stream, each from its
        // own class (a drained class fails the chunk even if other
        // classes have room).
        if self.spans_free() == 0 {
            bail!(
                "block pool exhausted: chunk needs {} blocks, {} free",
                2 * l,
                self.pool.free_blocks()
            );
        }
        let margin = self.cfg.scale_margin;
        for layer in 0..l {
            for (kv, data) in [k, v].into_iter().enumerate() {
                let layout = self.layouts[layer][kv].clone();
                // Freeze this block's grid over the chunk rows — the same
                // expressions as the whole-prompt freeze restricted to one
                // block, so both paths store identical grids.
                let mut sc = vec![0.0f32; h * d];
                for head in 0..h {
                    let qdiv = layout.head_codec(head).qmax();
                    let base = ((layer * h) + head) * c * d;
                    for ch in 0..d {
                        let mut m = 0.0f32;
                        for r in 0..chunk_len {
                            let val = data[base + r * d + ch].abs();
                            if val > m {
                                m = val;
                            }
                        }
                        sc[head * d + ch] = m * margin / qdiv;
                    }
                }
                let b = self.pool.alloc_in(self.stream_class[layer][kv])?;
                let blk = self.pool.block_mut_raw(b);
                for head in 0..h {
                    let codec = layout.head_codec(head);
                    let base = ((layer * h) + head) * c * d;
                    let hsc = &sc[head * d..(head + 1) * d];
                    for r in 0..chunk_len {
                        let src = &data[base + r * d..base + (r + 1) * d];
                        codec.encode_row(self.isa, src, hsc, &mut blk[layout.row_range(head, r)]);
                    }
                }
                let seq = self.seqs.get_mut(&id).unwrap();
                seq.tables[layer][kv].push(b);
                seq.scales[layer][kv].extend_from_slice(&sc);
            }
        }
        self.seqs.get_mut(&id).unwrap().len = start + chunk_len;
        Ok(())
    }

    /// Append one decode-step K/V row (layout `(L, H, d)` flattened).
    pub fn append_row(&mut self, id: SeqId, k_new: &[f32], v_new: &[f32]) -> Result<()> {
        let (l, h, d) = (self.cfg.layers, self.cfg.heads, self.cfg.head_dim);
        if k_new.len() != l * h * d || v_new.len() != k_new.len() {
            bail!("row tensor size mismatch");
        }
        let (pos, need_block) = {
            let seq = self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?;
            if seq.len >= self.cfg.max_seq {
                bail!("sequence {id} at capacity {}", self.cfg.max_seq);
            }
            (seq.len, seq.len % self.cfg.block_size == 0)
        };
        // Atomicity: fail before touching the tables if the pool cannot
        // cover this append (fresh blocks and/or COW copies), so a caller
        // can reclaim blocks (evict prefix cache, preempt a victim) and
        // retry without leaking half-allocated streams.
        let need_by_class = self.append_need_by_class(id);
        if need_by_class
            .iter()
            .enumerate()
            .any(|(c, &n)| n > self.pool.class_free_blocks(c))
        {
            bail!(
                "block pool exhausted: append needs {} blocks, {} free",
                need_by_class.iter().sum::<usize>(),
                self.pool.free_blocks()
            );
        }
        if need_block {
            // Opening a block mid-generation: inherit the previous
            // block's frozen grid (deterministic — no decode-time rows
            // are ever consulted, so replay after preemption refreezes
            // identically). The very first block of a never-prefilled
            // sequence gets a zero grid, matching the legacy
            // initial-scale state.
            let hd = h * d;
            for layer in 0..l {
                for kv in 0..2 {
                    let b = self.pool.alloc_in(self.stream_class[layer][kv])?;
                    let seq = self.seqs.get_mut(&id).unwrap();
                    seq.tables[layer][kv].push(b);
                    let sc = &mut seq.scales[layer][kv];
                    if sc.is_empty() {
                        sc.extend(std::iter::repeat(0.0).take(hd));
                    } else {
                        let tail = sc[sc.len() - hd..].to_vec();
                        sc.extend_from_slice(&tail);
                    }
                }
            }
        }
        // Copy-on-write the tail block if shared (forked sequences).
        let tail_idx = pos / self.cfg.block_size;
        for layer in 0..l {
            for kv in 0..2 {
                let cur = self.seqs[&id].tables[layer][kv].blocks()[tail_idx];
                let uniq = self.pool.ensure_unique(cur)?;
                if uniq != cur {
                    self.seqs.get_mut(&id).unwrap().tables[layer][kv].replace(tail_idx, uniq);
                }
            }
        }
        for layer in 0..l {
            for (kv, data) in [k_new, v_new].into_iter().enumerate() {
                let row = &data[layer * h * d..(layer + 1) * h * d];
                self.write_one_row(id, layer, kv, pos, row)?;
            }
        }
        self.seqs.get_mut(&id).unwrap().len = pos + 1;
        Ok(())
    }

    /// Encode one (H, d) row into its block through each head's codec
    /// (decode append path; the prefill path uses the batched writer).
    fn write_one_row(
        &mut self,
        id: SeqId,
        layer: usize,
        kv: usize,
        pos: usize,
        row: &[f32],
    ) -> Result<()> {
        let (h, d, bs) = (self.cfg.heads, self.cfg.head_dim, self.cfg.block_size);
        let seq = self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?;
        let (block, in_row) = seq.tables[layer][kv].locate(pos, bs);
        // Clamp into the row's own block grid (the last block's — decode
        // appends only ever write the tail).
        let bi = pos / bs;
        let scales = &seq.scales[layer][kv][bi * h * d..(bi + 1) * h * d];
        let layout = &self.layouts[layer][kv];
        let blk = self.pool.block_mut_raw(block);
        for head in 0..h {
            let codec = layout.head_codec(head);
            let src = &row[head * d..(head + 1) * d];
            let sc = &scales[head * d..(head + 1) * d];
            codec.encode_row(self.isa, src, sc, &mut blk[layout.row_range(head, in_row)]);
        }
        Ok(())
    }

    /// Gather one (layer, K|V) stream into contiguous `(H, max_seq, d)`
    /// i8 staging — the decode artifact's cache input layout. Only valid
    /// for uniform-INT8 streams (the dense ABI); every other policy
    /// decodes through the paged [`CacheView`]. Only the first `len` rows
    /// are written; the artifact masks the rest by `pos`. Long sequences
    /// fan out across workers, one block per unit (all (head, block)
    /// destination ranges are disjoint).
    pub fn gather_i8(&self, id: SeqId, layer: usize, kv: usize, dst: &mut [i8]) -> Result<usize> {
        self.gather_i8_with(id, layer, kv, dst, self.threads)
    }

    /// [`Self::gather_i8`] with an explicit worker cap — the engine's
    /// decode waves pass 1 when the call already runs on a wave worker.
    pub fn gather_i8_with(
        &self,
        id: SeqId,
        layer: usize,
        kv: usize,
        dst: &mut [i8],
        max_threads: usize,
    ) -> Result<usize> {
        if self.layouts[layer][kv].uniform != Some(Precision::Int8) {
            bail!(
                "staged i8 gather needs a uniform int8 stream (policy {})",
                self.policy.name()
            );
        }
        let (h, s, d, bs) =
            (self.cfg.heads, self.cfg.max_seq, self.cfg.head_dim, self.cfg.block_size);
        if dst.len() != h * s * d {
            bail!("staging size mismatch: {} vs {}", dst.len(), h * s * d);
        }
        let seq = self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?;
        let table = &seq.tables[layer][kv];
        let len = seq.len;
        let used = BlockTable::blocks_for(len, bs).min(table.blocks().len());
        let blocks = &table.blocks()[..used];
        let threads = self.threads_capped(len * h * d, max_threads.min(self.threads));
        let dstp = SendPtr::new(dst.as_mut_ptr() as *mut u8);
        parallel::parallel_chunks(used, 1, threads, |lo, hi| {
            for bi in lo..hi {
                let rows_here = bs.min(len.saturating_sub(bi * bs));
                let blk = self.pool.block_raw(blocks[bi]);
                for head in 0..h {
                    // Uniform int8: one byte per element, head-major.
                    let src = &blk[head * bs * d..(head * bs + rows_here) * d];
                    let doff = head * s * d + bi * bs * d;
                    // SAFETY: (head, block) ranges are disjoint across
                    // workers and in bounds of dst (checked above).
                    let dslice =
                        unsafe { std::slice::from_raw_parts_mut(dstp.add(doff), rows_here * d) };
                    dslice.copy_from_slice(src);
                }
            }
        });
        Ok(len)
    }

    /// FP32 variant of [`Self::gather_i8`] (uniform-FP32 streams only).
    pub fn gather_f32(&self, id: SeqId, layer: usize, kv: usize, dst: &mut [f32]) -> Result<usize> {
        self.gather_f32_with(id, layer, kv, dst, self.threads)
    }

    /// [`Self::gather_f32`] with an explicit worker cap (see
    /// [`Self::gather_i8_with`]).
    pub fn gather_f32_with(
        &self,
        id: SeqId,
        layer: usize,
        kv: usize,
        dst: &mut [f32],
        max_threads: usize,
    ) -> Result<usize> {
        if self.layouts[layer][kv].uniform != Some(Precision::Fp32) {
            bail!(
                "staged f32 gather needs a uniform fp32 stream (policy {})",
                self.policy.name()
            );
        }
        let (h, s, d, bs) =
            (self.cfg.heads, self.cfg.max_seq, self.cfg.head_dim, self.cfg.block_size);
        if dst.len() != h * s * d {
            bail!("staging size mismatch");
        }
        let seq = self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?;
        let table = &seq.tables[layer][kv];
        let len = seq.len;
        let used = BlockTable::blocks_for(len, bs).min(table.blocks().len());
        let blocks = &table.blocks()[..used];
        let threads = self.threads_capped(len * h * d, max_threads.min(self.threads));
        let dstp = SendPtr::new(dst.as_mut_ptr() as *mut u8);
        parallel::parallel_chunks(used, 1, threads, |lo, hi| {
            for bi in lo..hi {
                let rows_here = bs.min(len.saturating_sub(bi * bs));
                let blk = self.pool.block_raw(blocks[bi]);
                for head in 0..h {
                    // Uniform fp32: 4 bytes per element, head-major.
                    let src = &blk[head * bs * d * 4..(head * bs + rows_here) * d * 4];
                    let doff = (head * s * d + bi * bs * d) * 4;
                    // SAFETY: (head, block) byte ranges are disjoint
                    // across workers and in bounds of dst (checked above);
                    // a bit-exact byte copy of f32 payloads.
                    let dslice = unsafe {
                        std::slice::from_raw_parts_mut(dstp.add(doff), rows_here * d * 4)
                    };
                    dslice.copy_from_slice(src);
                }
            }
        });
        Ok(len)
    }

    /// Zero-copy view of one sequence's cache: per-(layer, K|V) block
    /// slices plus frozen scales and per-head codecs, borrowed straight
    /// from the pool. The fused paged decode path attends over this in
    /// place — nothing is materialized per token (contrast
    /// [`Self::gather_i8`]).
    pub fn view(&self, id: SeqId) -> Result<CacheView<'_>> {
        let seq = self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?;
        Ok(CacheView { pool: &self.pool, seq, cfg: &self.cfg, layouts: &self.layouts })
    }

    /// Wave-level view over a decode wave's sequences, for the fused
    /// multi-query decode path. Per (layer, K|V) stream the wave's blocks
    /// are grouped by (logical block index, physical block id, valid
    /// rows): a COW-shared prefix block appears in ONE [`WaveGroup`]
    /// listing every wave member that references it, so the batched
    /// kernels dequantize it once and fan scores/accumulations out to all
    /// members. Members only join a group when their frozen stream scales
    /// are bit-equal (always true for fork-derived sharing — fork clones
    /// scales — but checked, so dedup can never change dequantized
    /// values). Groups are ordered ascending by logical block index,
    /// which keeps each member's V-accumulation order identical to its
    /// per-sequence block walk — load-bearing for bit-identity.
    ///
    /// Member indices in the groups refer to positions in `ids`.
    pub fn wave_view(&self, ids: &[SeqId]) -> Result<WaveView<'_>> {
        let mut seqs = Vec::with_capacity(ids.len());
        for &id in ids {
            seqs.push(self.seqs.get(&id).ok_or_else(|| anyhow!("unknown seq {id}"))?);
        }
        let bs = self.cfg.block_size;
        let mut groups: Vec<[Vec<WaveGroup>; 2]> = Vec::with_capacity(self.cfg.layers);
        let mut deduped = 0usize;
        for layer in 0..self.cfg.layers {
            let mut pair: [Vec<WaveGroup>; 2] = [Vec::new(), Vec::new()];
            for (kv, out) in pair.iter_mut().enumerate() {
                let max_blocks = seqs
                    .iter()
                    .map(|s| {
                        BlockTable::blocks_for(s.len, bs).min(s.tables[layer][kv].len())
                    })
                    .max()
                    .unwrap_or(0);
                for bi in 0..max_blocks {
                    let first_at_bi = out.len();
                    for (m, seq) in seqs.iter().enumerate() {
                        let table = &seq.tables[layer][kv];
                        let used = BlockTable::blocks_for(seq.len, bs).min(table.len());
                        if bi >= used {
                            continue;
                        }
                        let rows = bs.min(seq.len - bi * bs);
                        let block = table.blocks()[bi];
                        // Per-block scale grids: members join on bit-equal
                        // scales of THIS block only — a diverged tail no
                        // longer un-shares the whole stream's prefix.
                        let hd = self.cfg.heads * self.cfg.head_dim;
                        let sc = &seq.scales[layer][kv][bi * hd..(bi + 1) * hd];
                        let joined = out[first_at_bi..].iter_mut().find(|g| {
                            g.block == block
                                && g.rows == rows
                                && seqs[g.members[0]].scales[layer][kv]
                                    [bi * hd..(bi + 1) * hd]
                                    == *sc
                        });
                        match joined {
                            Some(g) => {
                                g.members.push(m);
                                deduped += 1;
                            }
                            None => out.push(WaveGroup { bi, rows, block, members: vec![m] }),
                        }
                    }
                }
            }
            groups.push(pair);
        }
        Ok(WaveView {
            pool: &self.pool,
            cfg: &self.cfg,
            layouts: &self.layouts,
            seqs,
            groups,
            deduped,
        })
    }
}

/// Borrow-based, read-only view of one sequence's paged cache (see
/// [`KvCacheManager::view`]). Holding a view borrows the manager
/// immutably, so appends/frees cannot invalidate it mid-read.
pub struct CacheView<'a> {
    pool: &'a BlockPool,
    seq: &'a SequenceCache,
    cfg: &'a CacheConfig,
    layouts: &'a [[StreamLayout; 2]],
}

impl<'a> CacheView<'a> {
    /// Valid token rows (the decode `pos`).
    pub fn len(&self) -> usize {
        self.seq.len
    }

    pub fn is_empty(&self) -> bool {
        self.seq.len == 0
    }

    pub fn layers(&self) -> usize {
        self.cfg.layers
    }

    pub fn heads(&self) -> usize {
        self.cfg.heads
    }

    pub fn head_dim(&self) -> usize {
        self.cfg.head_dim
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    /// Frozen per-block scales of one (layer, K|V) stream, block-major
    /// (`allocated_blocks · heads · head_dim`; see
    /// [`KvCacheManager::scales`]).
    pub fn scales(&self, layer: usize, kv: usize) -> &'a [f32] {
        &self.seq.scales[layer][kv]
    }

    /// Per-stream block view (kv: 0 = K, 1 = V).
    pub fn stream(&self, layer: usize, kv: usize) -> StreamView<'a> {
        let table = &self.seq.tables[layer][kv];
        let used = BlockTable::blocks_for(self.seq.len, self.cfg.block_size)
            .min(table.blocks().len());
        StreamView {
            pool: self.pool,
            blocks: &table.blocks()[..used],
            scales: &self.seq.scales[layer][kv],
            layout: &self.layouts[layer][kv],
            len: self.seq.len,
            block_size: self.cfg.block_size,
            heads: self.cfg.heads,
            head_dim: self.cfg.head_dim,
        }
    }

    /// Payload + scale bytes one full attention pass over this view reads
    /// (valid rows of K and V across all layers/heads, each at its own
    /// codec's per-row width). This is the per-token cache traffic of the
    /// zero-copy path — O(len), not O(max_seq) — surfaced at
    /// `GET /metrics` as `cache_bytes_read`.
    ///
    /// Scale bytes are counted for **every** stream, fp32 included (whose
    /// codec never reads them) — deliberately: that is the pre-policy
    /// metric's convention, and the uniform presets must report byte
    /// counts identical to the legacy `--precision` paths. The
    /// memory-footprint accounting ([`QuantPolicy::scale_overhead_bytes`])
    /// uses the opposite convention (fp32 streams store no *useful*
    /// scales); the two measure different things — traffic vs footprint.
    ///
    /// With per-block grids the scale traffic is one `H·d` f32 grid per
    /// *touched block* per stream — still O(len), never O(max_seq).
    pub fn attention_bytes(&self) -> usize {
        let c = self.cfg;
        let nblocks = BlockTable::blocks_for(self.seq.len, c.block_size);
        let scale_bytes = nblocks * c.heads * c.head_dim * 4;
        self.layouts
            .iter()
            .flat_map(|pair| pair.iter())
            .map(|l| l.payload_bytes(self.seq.len) + scale_bytes)
            .sum()
    }
}

/// One (layer, K|V) stream of a [`CacheView`]: ordered blocks + frozen
/// scales + the stream's byte [`StreamLayout`]. Accessors return
/// per-(block, head) row slabs borrowed from the pool —
/// `rows_in_block(bi)` rows at the head codec's row width, ready for the
/// fused [`crate::quant::Codec`] kernels.
pub struct StreamView<'a> {
    pool: &'a BlockPool,
    blocks: &'a [BlockId],
    scales: &'a [f32],
    layout: &'a StreamLayout,
    len: usize,
    block_size: usize,
    heads: usize,
    head_dim: usize,
}

impl<'a> StreamView<'a> {
    /// Valid token rows in the stream.
    pub fn len(&self) -> usize {
        self.len
    }

    pub fn is_empty(&self) -> bool {
        self.len == 0
    }

    /// Blocks holding valid rows.
    pub fn num_blocks(&self) -> usize {
        self.blocks.len()
    }

    /// Valid rows inside block `bi` (the tail block may be partial).
    pub fn rows_in_block(&self, bi: usize) -> usize {
        self.block_size.min(self.len.saturating_sub(bi * self.block_size))
    }

    /// Frozen scales of one head in block `bi` (length `head_dim`) —
    /// the grid block `bi`'s rows were encoded with.
    pub fn head_scales(&self, bi: usize, head: usize) -> &'a [f32] {
        let hd = self.heads * self.head_dim;
        let base = bi * hd + head * self.head_dim;
        &self.scales[base..base + self.head_dim]
    }

    /// This head's storage codec under the cache's policy.
    pub fn head_codec(&self, head: usize) -> &'static dyn crate::quant::Codec {
        self.layout.head_codec(head)
    }

    /// The valid rows of `head` in block `bi` as raw page bytes —
    /// `rows_in_block(bi) × head_codec(head).bytes_per_row(d)` bytes, in
    /// place in the pool. Feed straight into the codec's fused kernels.
    pub fn head_rows_raw(&self, bi: usize, head: usize) -> &'a [u8] {
        let blk = self.pool.block_raw(self.blocks[bi]);
        &blk[self.layout.head_slab(head, self.rows_in_block(bi))]
    }

    /// Typed i8 view of [`Self::head_rows_raw`] (INT8 heads only).
    pub fn head_rows_i8(&self, bi: usize, head: usize) -> &'a [i8] {
        debug_assert_eq!(self.head_codec(head).name(), "int8");
        crate::quant::codec::as_i8(self.head_rows_raw(bi, head))
    }

    /// Typed f32 view of [`Self::head_rows_raw`] (FP32 heads only; slabs
    /// are 4-byte aligned by the stream layout).
    pub fn head_rows_f32(&self, bi: usize, head: usize) -> &'a [f32] {
        debug_assert_eq!(self.head_codec(head).name(), "fp32");
        crate::quant::codec::as_f32(self.head_rows_raw(bi, head))
    }

    /// Nibble-packed view (INT4 heads): `rows_in_block(bi) × head_dim/2`
    /// bytes (rows are byte-aligned — int4 streams require an even
    /// `head_dim`). Unpack per row with
    /// [`crate::quant::int4::dequantize4_row_into`].
    pub fn head_rows_i4(&self, bi: usize, head: usize) -> &'a [u8] {
        debug_assert_eq!(self.head_codec(head).name(), "int4");
        self.head_rows_raw(bi, head)
    }
}

/// One deduped physical block in a wave's (layer, K|V) pass: every wave
/// member in `members` reads this block at the same logical index with
/// the same valid rows and bit-equal scales, so one dequantization
/// serves them all (see [`KvCacheManager::wave_view`]).
#[derive(Debug, Clone)]
pub struct WaveGroup {
    /// Logical block index — identical for every member by COW
    /// construction (prefix sharing aligns blocks positionally).
    pub bi: usize,
    /// Valid token rows in the block (the tail block may be partial).
    pub rows: usize,
    /// Physical pool block backing the group.
    pub block: BlockId,
    /// Wave member indices (positions in the `ids` slice passed to
    /// `wave_view`) referencing this block. Never empty.
    pub members: Vec<usize>,
}

/// Read-only view of a whole decode wave with physical blocks deduped
/// per (layer, K|V) stream. Borrows the manager immutably, so appends
/// and frees cannot invalidate it mid-read. Built by
/// [`KvCacheManager::wave_view`].
pub struct WaveView<'a> {
    pool: &'a BlockPool,
    cfg: &'a CacheConfig,
    layouts: &'a [[StreamLayout; 2]],
    seqs: Vec<&'a SequenceCache>,
    /// groups[layer][kv], ascending by logical block index.
    groups: Vec<[Vec<WaveGroup>; 2]>,
    deduped: usize,
}

impl<'a> WaveView<'a> {
    /// Number of wave members (queries).
    pub fn width(&self) -> usize {
        self.seqs.len()
    }

    /// Valid token rows (the decode `pos`) of member `m`.
    pub fn len(&self, m: usize) -> usize {
        self.seqs[m].len
    }

    pub fn is_empty(&self) -> bool {
        self.seqs.is_empty()
    }

    pub fn layers(&self) -> usize {
        self.cfg.layers
    }

    pub fn heads(&self) -> usize {
        self.cfg.heads
    }

    pub fn head_dim(&self) -> usize {
        self.cfg.head_dim
    }

    pub fn block_size(&self) -> usize {
        self.cfg.block_size
    }

    /// Longest member length in the wave (sizes per-head score scratch).
    pub fn max_len(&self) -> usize {
        self.seqs.iter().map(|s| s.len).max().unwrap_or(0)
    }

    /// Physical blocks dequantized once on behalf of several members:
    /// Σ over groups of (members − 1). Surfaced at `GET /metrics` as
    /// `blocks_deduped`.
    pub fn blocks_deduped(&self) -> usize {
        self.deduped
    }

    /// Deduped block groups of one (layer, K|V) stream, ascending by
    /// logical block index.
    pub fn groups(&self, layer: usize, kv: usize) -> &[WaveGroup] {
        &self.groups[layer][kv]
    }

    /// Frozen scales of one head of one member's (layer, K|V) stream in
    /// block `bi` (length `head_dim`). For dequantizing a [`WaveGroup`],
    /// pass any member of the group and the group's `bi` — the grouping
    /// guarantees the block grids are bit-equal across members.
    pub fn head_scales(
        &self,
        m: usize,
        layer: usize,
        kv: usize,
        bi: usize,
        head: usize,
    ) -> &'a [f32] {
        let d = self.cfg.head_dim;
        let base = bi * self.cfg.heads * d + head * d;
        &self.seqs[m].scales[layer][kv][base..base + d]
    }

    /// Storage codec of one head of a (layer, K|V) stream — policy
    /// geometry, identical across members.
    pub fn head_codec(
        &self,
        layer: usize,
        kv: usize,
        head: usize,
    ) -> &'static dyn crate::quant::Codec {
        self.layouts[layer][kv].head_codec(head)
    }

    /// The valid rows of `head` in a group's physical block as raw page
    /// bytes — `group.rows × bytes_per_row(head_dim)` bytes, in place in
    /// the pool. Feed straight into the codec's fused multi-query
    /// kernels.
    pub fn head_rows_raw(&self, layer: usize, kv: usize, g: &WaveGroup, head: usize) -> &'a [u8] {
        let blk = self.pool.block_raw(g.block);
        &blk[self.layouts[layer][kv].head_slab(head, g.rows)]
    }

    /// Payload + scale bytes one batched attention pass over this wave
    /// reads, with dedup amortization: each group's payload AND its
    /// block scale grid are counted once regardless of member count (the
    /// grouping guarantees bit-equal grids within a group). For a wave of
    /// width 1 this equals [`CacheView::attention_bytes`]; for
    /// shared-prefix waves it is smaller than the sum of per-member views
    /// — the bandwidth saving surfaced at `GET /metrics` as
    /// `cache_bytes_read`.
    pub fn attention_bytes(&self) -> usize {
        let scale_bytes = self.cfg.heads * self.cfg.head_dim * 4;
        let mut total = 0usize;
        for layer in 0..self.cfg.layers {
            for kv in 0..2 {
                let layout = &self.layouts[layer][kv];
                total += self.groups[layer][kv]
                    .iter()
                    .map(|g| layout.payload_bytes(g.rows) + scale_bytes)
                    .sum::<usize>();
            }
        }
        total
    }
}

impl Drop for KvCacheManager {
    /// Double-free / leak guard: when a manager goes away, its pool
    /// refcounts must still exactly match the live block tables. Debug
    /// builds only (tier-1 tests run debug); skipped mid-panic so a
    /// failing test reports its own assertion, not a drop cascade.
    fn drop(&mut self) {
        if cfg!(debug_assertions) && !std::thread::panicking() {
            self.assert_refcounts_consistent();
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::policy::PolicySpec;
    use crate::util::rng::Rng;

    fn cfg() -> CacheConfig {
        CacheConfig {
            layers: 2,
            heads: 2,
            head_dim: 8,
            max_seq: 32,
            block_size: 4,
            num_blocks: 128,
            scale_margin: 1.0,
        }
    }

    fn mgr(c: CacheConfig, precision: Precision) -> KvCacheManager {
        KvCacheManager::new(c, QuantPolicy::uniform(precision, c.layers, c.heads))
    }

    fn prefill_tensors(c: &CacheConfig, len: usize, seed: u64) -> (Vec<f32>, Vec<f32>) {
        let n = c.layers * c.heads * c.max_seq * c.head_dim;
        let mut k = vec![0.0; n];
        let mut v = vec![0.0; n];
        let mut rng = Rng::new(seed);
        // Fill only valid rows; leave padding as garbage-ish constants to
        // verify it is never read.
        for layer in 0..c.layers {
            for head in 0..c.heads {
                for t in 0..c.max_seq {
                    for ch in 0..c.head_dim {
                        let i = ((layer * c.heads + head) * c.max_seq + t) * c.head_dim + ch;
                        if t < len {
                            k[i] = rng.uniform(-1.0, 1.0);
                            v[i] = rng.uniform(-1.0, 1.0);
                        } else {
                            k[i] = 99.0;
                            v[i] = -99.0;
                        }
                    }
                }
            }
        }
        (k, v)
    }

    #[test]
    fn prefill_roundtrip_within_quant_bound() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let len = 10;
        let (k, v) = prefill_tensors(&c, len, 1);
        m.set_prefill(id, &k, &v, len).unwrap();
        assert_eq!(m.seq_len(id), Some(len));

        let mut staging = vec![0i8; c.heads * c.max_seq * c.head_dim];
        let n = m.gather_i8(id, 1, 0, &mut staging).unwrap();
        assert_eq!(n, len);
        let scales = m.scales(id, 1, 0).unwrap().to_vec();
        let hd = c.heads * c.head_dim;
        assert_eq!(scales.len(), len.div_ceil(c.block_size) * hd, "one grid per block");
        // Dequantize and compare against the original K rows of layer 1,
        // each row through its own block's grid.
        for head in 0..c.heads {
            for t in 0..len {
                for ch in 0..c.head_dim {
                    let q = staging[(head * c.max_seq + t) * c.head_dim + ch];
                    let s = scales[(t / c.block_size) * hd + head * c.head_dim + ch];
                    let got = q as f32 * s;
                    let want = k[((1 * c.heads + head) * c.max_seq + t) * c.head_dim + ch];
                    assert!(
                        (got - want).abs() <= s / 2.0 + 1e-7,
                        "t={t} ch={ch}: {got} vs {want} (s={s})"
                    );
                }
            }
        }
    }

    #[test]
    fn append_then_gather_sees_new_rows() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 2);
        m.set_prefill(id, &k, &v, 4).unwrap();

        let hd = c.layers * c.heads * c.head_dim;
        let mut rng = Rng::new(3);
        let mut k_new = vec![0.0f32; hd];
        let mut v_new = vec![0.0f32; hd];
        rng.fill_uniform(&mut k_new, -0.5, 0.5);
        rng.fill_uniform(&mut v_new, -0.5, 0.5);
        m.append_row(id, &k_new, &v_new).unwrap();
        assert_eq!(m.seq_len(id), Some(5));

        let mut staging = vec![0i8; c.heads * c.max_seq * c.head_dim];
        m.gather_i8(id, 0, 1, &mut staging).unwrap(); // layer 0, V
        let scales = m.scales(id, 0, 1).unwrap();
        // Row 4 opened block 1, whose grid inherits block 0's frozen scales.
        for head in 0..c.heads {
            for ch in 0..c.head_dim {
                let q = staging[(head * c.max_seq + 4) * c.head_dim + ch];
                let s = scales[c.heads * c.head_dim + head * c.head_dim + ch];
                assert_eq!(s, scales[head * c.head_dim + ch], "inherited grid");
                let want = v_new[head * c.head_dim + ch]; // layer 0
                assert!((q as f32 * s - want).abs() <= s / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn append_clamps_to_frozen_scales() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 4);
        m.set_prefill(id, &k, &v, 4).unwrap();
        // New row 100x outside the prefill range must clamp, not wrap.
        let hd = c.layers * c.heads * c.head_dim;
        let k_new = vec![100.0f32; hd];
        let v_new = vec![-100.0f32; hd];
        m.append_row(id, &k_new, &v_new).unwrap();
        let mut staging = vec![0i8; c.heads * c.max_seq * c.head_dim];
        m.gather_i8(id, 0, 0, &mut staging).unwrap();
        for head in 0..c.heads {
            for ch in 0..c.head_dim {
                let q = staging[(head * c.max_seq + 4) * c.head_dim + ch];
                assert_eq!(q, 127, "clamped to +127");
            }
        }
    }

    #[test]
    fn capacity_and_admission() {
        let c = CacheConfig { num_blocks: 2 * 2 * 2, ..cfg() }; // 8 blocks
        let mut m = mgr(c, Precision::Int8);
        // One sequence of <=4 tokens needs 1 block x 2 layers x 2 (K,V) = 4.
        assert!(m.can_admit(4));
        assert!(m.can_admit(8)); // 8 blocks exactly
        assert!(!m.can_admit(9));
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 5);
        m.set_prefill(id, &k, &v, 4).unwrap();
        assert_eq!(m.free_blocks(), 4);
        assert!(!m.can_admit(8));
        m.free(id);
        assert_eq!(m.free_blocks(), 8);
        assert_eq!(m.live_sequences(), 0);
    }

    #[test]
    fn pool_exhaustion_surfaces_as_error() {
        let c = CacheConfig { num_blocks: 4, ..cfg() };
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 8, 6); // needs 2 blocks x4 streams = 8
        assert!(m.set_prefill(id, &k, &v, 8).is_err());
    }

    #[test]
    fn fp32_mode_roundtrips_exactly() {
        let c = cfg();
        let mut m = mgr(c, Precision::Fp32);
        let id = m.new_sequence();
        let len = 6;
        let (k, v) = prefill_tensors(&c, len, 7);
        m.set_prefill(id, &k, &v, len).unwrap();
        let mut staging = vec![0f32; c.heads * c.max_seq * c.head_dim];
        m.gather_f32(id, 0, 0, &mut staging).unwrap();
        for head in 0..c.heads {
            for t in 0..len {
                for ch in 0..c.head_dim {
                    let got = staging[(head * c.max_seq + t) * c.head_dim + ch];
                    let want = k[((head) * c.max_seq + t) * c.head_dim + ch];
                    assert_eq!(got, want);
                }
            }
        }
    }

    #[test]
    fn fork_shares_then_diverges() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let a = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 8);
        m.set_prefill(a, &k, &v, 4).unwrap();
        let used_before = c.num_blocks - m.free_blocks();
        let b = m.fork(a).unwrap();
        // Fork allocates nothing.
        assert_eq!(c.num_blocks - m.free_blocks(), used_before);
        // Appending to the fork triggers COW, not corruption of `a`.
        let hd = c.layers * c.heads * c.head_dim;
        m.append_row(b, &vec![0.25; hd], &vec![0.25; hd]).unwrap();
        assert_eq!(m.seq_len(a), Some(4));
        assert_eq!(m.seq_len(b), Some(5));
        let mut sa = vec![0i8; c.heads * c.max_seq * c.head_dim];
        let mut sb = vec![0i8; c.heads * c.max_seq * c.head_dim];
        m.gather_i8(a, 0, 0, &mut sa).unwrap();
        m.gather_i8(b, 0, 0, &mut sb).unwrap();
        // Shared prefix identical.
        for head in 0..c.heads {
            for t in 0..4 {
                for ch in 0..c.head_dim {
                    let i = (head * c.max_seq + t) * c.head_dim + ch;
                    assert_eq!(sa[i], sb[i]);
                }
            }
        }
        m.free(a);
        m.free(b);
        assert_eq!(m.free_blocks(), c.num_blocks, "all blocks returned");
    }

    #[test]
    fn parallel_paths_bit_identical_to_serial() {
        // Prefill + gather through the parallel runtime must store and
        // return exactly the bytes the serial path does.
        for precision in [Precision::Int8, Precision::Fp32] {
            let c = cfg();
            let len = 23; // crosses block boundaries, partial tail block
            let (k, v) = prefill_tensors(&c, len, 42);

            let mut serial = mgr(c, precision);
            let sid = serial.new_sequence();
            serial.set_prefill(sid, &k, &v, len).unwrap();

            let mut par = mgr(c, precision);
            par.set_parallelism(8);
            par.set_parallel_threshold(0); // force fan-out on small input
            let pid = par.new_sequence();
            par.set_prefill(pid, &k, &v, len).unwrap();

            let n = c.heads * c.max_seq * c.head_dim;
            for layer in 0..c.layers {
                for kv in 0..2 {
                    assert_eq!(
                        serial.scales(sid, layer, kv).unwrap(),
                        par.scales(pid, layer, kv).unwrap(),
                        "scales diverged at layer {layer} kv {kv}"
                    );
                    if precision == Precision::Int8 {
                        let mut a = vec![0i8; n];
                        let mut b = vec![0i8; n];
                        serial.gather_i8(sid, layer, kv, &mut a).unwrap();
                        par.gather_i8(pid, layer, kv, &mut b).unwrap();
                        assert_eq!(a, b, "i8 payload diverged at layer {layer} kv {kv}");
                    } else {
                        let mut a = vec![0f32; n];
                        let mut b = vec![0f32; n];
                        serial.gather_f32(sid, layer, kv, &mut a).unwrap();
                        par.gather_f32(pid, layer, kv, &mut b).unwrap();
                        let bits =
                            |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
                        assert_eq!(bits(&a), bits(&b), "f32 payload diverged");
                    }
                }
            }
        }
    }

    #[test]
    fn shared_blocks_reported_once_and_reclaim_is_refcount_aware() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let a = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 8, 21); // 2 blocks x 4 streams = 8
        m.set_prefill(a, &k, &v, 8).unwrap();
        let used = m.used_blocks();
        assert_eq!(used, 8);
        let b = m.fork(a).unwrap();
        // Physical occupancy unchanged; all 8 blocks now shared.
        assert_eq!(m.used_blocks(), used, "fork allocates nothing");
        assert_eq!(m.shared_blocks(), 8);
        assert_eq!(m.seq_blocks(b), 8, "logical footprint");
        assert_eq!(m.seq_reclaimable_blocks(b), 0, "all shared — freeing b reclaims none");
        m.assert_refcounts_consistent();
        m.free(b);
        assert_eq!(m.used_blocks(), used, "a still holds everything");
        assert_eq!(m.seq_reclaimable_blocks(a), 8);
        m.free(a);
        assert_eq!(m.free_blocks(), c.num_blocks);
        m.assert_refcounts_consistent(); // and again via Drop
    }

    #[test]
    fn wave_view_dedups_cow_shared_blocks() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let a = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 6, 31); // blocks: [4 rows, 2 rows] per stream
        m.set_prefill(a, &k, &v, 6).unwrap();
        let b = m.fork(a).unwrap();

        // Fully shared fork: every physical block serves both members
        // through a single group.
        let w = m.wave_view(&[a, b]).unwrap();
        assert_eq!(w.width(), 2);
        assert_eq!((w.len(0), w.len(1), w.max_len()), (6, 6, 6));
        let streams = 2 * c.layers;
        assert_eq!(w.blocks_deduped(), streams * 2, "2 shared blocks per stream");
        for layer in 0..c.layers {
            for kv in 0..2 {
                let gs = w.groups(layer, kv);
                assert_eq!(gs.len(), 2);
                assert_eq!((gs[0].bi, gs[0].rows), (0, 4));
                assert_eq!((gs[1].bi, gs[1].rows), (1, 2));
                for g in gs {
                    assert_eq!(g.members, vec![0, 1]);
                    assert_eq!(m.pool.refcount(g.block), 2, "shared block refcount");
                }
            }
        }
        // Group slabs and scales address exactly what the per-sequence
        // stream view reads.
        let sv = m.view(a).unwrap();
        let st = sv.stream(0, 0);
        for (gi, g) in w.groups(0, 0).iter().enumerate() {
            for h in 0..c.heads {
                assert_eq!(w.head_rows_raw(0, 0, g, h), st.head_rows_raw(gi, h));
                assert_eq!(w.head_scales(0, 0, 0, g.bi, h), st.head_scales(gi, h));
                assert_eq!(w.head_codec(0, 0, h).name(), st.head_codec(h).name());
            }
        }
        // Amortized traffic: the fully shared wave reads each block and
        // each distinct scales slice once — one sequence's worth.
        assert_eq!(w.attention_bytes(), sv.attention_bytes());
        drop(st);
        drop(sv);
        drop(w);
        m.free(a);
        m.free(b);
    }

    #[test]
    fn wave_view_tracks_cow_divergence_and_refcounts() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let a = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 6, 32);
        m.set_prefill(a, &k, &v, 6).unwrap();
        let b = m.fork(a).unwrap();
        let hd = c.layers * c.heads * c.head_dim;
        // Appending to the fork COWs its tail blocks: the prefix keeps
        // deduping, the diverged tails must not.
        m.append_row(b, &vec![0.3; hd], &vec![0.3; hd]).unwrap();

        let w = m.wave_view(&[a, b]).unwrap();
        assert_eq!((w.len(0), w.len(1)), (6, 7));
        let streams = 2 * c.layers;
        assert_eq!(w.blocks_deduped(), streams, "only the full prefix block dedups");
        for layer in 0..c.layers {
            for kv in 0..2 {
                let gs = w.groups(layer, kv);
                assert_eq!(gs.len(), 3, "shared prefix + two diverged tails");
                assert_eq!(gs[0].bi, 0);
                assert_eq!(gs[0].members, vec![0, 1]);
                assert_eq!(m.pool.refcount(gs[0].block), 2);
                // Ascending bi; diverged tails are singleton groups with
                // distinct physical blocks and member-specific rows.
                assert_eq!((gs[1].bi, gs[2].bi), (1, 1));
                assert_ne!(gs[1].block, gs[2].block);
                for g in &gs[1..] {
                    assert_eq!(g.members.len(), 1);
                    assert_eq!(m.pool.refcount(g.block), 1, "diverged tail is unique");
                    let expect_rows = if g.members[0] == 0 { 2 } else { 3 };
                    assert_eq!(g.rows, expect_rows);
                }
            }
        }
        drop(w);

        // Width-1 waves reduce to the per-sequence view byte-for-byte.
        let w1 = m.wave_view(&[a]).unwrap();
        assert_eq!(w1.blocks_deduped(), 0);
        assert_eq!(w1.attention_bytes(), m.view(a).unwrap().attention_bytes());
        drop(w1);

        assert!(m.wave_view(&[a, 999]).is_err(), "unknown member id");
        m.free(a);
        m.free(b);
    }

    #[test]
    fn append_need_accounts_boundaries_and_cow() {
        let c = cfg(); // layers=2, block_size=4
        let mut m = mgr(c, Precision::Int8);
        let a = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 22); // exactly one full block
        m.set_prefill(a, &k, &v, 4).unwrap();
        // len % block_size == 0: next append opens a block per stream.
        assert_eq!(m.append_need_blocks(a), 2 * c.layers);
        let hd = c.layers * c.heads * c.head_dim;
        m.append_row(a, &vec![0.1; hd], &vec![0.1; hd]).unwrap();
        // Mid-block, unshared: append allocates nothing.
        assert_eq!(m.append_need_blocks(a), 0);
        // Fork shares the (partial) tail block: COW needs one per stream.
        let b = m.fork(a).unwrap();
        assert_eq!(m.append_need_blocks(b), 2 * c.layers);
        m.free(a);
        m.free(b);
    }

    #[test]
    fn failed_append_leaves_sequence_untouched() {
        // Pool sized so the prefill fits but the block-boundary append
        // cannot: the append must fail atomically and stay retryable.
        let c = CacheConfig { num_blocks: 4, ..cfg() };
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 23); // 1 block x 4 streams = 4
        m.set_prefill(id, &k, &v, 4).unwrap();
        assert_eq!(m.free_blocks(), 0);
        let hd = c.layers * c.heads * c.head_dim;
        let before = m.seq_blocks(id);
        assert!(m.append_row(id, &vec![0.2; hd], &vec![0.2; hd]).is_err());
        assert_eq!(m.seq_blocks(id), before, "no partial allocation");
        assert_eq!(m.seq_len(id), Some(4));
        m.assert_refcounts_consistent();
        m.free(id);
        // Retry path: blocks are back, the same append now succeeds on a
        // fresh sequence.
        assert_eq!(m.free_blocks(), 4);
    }

    #[test]
    fn view_exposes_exact_pool_bytes() {
        // The zero-copy view must show byte-for-byte what gather copies.
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let len = 11; // partial tail block
        let (k, v) = prefill_tensors(&c, len, 31);
        m.set_prefill(id, &k, &v, len).unwrap();
        let hd = c.layers * c.heads * c.head_dim;
        let mut rng = Rng::new(32);
        let mut k_new = vec![0.0f32; hd];
        let mut v_new = vec![0.0f32; hd];
        rng.fill_uniform(&mut k_new, -0.5, 0.5);
        rng.fill_uniform(&mut v_new, -0.5, 0.5);
        m.append_row(id, &k_new, &v_new).unwrap();

        let mut staging = vec![0i8; c.heads * c.max_seq * c.head_dim];
        for layer in 0..c.layers {
            for kv in 0..2 {
                m.gather_i8(id, layer, kv, &mut staging).unwrap();
                let view = m.view(id).unwrap();
                assert_eq!(view.len(), len + 1);
                let stream = view.stream(layer, kv);
                assert_eq!(stream.len(), len + 1);
                assert_eq!(view.scales(layer, kv), m.scales(id, layer, kv).unwrap());
                let mut t0 = 0;
                for bi in 0..stream.num_blocks() {
                    let rows = stream.rows_in_block(bi);
                    for head in 0..c.heads {
                        assert_eq!(stream.head_codec(head).name(), "int8");
                        let slab = stream.head_rows_i8(bi, head);
                        assert_eq!(slab.len(), rows * c.head_dim);
                        for r in 0..rows {
                            let off = (head * c.max_seq + t0 + r) * c.head_dim;
                            let srow = &staging[off..off + c.head_dim];
                            assert_eq!(
                                &slab[r * c.head_dim..(r + 1) * c.head_dim],
                                srow,
                                "bytes diverged at block {bi} head {head} row {r}"
                            );
                        }
                    }
                    t0 += rows;
                }
                assert_eq!(t0, len + 1, "view covered all valid rows");
            }
        }
    }

    #[test]
    fn view_attention_bytes_scales_with_len_not_max_seq() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 33);
        m.set_prefill(id, &k, &v, 4).unwrap();
        let per_row = 2 * c.layers * c.heads * c.head_dim; // K+V payload/row (i8)
        // Per-block grids: one H·d f32 grid per touched block per stream.
        let per_block_scales = 2 * c.layers * c.heads * c.head_dim * 4;
        assert_eq!(m.view(id).unwrap().attention_bytes(), 4 * per_row + per_block_scales);
        let hd = c.layers * c.heads * c.head_dim;
        m.append_row(id, &vec![0.1; hd], &vec![0.1; hd]).unwrap();
        // The append opened block 1: scale traffic doubles with it.
        assert_eq!(
            m.view(id).unwrap().attention_bytes(),
            5 * per_row + 2 * per_block_scales
        );
    }

    #[test]
    fn int4_prefill_and_append_roundtrip_within_bound() {
        use crate::quant::int4::dequantize4_row_into;
        let c = cfg();
        let mut m = mgr(c, Precision::Int4);
        let id = m.new_sequence();
        let len = 6;
        let (k, v) = prefill_tensors(&c, len, 34);
        m.set_prefill(id, &k, &v, len).unwrap();
        // Append one row (exercises the nibble-packed writer mid-block).
        let hd = c.layers * c.heads * c.head_dim;
        // Zero rows quantize exactly on any grid, so the tight (un-clamped)
        // bound below applies even to the tail block's narrower frozen range.
        let k_new = vec![0.0f32; hd];
        let v_new = vec![0.0f32; hd];
        m.append_row(id, &k_new, &v_new).unwrap();

        let view = m.view(id).unwrap();
        let (layer, kv) = (1, 0);
        let stream = view.stream(layer, kv);
        let mut row = vec![0.0f32; c.head_dim];
        let mut t0 = 0;
        for bi in 0..stream.num_blocks() {
            let rows = stream.rows_in_block(bi);
            for head in 0..c.heads {
                let slab = stream.head_rows_i4(bi, head);
                let sc = stream.head_scales(bi, head);
                for r in 0..rows {
                    let t = t0 + r;
                    dequantize4_row_into(
                        &slab[r * c.head_dim / 2..(r + 1) * c.head_dim / 2],
                        sc,
                        &mut row,
                    );
                    for ch in 0..c.head_dim {
                        let want = if t < len {
                            k[((layer * c.heads + head) * c.max_seq + t) * c.head_dim + ch]
                        } else {
                            k_new[(layer * c.heads + head) * c.head_dim + ch]
                        };
                        // eq. (9) with the 4-bit grid: |x - x̂| <= s/2
                        // (appended rows clamp into frozen scales — the
                        // test row stays inside the prefill range).
                        assert!(
                            (row[ch] - want).abs() <= sc[ch] / 2.0 + 1e-6,
                            "t={t} ch={ch}: {} vs {want} (s={})",
                            row[ch],
                            sc[ch]
                        );
                    }
                }
            }
            t0 += rows;
        }
        assert_eq!(t0, len + 1);
    }

    #[test]
    fn int4_rejects_odd_head_dim() {
        let c = CacheConfig { head_dim: 7, ..cfg() };
        let mut m = mgr(c, Precision::Int4);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 36);
        let err = m.set_prefill(id, &k, &v, 4).unwrap_err();
        assert!(err.to_string().contains("even head_dim"), "{err}");
    }

    #[test]
    fn int4_scales_freeze_on_the_4bit_grid() {
        // Frozen INT4 scales divide by the codec's qmax (7, not 127): the
        // column abs-max must quantize to ±7 exactly.
        let c = cfg();
        let mut m = mgr(c, Precision::Int4);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 37);
        m.set_prefill(id, &k, &v, 4).unwrap();
        for (kv, data) in [&k, &v].into_iter().enumerate() {
            let sc = m.scales(id, 0, kv).unwrap();
            for head in 0..c.heads {
                for ch in 0..c.head_dim {
                    let mut mx = 0.0f32;
                    for t in 0..4 {
                        let i = ((head) * c.max_seq + t) * c.head_dim + ch; // layer 0
                        mx = mx.max(data[i].abs());
                    }
                    assert!(
                        (sc[head * c.head_dim + ch] * 7.0 - mx).abs() <= 1e-6,
                        "scale not on the 4-bit grid"
                    );
                }
            }
        }
    }

    #[test]
    fn k8v4_policy_splits_sides_in_one_cache() {
        // Keys INT8, values INT4 — both sides round-trip within their own
        // codec's bound, and the staged gather only exists for the K side.
        use crate::quant::int4::dequantize4_row_into;
        let c = cfg();
        let policy = PolicySpec::K8V4.resolve(c.layers, c.heads, c.head_dim).unwrap();
        let mut m = KvCacheManager::new(c, policy);
        let id = m.new_sequence();
        let len = 6;
        let (k, v) = prefill_tensors(&c, len, 51);
        m.set_prefill(id, &k, &v, len).unwrap();

        // K side: staged gather works (uniform int8 stream).
        let mut staging = vec![0i8; c.heads * c.max_seq * c.head_dim];
        m.gather_i8(id, 0, 0, &mut staging).unwrap();
        let ks = m.scales(id, 0, 0).unwrap().to_vec();
        let grid = c.heads * c.head_dim;
        for head in 0..c.heads {
            for t in 0..len {
                for ch in 0..c.head_dim {
                    let q = staging[(head * c.max_seq + t) * c.head_dim + ch];
                    let s = ks[(t / c.block_size) * grid + head * c.head_dim + ch];
                    let want = k[((head) * c.max_seq + t) * c.head_dim + ch]; // layer 0
                    assert!((q as f32 * s - want).abs() <= s / 2.0 + 1e-6);
                }
            }
        }
        // V side: no staged ABI — int8 gather must refuse.
        let err = m.gather_i8(id, 0, 1, &mut staging).unwrap_err();
        assert!(err.to_string().contains("uniform int8"), "{err}");
        // V side reads in place through the int4 codec.
        let view = m.view(id).unwrap();
        let stream = view.stream(0, 1);
        assert_eq!(stream.head_codec(0).name(), "int4");
        let mut row = vec![0.0f32; c.head_dim];
        let sc = stream.head_scales(0, 0);
        let slab = stream.head_rows_i4(0, 0);
        dequantize4_row_into(&slab[..c.head_dim / 2], sc, &mut row);
        for ch in 0..c.head_dim {
            let want = v[ch]; // layer 0, head 0, t 0
            assert!((row[ch] - want).abs() <= sc[ch] / 2.0 + 1e-6, "{} vs {want}", row[ch]);
        }
        // Byte accounting: K rows cost d bytes, V rows d/2, per head.
        let view = m.view(id).unwrap();
        let payload = 2 * c.heads * len * c.head_dim + 2 * c.heads * len * (c.head_dim / 2);
        // len 6 spans 2 blocks: one H·d grid per touched block per stream.
        let nblocks = len.div_ceil(c.block_size);
        let scale_bytes = 2 * c.layers * nblocks * c.heads * c.head_dim * 4;
        assert_eq!(view.attention_bytes(), payload + scale_bytes);
        let by = m.payload_bytes_by_precision();
        assert_eq!(by[Precision::Int8 as usize], (2 * c.heads * len * c.head_dim) as u64);
        assert_eq!(by[Precision::Int4 as usize], (c.heads * len * c.head_dim) as u64);
        assert_eq!(by[Precision::Fp32 as usize], 0);
    }

    #[test]
    fn mixed_policy_scale_grids_follow_each_side() {
        // k8v4: K scales freeze on /127, V scales on /7 — per stream, in
        // the same prefill pass.
        let c = cfg();
        let policy = PolicySpec::K8V4.resolve(c.layers, c.heads, c.head_dim).unwrap();
        let mut m = KvCacheManager::new(c, policy);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 52);
        m.set_prefill(id, &k, &v, 4).unwrap();
        let abs_max = |data: &[f32], head: usize, ch: usize| {
            (0..4)
                .map(|t| data[((head) * c.max_seq + t) * c.head_dim + ch].abs())
                .fold(0.0f32, f32::max)
        };
        let ks = m.scales(id, 0, 0).unwrap();
        let vs = m.scales(id, 0, 1).unwrap();
        for head in 0..c.heads {
            for ch in 0..c.head_dim {
                let i = head * c.head_dim + ch;
                assert!((ks[i] * 127.0 - abs_max(&k, head, ch)).abs() <= 1e-5, "K on /127");
                assert!((vs[i] * 7.0 - abs_max(&v, head, ch)).abs() <= 1e-6, "V on /7");
            }
        }
    }

    #[test]
    fn gather_rejects_bad_staging() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let mut tiny = vec![0i8; 3];
        assert!(m.gather_i8(id, 0, 0, &mut tiny).is_err());
    }

    #[test]
    fn sequence_at_capacity_errors() {
        let c = CacheConfig { max_seq: 4, ..cfg() };
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 9);
        m.set_prefill(id, &k, &v, 4).unwrap();
        let hd = c.layers * c.heads * c.head_dim;
        assert!(m.append_row(id, &vec![0.0; hd], &vec![0.0; hd]).is_err());
    }

    #[test]
    fn per_block_scales_freeze_on_each_blocks_rows() {
        // Two full blocks: each block's grid is the abs-max of its *own*
        // rows over the /127 grid, not one prompt-wide freeze.
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let len = 8;
        let (k, v) = prefill_tensors(&c, len, 61);
        m.set_prefill(id, &k, &v, len).unwrap();
        let sc = m.scales(id, 0, 0).unwrap();
        let grid = c.heads * c.head_dim;
        assert_eq!(sc.len(), 2 * grid);
        for head in 0..c.heads {
            for ch in 0..c.head_dim {
                for bi in 0..2 {
                    let mut mx = 0.0f32;
                    for t in bi * c.block_size..(bi + 1) * c.block_size {
                        // layer 0, K side
                        mx = mx.max(k[(head * c.max_seq + t) * c.head_dim + ch].abs());
                    }
                    let s = sc[bi * grid + head * c.head_dim + ch];
                    assert!(
                        (s * 127.0 - mx).abs() <= 1e-5,
                        "block {bi} grid must be its own rows' abs-max"
                    );
                }
                // Distinct random rows ⇒ distinct grids: the refactor must
                // not smear one prompt-wide scale across blocks.
                assert_ne!(sc[head * c.head_dim + ch], sc[grid + head * c.head_dim + ch]);
            }
        }
        let _ = v;
    }

    #[test]
    fn boundary_append_inherits_last_block_grid() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 8, 63); // two full blocks
        m.set_prefill(id, &k, &v, 8).unwrap();
        let grid = c.heads * c.head_dim;
        let before = m.scales(id, 1, 1).unwrap().to_vec();
        assert_eq!(before.len(), 2 * grid);
        let hd = c.layers * c.heads * c.head_dim;
        m.append_row(id, &vec![0.2; hd], &vec![0.2; hd]).unwrap();
        let after = m.scales(id, 1, 1).unwrap();
        // The boundary append opened block 2 with block 1's frozen grid.
        assert_eq!(after.len(), 3 * grid);
        assert_eq!(&after[..2 * grid], &before[..]);
        assert_eq!(&after[2 * grid..], &before[grid..]);
    }

    #[test]
    fn pin_adopt_sequence_shares_blocks_and_keeps_refcounts() {
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let a = m.new_sequence();
        let len = 6;
        let (k, v) = prefill_tensors(&c, len, 62);
        m.set_prefill(a, &k, &v, len).unwrap();

        // Externally pin every block of `a` (what the prefix trie does on
        // insert), snapshot tables + per-block scales, then free the
        // sequence: the pins keep the payload alive.
        let mut tables = Vec::new();
        let mut scales = Vec::new();
        for layer in 0..c.layers {
            let mut t2 = [Vec::new(), Vec::new()];
            let mut s2 = [Vec::new(), Vec::new()];
            for kv in 0..2 {
                let blocks = m.seq_stream_blocks(a, layer, kv).unwrap().to_vec();
                for &b in &blocks {
                    m.pin_block(b);
                }
                s2[kv] = m.scales(a, layer, kv).unwrap().to_vec();
                t2[kv] = blocks;
            }
            tables.push(t2);
            scales.push(s2);
        }
        m.assert_refcounts_consistent();
        let used = m.used_blocks();
        m.free(a);
        assert_eq!(m.used_blocks(), used, "pins keep blocks resident");
        m.assert_refcounts_consistent();

        // Adopt the pinned blocks as a new sequence (a partial-hit fork):
        // gathers must see the original bytes through block 0's grid.
        let b = m.adopt_sequence(tables.clone(), scales.clone(), len).unwrap();
        assert_eq!(m.seq_len(b), Some(len));
        let mut staging = vec![0i8; c.heads * c.max_seq * c.head_dim];
        m.gather_i8(b, 0, 0, &mut staging).unwrap();
        let sc = m.scales(b, 0, 0).unwrap();
        for ch in 0..c.head_dim {
            let q = staging[ch];
            let s = sc[ch];
            let want = k[ch]; // layer 0, head 0, t 0
            assert!((q as f32 * s - want).abs() <= s / 2.0 + 1e-6);
        }
        m.assert_refcounts_consistent();
        m.free(b);
        // Unpin everything: the pool drains back to empty.
        for t2 in &tables {
            for kvb in t2 {
                for &blk in kvb {
                    m.unpin_block(blk);
                }
            }
        }
        assert_eq!(m.free_blocks(), c.num_blocks);
        m.assert_refcounts_consistent();
        let _ = v;
    }

    #[test]
    fn append_prefill_chunk_matches_whole_prompt_prefill() {
        // Chunked prefill (the suffix-prefill write path) must produce the
        // same payload bytes and the same per-block grids as one-shot
        // set_prefill of the full prompt.
        let c = cfg();
        let len = 8; // two full blocks
        let (k, v) = prefill_tensors(&c, len, 64);

        let mut whole = mgr(c, Precision::Int8);
        let wid = whole.new_sequence();
        whole.set_prefill(wid, &k, &v, len).unwrap();

        let mut chunked = mgr(c, Precision::Int8);
        let cid = chunked.new_sequence();
        // Feed block-sized (L, H, C, d) chunks sliced from the same tensors.
        let bs = c.block_size;
        for start in (0..len).step_by(bs) {
            let rows = bs.min(len - start);
            let n = c.layers * c.heads * rows * c.head_dim;
            let mut kc = vec![0.0f32; n];
            let mut vc = vec![0.0f32; n];
            for layer in 0..c.layers {
                for head in 0..c.heads {
                    for r in 0..rows {
                        for ch in 0..c.head_dim {
                            let src =
                                ((layer * c.heads + head) * c.max_seq + start + r) * c.head_dim + ch;
                            let dst = ((layer * c.heads + head) * rows + r) * c.head_dim + ch;
                            kc[dst] = k[src];
                            vc[dst] = v[src];
                        }
                    }
                }
            }
            chunked.append_prefill_chunk(cid, &kc, &vc, rows).unwrap();
        }
        assert_eq!(chunked.seq_len(cid), Some(len));

        let n = c.heads * c.max_seq * c.head_dim;
        for layer in 0..c.layers {
            for kv in 0..2 {
                assert_eq!(
                    whole.scales(wid, layer, kv).unwrap(),
                    chunked.scales(cid, layer, kv).unwrap(),
                    "per-block grids diverged at layer {layer} kv {kv}"
                );
                let mut a = vec![0i8; n];
                let mut b = vec![0i8; n];
                whole.gather_i8(wid, layer, kv, &mut a).unwrap();
                chunked.gather_i8(cid, layer, kv, &mut b).unwrap();
                assert_eq!(a, b, "payload diverged at layer {layer} kv {kv}");
            }
        }
    }

    #[test]
    fn uniform_policy_collapses_to_single_class() {
        let c = cfg();
        let m = mgr(c, Precision::Int8);
        assert_eq!(m.num_width_classes(), 1);
        // int8 at this geometry: 4·2·8 = 64 B per block.
        assert_eq!(m.pool_physical_bytes(), (128 * 64) as u64);
        assert_eq!(m.pool_physical_bytes(), m.padded_pool_bytes(), "no padding to reclaim");
        // One span = one block in each of the 2L·2 = 4 streams.
        assert_eq!(m.span_bytes(), 4 * 64);
        assert_eq!(m.spans_free(), 128 / 4);
        assert_eq!(m.free_bytes(), m.raw_free_bytes());
        assert_eq!(m.fragmentation_bytes(), 0);
        assert_eq!(m.bytes_for_tokens(4), 4 * 64);
        assert_eq!(m.bytes_for_tokens(5), 2 * 4 * 64);
    }

    #[test]
    fn mixed_policy_sub_pools_shrink_physical_footprint() {
        let c = cfg();
        let policy = PolicySpec::K8V4.resolve(c.layers, c.heads, c.head_dim).unwrap();
        let mut m = KvCacheManager::new(c, policy);
        // K streams: int8, 64 B blocks; V streams: int4, 32 B. Two
        // classes, 2 streams each → 64 blocks per class.
        assert_eq!(m.num_width_classes(), 2);
        assert_eq!(m.pool_physical_bytes(), (64 * 64 + 64 * 32) as u64);
        assert_eq!(m.padded_pool_bytes(), (128 * 64) as u64);
        assert!(m.pool_physical_bytes() < m.padded_pool_bytes(), "padding reclaimed");
        assert_eq!(m.span_bytes(), 2 * 64 + 2 * 32);
        assert_eq!(m.spans_free(), 32);
        // Admission converts spans to tokens: 32 spans × 4 tokens.
        assert!(m.can_admit(128));
        assert!(!m.can_admit(129));
        assert_eq!(m.fragmentation_bytes(), 0);

        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 4, 71);
        m.set_prefill(id, &k, &v, 4).unwrap();
        // One block per stream: 2 K blocks (64 B int8) + 2 V (32 B int4).
        let phys = m.physical_bytes_by_precision();
        assert_eq!(phys[Precision::Int8 as usize], 2 * 64);
        assert_eq!(phys[Precision::Int4 as usize], 2 * 32);
        assert_eq!(phys[Precision::Fp32 as usize], 0);
        // The logical (row-granular, per-holder) gauge is pinned: 4 rows
        // of 2 heads × 8 ch per stream, int8 1 B/elem, int4 ½ B/elem.
        let by = m.payload_bytes_by_precision();
        assert_eq!(by[Precision::Int8 as usize], (2 * c.heads * 4 * c.head_dim) as u64);
        assert_eq!(by[Precision::Int4 as usize], (c.heads * 4 * c.head_dim) as u64);
        // Boundary append costs one full span; all blocks are unshared so
        // freeing reclaims exactly one span.
        assert_eq!(m.append_need_bytes(id), m.span_bytes() as u64);
        assert_eq!(m.seq_reclaimable_bytes(id), m.span_bytes() as u64);
        m.free(id);
    }

    #[test]
    fn class_exhaustion_binds_admission() {
        // 8 blocks over k8v4 → 4 wide + 4 narrow; two spans' worth.
        let c = CacheConfig { num_blocks: 8, ..cfg() };
        let policy = PolicySpec::K8V4.resolve(c.layers, c.heads, c.head_dim).unwrap();
        let mut m = KvCacheManager::new(c, policy);
        assert_eq!(m.spans_free(), 2);
        assert!(m.can_admit(8));
        assert!(!m.can_admit(9));
        let id = m.new_sequence();
        let (k, v) = prefill_tensors(&c, 8, 72);
        m.set_prefill(id, &k, &v, 8).unwrap();
        assert_eq!(m.spans_free(), 0);
        // Both classes drained evenly — nothing stranded.
        assert_eq!(m.fragmentation_bytes(), 0);
        let hd = c.layers * c.heads * c.head_dim;
        assert!(m.append_row(id, &vec![0.1; hd], &vec![0.1; hd]).is_err());
        m.free(id);
        assert_eq!(m.spans_free(), 2);
    }

    #[test]
    fn restore_block_and_adopt_owned_roundtrip() {
        // The cold-tier promote primitive: captured payload + scales come
        // back byte-identical through restore_block + adopt_owned_sequence.
        let c = cfg();
        let mut m = mgr(c, Precision::Int8);
        let a = m.new_sequence();
        let len = 6;
        let (k, v) = prefill_tensors(&c, len, 73);
        m.set_prefill(a, &k, &v, len).unwrap();
        let n = c.heads * c.max_seq * c.head_dim;
        let mut want = vec![0i8; n];
        m.gather_i8(a, 0, 0, &mut want).unwrap();
        // Capture raw block payloads + scales, then free the original.
        let mut payloads: Vec<[Vec<Vec<u8>>; 2]> = Vec::new();
        let mut scales: Vec<[Vec<f32>; 2]> = Vec::new();
        for layer in 0..c.layers {
            let mut p2: [Vec<Vec<u8>>; 2] = [Vec::new(), Vec::new()];
            let mut s2: [Vec<f32>; 2] = [Vec::new(), Vec::new()];
            for kv in 0..2 {
                for &b in m.seq_stream_blocks(a, layer, kv).unwrap() {
                    p2[kv].push(m.block_payload(b).to_vec());
                }
                s2[kv] = m.scales(a, layer, kv).unwrap().to_vec();
            }
            payloads.push(p2);
            scales.push(s2);
        }
        m.free(a);
        assert_eq!(m.free_blocks(), c.num_blocks);
        // Restore into fresh blocks and adopt without extra retains.
        let mut tables: Vec<[Vec<BlockId>; 2]> = Vec::new();
        for (layer, p2) in payloads.iter().enumerate() {
            let mut t2: [Vec<BlockId>; 2] = [Vec::new(), Vec::new()];
            for kv in 0..2 {
                for bytes in &p2[kv] {
                    t2[kv].push(m.restore_block(layer, kv, bytes).unwrap());
                }
            }
            tables.push(t2);
        }
        let b = m.adopt_owned_sequence(tables, scales, len).unwrap();
        m.assert_refcounts_consistent();
        let mut got = vec![0i8; n];
        m.gather_i8(b, 0, 0, &mut got).unwrap();
        assert_eq!(got, want, "restored payload diverged");
        m.free(b);
        assert_eq!(m.free_blocks(), c.num_blocks, "owned adoption holds exactly once");
        // Width mismatch is rejected without leaking.
        assert!(m.restore_block(0, 0, &[0u8; 3]).is_err());
        m.assert_refcounts_consistent();
        let _ = v;
    }
}
