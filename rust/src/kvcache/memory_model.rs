//! Closed-form KV-cache memory model — Table 1 of the paper.
//!
//! `size = 2 × L × H × T × bytes_per_row(d)` (eq. 2, accounted per-row so
//! INT4's padding nibble at odd `d` is not undercounted), plus — for
//! quantized caches — the per-channel scale overhead the paper calls
//! "negligible" (and this model makes precise: 2·L·H·d f32 per sequence).
//!
//! [`PolicyMemory`] is the mixed-precision generalization: the same
//! closed form evaluated under a [`QuantPolicy`], so `k8v4`/`sink8`/table
//! policies get honest per-stream byte accounting and a compression
//! ratio vs the FP32 baseline (`table1_memory` sweeps these).

use super::policy::QuantPolicy;
use super::Precision;
use crate::util::stats::fmt_bytes;

/// Model/cache dimensions for the memory calculation.
#[derive(Debug, Clone, Copy, PartialEq)]
pub struct MemoryModel {
    pub layers: usize,
    pub heads: usize,
    pub head_dim: usize,
    pub seq_len: usize,
    pub precision: Precision,
}

impl MemoryModel {
    /// The paper's Table-1 example: L=32, H=32, d=128, T=131072, FP32.
    pub fn table1_example() -> MemoryModel {
        MemoryModel {
            layers: 32,
            heads: 32,
            head_dim: 128,
            seq_len: 131_072,
            precision: Precision::Fp32,
        }
    }

    /// Total cached elements: 2 (K and V) × L × H × d × T.
    pub fn elements(&self) -> u64 {
        2 * self.layers as u64
            * self.heads as u64
            * self.head_dim as u64
            * self.seq_len as u64
    }

    /// Payload bytes (eq. 2), accounted per `(head, token)` row so INT4
    /// packing pads each row independently (`bytes_for_rows`).
    pub fn payload_bytes(&self) -> u64 {
        let rows = 2 * self.layers * self.heads * self.seq_len;
        self.precision.bytes_for_rows(rows, self.head_dim) as u64
    }

    /// Per-channel scale overhead for quantized caches: one f32 per
    /// (K|V, layer, head, channel) — independent of T.
    pub fn scale_overhead_bytes(&self) -> u64 {
        match self.precision {
            Precision::Fp32 => 0,
            _ => (2 * self.layers * self.heads * self.head_dim * 4) as u64,
        }
    }

    pub fn total_bytes(&self) -> u64 {
        self.payload_bytes() + self.scale_overhead_bytes()
    }

    /// Memory ratio vs an FP32 cache of the same dimensions.
    pub fn compression_vs_fp32(&self) -> f64 {
        let fp32 = MemoryModel { precision: Precision::Fp32, ..*self };
        fp32.total_bytes() as f64 / self.total_bytes() as f64
    }

    /// With a fixed memory budget, the max sequence length this cache
    /// supports (the "longer context windows" claim, §8 Conclusion).
    pub fn max_seq_for_budget(&self, budget_bytes: u64) -> usize {
        let per_token =
            self.precision.bytes_for_rows(2 * self.layers * self.heads, self.head_dim) as u64;
        ((budget_bytes.saturating_sub(self.scale_overhead_bytes())) / per_token) as usize
    }

    /// With a fixed memory budget and this sequence length, how many
    /// concurrent sequences fit (the "larger batch sizes" claim).
    pub fn max_batch_for_budget(&self, budget_bytes: u64) -> usize {
        let per_seq = self.total_bytes();
        if per_seq == 0 {
            return 0;
        }
        (budget_bytes / per_seq) as usize
    }

    pub fn describe(&self) -> String {
        format!(
            "L={} H={} d={} T={} {} -> {}",
            self.layers,
            self.heads,
            self.head_dim,
            self.seq_len,
            self.precision.name(),
            fmt_bytes(self.total_bytes() as f64)
        )
    }
}

/// The closed-form model evaluated under a (possibly mixed-precision)
/// [`QuantPolicy`]: per-stream per-row byte accounting across all
/// `(layer, K|V, head)` streams.
pub struct PolicyMemory<'a> {
    pub policy: &'a QuantPolicy,
    pub head_dim: usize,
    pub seq_len: usize,
}

impl<'a> PolicyMemory<'a> {
    pub fn new(policy: &'a QuantPolicy, head_dim: usize, seq_len: usize) -> PolicyMemory<'a> {
        PolicyMemory { policy, head_dim, seq_len }
    }

    pub fn payload_bytes(&self) -> u64 {
        self.policy.payload_bytes(self.head_dim, self.seq_len)
    }

    /// One f32 per quantized (layer, K|V, head, channel); FP32 streams
    /// carry none.
    pub fn scale_overhead_bytes(&self) -> u64 {
        self.policy.scale_overhead_bytes(self.head_dim)
    }

    pub fn total_bytes(&self) -> u64 {
        self.payload_bytes() + self.scale_overhead_bytes()
    }

    /// Payload bytes broken down by precision (`[fp32, int8, int4]`).
    pub fn payload_by_precision(&self) -> [u64; 3] {
        self.policy.payload_bytes_by_precision(self.head_dim, self.seq_len)
    }

    /// Compression vs a uniform-FP32 cache of the same geometry.
    pub fn compression_vs_fp32(&self) -> f64 {
        let fp32 = MemoryModel {
            layers: self.policy.layers(),
            heads: self.policy.heads(),
            head_dim: self.head_dim,
            seq_len: self.seq_len,
            precision: Precision::Fp32,
        };
        fp32.total_bytes() as f64 / self.total_bytes() as f64
    }

    /// Physical bytes of one *span* — one block in every `(layer, K|V)`
    /// stream — under per-precision sub-pools, where each stream's block
    /// is padded only to its own codec alignment.
    pub fn subpool_span_bytes(&self, block_size: usize) -> u64 {
        (0..self.policy.layers())
            .flat_map(|l| (0..2).map(move |kv| (l, kv)))
            .map(|(l, kv)| {
                self.policy
                    .stream_layout(l, kv, block_size, self.head_dim)
                    .padded_block_bytes() as u64
            })
            .sum()
    }

    /// The same span under a legacy single-width pool: every block padded
    /// to the widest stream's block bytes.
    pub fn padded_span_bytes(&self, block_size: usize) -> u64 {
        2 * self.policy.layers() as u64
            * self.policy.max_block_bytes(block_size, self.head_dim) as u64
    }

    /// Physical bytes reclaimed per span by width-aware sub-pools. Zero
    /// for uniform policies (no stream narrower than the widest); strictly
    /// positive for mixed policies such as `k8v4`.
    pub fn reclaimed_span_bytes(&self, block_size: usize) -> u64 {
        self.padded_span_bytes(block_size) - self.subpool_span_bytes(block_size)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::policy::PolicySpec;

    #[test]
    fn table1_reproduces_137gb() {
        // Paper Table 1: ≈137 GB for the FP32 example.
        let m = MemoryModel::table1_example();
        let gb = m.total_bytes() as f64 / (1024.0 * 1024.0 * 1024.0);
        assert!((gb - 128.0).abs() < 1.0 || (gb - 137.4).abs() < 1.0,
            "paper says ≈137 GB (decimal GB) = 128 GiB; got {gb} GiB");
        // In decimal gigabytes (the paper's unit):
        let gb_dec = m.total_bytes() as f64 / 1e9;
        assert!((gb_dec - 137.4).abs() < 0.1, "decimal GB {gb_dec}");
    }

    #[test]
    fn fp16_is_half() {
        // Paper: "Even with FP16, this is nearly 70 GB."
        let m = MemoryModel::table1_example();
        let fp16_bytes = m.elements() * 2;
        assert!((fp16_bytes as f64 / 1e9 - 68.7).abs() < 0.1);
    }

    #[test]
    fn int8_is_quarter_plus_scales() {
        let m = MemoryModel { precision: Precision::Int8, ..MemoryModel::table1_example() };
        let r = m.compression_vs_fp32();
        assert!(r > 3.999 && r <= 4.0, "compression {r}");
        // Scale overhead truly negligible at this scale: < 0.01%.
        assert!((m.scale_overhead_bytes() as f64) < m.payload_bytes() as f64 * 1e-4);
    }

    #[test]
    fn int4_is_eighth() {
        let m = MemoryModel { precision: Precision::Int4, ..MemoryModel::table1_example() };
        assert!(m.compression_vs_fp32() > 7.99);
    }

    #[test]
    fn int4_odd_head_dim_accounts_per_row() {
        // Regression (per-row packing): d=7 INT4 rows occupy 4 bytes each,
        // never the flattened ceil(rows*7/2). 2·L·H·T rows of 4 bytes.
        let m = MemoryModel {
            layers: 2,
            heads: 3,
            head_dim: 7,
            seq_len: 5,
            precision: Precision::Int4,
        };
        let rows = 2 * 2 * 3 * 5;
        assert_eq!(m.payload_bytes(), (rows * 4) as u64);
        let flattened = Precision::Int4.bytes_for(rows * 7) as u64;
        assert!(m.payload_bytes() > flattened, "per-row padding must be counted");
        // Budget inversion uses the same per-row cost.
        let per_token = (2 * 2 * 3 * 4) as u64;
        assert_eq!(m.max_seq_for_budget(per_token * 10 + m.scale_overhead_bytes()), 10);
    }

    #[test]
    fn budget_inversions() {
        let m = MemoryModel { precision: Precision::Int8, ..MemoryModel::table1_example() };
        let budget = 16u64 * 1024 * 1024 * 1024; // a T4's 16 GB
        let t_int8 = m.max_seq_for_budget(budget);
        let t_fp32 = MemoryModel::table1_example().max_seq_for_budget(budget);
        // ~4x longer context at int8; the per-channel scale overhead costs
        // a handful of tokens off the exact 4x.
        assert!(t_int8 <= t_fp32 * 4 && t_int8 >= t_fp32 * 4 - 16, "{t_int8} vs {}", t_fp32 * 4);
        assert!(m.max_batch_for_budget(budget) < t_int8); // sanity
    }

    #[test]
    fn batch_budget_scales_with_precision() {
        let fp32 = MemoryModel { seq_len: 4096, ..MemoryModel::table1_example() };
        let int8 = MemoryModel { precision: Precision::Int8, ..fp32 };
        let budget = 64u64 << 30;
        let b_fp32 = fp32.max_batch_for_budget(budget);
        let b_int8 = int8.max_batch_for_budget(budget);
        assert!(b_int8 >= b_fp32 * 3, "{b_int8} vs {b_fp32}"); // ≈4x
    }

    #[test]
    fn k8v4_lands_between_uniform_int8_and_int4() {
        // The acceptance bar for the mixed preset: memory footprint
        // strictly between the two uniform quantized caches, compression
        // between 4x and 8x (≈5.3x: K at 1 byte + V at half a byte per
        // element vs 8 bytes fp32 per K+V element pair).
        let base = MemoryModel::table1_example();
        let (l, h, d, t) = (base.layers, base.heads, base.head_dim, base.seq_len);
        let k8v4 = PolicySpec::K8V4.resolve(l, h, d).unwrap();
        let pm = PolicyMemory::new(&k8v4, d, t);
        let int8 = MemoryModel { precision: Precision::Int8, ..base };
        let int4 = MemoryModel { precision: Precision::Int4, ..base };
        assert!(pm.total_bytes() < int8.total_bytes());
        assert!(pm.total_bytes() > int4.total_bytes());
        let c = pm.compression_vs_fp32();
        assert!(c > 4.0 && c < 8.0, "k8v4 compression {c}");
        assert!((c - 16.0 / 3.0).abs() < 0.01, "≈5.33x expected, got {c}");
        let by = pm.payload_by_precision();
        assert_eq!(by[Precision::Int8 as usize], 2 * by[Precision::Int4 as usize]);
    }

    #[test]
    fn subpool_spans_reclaim_mixed_policy_padding() {
        // Width-aware sub-pools: k8v4's V blocks take half the bytes of
        // its K blocks, so the physical span footprint sits strictly
        // below the padded widest-stream baseline. Uniform policies have
        // nothing to reclaim.
        let (l, h, d, bs) = (2usize, 2usize, 8usize, 4usize);
        let k8v4 = PolicySpec::K8V4.resolve(l, h, d).unwrap();
        let pm = PolicyMemory::new(&k8v4, d, 0);
        // K stream block: 2 heads × 4 tokens × 8 ch × 1 B = 64 B;
        // V stream block: same rows at half a byte per channel = 32 B.
        assert_eq!(pm.subpool_span_bytes(bs), (l * (64 + 32)) as u64);
        assert_eq!(pm.padded_span_bytes(bs), (2 * l * 64) as u64);
        assert_eq!(pm.reclaimed_span_bytes(bs), (l * 32) as u64);
        assert!(pm.subpool_span_bytes(bs) < pm.padded_span_bytes(bs));

        let int8 = PolicySpec::Uniform(Precision::Int8).resolve(l, h, d).unwrap();
        let pm8 = PolicyMemory::new(&int8, d, 0);
        assert_eq!(pm8.subpool_span_bytes(bs), pm8.padded_span_bytes(bs));
        assert_eq!(pm8.reclaimed_span_bytes(bs), 0);
    }

    #[test]
    fn sink8_costs_slightly_more_than_uniform_int8() {
        let base = MemoryModel::table1_example();
        let (l, h, d, t) = (base.layers, base.heads, base.head_dim, base.seq_len);
        let sink = PolicySpec::Sink8 { sink_layers: 1 }.resolve(l, h, d).unwrap();
        let pm = PolicyMemory::new(&sink, d, t);
        let int8 = MemoryModel { precision: Precision::Int8, ..base };
        assert!(pm.total_bytes() > int8.total_bytes(), "one fp32 layer costs extra");
        assert!(pm.total_bytes() < base.total_bytes(), "still far below fp32");
        let c = pm.compression_vs_fp32();
        assert!(c > 3.0 && c < 4.0, "sink8 compression {c}");
    }

    #[test]
    fn uniform_policy_memory_matches_the_scalar_model() {
        let base = MemoryModel { precision: Precision::Int8, ..MemoryModel::table1_example() };
        let p = PolicySpec::Uniform(Precision::Int8)
            .resolve(base.layers, base.heads, base.head_dim)
            .unwrap();
        let pm = PolicyMemory::new(&p, base.head_dim, base.seq_len);
        assert_eq!(pm.payload_bytes(), base.payload_bytes());
        assert_eq!(pm.scale_overhead_bytes(), base.scale_overhead_bytes());
        assert_eq!(pm.total_bytes(), base.total_bytes());
        assert!((pm.compression_vs_fp32() - base.compression_vs_fp32()).abs() < 1e-12);
    }

    #[test]
    fn describe_is_humane() {
        let d = MemoryModel::table1_example().describe();
        assert!(d.contains("T=131072") && d.contains("GiB"), "{d}");
    }
}
