//! Quantization policies: `(layer, head, K|V side) → Precision`.
//!
//! The paper quantizes the whole cache uniformly, but the accuracy/memory
//! frontier is non-uniform: keys are markedly more quantization-sensitive
//! than values (KVQuant, arXiv:2401.18079), and early/"sink" layers repay
//! higher precision while the rest tolerate aggressive bits (Cache Me If
//! You Must, arXiv:2501.19392). This module makes that a configuration
//! table instead of a refactor:
//!
//! * [`PolicySpec`] — the geometry-independent config surface
//!   (`--quant-policy`, `"quant_policy"` JSON key): named presets
//!   (`uniform:{fp32,int8,int4}`, `k8v4`, `sink8[:N]`) or a JSON
//!   per-layer table loaded from `configs/` (see [`PolicyTable`]).
//! * [`QuantPolicy`] — the spec resolved against a concrete model
//!   (layers × heads × head_dim), validated (bounds, unknown precisions,
//!   the even-`head_dim` guard for any INT4 side), mapping every
//!   `(layer, kv, head)` to a [`Codec`].
//! * [`StreamLayout`] — the byte layout one `(layer, K|V)` stream's
//!   blocks take under the policy: per-head codecs, per-head slab byte
//!   offsets (heads may differ in width), and the block payload size.
//! * [`StagedKind`] — which dense staging ABI (if any) the policy is
//!   compatible with. Only `uniform:int8` and `uniform:fp32` have a
//!   dense `(L, H, S, d)` artifact layout; **every other policy requires
//!   a paged-decode-capable backend** — the generalization of the old
//!   INT4-only fail-fast.
//!
//! The uniform presets are bit-identical to the legacy `--precision`
//! paths (same codecs, same grids, same layouts) — that equivalence is
//! the refactor's safety net, asserted by `tests/parallel_consistency.rs`.

use super::Precision;
use crate::quant::codec::{self, Codec};
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};

/// Dense staging ABIs a policy can be compatible with (the staged decode
/// path and the PJRT artifacts consume these).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum StagedKind {
    /// `(L, H, S, d)` i8 payloads + `(L, H, B, d)` f32 per-block scales,
    /// `B = ceil(max_seq / block_size)` (row `t` decodes through block
    /// `t / block_size`'s grid — the same grids the paged layout froze).
    I8,
    /// `(L, H, S, d)` f32 payloads.
    F32,
}

/// The canonical codec for a storage precision.
pub fn codec_for(p: Precision) -> &'static dyn Codec {
    match p {
        Precision::Fp32 => &codec::FP32,
        Precision::Int8 => &codec::INT8,
        Precision::Int4 => &codec::INT4,
    }
}

// ---------------------------------------------------------------------------
// PolicySpec — the config surface.
// ---------------------------------------------------------------------------

/// Geometry-independent policy description. Resolved against a model's
/// (layers, heads, head_dim) at engine/cache construction via
/// [`PolicySpec::resolve`].
#[derive(Debug, Clone, PartialEq)]
pub enum PolicySpec {
    /// One precision everywhere — the legacy `--precision` behavior.
    Uniform(Precision),
    /// Keys INT8, values INT4 on every layer (keys are the
    /// quantization-sensitive side).
    K8V4,
    /// First `sink_layers` layers FP32 (attention-sink protection), the
    /// rest INT8.
    Sink8 { sink_layers: usize },
    /// Explicit per-layer table (JSON under `configs/`).
    Table(PolicyTable),
}

impl PolicySpec {
    pub fn uniform(p: Precision) -> PolicySpec {
        PolicySpec::Uniform(p)
    }

    /// Parse a `--quant-policy` value: a preset name, a bare precision
    /// (legacy spelling), or a path to a policy JSON (`*.json`).
    pub fn parse(s: &str) -> Result<PolicySpec> {
        if let Some(rest) = s.strip_prefix("uniform:") {
            let p = Precision::parse(rest)
                .ok_or_else(|| anyhow!("unknown precision {rest:?} in policy {s:?}"))?;
            return Ok(PolicySpec::Uniform(p));
        }
        if let Some(p) = Precision::parse(s) {
            return Ok(PolicySpec::Uniform(p));
        }
        if s == "k8v4" {
            return Ok(PolicySpec::K8V4);
        }
        if s == "sink8" {
            return Ok(PolicySpec::Sink8 { sink_layers: 1 });
        }
        if let Some(n) = s.strip_prefix("sink8:") {
            let sink_layers: usize =
                n.parse().map_err(|_| anyhow!("bad sink layer count in {s:?}"))?;
            return Ok(PolicySpec::Sink8 { sink_layers });
        }
        if s.ends_with(".json") {
            return Ok(PolicySpec::Table(PolicyTable::load(s)?));
        }
        bail!(
            "unknown quant policy {s:?} (expected uniform:fp32|int8|int4, k8v4, \
             sink8[:N], or a policy .json path)"
        )
    }

    /// Canonical display name (`uniform:int8`, `k8v4`, `sink8:1`, or the
    /// table's declared name).
    pub fn name(&self) -> String {
        match self {
            PolicySpec::Uniform(p) => format!("uniform:{}", p.name()),
            PolicySpec::K8V4 => "k8v4".into(),
            PolicySpec::Sink8 { sink_layers } => format!("sink8:{sink_layers}"),
            PolicySpec::Table(t) => t.name.clone(),
        }
    }

    /// Router/engine label: uniform policies keep the legacy precision
    /// name (`int8`), everything else uses the policy name.
    pub fn engine_label(&self) -> String {
        match self {
            PolicySpec::Uniform(p) => p.name().to_string(),
            other => other.name(),
        }
    }

    /// Resolve against a concrete model geometry, validating bounds and
    /// the even-`head_dim` requirement for any INT4 side.
    pub fn resolve(&self, layers: usize, heads: usize, head_dim: usize) -> Result<QuantPolicy> {
        let mut map: Vec<[Vec<Precision>; 2]> = match self {
            PolicySpec::Uniform(p) => {
                (0..layers).map(|_| [vec![*p; heads], vec![*p; heads]]).collect()
            }
            PolicySpec::K8V4 => (0..layers)
                .map(|_| [vec![Precision::Int8; heads], vec![Precision::Int4; heads]])
                .collect(),
            PolicySpec::Sink8 { sink_layers } => (0..layers)
                .map(|l| {
                    let p = if l < *sink_layers { Precision::Fp32 } else { Precision::Int8 };
                    [vec![p; heads], vec![p; heads]]
                })
                .collect(),
            PolicySpec::Table(t) => t.resolve_map(layers, heads)?,
        };
        if map.is_empty() || heads == 0 {
            bail!("policy resolved over zero layers/heads");
        }
        let has_int4 = map
            .iter()
            .flat_map(|pair| pair.iter().flatten())
            .any(|&p| p == Precision::Int4);
        if has_int4 && head_dim % 2 != 0 {
            bail!(
                "policy {:?} puts INT4 on a stream but head_dim {head_dim} is odd \
                 (int4 rows must be nibble-aligned: even head_dim required)",
                self.name()
            );
        }
        // Shrink-to-fit so equality between identically resolved policies
        // is structural.
        for pair in &mut map {
            pair[0].shrink_to_fit();
            pair[1].shrink_to_fit();
        }
        Ok(QuantPolicy { name: self.name(), map, heads })
    }
}

// ---------------------------------------------------------------------------
// PolicyTable — the JSON per-layer table.
// ---------------------------------------------------------------------------

/// A per-(head, side) override inside one layer's table row.
#[derive(Debug, Clone, PartialEq)]
pub struct HeadOverride {
    pub head: usize,
    /// 0 = K, 1 = V.
    pub kv: usize,
    pub precision: Precision,
}

/// One layer's row: optional per-side precisions plus head overrides.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyRule {
    pub layer: usize,
    pub k: Option<Precision>,
    pub v: Option<Precision>,
    pub heads: Vec<HeadOverride>,
}

/// Parsed JSON policy table. Schema (see `rust/README.md`):
///
/// ```json
/// {
///   "name": "sink-mixed",
///   "layers": 2, "heads": 2,
///   "default": {"k": "int8", "v": "int4"},
///   "table": [
///     {"layer": 0, "k": "fp32", "v": "fp32"},
///     {"layer": 1, "heads": [{"head": 1, "side": "v", "precision": "int8"}]}
///   ]
/// }
/// ```
///
/// `default` may also be a bare string applying to both sides. The
/// declared `layers`/`heads` geometry is mandatory for files shipped
/// under `configs/` (the validation test resolves each file against its
/// own declaration); at serve time the declared geometry must match the
/// model's.
#[derive(Debug, Clone, PartialEq)]
pub struct PolicyTable {
    pub name: String,
    /// Declared geometry (validated against the model at resolve time).
    pub layers: Option<usize>,
    pub heads: Option<usize>,
    /// Per-side default `[K, V]`.
    pub default: [Precision; 2],
    pub rules: Vec<PolicyRule>,
}

fn parse_precision(j: &Json, what: &str) -> Result<Precision> {
    let s = j.as_str().ok_or_else(|| anyhow!("{what}: expected a precision string"))?;
    Precision::parse(s).ok_or_else(|| anyhow!("{what}: unknown precision {s:?}"))
}

impl PolicyTable {
    pub fn load(path: &str) -> Result<PolicyTable> {
        let text = std::fs::read_to_string(path)
            .with_context(|| format!("reading policy table {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing policy table {path}"))?;
        Self::from_json(&j).with_context(|| format!("in policy table {path}"))
    }

    pub fn from_json(j: &Json) -> Result<PolicyTable> {
        let name = j
            .get("name")
            .as_str()
            .ok_or_else(|| anyhow!("policy table missing \"name\""))?
            .to_string();
        let default = match j.get("default") {
            Json::Null => [Precision::Int8; 2],
            d @ Json::Str(_) => [parse_precision(d, "default")?; 2],
            d => [
                parse_precision(d.get("k"), "default.k")?,
                parse_precision(d.get("v"), "default.v")?,
            ],
        };
        let mut rules = Vec::new();
        if let Some(arr) = j.get("table").as_arr() {
            for (i, row) in arr.iter().enumerate() {
                let layer = row
                    .get("layer")
                    .as_usize()
                    .ok_or_else(|| anyhow!("table[{i}] missing \"layer\""))?;
                let side = |key: &str| -> Result<Option<Precision>> {
                    match row.get(key) {
                        Json::Null => Ok(None),
                        p => Ok(Some(parse_precision(p, &format!("table[{i}].{key}"))?)),
                    }
                };
                let mut heads = Vec::new();
                if let Some(hs) = row.get("heads").as_arr() {
                    for (hi, h) in hs.iter().enumerate() {
                        let head = h
                            .get("head")
                            .as_usize()
                            .ok_or_else(|| anyhow!("table[{i}].heads[{hi}] missing \"head\""))?;
                        let kv = match h.get("side").as_str() {
                            Some("k") => 0,
                            Some("v") => 1,
                            other => bail!(
                                "table[{i}].heads[{hi}].side must be \"k\" or \"v\", got {other:?}"
                            ),
                        };
                        let precision = parse_precision(
                            h.get("precision"),
                            &format!("table[{i}].heads[{hi}].precision"),
                        )?;
                        heads.push(HeadOverride { head, kv, precision });
                    }
                }
                rules.push(PolicyRule { layer, k: side("k")?, v: side("v")?, heads });
            }
        }
        Ok(PolicyTable {
            name,
            layers: j.get("layers").as_usize(),
            heads: j.get("heads").as_usize(),
            default,
            rules,
        })
    }

    /// Expand into the per-(layer, kv, head) map, bounds-checking every
    /// rule against the target geometry.
    fn resolve_map(&self, layers: usize, heads: usize) -> Result<Vec<[Vec<Precision>; 2]>> {
        if let Some(dl) = self.layers {
            if dl != layers {
                bail!(
                    "policy {:?} declares {dl} layers but the model has {layers}",
                    self.name
                );
            }
        }
        if let Some(dh) = self.heads {
            if dh != heads {
                bail!("policy {:?} declares {dh} heads but the model has {heads}", self.name);
            }
        }
        let mut map: Vec<[Vec<Precision>; 2]> = (0..layers)
            .map(|_| [vec![self.default[0]; heads], vec![self.default[1]; heads]])
            .collect();
        for rule in &self.rules {
            if rule.layer >= layers {
                bail!(
                    "policy {:?}: rule layer {} out of bounds for {layers}-layer model",
                    self.name,
                    rule.layer
                );
            }
            if let Some(p) = rule.k {
                map[rule.layer][0] = vec![p; heads];
            }
            if let Some(p) = rule.v {
                map[rule.layer][1] = vec![p; heads];
            }
            for h in &rule.heads {
                if h.head >= heads {
                    bail!(
                        "policy {:?}: layer {} head override {} out of bounds for {heads} heads",
                        self.name,
                        rule.layer,
                        h.head
                    );
                }
                map[rule.layer][h.kv][h.head] = h.precision;
            }
        }
        Ok(map)
    }
}

// ---------------------------------------------------------------------------
// QuantPolicy — the resolved map.
// ---------------------------------------------------------------------------

/// A [`PolicySpec`] resolved against one model geometry: every
/// `(layer, kv, head)` has a precision, and derived views (codecs,
/// stream layouts, byte accounting) hang off it.
#[derive(Clone)]
pub struct QuantPolicy {
    name: String,
    /// `map[layer][kv][head]`.
    map: Vec<[Vec<Precision>; 2]>,
    heads: usize,
}

impl std::fmt::Debug for QuantPolicy {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        write!(f, "QuantPolicy({}, {}L x {}H)", self.name, self.map.len(), self.heads)
    }
}

impl QuantPolicy {
    /// Uniform policy without going through a spec — the test/bench
    /// shorthand equivalent of the legacy per-cache `precision` knob.
    pub fn uniform(p: Precision, layers: usize, heads: usize) -> QuantPolicy {
        PolicySpec::Uniform(p)
            .resolve(layers, heads, 2) // head_dim only gates int4 oddness
            .expect("uniform policies always resolve")
    }

    pub fn name(&self) -> &str {
        &self.name
    }

    pub fn layers(&self) -> usize {
        self.map.len()
    }

    pub fn heads(&self) -> usize {
        self.heads
    }

    pub fn precision(&self, layer: usize, kv: usize, head: usize) -> Precision {
        self.map[layer][kv][head]
    }

    pub fn codec(&self, layer: usize, kv: usize, head: usize) -> &'static dyn Codec {
        codec_for(self.precision(layer, kv, head))
    }

    /// The single precision used everywhere, if the policy is uniform.
    pub fn as_uniform(&self) -> Option<Precision> {
        let first = self.map[0][0][0];
        self.map
            .iter()
            .flat_map(|pair| pair.iter().flatten())
            .all(|&p| p == first)
            .then_some(first)
    }

    /// Does any stream use `p`?
    pub fn uses(&self, p: Precision) -> bool {
        self.map.iter().flat_map(|pair| pair.iter().flatten()).any(|&q| q == p)
    }

    /// The dense staging ABI this policy is compatible with, if any.
    /// Only uniform policies whose codec has a dense layout
    /// ([`Codec::supports_staged`] — int8/fp32 today) qualify; every
    /// other policy (mixed, or INT4 anywhere) must decode over the paged
    /// layout.
    pub fn staged(&self) -> Option<StagedKind> {
        let p = self.as_uniform()?;
        if !codec_for(p).supports_staged() {
            return None;
        }
        match p {
            Precision::Int8 => Some(StagedKind::I8),
            Precision::Fp32 => Some(StagedKind::F32),
            // supports_staged() is the codec's authority; a staging-
            // capable codec without an ABI mapping here is a bug.
            Precision::Int4 => unreachable!("int4 has no dense staging ABI"),
        }
    }

    /// Byte layout of one `(layer, kv)` stream's blocks.
    pub fn stream_layout(
        &self,
        layer: usize,
        kv: usize,
        block_size: usize,
        head_dim: usize,
    ) -> StreamLayout {
        StreamLayout::new(&self.map[layer][kv], block_size, head_dim)
    }

    /// Largest per-block payload across all streams — the pool's block
    /// size. Uniform policies get exactly the legacy per-precision block
    /// bytes; mixed policies pad narrower streams to the widest (the
    /// logical byte accounting below still reports true per-precision
    /// footprints). Rounded up to the strictest codec alignment in the
    /// policy so *every* block's base stays aligned for in-place fp32
    /// reads, not just block 0 (uniform int8/int4 policies have align 1
    /// and uniform fp32 is naturally 4-aligned — no padding, so the
    /// legacy widths are preserved bit-for-bit).
    pub fn max_block_bytes(&self, block_size: usize, head_dim: usize) -> usize {
        let align = self
            .map
            .iter()
            .flat_map(|pair| pair.iter().flatten())
            .map(|&p| codec_for(p).row_align())
            .max()
            .unwrap_or(1);
        (0..self.layers())
            .flat_map(|l| (0..2).map(move |kv| (l, kv)))
            .map(|(l, kv)| self.stream_layout(l, kv, block_size, head_dim).block_bytes)
            .max()
            .unwrap_or(0)
            .next_multiple_of(align)
    }

    /// Payload bytes of `seq_len` cached tokens under this policy
    /// (per-row accounting, all layers/sides/heads).
    pub fn payload_bytes(&self, head_dim: usize, seq_len: usize) -> u64 {
        self.map
            .iter()
            .flat_map(|pair| pair.iter().flatten())
            .map(|&p| (seq_len * codec_for(p).bytes_per_row(head_dim)) as u64)
            .sum()
    }

    /// Per-channel frozen-scale overhead: one f32 per quantized
    /// (layer, kv, head, channel); FP32 streams carry none.
    pub fn scale_overhead_bytes(&self, head_dim: usize) -> u64 {
        self.map
            .iter()
            .flat_map(|pair| pair.iter().flatten())
            .filter(|&&p| p != Precision::Fp32)
            .map(|_| (head_dim * 4) as u64)
            .sum()
    }

    /// Payload bytes of `seq_len` tokens broken down by precision,
    /// indexed `[fp32, int8, int4]` — the `GET /metrics` breakdown.
    pub fn payload_bytes_by_precision(&self, head_dim: usize, seq_len: usize) -> [u64; 3] {
        let mut out = [0u64; 3];
        for &p in self.map.iter().flat_map(|pair| pair.iter().flatten()) {
            out[p as usize] += (seq_len * codec_for(p).bytes_per_row(head_dim)) as u64;
        }
        out
    }
}

// ---------------------------------------------------------------------------
// StreamLayout — block byte layout of one (layer, K|V) stream.
// ---------------------------------------------------------------------------

/// How one `(layer, kv)` stream's rows pack into a block: head-major
/// slabs of `block_size` rows each, where each head's row width comes
/// from its codec. For uniform streams this is exactly the legacy
/// `[heads][block_size][head_dim]` layout.
#[derive(Clone)]
pub struct StreamLayout {
    codecs: Vec<&'static dyn Codec>,
    /// Byte offset of each head's slab within a block.
    offsets: Vec<usize>,
    /// Payload bytes per row of each head.
    row_bytes: Vec<usize>,
    /// Total payload bytes of one block of this stream.
    pub block_bytes: usize,
    /// The stream's single precision, when all heads agree.
    pub uniform: Option<Precision>,
    block_size: usize,
}

impl StreamLayout {
    pub fn new(precisions: &[Precision], block_size: usize, head_dim: usize) -> StreamLayout {
        let codecs: Vec<&'static dyn Codec> =
            precisions.iter().map(|&p| codec_for(p)).collect();
        let row_bytes: Vec<usize> = codecs.iter().map(|c| c.bytes_per_row(head_dim)).collect();
        let mut offsets = Vec::with_capacity(codecs.len());
        let mut off = 0usize;
        for (c, &rb) in codecs.iter().zip(&row_bytes) {
            // Mixed-head streams: pad so e.g. an fp32 slab after an int4
            // one stays 4-byte aligned (uniform streams never pad — their
            // natural offsets already satisfy their own alignment).
            off = off.next_multiple_of(c.row_align());
            offsets.push(off);
            off += block_size * rb;
        }
        let uniform = precisions
            .iter()
            .all(|&p| p == precisions[0])
            .then_some(precisions[0]);
        StreamLayout { codecs, offsets, row_bytes, block_bytes: off, uniform, block_size }
    }

    pub fn heads(&self) -> usize {
        self.codecs.len()
    }

    pub fn head_codec(&self, head: usize) -> &'static dyn Codec {
        self.codecs[head]
    }

    /// Payload bytes of one row of `head`.
    pub fn head_row_bytes(&self, head: usize) -> usize {
        self.row_bytes[head]
    }

    /// Byte range of `rows` valid rows of `head` within a block.
    pub fn head_slab(&self, head: usize, rows: usize) -> std::ops::Range<usize> {
        debug_assert!(rows <= self.block_size);
        let start = self.offsets[head];
        start..start + rows * self.row_bytes[head]
    }

    /// Byte range of row `row` of `head` within a block.
    pub fn row_range(&self, head: usize, row: usize) -> std::ops::Range<usize> {
        debug_assert!(row < self.block_size);
        let start = self.offsets[head] + row * self.row_bytes[head];
        start..start + self.row_bytes[head]
    }

    /// Payload bytes `len` valid rows of this stream occupy (per-row
    /// accounting across all heads).
    pub fn payload_bytes(&self, len: usize) -> usize {
        self.row_bytes.iter().map(|rb| rb * len).sum()
    }

    /// Strictest row alignment any head codec in this stream requires.
    pub fn align(&self) -> usize {
        self.codecs.iter().map(|c| c.row_align()).max().unwrap_or(1)
    }

    /// Byte width one pool block of this stream occupies in its
    /// sub-pool: the raw payload rounded up to the stream's own
    /// alignment, so every block base in a same-width sub-pool stays
    /// aligned for in-place fp32 reads (align-1 codecs never pad — the
    /// legacy widths are preserved bit-for-bit).
    pub fn padded_block_bytes(&self) -> usize {
        self.block_bytes.next_multiple_of(self.align())
    }

    /// One full block's payload bytes broken down by storage precision,
    /// indexed `[fp32, int8, int4]` (alignment padding unattributed) —
    /// the physical-occupancy breakdown for `GET /metrics`.
    pub fn block_bytes_by_precision(&self) -> [u64; 3] {
        let mut out = [0u64; 3];
        for (c, &rb) in self.codecs.iter().zip(&self.row_bytes) {
            if let Some(p) = Precision::parse(c.name()) {
                out[p as usize] += (self.block_size * rb) as u64;
            }
        }
        out
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn presets_parse_and_name_roundtrip() {
        assert_eq!(PolicySpec::parse("int8").unwrap(), PolicySpec::Uniform(Precision::Int8));
        assert_eq!(
            PolicySpec::parse("uniform:fp32").unwrap(),
            PolicySpec::Uniform(Precision::Fp32)
        );
        assert_eq!(PolicySpec::parse("k8v4").unwrap(), PolicySpec::K8V4);
        assert_eq!(PolicySpec::parse("sink8").unwrap(), PolicySpec::Sink8 { sink_layers: 1 });
        assert_eq!(
            PolicySpec::parse("sink8:3").unwrap(),
            PolicySpec::Sink8 { sink_layers: 3 }
        );
        assert!(PolicySpec::parse("int99").is_err());
        assert!(PolicySpec::parse("sink8:x").is_err());
        assert_eq!(PolicySpec::parse("k8v4").unwrap().name(), "k8v4");
        assert_eq!(PolicySpec::Uniform(Precision::Int4).name(), "uniform:int4");
        assert_eq!(PolicySpec::Uniform(Precision::Int4).engine_label(), "int4");
        assert_eq!(PolicySpec::K8V4.engine_label(), "k8v4");
    }

    #[test]
    fn uniform_resolution_covers_every_stream() {
        let p = PolicySpec::Uniform(Precision::Int8).resolve(3, 2, 8).unwrap();
        assert_eq!(p.as_uniform(), Some(Precision::Int8));
        assert_eq!(p.staged(), Some(StagedKind::I8));
        for l in 0..3 {
            for kv in 0..2 {
                for h in 0..2 {
                    assert_eq!(p.precision(l, kv, h), Precision::Int8);
                }
            }
        }
        assert_eq!(
            PolicySpec::Uniform(Precision::Fp32).resolve(1, 1, 4).unwrap().staged(),
            Some(StagedKind::F32)
        );
        assert_eq!(
            PolicySpec::Uniform(Precision::Int4).resolve(1, 1, 4).unwrap().staged(),
            None,
            "int4 has no dense staging ABI"
        );
    }

    #[test]
    fn k8v4_splits_sides_and_requires_paged() {
        let p = PolicySpec::K8V4.resolve(2, 2, 8).unwrap();
        assert_eq!(p.precision(1, 0, 1), Precision::Int8, "keys int8");
        assert_eq!(p.precision(1, 1, 0), Precision::Int4, "values int4");
        assert_eq!(p.as_uniform(), None);
        assert_eq!(p.staged(), None);
        assert!(p.uses(Precision::Int4) && p.uses(Precision::Int8));
        assert!(!p.uses(Precision::Fp32));
    }

    #[test]
    fn sink8_keeps_early_layers_fp32() {
        let p = PolicySpec::Sink8 { sink_layers: 2 }.resolve(4, 1, 8).unwrap();
        assert_eq!(p.precision(0, 0, 0), Precision::Fp32);
        assert_eq!(p.precision(1, 1, 0), Precision::Fp32);
        assert_eq!(p.precision(2, 0, 0), Precision::Int8);
        assert_eq!(p.staged(), None, "mixed precision needs the paged path");
        // Sink count >= layers degenerates to uniform fp32 (and may stage).
        let all = PolicySpec::Sink8 { sink_layers: 9 }.resolve(4, 1, 8).unwrap();
        assert_eq!(all.as_uniform(), Some(Precision::Fp32));
    }

    #[test]
    fn int4_policies_reject_odd_head_dim() {
        for spec in [
            PolicySpec::Uniform(Precision::Int4),
            PolicySpec::K8V4,
        ] {
            let err = spec.resolve(2, 2, 7).unwrap_err();
            assert!(err.to_string().contains("even head_dim"), "{err}");
        }
        // No int4 side: odd head_dim is fine.
        PolicySpec::Sink8 { sink_layers: 1 }.resolve(2, 2, 7).unwrap();
    }

    #[test]
    fn table_from_json_with_head_overrides() {
        let j = Json::parse(
            r#"{
                "name": "sink-mixed", "layers": 2, "heads": 2,
                "default": {"k": "int8", "v": "int4"},
                "table": [
                    {"layer": 0, "k": "fp32", "v": "fp32"},
                    {"layer": 1, "heads": [{"head": 1, "side": "v", "precision": "int8"}]}
                ]
            }"#,
        )
        .unwrap();
        let t = PolicyTable::from_json(&j).unwrap();
        let p = PolicySpec::Table(t).resolve(2, 2, 8).unwrap();
        assert_eq!(p.name(), "sink-mixed");
        assert_eq!(p.precision(0, 0, 0), Precision::Fp32);
        assert_eq!(p.precision(0, 1, 1), Precision::Fp32);
        assert_eq!(p.precision(1, 0, 0), Precision::Int8, "default K");
        assert_eq!(p.precision(1, 1, 0), Precision::Int4, "default V");
        assert_eq!(p.precision(1, 1, 1), Precision::Int8, "head override");
    }

    #[test]
    fn table_validation_rejects_bad_inputs() {
        let parse = |s: &str| PolicyTable::from_json(&Json::parse(s).unwrap());
        assert!(parse(r#"{"default": "int8"}"#).is_err(), "missing name");
        assert!(
            parse(r#"{"name": "x", "default": "int9"}"#).is_err(),
            "unknown precision rejected"
        );
        assert!(
            parse(r#"{"name":"x","table":[{"k":"int8"}]}"#).is_err(),
            "rule without layer"
        );
        assert!(
            parse(r#"{"name":"x","table":[{"layer":0,"heads":[{"head":0,"side":"q",
                    "precision":"int8"}]}]}"#)
                .is_err(),
            "bad side"
        );
        // Out-of-bounds rules surface at resolution.
        let t = parse(r#"{"name":"x","table":[{"layer":5,"k":"int4"}]}"#).unwrap();
        assert!(PolicySpec::Table(t).resolve(2, 2, 8).is_err());
        let t = parse(
            r#"{"name":"x","table":[{"layer":0,"heads":[{"head":7,"side":"k",
                "precision":"int8"}]}]}"#,
        )
        .unwrap();
        assert!(PolicySpec::Table(t).resolve(2, 2, 8).is_err());
        // Declared geometry must match the model.
        let t = parse(r#"{"name":"x","layers":8,"default":"int8"}"#).unwrap();
        assert!(PolicySpec::Table(t).resolve(2, 2, 8).is_err());
    }

    #[test]
    fn every_shipped_policy_json_validates() {
        // CI gate for the configs/ policy tables: each file must parse,
        // declare its geometry, and resolve cleanly against it (bounds
        // checks, known precisions, even-head_dim for any INT4 side —
        // resolution is tried at head_dim 8). Unknown precisions or
        // out-of-range layer/head indices fail this test.
        let dir = ["configs", "../configs", "../../configs"]
            .iter()
            .map(std::path::PathBuf::from)
            .find(|p| p.exists())
            .expect("configs/ not found from cwd");
        let mut checked = 0;
        for entry in std::fs::read_dir(&dir).unwrap() {
            let path = entry.unwrap().path();
            let name = path.file_name().unwrap().to_string_lossy().to_string();
            if !name.starts_with("policy_") || !name.ends_with(".json") {
                continue;
            }
            let table = PolicyTable::load(path.to_str().unwrap())
                .unwrap_or_else(|e| panic!("{name} failed to parse: {e:#}"));
            let layers = table.layers.unwrap_or_else(|| {
                panic!("{name} must declare \"layers\" (validation geometry)")
            });
            let heads = table
                .heads
                .unwrap_or_else(|| panic!("{name} must declare \"heads\""));
            PolicySpec::Table(table)
                .resolve(layers, heads, 8)
                .unwrap_or_else(|e| panic!("{name} failed to resolve: {e:#}"));
            checked += 1;
        }
        assert!(checked >= 2, "expected the shipped policy tables, found {checked}");
    }

    #[test]
    fn stream_layout_offsets_match_legacy_for_uniform() {
        // Uniform int8, bs=4, d=8: head h at byte h*4*8 — the legacy
        // [heads][block_size][head_dim] layout.
        let l = StreamLayout::new(&[Precision::Int8; 2], 4, 8);
        assert_eq!(l.block_bytes, 2 * 4 * 8);
        assert_eq!(l.head_slab(1, 3), 32..32 + 24);
        assert_eq!(l.row_range(0, 2), 16..24);
        assert_eq!(l.uniform, Some(Precision::Int8));
        assert_eq!(l.payload_bytes(5), 2 * 5 * 8);
        // fp32: 4x.
        let lf = StreamLayout::new(&[Precision::Fp32; 2], 4, 8);
        assert_eq!(lf.block_bytes, 2 * 4 * 8 * 4);
        // int4: half, nibble-packed.
        let l4 = StreamLayout::new(&[Precision::Int4; 2], 4, 8);
        assert_eq!(l4.block_bytes, 2 * 4 * 4);
        assert_eq!(l4.row_range(1, 0), 16..20);
    }

    #[test]
    fn mixed_head_layout_uses_prefix_offsets() {
        let l = StreamLayout::new(&[Precision::Fp32, Precision::Int4], 2, 8);
        assert_eq!(l.head_slab(0, 2), 0..64, "fp32 head first");
        assert_eq!(l.head_slab(1, 2), 64..64 + 8, "int4 head after it");
        assert_eq!(l.uniform, None);
        assert_eq!(l.payload_bytes(3), 3 * 32 + 3 * 4);
    }

    #[test]
    fn byte_accounting_is_policy_aware() {
        // 2 layers, 2 heads, d=8, 10 tokens.
        let int8 = QuantPolicy::uniform(Precision::Int8, 2, 2);
        assert_eq!(int8.payload_bytes(8, 10), 2 * 2 * 2 * 10 * 8);
        assert_eq!(int8.scale_overhead_bytes(8), 2 * 2 * 2 * 8 * 4);
        let fp32 = QuantPolicy::uniform(Precision::Fp32, 2, 2);
        assert_eq!(fp32.payload_bytes(8, 10), 4 * int8.payload_bytes(8, 10));
        assert_eq!(fp32.scale_overhead_bytes(8), 0);
        let k8v4 = PolicySpec::K8V4.resolve(2, 2, 8).unwrap();
        let by = k8v4.payload_bytes_by_precision(8, 10);
        assert_eq!(by[Precision::Fp32 as usize], 0);
        assert_eq!(by[Precision::Int8 as usize], 2 * 2 * 10 * 8, "K streams");
        assert_eq!(by[Precision::Int4 as usize], 2 * 2 * 10 * 4, "V streams");
        assert_eq!(
            k8v4.payload_bytes(8, 10),
            by.iter().sum::<u64>(),
            "breakdown sums to the total"
        );
        // k8v4 lands strictly between uniform int8 and uniform int4.
        let int4 = QuantPolicy::uniform(Precision::Int4, 2, 2);
        assert!(k8v4.payload_bytes(8, 10) < int8.payload_bytes(8, 10));
        assert!(k8v4.payload_bytes(8, 10) > int4.payload_bytes(8, 10));
    }

    #[test]
    fn padded_block_bytes_and_precision_split_per_stream() {
        // k8v4 at bs=4, d=8, 2 heads: K stream 64 B (int8), V stream
        // 32 B (int4) — no padding (align 1), and the per-precision
        // split attributes each stream's full block to its own codec.
        let k8v4 = PolicySpec::K8V4.resolve(2, 2, 8).unwrap();
        let kl = k8v4.stream_layout(0, 0, 4, 8);
        let vl = k8v4.stream_layout(0, 1, 4, 8);
        assert_eq!((kl.padded_block_bytes(), vl.padded_block_bytes()), (64, 32));
        assert_eq!(kl.block_bytes_by_precision(), [0, 64, 0]);
        assert_eq!(vl.block_bytes_by_precision(), [0, 0, 32]);
        // Mixed-head stream with an fp32 head pads to 4-byte alignment:
        // 2×24 fp32 + 2×3 int4 = 54 raw bytes → 56 padded.
        let m = StreamLayout::new(&[Precision::Fp32, Precision::Int4], 2, 6);
        assert_eq!(m.align(), 4);
        assert_eq!(m.block_bytes, 54);
        assert_eq!(m.padded_block_bytes(), 56);
        assert_eq!(m.block_bytes_by_precision(), [48, 0, 6]);
    }

    #[test]
    fn max_block_bytes_pads_to_the_widest_stream() {
        let k8v4 = PolicySpec::K8V4.resolve(2, 2, 8).unwrap();
        // Widest stream is the int8 K side: 2 heads x 4 rows x 8 bytes.
        assert_eq!(k8v4.max_block_bytes(4, 8), 2 * 4 * 8);
        let sink = PolicySpec::Sink8 { sink_layers: 1 }.resolve(2, 2, 8).unwrap();
        assert_eq!(sink.max_block_bytes(4, 8), 2 * 4 * 8 * 4, "fp32 sink sets the width");
    }

    #[test]
    fn max_block_bytes_keeps_every_fp32_block_base_aligned() {
        // Mixed-head stream [fp32, int8] at head_dim 5, block_size 2:
        // the widest stream is 2*5*4 + 2*5 = 50 raw bytes. Without
        // rounding, block 1 would start at byte 50 (2 mod 4) and the
        // fp32 slab read would be misaligned — the policy must pad the
        // block width to the strictest codec alignment (4 here).
        let t = PolicyTable {
            name: "mixed-head".into(),
            layers: Some(1),
            heads: Some(2),
            default: [Precision::Int8; 2],
            rules: vec![PolicyRule {
                layer: 0,
                k: None,
                v: None,
                heads: vec![HeadOverride { head: 0, kv: 0, precision: Precision::Fp32 }],
            }],
        };
        let p = PolicySpec::Table(t).resolve(1, 2, 5).unwrap();
        assert_eq!(p.max_block_bytes(2, 5), 52, "50 raw bytes padded to 4-byte multiple");
        // Pure-int policies keep their legacy (unpadded) widths.
        let int4 = QuantPolicy::uniform(Precision::Int4, 1, 1);
        assert_eq!(int4.max_block_bytes(3, 6), 9, "align-1 codecs never pad");
    }
}
