//! Compressed cold tier and persistent prefix snapshots.
//!
//! The hot [`super::pool::BlockPool`] holds every block a decode wave can
//! touch; under capacity pressure the prefix trie used to *destroy* cold
//! cached prompts to make room. This module adds a second chance: the
//! engine **demotes** the same LRU-reclaimable units the trie would have
//! evicted, but captures their payloads first ([`CapturedPrompt`]) and
//! parks them in a compressed in-memory store. A later request for the
//! same prompt **promotes** the entry back into the hot pool —
//! bit-identical, because quantized payload bytes and frozen eq.-6 scale
//! grids round-trip losslessly through the codec below.
//!
//! - **Compression** — per block: byte-shuffle with the stream's row
//!   width as stride (groups each channel's bytes, which vary slowly
//!   across rows after quantization) followed by run-length coding, with
//!   a raw fallback when RLE would expand. Scale grids are kept as exact
//!   `f32`. Everything is lossless and deterministic.
//! - **Prefetch** — a background thread decompresses requested entries
//!   into a bounded ready map ahead of the decode window;
//!   [`ColdTier::promote`] falls back to synchronous decompression (a
//!   `prefetch_miss`) when a wave outruns it.
//! - **Snapshots** — the store serializes to a versioned, checksummed
//!   on-disk image (`KVQSNAP1`) loaded at engine start, so restarts keep
//!   their warmed prefix corpus. Geometry/policy mismatches and checksum
//!   failures are ignored with a warning — a snapshot is a cache, never
//!   a source of truth.

use std::collections::HashMap;
use std::path::Path;
use std::sync::mpsc;
use std::sync::{Arc, Mutex};
use std::thread::JoinHandle;
use std::time::Instant;

use anyhow::{bail, Context, Result};

use super::manager::{KvCacheManager, SeqId};
use super::pool::BlockId;
use super::prefix::{CapturedPrompt, PrefixCache};

/// Compressed-block method byte: payload stored verbatim.
const METHOD_RAW: u8 = 0;
/// Compressed-block method byte: byte-shuffle + run-length pairs.
const METHOD_SHUFFLE_RLE: u8 = 1;
/// Bytes of `[method u8][raw_len u32][stride u32]` before the body.
const BLOCK_HEADER: usize = 9;

const SNAP_MAGIC: &[u8; 8] = b"KVQSNAP1";
const SNAP_VERSION: u32 = 1;

// ---------------------------------------------------------------------------
// Block codec: shuffle + RLE with raw fallback, self-describing header
// ---------------------------------------------------------------------------

/// Transpose `data` viewed as rows of `stride` bytes into lane-major
/// order (lane 0 of every row, then lane 1, ...). A trailing partial row
/// is appended untouched.
fn shuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let rows = data.len() / stride;
    let mut out = Vec::with_capacity(data.len());
    for lane in 0..stride {
        for row in 0..rows {
            out.push(data[row * stride + lane]);
        }
    }
    out.extend_from_slice(&data[rows * stride..]);
    out
}

/// Inverse of [`shuffle`].
fn unshuffle(data: &[u8], stride: usize) -> Vec<u8> {
    let rows = data.len() / stride;
    let mut out = vec![0u8; data.len()];
    let mut i = 0;
    for lane in 0..stride {
        for row in 0..rows {
            out[row * stride + lane] = data[i];
            i += 1;
        }
    }
    out[rows * stride..].copy_from_slice(&data[i..]);
    out
}

/// Run-length coding as `(count u8 in 1..=255, value u8)` pairs.
fn rle_encode(data: &[u8]) -> Vec<u8> {
    let mut out = Vec::new();
    let mut i = 0;
    while i < data.len() {
        let v = data[i];
        let mut run = 1usize;
        while run < 255 && i + run < data.len() && data[i + run] == v {
            run += 1;
        }
        out.push(run as u8);
        out.push(v);
        i += run;
    }
    out
}

fn rle_decode(data: &[u8], raw_len: usize) -> Result<Vec<u8>> {
    if data.len() % 2 != 0 {
        bail!("rle stream has odd length {}", data.len());
    }
    let mut out = Vec::with_capacity(raw_len);
    for pair in data.chunks_exact(2) {
        let (run, v) = (pair[0] as usize, pair[1]);
        if run == 0 || out.len() + run > raw_len {
            bail!("rle stream decodes past {raw_len} bytes");
        }
        out.resize(out.len() + run, v);
    }
    if out.len() != raw_len {
        bail!("rle stream decodes to {} of {raw_len} bytes", out.len());
    }
    Ok(out)
}

/// Compress one raw block payload. The output is self-describing
/// (`[method][raw_len][stride][body]`) so [`decompress_block`] needs no
/// side channel — the prefetch thread and the snapshot loader both rely
/// on that. `stride` should be the stream's quantized row width; any
/// value is correct, it only changes the ratio.
pub fn compress_block(data: &[u8], stride: usize) -> Vec<u8> {
    let stride = stride.max(1);
    let rle = rle_encode(&shuffle(data, stride));
    let (method, body) = if rle.len() < data.len() {
        (METHOD_SHUFFLE_RLE, rle.as_slice())
    } else {
        (METHOD_RAW, data)
    };
    let mut out = Vec::with_capacity(BLOCK_HEADER + body.len());
    out.push(method);
    out.extend_from_slice(&(data.len() as u32).to_le_bytes());
    out.extend_from_slice(&(stride as u32).to_le_bytes());
    out.extend_from_slice(body);
    out
}

/// Recover the exact bytes passed to [`compress_block`].
pub fn decompress_block(comp: &[u8]) -> Result<Vec<u8>> {
    if comp.len() < BLOCK_HEADER {
        bail!("compressed block shorter than its {BLOCK_HEADER}-byte header");
    }
    let raw_len = u32::from_le_bytes(comp[1..5].try_into().unwrap()) as usize;
    let stride = (u32::from_le_bytes(comp[5..9].try_into().unwrap()) as usize).max(1);
    let body = &comp[BLOCK_HEADER..];
    match comp[0] {
        METHOD_RAW => {
            if body.len() != raw_len {
                bail!("raw block body is {} of {raw_len} bytes", body.len());
            }
            Ok(body.to_vec())
        }
        METHOD_SHUFFLE_RLE => Ok(unshuffle(&rle_decode(body, raw_len)?, stride)),
        m => bail!("unknown compression method {m}"),
    }
}

// ---------------------------------------------------------------------------
// Cold store
// ---------------------------------------------------------------------------

/// One demoted prompt, compressed. Mirrors [`CapturedPrompt`] with every
/// block payload run through [`compress_block`]; scales and logits stay
/// exact.
#[derive(Debug, Clone)]
struct ColdEntry {
    /// `[layer][kv]` → per-block compressed payloads, prompt block order.
    blocks: Vec<[Vec<Vec<u8>>; 2]>,
    /// `[layer][kv]` → concatenated frozen scale grids (exact).
    scales: Vec<[Vec<f32>; 2]>,
    /// Stored last-position prefill logits.
    logits: Vec<f32>,
    /// Total blocks across all streams (capacity accounting).
    nblocks: usize,
    /// Uncompressed payload bytes.
    raw_bytes: u64,
    /// Compressed payload bytes (headers included).
    comp_bytes: u64,
    /// LRU tick of the owning store.
    last_used: u64,
}

impl ColdEntry {
    fn from_capture(cap: &CapturedPrompt, mgr: &KvCacheManager) -> ColdEntry {
        let layers = mgr.config().layers;
        let mut blocks: Vec<[Vec<Vec<u8>>; 2]> = Vec::with_capacity(layers);
        let (mut nblocks, mut raw, mut comp) = (0usize, 0u64, 0u64);
        for layer in 0..layers {
            let mut pair = [Vec::new(), Vec::new()];
            for kv in 0..2 {
                let stride = mgr.stream_layout(layer, kv).head_row_bytes(0);
                for payload in &cap.payloads[layer][kv] {
                    raw += payload.len() as u64;
                    let c = compress_block(payload, stride);
                    comp += c.len() as u64;
                    pair[kv].push(c);
                    nblocks += 1;
                }
            }
            blocks.push(pair);
        }
        ColdEntry {
            blocks,
            scales: cap.scales.clone(),
            logits: cap.logits.clone(),
            nblocks,
            raw_bytes: raw,
            comp_bytes: comp,
            last_used: 0,
        }
    }

    /// Rehydrate into the exact capture that produced this entry.
    fn decompress(&self, tokens: Vec<i32>) -> Result<CapturedPrompt> {
        let mut payloads: Vec<[Vec<Vec<u8>>; 2]> = Vec::with_capacity(self.blocks.len());
        for pair in &self.blocks {
            let mut out = [Vec::new(), Vec::new()];
            for kv in 0..2 {
                for comp in &pair[kv] {
                    out[kv].push(decompress_block(comp)?);
                }
            }
            payloads.push(out);
        }
        Ok(CapturedPrompt {
            tokens,
            payloads,
            scales: self.scales.clone(),
            logits: self.logits.clone(),
        })
    }
}

/// Keyed by the full prompt token vector — promotion is exact-match;
/// partial-prefix reuse returns once a promoted prompt is re-inserted
/// into the hot trie at finalize.
#[derive(Debug, Default)]
struct ColdStore {
    entries: HashMap<Vec<i32>, ColdEntry>,
    /// Σ entry `nblocks` (capacity accounting).
    blocks: usize,
    tick: u64,
}

impl ColdStore {
    fn insert(&mut self, tokens: Vec<i32>, mut entry: ColdEntry) {
        self.tick += 1;
        entry.last_used = self.tick;
        if let Some(old) = self.entries.remove(&tokens) {
            self.blocks -= old.nblocks;
        }
        self.blocks += entry.nblocks;
        self.entries.insert(tokens, entry);
    }

    fn remove(&mut self, tokens: &[i32]) -> Option<ColdEntry> {
        let e = self.entries.remove(tokens)?;
        self.blocks -= e.nblocks;
        Some(e)
    }

    fn touch(&mut self, tokens: &[i32]) {
        self.tick += 1;
        let tick = self.tick;
        if let Some(e) = self.entries.get_mut(tokens) {
            e.last_used = tick;
        }
    }

    /// Evict least-recently-used entries until `blocks <= capacity`.
    /// Ties break on key order so eviction is deterministic.
    fn evict_lru_to(&mut self, capacity: usize) -> u64 {
        let mut evicted = 0;
        while self.blocks > capacity {
            let key = self
                .entries
                .iter()
                .min_by(|a, b| a.1.last_used.cmp(&b.1.last_used).then_with(|| a.0.cmp(b.0)))
                .map(|(k, _)| k.clone());
            match key {
                Some(k) => {
                    self.remove(&k);
                    evicted += 1;
                }
                None => break,
            }
        }
        evicted
    }

    fn raw_bytes(&self) -> u64 {
        self.entries.values().map(|e| e.raw_bytes).sum()
    }

    fn comp_bytes(&self) -> u64 {
        self.entries.values().map(|e| e.comp_bytes).sum()
    }
}

// ---------------------------------------------------------------------------
// Tier counters
// ---------------------------------------------------------------------------

/// Point-in-time tier counters, surfaced in `GET /metrics` (schema v4;
/// `snapshot_rejected` / `decompress_errors` added in v5).
#[derive(Debug, Clone, Copy, Default, PartialEq)]
pub struct TierStats {
    /// Prompts demoted hot → cold.
    pub demotions: u64,
    /// Prompts promoted cold → hot.
    pub promotions: u64,
    /// Promotions served from the async prefetch ready map.
    pub prefetch_hits: u64,
    /// Promotions that decompressed synchronously (wave outran prefetch).
    pub prefetch_misses: u64,
    /// Cold entries dropped by the store's own LRU capacity bound.
    pub cold_evictions: u64,
    /// Pool-pressure events absorbed by demotion: each `demote_for` call
    /// that freed hot bytes is one reclaim the engine satisfied without
    /// destroying the cached prefix or preempting a running sequence
    /// (with the tier off the same pressure evicts, and preempts once
    /// nothing reclaimable remains).
    pub preemptions_avoided: u64,
    /// Entries restored from an on-disk snapshot at startup.
    pub snapshot_loaded: u64,
    /// Snapshot images rejected (corrupt, truncated, or mismatched
    /// geometry/policy) — warn-and-skip, never fatal.
    pub snapshot_rejected: u64,
    /// Cold entries dropped because decompression failed or the
    /// decompressed block violated the declared slab geometry. The
    /// affected request falls back to backend prefill (bit-identical by
    /// the determinism contract); the bad entry never serves again.
    pub decompress_errors: u64,
    /// Current cold entries / blocks / bytes.
    pub cold_entries: u64,
    pub cold_blocks: u64,
    pub cold_raw_bytes: u64,
    pub cold_comp_bytes: u64,
    /// Cumulative wall-clock seconds in each phase.
    pub demote_secs: f64,
    pub promote_secs: f64,
    pub decompress_secs: f64,
}

impl TierStats {
    /// Uncompressed / compressed bytes currently resident (1.0 when
    /// empty).
    pub fn compression_ratio(&self) -> f64 {
        if self.cold_comp_bytes == 0 {
            1.0
        } else {
            self.cold_raw_bytes as f64 / self.cold_comp_bytes as f64
        }
    }
}

// ---------------------------------------------------------------------------
// ColdTier
// ---------------------------------------------------------------------------

/// The compressed cold tier: demotion sink, promotion source, prefetch
/// front-end, and snapshot reader/writer. A `capacity_blocks` of 0
/// disables the tier entirely (every operation is a no-op) — the
/// `KVQ_COLD_TIER=off` escape hatch resolves to that.
pub struct ColdTier {
    capacity_blocks: usize,
    prefetch_depth: usize,
    store: Arc<Mutex<ColdStore>>,
    /// Decompressed entries staged by the prefetch thread, bounded by
    /// `prefetch_depth`.
    ready: Arc<Mutex<HashMap<Vec<i32>, CapturedPrompt>>>,
    tx: Option<mpsc::Sender<Vec<i32>>>,
    worker: Option<JoinHandle<()>>,
    demotions: u64,
    promotions: u64,
    prefetch_hits: u64,
    prefetch_misses: u64,
    cold_evictions: u64,
    preemptions_avoided: u64,
    snapshot_loaded: u64,
    snapshot_rejected: u64,
    decompress_errors: u64,
    demote_secs: f64,
    promote_secs: f64,
    decompress_secs: f64,
}

impl ColdTier {
    /// `capacity_blocks` bounds resident cold blocks (0 disables the
    /// tier); `prefetch_depth` bounds the staged ready map (0 disables
    /// the background thread — promotions all decompress synchronously).
    pub fn new(capacity_blocks: usize, prefetch_depth: usize) -> ColdTier {
        let store = Arc::new(Mutex::new(ColdStore::default()));
        let ready = Arc::new(Mutex::new(HashMap::new()));
        let (tx, worker) = if capacity_blocks > 0 && prefetch_depth > 0 {
            let (tx, rx) = mpsc::channel::<Vec<i32>>();
            let (store, ready) = (Arc::clone(&store), Arc::clone(&ready));
            let handle = std::thread::Builder::new()
                .name("kvq-prefetch".into())
                .spawn(move || {
                    while let Ok(tokens) = rx.recv() {
                        if ready.lock().unwrap().len() >= prefetch_depth {
                            continue;
                        }
                        let entry = store.lock().unwrap().entries.get(&tokens).cloned();
                        if let Some(e) = entry {
                            if let Ok(cap) = e.decompress(tokens.clone()) {
                                let mut r = ready.lock().unwrap();
                                if r.len() < prefetch_depth {
                                    r.insert(tokens, cap);
                                }
                            }
                        }
                    }
                })
                .expect("spawn prefetch thread");
            (Some(tx), Some(handle))
        } else {
            (None, None)
        };
        ColdTier {
            capacity_blocks,
            prefetch_depth,
            store,
            ready,
            tx,
            worker,
            demotions: 0,
            promotions: 0,
            prefetch_hits: 0,
            prefetch_misses: 0,
            cold_evictions: 0,
            preemptions_avoided: 0,
            snapshot_loaded: 0,
            snapshot_rejected: 0,
            decompress_errors: 0,
            demote_secs: 0.0,
            promote_secs: 0.0,
            decompress_secs: 0.0,
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity_blocks > 0
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    pub fn prefetch_depth(&self) -> usize {
        self.prefetch_depth
    }

    /// Whether an exact-match promotion for `prompt` is available.
    pub fn contains(&self, prompt: &[i32]) -> bool {
        self.store.lock().unwrap().entries.contains_key(prompt)
    }

    /// Whether the prefetch thread has `prompt` decompressed and staged.
    pub fn prefetch_ready(&self, prompt: &[i32]) -> bool {
        self.ready.lock().unwrap().contains_key(prompt)
    }

    pub fn cold_entries(&self) -> usize {
        self.store.lock().unwrap().entries.len()
    }

    pub fn cold_blocks(&self) -> usize {
        self.store.lock().unwrap().blocks
    }

    /// Compress `cap` into the store, evicting LRU cold entries over
    /// capacity. No hot-pool interaction — the caller already owns the
    /// capture.
    pub fn admit(&mut self, cap: &CapturedPrompt, mgr: &KvCacheManager) {
        if !self.enabled() {
            return;
        }
        let entry = ColdEntry::from_capture(cap, mgr);
        let mut store = self.store.lock().unwrap();
        store.insert(cap.tokens.clone(), entry);
        self.cold_evictions += store.evict_lru_to(self.capacity_blocks);
        drop(store);
        // A staged decompression for the same key is byte-identical by
        // construction, but drop it anyway: the store is authoritative.
        self.ready.lock().unwrap().remove(&cap.tokens);
    }

    /// Demote LRU-reclaimable prefix units until the hot pool has
    /// `want_free` usable bytes ([`KvCacheManager::free_bytes`]) or
    /// nothing reclaimable remains. Frees exactly the blocks
    /// [`PrefixCache::evict_for_bytes`] would have destroyed — with the
    /// tier disabled the engine falls back to that — and returns the
    /// number of prompts demoted.
    pub fn demote_for(
        &mut self,
        pc: &mut PrefixCache,
        mgr: &mut KvCacheManager,
        want_free: u64,
    ) -> u64 {
        if !self.enabled() {
            return 0;
        }
        // Injected demotion failure: skip this reclaim round — the engine
        // falls back to trie eviction / preemption, which stays correct.
        if crate::util::fault::hit("tier_demote").is_err() {
            return 0;
        }
        let t0 = Instant::now();
        let mut demoted = 0;
        while mgr.free_bytes() < want_free {
            match pc.demote_reclaimable_lru(mgr) {
                Some(caps) => {
                    for cap in caps {
                        self.admit(&cap, mgr);
                        demoted += 1;
                    }
                }
                None => break,
            }
        }
        self.demotions += demoted;
        if demoted > 0 {
            self.preemptions_avoided += 1;
        }
        self.demote_secs += t0.elapsed().as_secs_f64();
        demoted
    }

    /// Ask the background thread to decompress `prompt` ahead of need.
    /// Cheap and non-blocking; a no-op when the thread is disabled, the
    /// prompt is not cold, or it is already staged.
    pub fn request_prefetch(&self, prompt: &[i32]) {
        let Some(tx) = &self.tx else { return };
        if self.prefetch_ready(prompt) {
            return;
        }
        let mut store = self.store.lock().unwrap();
        if !store.entries.contains_key(prompt) {
            return;
        }
        store.touch(prompt);
        drop(store);
        let _ = tx.send(prompt.to_vec());
    }

    /// Promote an exact-match cold entry back into the hot pool:
    /// decompress (staged or synchronous), restore every block at its
    /// original width class, and adopt the result as a live sequence
    /// whose blocks/scales are bit-identical to the demoted ones. The
    /// entry leaves the store on success and is restored untouched if
    /// the pool can't hold it. An entry whose payload fails to
    /// decompress — or decompresses to the wrong slab width — is
    /// **dropped** (counted in [`TierStats::decompress_errors`]) so the
    /// request falls back to backend prefill instead of retrying a
    /// poisoned entry forever.
    pub fn promote(
        &mut self,
        mgr: &mut KvCacheManager,
        prompt: &[i32],
    ) -> Option<(SeqId, Vec<f32>)> {
        if !self.enabled() {
            return None;
        }
        // Injected promotion failure: the entry stays cold and the
        // request is served by backend prefill.
        if crate::util::fault::hit("tier_promote").is_err() {
            return None;
        }
        let mut entry = self.store.lock().unwrap().remove(prompt)?;
        let staged = self.ready.lock().unwrap().remove(prompt);
        let t0 = Instant::now();
        let cap = match staged {
            Some(cap) => {
                self.prefetch_hits += 1;
                cap
            }
            None => {
                // Injected corruption flips compressed payload bytes —
                // the decode path below must reject them, never panic.
                if let Some(block) = entry
                    .blocks
                    .iter_mut()
                    .flat_map(|pair| pair.iter_mut())
                    .flat_map(|stream| stream.iter_mut())
                    .next()
                {
                    crate::util::fault::corrupt("tier_decompress", block);
                }
                let td = Instant::now();
                let cap = crate::util::fault::hit("tier_decompress")
                    .and_then(|()| entry.decompress(prompt.to_vec()));
                let cap = match cap {
                    Ok(c) => c,
                    Err(e) => {
                        self.decompress_errors += 1;
                        crate::warn!(
                            "dropping cold entry ({} tokens): {e}",
                            prompt.len()
                        );
                        return None;
                    }
                };
                self.decompress_secs += td.elapsed().as_secs_f64();
                self.prefetch_misses += 1;
                cap
            }
        };
        // Validate decompressed blocks against the declared slab
        // geometry before touching the pool: a lying `raw_len` header
        // must become a typed drop, not a restore-time surprise.
        let layers = mgr.config().layers;
        for layer in 0..layers {
            for kv in 0..2 {
                let want = mgr.stream_layout(layer, kv).block_bytes;
                for bytes in &cap.payloads[layer][kv] {
                    if bytes.len() != want {
                        self.decompress_errors += 1;
                        crate::warn!(
                            "dropping cold entry ({} tokens): block is {} of {want} bytes \
                             for stream ({layer}, {kv})",
                            prompt.len(),
                            bytes.len()
                        );
                        return None;
                    }
                }
            }
        }
        let mut tables: Vec<[Vec<BlockId>; 2]> = vec![[Vec::new(), Vec::new()]; layers];
        let mut ok = true;
        'restore: for layer in 0..layers {
            for kv in 0..2 {
                for bytes in &cap.payloads[layer][kv] {
                    match mgr.restore_block(layer, kv, bytes) {
                        Ok(b) => tables[layer][kv].push(b),
                        Err(_) => {
                            ok = false;
                            break 'restore;
                        }
                    }
                }
            }
        }
        if ok {
            match mgr.adopt_owned_sequence(tables.clone(), cap.scales.clone(), cap.tokens.len()) {
                Ok(seq) => {
                    self.promotions += 1;
                    self.promote_secs += t0.elapsed().as_secs_f64();
                    return Some((seq, cap.logits));
                }
                Err(_) => ok = false,
            }
        }
        let _ = ok;
        for pair in &tables {
            for stream in pair {
                for &b in stream {
                    mgr.release_block(b);
                }
            }
        }
        self.store.lock().unwrap().insert(prompt.to_vec(), entry);
        self.promote_secs += t0.elapsed().as_secs_f64();
        None
    }

    /// Counter snapshot plus current store occupancy.
    pub fn stats(&self) -> TierStats {
        let store = self.store.lock().unwrap();
        TierStats {
            demotions: self.demotions,
            promotions: self.promotions,
            prefetch_hits: self.prefetch_hits,
            prefetch_misses: self.prefetch_misses,
            cold_evictions: self.cold_evictions,
            preemptions_avoided: self.preemptions_avoided,
            snapshot_loaded: self.snapshot_loaded,
            snapshot_rejected: self.snapshot_rejected,
            decompress_errors: self.decompress_errors,
            cold_entries: store.entries.len() as u64,
            cold_blocks: store.blocks as u64,
            cold_raw_bytes: store.raw_bytes(),
            cold_comp_bytes: store.comp_bytes(),
            demote_secs: self.demote_secs,
            promote_secs: self.promote_secs,
            decompress_secs: self.decompress_secs,
        }
    }

    // -- snapshots ----------------------------------------------------------

    /// Serialize the cold store to `path` (temp file + rename). Entries
    /// are written in key order so identical stores produce identical
    /// files. Returns the entry count written.
    pub fn save_snapshot(&self, path: &Path, mgr: &KvCacheManager) -> Result<u64> {
        let store = self.store.lock().unwrap();
        let cfg = mgr.config();
        let mut buf = Vec::new();
        buf.extend_from_slice(SNAP_MAGIC);
        put_u32(&mut buf, SNAP_VERSION);
        put_u32(&mut buf, cfg.layers as u32);
        put_u32(&mut buf, cfg.heads as u32);
        put_u32(&mut buf, cfg.head_dim as u32);
        put_u32(&mut buf, cfg.block_size as u32);
        let policy = mgr.policy().name();
        put_u32(&mut buf, policy.len() as u32);
        buf.extend_from_slice(policy.as_bytes());
        let mut keys: Vec<&Vec<i32>> = store.entries.keys().collect();
        keys.sort();
        put_u32(&mut buf, keys.len() as u32);
        for key in &keys {
            let entry = &store.entries[*key];
            put_u32(&mut buf, key.len() as u32);
            for &t in key.iter() {
                buf.extend_from_slice(&t.to_le_bytes());
            }
            put_u32(&mut buf, entry.logits.len() as u32);
            for &f in &entry.logits {
                put_u32(&mut buf, f.to_bits());
            }
            for pair in &entry.blocks {
                for kv in 0..2 {
                    put_u32(&mut buf, pair[kv].len() as u32);
                    for block in &pair[kv] {
                        put_u32(&mut buf, block.len() as u32);
                        buf.extend_from_slice(block);
                    }
                }
            }
            for pair in &entry.scales {
                for kv in 0..2 {
                    put_u32(&mut buf, pair[kv].len() as u32);
                    for &f in &pair[kv] {
                        put_u32(&mut buf, f.to_bits());
                    }
                }
            }
        }
        let checksum = fnv1a64(&buf);
        buf.extend_from_slice(&checksum.to_le_bytes());
        let tmp = path.with_extension("tmp");
        std::fs::write(&tmp, &buf)
            .with_context(|| format!("write snapshot {}", tmp.display()))?;
        std::fs::rename(&tmp, path)
            .with_context(|| format!("rename snapshot into {}", path.display()))?;
        Ok(keys.len() as u64)
    }

    /// Load a snapshot written by [`Self::save_snapshot`] into the cold
    /// store. A missing file, corrupt image, or geometry/policy mismatch
    /// loads nothing (`Ok(0)`, with a warning on stderr) — the snapshot
    /// is advisory. Returns the entry count loaded.
    pub fn load_snapshot(&mut self, path: &Path, mgr: &KvCacheManager) -> Result<u64> {
        if !self.enabled() || !path.exists() {
            return Ok(0);
        }
        let mut buf = std::fs::read(path)
            .with_context(|| format!("read snapshot {}", path.display()))?;
        // Injected corruption flips image bytes; the checksum below must
        // reject them (counted, warned, never fatal).
        crate::util::fault::corrupt("snapshot_load", &mut buf);
        let parsed = crate::util::fault::hit("snapshot_load")
            .and_then(|()| self.parse_snapshot(&buf, mgr));
        match parsed {
            Ok(n) => {
                self.snapshot_loaded += n;
                Ok(n)
            }
            Err(e) => {
                self.snapshot_rejected += 1;
                eprintln!("warning: ignoring snapshot {}: {e}", path.display());
                Ok(0)
            }
        }
    }

    fn parse_snapshot(&mut self, buf: &[u8], mgr: &KvCacheManager) -> Result<u64> {
        if buf.len() < SNAP_MAGIC.len() + 8 {
            bail!("truncated snapshot ({} bytes)", buf.len());
        }
        let (body, tail) = buf.split_at(buf.len() - 8);
        let stored = u64::from_le_bytes(tail.try_into().unwrap());
        let computed = fnv1a64(body);
        if stored != computed {
            bail!("checksum mismatch (stored {stored:#x}, computed {computed:#x})");
        }
        let mut cur = Cursor { buf: body, pos: 0 };
        if cur.take(SNAP_MAGIC.len())? != SNAP_MAGIC {
            bail!("bad magic");
        }
        let version = cur.u32()?;
        if version != SNAP_VERSION {
            bail!("unsupported snapshot version {version}");
        }
        let cfg = mgr.config();
        let geom =
            [cur.u32()? as usize, cur.u32()? as usize, cur.u32()? as usize, cur.u32()? as usize];
        if geom != [cfg.layers, cfg.heads, cfg.head_dim, cfg.block_size] {
            bail!(
                "geometry mismatch: snapshot {geom:?} vs cache [{}, {}, {}, {}]",
                cfg.layers,
                cfg.heads,
                cfg.head_dim,
                cfg.block_size
            );
        }
        let name_len = cur.u32()? as usize;
        let name = std::str::from_utf8(cur.take(name_len)?).context("policy name")?;
        if name != mgr.policy().name() {
            bail!("policy mismatch: snapshot '{name}' vs cache '{}'", mgr.policy().name());
        }
        let entries = cur.u32()? as usize;
        let mut loaded = 0u64;
        for _ in 0..entries {
            let ntok = cur.u32()? as usize;
            let mut tokens = Vec::with_capacity(ntok);
            for _ in 0..ntok {
                tokens.push(cur.i32()?);
            }
            let nlogits = cur.u32()? as usize;
            let mut logits = Vec::with_capacity(nlogits);
            for _ in 0..nlogits {
                logits.push(f32::from_bits(cur.u32()?));
            }
            let mut blocks = Vec::with_capacity(cfg.layers);
            let (mut nblocks, mut raw, mut comp) = (0usize, 0u64, 0u64);
            for _ in 0..cfg.layers {
                let mut pair = [Vec::new(), Vec::new()];
                for kv in 0..2 {
                    let nb = cur.u32()? as usize;
                    for _ in 0..nb {
                        let len = cur.u32()? as usize;
                        let block = cur.take(len)?.to_vec();
                        if block.len() < BLOCK_HEADER {
                            bail!("snapshot block shorter than its header");
                        }
                        raw += u32::from_le_bytes(block[1..5].try_into().unwrap()) as u64;
                        comp += block.len() as u64;
                        pair[kv].push(block);
                        nblocks += 1;
                    }
                }
                blocks.push(pair);
            }
            let mut scales = Vec::with_capacity(cfg.layers);
            for _ in 0..cfg.layers {
                let mut pair = [Vec::new(), Vec::new()];
                for kv in 0..2 {
                    let ns = cur.u32()? as usize;
                    let mut s = Vec::with_capacity(ns);
                    for _ in 0..ns {
                        s.push(f32::from_bits(cur.u32()?));
                    }
                    pair[kv] = s;
                }
                scales.push(pair);
            }
            let entry = ColdEntry {
                blocks,
                scales,
                logits,
                nblocks,
                raw_bytes: raw,
                comp_bytes: comp,
                last_used: 0,
            };
            let mut store = self.store.lock().unwrap();
            store.insert(tokens, entry);
            self.cold_evictions += store.evict_lru_to(self.capacity_blocks);
            loaded += 1;
        }
        if cur.pos != cur.buf.len() {
            bail!("{} trailing bytes after last entry", cur.buf.len() - cur.pos);
        }
        Ok(loaded)
    }
}

impl Drop for ColdTier {
    fn drop(&mut self) {
        // Dropping the sender ends the worker's recv loop.
        self.tx.take();
        if let Some(handle) = self.worker.take() {
            let _ = handle.join();
        }
    }
}

// ---------------------------------------------------------------------------
// Serialization helpers
// ---------------------------------------------------------------------------

fn put_u32(buf: &mut Vec<u8>, v: u32) {
    buf.extend_from_slice(&v.to_le_bytes());
}

/// FNV-1a 64-bit (the snapshot checksum — fast, dependency-free, and
/// plenty for corruption detection; snapshots are not a trust boundary).
fn fnv1a64(bytes: &[u8]) -> u64 {
    let mut h: u64 = 0xcbf2_9ce4_8422_2325;
    for &b in bytes {
        h ^= b as u64;
        h = h.wrapping_mul(0x0000_0100_0000_01b3);
    }
    h
}

struct Cursor<'a> {
    buf: &'a [u8],
    pos: usize,
}

impl<'a> Cursor<'a> {
    fn take(&mut self, n: usize) -> Result<&'a [u8]> {
        if self.pos + n > self.buf.len() {
            bail!("snapshot truncated at byte {}", self.pos);
        }
        let s = &self.buf[self.pos..self.pos + n];
        self.pos += n;
        Ok(s)
    }

    fn u32(&mut self) -> Result<u32> {
        Ok(u32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }

    fn i32(&mut self) -> Result<i32> {
        Ok(i32::from_le_bytes(self.take(4)?.try_into().unwrap()))
    }
}

// ---------------------------------------------------------------------------

#[cfg(test)]
mod tests {
    use super::super::manager::{CacheConfig, KvCacheManager};
    use super::super::policy::{Precision, QuantPolicy};
    use super::super::prefix::PrefixCache;
    use super::*;

    fn cfg(num_blocks: usize) -> CacheConfig {
        CacheConfig {
            layers: 2,
            heads: 2,
            head_dim: 8,
            max_seq: 32,
            block_size: 4,
            num_blocks,
            scale_margin: 1.0,
        }
    }

    fn manager(num_blocks: usize) -> KvCacheManager {
        let c = cfg(num_blocks);
        KvCacheManager::new(c, QuantPolicy::uniform(Precision::Int8, c.layers, c.heads))
    }

    fn prefill(mgr: &mut KvCacheManager, len: usize, seed: u64) -> u64 {
        let c = *mgr.config();
        let n = c.layers * c.heads * c.max_seq * c.head_dim;
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        rng.fill_uniform(&mut k, -1.0, 1.0);
        rng.fill_uniform(&mut v, -1.0, 1.0);
        let id = mgr.new_sequence();
        mgr.set_prefill(id, &k, &v, len).unwrap();
        id
    }

    fn prompt(len: usize, seed: i32) -> Vec<i32> {
        (0..len as i32).map(|i| i * 7 + seed).collect()
    }

    /// Insert a freshly prefilled prompt into the trie and release the
    /// source sequence, leaving only the trie's pins.
    fn cache_prompt(
        pc: &mut PrefixCache,
        mgr: &mut KvCacheManager,
        len: usize,
        seed: i32,
    ) -> Vec<i32> {
        let toks = prompt(len, seed);
        let src = prefill(mgr, len, seed as u64);
        let logits: Vec<f32> = (0..4).map(|i| seed as f32 + i as f32).collect();
        pc.insert(mgr, src, &toks, &logits);
        mgr.free(src);
        toks
    }

    #[test]
    fn codec_round_trips_bit_identical() {
        let mut rng = crate::util::rng::Rng::new(11);
        let mut noise = vec![0.0f32; 257];
        rng.fill_uniform(&mut noise, 0.0, 255.0);
        let cases: Vec<Vec<u8>> = vec![
            Vec::new(),
            vec![7u8; 1000],
            (0..=255u8).collect(),
            noise.iter().map(|&f| f as u8).collect(),
            vec![1, 2, 3],
        ];
        for data in &cases {
            for stride in [1usize, 3, 16, 64, 1000] {
                let comp = compress_block(data, stride);
                assert_eq!(&decompress_block(&comp).unwrap(), data, "stride {stride}");
            }
        }
        // A constant slab must actually compress; incompressible input
        // must fall back to raw (method 0) and never expand past the
        // header.
        let constant = compress_block(&vec![7u8; 1000], 16);
        assert!(constant.len() < 100, "constant slab stayed {} bytes", constant.len());
        let hostile: Vec<u8> = (0..1000u32).map(|i| (i * 2654435761 >> 13) as u8).collect();
        let comp = compress_block(&hostile, 16);
        assert_eq!(comp[0], METHOD_RAW);
        assert_eq!(comp.len(), hostile.len() + BLOCK_HEADER);
    }

    #[test]
    fn rle_handles_runs_past_255() {
        let data = vec![42u8; 700];
        let enc = rle_encode(&data);
        assert_eq!(enc.len(), 6); // ceil(700/255) = 3 pairs
        assert_eq!(rle_decode(&enc, 700).unwrap(), data);
        assert!(rle_decode(&enc, 699).is_err());
        assert!(rle_decode(&enc[..5], 700).is_err());
    }

    #[test]
    fn demote_promote_round_trip_is_bit_identical() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(64, 0);
        let toks = cache_prompt(&mut pc, &mut mgr, 10, 3);

        let before = pc.capture_all(&mgr);
        assert_eq!(before.len(), 1);
        let before = before.into_iter().next().unwrap();
        assert_eq!(before.tokens, toks);

        // Demote everything: the hot pool must end fully free and the
        // store must hold the one prompt.
        let total =
            mgr.free_bytes() + (pc.pinned_blocks() / (2 * 2)) as u64 * mgr.span_bytes() as u64;
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 1);
        assert_eq!(pc.pinned_blocks(), 0);
        assert_eq!(mgr.free_bytes(), total);
        assert!(tier.contains(&toks));
        assert_eq!(tier.cold_entries(), 1);
        let stats = tier.stats();
        assert_eq!(stats.demotions, 1);
        assert!(stats.cold_raw_bytes > 0);
        assert!(stats.cold_comp_bytes > 0);

        // Promote and compare every byte by re-capturing from the pool.
        let (seq, logits) = tier.promote(&mut mgr, &toks).expect("promotion");
        assert_eq!(logits, before.logits);
        assert!(!tier.contains(&toks));
        let mut pc2 = PrefixCache::new(64);
        pc2.insert(&mut mgr, seq, &toks, &logits);
        mgr.free(seq);
        let after = pc2.capture_all(&mgr);
        assert_eq!(after.len(), 1);
        assert_eq!(before, after[0], "restored blocks/scales differ from demoted ones");

        let stats = tier.stats();
        assert_eq!(stats.promotions, 1);
        assert_eq!(stats.prefetch_misses, 1); // no prefetch thread
        assert_eq!(stats.cold_entries, 0);
        pc2.clear(&mut mgr);
        assert_eq!(mgr.free_bytes(), total);
        mgr.assert_refcounts_consistent();
    }

    #[test]
    fn disabled_tier_is_inert() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(0, 4);
        assert!(!tier.enabled());
        let toks = cache_prompt(&mut pc, &mut mgr, 8, 1);
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 0);
        assert!(pc.pinned_blocks() > 0, "disabled tier must not touch the trie");
        tier.request_prefetch(&toks);
        assert!(tier.promote(&mut mgr, &toks).is_none());
        assert_eq!(tier.stats(), TierStats::default());
        pc.clear(&mut mgr);
    }

    #[test]
    fn store_capacity_evicts_lru_entries() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        // A 4-token prompt is one block per stream = 4 blocks; capacity 6
        // holds one prompt but not two.
        let mut tier = ColdTier::new(6, 0);
        let a = cache_prompt(&mut pc, &mut mgr, 4, 1);
        let b = cache_prompt(&mut pc, &mut mgr, 4, 100);
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 2);
        assert_eq!(tier.cold_entries(), 1);
        assert_eq!(tier.cold_blocks(), 4);
        assert_eq!(tier.stats().cold_evictions, 1);
        // Exactly one of the two survives (the later demotion).
        assert!(tier.contains(&a) != tier.contains(&b));
    }

    #[test]
    fn promote_rolls_back_when_pool_is_full() {
        let mut mgr = manager(8); // 2 spans
        let mut pc = PrefixCache::new(8);
        let mut tier = ColdTier::new(64, 0);
        let toks = cache_prompt(&mut pc, &mut mgr, 8, 5); // both spans
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 1);
        // Refill the pool with a live sequence so promotion can't fit.
        let live = prefill(&mut mgr, 8, 9);
        assert_eq!(mgr.spans_free(), 0);
        assert!(tier.promote(&mut mgr, &toks).is_none());
        assert!(tier.contains(&toks), "failed promotion must keep the cold entry");
        mgr.assert_refcounts_consistent();
        // With room back, the same promotion succeeds.
        mgr.free(live);
        let (seq, _) = tier.promote(&mut mgr, &toks).expect("promotion after free");
        mgr.free(seq);
    }

    #[test]
    fn prefetch_thread_stages_entries_for_hit_promotion() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(64, 2);
        let toks = cache_prompt(&mut pc, &mut mgr, 8, 2);
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 1);
        tier.request_prefetch(&toks);
        for _ in 0..500 {
            if tier.prefetch_ready(&toks) {
                break;
            }
            std::thread::sleep(std::time::Duration::from_millis(10));
        }
        assert!(tier.prefetch_ready(&toks), "prefetch thread never staged the entry");
        let (seq, _) = tier.promote(&mut mgr, &toks).expect("promotion");
        let stats = tier.stats();
        assert_eq!(stats.prefetch_hits, 1);
        assert_eq!(stats.prefetch_misses, 0);
        mgr.free(seq);
    }

    #[test]
    fn snapshot_round_trips_and_rejects_corruption() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("kvq_snap_test_{}.bin", std::process::id()));
        let _ = std::fs::remove_file(&path);

        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(64, 0);
        let a = cache_prompt(&mut pc, &mut mgr, 10, 3);
        let b = cache_prompt(&mut pc, &mut mgr, 4, 50);
        let before = pc.capture_all(&mgr);
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 2);
        assert_eq!(tier.save_snapshot(&path, &mgr).unwrap(), 2);

        // A fresh engine instance loads the snapshot and promotes
        // bit-identically.
        let mut mgr2 = manager(64);
        let mut tier2 = ColdTier::new(64, 0);
        assert_eq!(tier2.load_snapshot(&path, &mgr2).unwrap(), 2);
        assert_eq!(tier2.stats().snapshot_loaded, 2);
        assert!(tier2.contains(&a) && tier2.contains(&b));
        for cap in &before {
            let (seq, logits) = tier2.promote(&mut mgr2, &cap.tokens).expect("promotion");
            assert_eq!(logits, cap.logits);
            let mut pc2 = PrefixCache::new(64);
            pc2.insert(&mut mgr2, seq, &cap.tokens, &logits);
            mgr2.free(seq);
            let restored = pc2.capture_all(&mgr2);
            assert_eq!(restored.len(), 1);
            assert_eq!(&restored[0], cap);
            pc2.clear(&mut mgr2);
        }

        // Corruption: flip one payload byte -> checksum rejects, loads 0.
        let mut bytes = std::fs::read(&path).unwrap();
        let mid = bytes.len() / 2;
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let mut tier3 = ColdTier::new(64, 0);
        assert_eq!(tier3.load_snapshot(&path, &mgr2).unwrap(), 0);
        assert_eq!(tier3.stats().snapshot_rejected, 1);

        // Policy mismatch: a valid file written under int8 must not load
        // into an int4 cache.
        bytes[mid] ^= 0xff;
        std::fs::write(&path, &bytes).unwrap();
        let c = cfg(64);
        let mgr4 = KvCacheManager::new(c, QuantPolicy::uniform(Precision::Int4, c.layers, c.heads));
        let mut tier4 = ColdTier::new(64, 0);
        assert_eq!(tier4.load_snapshot(&path, &mgr4).unwrap(), 0);
        assert_eq!(tier4.stats().snapshot_rejected, 1);

        // Missing file is silent (and not a rejection).
        let _ = std::fs::remove_file(&path);
        let mut tier5 = ColdTier::new(64, 0);
        assert_eq!(tier5.load_snapshot(&path, &mgr2).unwrap(), 0);
        assert_eq!(tier5.stats().snapshot_rejected, 0);
    }

    #[test]
    fn truncated_snapshot_is_rejected_with_counter() {
        let dir = std::env::temp_dir();
        let path = dir.join(format!("kvq_snap_trunc_{}.bin", std::process::id()));
        let _ = std::fs::remove_file(&path);

        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(64, 0);
        cache_prompt(&mut pc, &mut mgr, 10, 3);
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 1);
        assert_eq!(tier.save_snapshot(&path, &mgr).unwrap(), 1);

        // Every truncation point must warn-and-skip, never panic or err.
        let bytes = std::fs::read(&path).unwrap();
        for keep in [0usize, 4, SNAP_MAGIC.len(), bytes.len() / 2, bytes.len() - 1] {
            std::fs::write(&path, &bytes[..keep]).unwrap();
            let mut t = ColdTier::new(64, 0);
            assert_eq!(t.load_snapshot(&path, &mgr).unwrap(), 0, "keep={keep}");
            assert_eq!(t.stats().snapshot_rejected, 1, "keep={keep}");
            assert_eq!(t.cold_entries(), 0);
        }
        let _ = std::fs::remove_file(&path);
    }

    #[test]
    fn undecompressable_entry_is_dropped_not_retried() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(64, 0);
        let toks = cache_prompt(&mut pc, &mut mgr, 8, 4);
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 1);

        // Truncate one stored compressed block below its header: the
        // promotion must fail typed, drop the entry, and leave the pool
        // untouched — a poisoned entry must not be retried forever.
        tier.store
            .lock()
            .unwrap()
            .entries
            .get_mut(&toks)
            .unwrap()
            .blocks[0][0][0]
            .truncate(BLOCK_HEADER - 1);
        let free_before = mgr.free_bytes();
        assert!(tier.promote(&mut mgr, &toks).is_none());
        assert!(!tier.contains(&toks), "poisoned entry must be dropped");
        assert_eq!(tier.stats().decompress_errors, 1);
        assert_eq!(mgr.free_bytes(), free_before);
        mgr.assert_refcounts_consistent();
        // Gone means gone: the retry is a plain miss.
        assert!(tier.promote(&mut mgr, &toks).is_none());
        assert_eq!(tier.stats().decompress_errors, 1);
    }

    #[test]
    fn wrong_geometry_block_is_dropped_before_restore() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(64, 0);
        let toks = cache_prompt(&mut pc, &mut mgr, 8, 6);
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 1);

        // A block that decompresses fine but to the wrong slab width (a
        // lying raw_len header) must be a typed drop, not a restore-time
        // surprise.
        let bogus = compress_block(&vec![0u8; 3], 1);
        tier.store.lock().unwrap().entries.get_mut(&toks).unwrap().blocks[0][1][0] = bogus;
        assert!(tier.promote(&mut mgr, &toks).is_none());
        assert!(!tier.contains(&toks));
        assert_eq!(tier.stats().decompress_errors, 1);
        mgr.assert_refcounts_consistent();
    }

    #[test]
    fn fault_sites_gate_demote_and_promote() {
        let _g = crate::util::fault::install(
            r#"[{"site":"tier_demote","action":"error","nth":1,"count":1},
                {"site":"tier_promote","action":"error","nth":1,"count":1}]"#,
        )
        .unwrap();
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let mut tier = ColdTier::new(64, 0);
        let toks = cache_prompt(&mut pc, &mut mgr, 8, 9);

        // First demote_for hits the injected error: nothing demoted, the
        // trie still owns the prompt.
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 0);
        assert!(pc.pinned_blocks() > 0);
        // Budget spent: the retry succeeds.
        assert_eq!(tier.demote_for(&mut pc, &mut mgr, u64::MAX), 1);

        // First promote hits the injected error: entry stays cold.
        assert!(tier.promote(&mut mgr, &toks).is_none());
        assert!(tier.contains(&toks), "failed promote must keep the entry");
        assert_eq!(tier.stats().decompress_errors, 0);
        let (seq, _) = tier.promote(&mut mgr, &toks).expect("retry promotes");
        mgr.free(seq);
        mgr.assert_refcounts_consistent();
    }

    #[test]
    fn prop_mutated_compressed_blocks_never_panic() {
        use crate::util::prop::{check, ensure};
        // Satellite of the decompress-hardening work: arbitrary byte
        // mutations and truncations of a compressed block must yield
        // either a typed error or a successful decode — never a panic or
        // an out-of-bounds slice.
        check("mutated compressed block decompress is total", 300, |g| {
            let len = g.usize_in(1..2048);
            let stride = *g.choice(&[1usize, 3, 16, 64, 257]);
            let mut data = vec![0u8; len];
            for b in data.iter_mut() {
                *b = g.rng.below(256) as u8;
            }
            if g.bool() {
                // Compressible shape: long runs survive RLE.
                let v = g.rng.below(256) as u8;
                data.fill(v);
            }
            let mut comp = compress_block(&data, stride);
            ensure(
                decompress_block(&comp).map_err(|e| e.to_string())? == data,
                "clean round trip",
            )?;
            // Mutate: flip a few bytes, maybe truncate, maybe extend.
            for _ in 0..g.usize_in(1..6) {
                let i = g.rng.below(comp.len() as u64) as usize;
                comp[i] ^= (1 + g.rng.below(255)) as u8;
            }
            match g.rng.below(3) {
                0 => comp.truncate(g.rng.below(comp.len() as u64 + 1) as usize),
                1 => {
                    let n = comp.len() + g.usize_in(1..32);
                    comp.resize(n, 0xAB);
                }
                _ => {}
            }
            // Must not panic; a decode that still succeeds must stay in
            // bounds of what the header declared.
            if let Ok(out) = decompress_block(&comp) {
                if comp.len() >= BLOCK_HEADER {
                    let raw_len =
                        u32::from_le_bytes(comp[1..5].try_into().unwrap()) as usize;
                    ensure(out.len() == raw_len, "decoded length matches header")?;
                }
            }
            Ok(())
        });
    }
}
