//! Cross-request prefix cache: a token trie over the COW block pool.
//!
//! Serving traffic repeats prompts — system preambles, few-shot headers,
//! RAG templates, retry storms — and usually repeats *prefixes* rather
//! than whole prompts. This cache stores the quantized prompt blocks of
//! recently prefilled sequences in a radix-style trie keyed at block
//! granularity: each trie edge is one block's worth of tokens, each node
//! pins that block's K/V payload (per layer, per stream) together with
//! its frozen per-block scale grids. A lookup walks the query's
//! block-aligned chunks as far as they match and adopts every matched
//! block by reference bump — zero copy, zero re-quantization, zero
//! backend compute for the shared span. Full matches also reuse the
//! stored last-position logits; partial matches hand the engine a
//! sequence covering the matched span so it runs *suffix* prefill only.
//!
//! **Bit-exactness policy.** Scales are frozen per block over that
//! block's own rows (eq. 6 applied block-wise at prefill), so a block's
//! quantized payload and grid depend only on the tokens that produced it
//! — they travel with the block. Any token-aligned shared prefix
//! therefore inherits exactly the bytes and grids the query's own
//! prefill would have produced, and the decode trajectory is
//! bit-identical to an uncached run (asserted by `tests/preemption.rs`).
//! What still cannot be shared: non-block-aligned tails. A partial tail
//! block's grid freezes over a sub-block row set that the next prompt's
//! tail generally does not reproduce, so tail blocks are reused only on
//! an exact full-prompt match (stored per node as `Tail` entries, which
//! also preserves the legacy zero-compute hit for identical prompts).
//!
//! **Budget + eviction.** The trie pins at most `capacity_blocks`
//! logical blocks (`0` disables the cache). Eviction removes leaf units
//! LRU-first — a tail, or a childless node together with its tails — so
//! hot interior prefixes survive even when their extensions rotate out.
//! Pool-pressure eviction ([`PrefixCache::evict_for`]) only removes
//! units whose blocks would actually return to the pool (refcount-1
//! holders); units fully shared with running sequences are skipped —
//! freeing them returns nothing and keeping them costs the pool nothing.

use super::manager::{KvCacheManager, SeqId};
use super::pool::BlockId;
use std::collections::HashMap;

/// One trie node: a block-aligned chunk of some cached prompt. Owns (via
/// manager pins) one block per (layer, K|V) stream plus that block's
/// frozen scale grids.
struct Node {
    /// Children keyed by the *next* block's `block_size` tokens.
    children: HashMap<Vec<i32>, Node>,
    /// Exact-prompt completions ending at this node, keyed by the
    /// (possibly empty) sub-block tail tokens.
    tails: HashMap<Vec<i32>, Tail>,
    /// Per layer: the pinned [K, V] block of this chunk. Empty for root.
    blocks: Vec<[BlockId; 2]>,
    /// Per layer: each stream's frozen `heads · head_dim` scale grid.
    scales: Vec<[Vec<f32>; 2]>,
    last_used: u64,
}

impl Node {
    fn empty() -> Node {
        Node {
            children: HashMap::new(),
            tails: HashMap::new(),
            blocks: Vec::new(),
            scales: Vec::new(),
            last_used: 0,
        }
    }
}

/// A full-prompt completion: the stored first-token logits plus, for
/// prompts that do not end on a block boundary, the pinned partial tail
/// block per stream (reusable only on an exact match — see the module
/// bit-exactness policy).
struct Tail {
    /// Per layer: the pinned [K, V] tail block. Empty when the prompt is
    /// block-aligned (the trie nodes already cover every row).
    blocks: Vec<[BlockId; 2]>,
    /// Per layer: the tail block's frozen scale grids (empty iff
    /// `blocks` is).
    scales: Vec<[Vec<f32>; 2]>,
    /// Last-position prefill logits (first-token sampling input).
    logits: Vec<f32>,
    last_used: u64,
}

/// Lookup outcome. `Full` carries everything needed to skip prefill
/// entirely; `Partial` carries a sequence covering the matched
/// block-aligned span — the caller must prefill `prompt[matched_tokens..]`
/// (at least one token: a partial hit never consumes the whole prompt,
/// so the suffix prefill always produces fresh last-position logits).
pub enum PrefixHit {
    Full { seq: SeqId, logits: Vec<f32> },
    Partial { seq: SeqId, matched_tokens: usize },
}

/// Counters for `/metrics` and the bench report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub lookups: u64,
    /// Exact full-prompt hits (zero backend compute).
    pub hits: u64,
    /// Block-aligned partial hits (suffix prefill only).
    pub partial_hits: u64,
    /// Prompt tokens served from cached blocks (full span on a full
    /// hit, matched span on a partial hit).
    pub saved_tokens: u64,
    /// Total prompt tokens presented to `lookup` (hit-rate denominator).
    pub prompt_tokens: u64,
    pub insertions: u64,
    /// Evicted cached prompts (tail entries). Interior node removals are
    /// bookkeeping, not entry evictions.
    pub evictions: u64,
}

impl PrefixStats {
    /// Fraction of looked-up prompt tokens served from the cache. Full
    /// hits count 1.0 for their prompt; partial hits count fractionally
    /// by saved-token share.
    pub fn hit_rate(&self) -> f64 {
        self.saved_tokens as f64 / (self.prompt_tokens.max(1)) as f64
    }
}

/// A complete cached prompt captured out of the trie — the cold-tier
/// demotion payload and the snapshot record. Self-contained: it carries
/// the **entire** chain of block payloads root→tail (even chunks that
/// stay hot because other prompts share them), so a later promotion
/// never depends on trie state, and the restored bytes are the exact
/// bytes the trie pinned (bit-identical by construction — the blocks
/// were never mutated while pinned).
#[derive(Debug, Clone, PartialEq)]
pub struct CapturedPrompt {
    /// The full prompt tokens (block-aligned chunks + sub-block tail).
    pub tokens: Vec<i32>,
    /// `[layer][kv]` → per-block raw payload bytes, prompt block order.
    pub payloads: Vec<[Vec<Vec<u8>>; 2]>,
    /// `[layer][kv]` → concatenated per-block frozen scale grids.
    pub scales: Vec<[Vec<f32>; 2]>,
    /// Stored last-position prefill logits (first-token sampling input).
    pub logits: Vec<f32>,
}

/// An evictable leaf unit: one tail, or one childless node together with
/// its tails.
struct Unit {
    /// Chunk keys from the root to the owning node.
    path: Vec<Vec<i32>>,
    /// `Some(tail key)` evicts just that tail; `None` evicts the node at
    /// `path` (which must be childless) and everything it holds.
    tail: Option<Vec<i32>>,
    last_used: u64,
    /// Pool blocks an eviction would return right now (refcount-1 pins).
    reclaimable: usize,
}

/// The cache. Owned by the engine next to its [`KvCacheManager`]; every
/// mutating call takes the manager so trie pins and pool refcounts move
/// together.
pub struct PrefixCache {
    /// Max logical blocks pinned; 0 disables the cache entirely.
    capacity_blocks: usize,
    /// Partial (block-aligned prefix) hits enabled. The engine turns
    /// this off for backends without chunked prefill (PJRT): they
    /// cannot run a suffix prefill, so only exact full-prompt reuse is
    /// sound there.
    allow_partial: bool,
    root: Node,
    /// Cached prompts (tail entries across the whole trie).
    entries: usize,
    /// Trie nodes excluding the root.
    nodes: usize,
    pinned: usize,
    tick: u64,
    stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(capacity_blocks: usize) -> PrefixCache {
        PrefixCache {
            capacity_blocks,
            allow_partial: true,
            root: Node::empty(),
            entries: 0,
            nodes: 0,
            pinned: 0,
            tick: 0,
            stats: PrefixStats::default(),
        }
    }

    /// Enable/disable partial hits (see the field docs).
    pub fn set_allow_partial(&mut self, on: bool) {
        self.allow_partial = on;
    }

    pub fn enabled(&self) -> bool {
        self.capacity_blocks > 0
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    /// Logical blocks currently pinned by the trie.
    pub fn pinned_blocks(&self) -> usize {
        self.pinned
    }

    /// Cached prompts (exact-completion entries).
    pub fn len(&self) -> usize {
        self.entries
    }

    pub fn is_empty(&self) -> bool {
        self.entries == 0
    }

    /// Trie nodes (block-aligned chunks) currently held, excluding the
    /// root. The `/metrics` `prefix_trie_nodes` gauge.
    pub fn trie_nodes(&self) -> usize {
        self.nodes
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    /// Look up a prompt. Walks the trie over the prompt's block-aligned
    /// chunks; on any match the cached blocks are adopted into a fresh
    /// caller-owned sequence by reference bump (never copied or
    /// re-quantized). See [`PrefixHit`] for the two hit shapes.
    pub fn lookup(&mut self, mgr: &mut KvCacheManager, prompt: &[i32]) -> Option<PrefixHit> {
        if !self.enabled() {
            return None;
        }
        self.stats.lookups += 1;
        self.stats.prompt_tokens += prompt.len() as u64;
        self.tick += 1;
        let tick = self.tick;
        let bs = mgr.config().block_size;
        let full = prompt.len() / bs;

        // Walk matched chunks, snapshotting each node's blocks + grids.
        let mut chain: Vec<(Vec<[BlockId; 2]>, Vec<[Vec<f32>; 2]>)> = Vec::new();
        let mut cur = &mut self.root;
        while chain.len() < full {
            let key = &prompt[chain.len() * bs..(chain.len() + 1) * bs];
            if !cur.children.contains_key(key) {
                break;
            }
            let next = cur.children.get_mut(key).unwrap();
            next.last_used = tick;
            chain.push((next.blocks.clone(), next.scales.clone()));
            cur = next;
        }

        // Exact completion at the deepest matched node?
        if chain.len() == full {
            if let Some(tail) = cur.tails.get_mut(&prompt[full * bs..]) {
                tail.last_used = tick;
                let logits = tail.logits.clone();
                let (tb, ts) = (tail.blocks.clone(), tail.scales.clone());
                let seq = self.adopt(mgr, &chain, Some((&tb, &ts)), prompt.len())?;
                self.stats.hits += 1;
                self.stats.saved_tokens += prompt.len() as u64;
                return Some(PrefixHit::Full { seq, logits });
            }
        }

        // Partial hit: adopt matched chunks, but always leave at least
        // one suffix token so the caller's prefill produces the
        // first-token logits (no stale-logit reuse).
        if !self.allow_partial {
            return None;
        }
        let mut adopt = chain.len();
        if adopt * bs == prompt.len() && adopt > 0 {
            adopt -= 1;
        }
        if adopt == 0 {
            return None;
        }
        let seq = self.adopt(mgr, &chain[..adopt], None, adopt * bs)?;
        self.stats.partial_hits += 1;
        self.stats.saved_tokens += (adopt * bs) as u64;
        Some(PrefixHit::Partial { seq, matched_tokens: adopt * bs })
    }

    /// Assemble per-stream tables + scale grids from a matched chain
    /// (plus an optional tail block) and adopt them as a new sequence.
    fn adopt(
        &self,
        mgr: &mut KvCacheManager,
        chain: &[(Vec<[BlockId; 2]>, Vec<[Vec<f32>; 2]>)],
        tail: Option<(&Vec<[BlockId; 2]>, &Vec<[Vec<f32>; 2]>)>,
        len: usize,
    ) -> Option<SeqId> {
        let layers = mgr.config().layers;
        let mut tables: Vec<[Vec<BlockId>; 2]> = vec![[Vec::new(), Vec::new()]; layers];
        let mut scales: Vec<[Vec<f32>; 2]> = vec![[Vec::new(), Vec::new()]; layers];
        for (blocks, grids) in chain {
            for layer in 0..layers {
                for kv in 0..2 {
                    tables[layer][kv].push(blocks[layer][kv]);
                    scales[layer][kv].extend_from_slice(&grids[layer][kv]);
                }
            }
        }
        if let Some((tb, ts)) = tail {
            for layer in 0..layers {
                for kv in 0..2 {
                    if !tb.is_empty() {
                        tables[layer][kv].push(tb[layer][kv]);
                        scales[layer][kv].extend_from_slice(&ts[layer][kv]);
                    }
                }
            }
        }
        mgr.adopt_sequence(tables, scales, len).ok()
    }

    /// Cache a freshly prefilled sequence: pins `src`'s prompt blocks
    /// into the trie (reusing any chunks already cached), evicting LRU
    /// leaf units to respect the block budget. No-ops when disabled,
    /// when the prompt is already fully cached, or when the new pins
    /// alone exceed the whole budget.
    pub fn insert(
        &mut self,
        mgr: &mut KvCacheManager,
        src: SeqId,
        prompt: &[i32],
        logits: &[f32],
    ) {
        if !self.enabled() {
            return;
        }
        let c = *mgr.config();
        let (bs, layers) = (c.block_size, c.layers);
        let full = prompt.len() / bs;
        let tail_tokens = &prompt[full * bs..];
        // Respect the budget before touching the trie; eviction can
        // remove chunks we would have reused, so recount each round.
        loop {
            let need = self.new_blocks_needed(prompt, bs, layers);
            if need == 0 {
                return; // already fully cached
            }
            if need > self.capacity_blocks {
                return; // cannot fit even an empty cache
            }
            if self.pinned + need <= self.capacity_blocks {
                break;
            }
            if !self.evict_lru(mgr) {
                return; // nothing left to evict, budget still blown
            }
        }
        self.tick += 1;
        let tick = self.tick;
        // Grab what we need from the source sequence up front (the
        // node-creation walk holds `self.root` mutably).
        let grab = |mgr: &KvCacheManager, bi: usize| -> (Vec<[BlockId; 2]>, Vec<[Vec<f32>; 2]>) {
            let hd = c.heads * c.head_dim;
            let mut blocks = Vec::with_capacity(layers);
            let mut scales = Vec::with_capacity(layers);
            for layer in 0..layers {
                let mut b2 = [0, 0];
                let mut s2 = [Vec::new(), Vec::new()];
                for kv in 0..2 {
                    b2[kv] = mgr.seq_stream_blocks(src, layer, kv).unwrap()[bi];
                    s2[kv] =
                        mgr.scales(src, layer, kv).unwrap()[bi * hd..(bi + 1) * hd].to_vec();
                }
                blocks.push(b2);
                scales.push(s2);
            }
            (blocks, scales)
        };
        let mut new_nodes = 0;
        let mut pinned_delta = 0;
        let mut inserted_tail = false;
        let mut cur = &mut self.root;
        for bi in 0..full {
            let key = prompt[bi * bs..(bi + 1) * bs].to_vec();
            if !cur.children.contains_key(&key) {
                let (blocks, scales) = grab(mgr, bi);
                for pair in &blocks {
                    mgr.pin_block(pair[0]);
                    mgr.pin_block(pair[1]);
                }
                pinned_delta += 2 * layers;
                new_nodes += 1;
                cur.children.insert(
                    key.clone(),
                    Node { blocks, scales, last_used: tick, ..Node::empty() },
                );
            }
            cur = cur.children.get_mut(&key).unwrap();
            cur.last_used = tick;
        }
        if !cur.tails.contains_key(tail_tokens) {
            let (blocks, scales) = if tail_tokens.is_empty() {
                (Vec::new(), Vec::new())
            } else {
                let t = grab(mgr, full);
                for pair in &t.0 {
                    mgr.pin_block(pair[0]);
                    mgr.pin_block(pair[1]);
                }
                pinned_delta += 2 * layers;
                t
            };
            cur.tails.insert(
                tail_tokens.to_vec(),
                Tail { blocks, scales, logits: logits.to_vec(), last_used: tick },
            );
            inserted_tail = true;
        }
        self.nodes += new_nodes;
        self.pinned += pinned_delta;
        if inserted_tail {
            self.entries += 1;
            self.stats.insertions += 1;
        }
    }

    /// Logical blocks an insert of `prompt` would newly pin (chunks and
    /// tail not already in the trie).
    fn new_blocks_needed(&self, prompt: &[i32], bs: usize, layers: usize) -> usize {
        let full = prompt.len() / bs;
        let mut cur = &self.root;
        let mut matched = 0;
        while matched < full {
            match cur.children.get(&prompt[matched * bs..(matched + 1) * bs]) {
                Some(next) => {
                    cur = next;
                    matched += 1;
                }
                None => break,
            }
        }
        let mut need = (full - matched) * 2 * layers;
        let tail_tokens = &prompt[full * bs..];
        if matched == full && cur.tails.contains_key(tail_tokens) {
            return 0; // fully cached (need == 0 by construction here)
        }
        if !tail_tokens.is_empty() {
            need += 2 * layers;
        }
        need
    }

    /// Enumerate evictable leaf units with their LRU stamps and
    /// currently-reclaimable block counts.
    fn units(&self, mgr: &KvCacheManager) -> Vec<Unit> {
        fn reclaimable(mgr: &KvCacheManager, blocks: &[[BlockId; 2]]) -> usize {
            blocks
                .iter()
                .flat_map(|p| p.iter())
                .filter(|&&b| mgr.block_refcount(b) == 1)
                .count()
        }
        fn walk(node: &Node, path: &mut Vec<Vec<i32>>, out: &mut Vec<Unit>, mgr: &KvCacheManager) {
            for (key, tail) in &node.tails {
                out.push(Unit {
                    path: path.clone(),
                    tail: Some(key.clone()),
                    last_used: tail.last_used,
                    reclaimable: reclaimable(mgr, &tail.blocks),
                });
            }
            if !path.is_empty() && node.children.is_empty() {
                let mut r = reclaimable(mgr, &node.blocks);
                for tail in node.tails.values() {
                    r += reclaimable(mgr, &tail.blocks);
                }
                out.push(Unit {
                    path: path.clone(),
                    tail: None,
                    last_used: node.last_used,
                    reclaimable: r,
                });
            }
            for (key, child) in &node.children {
                path.push(key.clone());
                walk(child, path, out, mgr);
                path.pop();
            }
        }
        let mut out = Vec::new();
        walk(&self.root, &mut Vec::new(), &mut out, mgr);
        out
    }

    /// Remove one unit, releasing its pins. Deterministic given the unit.
    fn evict_unit(&mut self, mgr: &mut KvCacheManager, unit: &Unit) {
        let mut release = |mgr: &mut KvCacheManager,
                           pinned: &mut usize,
                           blocks: &[[BlockId; 2]]| {
            for pair in blocks {
                mgr.unpin_block(pair[0]);
                mgr.unpin_block(pair[1]);
            }
            *pinned -= 2 * blocks.len();
        };
        // Navigate to the unit's parent node.
        let (last, parents) = match unit.tail {
            Some(_) => (None, unit.path.as_slice()),
            None => unit.path.split_last().map(|(l, p)| (Some(l), p)).unwrap(),
        };
        let mut cur = &mut self.root;
        for key in parents {
            cur = cur.children.get_mut(key).unwrap();
        }
        match (&unit.tail, last) {
            (Some(key), _) => {
                let tail = cur.tails.remove(key).unwrap();
                release(mgr, &mut self.pinned, &tail.blocks);
                self.entries -= 1;
                self.stats.evictions += 1;
            }
            (None, Some(key)) => {
                let node = cur.children.remove(key).unwrap();
                debug_assert!(node.children.is_empty(), "evicting a non-leaf node");
                for tail in node.tails.values() {
                    release(mgr, &mut self.pinned, &tail.blocks);
                    self.entries -= 1;
                    self.stats.evictions += 1;
                }
                release(mgr, &mut self.pinned, &node.blocks);
                self.nodes -= 1;
            }
            (None, None) => unreachable!("node unit with empty path"),
        }
    }

    /// Deterministic LRU order among units: oldest first, deepest first
    /// on ties (peel leaves before their parents), tails before their
    /// own node, then by key tokens.
    fn pick_lru<'a>(units: &'a [Unit], filter_reclaimable: bool) -> Option<&'a Unit> {
        units
            .iter()
            .filter(|u| !filter_reclaimable || u.reclaimable > 0)
            .min_by(|a, b| {
                a.last_used
                    .cmp(&b.last_used)
                    .then(b.path.len().cmp(&a.path.len()))
                    .then(b.tail.is_some().cmp(&a.tail.is_some()))
                    .then(a.path.cmp(&b.path))
                    .then(a.tail.cmp(&b.tail))
            })
    }

    /// Drop the least-recently-used leaf unit; returns false when the
    /// trie is empty. Budget-driven eviction: every pinned block counts
    /// against the logical budget, shared or not, so plain LRU order is
    /// correct here.
    pub fn evict_lru(&mut self, mgr: &mut KvCacheManager) -> bool {
        let units = self.units(mgr);
        let Some(unit) = Self::pick_lru(&units, false) else {
            return false;
        };
        let unit = Unit {
            path: unit.path.clone(),
            tail: unit.tail.clone(),
            last_used: unit.last_used,
            reclaimable: unit.reclaimable,
        };
        self.evict_unit(mgr, &unit);
        true
    }

    /// Drop the LRU leaf unit **among those whose eviction returns
    /// blocks to the pool right now** (refcount-1 pins); returns false
    /// when no unit can reclaim anything. Pool-pressure eviction must
    /// use this, not plain LRU: dropping a fully-shared unit frees
    /// nothing yet forfeits its future hits.
    pub fn evict_reclaimable_lru(&mut self, mgr: &mut KvCacheManager) -> bool {
        let units = self.units(mgr);
        let Some(unit) = Self::pick_lru(&units, true) else {
            return false;
        };
        let unit = Unit {
            path: unit.path.clone(),
            tail: unit.tail.clone(),
            last_used: unit.last_used,
            reclaimable: unit.reclaimable,
        };
        self.evict_unit(mgr, &unit);
        true
    }

    /// Evict reclaimable units (LRU-first) until at least `want_free`
    /// pool blocks are free or nothing evictable remains. The
    /// pool-pressure valve: the coordinator drains cached prefixes
    /// before preempting running requests.
    pub fn evict_for(&mut self, mgr: &mut KvCacheManager, want_free: usize) {
        while mgr.free_blocks() < want_free && self.evict_reclaimable_lru(mgr) {}
    }

    /// Byte-budget twin of [`Self::evict_for`]: evict reclaimable units
    /// until at least `want_free` usable bytes
    /// ([`KvCacheManager::free_bytes`]) are free. Under sub-pools the
    /// binding constraint is the drained width class, which block counts
    /// can't see — the engine's pressure valve uses this form.
    pub fn evict_for_bytes(&mut self, mgr: &mut KvCacheManager, want_free: u64) {
        while mgr.free_bytes() < want_free && self.evict_reclaimable_lru(mgr) {}
    }

    /// Capture one complete cached prompt: walk `path` from the root
    /// snapshotting every chunk's raw block payloads + grids, then the
    /// tail entry at `tail_key`. Read-only — pins and trie state are
    /// untouched.
    fn capture_prompt(
        &self,
        mgr: &KvCacheManager,
        path: &[Vec<i32>],
        tail_key: &[i32],
    ) -> CapturedPrompt {
        let layers = mgr.config().layers;
        let mut tokens: Vec<i32> = path.iter().flatten().copied().collect();
        tokens.extend_from_slice(tail_key);
        let mut payloads: Vec<[Vec<Vec<u8>>; 2]> = vec![[Vec::new(), Vec::new()]; layers];
        let mut scales: Vec<[Vec<f32>; 2]> = vec![[Vec::new(), Vec::new()]; layers];
        let mut grab = |blocks: &[[BlockId; 2]], grids: &[[Vec<f32>; 2]]| {
            for layer in 0..layers {
                for kv in 0..2 {
                    payloads[layer][kv].push(mgr.block_payload(blocks[layer][kv]).to_vec());
                    scales[layer][kv].extend_from_slice(&grids[layer][kv]);
                }
            }
        };
        let mut cur = &self.root;
        for key in path {
            cur = cur.children.get(key).expect("capture path diverged from trie");
            grab(&cur.blocks, &cur.scales);
        }
        let tail = cur.tails.get(tail_key).expect("capture tail missing");
        if !tail.blocks.is_empty() {
            grab(&tail.blocks, &tail.scales);
        }
        CapturedPrompt { tokens, payloads, scales, logits: tail.logits.clone() }
    }

    /// Demote the LRU reclaimable leaf unit: capture every complete
    /// prompt the unit holds (a tail unit holds one; a childless-node
    /// unit holds one per tail), then evict it. The pool effect is
    /// **identical** to [`Self::evict_reclaimable_lru`] — same unit
    /// order, same releases — so running the cold tier never changes
    /// scheduling outcomes; it only preserves what eviction would have
    /// destroyed. Returns `None` when nothing is evictable; the captured
    /// list may be empty (an interior chunk whose completions were
    /// already demoted separately). Prompts still shared with live
    /// sequences stay hot (the reclaimable filter), so a shared span is
    /// never demoted out from under a writer.
    pub fn demote_reclaimable_lru(
        &mut self,
        mgr: &mut KvCacheManager,
    ) -> Option<Vec<CapturedPrompt>> {
        let units = self.units(mgr);
        let unit = Self::pick_lru(&units, true)?;
        let unit = Unit {
            path: unit.path.clone(),
            tail: unit.tail.clone(),
            last_used: unit.last_used,
            reclaimable: unit.reclaimable,
        };
        let mut captured = Vec::new();
        match &unit.tail {
            Some(key) => captured.push(self.capture_prompt(mgr, &unit.path, key)),
            None => {
                let mut cur = &self.root;
                for key in &unit.path {
                    cur = cur.children.get(key).unwrap();
                }
                let mut keys: Vec<Vec<i32>> = cur.tails.keys().cloned().collect();
                keys.sort();
                for key in &keys {
                    captured.push(self.capture_prompt(mgr, &unit.path, key));
                }
            }
        }
        self.evict_unit(mgr, &unit);
        Some(captured)
    }

    /// Capture every complete cached prompt without touching the trie —
    /// the persistent-snapshot writer. Deterministic order (sorted by
    /// chunk path, then tail key).
    pub fn capture_all(&self, mgr: &KvCacheManager) -> Vec<CapturedPrompt> {
        type Found = Vec<(Vec<Vec<i32>>, Vec<i32>)>;
        fn collect(node: &Node, path: &mut Vec<Vec<i32>>, out: &mut Found) {
            for key in node.tails.keys() {
                out.push((path.clone(), key.clone()));
            }
            for (key, child) in &node.children {
                path.push(key.clone());
                collect(child, path, out);
                path.pop();
            }
        }
        let mut prompts = Vec::new();
        collect(&self.root, &mut Vec::new(), &mut prompts);
        prompts.sort();
        prompts
            .iter()
            .map(|(path, key)| self.capture_prompt(mgr, path, key))
            .collect()
    }

    /// Drop everything (engine shutdown / reconfiguration).
    pub fn clear(&mut self, mgr: &mut KvCacheManager) {
        while self.evict_lru(mgr) {}
        debug_assert_eq!(self.pinned, 0, "clear left pins behind");
        debug_assert_eq!(self.nodes, 0);
        debug_assert_eq!(self.entries, 0);
    }

    /// Byte twin of [`Self::evictable_blocks`]: physical bytes (class
    /// widths) an eviction sweep could return right now.
    pub fn evictable_bytes(&self, mgr: &KvCacheManager) -> u64 {
        fn walk(node: &Node, mgr: &KvCacheManager) -> u64 {
            let count = |blocks: &[[BlockId; 2]]| -> u64 {
                blocks
                    .iter()
                    .flat_map(|p| p.iter())
                    .filter(|&&b| mgr.block_refcount(b) == 1)
                    .map(|&b| mgr.block_bytes_of(b) as u64)
                    .sum()
            };
            let mut n = count(&node.blocks);
            for tail in node.tails.values() {
                n += count(&tail.blocks);
            }
            for child in node.children.values() {
                n += walk(child, mgr);
            }
            n
        }
        walk(&self.root, mgr)
    }

    /// Upper bound on pool blocks an eviction sweep could return right
    /// now: pinned blocks that are *not* shared with anyone else.
    pub fn evictable_blocks(&self, mgr: &KvCacheManager) -> usize {
        fn walk(node: &Node, mgr: &KvCacheManager) -> usize {
            let count = |blocks: &[[BlockId; 2]]| {
                blocks
                    .iter()
                    .flat_map(|p| p.iter())
                    .filter(|&&b| mgr.block_refcount(b) == 1)
                    .count()
            };
            let mut n = count(&node.blocks);
            for tail in node.tails.values() {
                n += count(&tail.blocks);
            }
            for child in node.children.values() {
                n += walk(child, mgr);
            }
            n
        }
        walk(&self.root, mgr)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::CacheConfig;
    use crate::kvcache::{Precision, QuantPolicy};

    fn cfg(num_blocks: usize) -> CacheConfig {
        CacheConfig {
            layers: 2,
            heads: 2,
            head_dim: 8,
            max_seq: 32,
            block_size: 4,
            num_blocks,
            scale_margin: 1.0,
        }
    }

    fn manager(num_blocks: usize) -> KvCacheManager {
        let c = cfg(num_blocks);
        KvCacheManager::new(c, QuantPolicy::uniform(Precision::Int8, c.layers, c.heads))
    }

    fn prefill(mgr: &mut KvCacheManager, len: usize, seed: u64) -> SeqId {
        let c = *mgr.config();
        let n = c.layers * c.heads * c.max_seq * c.head_dim;
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        rng.fill_uniform(&mut k, -1.0, 1.0);
        rng.fill_uniform(&mut v, -1.0, 1.0);
        let id = mgr.new_sequence();
        mgr.set_prefill(id, &k, &v, len).unwrap();
        id
    }

    fn full_hit(hit: PrefixHit) -> (SeqId, Vec<f32>) {
        match hit {
            PrefixHit::Full { seq, logits } => (seq, logits),
            PrefixHit::Partial { .. } => panic!("expected full hit"),
        }
    }

    #[test]
    fn disabled_cache_never_hits_or_pins() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(0);
        let src = prefill(&mut mgr, 8, 1);
        pc.insert(&mut mgr, src, &[1, 2, 3, 9, 9, 9, 9, 9], &[0.0; 4]);
        assert!(pc.lookup(&mut mgr, &[1, 2, 3, 9, 9, 9, 9, 9]).is_none());
        assert_eq!(pc.pinned_blocks(), 0);
        assert_eq!(pc.trie_nodes(), 0);
        assert_eq!(pc.stats(), PrefixStats::default());
        mgr.free(src);
    }

    #[test]
    fn hit_adopts_without_allocating() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let prompt = vec![5i32; 8];
        let src = prefill(&mut mgr, 8, 2);
        pc.insert(&mut mgr, src, &prompt, &[1.0, 2.0]);
        assert_eq!(pc.trie_nodes(), 2, "two block-aligned chunks");
        mgr.free(src); // request finished; cache keeps the blocks alive
        let used = mgr.used_blocks();
        let (fork, logits) = full_hit(pc.lookup(&mut mgr, &prompt).unwrap());
        assert_eq!(logits, vec![1.0, 2.0]);
        assert_eq!(mgr.used_blocks(), used, "hit reference-bumps, allocates nothing");
        assert_eq!(mgr.seq_len(fork), Some(8));
        assert_eq!(pc.stats().hits, 1);
        assert_eq!(pc.stats().lookups, 1);
        assert!((pc.stats().hit_rate() - 1.0).abs() < 1e-12);
        mgr.free(fork);
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks);
    }

    #[test]
    fn partial_hit_adopts_shared_blocks_only() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        // Cache a 10-token prompt: 2 full chunks + a 2-token tail.
        let mut prompt = vec![7i32; 8];
        prompt.extend([1, 2]);
        let src = prefill(&mut mgr, 10, 3);
        pc.insert(&mut mgr, src, &prompt, &[0.5]);
        assert_eq!(pc.trie_nodes(), 2);
        // 2 chunk nodes + 1 tail, each 2 layers x {K,V}.
        assert_eq!(pc.pinned_blocks(), 12);
        mgr.free(src);

        // Same first 8 tokens, different continuation: partial hit over
        // exactly the 2 shared chunks.
        let mut query = vec![7i32; 8];
        query.extend([3, 4, 5]);
        let used = mgr.used_blocks();
        match pc.lookup(&mut mgr, &query).unwrap() {
            PrefixHit::Partial { seq, matched_tokens } => {
                assert_eq!(matched_tokens, 8);
                assert_eq!(mgr.seq_len(seq), Some(8));
                assert_eq!(mgr.used_blocks(), used, "adoption allocates nothing");
                mgr.free(seq);
            }
            PrefixHit::Full { .. } => panic!("tail differs — must not be a full hit"),
        }
        let s = pc.stats();
        assert_eq!((s.hits, s.partial_hits), (0, 1));
        assert_eq!(s.saved_tokens, 8);
        assert!((s.hit_rate() - 8.0 / 11.0).abs() < 1e-12, "fractional by saved share");

        // A 4-token query shares one chunk; a 3-token one shares none.
        match pc.lookup(&mut mgr, &[7i32; 5]).unwrap() {
            PrefixHit::Partial { seq, matched_tokens } => {
                assert_eq!(matched_tokens, 4);
                mgr.free(seq);
            }
            _ => panic!("expected partial"),
        }
        assert!(pc.lookup(&mut mgr, &[7i32; 3]).is_none(), "sub-block prefix never shares");
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks);
    }

    #[test]
    fn block_aligned_partial_hit_leaves_one_suffix_token() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let src = prefill(&mut mgr, 12, 4);
        let long = vec![9i32; 12];
        pc.insert(&mut mgr, src, &long, &[0.1]);
        mgr.free(src);
        // An 8-token query matches 2 chunks exactly but was never cached
        // as a completion: the hit must hold back the last chunk so the
        // caller's suffix prefill regenerates the first-token logits.
        match pc.lookup(&mut mgr, &[9i32; 8]).unwrap() {
            PrefixHit::Partial { seq, matched_tokens } => {
                assert_eq!(matched_tokens, 4, "one chunk held back for logits");
                mgr.free(seq);
            }
            _ => panic!("expected partial"),
        }
        pc.clear(&mut mgr);
    }

    #[test]
    fn shared_chunks_are_stored_once() {
        let mut mgr = manager(128);
        let mut pc = PrefixCache::new(128);
        // Two prompts sharing their first chunk: the trie stores 3 chunk
        // nodes, not 4, and the shared chunk pins one block per stream.
        let a = prefill(&mut mgr, 8, 5);
        let mut pa = vec![1i32; 4];
        pa.extend(vec![2i32; 4]);
        pc.insert(&mut mgr, a, &pa, &[0.0]);
        let pinned_one = pc.pinned_blocks();
        let b = prefill(&mut mgr, 8, 6);
        let mut pb = vec![1i32; 4];
        pb.extend(vec![3i32; 4]);
        pc.insert(&mut mgr, b, &pb, &[0.0]);
        assert_eq!(pc.trie_nodes(), 3, "first chunk deduped");
        assert_eq!(pc.pinned_blocks(), pinned_one + 4, "only the new chunk pinned");
        mgr.free(a);
        mgr.free(b);
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks, "no leaks");
    }

    #[test]
    fn exact_match_required_for_full_hit() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let src = prefill(&mut mgr, 8, 3);
        pc.insert(&mut mgr, src, &[7i32; 8], &[0.0]);
        // Longer prompt: partial hit over the stored chunks, not full.
        match pc.lookup(&mut mgr, &[7i32; 12]).unwrap() {
            PrefixHit::Partial { seq, matched_tokens } => {
                assert_eq!(matched_tokens, 8);
                mgr.free(seq);
            }
            _ => panic!("longer prompt must not be a full hit"),
        }
        assert_eq!(pc.stats().hits, 0);
        mgr.free(src);
        pc.clear(&mut mgr);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let mut mgr = manager(128);
        // 8 tokens -> 2 chunks x 4 streams = 8 logical blocks per prompt.
        let mut pc = PrefixCache::new(16);
        let a = prefill(&mut mgr, 8, 4);
        let b = prefill(&mut mgr, 8, 5);
        let c = prefill(&mut mgr, 8, 6);
        pc.insert(&mut mgr, a, &[1i32; 8], &[0.0]);
        pc.insert(&mut mgr, b, &[2i32; 8], &[0.0]);
        assert_eq!(pc.pinned_blocks(), 16);
        // Touch entry 1 so entry 2 is LRU.
        let touch = full_hit(pc.lookup(&mut mgr, &[1i32; 8]).expect("entry 1 cached"));
        mgr.free(touch.0);
        pc.insert(&mut mgr, c, &[3i32; 8], &[0.0]);
        assert_eq!(pc.len(), 2);
        assert_eq!(pc.stats().evictions, 1, "one cached prompt dropped");
        assert!(pc.lookup(&mut mgr, &[2i32; 8]).is_none(), "LRU entry evicted");
        let again = full_hit(pc.lookup(&mut mgr, &[1i32; 8]).expect("entry 1 survived"));
        mgr.free(again.0);
        for s in [a, b, c] {
            mgr.free(s);
        }
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks, "no leaks");
    }

    #[test]
    fn oversized_entry_is_not_cached() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(4); // one 8-token prompt needs 8
        let src = prefill(&mut mgr, 8, 7);
        pc.insert(&mut mgr, src, &[9i32; 8], &[0.0]);
        assert!(pc.is_empty());
        assert_eq!(pc.pinned_blocks(), 0);
        assert_eq!(pc.stats().insertions, 0);
        mgr.free(src);
    }

    #[test]
    fn pool_pressure_eviction_skips_fully_shared_entries() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(32);
        // Entry A (older) stays shared with a live sequence; entry B
        // (newer) is the only holder of its blocks.
        let a = prefill(&mut mgr, 8, 11);
        pc.insert(&mut mgr, a, &[1i32; 8], &[0.0]); // a keeps its blocks alive
        let b = prefill(&mut mgr, 8, 12);
        pc.insert(&mut mgr, b, &[2i32; 8], &[0.0]);
        mgr.free(b); // only the cache holds B's blocks now
        let free_before = mgr.free_blocks();
        pc.evict_for(&mut mgr, free_before + 8);
        assert_eq!(mgr.free_blocks(), free_before + 8, "B's blocks reclaimed");
        assert!(
            pc.lookup(&mut mgr, &[2i32; 8]).is_none(),
            "reclaimable entry B evicted"
        );
        let hit = full_hit(pc.lookup(&mut mgr, &[1i32; 8]).expect("shared entry A survives"));
        mgr.free(hit.0);
        mgr.free(a);
        pc.clear(&mut mgr);
    }

    #[test]
    fn evict_for_frees_pool_pressure() {
        let mut mgr = manager(16);
        let mut pc = PrefixCache::new(16);
        let src = prefill(&mut mgr, 8, 8); // 8 blocks
        pc.insert(&mut mgr, src, &[4i32; 8], &[0.0]);
        mgr.free(src); // only the cache holds them now
        assert_eq!(mgr.free_blocks(), 8);
        assert_eq!(pc.evictable_blocks(&mgr), 8);
        pc.evict_for(&mut mgr, 12);
        assert!(mgr.free_blocks() >= 12);
        assert!(pc.is_empty());
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), 16);
    }

    #[test]
    fn interior_nodes_survive_leaf_eviction() {
        let mut mgr = manager(128);
        let mut pc = PrefixCache::new(128);
        // Shared 4-token system prefix with two 8-token completions.
        let a = prefill(&mut mgr, 8, 13);
        let mut pa = vec![5i32; 4];
        pa.extend(vec![6i32; 4]);
        pc.insert(&mut mgr, a, &pa, &[0.0]);
        let b = prefill(&mut mgr, 8, 14);
        let mut pb = vec![5i32; 4];
        pb.extend(vec![7i32; 4]);
        pc.insert(&mut mgr, b, &pb, &[0.0]);
        mgr.free(a);
        mgr.free(b);
        // Touch prompt A so B's leaf is LRU, then evict one unit: the
        // interior (shared) chunk must survive for A's next hit.
        let t = full_hit(pc.lookup(&mut mgr, &pa).unwrap());
        mgr.free(t.0);
        assert!(pc.evict_lru(&mut mgr));
        // B's completion is gone (its tail went with the leaf unit), A
        // still fully hits through the shared interior chunk.
        assert_eq!(pc.stats().evictions, 1);
        let t = full_hit(pc.lookup(&mut mgr, &pa).expect("A survives"));
        mgr.free(t.0);
        match pc.lookup(&mut mgr, &pb) {
            None => {}
            Some(PrefixHit::Partial { seq, matched_tokens }) => {
                assert_eq!(matched_tokens, 4, "only the shared interior chunk remains");
                mgr.free(seq);
            }
            Some(PrefixHit::Full { .. }) => panic!("B's completion was evicted"),
        }
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks);
    }

    #[test]
    fn misaligned_tail_reused_only_on_exact_match() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let src = prefill(&mut mgr, 6, 15); // 1 full chunk + 2-token tail
        let prompt = vec![8i32; 6];
        pc.insert(&mut mgr, src, &prompt, &[0.3]);
        mgr.free(src);
        // Exact prompt: full hit including the tail block.
        let (seq, logits) = full_hit(pc.lookup(&mut mgr, &prompt).unwrap());
        assert_eq!(logits, vec![0.3]);
        assert_eq!(mgr.seq_len(seq), Some(6));
        mgr.free(seq);
        // Same 6 leading tokens, longer prompt: the sub-block tail must
        // NOT be reused — only the aligned chunk shares.
        match pc.lookup(&mut mgr, &[8i32; 9]).unwrap() {
            PrefixHit::Partial { seq, matched_tokens } => {
                assert_eq!(matched_tokens, 4);
                mgr.free(seq);
            }
            _ => panic!("expected partial over the aligned chunk only"),
        }
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks);
    }

    #[test]
    fn demote_captures_the_full_prompt_and_matches_plain_eviction() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let src = prefill(&mut mgr, 10, 41); // 2 chunks + 2-token tail
        let mut prompt = vec![3i32; 8];
        prompt.extend([7, 9]);
        pc.insert(&mut mgr, src, &prompt, &[0.25, 0.75]);
        mgr.free(src); // cache is now the only holder
        let free_before = mgr.free_blocks();

        // LRU reclaimable unit is the tail entry: the capture must carry
        // the WHOLE prompt (both interior chunks + the tail block), while
        // the eviction releases only the tail unit's pins — the same pool
        // effect evict_reclaimable_lru would have had.
        let captured = pc.demote_reclaimable_lru(&mut mgr).expect("something evictable");
        assert_eq!(captured.len(), 1);
        let cap = &captured[0];
        assert_eq!(cap.tokens, prompt);
        assert_eq!(cap.logits, vec![0.25, 0.75]);
        let layers = mgr.config().layers;
        let hd = mgr.config().heads * mgr.config().head_dim;
        for layer in 0..layers {
            for kv in 0..2 {
                assert_eq!(cap.payloads[layer][kv].len(), 3, "2 chunks + tail");
                assert_eq!(cap.scales[layer][kv].len(), 3 * hd, "one grid per block");
                for p in &cap.payloads[layer][kv] {
                    assert_eq!(p.len(), mgr.stream_layout(layer, kv).padded_block_bytes());
                }
            }
        }
        // Only the tail unit's blocks were released (1 block per stream).
        assert_eq!(mgr.free_blocks(), free_before + 2 * layers);
        assert_eq!(pc.len(), 0, "the completion left the hot trie");
        assert!(pc.trie_nodes() > 0, "interior chunks stay for other extensions");

        // Draining the rest captures nothing new (no completions remain).
        while let Some(more) = pc.demote_reclaimable_lru(&mut mgr) {
            assert!(more.is_empty(), "interior chunks carry no completions");
        }
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks);
        pc.clear(&mut mgr);
    }

    #[test]
    fn demote_skips_blocks_shared_with_live_sequences() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let src = prefill(&mut mgr, 8, 42);
        pc.insert(&mut mgr, src, &[5i32; 8], &[0.0]);
        // `src` still lives: every pinned block is shared → nothing may
        // demote (a demotion would otherwise race the live writer).
        assert!(pc.demote_reclaimable_lru(&mut mgr).is_none());
        mgr.free(src);
        assert!(pc.demote_reclaimable_lru(&mut mgr).is_some());
        pc.clear(&mut mgr);
    }

    #[test]
    fn capture_all_is_nondestructive_and_complete() {
        let mut mgr = manager(128);
        let mut pc = PrefixCache::new(128);
        let a = prefill(&mut mgr, 8, 43);
        let mut pa = vec![1i32; 4];
        pa.extend(vec![2i32; 4]);
        pc.insert(&mut mgr, a, &pa, &[0.1]);
        let b = prefill(&mut mgr, 6, 44);
        let pb = vec![1i32; 6]; // shares the first chunk, sub-block tail
        pc.insert(&mut mgr, b, &pb, &[0.2]);
        mgr.free(a);
        mgr.free(b);

        let pinned = pc.pinned_blocks();
        let caps = pc.capture_all(&mgr);
        assert_eq!(caps.len(), 2);
        let mut tokens: Vec<&Vec<i32>> = caps.iter().map(|c| &c.tokens).collect();
        tokens.sort();
        assert_eq!(tokens, vec![&pb, &pa]);
        assert_eq!(pc.pinned_blocks(), pinned, "capture_all leaves the trie untouched");
        assert_eq!(pc.len(), 2);
        // Both captures carry the shared first chunk's bytes — each
        // record restores independently.
        for c in &caps {
            let nblocks = c.tokens.len().div_ceil(mgr.config().block_size);
            assert_eq!(c.payloads[0][0].len(), nblocks);
        }
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks);
    }

    #[test]
    fn evict_for_bytes_frees_byte_pressure() {
        let mut mgr = manager(16);
        let mut pc = PrefixCache::new(16);
        let src = prefill(&mut mgr, 8, 45); // 8 of 16 blocks
        pc.insert(&mut mgr, src, &[4i32; 8], &[0.0]);
        mgr.free(src);
        let bb = mgr.span_bytes() as u64 / (2 * mgr.config().layers as u64); // 64 B
        assert_eq!(pc.evictable_bytes(&mgr), 8 * bb);
        assert_eq!(mgr.free_bytes(), 8 * bb);
        pc.evict_for_bytes(&mut mgr, 12 * bb);
        assert!(mgr.free_bytes() >= 12 * bb);
        assert!(pc.is_empty());
        pc.clear(&mut mgr);
    }
}
