//! Cross-request prefix cache over the COW block pool.
//!
//! Serving traffic repeats prompts — system preambles, few-shot headers,
//! retry storms. This cache keeps the quantized prompt blocks of recently
//! prefilled sequences alive (as cache-owned forks inside the
//! [`KvCacheManager`]) so an identical prompt is admitted by
//! reference-bumping those blocks instead of re-running prefill and
//! re-quantizing: the hit path is a [`KvCacheManager::fork`] plus a clone
//! of the stored last-position logits (for first-token sampling), zero
//! backend compute.
//!
//! **Bit-exactness policy.** Matching is at block granularity over prompt
//! tokens, but a *usable* hit requires the stored prompt to equal the
//! query prompt exactly. INT8 scales are frozen per sequence over its
//! whole prompt (eq. 6 applied at prefill), so a partial-prefix reuse
//! would inherit scales frozen over a *different* token set and the
//! decode trajectory could diverge from an uncontended run. Exact-match
//! sharing inherits exactly the scales the query's own prefill would have
//! frozen — shared blocks, scales, and therefore generated tokens are
//! bit-identical to the unshared baseline (asserted by
//! `tests/preemption.rs`). Partial-prefix reuse stays future work gated
//! on per-block scale storage.
//!
//! **Budget + eviction.** The cache pins at most `capacity_blocks`
//! logical blocks (`0` disables it, the default). Insertion and the
//! coordinator's pool-pressure path evict LRU entries; freeing an entry
//! releases its fork, which returns only last-holder blocks to the pool —
//! entries whose blocks are still shared with running sequences cost
//! nothing extra to keep and nothing to drop.

use super::manager::{KvCacheManager, SeqId};
use std::collections::HashMap;

/// One cached prompt: a manager-owned fork of the sequence that prefilled
/// it, plus everything needed to skip that prefill next time.
struct Entry {
    /// Cache-owned sequence holding the prompt blocks alive.
    seq: SeqId,
    /// Last-position prefill logits (first-token sampling input).
    logits: Vec<f32>,
    /// Logical blocks this entry pins (budget accounting).
    blocks: usize,
    /// LRU tick of the last hit/insert.
    last_used: u64,
}

/// Counters for `/metrics` and the bench report.
#[derive(Debug, Clone, Copy, Default, PartialEq, Eq)]
pub struct PrefixStats {
    pub lookups: u64,
    pub hits: u64,
    pub insertions: u64,
    pub evictions: u64,
}

impl PrefixStats {
    pub fn hit_rate(&self) -> f64 {
        self.hits as f64 / (self.lookups.max(1)) as f64
    }
}

/// The cache. Owned by the engine next to its [`KvCacheManager`]; every
/// mutating call takes the manager so entry lifetimes and pool refcounts
/// move together.
pub struct PrefixCache {
    /// Max logical blocks pinned; 0 disables the cache entirely.
    capacity_blocks: usize,
    entries: HashMap<Vec<i32>, Entry>,
    pinned: usize,
    tick: u64,
    stats: PrefixStats,
}

impl PrefixCache {
    pub fn new(capacity_blocks: usize) -> PrefixCache {
        PrefixCache {
            capacity_blocks,
            entries: HashMap::new(),
            pinned: 0,
            tick: 0,
            stats: PrefixStats::default(),
        }
    }

    pub fn enabled(&self) -> bool {
        self.capacity_blocks > 0
    }

    pub fn capacity_blocks(&self) -> usize {
        self.capacity_blocks
    }

    /// Logical blocks currently pinned by cache entries.
    pub fn pinned_blocks(&self) -> usize {
        self.pinned
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn stats(&self) -> PrefixStats {
        self.stats
    }

    /// Look up a prompt. On a hit, returns a **fresh fork** of the cached
    /// sequence (caller owns it) and the stored first-token logits; the
    /// shared prompt blocks are reference-bumped, never copied or
    /// re-quantized.
    pub fn lookup(
        &mut self,
        mgr: &mut KvCacheManager,
        prompt: &[i32],
    ) -> Option<(SeqId, Vec<f32>)> {
        if !self.enabled() {
            return None;
        }
        self.stats.lookups += 1;
        self.tick += 1;
        let entry = self.entries.get_mut(prompt)?;
        let fork = match mgr.fork(entry.seq) {
            Ok(id) => id,
            Err(_) => return None, // cached seq vanished — treat as miss
        };
        entry.last_used = self.tick;
        self.stats.hits += 1;
        Some((fork, entry.logits.clone()))
    }

    /// Cache a freshly prefilled sequence: forks `src` (the live request's
    /// sequence) into a cache-owned sequence, evicting LRU entries to
    /// respect the block budget. No-ops when disabled, when the prompt is
    /// already cached, or when the entry alone exceeds the whole budget.
    pub fn insert(
        &mut self,
        mgr: &mut KvCacheManager,
        src: SeqId,
        prompt: &[i32],
        logits: &[f32],
    ) {
        if !self.enabled() || self.entries.contains_key(prompt) {
            return;
        }
        let blocks = mgr.config().blocks_for_tokens(prompt.len());
        if blocks > self.capacity_blocks {
            return;
        }
        while self.pinned + blocks > self.capacity_blocks {
            if !self.evict_lru(mgr) {
                return; // nothing left to evict, budget still blown
            }
        }
        let Ok(seq) = mgr.fork(src) else { return };
        self.tick += 1;
        self.pinned += blocks;
        self.stats.insertions += 1;
        self.entries.insert(
            prompt.to_vec(),
            Entry { seq, logits: logits.to_vec(), blocks, last_used: self.tick },
        );
    }

    /// Remove one entry and release its fork.
    fn evict_entry(&mut self, key: &[i32], mgr: &mut KvCacheManager) {
        let entry = self.entries.remove(key).unwrap();
        self.pinned -= entry.blocks;
        self.stats.evictions += 1;
        mgr.free(entry.seq);
    }

    /// Drop the least-recently-used entry; returns false when empty.
    /// Budget-driven eviction: every entry counts against the logical
    /// pin budget, shared or not, so plain LRU order is correct here.
    pub fn evict_lru(&mut self, mgr: &mut KvCacheManager) -> bool {
        let Some(key) = self
            .entries
            .iter()
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| k.clone())
        else {
            return false;
        };
        self.evict_entry(&key, mgr);
        true
    }

    /// Drop the LRU entry **among those whose eviction returns blocks to
    /// the pool right now** (refcount-1 holders); returns false when no
    /// entry can reclaim anything. Pool-pressure eviction must use this,
    /// not plain LRU: dropping a fully-shared entry frees nothing yet
    /// forfeits its future hits.
    pub fn evict_reclaimable_lru(&mut self, mgr: &mut KvCacheManager) -> bool {
        let Some(key) = self
            .entries
            .iter()
            .filter(|(_, e)| mgr.seq_reclaimable_blocks(e.seq) > 0)
            .min_by_key(|(_, e)| e.last_used)
            .map(|(k, _)| k.clone())
        else {
            return false;
        };
        self.evict_entry(&key, mgr);
        true
    }

    /// Evict reclaimable entries (LRU-first) until at least `want_free`
    /// pool blocks are free or nothing evictable remains. The
    /// pool-pressure valve: the coordinator drains cached prefixes before
    /// preempting running requests. Entries fully shared with live
    /// sequences are skipped — freeing them returns nothing and keeping
    /// them costs the pool nothing.
    pub fn evict_for(&mut self, mgr: &mut KvCacheManager, want_free: usize) {
        while mgr.free_blocks() < want_free && self.evict_reclaimable_lru(mgr) {}
    }

    /// Drop everything (engine shutdown / reconfiguration).
    pub fn clear(&mut self, mgr: &mut KvCacheManager) {
        while self.evict_lru(mgr) {}
    }

    /// Upper bound on pool blocks an eviction sweep could return right
    /// now: the pinned blocks that are *not* shared with anyone else.
    pub fn evictable_blocks(&self, mgr: &KvCacheManager) -> usize {
        self.entries.values().map(|e| mgr.seq_reclaimable_blocks(e.seq)).sum()
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::CacheConfig;
    use crate::kvcache::{Precision, QuantPolicy};

    fn cfg(num_blocks: usize) -> CacheConfig {
        CacheConfig {
            layers: 2,
            heads: 2,
            head_dim: 8,
            max_seq: 32,
            block_size: 4,
            num_blocks,
            scale_margin: 1.0,
        }
    }

    fn manager(num_blocks: usize) -> KvCacheManager {
        let c = cfg(num_blocks);
        KvCacheManager::new(c, QuantPolicy::uniform(Precision::Int8, c.layers, c.heads))
    }

    fn prefill(mgr: &mut KvCacheManager, len: usize, seed: u64) -> SeqId {
        let c = *mgr.config();
        let n = c.layers * c.heads * c.max_seq * c.head_dim;
        let mut rng = crate::util::rng::Rng::new(seed);
        let mut k = vec![0.0f32; n];
        let mut v = vec![0.0f32; n];
        rng.fill_uniform(&mut k, -1.0, 1.0);
        rng.fill_uniform(&mut v, -1.0, 1.0);
        let id = mgr.new_sequence();
        mgr.set_prefill(id, &k, &v, len).unwrap();
        id
    }

    #[test]
    fn disabled_cache_never_hits_or_pins() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(0);
        let src = prefill(&mut mgr, 8, 1);
        pc.insert(&mut mgr, src, &[1, 2, 3], &[0.0; 4]);
        assert!(pc.lookup(&mut mgr, &[1, 2, 3]).is_none());
        assert_eq!(pc.pinned_blocks(), 0);
        assert_eq!(pc.stats(), PrefixStats::default());
        mgr.free(src);
    }

    #[test]
    fn hit_forks_without_allocating() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let prompt = vec![5i32; 8];
        let src = prefill(&mut mgr, 8, 2);
        pc.insert(&mut mgr, src, &prompt, &[1.0, 2.0]);
        mgr.free(src); // request finished; cache keeps the blocks alive
        let used = mgr.used_blocks();
        let (fork, logits) = pc.lookup(&mut mgr, &prompt).unwrap();
        assert_eq!(logits, vec![1.0, 2.0]);
        assert_eq!(mgr.used_blocks(), used, "hit reference-bumps, allocates nothing");
        assert_eq!(mgr.seq_len(fork), Some(8));
        assert_eq!(pc.stats().hits, 1);
        assert_eq!(pc.stats().lookups, 1);
        assert!((pc.stats().hit_rate() - 1.0).abs() < 1e-12);
        mgr.free(fork);
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks);
    }

    #[test]
    fn exact_match_only() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(64);
        let src = prefill(&mut mgr, 8, 3);
        pc.insert(&mut mgr, src, &[7i32; 8], &[0.0]);
        // Same leading blocks, longer prompt: not bit-exact to reuse.
        assert!(pc.lookup(&mut mgr, &[7i32; 12]).is_none());
        assert!(pc.lookup(&mut mgr, &[7i32; 4]).is_none());
        assert_eq!(pc.stats().hits, 0);
        assert_eq!(pc.stats().lookups, 2);
        mgr.free(src);
        pc.clear(&mut mgr);
    }

    #[test]
    fn lru_eviction_respects_budget() {
        let mut mgr = manager(128);
        // 8 tokens -> 2 blocks x 4 streams = 8 logical blocks per entry.
        let mut pc = PrefixCache::new(16);
        let a = prefill(&mut mgr, 8, 4);
        let b = prefill(&mut mgr, 8, 5);
        let c = prefill(&mut mgr, 8, 6);
        pc.insert(&mut mgr, a, &[1i32; 8], &[0.0]);
        pc.insert(&mut mgr, b, &[2i32; 8], &[0.0]);
        assert_eq!(pc.pinned_blocks(), 16);
        // Touch entry 1 so entry 2 is LRU.
        let touch = pc.lookup(&mut mgr, &[1i32; 8]).expect("entry 1 cached");
        mgr.free(touch.0);
        pc.insert(&mut mgr, c, &[3i32; 8], &[0.0]);
        assert_eq!(pc.len(), 2);
        assert_eq!(pc.stats().evictions, 1);
        assert!(pc.lookup(&mut mgr, &[2i32; 8]).is_none(), "LRU entry evicted");
        let again = pc.lookup(&mut mgr, &[1i32; 8]).expect("entry 1 survived");
        mgr.free(again.0);
        for s in [a, b, c] {
            mgr.free(s);
        }
        pc.clear(&mut mgr);
        assert_eq!(mgr.free_blocks(), mgr.config().num_blocks, "no leaks");
    }

    #[test]
    fn oversized_entry_is_not_cached() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(4); // one 8-token entry needs 8
        let src = prefill(&mut mgr, 8, 7);
        pc.insert(&mut mgr, src, &[9i32; 8], &[0.0]);
        assert!(pc.is_empty());
        assert_eq!(pc.stats().insertions, 0);
        mgr.free(src);
    }

    #[test]
    fn pool_pressure_eviction_skips_fully_shared_entries() {
        let mut mgr = manager(64);
        let mut pc = PrefixCache::new(32);
        // Entry A (older) stays shared with a live sequence; entry B
        // (newer) is the only holder of its blocks.
        let a = prefill(&mut mgr, 8, 11);
        pc.insert(&mut mgr, a, &[1i32; 8], &[0.0]); // a keeps its fork alive
        let b = prefill(&mut mgr, 8, 12);
        pc.insert(&mut mgr, b, &[2i32; 8], &[0.0]);
        mgr.free(b); // only the cache holds B's blocks now
        let free_before = mgr.free_blocks();
        pc.evict_for(&mut mgr, free_before + 8);
        assert_eq!(mgr.free_blocks(), free_before + 8, "B's blocks reclaimed");
        assert!(
            pc.lookup(&mut mgr, &[2i32; 8]).is_none(),
            "reclaimable entry B evicted"
        );
        let hit = pc.lookup(&mut mgr, &[1i32; 8]).expect("shared entry A survives");
        mgr.free(hit.0);
        mgr.free(a);
        pc.clear(&mut mgr);
    }

    #[test]
    fn evict_for_frees_pool_pressure() {
        let mut mgr = manager(16);
        let mut pc = PrefixCache::new(16);
        let src = prefill(&mut mgr, 8, 8); // 8 blocks
        pc.insert(&mut mgr, src, &[4i32; 8], &[0.0]);
        mgr.free(src); // only the cache holds them now
        assert_eq!(mgr.free_blocks(), 8);
        assert_eq!(pc.evictable_blocks(&mgr), 8);
        pc.evict_for(&mut mgr, 12);
        assert!(mgr.free_blocks() >= 12);
        assert!(pc.is_empty());
    }
}
