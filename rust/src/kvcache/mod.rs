//! Paged KV cache with first-class INT8 (and INT4) pages.
//!
//! The paper's technique — per-channel INT8 quantization of cached K/V —
//! embedded in a vLLM-style paged allocator:
//!
//! * [`pool`]: a preallocated slab of fixed-size blocks with a free list
//!   and reference counts (refcounts enable prefix sharing / fork).
//! * [`table`]: per-sequence block tables mapping token positions to
//!   blocks, one table per (layer, K|V) stream.
//! * [`manager`]: the engine-facing API — create/fork/free sequences
//!   (mid-flight free powers preemption), quantize-and-append K/V rows
//!   (frozen prefill scales, clamped; appends are atomic and retryable
//!   after reclaim), zero-copy [`manager::CacheView`]s for block-native
//!   fused decode, gather a sequence's stream into the contiguous
//!   staging layout the decode artifact consumes, refcount-aware free
//!   accounting for admission and preemption planning.
//! * [`prefix`]: the cross-request prefix cache — exact-prompt entries
//!   fork their cached sequence so repeated prompts skip prefill and
//!   re-quantization entirely (bit-identical shared blocks).
//! * [`policy`]: quantization policies — `(layer, head, K|V side) →
//!   Precision` maps (uniform presets, `k8v4`, `sink8`, JSON per-layer
//!   tables) resolved into per-stream [`policy::StreamLayout`]s.
//! * [`tier`]: the compressed cold tier — LRU-cold prefix entries demote
//!   out of the hot pool into a byte-shuffle + RLE compressed in-memory
//!   store (async prefetch, bit-identical promotion) with versioned,
//!   checksummed on-disk snapshots that persist the warmed corpus across
//!   restarts.
//! * [`memory_model`]: the closed-form Table-1 calculator (policy-aware).
//!
//! Storage precision is a [`QuantPolicy`] (the legacy single
//! [`Precision`] knob is the `uniform:*` preset family); every policy
//! runs through identical code paths — the manager and decode kernels
//! dispatch per stream through [`crate::quant::Codec`] — so the serving
//! benches compare configurations apples-to-apples.

pub mod manager;
pub mod memory_model;
pub mod policy;
pub mod pool;
pub mod prefix;
pub mod table;
pub mod tier;

pub use manager::{CacheView, KvCacheManager, SequenceCache, StreamView, WaveGroup, WaveView};
pub use memory_model::{MemoryModel, PolicyMemory};
pub use policy::{PolicySpec, PolicyTable, QuantPolicy, StagedKind};
pub use pool::{BlockId, BlockPool};
pub use prefix::{CapturedPrompt, PrefixCache, PrefixHit, PrefixStats};
pub use tier::{ColdTier, TierStats};

/// Storage precision of cache pages.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum Precision {
    Fp32,
    Int8,
    Int4,
}

impl Precision {
    /// Payload bytes for `n` elements of one contiguous run (a flat
    /// buffer or a single packed row). **Do not use this for multi-row
    /// slabs**: INT4 pads every row to a whole byte, so slab accounting
    /// must go per-row through [`Precision::bytes_for_rows`] /
    /// [`Precision::bytes_per_row`] — flattening first undercounts odd
    /// rows.
    pub fn bytes_for(self, n: usize) -> usize {
        match self {
            Precision::Fp32 => n * 4,
            Precision::Int8 => n,
            Precision::Int4 => n.div_ceil(2),
        }
    }

    /// Payload bytes of one `d`-channel row (INT4 rows pad to
    /// `ceil(d/2)` bytes). Delegates to the codec, the layout's single
    /// source of truth.
    pub fn bytes_per_row(self, d: usize) -> usize {
        policy::codec_for(self).bytes_per_row(d)
    }

    /// Payload bytes of `rows` rows of `d` channels, accounted per-row.
    /// For INT4 at odd `d` this exceeds `bytes_for(rows * d)` — each row
    /// carries its own padding nibble.
    pub fn bytes_for_rows(self, rows: usize, d: usize) -> usize {
        rows * self.bytes_per_row(d)
    }

    pub fn name(self) -> &'static str {
        match self {
            Precision::Fp32 => "fp32",
            Precision::Int8 => "int8",
            Precision::Int4 => "int4",
        }
    }

    pub fn parse(s: &str) -> Option<Precision> {
        Some(match s {
            "fp32" | "f32" => Precision::Fp32,
            "int8" | "i8" => Precision::Int8,
            "int4" | "i4" => Precision::Int4,
            _ => return None,
        })
    }

    /// Compression vs FP32 payload (4x / 8x — §5.1, §8.1).
    pub fn compression(self) -> f64 {
        4.0 / (self.bytes_for(1024) as f64 / 1024.0)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn precision_sizes() {
        assert_eq!(Precision::Fp32.bytes_for(10), 40);
        assert_eq!(Precision::Int8.bytes_for(10), 10);
        assert_eq!(Precision::Int4.bytes_for(10), 5);
        assert_eq!(Precision::Int4.bytes_for(11), 6);
    }

    #[test]
    fn int4_row_accounting_pads_each_odd_row() {
        // Regression: 3 rows of 7 channels are 3 x ceil(7/2) = 12 packed
        // bytes in storage — flattening to bytes_for(21) = 11 undercounts
        // the per-row padding nibble. Per-row accounting must be used for
        // every slab-shaped byte count (MemoryModel, cache_bytes_read).
        assert_eq!(Precision::Int4.bytes_per_row(7), 4);
        assert_eq!(Precision::Int4.bytes_for_rows(3, 7), 12);
        assert_eq!(Precision::Int4.bytes_for(3 * 7), 11, "flat count is smaller");
        // Even rows agree with the flat count.
        assert_eq!(Precision::Int4.bytes_for_rows(3, 8), Precision::Int4.bytes_for(24));
        assert_eq!(Precision::Fp32.bytes_for_rows(3, 7), 84);
        assert_eq!(Precision::Int8.bytes_for_rows(3, 7), 21);
    }

    #[test]
    fn precision_compression() {
        assert_eq!(Precision::Fp32.compression(), 1.0);
        assert_eq!(Precision::Int8.compression(), 4.0);
        assert_eq!(Precision::Int4.compression(), 8.0);
    }

    #[test]
    fn precision_parse() {
        assert_eq!(Precision::parse("int8"), Some(Precision::Int8));
        assert_eq!(Precision::parse("nope"), None);
    }
}
