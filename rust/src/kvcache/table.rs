//! Per-sequence block tables: position → block mapping for one
//! (layer, K|V) stream.

use super::pool::BlockId;

/// Ordered list of blocks backing one stream of one sequence.
#[derive(Debug, Clone, Default)]
pub struct BlockTable {
    blocks: Vec<BlockId>,
}

impl BlockTable {
    pub fn new() -> BlockTable {
        BlockTable::default()
    }

    pub fn push(&mut self, id: BlockId) {
        self.blocks.push(id);
    }

    pub fn blocks(&self) -> &[BlockId] {
        &self.blocks
    }

    pub fn len(&self) -> usize {
        self.blocks.len()
    }

    pub fn is_empty(&self) -> bool {
        self.blocks.is_empty()
    }

    /// Block + in-block row for a token position.
    pub fn locate(&self, pos: usize, block_size: usize) -> (BlockId, usize) {
        let b = pos / block_size;
        assert!(
            b < self.blocks.len(),
            "position {pos} beyond table ({} blocks)",
            self.blocks.len()
        );
        (self.blocks[b], pos % block_size)
    }

    /// Number of blocks needed to hold `len` tokens.
    pub fn blocks_for(len: usize, block_size: usize) -> usize {
        len.div_ceil(block_size)
    }

    /// Replace a block id (after copy-on-write).
    pub fn replace(&mut self, idx: usize, id: BlockId) {
        self.blocks[idx] = id;
    }

    pub fn drain(&mut self) -> Vec<BlockId> {
        std::mem::take(&mut self.blocks)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn locate_maps_positions() {
        let mut t = BlockTable::new();
        t.push(7);
        t.push(3);
        assert_eq!(t.locate(0, 4), (7, 0));
        assert_eq!(t.locate(3, 4), (7, 3));
        assert_eq!(t.locate(4, 4), (3, 0));
        assert_eq!(t.locate(6, 4), (3, 2));
    }

    #[test]
    #[should_panic(expected = "beyond table")]
    fn locate_past_end_panics() {
        let t = BlockTable::new();
        t.locate(0, 4);
    }

    #[test]
    fn blocks_for_rounds_up() {
        assert_eq!(BlockTable::blocks_for(0, 16), 0);
        assert_eq!(BlockTable::blocks_for(1, 16), 1);
        assert_eq!(BlockTable::blocks_for(16, 16), 1);
        assert_eq!(BlockTable::blocks_for(17, 16), 2);
    }

    #[test]
    fn drain_empties() {
        let mut t = BlockTable::new();
        t.push(1);
        assert_eq!(t.drain(), vec![1]);
        assert!(t.is_empty());
    }
}
