//! Block pool: preallocated slabs of fixed-size pages with free lists
//! and reference counts, split into per-width **sub-pools**.
//!
//! One pool backs every sequence's K and V streams across all layers.
//! A block holds `block_size` token rows of one (layer, K|V) stream; the
//! *byte* layout of those rows is owned by the stream's
//! [`crate::kvcache::policy::StreamLayout`] (head-major slabs whose row
//! width comes from each head's [`crate::quant::Codec`]). The pool itself
//! is precision-agnostic: it deals in raw bytes.
//!
//! Mixed policies produce streams of different block widths (an INT4
//! value stream's block is half an INT8 key stream's). Padding every
//! block to the widest stream would forfeit most of the quantization
//! win, so the pool is segmented into **width classes**: each class is
//! its own slab + free list + refcounts, sized for exactly one block
//! width. A [`BlockId`] encodes `(class, slot)` so everything downstream
//! (tables, COW refcounts, the prefix trie) keeps treating blocks as
//! opaque `u32` handles. Uniform policies collapse to a single class and
//! behave bit-for-bit like the old flat pool.
//!
//! Refcounts implement copy-on-write prefix sharing: `fork` bumps counts;
//! writers call `ensure_unique` (copy-on-write) before mutating — the
//! copy always lands in the source block's own class.

use anyhow::{bail, Result};

/// Handle of a block in the pool: `class << CLASS_SHIFT | slot`.
pub type BlockId = u32;

/// Bits reserved for the slot index within a class (16M blocks/class,
/// 256 classes — far beyond any real pool).
const CLASS_SHIFT: u32 = 24;
const SLOT_MASK: u32 = (1 << CLASS_SHIFT) - 1;

/// Geometry of one block (rows × heads × channels; bytes come from the
/// per-stream codecs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockShape {
    pub block_size: usize,
    pub heads: usize,
    pub head_dim: usize,
}

impl BlockShape {
    pub fn elements(&self) -> usize {
        self.block_size * self.heads * self.head_dim
    }
}

/// One width class: a slab of equally sized pages with its own free
/// list and refcounts.
struct SubPool {
    block_bytes: usize,
    storage: Vec<u8>,
    refcounts: Vec<u32>,
    /// Free slot indices (not full [`BlockId`]s).
    free: Vec<u32>,
    num_blocks: usize,
}

impl SubPool {
    fn new(num_blocks: usize, block_bytes: usize) -> SubPool {
        SubPool {
            block_bytes,
            storage: vec![0u8; num_blocks * block_bytes],
            refcounts: vec![0; num_blocks],
            free: (0..num_blocks as u32).rev().collect(),
            num_blocks,
        }
    }

    fn range(&self, slot: u32) -> std::ops::Range<usize> {
        let s = slot as usize * self.block_bytes;
        s..s + self.block_bytes
    }
}

/// Fixed-capacity page allocator over raw bytes, one sub-pool per block
/// width.
pub struct BlockPool {
    shape: BlockShape,
    classes: Vec<SubPool>,
}

/// Width class of a block id.
#[inline]
pub fn class_of(id: BlockId) -> usize {
    (id >> CLASS_SHIFT) as usize
}

/// Slot of a block id within its class.
#[inline]
pub fn slot_of(id: BlockId) -> u32 {
    id & SLOT_MASK
}

/// Compose a block id from a class and a slot.
#[inline]
pub fn make_id(class: usize, slot: u32) -> BlockId {
    debug_assert!(class < (1 << (32 - CLASS_SHIFT)));
    debug_assert_eq!(slot & !SLOT_MASK, 0);
    (class as u32) << CLASS_SHIFT | slot
}

impl BlockPool {
    /// Single-class pool: every block `block_bytes` wide — the uniform-
    /// policy (and legacy) shape.
    pub fn new(num_blocks: usize, shape: BlockShape, block_bytes: usize) -> BlockPool {
        Self::with_classes(shape, &[(num_blocks, block_bytes)])
    }

    /// Multi-class pool: one sub-pool per `(num_blocks, block_bytes)`
    /// spec. Class indices follow spec order.
    pub fn with_classes(shape: BlockShape, specs: &[(usize, usize)]) -> BlockPool {
        assert!(!specs.is_empty(), "pool needs at least one width class");
        assert!(specs.len() <= 1 << (32 - CLASS_SHIFT), "too many width classes");
        for &(n, _) in specs {
            assert!(n <= SLOT_MASK as usize + 1, "class too large for slot encoding");
        }
        BlockPool {
            shape,
            classes: specs.iter().map(|&(n, w)| SubPool::new(n, w)).collect(),
        }
    }

    pub fn shape(&self) -> BlockShape {
        self.shape
    }

    /// Width classes in this pool (1 for uniform policies).
    pub fn num_classes(&self) -> usize {
        self.classes.len()
    }

    /// Payload bytes of one block of `class`.
    pub fn class_block_bytes(&self, class: usize) -> usize {
        self.classes[class].block_bytes
    }

    /// Payload bytes of the block behind `id`.
    pub fn block_bytes_of(&self, id: BlockId) -> usize {
        self.classes[class_of(id)].block_bytes
    }

    /// Payload bytes of one block, valid only for single-class pools
    /// (the legacy accessor — multi-class pools have no single width).
    pub fn block_bytes(&self) -> usize {
        debug_assert_eq!(self.classes.len(), 1, "block_bytes() on a multi-class pool");
        self.classes[0].block_bytes
    }

    /// Total blocks across all classes.
    pub fn num_blocks(&self) -> usize {
        self.classes.iter().map(|c| c.num_blocks).sum()
    }

    /// Blocks in one class.
    pub fn class_num_blocks(&self, class: usize) -> usize {
        self.classes[class].num_blocks
    }

    /// Free blocks across all classes.
    pub fn free_blocks(&self) -> usize {
        self.classes.iter().map(|c| c.free.len()).sum()
    }

    /// Free blocks in one class.
    pub fn class_free_blocks(&self, class: usize) -> usize {
        self.classes[class].free.len()
    }

    /// Physically occupied blocks. A block shared by N sequences (COW /
    /// prefix sharing) is counted **once** — this is true pool pressure,
    /// not the sum of per-sequence footprints.
    pub fn used_blocks(&self) -> usize {
        self.classes.iter().map(|c| c.num_blocks - c.free.len()).sum()
    }

    /// Sum of refcounts: the per-sequence ("logical") footprint. With
    /// prefix sharing this exceeds [`Self::used_blocks`]; the difference
    /// is memory the COW machinery is saving.
    pub fn logical_used_blocks(&self) -> usize {
        self.classes
            .iter()
            .map(|c| c.refcounts.iter().map(|&rc| rc as usize).sum::<usize>())
            .sum()
    }

    /// Blocks held by more than one sequence (refcount > 1).
    pub fn shared_blocks(&self) -> usize {
        self.classes
            .iter()
            .map(|c| c.refcounts.iter().filter(|&&rc| rc > 1).count())
            .sum()
    }

    /// True physical utilization (shared blocks counted once).
    pub fn utilization(&self) -> f64 {
        self.used_blocks() as f64 / self.num_blocks().max(1) as f64
    }

    /// Bytes of payload memory held by this pool — the **physical**
    /// footprint (Σ per-class `num_blocks × block_bytes`), which mixed
    /// policies keep strictly below the padded widest-stream baseline.
    pub fn storage_bytes(&self) -> usize {
        self.classes.iter().map(|c| c.storage.len()).sum()
    }

    /// Bytes currently on free lists, per-class widths respected.
    pub fn free_bytes_raw(&self) -> u64 {
        self.classes.iter().map(|c| (c.free.len() * c.block_bytes) as u64).sum()
    }

    /// Bytes currently occupied (used blocks × their class width).
    pub fn used_bytes(&self) -> u64 {
        self.classes
            .iter()
            .map(|c| ((c.num_blocks - c.free.len()) * c.block_bytes) as u64)
            .sum()
    }

    /// Allocate one block of class 0 (single-class pools' shorthand).
    pub fn alloc(&mut self) -> Result<BlockId> {
        self.alloc_in(0)
    }

    /// Allocate one block in `class` (refcount 1, zeroed).
    pub fn alloc_in(&mut self, class: usize) -> Result<BlockId> {
        let c = &mut self.classes[class];
        let Some(slot) = c.free.pop() else {
            bail!(
                "block pool exhausted (class {class}: {} blocks of {} bytes)",
                c.num_blocks,
                c.block_bytes
            )
        };
        debug_assert_eq!(c.refcounts[slot as usize], 0);
        c.refcounts[slot as usize] = 1;
        let r = c.range(slot);
        c.storage[r].fill(0);
        Ok(make_id(class, slot))
    }

    /// Increment a block's refcount (prefix sharing).
    pub fn retain(&mut self, id: BlockId) {
        let c = &mut self.classes[class_of(id)];
        let rc = &mut c.refcounts[slot_of(id) as usize];
        assert!(*rc > 0, "retain of free block {id}");
        *rc += 1;
    }

    /// Decrement; returns the block to its class free list at zero.
    pub fn release(&mut self, id: BlockId) {
        let c = &mut self.classes[class_of(id)];
        let slot = slot_of(id);
        let rc = &mut c.refcounts[slot as usize];
        assert!(*rc > 0, "release of free block {id}");
        *rc -= 1;
        if *rc == 0 {
            c.free.push(slot);
        }
    }

    pub fn refcount(&self, id: BlockId) -> u32 {
        self.classes[class_of(id)].refcounts[slot_of(id) as usize]
    }

    /// Copy-on-write: if `id` is shared, copy its payload into a fresh
    /// block **of the same class**, release the original, and return the
    /// new id; otherwise return `id` unchanged.
    pub fn ensure_unique(&mut self, id: BlockId) -> Result<BlockId> {
        let class = class_of(id);
        if self.refcount(id) <= 1 {
            return Ok(id);
        }
        let new = self.alloc_in(class)?;
        let c = &mut self.classes[class];
        let (src_range, dst_range) = (c.range(slot_of(id)), c.range(slot_of(new)));
        // Split borrows: ranges are disjoint (different blocks of one
        // class slab).
        let w = c.block_bytes;
        let (a, b) = if src_range.start < dst_range.start {
            let (lo, hi) = c.storage.split_at_mut(dst_range.start);
            (&lo[src_range.clone()], &mut hi[..w])
        } else {
            let (lo, hi) = c.storage.split_at_mut(src_range.start);
            (&hi[..w], &mut lo[dst_range.clone()])
        };
        b.copy_from_slice(a);
        self.release(id);
        Ok(new)
    }

    /// Raw byte view of a block's payload.
    pub fn block_raw(&self, id: BlockId) -> &[u8] {
        let c = &self.classes[class_of(id)];
        &c.storage[c.range(slot_of(id))]
    }

    pub fn block_mut_raw(&mut self, id: BlockId) -> &mut [u8] {
        let c = &mut self.classes[class_of(id)];
        let r = c.range(slot_of(id));
        &mut c.storage[r]
    }

    /// Dense `0..num_blocks` index of a block (class-major order), for
    /// side tables indexed per block (the manager's external pins).
    pub fn dense_index(&self, id: BlockId) -> usize {
        let class = class_of(id);
        let off: usize = self.classes[..class].iter().map(|c| c.num_blocks).sum();
        off + slot_of(id) as usize
    }

    /// Every block id in the pool, in dense (class-major) order —
    /// pairs with [`Self::dense_index`].
    pub fn all_ids(&self) -> impl Iterator<Item = BlockId> + '_ {
        self.classes
            .iter()
            .enumerate()
            .flat_map(|(c, sp)| (0..sp.num_blocks as u32).map(move |s| make_id(c, s)))
    }

    /// Raw payload pointers for a set of blocks, all derived from one
    /// mutable borrow of the pool (clean provenance for parallel
    /// writers). Callers guarantee the ids are distinct and own the
    /// disjointness of concurrent writes.
    pub fn block_raw_ptrs(&mut self, ids: &[BlockId]) -> Vec<*mut u8> {
        let bases: Vec<(*mut u8, usize)> = self
            .classes
            .iter_mut()
            .map(|c| (c.storage.as_mut_ptr(), c.block_bytes))
            .collect();
        // SAFETY: every id indexes a whole block inside its class slab.
        ids.iter()
            .map(|&id| {
                let (base, w) = bases[class_of(id)];
                unsafe { base.add(slot_of(id) as usize * w) }
            })
            .collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> BlockShape {
        BlockShape { block_size: 4, heads: 2, head_dim: 8 }
    }

    fn pool(n: usize) -> BlockPool {
        // int8-width blocks: 1 byte per element.
        BlockPool::new(n, shape(), shape().elements())
    }

    #[test]
    fn alloc_free_cycle() {
        let mut p = pool(3);
        assert_eq!(p.free_blocks(), 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.used_blocks(), 2);
        p.release(a);
        assert_eq!(p.free_blocks(), 2);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "freed block is reused");
    }

    #[test]
    fn exhaustion_errors() {
        let mut p = pool(1);
        let _a = p.alloc().unwrap();
        assert!(p.alloc().is_err());
    }

    #[test]
    fn alloc_zeroes_payload() {
        let mut p = pool(1);
        let a = p.alloc().unwrap();
        p.block_mut_raw(a).fill(7);
        p.release(a);
        let b = p.alloc().unwrap();
        assert!(p.block_raw(b).iter().all(|&v| v == 0));
    }

    #[test]
    fn refcounting() {
        let mut p = pool(2);
        let a = p.alloc().unwrap();
        p.retain(a);
        assert_eq!(p.refcount(a), 2);
        p.release(a);
        assert_eq!(p.free_blocks(), 1, "still held");
        p.release(a);
        assert_eq!(p.free_blocks(), 2);
    }

    #[test]
    fn shared_blocks_count_once_physically() {
        let mut p = pool(4);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        p.retain(a); // a now shared by two logical holders
        p.retain(a); // and a third
        assert_eq!(p.used_blocks(), 2, "physical: shared block counted once");
        assert_eq!(p.logical_used_blocks(), 4, "logical: 3 holds of a + 1 of b");
        assert_eq!(p.shared_blocks(), 1);
        assert_eq!(p.free_blocks(), 2, "free list unaffected by retains");
        p.release(a);
        p.release(a);
        assert_eq!(p.shared_blocks(), 0);
        assert_eq!(p.used_blocks(), 2, "a still held once");
    }

    #[test]
    #[should_panic(expected = "release of free block")]
    fn double_free_panics() {
        let mut p = pool(1);
        let a = p.alloc().unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    fn cow_copies_shared_blocks() {
        let mut p = pool(2);
        let a = p.alloc().unwrap();
        p.block_mut_raw(a)[0] = 42;
        p.retain(a); // shared twice
        let b = p.ensure_unique(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.block_raw(b)[0], 42, "payload copied");
        assert_eq!(p.refcount(a), 1, "original released once");
        // Unshared block is returned as-is.
        let c = p.ensure_unique(b).unwrap();
        assert_eq!(b, c);
    }

    #[test]
    fn byte_width_is_caller_defined() {
        // fp32-width blocks: 4 bytes per element; int4-width: half a byte.
        let p32 = BlockPool::new(10, shape(), shape().elements() * 4);
        let p8 = pool(10);
        let p4 = BlockPool::new(10, shape(), shape().elements() / 2);
        assert_eq!(p32.storage_bytes(), p8.storage_bytes() * 4);
        assert_eq!(p4.storage_bytes() * 2, p8.storage_bytes());
        assert_eq!(p32.block_bytes(), shape().elements() * 4);
    }

    #[test]
    fn raw_ptrs_index_whole_blocks() {
        let mut p = pool(3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let ptrs = p.block_raw_ptrs(&[a, b]);
        // SAFETY: test-only — blocks are distinct and in bounds.
        unsafe {
            *ptrs[0] = 11;
            *ptrs[1] = 22;
        }
        assert_eq!(p.block_raw(a)[0], 11);
        assert_eq!(p.block_raw(b)[0], 22);
    }

    #[test]
    fn sub_pools_allocate_per_width() {
        // Two classes: 4 wide blocks (64 B) + 4 narrow (32 B) — the k8v4
        // shape at this geometry.
        let mut p = BlockPool::with_classes(shape(), &[(4, 64), (4, 32)]);
        assert_eq!(p.num_classes(), 2);
        assert_eq!(p.num_blocks(), 8);
        assert_eq!(p.storage_bytes(), 4 * 64 + 4 * 32);
        let wide = p.alloc_in(0).unwrap();
        let narrow = p.alloc_in(1).unwrap();
        assert_eq!(p.block_raw(wide).len(), 64);
        assert_eq!(p.block_raw(narrow).len(), 32);
        assert_eq!(p.block_bytes_of(wide), 64);
        assert_eq!(p.block_bytes_of(narrow), 32);
        assert_ne!(wide, narrow, "ids are class-disambiguated");
        assert_eq!(p.class_free_blocks(0), 3);
        assert_eq!(p.class_free_blocks(1), 3);
        assert_eq!(p.used_bytes(), 64 + 32);
        assert_eq!(p.free_bytes_raw(), (3 * 64 + 3 * 32) as u64);
        // Exhausting the narrow class leaves the wide class allocatable.
        for _ in 0..3 {
            p.alloc_in(1).unwrap();
        }
        assert!(p.alloc_in(1).is_err());
        assert!(p.alloc_in(0).is_ok());
    }

    #[test]
    fn cow_stays_in_class() {
        let mut p = BlockPool::with_classes(shape(), &[(2, 64), (2, 32)]);
        let a = p.alloc_in(1).unwrap();
        p.block_mut_raw(a)[0] = 9;
        p.retain(a);
        let b = p.ensure_unique(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.block_bytes_of(b), 32, "copy lands in the source class");
        assert_eq!(p.block_raw(b)[0], 9);
        assert_eq!(p.refcount(a), 1);
    }

    #[test]
    fn raw_ptrs_span_classes() {
        let mut p = BlockPool::with_classes(shape(), &[(2, 64), (2, 32)]);
        let a = p.alloc_in(0).unwrap();
        let b = p.alloc_in(1).unwrap();
        let ptrs = p.block_raw_ptrs(&[a, b]);
        // SAFETY: test-only — distinct blocks in distinct slabs.
        unsafe {
            *ptrs[0] = 5;
            *ptrs[1] = 6;
        }
        assert_eq!(p.block_raw(a)[0], 5);
        assert_eq!(p.block_raw(b)[0], 6);
    }
}
