//! Block pool: a preallocated slab of fixed-size pages with a free list
//! and reference counts.
//!
//! One pool backs every sequence's K and V streams across all layers.
//! A block holds `block_size` token rows of one (layer, K|V) stream; the
//! *byte* layout of those rows is owned by the stream's
//! [`crate::kvcache::policy::StreamLayout`] (head-major slabs whose row
//! width comes from each head's [`crate::quant::Codec`]). The pool itself
//! is precision-agnostic: it deals in raw bytes, sized at construction
//! for the widest stream the active policy produces, so one pool can back
//! mixed-precision caches with fungible blocks (the scheduler's block
//! accounting never needs to know which stream a block serves).
//!
//! Refcounts implement copy-on-write prefix sharing: `fork` bumps counts;
//! writers call `ensure_unique` (copy-on-write) before mutating.

use anyhow::{bail, Result};

/// Index of a block in the pool.
pub type BlockId = u32;

/// Geometry of one block (rows × heads × channels; bytes come from the
/// per-stream codecs).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct BlockShape {
    pub block_size: usize,
    pub heads: usize,
    pub head_dim: usize,
}

impl BlockShape {
    pub fn elements(&self) -> usize {
        self.block_size * self.heads * self.head_dim
    }
}

/// Fixed-capacity page allocator over raw bytes.
pub struct BlockPool {
    shape: BlockShape,
    block_bytes: usize,
    storage: Vec<u8>,
    refcounts: Vec<u32>,
    free: Vec<BlockId>,
    num_blocks: usize,
}

impl BlockPool {
    pub fn new(num_blocks: usize, shape: BlockShape, block_bytes: usize) -> BlockPool {
        BlockPool {
            shape,
            block_bytes,
            storage: vec![0u8; num_blocks * block_bytes],
            refcounts: vec![0; num_blocks],
            free: (0..num_blocks as BlockId).rev().collect(),
            num_blocks,
        }
    }

    pub fn shape(&self) -> BlockShape {
        self.shape
    }

    /// Payload bytes of one block.
    pub fn block_bytes(&self) -> usize {
        self.block_bytes
    }

    pub fn num_blocks(&self) -> usize {
        self.num_blocks
    }

    pub fn free_blocks(&self) -> usize {
        self.free.len()
    }

    /// Physically occupied blocks. A block shared by N sequences (COW /
    /// prefix sharing) is counted **once** — this is true pool pressure,
    /// not the sum of per-sequence footprints.
    pub fn used_blocks(&self) -> usize {
        self.num_blocks - self.free.len()
    }

    /// Sum of refcounts: the per-sequence ("logical") footprint. With
    /// prefix sharing this exceeds [`Self::used_blocks`]; the difference
    /// is memory the COW machinery is saving.
    pub fn logical_used_blocks(&self) -> usize {
        self.refcounts.iter().map(|&rc| rc as usize).sum()
    }

    /// Blocks held by more than one sequence (refcount > 1).
    pub fn shared_blocks(&self) -> usize {
        self.refcounts.iter().filter(|&&rc| rc > 1).count()
    }

    /// True physical utilization (shared blocks counted once).
    pub fn utilization(&self) -> f64 {
        self.used_blocks() as f64 / self.num_blocks.max(1) as f64
    }

    /// Bytes of payload memory held by this pool.
    pub fn storage_bytes(&self) -> usize {
        self.storage.len()
    }

    /// Allocate one block (refcount 1, zeroed).
    pub fn alloc(&mut self) -> Result<BlockId> {
        let Some(id) = self.free.pop() else {
            bail!("block pool exhausted ({} blocks)", self.num_blocks)
        };
        debug_assert_eq!(self.refcounts[id as usize], 0);
        self.refcounts[id as usize] = 1;
        self.block_mut_raw(id).fill(0);
        Ok(id)
    }

    /// Increment a block's refcount (prefix sharing).
    pub fn retain(&mut self, id: BlockId) {
        assert!(self.refcounts[id as usize] > 0, "retain of free block {id}");
        self.refcounts[id as usize] += 1;
    }

    /// Decrement; returns the block to the free list at zero.
    pub fn release(&mut self, id: BlockId) {
        let rc = &mut self.refcounts[id as usize];
        assert!(*rc > 0, "release of free block {id}");
        *rc -= 1;
        if *rc == 0 {
            self.free.push(id);
        }
    }

    pub fn refcount(&self, id: BlockId) -> u32 {
        self.refcounts[id as usize]
    }

    /// Copy-on-write: if `id` is shared, copy its payload into a fresh
    /// block, release the original, and return the new id; otherwise
    /// return `id` unchanged.
    pub fn ensure_unique(&mut self, id: BlockId) -> Result<BlockId> {
        if self.refcounts[id as usize] <= 1 {
            return Ok(id);
        }
        let new = self.alloc()?;
        let (src_range, dst_range) = (self.range(id), self.range(new));
        // Split borrows: ranges are disjoint (different blocks).
        let (a, b) = if src_range.start < dst_range.start {
            let (lo, hi) = self.storage.split_at_mut(dst_range.start);
            (&lo[src_range.clone()], &mut hi[..self.block_bytes])
        } else {
            let (lo, hi) = self.storage.split_at_mut(src_range.start);
            (&hi[..self.block_bytes], &mut lo[dst_range.clone()])
        };
        b.copy_from_slice(a);
        self.release(id);
        Ok(new)
    }

    fn range(&self, id: BlockId) -> std::ops::Range<usize> {
        let s = id as usize * self.block_bytes;
        s..s + self.block_bytes
    }

    /// Raw byte view of a block's payload.
    pub fn block_raw(&self, id: BlockId) -> &[u8] {
        &self.storage[self.range(id)]
    }

    pub fn block_mut_raw(&mut self, id: BlockId) -> &mut [u8] {
        let r = self.range(id);
        &mut self.storage[r]
    }

    /// Raw payload pointers for a set of blocks, all derived from one
    /// mutable borrow of the storage (clean provenance for parallel
    /// writers). Callers guarantee the ids are distinct and own the
    /// disjointness of concurrent writes.
    pub fn block_raw_ptrs(&mut self, ids: &[BlockId]) -> Vec<*mut u8> {
        let base = self.storage.as_mut_ptr();
        // SAFETY: every id indexes a whole block inside `storage`.
        ids.iter().map(|&id| unsafe { base.add(id as usize * self.block_bytes) }).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn shape() -> BlockShape {
        BlockShape { block_size: 4, heads: 2, head_dim: 8 }
    }

    fn pool(n: usize) -> BlockPool {
        // int8-width blocks: 1 byte per element.
        BlockPool::new(n, shape(), shape().elements())
    }

    #[test]
    fn alloc_free_cycle() {
        let mut p = pool(3);
        assert_eq!(p.free_blocks(), 3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        assert_ne!(a, b);
        assert_eq!(p.used_blocks(), 2);
        p.release(a);
        assert_eq!(p.free_blocks(), 2);
        let c = p.alloc().unwrap();
        assert_eq!(c, a, "freed block is reused");
    }

    #[test]
    fn exhaustion_errors() {
        let mut p = pool(1);
        let _a = p.alloc().unwrap();
        assert!(p.alloc().is_err());
    }

    #[test]
    fn alloc_zeroes_payload() {
        let mut p = pool(1);
        let a = p.alloc().unwrap();
        p.block_mut_raw(a).fill(7);
        p.release(a);
        let b = p.alloc().unwrap();
        assert!(p.block_raw(b).iter().all(|&v| v == 0));
    }

    #[test]
    fn refcounting() {
        let mut p = pool(2);
        let a = p.alloc().unwrap();
        p.retain(a);
        assert_eq!(p.refcount(a), 2);
        p.release(a);
        assert_eq!(p.free_blocks(), 1, "still held");
        p.release(a);
        assert_eq!(p.free_blocks(), 2);
    }

    #[test]
    fn shared_blocks_count_once_physically() {
        let mut p = pool(4);
        let a = p.alloc().unwrap();
        let _b = p.alloc().unwrap();
        p.retain(a); // a now shared by two logical holders
        p.retain(a); // and a third
        assert_eq!(p.used_blocks(), 2, "physical: shared block counted once");
        assert_eq!(p.logical_used_blocks(), 4, "logical: 3 holds of a + 1 of b");
        assert_eq!(p.shared_blocks(), 1);
        assert_eq!(p.free_blocks(), 2, "free list unaffected by retains");
        p.release(a);
        p.release(a);
        assert_eq!(p.shared_blocks(), 0);
        assert_eq!(p.used_blocks(), 2, "a still held once");
    }

    #[test]
    #[should_panic(expected = "release of free block")]
    fn double_free_panics() {
        let mut p = pool(1);
        let a = p.alloc().unwrap();
        p.release(a);
        p.release(a);
    }

    #[test]
    fn cow_copies_shared_blocks() {
        let mut p = pool(2);
        let a = p.alloc().unwrap();
        p.block_mut_raw(a)[0] = 42;
        p.retain(a); // shared twice
        let b = p.ensure_unique(a).unwrap();
        assert_ne!(a, b);
        assert_eq!(p.block_raw(b)[0], 42, "payload copied");
        assert_eq!(p.refcount(a), 1, "original released once");
        // Unshared block is returned as-is.
        let c = p.ensure_unique(b).unwrap();
        assert_eq!(b, c);
    }

    #[test]
    fn byte_width_is_caller_defined() {
        // fp32-width blocks: 4 bytes per element; int4-width: half a byte.
        let p32 = BlockPool::new(10, shape(), shape().elements() * 4);
        let p8 = pool(10);
        let p4 = BlockPool::new(10, shape(), shape().elements() / 2);
        assert_eq!(p32.storage_bytes(), p8.storage_bytes() * 4);
        assert_eq!(p4.storage_bytes() * 2, p8.storage_bytes());
        assert_eq!(p32.block_bytes(), shape().elements() * 4);
    }

    #[test]
    fn raw_ptrs_index_whole_blocks() {
        let mut p = pool(3);
        let a = p.alloc().unwrap();
        let b = p.alloc().unwrap();
        let ptrs = p.block_raw_ptrs(&[a, b]);
        // SAFETY: test-only — blocks are distinct and in bounds.
        unsafe {
            *ptrs[0] = 11;
            *ptrs[1] = 22;
        }
        assert_eq!(p.block_raw(a)[0], 11);
        assert_eq!(p.block_raw(b)[0], 22);
    }
}
