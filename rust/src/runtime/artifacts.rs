//! Artifact manifest: the ABI between `python -m compile.aot` and the
//! Rust runtime. Parsed with the in-repo JSON parser.

use super::tensor::DType;
use crate::util::json::Json;
use anyhow::{anyhow, bail, Context, Result};
use std::collections::BTreeMap;
use std::path::{Path, PathBuf};

/// dtype + shape of one executable input/output.
#[derive(Debug, Clone, PartialEq, Eq)]
pub struct TensorSpec {
    pub dtype: DType,
    pub shape: Vec<usize>,
}

impl TensorSpec {
    pub fn elements(&self) -> usize {
        self.shape.iter().product()
    }

    pub fn size_bytes(&self) -> usize {
        self.elements() * self.dtype.size()
    }

    fn from_json(j: &Json) -> Result<TensorSpec> {
        let dtype = DType::parse(j.get("dtype").as_str().ok_or_else(|| anyhow!("missing dtype"))?)?;
        let shape = j
            .get("shape")
            .as_arr()
            .ok_or_else(|| anyhow!("missing shape"))?
            .iter()
            .map(|v| v.as_usize().ok_or_else(|| anyhow!("bad dim")))
            .collect::<Result<Vec<_>>>()?;
        Ok(TensorSpec { dtype, shape })
    }
}

/// One AOT-lowered executable.
#[derive(Debug, Clone)]
pub struct ManifestEntry {
    pub name: String,
    /// Path of the HLO text file, relative to the artifact dir.
    pub path: String,
    /// Entry kind: quantize / dequantize / scales / quantize_fused /
    /// quantize_ref / attnerr / prefill / decode / decode_pallas.
    pub kind: String,
    pub inputs: Vec<TensorSpec>,
    pub outputs: Vec<TensorSpec>,
    pub meta: Json,
}

/// A bench shape recorded by aot.py (Table-3 row, ci or paper set).
#[derive(Debug, Clone)]
pub struct ShapeInfo {
    pub set: String,
    pub name: String,
    pub tokens: usize,
    pub dim: usize,
    pub tag: String,
    pub desc: String,
}

/// Parsed manifest.
#[derive(Debug, Clone)]
pub struct Manifest {
    pub root: PathBuf,
    pub entries: BTreeMap<String, ManifestEntry>,
    pub shapes: Vec<ShapeInfo>,
    /// Model configs as raw JSON (decoded further by `model::spec`).
    pub models: Vec<Json>,
}

impl Manifest {
    pub fn load(dir: impl AsRef<Path>) -> Result<Manifest> {
        let root = dir.as_ref().to_path_buf();
        let path = root.join("manifest.json");
        let text = std::fs::read_to_string(&path)
            .with_context(|| format!("reading {} (run `make artifacts`?)", path.display()))?;
        Self::parse(&text, root)
    }

    pub fn parse(text: &str, root: PathBuf) -> Result<Manifest> {
        let j = Json::parse(text).context("parsing manifest.json")?;
        let version = j.get("version").as_usize().unwrap_or(0);
        if version != 1 {
            bail!("unsupported manifest version {version}");
        }
        let mut entries = BTreeMap::new();
        for e in j.get("entries").as_arr().unwrap_or(&[]) {
            let name = e
                .get("name")
                .as_str()
                .ok_or_else(|| anyhow!("entry missing name"))?
                .to_string();
            let entry = ManifestEntry {
                name: name.clone(),
                path: e.get("path").as_str().ok_or_else(|| anyhow!("missing path"))?.to_string(),
                kind: e.get("kind").as_str().unwrap_or("").to_string(),
                inputs: e
                    .get("inputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()
                    .with_context(|| format!("entry {name}: inputs"))?,
                outputs: e
                    .get("outputs")
                    .as_arr()
                    .unwrap_or(&[])
                    .iter()
                    .map(TensorSpec::from_json)
                    .collect::<Result<Vec<_>>>()
                    .with_context(|| format!("entry {name}: outputs"))?,
                meta: e.get("meta").clone(),
            };
            entries.insert(name, entry);
        }
        let shapes = j
            .get("shapes")
            .as_arr()
            .unwrap_or(&[])
            .iter()
            .map(|s| {
                Ok(ShapeInfo {
                    set: s.get("set").as_str().unwrap_or("").to_string(),
                    name: s.get("name").as_str().unwrap_or("").to_string(),
                    tokens: s.get("tokens").as_usize().ok_or_else(|| anyhow!("shape tokens"))?,
                    dim: s.get("dim").as_usize().ok_or_else(|| anyhow!("shape dim"))?,
                    tag: s.get("tag").as_str().unwrap_or("").to_string(),
                    desc: s.get("desc").as_str().unwrap_or("").to_string(),
                })
            })
            .collect::<Result<Vec<_>>>()?;
        let models = j.get("models").as_arr().unwrap_or(&[]).to_vec();
        Ok(Manifest { root, entries, shapes, models })
    }

    pub fn entry(&self, name: &str) -> Result<&ManifestEntry> {
        self.entries
            .get(name)
            .ok_or_else(|| {
                anyhow!("artifact {name:?} not in manifest ({} entries)", self.entries.len())
            })
    }

    /// Absolute path of an entry's HLO file.
    pub fn hlo_path(&self, entry: &ManifestEntry) -> PathBuf {
        self.root.join(&entry.path)
    }

    /// Shapes in a given set ("ci" or "paper"), in manifest order.
    pub fn shape_set(&self, set: &str) -> Vec<&ShapeInfo> {
        self.shapes.iter().filter(|s| s.set == set).collect()
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    const SAMPLE: &str = r#"{
      "version": 1,
      "entries": [
        {"name": "quantize_naive_8x4", "path": "q.hlo.txt", "kind": "quantize",
         "inputs": [{"dtype": "float32", "shape": [8, 4]},
                    {"dtype": "float32", "shape": [4]}],
         "outputs": [{"dtype": "int8", "shape": [8, 4]}],
         "meta": {"variant": "naive", "tokens": 8, "dim": 4}}
      ],
      "shapes": [{"set": "ci", "name": "small", "tokens": 8, "dim": 4,
                  "tag": "8x4", "desc": "d"}],
      "models": [{"name": "kvq-3m"}]
    }"#;

    #[test]
    fn parses_sample() {
        let m = Manifest::parse(SAMPLE, PathBuf::from("/tmp/a")).unwrap();
        let e = m.entry("quantize_naive_8x4").unwrap();
        assert_eq!(e.kind, "quantize");
        assert_eq!(e.inputs[0].shape, vec![8, 4]);
        assert_eq!(e.inputs[0].dtype, DType::F32);
        assert_eq!(e.outputs[0].dtype, DType::I8);
        assert_eq!(e.meta.get("variant").as_str(), Some("naive"));
        assert_eq!(m.hlo_path(e), PathBuf::from("/tmp/a/q.hlo.txt"));
        assert_eq!(m.shape_set("ci").len(), 1);
        assert_eq!(m.shape_set("paper").len(), 0);
    }

    #[test]
    fn missing_entry_is_error() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        assert!(m.entry("nope").is_err());
    }

    #[test]
    fn rejects_wrong_version() {
        let bad = SAMPLE.replace("\"version\": 1", "\"version\": 9");
        assert!(Manifest::parse(&bad, PathBuf::from(".")).is_err());
    }

    #[test]
    fn tensor_spec_sizes() {
        let m = Manifest::parse(SAMPLE, PathBuf::from(".")).unwrap();
        let e = m.entry("quantize_naive_8x4").unwrap();
        assert_eq!(e.inputs[0].elements(), 32);
        assert_eq!(e.inputs[0].size_bytes(), 128);
        assert_eq!(e.outputs[0].size_bytes(), 32);
    }

    #[test]
    fn loads_real_manifest_if_present() {
        // Exercises the real artifacts/ when built (skips otherwise so
        // unit tests don't depend on `make artifacts`).
        let dir = crate::runtime::default_artifact_dir();
        if !std::path::Path::new(&dir).join("manifest.json").exists() {
            return;
        }
        let m = Manifest::load(&dir).unwrap();
        assert!(m.entries.len() >= 10);
        assert!(!m.shape_set("ci").is_empty());
    }
}
