//! PJRT runtime: load AOT artifacts (HLO text) and execute them.
//!
//! The bridge follows /opt/xla-example/load_hlo: `PjRtClient::cpu()` →
//! `HloModuleProto::from_text_file` → `client.compile` → `execute`.
//! HLO **text** is the interchange format (xla_extension 0.5.1 rejects
//! jax≥0.5 serialized protos — 64-bit instruction ids).
//!
//! Python never runs at serve time: `artifacts/manifest.json` (written by
//! `python -m compile.aot`) describes every executable's input/output
//! signature, and [`Runtime`] validates host tensors against it before
//! execution. Executables are compiled once and cached for the process
//! lifetime.
//!
//! Threading: the underlying `xla` crate wraps raw pointers without
//! `Send`/`Sync`, so a [`Runtime`] is confined to the thread that created
//! it. The coordinator runs the engine (and thus the runtime) on a single
//! dedicated thread and communicates via channels.

pub mod artifacts;
pub mod executable;
pub mod tensor;

pub use artifacts::{Manifest, ManifestEntry, TensorSpec};
pub use executable::Runtime;
pub use tensor::{DType, HostTensor};

/// Default artifact directory (overridable via `KVQ_ARTIFACTS` or CLI).
pub fn default_artifact_dir() -> String {
    std::env::var("KVQ_ARTIFACTS").unwrap_or_else(|_| "artifacts".to_string())
}
