//! Host-side tensors and their conversion to/from XLA literals.

use anyhow::{anyhow, bail, Context, Result};

/// Element dtypes used by the artifact ABI.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Hash)]
pub enum DType {
    F32,
    I8,
    I32,
}

impl DType {
    pub fn parse(s: &str) -> Result<DType> {
        Ok(match s {
            "float32" | "f32" => DType::F32,
            "int8" | "i8" => DType::I8,
            "int32" | "i32" => DType::I32,
            _ => bail!("unsupported dtype {s:?}"),
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            DType::F32 => "float32",
            DType::I8 => "int8",
            DType::I32 => "int32",
        }
    }

    pub fn size(self) -> usize {
        match self {
            DType::F32 | DType::I32 => 4,
            DType::I8 => 1,
        }
    }

    fn element_type(self) -> xla::ElementType {
        match self {
            DType::F32 => xla::ElementType::F32,
            DType::I8 => xla::ElementType::S8,
            DType::I32 => xla::ElementType::S32,
        }
    }
}

/// A host tensor: typed buffer + shape. The only data type that crosses
/// the coordinator ↔ runtime boundary.
#[derive(Debug, Clone, PartialEq)]
pub enum HostTensor {
    F32(Vec<f32>, Vec<usize>),
    I8(Vec<i8>, Vec<usize>),
    I32(Vec<i32>, Vec<usize>),
}

impl HostTensor {
    pub fn scalar_i32(v: i32) -> HostTensor {
        HostTensor::I32(vec![v], vec![])
    }

    pub fn f32(data: Vec<f32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        HostTensor::F32(data, shape.to_vec())
    }

    pub fn i8(data: Vec<i8>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        HostTensor::I8(data, shape.to_vec())
    }

    pub fn i32(data: Vec<i32>, shape: &[usize]) -> HostTensor {
        assert_eq!(data.len(), shape.iter().product::<usize>(), "shape/data mismatch");
        HostTensor::I32(data, shape.to_vec())
    }

    pub fn dtype(&self) -> DType {
        match self {
            HostTensor::F32(..) => DType::F32,
            HostTensor::I8(..) => DType::I8,
            HostTensor::I32(..) => DType::I32,
        }
    }

    pub fn shape(&self) -> &[usize] {
        match self {
            HostTensor::F32(_, s) | HostTensor::I8(_, s) | HostTensor::I32(_, s) => s,
        }
    }

    pub fn len(&self) -> usize {
        match self {
            HostTensor::F32(d, _) => d.len(),
            HostTensor::I8(d, _) => d.len(),
            HostTensor::I32(d, _) => d.len(),
        }
    }

    pub fn is_empty(&self) -> bool {
        self.len() == 0
    }

    pub fn as_f32(&self) -> Result<&[f32]> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            other => Err(anyhow!("expected f32 tensor, got {}", other.dtype().name())),
        }
    }

    pub fn as_i8(&self) -> Result<&[i8]> {
        match self {
            HostTensor::I8(d, _) => Ok(d),
            other => Err(anyhow!("expected i8 tensor, got {}", other.dtype().name())),
        }
    }

    pub fn as_i32(&self) -> Result<&[i32]> {
        match self {
            HostTensor::I32(d, _) => Ok(d),
            other => Err(anyhow!("expected i32 tensor, got {}", other.dtype().name())),
        }
    }

    pub fn into_f32(self) -> Result<Vec<f32>> {
        match self {
            HostTensor::F32(d, _) => Ok(d),
            other => Err(anyhow!("expected f32 tensor, got {}", other.dtype().name())),
        }
    }

    pub fn into_i8(self) -> Result<Vec<i8>> {
        match self {
            HostTensor::I8(d, _) => Ok(d),
            other => Err(anyhow!("expected i8 tensor, got {}", other.dtype().name())),
        }
    }

    /// Bytes view of the payload (for literal construction).
    fn bytes(&self) -> &[u8] {
        match self {
            HostTensor::F32(d, _) => unsafe {
                std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len() * 4)
            },
            HostTensor::I8(d, _) => unsafe {
                std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len())
            },
            HostTensor::I32(d, _) => unsafe {
                std::slice::from_raw_parts(d.as_ptr() as *const u8, d.len() * 4)
            },
        }
    }

    /// Convert to an XLA literal (copies once).
    pub fn to_literal(&self) -> Result<xla::Literal> {
        xla::Literal::create_from_shape_and_untyped_data(
            self.dtype().element_type(),
            self.shape(),
            self.bytes(),
        )
        .context("creating literal")
    }

    /// Convert an XLA literal back to a host tensor.
    pub fn from_literal(lit: &xla::Literal) -> Result<HostTensor> {
        let shape = lit.array_shape().context("literal array shape")?;
        let dims: Vec<usize> = shape.dims().iter().map(|&d| d as usize).collect();
        let n: usize = dims.iter().product();
        match shape.ty() {
            xla::ElementType::F32 => {
                let mut data = vec![0.0f32; n];
                lit.copy_raw_to(&mut data).context("copy f32")?;
                Ok(HostTensor::F32(data, dims))
            }
            xla::ElementType::S8 => {
                let mut data = vec![0i8; n];
                lit.copy_raw_to(&mut data).context("copy i8")?;
                Ok(HostTensor::I8(data, dims))
            }
            xla::ElementType::S32 => {
                let mut data = vec![0i32; n];
                lit.copy_raw_to(&mut data).context("copy i32")?;
                Ok(HostTensor::I32(data, dims))
            }
            other => bail!("unsupported literal element type {other:?}"),
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn dtype_parse_roundtrip() {
        for (s, d) in [("float32", DType::F32), ("int8", DType::I8), ("int32", DType::I32)] {
            assert_eq!(DType::parse(s).unwrap(), d);
            assert_eq!(DType::parse(d.name()).unwrap(), d);
        }
        assert!(DType::parse("float64").is_err());
    }

    #[test]
    fn constructors_validate_shape() {
        let t = HostTensor::f32(vec![1.0, 2.0, 3.0, 4.0], &[2, 2]);
        assert_eq!(t.shape(), &[2, 2]);
        assert_eq!(t.len(), 4);
        assert_eq!(t.dtype(), DType::F32);
    }

    #[test]
    #[should_panic(expected = "shape/data mismatch")]
    fn constructor_rejects_bad_shape() {
        HostTensor::f32(vec![1.0], &[2, 2]);
    }

    #[test]
    fn accessors_enforce_dtype() {
        let t = HostTensor::i8(vec![1, 2], &[2]);
        assert!(t.as_i8().is_ok());
        assert!(t.as_f32().is_err());
    }

    #[test]
    fn scalar_shape_is_rank0() {
        let t = HostTensor::scalar_i32(7);
        assert!(t.shape().is_empty());
        assert_eq!(t.len(), 1);
    }

    // Literal round-trips are covered by the integration test
    // (rust/tests/runtime_artifacts.rs) since they need libxla at runtime.
}
