//! Compile-once executable cache over the PJRT CPU client.

use super::artifacts::{Manifest, ManifestEntry};
use super::tensor::HostTensor;
use anyhow::{bail, Context, Result};
use std::cell::RefCell;
use std::collections::HashMap;
use std::rc::Rc;
use std::time::Instant;

/// A compiled artifact: manifest entry + PJRT executable.
pub struct Compiled {
    pub entry: ManifestEntry,
    exe: xla::PjRtLoadedExecutable,
}

impl Compiled {
    /// Validate inputs against the manifest signature, execute, and return
    /// the decomposed tuple outputs as host tensors.
    pub fn run(&self, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.validate(inputs)?;
        let literals: Vec<xla::Literal> = inputs
            .iter()
            .enumerate()
            .map(|(i, t)| t.to_literal().with_context(|| format!("input {i}")))
            .collect::<Result<Vec<_>>>()?;
        let result = self
            .exe
            .execute::<xla::Literal>(&literals)
            .with_context(|| format!("executing {}", self.entry.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.entry.name))?;
        // aot.py lowers with return_tuple=True: outputs arrive as one tuple.
        let parts = tuple.decompose_tuple().context("decomposing output tuple")?;
        let mut out = Vec::with_capacity(parts.len());
        for (i, lit) in parts.iter().enumerate() {
            out.push(HostTensor::from_literal(lit).with_context(|| format!("output {i}"))?);
        }
        if out.len() != self.entry.outputs.len() {
            bail!(
                "{}: expected {} outputs, got {}",
                self.entry.name,
                self.entry.outputs.len(),
                out.len()
            );
        }
        Ok(out)
    }

    /// Buffer-based execution: callers stage large, reused inputs (e.g.
    /// model weights) on the device once and pass cheap references per
    /// step. No signature validation here — the caller owns the staging.
    pub fn run_b(&self, args: &[&xla::PjRtBuffer]) -> Result<Vec<HostTensor>> {
        let result = self
            .exe
            .execute_b::<&xla::PjRtBuffer>(args)
            .with_context(|| format!("executing (buffers) {}", self.entry.name))?;
        let mut tuple = result[0][0]
            .to_literal_sync()
            .with_context(|| format!("fetching result of {}", self.entry.name))?;
        let parts = tuple.decompose_tuple().context("decomposing output tuple")?;
        parts
            .iter()
            .enumerate()
            .map(|(i, lit)| HostTensor::from_literal(lit).with_context(|| format!("output {i}")))
            .collect()
    }

    fn validate(&self, inputs: &[HostTensor]) -> Result<()> {
        if inputs.len() != self.entry.inputs.len() {
            bail!(
                "{}: expected {} inputs, got {}",
                self.entry.name,
                self.entry.inputs.len(),
                inputs.len()
            );
        }
        for (i, (t, spec)) in inputs.iter().zip(&self.entry.inputs).enumerate() {
            if t.dtype() != spec.dtype {
                bail!(
                    "{} input {i}: dtype {} != manifest {}",
                    self.entry.name,
                    t.dtype().name(),
                    spec.dtype.name()
                );
            }
            if t.shape() != spec.shape.as_slice() {
                bail!(
                    "{} input {i}: shape {:?} != manifest {:?}",
                    self.entry.name,
                    t.shape(),
                    spec.shape
                );
            }
        }
        Ok(())
    }
}

/// The runtime: PJRT CPU client + manifest + compiled-executable cache.
///
/// Not `Send`/`Sync` (the xla crate wraps raw pointers); confine to one
/// thread — the coordinator gives the engine its own thread.
pub struct Runtime {
    client: xla::PjRtClient,
    pub manifest: Manifest,
    cache: RefCell<HashMap<String, Rc<Compiled>>>,
}

impl Runtime {
    /// Create a CPU-PJRT runtime over an artifact directory.
    pub fn new(artifact_dir: &str) -> Result<Runtime> {
        let manifest = Manifest::load(artifact_dir)?;
        let client = xla::PjRtClient::cpu().context("creating PJRT CPU client")?;
        crate::info!(
            "runtime up: platform={} devices={} artifacts={}",
            client.platform_name(),
            client.device_count(),
            manifest.entries.len()
        );
        Ok(Runtime { client, manifest, cache: RefCell::new(HashMap::new()) })
    }

    /// Compile (or fetch from cache) an artifact by manifest name.
    pub fn load(&self, name: &str) -> Result<Rc<Compiled>> {
        if let Some(c) = self.cache.borrow().get(name) {
            return Ok(c.clone());
        }
        let entry = self.manifest.entry(name)?.clone();
        let path = self.manifest.hlo_path(&entry);
        let t0 = Instant::now();
        let proto = xla::HloModuleProto::from_text_file(
            path.to_str().context("non-utf8 path")?,
        )
        .with_context(|| format!("parsing HLO text {}", path.display()))?;
        let comp = xla::XlaComputation::from_proto(&proto);
        let exe = self
            .client
            .compile(&comp)
            .with_context(|| format!("compiling {}", name))?;
        crate::debug!("compiled {name} in {:.2}s", t0.elapsed().as_secs_f64());
        let compiled = Rc::new(Compiled { entry, exe });
        self.cache.borrow_mut().insert(name.to_string(), compiled.clone());
        Ok(compiled)
    }

    /// One-shot convenience: load + run.
    pub fn run(&self, name: &str, inputs: &[HostTensor]) -> Result<Vec<HostTensor>> {
        self.load(name)?.run(inputs)
    }

    /// Number of executables compiled so far.
    pub fn compiled_count(&self) -> usize {
        self.cache.borrow().len()
    }

    /// Stage an f32 host buffer on the device (for reused inputs).
    pub fn stage_f32(&self, data: &[f32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).context("staging f32 buffer")
    }

    /// Stage an i8 host buffer on the device.
    pub fn stage_i8(&self, data: &[i8], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).context("staging i8 buffer")
    }

    /// Stage an i32 host buffer on the device.
    pub fn stage_i32(&self, data: &[i32], dims: &[usize]) -> Result<xla::PjRtBuffer> {
        self.client.buffer_from_host_buffer(data, dims, None).context("staging i32 buffer")
    }
}

// Unit tests for validation logic are in rust/tests/runtime_artifacts.rs
// (they need real artifacts + libxla; `Manifest`-level parsing is unit
// tested in artifacts.rs).
