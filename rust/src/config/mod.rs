//! Typed configuration: JSON files + CLI overrides.
//!
//! Two config surfaces:
//! * [`ServeConfig`] — everything the `kvq serve`/`serve_demo` path needs
//!   (model, precision, cache sizing, batching, sharding, HTTP port).
//!   Loadable from a JSON file (`--config path`) with CLI flags taking
//!   precedence.
//! * [`shapes`] — the shared bench-shape registry
//!   (`configs/bench_shapes.json`), the same file aot.py lowers from, so
//!   Rust benches and Python artifacts can never drift apart.
//!
//! Every knob has exactly one home: [`ServeConfig::set`] is the single
//! edit site that knows a key's spelling, coercion, and validation. JSON
//! files, CLI flags (via the [`CLI_FLAGS`] alias table), and the
//! [`ServeConfigBuilder`] all funnel through it, and `GET /config`
//! renders from the struct — adding a knob is one `set` arm + one flag
//! alias + one line in the response, instead of four hand-kept sites.

pub mod shapes;

use crate::coordinator::admission::{AdmissionConfig, AdmissionMode};
use crate::coordinator::batcher::BatcherConfig;
use crate::coordinator::router::{Affinity, RoutePolicy, RouterConfig};
use crate::coordinator::DecodeBatching;
use crate::kvcache::{PolicySpec, Precision};
use crate::model::runner::DecodeKernel;
use crate::quant::simd::KernelBackend;
use crate::quant::Variant;
use crate::util::args::Args;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// Which backend executes the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifacts via PJRT (production path).
    Pjrt,
    /// Pure-Rust oracle (no artifacts needed; slow but dependency-free).
    CpuRef,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s {
            "pjrt" => Backend::Pjrt,
            "cpu" | "cpu-ref" => Backend::CpuRef,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Backend::Pjrt => "pjrt",
            Backend::CpuRef => "cpu",
        }
    }
}

/// Full serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: String,
    pub backend: Backend,
    /// Cache quantization policy. The legacy `--precision X` /
    /// `"precision"` knobs map to `uniform:X`; `--quant-policy` /
    /// `"quant_policy"` additionally accept `k8v4`, `sink8[:N]`, and
    /// paths to JSON per-layer tables (see `configs/`). Non-staging
    /// policies (mixed precision, INT4 anywhere) require `--backend cpu`
    /// with paged decode on.
    pub quant_policy: PolicySpec,
    pub decode_kernel: DecodeKernel,
    pub artifact_dir: String,
    pub weight_seed: u64,
    pub num_blocks: Option<usize>,
    pub expected_concurrency: usize,
    pub scale_margin: f32,
    pub batcher: BatcherConfig,
    pub port: u16,
    /// Worker count for the parallel quantization runtime (0 = auto:
    /// available parallelism, `KVQ_THREADS` override).
    pub parallelism: usize,
    /// Logical block budget of the cross-request prefix cache (repeated
    /// prompts fork cached INT8 blocks instead of re-prefilling). 0
    /// disables sharing.
    pub prefix_cache_blocks: usize,
    /// Fused dequant-attention kernel variant for the zero-copy paged
    /// decode path (naive|tiled|coarsened|vectorized). Access pattern
    /// only — outputs are bit-identical across variants.
    pub attention_kernel: Variant,
    /// Attend directly over the paged cache when the backend supports it
    /// (default true; PJRT always stages regardless). `false` forces the
    /// legacy gather-into-staging decode.
    pub paged_decode: bool,
    /// Kernel backend for the host-side hot loops (`auto|scalar|simd`,
    /// `KVQ_KERNEL_BACKEND` env override). `auto` dispatches to the best
    /// ISA the CPU reports (AVX2 on x86_64, NEON on aarch64); `scalar`
    /// reproduces legacy bytes exactly. The selected ISA shows up at
    /// `GET /metrics` as `kernel_isa`.
    pub kernel_backend: KernelBackend,
    /// Fused multi-query batched decode (`auto|off`,
    /// `KVQ_DECODE_BATCHING` env override). `auto` regroups each decode
    /// wave into per-(layer, head) passes that dequantize every physical
    /// cache block at most once per wave; `off` forces the per-sequence
    /// path. Outputs are bit-identical either way.
    pub decode_batching: DecodeBatching,
    /// Engine shard count. Each shard owns its own block pool, prefix
    /// cache, and engine thread; the router front door spreads sessions
    /// across them (`--shards`).
    pub shards: usize,
    /// Home-shard selection: `session` (default; hash of the client
    /// session key, prompt-prefix fallback), `prefix`, or `none`
    /// (pure least-loaded dispatch).
    pub affinity: Affinity,
    /// Per-shard admission bound: live depth at which a shard stops
    /// taking new requests (spillover, then overflow). 0 = unbounded.
    pub queue_depth: usize,
    /// Router overflow queue capacity once every shard is saturated;
    /// beyond it, submissions get a typed 503.
    pub overflow_depth: usize,
    /// Compressed cold-tier capacity in blocks (`--cold-tier-blocks`).
    /// Unset = auto-size to the hot pool; `0` disables the tier. Only
    /// engages when the prefix cache is on; `KVQ_COLD_TIER` env
    /// overrides.
    pub cold_tier_blocks: Option<usize>,
    /// Persistent prefix snapshot path (`--snapshot-path`): the cold
    /// tier (plus the trie, demoted at drain) is written here on engine
    /// exit and reloaded at startup. Unset = no persistence.
    pub snapshot_path: Option<String>,
    /// Cold-tier async prefetch ready-map depth (`--prefetch-depth`);
    /// 0 = synchronous decompression only.
    pub prefetch_depth: usize,
    /// Default per-request deadline in milliseconds
    /// (`--default-deadline-ms`); applied to requests that don't carry
    /// their own `deadline_ms`. 0 = no default deadline.
    pub default_deadline_ms: u64,
    /// Watchdog threshold (`--stall-timeout-ms`): a running stream that
    /// makes no token progress for this long is flagged, then cancelled
    /// with `FinishReason::Stalled` at 2x. 0 = watchdog off.
    pub stall_timeout_ms: u64,
    /// Deterministic fault-injection spec (`--fault-spec`): inline JSON
    /// rule array or a path to one, same grammar as the `KVQ_FAULT` env
    /// var (see `util::fault`). Empty string clears; unset = no faults.
    pub fault_spec: Option<String>,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "kvq-3m".into(),
            backend: Backend::Pjrt,
            quant_policy: PolicySpec::uniform(Precision::Int8),
            decode_kernel: DecodeKernel::PlainXla,
            artifact_dir: crate::runtime::default_artifact_dir(),
            weight_seed: 0xA11CE,
            num_blocks: None,
            expected_concurrency: 8,
            scale_margin: 1.0,
            batcher: BatcherConfig::default(),
            port: 8080,
            parallelism: 0,
            prefix_cache_blocks: 0,
            attention_kernel: Variant::Vectorized,
            paged_decode: true,
            kernel_backend: KernelBackend::Auto,
            decode_batching: DecodeBatching::Auto,
            shards: 1,
            affinity: Affinity::Session,
            queue_depth: 0,
            overflow_depth: 256,
            cold_tier_blocks: None,
            snapshot_path: None,
            prefetch_depth: 2,
            default_deadline_ms: 0,
            stall_timeout_ms: 0,
            fault_spec: None,
        }
    }
}

/// CLI flag → config key aliases, applied in order (so `--quant-policy`
/// beats `--precision` regardless of argv order, matching the JSON
/// later-key-wins rule). Legacy spellings (`--threads`, `--concurrency`,
/// `--artifacts`, `--max-prefills`) keep working here.
pub const CLI_FLAGS: &[(&str, &str)] = &[
    ("model", "model"),
    ("backend", "backend"),
    ("precision", "precision"),
    ("quant-policy", "quant_policy"),
    ("decode-kernel", "decode_kernel"),
    ("artifacts", "artifact_dir"),
    ("artifact-dir", "artifact_dir"),
    ("weight-seed", "weight_seed"),
    ("num-blocks", "num_blocks"),
    ("concurrency", "expected_concurrency"),
    ("scale-margin", "scale_margin"),
    ("port", "port"),
    ("threads", "parallelism"),
    ("admission-mode", "admission_mode"),
    ("prefix-cache-blocks", "prefix_cache_blocks"),
    ("attention-kernel", "attention_kernel"),
    ("paged-decode", "paged_decode"),
    ("kernel-backend", "kernel_backend"),
    ("decode-batching", "decode_batching"),
    ("max-running", "max_running"),
    ("max-waiting", "max_waiting"),
    ("watermark", "watermark"),
    ("max-prefills", "max_prefills_per_step"),
    ("max-decode-batch", "max_decode_batch"),
    ("shards", "shards"),
    ("affinity", "affinity"),
    ("queue-depth", "queue_depth"),
    ("overflow-depth", "overflow_depth"),
    ("cold-tier-blocks", "cold_tier_blocks"),
    ("snapshot-path", "snapshot_path"),
    ("prefetch-depth", "prefetch_depth"),
    ("default-deadline-ms", "default_deadline_ms"),
    ("stall-timeout-ms", "stall_timeout_ms"),
    ("fault-spec", "fault_spec"),
];

impl ServeConfig {
    pub fn builder() -> ServeConfigBuilder {
        ServeConfigBuilder { cfg: ServeConfig::default() }
    }

    /// Load from a JSON file (missing keys keep defaults).
    pub fn from_file(path: &str) -> Result<ServeConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing config {path}"))?;
        let mut c = ServeConfig::default();
        c.apply_json(&j)?;
        Ok(c)
    }

    /// Set one knob by its JSON key. Returns `Ok(false)` for unknown
    /// keys (the caller decides whether that's an error); bad values
    /// error. String values are coerced for numeric/bool knobs so the
    /// CLI path reuses the same arms.
    pub fn set(&mut self, key: &str, v: &Json) -> Result<bool> {
        match key {
            "model" => self.model = str_val(key, v)?.to_string(),
            "backend" => {
                let s = str_val(key, v)?;
                self.backend = Backend::parse(s).ok_or_else(|| anyhow!("bad backend {s:?}"))?;
            }
            "precision" => {
                let s = str_val(key, v)?;
                let p = Precision::parse(s).ok_or_else(|| anyhow!("bad precision {s:?}"))?;
                self.quant_policy = PolicySpec::uniform(p);
            }
            "quant_policy" => {
                let s = str_val(key, v)?;
                self.quant_policy =
                    PolicySpec::parse(s).with_context(|| format!("bad quant_policy {s:?}"))?;
            }
            "decode_kernel" => {
                self.decode_kernel = match str_val(key, v)? {
                    "plain" | "xla" => DecodeKernel::PlainXla,
                    "pallas" => DecodeKernel::Pallas,
                    s => return Err(anyhow!("bad decode_kernel {s:?}")),
                };
            }
            "artifact_dir" => self.artifact_dir = str_val(key, v)?.to_string(),
            "weight_seed" => self.weight_seed = usize_val(key, v)? as u64,
            "num_blocks" => self.num_blocks = Some(usize_val(key, v)?),
            "expected_concurrency" => self.expected_concurrency = usize_val(key, v)?,
            "scale_margin" => self.scale_margin = f64_val(key, v)? as f32,
            "port" => self.port = usize_val(key, v)? as u16,
            "parallelism" => self.parallelism = usize_val(key, v)?,
            "admission_mode" => {
                let s = str_val(key, v)?;
                self.batcher.admission.mode =
                    AdmissionMode::parse(s).ok_or_else(|| anyhow!("bad admission_mode {s:?}"))?;
            }
            "prefix_cache_blocks" => self.prefix_cache_blocks = usize_val(key, v)?,
            "attention_kernel" => {
                let s = str_val(key, v)?;
                self.attention_kernel =
                    Variant::from_name(s).ok_or_else(|| anyhow!("bad attention_kernel {s:?}"))?;
            }
            "paged_decode" => self.paged_decode = bool_val(key, v)?,
            "kernel_backend" => {
                let s = str_val(key, v)?;
                self.kernel_backend = KernelBackend::parse(s)
                    .ok_or_else(|| anyhow!("bad kernel_backend {s:?} (auto|scalar|simd)"))?;
            }
            "decode_batching" => {
                let s = str_val(key, v)?;
                self.decode_batching = DecodeBatching::parse(s)
                    .ok_or_else(|| anyhow!("bad decode_batching {s:?} (auto|off)"))?;
            }
            "max_running" => self.batcher.admission.max_running = usize_val(key, v)?,
            "max_waiting" => self.batcher.admission.max_waiting = usize_val(key, v)?,
            "watermark" => self.batcher.admission.watermark = f64_val(key, v)?,
            "max_prefills_per_step" => self.batcher.max_prefills_per_step = usize_val(key, v)?,
            "max_decode_batch" => self.batcher.max_decode_batch = usize_val(key, v)?,
            "shards" => self.shards = usize_val(key, v)?.max(1),
            "affinity" => {
                let s = str_val(key, v)?;
                self.affinity = Affinity::parse(s)
                    .ok_or_else(|| anyhow!("bad affinity {s:?} (session|prefix|none)"))?;
            }
            "queue_depth" => self.queue_depth = usize_val(key, v)?,
            "overflow_depth" => self.overflow_depth = usize_val(key, v)?,
            "cold_tier_blocks" => self.cold_tier_blocks = Some(usize_val(key, v)?),
            "snapshot_path" => {
                let s = str_val(key, v)?;
                self.snapshot_path = if s.is_empty() { None } else { Some(s.to_string()) };
            }
            "prefetch_depth" => self.prefetch_depth = usize_val(key, v)?,
            "default_deadline_ms" => self.default_deadline_ms = usize_val(key, v)? as u64,
            "stall_timeout_ms" => self.stall_timeout_ms = usize_val(key, v)? as u64,
            "fault_spec" => {
                let s = str_val(key, v)?;
                self.fault_spec = if s.is_empty() { None } else { Some(s.to_string()) };
            }
            _ => return Ok(false),
        }
        Ok(true)
    }

    /// Apply a JSON document. Unknown keys are ignored (configs are
    /// shared with Python tooling); known keys with bad values error.
    /// Keys apply in document (alphabetical) order, so `quant_policy`
    /// wins over the legacy `precision` shorthand.
    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        let Json::Obj(map) = j else { return Ok(()) };
        for (k, v) in map {
            if matches!(v, Json::Null) {
                continue;
            }
            self.set(k, v)?;
        }
        Ok(())
    }

    /// Apply CLI overrides (flags win over file values) via the
    /// [`CLI_FLAGS`] alias table.
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        for &(flag, key) in CLI_FLAGS {
            if let Some(v) = args.get(flag) {
                let jv = Json::Str(v.to_string());
                self.set(key, &jv).with_context(|| format!("--{flag}"))?;
            }
        }
        Ok(())
    }

    /// Engine config slice of this serve config (one per shard).
    pub fn engine_config(&self) -> crate::coordinator::EngineConfig {
        crate::coordinator::EngineConfig {
            quant_policy: self.quant_policy.clone(),
            num_blocks: self.num_blocks,
            expected_concurrency: self.expected_concurrency,
            scale_margin: self.scale_margin,
            batcher: self.batcher,
            seed: self.weight_seed,
            parallelism: self.parallelism,
            prefix_cache_blocks: self.prefix_cache_blocks,
            attention_kernel: self.attention_kernel,
            paged_decode: self.paged_decode,
            kernel_backend: self.kernel_backend,
            decode_batching: self.decode_batching,
            cold_tier_blocks: self.cold_tier_blocks,
            snapshot_path: self.snapshot_path.clone(),
            prefetch_depth: self.prefetch_depth,
            stall_timeout_ms: self.stall_timeout_ms,
        }
    }

    /// Router config slice of this serve config: least-loaded dispatch
    /// under the configured affinity and queue bounds.
    pub fn router_config(&self) -> RouterConfig {
        RouterConfig {
            policy: RoutePolicy::LeastLoaded,
            affinity: self.affinity,
            queue_depth: self.queue_depth,
            overflow_depth: self.overflow_depth,
            default_deadline_ms: self.default_deadline_ms,
        }
    }

    pub fn admission(&self) -> &AdmissionConfig {
        &self.batcher.admission
    }

    /// Legacy `precision` shorthand for the wire schema: the uniform
    /// precision name, or `"mixed"`.
    pub fn precision_label(&self) -> &'static str {
        match self.quant_policy {
            PolicySpec::Uniform(p) => p.name(),
            _ => "mixed",
        }
    }
}

/// Chainable builder over [`ServeConfig::set`] — the programmatic way to
/// assemble a config (benches, tests) without touching JSON or argv.
pub struct ServeConfigBuilder {
    cfg: ServeConfig,
}

impl ServeConfigBuilder {
    pub fn backend(mut self, b: Backend) -> Self {
        self.cfg.backend = b;
        self
    }

    pub fn quant_policy(mut self, p: PolicySpec) -> Self {
        self.cfg.quant_policy = p;
        self
    }

    pub fn shards(mut self, n: usize) -> Self {
        self.cfg.shards = n.max(1);
        self
    }

    pub fn affinity(mut self, a: Affinity) -> Self {
        self.cfg.affinity = a;
        self
    }

    pub fn queue_depth(mut self, n: usize) -> Self {
        self.cfg.queue_depth = n;
        self
    }

    pub fn overflow_depth(mut self, n: usize) -> Self {
        self.cfg.overflow_depth = n;
        self
    }

    pub fn num_blocks(mut self, n: usize) -> Self {
        self.cfg.num_blocks = Some(n);
        self
    }

    pub fn port(mut self, p: u16) -> Self {
        self.cfg.port = p;
        self
    }

    /// Escape hatch: any knob by its JSON key.
    pub fn set(mut self, key: &str, v: &Json) -> Result<Self> {
        if !self.cfg.set(key, v)? {
            return Err(anyhow!("unknown config key {key:?}"));
        }
        Ok(self)
    }

    pub fn build(self) -> ServeConfig {
        self.cfg
    }
}

fn str_val<'a>(key: &str, v: &'a Json) -> Result<&'a str> {
    v.as_str().ok_or_else(|| anyhow!("{key}: expected a string"))
}

fn usize_val(key: &str, v: &Json) -> Result<usize> {
    if let Some(n) = v.as_usize() {
        return Ok(n);
    }
    if let Some(s) = v.as_str() {
        return s.trim().parse::<usize>().map_err(|_| anyhow!("{key}: bad count {s:?}"));
    }
    Err(anyhow!("{key}: expected a non-negative integer"))
}

fn f64_val(key: &str, v: &Json) -> Result<f64> {
    if let Some(n) = v.as_f64() {
        return Ok(n);
    }
    if let Some(s) = v.as_str() {
        return s.trim().parse::<f64>().map_err(|_| anyhow!("{key}: bad number {s:?}"));
    }
    Err(anyhow!("{key}: expected a number"))
}

fn bool_val(key: &str, v: &Json) -> Result<bool> {
    if let Some(b) = v.as_bool() {
        return Ok(b);
    }
    match v.as_str() {
        Some("true") | Some("1") | Some("on") => Ok(true),
        Some("false") | Some("0") | Some("off") => Ok(false),
        _ => Err(anyhow!("{key}: expected a bool (true|false)")),
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Int8));
        assert_eq!(c.backend, Backend::Pjrt);
        assert_eq!(c.port, 8080);
        assert_eq!(c.shards, 1);
        assert_eq!(c.affinity, Affinity::Session);
        assert_eq!(c.queue_depth, 0);
    }

    #[test]
    fn json_overrides() {
        let mut c = ServeConfig::default();
        let j = Json::parse(
            r#"{"model":"kvq-25m","precision":"fp32","port":9000,
                "max_running":4,"decode_kernel":"pallas","backend":"cpu",
                "parallelism":3,"admission_mode":"worst_case",
                "prefix_cache_blocks":256}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.model, "kvq-25m");
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Fp32));
        assert_eq!(c.port, 9000);
        assert_eq!(c.batcher.admission.max_running, 4);
        assert_eq!(c.decode_kernel, DecodeKernel::Pallas);
        assert_eq!(c.backend, Backend::CpuRef);
        assert_eq!(c.parallelism, 3);
        assert_eq!(c.engine_config().parallelism, 3);
        assert_eq!(c.batcher.admission.mode, AdmissionMode::WorstCase);
        assert_eq!(c.prefix_cache_blocks, 256);
        assert_eq!(c.engine_config().prefix_cache_blocks, 256);
    }

    #[test]
    fn defaults_admit_optimistically_without_prefix_cache() {
        let c = ServeConfig::default();
        assert_eq!(c.batcher.admission.mode, AdmissionMode::Optimistic);
        assert_eq!(c.prefix_cache_blocks, 0);
    }

    #[test]
    fn quant_policy_knob_round_trips() {
        // JSON key: presets parse, legacy "precision" still works, and
        // the later key wins.
        let mut c = ServeConfig::default();
        c.apply_json(&Json::parse(r#"{"quant_policy":"k8v4"}"#).unwrap()).unwrap();
        assert_eq!(c.quant_policy, PolicySpec::K8V4);
        assert_eq!(c.engine_config().quant_policy, PolicySpec::K8V4);
        c.apply_json(&Json::parse(r#"{"quant_policy":"sink8:2"}"#).unwrap()).unwrap();
        assert_eq!(c.quant_policy, PolicySpec::Sink8 { sink_layers: 2 });
        // Legacy precision spelling maps onto the uniform preset...
        c.apply_json(&Json::parse(r#"{"precision":"int4"}"#).unwrap()).unwrap();
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Int4));
        // ...and an explicit quant_policy in the same document wins.
        c.apply_json(&Json::parse(r#"{"precision":"int8","quant_policy":"k8v4"}"#).unwrap())
            .unwrap();
        assert_eq!(c.quant_policy, PolicySpec::K8V4);
        // CLI flags: --quant-policy beats --precision; bad values error.
        let args = Args::parse_from(
            ["--precision", "fp32", "--quant-policy", "uniform:int8"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Int8));
        assert_eq!(c.quant_policy.engine_label(), "int8");
        let bad = Args::parse_from(["--quant-policy", "sink8:x"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
        assert!(ServeConfig::default()
            .apply_json(&Json::parse(r#"{"quant_policy":"warp"}"#).unwrap())
            .is_err());
    }

    #[test]
    fn quant_policy_loads_json_tables_from_disk() {
        // The shipped example table under configs/ parses through the
        // same --quant-policy path the CLI uses.
        for base in ["configs", "../configs", "../../configs"] {
            let path = format!("{base}/policy_sink_mixed.json");
            if std::path::Path::new(&path).exists() {
                let mut c = ServeConfig::default();
                let args = Args::parse_from(["--quant-policy".to_string(), path.clone()]);
                c.apply_args(&args).unwrap();
                let PolicySpec::Table(t) = &c.quant_policy else {
                    panic!("expected a table policy")
                };
                assert_eq!(t.name, c.quant_policy.name());
                return;
            }
        }
        panic!("configs/policy_sink_mixed.json not found from cwd");
    }

    #[test]
    fn bad_values_error() {
        let mut c = ServeConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"precision":"int99"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"backend":"tpu"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"admission_mode":"psychic"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"attention_kernel":"warp"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"kernel_backend":"warp"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"decode_batching":"turbo"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"affinity":"sticky"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"shards":"many"}"#).unwrap()).is_err());
    }

    #[test]
    fn kernel_backend_knob_round_trips() {
        let mut c = ServeConfig::default();
        assert_eq!(c.kernel_backend, KernelBackend::Auto, "auto is the default");
        c.apply_json(&Json::parse(r#"{"kernel_backend":"scalar"}"#).unwrap()).unwrap();
        assert_eq!(c.kernel_backend, KernelBackend::Scalar);
        assert_eq!(c.engine_config().kernel_backend, KernelBackend::Scalar);
        // CLI wins over the file.
        let args = Args::parse_from(["--kernel-backend", "simd"].iter().map(|s| s.to_string()));
        c.apply_args(&args).unwrap();
        assert_eq!(c.kernel_backend, KernelBackend::Simd);
        let bad = Args::parse_from(["--kernel-backend", "avx9"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn decode_batching_knob_round_trips() {
        let mut c = ServeConfig::default();
        assert_eq!(c.decode_batching, DecodeBatching::Auto, "auto is the default");
        c.apply_json(&Json::parse(r#"{"decode_batching":"off"}"#).unwrap()).unwrap();
        assert_eq!(c.decode_batching, DecodeBatching::Off);
        assert_eq!(c.engine_config().decode_batching, DecodeBatching::Off);
        // CLI wins over the file.
        let args = Args::parse_from(["--decode-batching", "auto"].iter().map(|s| s.to_string()));
        c.apply_args(&args).unwrap();
        assert_eq!(c.decode_batching, DecodeBatching::Auto);
        let bad = Args::parse_from(["--decode-batching", "turbo"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn attention_kernel_and_paged_decode_knobs() {
        let mut c = ServeConfig::default();
        assert_eq!(c.attention_kernel, Variant::Vectorized);
        assert!(c.paged_decode);
        c.apply_json(
            &Json::parse(r#"{"attention_kernel":"coarsened","paged_decode":false}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.attention_kernel, Variant::Coarsened);
        assert!(!c.paged_decode);
        assert_eq!(c.engine_config().attention_kernel, Variant::Coarsened);
        assert!(!c.engine_config().paged_decode);
        // CLI wins over the file.
        let args = Args::parse_from(
            ["--attention-kernel", "tiled", "--paged-decode", "true"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.attention_kernel, Variant::Tiled);
        assert!(c.paged_decode);
        let bad = Args::parse_from(["--attention-kernel", "warp"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn args_override_file() {
        let mut c = ServeConfig::default();
        c.apply_json(&Json::parse(r#"{"port":9000}"#).unwrap()).unwrap();
        let args = Args::parse_from(
            [
                "--port", "9100", "--precision", "fp32", "--threads", "2",
                "--admission-mode", "worst-case", "--prefix-cache-blocks", "128",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.port, 9100);
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Fp32));
        assert_eq!(c.parallelism, 2);
        assert_eq!(c.batcher.admission.mode, AdmissionMode::WorstCase);
        assert_eq!(c.prefix_cache_blocks, 128);
    }

    #[test]
    fn shard_knobs_round_trip() {
        let mut c = ServeConfig::default();
        c.apply_json(
            &Json::parse(r#"{"shards":4,"affinity":"prefix","queue_depth":8,"overflow_depth":32}"#)
                .unwrap(),
        )
        .unwrap();
        assert_eq!(c.shards, 4);
        assert_eq!(c.affinity, Affinity::Prefix);
        assert_eq!(c.queue_depth, 8);
        assert_eq!(c.overflow_depth, 32);
        let rc = c.router_config();
        assert_eq!(rc.policy, RoutePolicy::LeastLoaded);
        assert_eq!(rc.affinity, Affinity::Prefix);
        assert_eq!(rc.queue_depth, 8);
        assert_eq!(rc.overflow_depth, 32);
        // CLI wins over the file; shards clamps to >= 1.
        let args = Args::parse_from(
            ["--shards", "0", "--affinity", "none", "--queue-depth", "2"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.shards, 1);
        assert_eq!(c.affinity, Affinity::None);
        assert_eq!(c.queue_depth, 2);
    }

    #[test]
    fn tier_knobs_round_trip() {
        let mut c = ServeConfig::default();
        assert_eq!(c.cold_tier_blocks, None, "auto-size is the default");
        assert_eq!(c.snapshot_path, None);
        assert_eq!(c.prefetch_depth, 2);
        c.apply_json(
            &Json::parse(
                r#"{"cold_tier_blocks":128,"snapshot_path":"/tmp/kvq.snap","prefetch_depth":4}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.cold_tier_blocks, Some(128));
        assert_eq!(c.snapshot_path.as_deref(), Some("/tmp/kvq.snap"));
        assert_eq!(c.prefetch_depth, 4);
        let ec = c.engine_config();
        assert_eq!(ec.cold_tier_blocks, Some(128));
        assert_eq!(ec.snapshot_path.as_deref(), Some("/tmp/kvq.snap"));
        assert_eq!(ec.prefetch_depth, 4);
        // CLI wins over the file; 0 means "tier off"; empty path clears.
        let args = Args::parse_from(
            ["--cold-tier-blocks", "0", "--snapshot-path", "", "--prefetch-depth", "0"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.cold_tier_blocks, Some(0));
        assert_eq!(c.snapshot_path, None);
        assert_eq!(c.prefetch_depth, 0);
        let bad =
            Args::parse_from(["--cold-tier-blocks", "icy"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn robustness_knobs_round_trip() {
        let mut c = ServeConfig::default();
        assert_eq!(c.default_deadline_ms, 0, "no default deadline");
        assert_eq!(c.stall_timeout_ms, 0, "watchdog off by default");
        assert_eq!(c.fault_spec, None);
        c.apply_json(
            &Json::parse(
                r#"{"default_deadline_ms":2500,"stall_timeout_ms":400,
                    "fault_spec":"[{\"site\":\"prefill\",\"action\":\"panic\"}]"}"#,
            )
            .unwrap(),
        )
        .unwrap();
        assert_eq!(c.default_deadline_ms, 2500);
        assert_eq!(c.stall_timeout_ms, 400);
        assert!(c.fault_spec.as_deref().unwrap().contains("prefill"));
        assert_eq!(c.router_config().default_deadline_ms, 2500);
        assert_eq!(c.engine_config().stall_timeout_ms, 400);
        // CLI wins over the file; an empty fault spec clears it.
        let args = Args::parse_from(
            [
                "--default-deadline-ms", "100", "--stall-timeout-ms", "0",
                "--fault-spec", "",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.default_deadline_ms, 100);
        assert_eq!(c.stall_timeout_ms, 0);
        assert_eq!(c.fault_spec, None);
        let bad =
            Args::parse_from(["--default-deadline-ms", "soon"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn builder_assembles_configs() {
        let c = ServeConfig::builder()
            .backend(Backend::CpuRef)
            .shards(2)
            .affinity(Affinity::Session)
            .queue_depth(4)
            .overflow_depth(16)
            .num_blocks(64)
            .port(0)
            .set("model", &Json::Str("test-tiny".into()))
            .unwrap()
            .build();
        assert_eq!(c.backend, Backend::CpuRef);
        assert_eq!(c.shards, 2);
        assert_eq!(c.queue_depth, 4);
        assert_eq!(c.num_blocks, Some(64));
        assert_eq!(c.model, "test-tiny");
        assert!(ServeConfig::builder().set("warp_factor", &Json::Num(9.0)).is_err());
    }

    #[test]
    fn string_coercion_serves_the_cli_path() {
        // The CLI funnels through set() with string values: numerics and
        // bools coerce, garbage errors.
        let mut c = ServeConfig::default();
        assert!(c.set("port", &Json::Str("9100".into())).unwrap());
        assert_eq!(c.port, 9100);
        assert!(c.set("watermark", &Json::Str("0.5".into())).unwrap());
        assert!((c.batcher.admission.watermark - 0.5).abs() < 1e-12);
        assert!(c.set("paged_decode", &Json::Str("off".into())).unwrap());
        assert!(!c.paged_decode);
        assert!(c.set("port", &Json::Str("a lot".into())).is_err());
        assert!(!c.set("unknown_knob", &Json::Num(1.0)).unwrap(), "unknown keys report false");
    }
}
