//! Typed configuration: JSON files + CLI overrides.
//!
//! Two config surfaces:
//! * [`ServeConfig`] — everything the `kvq serve`/`serve_demo` path needs
//!   (model, precision, cache sizing, batching, HTTP port). Loadable from
//!   a JSON file (`--config path`) with CLI flags taking precedence.
//! * [`shapes`] — the shared bench-shape registry
//!   (`configs/bench_shapes.json`), the same file aot.py lowers from, so
//!   Rust benches and Python artifacts can never drift apart.

pub mod shapes;

use crate::coordinator::admission::{AdmissionConfig, AdmissionMode};
use crate::coordinator::batcher::BatcherConfig;
use crate::kvcache::{PolicySpec, Precision};
use crate::model::runner::DecodeKernel;
use crate::quant::simd::KernelBackend;
use crate::quant::Variant;
use crate::util::args::Args;
use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

/// Which backend executes the model.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Backend {
    /// AOT artifacts via PJRT (production path).
    Pjrt,
    /// Pure-Rust oracle (no artifacts needed; slow but dependency-free).
    CpuRef,
}

impl Backend {
    pub fn parse(s: &str) -> Option<Backend> {
        Some(match s {
            "pjrt" => Backend::Pjrt,
            "cpu" | "cpu-ref" => Backend::CpuRef,
            _ => return None,
        })
    }
}

/// Full serving configuration.
#[derive(Debug, Clone)]
pub struct ServeConfig {
    pub model: String,
    pub backend: Backend,
    /// Cache quantization policy. The legacy `--precision X` /
    /// `"precision"` knobs map to `uniform:X`; `--quant-policy` /
    /// `"quant_policy"` additionally accept `k8v4`, `sink8[:N]`, and
    /// paths to JSON per-layer tables (see `configs/`). Non-staging
    /// policies (mixed precision, INT4 anywhere) require `--backend cpu`
    /// with paged decode on.
    pub quant_policy: PolicySpec,
    pub decode_kernel: DecodeKernel,
    pub artifact_dir: String,
    pub weight_seed: u64,
    pub num_blocks: Option<usize>,
    pub expected_concurrency: usize,
    pub scale_margin: f32,
    pub batcher: BatcherConfig,
    pub port: u16,
    /// Worker count for the parallel quantization runtime (0 = auto:
    /// available parallelism, `KVQ_THREADS` override).
    pub parallelism: usize,
    /// Logical block budget of the cross-request prefix cache (repeated
    /// prompts fork cached INT8 blocks instead of re-prefilling). 0
    /// disables sharing.
    pub prefix_cache_blocks: usize,
    /// Fused dequant-attention kernel variant for the zero-copy paged
    /// decode path (naive|tiled|coarsened|vectorized). Access pattern
    /// only — outputs are bit-identical across variants.
    pub attention_kernel: Variant,
    /// Attend directly over the paged cache when the backend supports it
    /// (default true; PJRT always stages regardless). `false` forces the
    /// legacy gather-into-staging decode.
    pub paged_decode: bool,
    /// Kernel backend for the host-side hot loops (`auto|scalar|simd`,
    /// `KVQ_KERNEL_BACKEND` env override). `auto` dispatches to the best
    /// ISA the CPU reports (AVX2 on x86_64, NEON on aarch64); `scalar`
    /// reproduces legacy bytes exactly. The selected ISA shows up at
    /// `GET /metrics` as `kernel_isa`.
    pub kernel_backend: KernelBackend,
}

impl Default for ServeConfig {
    fn default() -> Self {
        ServeConfig {
            model: "kvq-3m".into(),
            backend: Backend::Pjrt,
            quant_policy: PolicySpec::uniform(Precision::Int8),
            decode_kernel: DecodeKernel::PlainXla,
            artifact_dir: crate::runtime::default_artifact_dir(),
            weight_seed: 0xA11CE,
            num_blocks: None,
            expected_concurrency: 8,
            scale_margin: 1.0,
            batcher: BatcherConfig::default(),
            port: 8080,
            parallelism: 0,
            prefix_cache_blocks: 0,
            attention_kernel: Variant::Vectorized,
            paged_decode: true,
            kernel_backend: KernelBackend::Auto,
        }
    }
}

impl ServeConfig {
    /// Load from a JSON file (missing keys keep defaults).
    pub fn from_file(path: &str) -> Result<ServeConfig> {
        let text =
            std::fs::read_to_string(path).with_context(|| format!("reading config {path}"))?;
        let j = Json::parse(&text).with_context(|| format!("parsing config {path}"))?;
        let mut c = ServeConfig::default();
        c.apply_json(&j)?;
        Ok(c)
    }

    pub fn apply_json(&mut self, j: &Json) -> Result<()> {
        if let Some(v) = j.get("model").as_str() {
            self.model = v.to_string();
        }
        if let Some(v) = j.get("backend").as_str() {
            self.backend = Backend::parse(v).ok_or_else(|| anyhow!("bad backend {v:?}"))?;
        }
        if let Some(v) = j.get("precision").as_str() {
            let p = Precision::parse(v).ok_or_else(|| anyhow!("bad precision {v:?}"))?;
            self.quant_policy = PolicySpec::uniform(p);
        }
        if let Some(v) = j.get("quant_policy").as_str() {
            self.quant_policy =
                PolicySpec::parse(v).with_context(|| format!("bad quant_policy {v:?}"))?;
        }
        if let Some(v) = j.get("decode_kernel").as_str() {
            self.decode_kernel = match v {
                "plain" | "xla" => DecodeKernel::PlainXla,
                "pallas" => DecodeKernel::Pallas,
                _ => return Err(anyhow!("bad decode_kernel {v:?}")),
            };
        }
        if let Some(v) = j.get("artifact_dir").as_str() {
            self.artifact_dir = v.to_string();
        }
        if let Some(v) = j.get("weight_seed").as_usize() {
            self.weight_seed = v as u64;
        }
        if let Some(v) = j.get("num_blocks").as_usize() {
            self.num_blocks = Some(v);
        }
        if let Some(v) = j.get("expected_concurrency").as_usize() {
            self.expected_concurrency = v;
        }
        if let Some(v) = j.get("scale_margin").as_f64() {
            self.scale_margin = v as f32;
        }
        if let Some(v) = j.get("port").as_usize() {
            self.port = v as u16;
        }
        if let Some(v) = j.get("parallelism").as_usize() {
            self.parallelism = v;
        }
        if let Some(v) = j.get("admission_mode").as_str() {
            self.batcher.admission.mode =
                AdmissionMode::parse(v).ok_or_else(|| anyhow!("bad admission_mode {v:?}"))?;
        }
        if let Some(v) = j.get("prefix_cache_blocks").as_usize() {
            self.prefix_cache_blocks = v;
        }
        if let Some(v) = j.get("attention_kernel").as_str() {
            self.attention_kernel =
                Variant::from_name(v).ok_or_else(|| anyhow!("bad attention_kernel {v:?}"))?;
        }
        if let Some(v) = j.get("paged_decode").as_bool() {
            self.paged_decode = v;
        }
        if let Some(v) = j.get("kernel_backend").as_str() {
            self.kernel_backend = KernelBackend::parse(v)
                .ok_or_else(|| anyhow!("bad kernel_backend {v:?} (auto|scalar|simd)"))?;
        }
        if let Some(v) = j.get("max_running").as_usize() {
            self.batcher.admission.max_running = v;
        }
        if let Some(v) = j.get("max_waiting").as_usize() {
            self.batcher.admission.max_waiting = v;
        }
        if let Some(v) = j.get("watermark").as_f64() {
            self.batcher.admission.watermark = v;
        }
        if let Some(v) = j.get("max_prefills_per_step").as_usize() {
            self.batcher.max_prefills_per_step = v;
        }
        if let Some(v) = j.get("max_decode_batch").as_usize() {
            self.batcher.max_decode_batch = v;
        }
        Ok(())
    }

    /// Apply CLI overrides (flags win over file values).
    pub fn apply_args(&mut self, args: &Args) -> Result<()> {
        if let Some(v) = args.get("model") {
            self.model = v.to_string();
        }
        if let Some(v) = args.get("backend") {
            self.backend = Backend::parse(v).ok_or_else(|| anyhow!("bad --backend {v:?}"))?;
        }
        if let Some(v) = args.get("precision") {
            let p = Precision::parse(v).ok_or_else(|| anyhow!("bad --precision {v:?}"))?;
            self.quant_policy = PolicySpec::uniform(p);
        }
        if let Some(v) = args.get("quant-policy") {
            self.quant_policy =
                PolicySpec::parse(v).with_context(|| format!("bad --quant-policy {v:?}"))?;
        }
        if let Some(v) = args.get("decode-kernel") {
            self.decode_kernel = match v {
                "plain" | "xla" => DecodeKernel::PlainXla,
                "pallas" => DecodeKernel::Pallas,
                _ => return Err(anyhow!("bad --decode-kernel {v:?}")),
            };
        }
        if let Some(v) = args.get("artifacts") {
            self.artifact_dir = v.to_string();
        }
        if args.has("num-blocks") {
            self.num_blocks = Some(args.usize_or("num-blocks", 0));
        }
        self.weight_seed = args.u64_or("weight-seed", self.weight_seed);
        self.expected_concurrency =
            args.usize_or("concurrency", self.expected_concurrency);
        self.scale_margin = args.f64_or("scale-margin", self.scale_margin as f64) as f32;
        self.port = args.usize_or("port", self.port as usize) as u16;
        self.parallelism = args.usize_or("threads", self.parallelism);
        if let Some(v) = args.get("admission-mode") {
            self.batcher.admission.mode =
                AdmissionMode::parse(v).ok_or_else(|| anyhow!("bad --admission-mode {v:?}"))?;
        }
        self.prefix_cache_blocks =
            args.usize_or("prefix-cache-blocks", self.prefix_cache_blocks);
        if let Some(v) = args.get("attention-kernel") {
            self.attention_kernel =
                Variant::from_name(v).ok_or_else(|| anyhow!("bad --attention-kernel {v:?}"))?;
        }
        if let Some(v) = args.get("paged-decode") {
            self.paged_decode = match v {
                "true" | "1" | "on" => true,
                "false" | "0" | "off" => false,
                _ => return Err(anyhow!("bad --paged-decode {v:?} (true|false)")),
            };
        }
        if let Some(v) = args.get("kernel-backend") {
            self.kernel_backend = KernelBackend::parse(v)
                .ok_or_else(|| anyhow!("bad --kernel-backend {v:?} (auto|scalar|simd)"))?;
        }
        self.batcher.admission.max_running =
            args.usize_or("max-running", self.batcher.admission.max_running);
        self.batcher.max_prefills_per_step =
            args.usize_or("max-prefills", self.batcher.max_prefills_per_step);
        self.batcher.max_decode_batch =
            args.usize_or("max-decode-batch", self.batcher.max_decode_batch);
        Ok(())
    }

    /// Engine config slice of this serve config.
    pub fn engine_config(&self) -> crate::coordinator::EngineConfig {
        crate::coordinator::EngineConfig {
            quant_policy: self.quant_policy.clone(),
            num_blocks: self.num_blocks,
            expected_concurrency: self.expected_concurrency,
            scale_margin: self.scale_margin,
            batcher: self.batcher,
            seed: self.weight_seed,
            parallelism: self.parallelism,
            prefix_cache_blocks: self.prefix_cache_blocks,
            attention_kernel: self.attention_kernel,
            paged_decode: self.paged_decode,
            kernel_backend: self.kernel_backend,
        }
    }

    pub fn admission(&self) -> &AdmissionConfig {
        &self.batcher.admission
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn defaults_are_sane() {
        let c = ServeConfig::default();
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Int8));
        assert_eq!(c.backend, Backend::Pjrt);
        assert_eq!(c.port, 8080);
    }

    #[test]
    fn json_overrides() {
        let mut c = ServeConfig::default();
        let j = Json::parse(
            r#"{"model":"kvq-25m","precision":"fp32","port":9000,
                "max_running":4,"decode_kernel":"pallas","backend":"cpu",
                "parallelism":3,"admission_mode":"worst_case",
                "prefix_cache_blocks":256}"#,
        )
        .unwrap();
        c.apply_json(&j).unwrap();
        assert_eq!(c.model, "kvq-25m");
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Fp32));
        assert_eq!(c.port, 9000);
        assert_eq!(c.batcher.admission.max_running, 4);
        assert_eq!(c.decode_kernel, DecodeKernel::Pallas);
        assert_eq!(c.backend, Backend::CpuRef);
        assert_eq!(c.parallelism, 3);
        assert_eq!(c.engine_config().parallelism, 3);
        assert_eq!(c.batcher.admission.mode, AdmissionMode::WorstCase);
        assert_eq!(c.prefix_cache_blocks, 256);
        assert_eq!(c.engine_config().prefix_cache_blocks, 256);
    }

    #[test]
    fn defaults_admit_optimistically_without_prefix_cache() {
        let c = ServeConfig::default();
        assert_eq!(c.batcher.admission.mode, AdmissionMode::Optimistic);
        assert_eq!(c.prefix_cache_blocks, 0);
    }

    #[test]
    fn quant_policy_knob_round_trips() {
        // JSON key: presets parse, legacy "precision" still works, and
        // the later key wins.
        let mut c = ServeConfig::default();
        c.apply_json(&Json::parse(r#"{"quant_policy":"k8v4"}"#).unwrap()).unwrap();
        assert_eq!(c.quant_policy, PolicySpec::K8V4);
        assert_eq!(c.engine_config().quant_policy, PolicySpec::K8V4);
        c.apply_json(&Json::parse(r#"{"quant_policy":"sink8:2"}"#).unwrap()).unwrap();
        assert_eq!(c.quant_policy, PolicySpec::Sink8 { sink_layers: 2 });
        // Legacy precision spelling maps onto the uniform preset...
        c.apply_json(&Json::parse(r#"{"precision":"int4"}"#).unwrap()).unwrap();
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Int4));
        // ...and an explicit quant_policy in the same document wins.
        c.apply_json(&Json::parse(r#"{"precision":"int8","quant_policy":"k8v4"}"#).unwrap())
            .unwrap();
        assert_eq!(c.quant_policy, PolicySpec::K8V4);
        // CLI flags: --quant-policy beats --precision; bad values error.
        let args = Args::parse_from(
            ["--precision", "fp32", "--quant-policy", "uniform:int8"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Int8));
        assert_eq!(c.quant_policy.engine_label(), "int8");
        let bad = Args::parse_from(["--quant-policy", "sink8:x"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
        assert!(ServeConfig::default()
            .apply_json(&Json::parse(r#"{"quant_policy":"warp"}"#).unwrap())
            .is_err());
    }

    #[test]
    fn quant_policy_loads_json_tables_from_disk() {
        // The shipped example table under configs/ parses through the
        // same --quant-policy path the CLI uses.
        for base in ["configs", "../configs", "../../configs"] {
            let path = format!("{base}/policy_sink_mixed.json");
            if std::path::Path::new(&path).exists() {
                let mut c = ServeConfig::default();
                let args = Args::parse_from(["--quant-policy".to_string(), path.clone()]);
                c.apply_args(&args).unwrap();
                let PolicySpec::Table(t) = &c.quant_policy else {
                    panic!("expected a table policy")
                };
                assert_eq!(t.name, c.quant_policy.name());
                return;
            }
        }
        panic!("configs/policy_sink_mixed.json not found from cwd");
    }

    #[test]
    fn bad_values_error() {
        let mut c = ServeConfig::default();
        assert!(c.apply_json(&Json::parse(r#"{"precision":"int99"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"backend":"tpu"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"admission_mode":"psychic"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"attention_kernel":"warp"}"#).unwrap()).is_err());
        assert!(c.apply_json(&Json::parse(r#"{"kernel_backend":"warp"}"#).unwrap()).is_err());
    }

    #[test]
    fn kernel_backend_knob_round_trips() {
        let mut c = ServeConfig::default();
        assert_eq!(c.kernel_backend, KernelBackend::Auto, "auto is the default");
        c.apply_json(&Json::parse(r#"{"kernel_backend":"scalar"}"#).unwrap()).unwrap();
        assert_eq!(c.kernel_backend, KernelBackend::Scalar);
        assert_eq!(c.engine_config().kernel_backend, KernelBackend::Scalar);
        // CLI wins over the file.
        let args = Args::parse_from(["--kernel-backend", "simd"].iter().map(|s| s.to_string()));
        c.apply_args(&args).unwrap();
        assert_eq!(c.kernel_backend, KernelBackend::Simd);
        let bad = Args::parse_from(["--kernel-backend", "avx9"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn attention_kernel_and_paged_decode_knobs() {
        let mut c = ServeConfig::default();
        assert_eq!(c.attention_kernel, Variant::Vectorized);
        assert!(c.paged_decode);
        c.apply_json(
            &Json::parse(r#"{"attention_kernel":"coarsened","paged_decode":false}"#).unwrap(),
        )
        .unwrap();
        assert_eq!(c.attention_kernel, Variant::Coarsened);
        assert!(!c.paged_decode);
        assert_eq!(c.engine_config().attention_kernel, Variant::Coarsened);
        assert!(!c.engine_config().paged_decode);
        // CLI wins over the file.
        let args = Args::parse_from(
            ["--attention-kernel", "tiled", "--paged-decode", "true"]
                .iter()
                .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.attention_kernel, Variant::Tiled);
        assert!(c.paged_decode);
        let bad = Args::parse_from(["--attention-kernel", "warp"].iter().map(|s| s.to_string()));
        assert!(ServeConfig::default().apply_args(&bad).is_err());
    }

    #[test]
    fn args_override_file() {
        let mut c = ServeConfig::default();
        c.apply_json(&Json::parse(r#"{"port":9000}"#).unwrap()).unwrap();
        let args = Args::parse_from(
            [
                "--port", "9100", "--precision", "fp32", "--threads", "2",
                "--admission-mode", "worst-case", "--prefix-cache-blocks", "128",
            ]
            .iter()
            .map(|s| s.to_string()),
        );
        c.apply_args(&args).unwrap();
        assert_eq!(c.port, 9100);
        assert_eq!(c.quant_policy, PolicySpec::Uniform(Precision::Fp32));
        assert_eq!(c.parallelism, 2);
        assert_eq!(c.batcher.admission.mode, AdmissionMode::WorstCase);
        assert_eq!(c.prefix_cache_blocks, 128);
    }
}
