//! The bench-shape registry — Table 3 of the paper plus the CI-scaled
//! set, loaded from `configs/bench_shapes.json` (the same file aot.py
//! lowers artifacts from).

use crate::util::json::Json;
use anyhow::{anyhow, Context, Result};

#[derive(Debug, Clone, PartialEq, Eq)]
pub struct BenchShape {
    pub name: String,
    pub tokens: usize,
    pub dim: usize,
    pub desc: String,
}

impl BenchShape {
    pub fn elements(&self) -> usize {
        self.tokens * self.dim
    }

    pub fn tag(&self) -> String {
        format!("{}x{}", self.tokens, self.dim)
    }
}

#[derive(Debug, Clone)]
pub struct ShapeRegistry {
    pub paper: Vec<BenchShape>,
    pub ci: Vec<BenchShape>,
}

impl ShapeRegistry {
    /// Locate configs/bench_shapes.json relative to the repo root (works
    /// from `cargo test`/`bench` cwd and from target/ binaries).
    pub fn load_default() -> Result<ShapeRegistry> {
        for base in ["configs", "../configs", "../../configs"] {
            let p = format!("{base}/bench_shapes.json");
            if std::path::Path::new(&p).exists() {
                return Self::load(&p);
            }
        }
        Err(anyhow!("configs/bench_shapes.json not found from cwd"))
    }

    pub fn load(path: &str) -> Result<ShapeRegistry> {
        let text = std::fs::read_to_string(path).with_context(|| format!("reading {path}"))?;
        let j = Json::parse(&text).context("parsing bench_shapes.json")?;
        Ok(ShapeRegistry { paper: parse_set(&j, "paper")?, ci: parse_set(&j, "ci")? })
    }

    /// The set to run: paper when `full`, ci otherwise.
    pub fn active(&self, full: bool) -> &[BenchShape] {
        if full {
            &self.paper
        } else {
            &self.ci
        }
    }
}

fn parse_set(j: &Json, key: &str) -> Result<Vec<BenchShape>> {
    j.get(key)
        .as_arr()
        .ok_or_else(|| anyhow!("missing {key} set"))?
        .iter()
        .map(|s| {
            Ok(BenchShape {
                name: s.get("name").as_str().unwrap_or("").to_string(),
                tokens: s.get("tokens").as_usize().ok_or_else(|| anyhow!("tokens"))?,
                dim: s.get("dim").as_usize().ok_or_else(|| anyhow!("dim"))?,
                desc: s.get("desc").as_str().unwrap_or("").to_string(),
            })
        })
        .collect()
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn loads_repo_registry() {
        let r = ShapeRegistry::load_default().unwrap();
        assert_eq!(r.paper.len(), 8);
        assert_eq!(r.ci.len(), 8);
        // Table 3 exact rows.
        assert_eq!(r.paper[0].tokens, 2048);
        assert_eq!(r.paper[0].dim, 128);
        assert_eq!(r.paper[7].tokens, 131_072);
        assert_eq!(r.paper[7].dim, 8192);
        assert_eq!(r.paper[7].elements(), 1_073_741_824); // the "1B elements"
    }

    #[test]
    fn ci_set_is_smaller_but_keeps_d_sweep() {
        let r = ShapeRegistry::load_default().unwrap();
        for (p, c) in r.paper.iter().zip(&r.ci) {
            assert!(c.elements() <= p.elements());
            assert_eq!(p.dim, c.dim, "D sweep preserved for error figures");
        }
    }

    #[test]
    fn active_switches_sets() {
        let r = ShapeRegistry::load_default().unwrap();
        assert_eq!(r.active(true).len(), 8);
        assert!(r.active(false)[3].elements() < r.active(true)[3].elements());
    }

    #[test]
    fn tags_match_artifact_names() {
        let r = ShapeRegistry::load_default().unwrap();
        assert_eq!(r.paper[0].tag(), "2048x128");
    }
}
