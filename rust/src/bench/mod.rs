//! Benchmark support library: workload generation and the shared drivers
//! the `cargo bench` targets (rust/benches/*.rs) call into.
//!
//! Each paper table/figure has a driver in [`figures`] that produces a
//! [`crate::util::harness::Table`] with the same rows/series the paper
//! reports; the bench binaries print it and write CSV to bench_results/.

pub mod figures;
pub mod report;
pub mod workload;

pub use report::BenchReport;
pub use workload::Workload;
