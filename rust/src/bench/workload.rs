//! Workload generation for the kernel benches and the serving bench:
//! kernel matrices, the legacy Poisson prompt stream, and the
//! trace-driven load model ([`Trace`]) the sharded load harness replays
//! — bursty arrivals, heavy-tailed lengths, sessions, priority classes,
//! all seed-deterministic.

use crate::config::shapes::BenchShape;
use crate::coordinator::request::Priority;
use crate::quant::Fp32Matrix;
use crate::util::rng::Rng;

/// A materialized kernel workload: the K matrix for one bench shape.
pub struct Workload {
    pub shape: BenchShape,
    pub k: Fp32Matrix,
}

impl Workload {
    /// The paper's randomized matrices: U(-1, 1) (which pins max-abs error
    /// at ≈0.00394, §7.2).
    pub fn uniform(shape: &BenchShape, seed: u64) -> Workload {
        Workload {
            shape: shape.clone(),
            k: Fp32Matrix::random_uniform(shape.tokens, shape.dim, -1.0, 1.0, seed),
        }
    }

    /// Normal-distributed variant (closer to real K/V statistics).
    pub fn normal(shape: &BenchShape, seed: u64) -> Workload {
        Workload {
            shape: shape.clone(),
            k: Fp32Matrix::random_normal(shape.tokens, shape.dim, 1.0, seed),
        }
    }

    pub fn elements(&self) -> usize {
        self.k.elements()
    }
}

/// Serving workload: Poisson arrivals of prompts with bounded lengths.
pub struct ServingWorkload {
    pub prompts: Vec<Vec<i32>>,
    /// Arrival offsets in seconds from t0.
    pub arrivals: Vec<f64>,
    pub max_new_tokens: usize,
}

impl ServingWorkload {
    pub fn poisson(
        n_requests: usize,
        rate_per_sec: f64,
        prompt_len: (usize, usize),
        max_new_tokens: usize,
        vocab: usize,
        seed: u64,
    ) -> ServingWorkload {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut prompts = Vec::new();
        let mut arrivals = Vec::new();
        for _ in 0..n_requests {
            t += rng.exponential(rate_per_sec);
            arrivals.push(t);
            let len = rng.range(prompt_len.0 as i64, prompt_len.1 as i64) as usize;
            prompts.push((0..len).map(|_| rng.below(vocab as u64) as i32).collect());
        }
        ServingWorkload { prompts, arrivals, max_new_tokens }
    }
}

/// Arrival process for the trace generator.
#[derive(Debug, Clone, Copy)]
pub enum Arrivals {
    /// Memoryless arrivals at `rate` req/s.
    Poisson { rate: f64 },
    /// On/off bursts: Poisson at `rate` during `on_s`-second windows,
    /// silence for `off_s` between them — the overload shape that
    /// actually exercises spillover and the overflow queue.
    Bursty { rate: f64, on_s: f64, off_s: f64 },
}

impl Arrivals {
    /// Map cumulative *active* seconds onto wall-clock seconds: bursty
    /// traffic is a Poisson process on the active timeline with the off
    /// windows spliced in.
    fn wall_clock(&self, active_s: f64) -> f64 {
        match *self {
            Arrivals::Poisson { .. } => active_s,
            Arrivals::Bursty { on_s, off_s, .. } => {
                let cycles = (active_s / on_s).floor();
                cycles * (on_s + off_s) + (active_s - cycles * on_s)
            }
        }
    }

    fn rate(&self) -> f64 {
        match *self {
            Arrivals::Poisson { rate } | Arrivals::Bursty { rate, .. } => rate,
        }
    }
}

/// Token-length distribution for prompts and output budgets.
#[derive(Debug, Clone, Copy)]
pub enum LengthDist {
    Fixed(usize),
    /// Inclusive uniform range.
    Uniform(usize, usize),
    /// Bounded Pareto on `[lo, hi]` with tail index `alpha` — the
    /// heavy-tailed shape of real prompt/output lengths (many short, a
    /// fat tail of huge ones). Smaller `alpha` = heavier tail.
    Pareto { lo: usize, hi: usize, alpha: f64 },
}

impl LengthDist {
    pub fn sample(&self, rng: &mut Rng) -> usize {
        match *self {
            LengthDist::Fixed(n) => n,
            LengthDist::Uniform(lo, hi) => rng.range(lo as i64, hi as i64) as usize,
            LengthDist::Pareto { lo, hi, alpha } => {
                // Inverse-CDF of the bounded Pareto.
                let (l, h) = (lo.max(1) as f64, hi.max(lo.max(1)) as f64);
                let u = rng.next_f64().min(1.0 - 1e-12);
                let x = l / (1.0 - u * (1.0 - (l / h).powf(alpha))).powf(1.0 / alpha);
                (x as usize).clamp(lo.max(1), hi)
            }
        }
    }
}

/// One request in a load trace.
#[derive(Debug, Clone)]
pub struct TraceRequest {
    /// Arrival offset in seconds from trace start.
    pub at_s: f64,
    /// Session key (affinity routing groups these onto one shard).
    pub session: String,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub priority: Priority,
    /// Per-request sampling seed.
    pub seed: u64,
}

/// Trace generator configuration.
#[derive(Debug, Clone)]
pub struct TraceConfig {
    pub requests: usize,
    pub arrivals: Arrivals,
    pub prompt_len: LengthDist,
    pub output_len: LengthDist,
    /// Distinct session keys; requests draw a session uniformly, so
    /// expected per-session request count is `requests / sessions`.
    pub sessions: usize,
    /// Priority classes with relative weights (empty = all Normal).
    pub priorities: Vec<(Priority, f64)>,
    pub vocab: usize,
    pub seed: u64,
}

impl Default for TraceConfig {
    fn default() -> Self {
        TraceConfig {
            requests: 64,
            arrivals: Arrivals::Poisson { rate: 50.0 },
            prompt_len: LengthDist::Pareto { lo: 4, hi: 64, alpha: 1.5 },
            output_len: LengthDist::Uniform(4, 16),
            sessions: 8,
            priorities: vec![
                (Priority::Interactive, 0.3),
                (Priority::Normal, 0.5),
                (Priority::Batch, 0.2),
            ],
            vocab: 64,
            seed: 0x7ACE,
        }
    }
}

/// A fully materialized, seed-deterministic load trace.
#[derive(Debug, Clone)]
pub struct Trace {
    pub requests: Vec<TraceRequest>,
}

impl Trace {
    pub fn generate(cfg: &TraceConfig) -> Trace {
        let mut rng = Rng::new(cfg.seed ^ 0x7ACE_D00D);
        let total_w: f64 = cfg.priorities.iter().map(|(_, w)| w.max(0.0)).sum();
        let mut active = 0.0;
        let mut requests = Vec::with_capacity(cfg.requests);
        for i in 0..cfg.requests {
            active += rng.exponential(cfg.arrivals.rate());
            let at_s = cfg.arrivals.wall_clock(active);
            let session = format!("s{}", rng.below(cfg.sessions.max(1) as u64));
            let plen = cfg.prompt_len.sample(&mut rng).max(1);
            let prompt =
                (0..plen).map(|_| rng.below(cfg.vocab as u64) as i32).collect::<Vec<_>>();
            let max_new_tokens = cfg.output_len.sample(&mut rng).max(1);
            let priority = if total_w <= 0.0 {
                Priority::Normal
            } else {
                let mut draw = rng.next_f64() * total_w;
                let mut picked = Priority::Normal;
                for (p, w) in &cfg.priorities {
                    draw -= w.max(0.0);
                    if draw <= 0.0 {
                        picked = *p;
                        break;
                    }
                }
                picked
            };
            requests.push(TraceRequest {
                at_s,
                session,
                prompt,
                max_new_tokens,
                priority,
                seed: cfg.seed ^ (i as u64).wrapping_mul(0x9E37_79B9_7F4A_7C15),
            });
        }
        Trace { requests }
    }

    pub fn len(&self) -> usize {
        self.requests.len()
    }

    pub fn is_empty(&self) -> bool {
        self.requests.is_empty()
    }

    /// Wall-clock span of the trace (arrival of the last request).
    pub fn duration_s(&self) -> f64 {
        self.requests.last().map(|r| r.at_s).unwrap_or(0.0)
    }

    pub fn truncate(&mut self, n: usize) {
        self.requests.truncate(n);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::shapes::ShapeRegistry;

    #[test]
    fn workload_matches_shape() {
        let r = ShapeRegistry::load_default().unwrap();
        let w = Workload::uniform(&r.ci[0], 1);
        assert_eq!(w.elements(), r.ci[0].elements());
        assert!(w.k.data.iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn workloads_deterministic() {
        let r = ShapeRegistry::load_default().unwrap();
        let a = Workload::uniform(&r.ci[0], 7);
        let b = Workload::uniform(&r.ci[0], 7);
        assert_eq!(a.k.data, b.k.data);
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let w = ServingWorkload::poisson(50, 10.0, (4, 16), 8, 256, 3);
        assert_eq!(w.prompts.len(), 50);
        assert!(w.arrivals.windows(2).all(|p| p[0] <= p[1]));
        assert!(w.prompts.iter().all(|p| (4..=16).contains(&p.len())));
        // Mean inter-arrival ≈ 1/rate.
        let mean = w.arrivals.last().unwrap() / 50.0;
        assert!((mean - 0.1).abs() < 0.05, "mean gap {mean}");
    }

    #[test]
    fn traces_are_seed_deterministic() {
        let cfg = TraceConfig::default();
        let a = Trace::generate(&cfg);
        let b = Trace::generate(&cfg);
        assert_eq!(a.len(), cfg.requests);
        for (x, y) in a.requests.iter().zip(&b.requests) {
            assert_eq!(x.at_s, y.at_s);
            assert_eq!(x.session, y.session);
            assert_eq!(x.prompt, y.prompt);
            assert_eq!(x.max_new_tokens, y.max_new_tokens);
            assert_eq!(x.priority, y.priority);
            assert_eq!(x.seed, y.seed);
        }
        let c = Trace::generate(&TraceConfig { seed: 99, ..cfg });
        assert!(a.requests.iter().zip(&c.requests).any(|(x, y)| x.prompt != y.prompt));
    }

    #[test]
    fn trace_arrivals_monotone_and_sessions_bounded() {
        let t = Trace::generate(&TraceConfig {
            requests: 200,
            sessions: 4,
            ..Default::default()
        });
        assert!(t.requests.windows(2).all(|p| p[0].at_s <= p[1].at_s));
        for r in &t.requests {
            assert!(["s0", "s1", "s2", "s3"].contains(&r.session.as_str()), "{}", r.session);
            assert!(!r.prompt.is_empty());
            assert!(r.max_new_tokens >= 1);
        }
        assert!(t.duration_s() > 0.0);
    }

    #[test]
    fn bursty_arrivals_have_gaps() {
        // 50 req/s over 0.1s-on / 0.5s-off cycles: arrivals cluster in
        // the on-windows, so some consecutive gap spans an off period.
        let t = Trace::generate(&TraceConfig {
            requests: 50,
            arrivals: Arrivals::Bursty { rate: 50.0, on_s: 0.1, off_s: 0.5 },
            seed: 11,
            ..Default::default()
        });
        let max_gap = t
            .requests
            .windows(2)
            .map(|p| p[1].at_s - p[0].at_s)
            .fold(0.0f64, f64::max);
        assert!(max_gap >= 0.5, "expected an off-window gap, max {max_gap}");
        // And the wall-clock mapping keeps ordering.
        assert!(t.requests.windows(2).all(|p| p[0].at_s <= p[1].at_s));
    }

    #[test]
    fn pareto_lengths_are_bounded_and_heavy_tailed() {
        let mut rng = Rng::new(5);
        let d = LengthDist::Pareto { lo: 4, hi: 512, alpha: 1.2 };
        let samples: Vec<usize> = (0..2000).map(|_| d.sample(&mut rng)).collect();
        assert!(samples.iter().all(|&n| (4..=512).contains(&n)));
        let short = samples.iter().filter(|&&n| n <= 16).count();
        let long = samples.iter().filter(|&&n| n >= 128).count();
        assert!(short > samples.len() / 2, "mass concentrates low: {short}");
        assert!(long > 0, "but the tail reaches high");
    }

    #[test]
    fn priority_mix_follows_weights() {
        let t = Trace::generate(&TraceConfig {
            requests: 500,
            priorities: vec![(Priority::Interactive, 0.8), (Priority::Batch, 0.2)],
            ..Default::default()
        });
        let inter =
            t.requests.iter().filter(|r| r.priority == Priority::Interactive).count();
        let batch = t.requests.iter().filter(|r| r.priority == Priority::Batch).count();
        assert_eq!(inter + batch, 500, "only the configured classes appear");
        assert!(inter > 300 && batch > 40, "≈80/20 split, got {inter}/{batch}");
    }
}
