//! Workload generation for the kernel benches and the serving bench.

use crate::config::shapes::BenchShape;
use crate::quant::Fp32Matrix;
use crate::util::rng::Rng;

/// A materialized kernel workload: the K matrix for one bench shape.
pub struct Workload {
    pub shape: BenchShape,
    pub k: Fp32Matrix,
}

impl Workload {
    /// The paper's randomized matrices: U(-1, 1) (which pins max-abs error
    /// at ≈0.00394, §7.2).
    pub fn uniform(shape: &BenchShape, seed: u64) -> Workload {
        Workload {
            shape: shape.clone(),
            k: Fp32Matrix::random_uniform(shape.tokens, shape.dim, -1.0, 1.0, seed),
        }
    }

    /// Normal-distributed variant (closer to real K/V statistics).
    pub fn normal(shape: &BenchShape, seed: u64) -> Workload {
        Workload {
            shape: shape.clone(),
            k: Fp32Matrix::random_normal(shape.tokens, shape.dim, 1.0, seed),
        }
    }

    pub fn elements(&self) -> usize {
        self.k.elements()
    }
}

/// Serving workload: Poisson arrivals of prompts with bounded lengths.
pub struct ServingWorkload {
    pub prompts: Vec<Vec<i32>>,
    /// Arrival offsets in seconds from t0.
    pub arrivals: Vec<f64>,
    pub max_new_tokens: usize,
}

impl ServingWorkload {
    pub fn poisson(
        n_requests: usize,
        rate_per_sec: f64,
        prompt_len: (usize, usize),
        max_new_tokens: usize,
        vocab: usize,
        seed: u64,
    ) -> ServingWorkload {
        let mut rng = Rng::new(seed);
        let mut t = 0.0;
        let mut prompts = Vec::new();
        let mut arrivals = Vec::new();
        for _ in 0..n_requests {
            t += rng.exponential(rate_per_sec);
            arrivals.push(t);
            let len = rng.range(prompt_len.0 as i64, prompt_len.1 as i64) as usize;
            prompts.push((0..len).map(|_| rng.below(vocab as u64) as i32).collect());
        }
        ServingWorkload { prompts, arrivals, max_new_tokens }
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::config::shapes::ShapeRegistry;

    #[test]
    fn workload_matches_shape() {
        let r = ShapeRegistry::load_default().unwrap();
        let w = Workload::uniform(&r.ci[0], 1);
        assert_eq!(w.elements(), r.ci[0].elements());
        assert!(w.k.data.iter().all(|v| (-1.0..1.0).contains(v)));
    }

    #[test]
    fn workloads_deterministic() {
        let r = ShapeRegistry::load_default().unwrap();
        let a = Workload::uniform(&r.ci[0], 7);
        let b = Workload::uniform(&r.ci[0], 7);
        assert_eq!(a.k.data, b.k.data);
    }

    #[test]
    fn poisson_arrivals_monotone() {
        let w = ServingWorkload::poisson(50, 10.0, (4, 16), 8, 256, 3);
        assert_eq!(w.prompts.len(), 50);
        assert!(w.arrivals.windows(2).all(|p| p[0] <= p[1]));
        assert!(w.prompts.iter().all(|p| (4..=16).contains(&p.len())));
        // Mean inter-arrival ≈ 1/rate.
        let mean = w.arrivals.last().unwrap() / 50.0;
        assert!((mean - 0.1).abs() < 0.05, "mean gap {mean}");
    }
}
