//! Shared drivers that regenerate each paper table/figure.
//!
//! Substitution notes (DESIGN.md §Hardware-Adaptation): the paper times
//! CUDA kernels with events (device-side only). Our "GPU-analog" numbers
//! time `execute_b` + result fetch on the XLA-CPU PJRT client with inputs
//! pre-staged as device buffers, so they *include* result readback —
//! reported speedups are therefore conservative. The CPU baseline is the
//! paper's scalar C loop nest ported verbatim (quant::*_naive).

use crate::config::shapes::{BenchShape, ShapeRegistry};
use crate::quant::{self, Fp32Matrix, Int8Matrix, Variant};
use crate::runtime::Runtime;
use crate::util::harness::{cell_f, cell_speedup, cell_time, Bencher, Table};
use anyhow::{Context, Result};
use std::rc::Rc;

/// Context shared by the figure drivers.
pub struct FigCtx {
    pub rt: Rc<Runtime>,
    pub bencher: Bencher,
    pub full: bool,
    pub shapes: Vec<BenchShape>,
}

impl FigCtx {
    /// Build from env/CLI: `--full` / KVQ_BENCH_FULL=1 runs the paper's
    /// Table-3 sizes; default runs the CI-scaled set.
    pub fn from_env() -> Result<FigCtx> {
        let args = crate::util::args::Args::parse();
        let full = args.bool_or("full", crate::util::harness::full_mode());
        let registry = ShapeRegistry::load_default()?;
        let shapes = registry.active(full).to_vec();
        let rt = Rc::new(Runtime::new(&crate::runtime::default_artifact_dir()).context(
            "PJRT runtime (run `make artifacts` first)",
        )?);
        let bencher = if full {
            Bencher { min_reps: 2, max_reps: 5, budget: 20.0, warmup: 1 }
        } else {
            Bencher::default()
        };
        Ok(FigCtx { rt, bencher, full, shapes })
    }

    /// Median seconds to run an artifact with pre-staged inputs.
    fn time_artifact(&self, name: &str, staged: &[&xla::PjRtBuffer]) -> Result<f64> {
        let exe = self.rt.load(name)?;
        // Correctness smoke before timing: one run must succeed.
        exe.run_b(staged)?;
        let m = self.bencher.measure(name, || {
            exe.run_b(staged).expect("bench artifact run");
        });
        Ok(m.median())
    }

    /// Median seconds for a CPU quantize variant.
    fn time_cpu_variant(&self, v: Variant, k: &Fp32Matrix, scales: &[f32]) -> f64 {
        let mut out = Int8Matrix::zeros(k.rows, k.cols);
        let m = self.bencher.measure(v.name(), || {
            quant::quantize::quantize_variant(v, k, scales, &mut out);
        });
        m.median()
    }

    /// Median seconds for the paper-methodology scalar baseline (Listing 3
    /// loop nest, optimization-barriered — see quantize_naive_unopt docs).
    fn time_cpu_baseline(&self, k: &Fp32Matrix, scales: &[f32]) -> f64 {
        let mut out = Int8Matrix::zeros(k.rows, k.cols);
        let m = self.bencher.measure("cpu_baseline", || {
            quant::quantize::quantize_naive_unopt(k, scales, &mut out);
        });
        m.median()
    }
}

/// One measured shape row shared by Figs 1/2/5.
pub struct SpeedupRow {
    pub shape: BenchShape,
    /// Paper-methodology scalar baseline (optimization-barriered).
    pub cpu_secs: f64,
    /// Optimized (-O3, autovectorized) Rust port of the same loop.
    pub cpu_opt_secs: f64,
    /// (variant name, seconds) for the four XLA-executed Pallas variants.
    pub gpu_secs: Vec<(String, f64)>,
}

impl SpeedupRow {
    pub fn best_gpu(&self) -> f64 {
        self.gpu_secs.iter().map(|(_, s)| *s).fold(f64::INFINITY, f64::min)
    }

    pub fn speedup(&self, variant: &str) -> f64 {
        let s = self.gpu_secs.iter().find(|(n, _)| n == variant).map(|(_, s)| *s).unwrap();
        self.cpu_secs / s
    }
}

/// Measurement cache: fig1 measures and saves; figs 2/3/5 reuse the same
/// rows (they are different presentations of one experiment). Set
/// KVQ_BENCH_REMEASURE=1 to force fresh measurements everywhere.
fn cache_path(full: bool) -> String {
    format!("bench_results/speedups_{}.json", if full { "paper" } else { "ci" })
}

fn save_rows(rows: &[SpeedupRow], full: bool) {
    use crate::util::json::{obj, Json};
    let arr: Vec<Json> = rows
        .iter()
        .map(|r| {
            obj([
                ("name", r.shape.name.as_str().into()),
                ("tokens", r.shape.tokens.into()),
                ("dim", r.shape.dim.into()),
                ("desc", r.shape.desc.as_str().into()),
                ("cpu_secs", r.cpu_secs.into()),
                ("cpu_opt_secs", r.cpu_opt_secs.into()),
                (
                    "gpu",
                    Json::Obj(
                        r.gpu_secs
                            .iter()
                            .map(|(n, s)| (n.clone(), Json::Num(*s)))
                            .collect(),
                    ),
                ),
            ])
        })
        .collect();
    let _ = std::fs::create_dir_all("bench_results");
    let _ = std::fs::write(cache_path(full), Json::Arr(arr).to_string());
}

fn load_rows(full: bool) -> Option<Vec<SpeedupRow>> {
    let text = std::fs::read_to_string(cache_path(full)).ok()?;
    let j = crate::util::json::Json::parse(&text).ok()?;
    let mut rows = Vec::new();
    for e in j.as_arr()? {
        let shape = BenchShape {
            name: e.get("name").as_str()?.to_string(),
            tokens: e.get("tokens").as_usize()?,
            dim: e.get("dim").as_usize()?,
            desc: e.get("desc").as_str().unwrap_or("").to_string(),
        };
        let gpu_secs = e
            .get("gpu")
            .as_obj()?
            .iter()
            .map(|(n, s)| (n.clone(), s.as_f64().unwrap_or(0.0)))
            .collect();
        rows.push(SpeedupRow {
            shape,
            cpu_secs: e.get("cpu_secs").as_f64()?,
            cpu_opt_secs: e.get("cpu_opt_secs").as_f64()?,
            gpu_secs,
        });
    }
    Some(rows)
}

/// Reuse fig1's measurements if present (figs 2/3/5); measure otherwise.
pub fn measure_speedups_cached(ctx: &FigCtx) -> Result<Vec<SpeedupRow>> {
    let force = std::env::var("KVQ_BENCH_REMEASURE").map(|v| v == "1").unwrap_or(false);
    if !force {
        if let Some(rows) = load_rows(ctx.full) {
            if rows.len() == ctx.shapes.len() {
                println!("[bench] reusing measurements from {}", cache_path(ctx.full));
                return Ok(rows);
            }
        }
    }
    let rows = measure_speedups(ctx)?;
    Ok(rows)
}

/// Measure all shapes for the speedup figures (Fig 1/2/5 share this).
pub fn measure_speedups(ctx: &FigCtx) -> Result<Vec<SpeedupRow>> {
    let mut rows = Vec::new();
    for shape in &ctx.shapes {
        crate::info!("fig: measuring {} ({} elements)", shape.tag(), shape.elements());
        let wl = super::workload::Workload::uniform(shape, 0xF16);
        let scales = quant::compute_scales(&wl.k);
        let cpu_secs = ctx.time_cpu_baseline(&wl.k, &scales);
        let cpu_opt_secs = ctx.time_cpu_variant(Variant::Naive, &wl.k, &scales);

        // Stage inputs once (paper times kernels with resident inputs).
        let kbuf = ctx.rt.stage_f32(&wl.k.data, &[shape.tokens, shape.dim])?;
        let sbuf = ctx.rt.stage_f32(&scales, &[shape.dim])?;
        let staged = [&kbuf, &sbuf];

        let mut gpu_secs = Vec::new();
        for v in Variant::ALL {
            let name = format!("quantize_{}_{}", v.name(), shape.tag());
            let secs = ctx.time_artifact(&name, &staged)?;
            gpu_secs.push((v.name().to_string(), secs));
        }
        rows.push(SpeedupRow { shape: shape.clone(), cpu_secs, cpu_opt_secs, gpu_secs });
    }
    save_rows(&rows, ctx.full);
    Ok(rows)
}

/// Figure 1: per-config speedup of each kernel variant over the CPU.
pub fn fig1_table(rows: &[SpeedupRow]) -> Table {
    let mut t = Table::new(
        "Figure 1 — kernel speedup over the paper-methodology CPU baseline (quantize)",
        &["config", "T", "D", "elements", "naive", "tiled", "coarsened", "vectorized",
          "vect_vs_O3cpu"],
    );
    for r in rows {
        t.row(&[
            r.shape.name.clone(),
            r.shape.tokens.to_string(),
            r.shape.dim.to_string(),
            r.shape.elements().to_string(),
            cell_speedup(r.speedup("naive")),
            cell_speedup(r.speedup("tiled")),
            cell_speedup(r.speedup("coarsened")),
            cell_speedup(r.speedup("vectorized")),
            cell_speedup(
                r.cpu_opt_secs
                    / r.gpu_secs.iter().find(|(n, _)| n == "vectorized").unwrap().1,
            ),
        ]);
    }
    t
}

/// Figure 2: absolute execution time, CPU vs best GPU kernel (log-log in
/// the paper; we emit the raw series for plotting).
pub fn fig2_table(rows: &[SpeedupRow]) -> Table {
    let mut t = Table::new(
        "Figure 2 — Execution time: CPU vs GPU (seconds)",
        &["config", "elements", "cpu", "cpu_O3", "gpu_naive", "gpu_vectorized", "gpu_best"],
    );
    for r in rows {
        let naive = r.gpu_secs.iter().find(|(n, _)| n == "naive").unwrap().1;
        let vect = r.gpu_secs.iter().find(|(n, _)| n == "vectorized").unwrap().1;
        t.row(&[
            r.shape.name.clone(),
            r.shape.elements().to_string(),
            cell_time(r.cpu_secs),
            cell_time(r.cpu_opt_secs),
            cell_time(naive),
            cell_time(vect),
            cell_time(r.best_gpu()),
        ]);
    }
    t
}

/// Figure 3: GPU time on the realistic configs (paper band: 6–58 ms).
pub fn fig3_table(rows: &[SpeedupRow]) -> Table {
    let mut t = Table::new(
        "Figure 3 — GPU kernel time on realistic LLM workloads",
        &["config", "T", "D", "naive", "tiled", "coarsened", "vectorized"],
    );
    for r in rows.iter().filter(|r| r.shape.dim >= 1024) {
        let get = |v: &str| r.gpu_secs.iter().find(|(n, _)| n == v).unwrap().1;
        t.row(&[
            r.shape.name.clone(),
            r.shape.tokens.to_string(),
            r.shape.dim.to_string(),
            cell_time(get("naive")),
            cell_time(get("tiled")),
            cell_time(get("coarsened")),
            cell_time(get("vectorized")),
        ]);
    }
    t
}

/// Figure 5: speedup vs problem size (vectorized + naive series).
pub fn fig5_table(rows: &[SpeedupRow]) -> Table {
    let mut sorted: Vec<&SpeedupRow> = rows.iter().collect();
    sorted.sort_by_key(|r| r.shape.elements());
    let mut t = Table::new(
        "Figure 5 — Speedup vs problem size",
        &["elements", "naive", "tiled", "coarsened", "vectorized"],
    );
    for r in sorted {
        t.row(&[
            r.shape.elements().to_string(),
            cell_speedup(r.speedup("naive")),
            cell_speedup(r.speedup("tiled")),
            cell_speedup(r.speedup("coarsened")),
            cell_speedup(r.speedup("vectorized")),
        ]);
    }
    t
}

/// Row-wise softmax (f32, max-subtracted) — attention weights for the
/// value/output-side error probe below.
fn softmax_rows(scores: &Fp32Matrix) -> Fp32Matrix {
    let mut out = Fp32Matrix::zeros(scores.rows, scores.cols);
    for r in 0..scores.rows {
        let row = scores.row(r);
        let mx = row.iter().copied().fold(f32::NEG_INFINITY, f32::max);
        let mut denom = 0.0f32;
        let dst = &mut out.data[r * scores.cols..(r + 1) * scores.cols];
        for (o, &s) in dst.iter_mut().zip(row) {
            *o = (s - mx).exp();
            denom += *o;
        }
        for o in dst.iter_mut() {
            *o /= denom;
        }
    }
    out
}

/// Figure 4: reconstruction + attention-score error per config, plus the
/// value/output-side error |PV − PV̂| (softmaxed random queries over a
/// quantized V — what V-quantization does to the attention *output*).
/// K-side attention error is computed by the XLA artifacts and
/// cross-checked on CPU; the V-side probe is substrate-independent.
pub fn fig4_table(ctx: &FigCtx) -> Result<Table> {
    let mut t = Table::new(
        "Figure 4 — Reconstruction & attention-score error",
        &["config", "T", "D", "max_abs_err", "l2_err", "attn_err", "attn_err/sqrt(D)", "vout_err"],
    );
    for shape in &ctx.shapes {
        let wl = super::workload::Workload::uniform(shape, 0xE44);
        let q = quant::quantize_fused(&wl.k);
        let rec = quant::dequantize(&q);
        let max_abs = quant::max_abs_error(&wl.k, &rec);
        let l2 = quant::l2_error(&wl.k, &rec);

        // Value/output-side error on a token subsample: softmaxed random
        // scores as attention weights over a quantized V matrix.
        let vout_err = {
            let tsub = shape.tokens.min(2048);
            let v = Fp32Matrix::random_uniform(tsub, shape.dim, -1.0, 1.0, 0xE45);
            let vq = quant::quantize_fused(&v);
            let vrec = quant::dequantize(&vq);
            let probs = softmax_rows(&Fp32Matrix::random_normal(16, tsub, 1.0, 0xE46));
            quant::value_output_error(&probs, &v, &vrec)
        };

        // Attention error via the lowered probe (token-subsampled per the
        // manifest's probe_tokens).
        let entry = ctx.rt.manifest.entry(&format!("attnerr_{}", shape.tag()))?;
        let tsub = entry.meta.get("probe_tokens").as_usize().unwrap_or(shape.tokens);
        let nq = entry.meta.get("queries").as_usize().unwrap_or(64);
        let queries = Fp32Matrix::random_uniform(nq, shape.dim, -1.0, 1.0, 0x9);
        let out = ctx.rt.run(
            &format!("attnerr_{}", shape.tag()),
            &[
                crate::runtime::HostTensor::f32(queries.data, &[nq, shape.dim]),
                crate::runtime::HostTensor::f32(
                    wl.k.data[..tsub * shape.dim].to_vec(),
                    &[tsub, shape.dim],
                ),
                crate::runtime::HostTensor::i8(
                    q.data[..tsub * shape.dim].to_vec(),
                    &[tsub, shape.dim],
                ),
                crate::runtime::HostTensor::f32(q.scales.clone(), &[shape.dim]),
            ],
        )?;
        let attn_err = out[0].as_f32()?[0] as f64;

        t.row(&[
            shape.name.clone(),
            shape.tokens.to_string(),
            shape.dim.to_string(),
            cell_f(max_abs, 5),
            cell_f(l2, 2),
            cell_f(attn_err, 5),
            cell_f(attn_err / (shape.dim as f64).sqrt(), 7),
            cell_f(vout_err, 7),
        ]);
    }
    Ok(t)
}

/// The named policies every policy sweep reports (uniform presets plus
/// the mixed-precision ones the related work motivates).
pub fn sweep_policies() -> Vec<crate::kvcache::PolicySpec> {
    use crate::kvcache::{PolicySpec, Precision};
    vec![
        PolicySpec::Uniform(Precision::Int8),
        PolicySpec::Uniform(Precision::Int4),
        PolicySpec::K8V4,
        PolicySpec::Sink8 { sink_layers: 1 },
    ]
}

/// Quantize-and-reconstruct a matrix at one precision (the closed-loop
/// error probe used by the policy sweep).
fn reconstruct(p: crate::kvcache::Precision, m: &Fp32Matrix) -> Fp32Matrix {
    use crate::kvcache::Precision;
    use crate::quant::int4;
    match p {
        Precision::Fp32 => m.clone(),
        Precision::Int8 => quant::dequantize(&quant::quantize_fused(m)),
        Precision::Int4 => int4::dequantize4(&int4::quantize4(m)),
    }
}

/// Figure 4 policy sweep: per-policy key/attention/value-output error on
/// a synthetic multi-layer cache, with the policy's payload compression.
/// Substrate-independent (no PJRT needed) — this is the error side of
/// the non-uniform accuracy/memory frontier the mixed policies target:
/// `k8v4` keeps the K-side (attention-score) error at INT8 level while
/// taking the V side to INT4, and `sink8` zeroes layer-0 error entirely.
pub fn fig4_policy_table() -> Table {
    use crate::kvcache::PolicyMemory;
    let (layers, tokens, dim, queries) = (4usize, 2048usize, 64usize, 16usize);
    let mut t = Table::new(
        "Figure 4b — error by quantization policy (L=4, T=2048, D=64)",
        &["policy", "key_max_abs", "attn_err", "vout_err", "payload_vs_fp32"],
    );
    let q = Fp32Matrix::random_uniform(queries, dim, -1.0, 1.0, 0x9E44);
    for spec in sweep_policies() {
        let policy = spec.resolve(layers, 1, dim).expect("sweep policies resolve");
        let (mut key_max, mut attn_sum, mut vout_sum) = (0.0f64, 0.0f64, 0.0f64);
        for layer in 0..layers {
            let seed = 0xE44 + layer as u64;
            let k = Fp32Matrix::random_uniform(tokens, dim, -1.0, 1.0, seed);
            let v = Fp32Matrix::random_uniform(tokens, dim, -1.0, 1.0, seed ^ 0x5A5A);
            let k_hat = reconstruct(policy.precision(layer, 0, 0), &k);
            let v_hat = reconstruct(policy.precision(layer, 1, 0), &v);
            key_max = key_max.max(quant::max_abs_error(&k, &k_hat));
            attn_sum += quant::attention_score_error(&q, &k, &k_hat);
            let probs = softmax_rows(&Fp32Matrix::random_normal(queries, tokens, 1.0, seed ^ 1));
            vout_sum += quant::value_output_error(&probs, &v, &v_hat);
        }
        let mem = PolicyMemory::new(&policy, dim, tokens);
        // fp32 payload of the sweep geometry: 2 sides × L × H=1 rows.
        let fp32_payload = (2 * layers * tokens * dim * 4) as u64;
        t.row(&[
            spec.name(),
            cell_f(key_max, 5),
            cell_f(attn_sum / layers as f64, 5),
            cell_f(vout_sum / layers as f64, 7),
            format!("{:.2}x", fp32_payload as f64 / mem.payload_bytes() as f64),
        ]);
    }
    t
}

/// Table 1 policy sweep: the closed-form memory model under each named
/// policy on the paper's Table-1 geometry. `k8v4` must land between the
/// uniform int8 (4x) and int4 (8x) caches (≈5.3x). The physical columns
/// report the pooled footprint per span (one block in every stream,
/// block_size 16): width-aware sub-pools vs a single pool padded to the
/// widest stream, and the bytes that padding would have wasted.
pub fn table1_policies() -> Table {
    use crate::kvcache::{MemoryModel, PolicyMemory};
    use crate::util::stats::fmt_bytes;
    let base = MemoryModel::table1_example();
    let block_size = 16usize;
    let mut t = Table::new(
        "Table 1b — KV cache memory by quantization policy (L=32 H=32 d=128 T=131072)",
        &["policy", "payload", "scales", "total", "vs fp32", "span (sub-pools)",
          "span (padded)", "reclaimed/span"],
    );
    for spec in sweep_policies() {
        let policy = spec
            .resolve(base.layers, base.heads, base.head_dim)
            .expect("sweep policies resolve");
        let m = PolicyMemory::new(&policy, base.head_dim, base.seq_len);
        t.row(&[
            spec.name(),
            fmt_bytes(m.payload_bytes() as f64),
            fmt_bytes(m.scale_overhead_bytes() as f64),
            fmt_bytes(m.total_bytes() as f64),
            format!("{:.2}x", m.compression_vs_fp32()),
            fmt_bytes(m.subpool_span_bytes(block_size) as f64),
            fmt_bytes(m.padded_span_bytes(block_size) as f64),
            fmt_bytes(m.reclaimed_span_bytes(block_size) as f64),
        ]);
    }
    t
}

/// Table 1: the closed-form memory model across precisions.
pub fn table1() -> Table {
    use crate::kvcache::{MemoryModel, Precision};
    use crate::util::stats::fmt_bytes;
    let base = MemoryModel::table1_example();
    let mut t = Table::new(
        "Table 1 — KV cache memory (L=32 H=32 d=128 T=131072)",
        &[
            "precision",
            "payload",
            "scales",
            "total",
            "vs fp32",
            "max T @16GB",
            "max batch(T=4096) @64GB",
        ],
    );
    for p in [Precision::Fp32, Precision::Int8, Precision::Int4] {
        let m = MemoryModel { precision: p, ..base };
        let batch_model = MemoryModel { seq_len: 4096, ..m };
        t.row(&[
            p.name().to_string(),
            fmt_bytes(m.payload_bytes() as f64),
            fmt_bytes(m.scale_overhead_bytes() as f64),
            fmt_bytes(m.total_bytes() as f64),
            format!("{:.2}x", m.compression_vs_fp32()),
            m.max_seq_for_budget(16 << 30).to_string(),
            batch_model.max_batch_for_budget(64u64 << 30).to_string(),
        ]);
    }
    t
}

/// Write a table to stdout + CSV under bench_results/.
pub fn emit(t: &Table, csv_name: &str) {
    t.print();
    let path = format!("bench_results/{csv_name}.csv");
    if let Err(e) = t.write_csv(&path) {
        crate::warn!("csv write failed for {path}: {e}");
    } else {
        println!("[csv] {path}");
    }
}
