//! Machine-readable bench artifacts: `bench_results/BENCH_<name>.json`.
//!
//! Every bench binary emits one report next to its printed tables so CI
//! can archive results and perf regressions become visible PR-over-PR.
//! Schema (`kvq-bench-v1`, documented in rust/README.md):
//!
//! ```text
//! {
//!   "schema": "kvq-bench-v1",
//!   "name": "<report name>",
//!   "created_unix_s": <seconds since epoch>,
//!   "env": { "<key>": <value>, ... },          // e.g. threads_auto
//!   "entries": [
//!     { "section": "<table/figure id>",
//!       "label":   "<row label>",
//!       "median_s": <seconds, may be null for non-timing rows>,
//!       "params":  { "<key>": <value>, ... } }, // e.g. threads, shape
//!     ...
//!   ]
//! }
//! ```

use crate::util::json::{obj, Json};
use std::collections::BTreeMap;

/// Accumulates bench entries and writes `BENCH_<name>.json`.
pub struct BenchReport {
    name: String,
    env: BTreeMap<String, Json>,
    entries: Vec<Json>,
}

impl BenchReport {
    pub fn new(name: &str) -> BenchReport {
        let mut env = BTreeMap::new();
        env.insert(
            "threads_auto".to_string(),
            Json::Num(crate::parallel::default_threads() as f64),
        );
        BenchReport { name: name.to_string(), env, entries: Vec::new() }
    }

    /// Record an environment fact (mode flags, workload sizes, ...).
    pub fn env(&mut self, key: &str, value: Json) {
        self.env.insert(key.to_string(), value);
    }

    /// Record one measured row. `median_s = None` marks non-timing rows
    /// (error metrics, memory figures) whose value lives in `params`.
    pub fn add(
        &mut self,
        section: &str,
        label: &str,
        median_s: Option<f64>,
        params: &[(&str, Json)],
    ) {
        self.entries.push(obj([
            ("section", section.into()),
            ("label", label.into()),
            (
                "median_s",
                match median_s {
                    Some(s) => Json::Num(s),
                    None => Json::Null,
                },
            ),
            (
                "params",
                Json::Obj(params.iter().map(|(k, v)| (k.to_string(), v.clone())).collect()),
            ),
        ]));
    }

    pub fn len(&self) -> usize {
        self.entries.len()
    }

    pub fn is_empty(&self) -> bool {
        self.entries.is_empty()
    }

    pub fn to_json(&self) -> Json {
        let created = std::time::SystemTime::now()
            .duration_since(std::time::UNIX_EPOCH)
            .map(|d| d.as_secs() as f64)
            .unwrap_or(0.0);
        obj([
            ("schema", "kvq-bench-v1".into()),
            ("name", self.name.as_str().into()),
            ("created_unix_s", Json::Num(created)),
            ("env", Json::Obj(self.env.clone())),
            ("entries", Json::Arr(self.entries.clone())),
        ])
    }

    /// Write to an explicit path (tests use a temp dir).
    pub fn write_to(&self, path: &str) -> std::io::Result<()> {
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        std::fs::write(path, self.to_json().to_string())
    }

    /// Write to the conventional `bench_results/BENCH_<name>.json` and
    /// return the path.
    pub fn write(&self) -> std::io::Result<String> {
        let path = format!("bench_results/BENCH_{}.json", self.name);
        self.write_to(&path)?;
        Ok(path)
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn report_roundtrips_through_json() {
        let mut r = BenchReport::new("unit");
        r.env("full", Json::Bool(false));
        r.add(
            "a4_quantize",
            "vectorized",
            Some(0.25),
            &[("threads", Json::Num(2.0)), ("shape", "2048x128".into())],
        );
        r.add("a6_int4", "int4", None, &[("l2_err", Json::Num(1.5))]);
        assert_eq!(r.len(), 2);
        let j = Json::parse(&r.to_json().to_string()).unwrap();
        assert_eq!(j.get("schema").as_str(), Some("kvq-bench-v1"));
        assert_eq!(j.get("name").as_str(), Some("unit"));
        assert!(j.get("env").get("threads_auto").as_usize().unwrap() >= 1);
        let e0 = j.get("entries").at(0);
        assert_eq!(e0.get("section").as_str(), Some("a4_quantize"));
        assert_eq!(e0.get("median_s").as_f64(), Some(0.25));
        assert_eq!(e0.get("params").get("threads").as_usize(), Some(2));
        assert_eq!(j.get("entries").at(1).get("median_s"), &Json::Null);
    }

    #[test]
    fn writes_to_disk() {
        let mut r = BenchReport::new("unit_write");
        r.add("s", "l", Some(1.0), &[]);
        let path = std::env::temp_dir().join("kvq_bench_report_test.json");
        r.write_to(path.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("kvq-bench-v1"));
        let _ = std::fs::remove_file(path);
    }
}
