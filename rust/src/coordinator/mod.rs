//! The serving coordinator — the L3 system the paper's future-work section
//! calls for: a vLLM-style framework with the INT8 KV cache as a
//! first-class feature.
//!
//! Architecture (single-process, channel-wired):
//!
//! ```text
//! clients → Router (ids, validation, dispatch)
//!             │ mpsc
//!             ▼
//!          Engine thread (owns Runtime/backend + KvCacheManager
//!             │           + PrefixCache)
//!             │  step loop:
//!             │    admit (optimistic prompt-fit or worst-case reserve)
//!             │    plan  (continuous batcher: resumes + prefills +
//!             │           decode sets + preemption victims)
//!             │    run   (prefix-hit forks / prefill artifacts / decode
//!             │           artifacts / CPU ref; preempt + replay under
//!             │           pool pressure)
//!             ▼
//!          per-request token streams → clients, Metrics
//! ```
//!
//! The PJRT runtime is not `Send`, so each engine owns its backend on a
//! dedicated thread; the router holds only channel handles and is freely
//! shareable. Multiple engines (e.g. INT8 + FP32 side-by-side) can run
//! under one router for A/B serving — or N identical shards for
//! session-affine sharded serving (see `router` for the admission plane:
//! bounded per-shard queues, load-aware spillover, overflow pump).

pub mod admission;
pub mod batcher;
pub mod engine;
pub mod metrics;
pub mod request;
pub mod router;
pub mod scheduler;

pub use admission::AdmissionMode;
pub use engine::{DecodeBatching, EngineConfig, EngineHandle};
pub use metrics::MetricsSnapshot;
pub use request::{FinishReason, Priority, Request, RequestId, TokenEvent};
pub use router::{
    Affinity, RoutePolicy, Router, RouterConfig, RouterStatsSnapshot, SubmitError, SubmitOptions,
};
