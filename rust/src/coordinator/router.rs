//! The sharded serving front door.
//!
//! N engine shards — each owning its own `BlockPool`, prefix cache, and
//! thread set — behind session-affine routing with load-aware spillover
//! and a bounded async admission plane:
//!
//! ```text
//! submit ──▶ home shard = hash(session | prompt prefix) % N
//!              │ depth < queue_depth?          ──▶ dispatch (home)
//!              │ else least-loaded shard open? ──▶ dispatch (spillover)
//!              │ else overflow queue has room? ──▶ park; pump thread
//!              │                                   dispatches FIFO when
//!              │                                   any shard drains
//!              └ else ──▶ SubmitError::Saturated (typed 503 upstream)
//! ```
//!
//! Shard load is the live request depth from the engine's own metrics
//! (submitted − terminated), which counts work still queued in the
//! engine's command channel — so the bound applies to true backlog, not
//! just the running set. Because each shard runs its own continuous
//! batcher on its own thread, prefill admission, decode waves, and
//! streaming on different shards overlap; nothing in the router blocks
//! on engine work.
//!
//! Determinism: routing never changes tokens. Per-request sampling RNG is
//! derived from (engine seed, prompt, sampling seed) only — see
//! `engine::request_rng` — so an affinity-pinned trace produces
//! byte-identical streams on 1 shard or N (pinned by tests/routing.rs).
//!
//! The legacy single/dual-engine API (`new` + `add_engine` + `submit` /
//! `submit_to`) is preserved for the A/B bench and examples: a default
//! `RouterConfig` has no affinity and an unbounded queue, which reduces
//! to the old round-robin/least-loaded validator + id allocator.

use super::engine::EngineHandle;
use super::request::{EventRx, EventTx, FinishReason, Priority, Request, RequestId, TokenEvent};
use crate::model::sample::SamplingParams;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::Duration;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

/// How a request's home shard is chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Affinity {
    /// Hash the session key; requests without one fall back to the
    /// prompt-prefix hash. Keeps a session's prompts on one shard so its
    /// prefix-cache entries stay hot.
    Session,
    /// Hash the first [`AFFINITY_PREFIX_TOKENS`] prompt tokens.
    Prefix,
    /// No affinity: pure policy pick (legacy round-robin/least-loaded).
    None,
}

impl Affinity {
    pub fn parse(s: &str) -> Option<Affinity> {
        Some(match s {
            "session" => Affinity::Session,
            "prefix" => Affinity::Prefix,
            "none" => Affinity::None,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Affinity::Session => "session",
            Affinity::Prefix => "prefix",
            Affinity::None => "none",
        }
    }
}

/// Prompt tokens hashed for prefix affinity (and the session fallback).
pub const AFFINITY_PREFIX_TOKENS: usize = 16;

/// Router configuration. The default reproduces the legacy behavior
/// exactly: no affinity, unbounded per-shard queues (never spills, never
/// overflows), round-robin dispatch.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Dispatch policy when affinity is `None` (and the tie-break order
    /// for spillover).
    pub policy: RoutePolicy,
    pub affinity: Affinity,
    /// Per-shard admission bound: a shard whose live depth reaches this
    /// is saturated (spillover, then overflow). 0 = unbounded.
    pub queue_depth: usize,
    /// Router-level overflow queue capacity; parked submissions wait here
    /// when every shard is saturated. Beyond it, submits fail typed.
    pub overflow_depth: usize,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: RoutePolicy::RoundRobin,
            affinity: Affinity::None,
            queue_depth: 0,
            overflow_depth: 256,
        }
    }
}

/// Per-submit routing options.
#[derive(Debug, Clone, Default)]
pub struct SubmitOptions {
    /// Session key for affinity routing (None = prompt-prefix fallback).
    pub session: Option<String>,
    pub priority: Option<Priority>,
    pub stop_token: Option<i32>,
    /// Pin to a shard index, bypassing affinity and saturation (A/B
    /// harnesses and tests).
    pub shard: Option<usize>,
}

/// Typed submission failure — the HTTP layer maps these onto honest
/// status codes (400 / 503) instead of stringly errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Malformed request: empty prompt, zero token budget, bad shard.
    Invalid(String),
    /// Every shard is at `queue_depth` and the overflow queue is full.
    Saturated { retry_after_ms: u64 },
    /// No shards registered, or the target engine's channel is closed.
    Unavailable(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Invalid(m) => write!(f, "invalid request: {m}"),
            SubmitError::Saturated { retry_after_ms } => {
                write!(f, "all shards saturated (retry in {retry_after_ms} ms)")
            }
            SubmitError::Unavailable(m) => write!(f, "service unavailable: {m}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Router counters (atomics: written on the submit path, read by
/// `/metrics`).
#[derive(Debug, Default)]
pub struct RouterStats {
    pub submitted: AtomicU64,
    /// Requests handed to a shard (directly or via the pump).
    pub dispatched: AtomicU64,
    /// Dispatches that left a saturated home shard for the least-loaded.
    pub spillovers: AtomicU64,
    pub overflow_enqueued: AtomicU64,
    pub overflow_dispatched: AtomicU64,
    /// High-water mark of the overflow queue.
    pub overflow_peak: AtomicU64,
    /// Submits refused with `SubmitError::Saturated`.
    pub rejected_saturated: AtomicU64,
}

/// Plain-value snapshot of [`RouterStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouterStatsSnapshot {
    pub submitted: u64,
    pub dispatched: u64,
    pub spillovers: u64,
    pub overflow_enqueued: u64,
    pub overflow_dispatched: u64,
    pub overflow_peak: u64,
    pub rejected_saturated: u64,
    /// Current overflow queue length.
    pub overflow_len: usize,
}

/// A submission parked in the overflow queue (its `EventTx` keeps the
/// client stream alive; the pump either dispatches or rejects it — a
/// parked stream is never silently dropped).
struct Pending {
    req: Request,
    events: EventTx,
    home: usize,
}

pub struct Router {
    engines: Vec<(String, EngineHandle)>,
    next_id: AtomicU64,
    rr: Mutex<usize>,
    cfg: RouterConfig,
    overflow: Mutex<VecDeque<Pending>>,
    overflow_cv: Condvar,
    pump_stop: AtomicBool,
    stats: RouterStats,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router::with_config(RouterConfig { policy, ..Default::default() })
    }

    pub fn with_config(cfg: RouterConfig) -> Router {
        Router {
            engines: Vec::new(),
            next_id: AtomicU64::new(1),
            rr: Mutex::new(0),
            cfg,
            overflow: Mutex::new(VecDeque::new()),
            overflow_cv: Condvar::new(),
            pump_stop: AtomicBool::new(false),
            stats: RouterStats::default(),
        }
    }

    pub fn add_engine(&mut self, name: &str, handle: EngineHandle) {
        self.engines.push((name.to_string(), handle));
    }

    pub fn engine_names(&self) -> Vec<&str> {
        self.engines.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn engine(&self, name: &str) -> Option<&EngineHandle> {
        self.engines.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    /// All shards in index order (shard i = i-th `add_engine`).
    pub fn shards(&self) -> &[(String, EngineHandle)] {
        &self.engines
    }

    pub fn shard_count(&self) -> usize {
        self.engines.len()
    }

    pub fn config(&self) -> &RouterConfig {
        &self.cfg
    }

    pub fn stats(&self) -> RouterStatsSnapshot {
        let s = &self.stats;
        RouterStatsSnapshot {
            submitted: s.submitted.load(Ordering::Relaxed),
            dispatched: s.dispatched.load(Ordering::Relaxed),
            spillovers: s.spillovers.load(Ordering::Relaxed),
            overflow_enqueued: s.overflow_enqueued.load(Ordering::Relaxed),
            overflow_dispatched: s.overflow_dispatched.load(Ordering::Relaxed),
            overflow_peak: s.overflow_peak.load(Ordering::Relaxed),
            rejected_saturated: s.rejected_saturated.load(Ordering::Relaxed),
            overflow_len: self.overflow.lock().unwrap().len(),
        }
    }

    pub fn alloc_id(&self) -> RequestId {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn depth(&self, idx: usize) -> usize {
        self.engines[idx].1.depth()
    }

    fn saturated(&self, idx: usize) -> bool {
        self.cfg.queue_depth > 0 && self.depth(idx) >= self.cfg.queue_depth
    }

    /// Policy pick over all shards (the legacy no-affinity path).
    fn pick_index(&self) -> usize {
        let n = self.engines.len();
        let mut rr = self.rr.lock().unwrap();
        let start = *rr % n;
        *rr += 1;
        match self.cfg.policy {
            RoutePolicy::RoundRobin => start,
            // Min current depth; ties broken round-robin so idle shards
            // share load instead of shard 0 absorbing it.
            RoutePolicy::LeastLoaded => (0..n)
                .map(|i| (start + i) % n)
                .min_by_key(|&i| self.depth(i))
                .unwrap_or(start),
        }
    }

    /// Least-loaded shard strictly below `queue_depth` (rotating
    /// tie-break), or None when every shard is saturated.
    fn least_loaded_open(&self) -> Option<usize> {
        let n = self.engines.len();
        let mut rr = self.rr.lock().unwrap();
        let start = *rr % n;
        *rr += 1;
        (0..n)
            .map(|i| (start + i) % n)
            .filter(|&i| !self.saturated(i))
            .min_by_key(|&i| self.depth(i))
    }

    /// Home shard for a (session, prompt) pair under the configured
    /// affinity. Stable across calls and shard-count-independent hashing
    /// (modulo N at the end): the routing contract affinity tests pin.
    pub fn home_shard(&self, session: Option<&str>, prompt: &[i32]) -> usize {
        let n = self.engines.len().max(1);
        let h = match (self.cfg.affinity, session) {
            (Affinity::None, _) => return self.pick_index(),
            (Affinity::Session, Some(s)) => fnv1a(s.as_bytes()),
            // Session affinity without a key, or prefix affinity: hash
            // the prompt prefix.
            _ => {
                let take = prompt.len().min(AFFINITY_PREFIX_TOKENS);
                let mut h = 0xcbf2_9ce4_8422_2325u64;
                for &t in &prompt[..take] {
                    h = (h ^ (t as u32 as u64)).wrapping_mul(0x100_0000_01b3);
                }
                h
            }
        };
        (h % n as u64) as usize
    }

    fn dispatch(&self, idx: usize, req: Request, events: EventTx) -> Result<(), SubmitError> {
        self.engines[idx]
            .1
            .submit(req, events)
            .map_err(|e| SubmitError::Unavailable(format!("{e}")))?;
        self.stats.dispatched.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Submit with routing options: affinity, spillover, and the bounded
    /// overflow queue. The returned stream always terminates — dispatched
    /// requests finish or are rejected by the engine; parked requests are
    /// dispatched or rejected by the pump.
    pub fn submit_with(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: SamplingParams,
        opts: SubmitOptions,
    ) -> Result<(RequestId, EventRx), SubmitError> {
        if prompt.is_empty() {
            return Err(SubmitError::Invalid("empty prompt".into()));
        }
        if max_new_tokens == 0 {
            return Err(SubmitError::Invalid("max_new_tokens must be >= 1".into()));
        }
        let n = self.engines.len();
        if n == 0 {
            return Err(SubmitError::Unavailable("no engines registered".into()));
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let id = self.alloc_id();
        let mut req = Request::new(id, prompt, max_new_tokens);
        req.sampling = sampling;
        if let Some(p) = opts.priority {
            req.priority = p;
        }
        req.stop_token = opts.stop_token;
        let (tx, rx) = mpsc::channel::<TokenEvent>();

        if let Some(s) = opts.shard {
            if s >= n {
                return Err(SubmitError::Invalid(format!("shard {s} >= shard count {n}")));
            }
            self.dispatch(s, req, tx)?;
            return Ok((id, rx));
        }

        let home = self.home_shard(opts.session.as_deref(), &req.prompt);
        if !self.saturated(home) {
            self.dispatch(home, req, tx)?;
            return Ok((id, rx));
        }
        // Home saturated: spill to the least-loaded open shard.
        if let Some(alt) = self.least_loaded_open() {
            self.stats.spillovers.fetch_add(1, Ordering::Relaxed);
            self.dispatch(alt, req, tx)?;
            return Ok((id, rx));
        }
        // Every shard saturated: park in the bounded overflow queue.
        // `req.arrival` was stamped above, so queueing delay counts
        // toward the client-observed TTFT.
        let mut q = self.overflow.lock().unwrap();
        if q.len() >= self.cfg.overflow_depth {
            self.stats.rejected_saturated.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Saturated {
                retry_after_ms: self.retry_after_ms(q.len()),
            });
        }
        q.push_back(Pending { req, events: tx, home });
        let len = q.len() as u64;
        self.stats.overflow_enqueued.fetch_add(1, Ordering::Relaxed);
        self.stats.overflow_peak.fetch_max(len, Ordering::Relaxed);
        drop(q);
        self.overflow_cv.notify_one();
        Ok((id, rx))
    }

    /// Crude backpressure hint: deeper backlog, longer suggested retry.
    fn retry_after_ms(&self, backlog: usize) -> u64 {
        50 * (backlog as u64 + 1)
    }

    /// Legacy submit: routes via `submit_with` with default options and
    /// adapts the typed error into `anyhow` for existing callers.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: SamplingParams,
    ) -> Result<(RequestId, EventRx)> {
        self.submit_with(prompt, max_new_tokens, sampling, SubmitOptions::default())
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Submit to a specific engine by name (A/B harness).
    pub fn submit_to(
        &self,
        engine: &str,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: SamplingParams,
    ) -> Result<(RequestId, EventRx)> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if max_new_tokens == 0 {
            bail!("max_new_tokens must be >= 1");
        }
        let h = self.engine(engine).ok_or_else(|| anyhow::anyhow!("no engine {engine:?}"))?;
        let id = self.alloc_id();
        let mut req = Request::new(id, prompt, max_new_tokens);
        req.sampling = sampling;
        let (tx, rx) = mpsc::channel::<TokenEvent>();
        h.submit(req, tx)?;
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        self.stats.dispatched.fetch_add(1, Ordering::Relaxed);
        Ok((id, rx))
    }

    /// Spawn the overflow pump: a background thread that drains the
    /// overflow queue FIFO into whichever shard frees capacity first
    /// (preferring a request's home shard when open). Required whenever
    /// `queue_depth > 0`; call [`Router::stop_pump`] before dropping the
    /// router so parked streams are rejected, not leaked.
    pub fn spawn_pump(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let r = Arc::clone(self);
        std::thread::Builder::new()
            .name("kvq-router-pump".into())
            .spawn(move || r.pump_loop())
            .expect("spawn router pump thread")
    }

    /// Stop the pump; it rejects any still-parked submissions on exit
    /// (their streams terminate with `FinishReason::Rejected`).
    pub fn stop_pump(&self) {
        self.pump_stop.store(true, Ordering::Relaxed);
        self.overflow_cv.notify_all();
    }

    fn pump_loop(&self) {
        let mut q = self.overflow.lock().unwrap();
        loop {
            if self.pump_stop.load(Ordering::Relaxed) {
                break;
            }
            if q.is_empty() {
                let (guard, _) = self
                    .overflow_cv
                    .wait_timeout(q, Duration::from_millis(5))
                    .unwrap();
                q = guard;
                continue;
            }
            // FIFO head-of-line: home shard if open, else least-loaded
            // open shard; no shard open → poll again shortly.
            let home = q.front().map(|p| p.home).unwrap_or(0);
            let target = if !self.saturated(home) { Some(home) } else { self.least_loaded_open() };
            match target {
                Some(idx) => {
                    let p = q.pop_front().unwrap();
                    drop(q);
                    self.stats.overflow_dispatched.fetch_add(1, Ordering::Relaxed);
                    if let Err(e) = self.dispatch(idx, p.req, p.events.clone()) {
                        // Engine died under us: terminate the stream.
                        let _ = p.events.send(TokenEvent::Finished {
                            reason: FinishReason::Rejected(format!("{e}")),
                            tokens: 0,
                            elapsed: 0.0,
                        });
                    }
                    q = self.overflow.lock().unwrap();
                }
                None => {
                    drop(q);
                    std::thread::sleep(Duration::from_millis(1));
                    q = self.overflow.lock().unwrap();
                }
            }
        }
        // No lost streams: reject everything still parked.
        for p in q.drain(..) {
            let _ = p.events.send(TokenEvent::Finished {
                reason: FinishReason::Rejected("router shutting down".into()),
                tokens: 0,
                elapsed: p.req.arrival.elapsed().as_secs_f64(),
            });
        }
    }
}

/// FNV-1a over bytes (session keys).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_inputs() {
        let r = Router::new(RoutePolicy::RoundRobin);
        assert!(r.submit(vec![], 4, SamplingParams::default()).is_err());
        assert!(r.submit(vec![1], 0, SamplingParams::default()).is_err());
        // no engines
        assert!(r.submit(vec![1], 1, SamplingParams::default()).is_err());
    }

    #[test]
    fn typed_errors_for_bad_submissions() {
        let r = Router::new(RoutePolicy::RoundRobin);
        let e = r
            .submit_with(vec![], 4, SamplingParams::default(), SubmitOptions::default())
            .unwrap_err();
        assert!(matches!(e, SubmitError::Invalid(_)));
        let e = r
            .submit_with(vec![1], 1, SamplingParams::default(), SubmitOptions::default())
            .unwrap_err();
        assert!(matches!(e, SubmitError::Unavailable(_)));
    }

    #[test]
    fn ids_are_unique_and_monotone() {
        let r = Router::new(RoutePolicy::RoundRobin);
        let a = r.alloc_id();
        let b = r.alloc_id();
        assert!(b > a);
    }

    #[test]
    fn affinity_hash_is_stable_and_session_keyed() {
        let cfg = RouterConfig { affinity: Affinity::Session, ..Default::default() };
        let r = Router::with_config(cfg);
        // The hash never dereferences engine handles; with no shards the
        // modulo clamps to a single slot, and repeated calls are stable.
        assert_eq!(r.home_shard(Some("s"), &[1, 2]), 0);
        assert_eq!(r.home_shard(Some("s"), &[9, 9]), r.home_shard(Some("s"), &[1, 2]));
        assert_eq!(r.home_shard(None, &[1, 2, 3]), r.home_shard(None, &[1, 2, 3]));
    }

    #[test]
    fn affinity_parse_round_trips() {
        for a in [Affinity::Session, Affinity::Prefix, Affinity::None] {
            assert_eq!(Affinity::parse(a.name()), Some(a));
        }
        assert_eq!(Affinity::parse("sticky"), None);
    }

    // Sharded dispatch, spillover, overflow, and determinism are
    // exercised with live engines in rust/tests/routing.rs; round-robin
    // and least-loaded dispatch in rust/tests/serving_integration.rs.
}
