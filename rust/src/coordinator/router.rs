//! The sharded serving front door.
//!
//! N engine shards — each owning its own `BlockPool`, prefix cache, and
//! thread set — behind session-affine routing with load-aware spillover
//! and a bounded async admission plane:
//!
//! ```text
//! submit ──▶ home shard = hash(session | prompt prefix) % N
//!              │ depth < queue_depth?          ──▶ dispatch (home)
//!              │ else least-loaded shard open? ──▶ dispatch (spillover)
//!              │ else overflow queue has room? ──▶ park; pump thread
//!              │                                   dispatches FIFO when
//!              │                                   any shard drains
//!              └ else ──▶ SubmitError::Saturated (typed 503 upstream)
//! ```
//!
//! Shard load is the live request depth from the engine's own metrics
//! (submitted − terminated), which counts work still queued in the
//! engine's command channel — so the bound applies to true backlog, not
//! just the running set. Because each shard runs its own continuous
//! batcher on its own thread, prefill admission, decode waves, and
//! streaming on different shards overlap; nothing in the router blocks
//! on engine work.
//!
//! Determinism: routing never changes tokens. Per-request sampling RNG is
//! derived from (engine seed, prompt, sampling seed) only — see
//! `engine::request_rng` — so an affinity-pinned trace produces
//! byte-identical streams on 1 shard or N (pinned by tests/routing.rs).
//!
//! The legacy single/dual-engine API (`new` + `add_engine` + `submit` /
//! `submit_to`) is preserved for the A/B bench and examples: a default
//! `RouterConfig` has no affinity and an unbounded queue, which reduces
//! to the old round-robin/least-loaded validator + id allocator.
//!
//! **Shard supervision.** Shards registered via [`Router::add_supervised`]
//! carry a respawn factory. The engine thread runs under `catch_unwind`
//! (see `engine::spawn_with`): on panic it fails every in-flight stream
//! typed (`FinishReason::ShardFailed`) and flips its [`ShardHealth`] to
//! `Dead`. The supervisor thread ([`Router::spawn_supervisor`]) notices,
//! waits out a bounded exponential backoff (with deterministic jitter),
//! respawns the shard through its factory — which re-runs snapshot
//! restore when `--snapshot-path` is set — and swaps the fresh handle in.
//! Dead/restarting shards read as saturated, so session-affine traffic
//! re-homes through the existing spillover path while the other shards
//! keep serving; nothing waits on the restart.

use super::engine::{EngineHandle, ShardHealth, ShardState};
use super::metrics::Metrics;
use super::request::{EventRx, EventTx, FinishReason, Priority, Request, RequestId, TokenEvent};
use crate::model::sample::SamplingParams;
use anyhow::{bail, Result};
use std::collections::VecDeque;
use std::sync::atomic::{AtomicBool, AtomicU64, Ordering};
use std::sync::{mpsc, Arc, Condvar, Mutex};
use std::time::{Duration, Instant};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

/// How a request's home shard is chosen.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum Affinity {
    /// Hash the session key; requests without one fall back to the
    /// prompt-prefix hash. Keeps a session's prompts on one shard so its
    /// prefix-cache entries stay hot.
    Session,
    /// Hash the first [`AFFINITY_PREFIX_TOKENS`] prompt tokens.
    Prefix,
    /// No affinity: pure policy pick (legacy round-robin/least-loaded).
    None,
}

impl Affinity {
    pub fn parse(s: &str) -> Option<Affinity> {
        Some(match s {
            "session" => Affinity::Session,
            "prefix" => Affinity::Prefix,
            "none" => Affinity::None,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Affinity::Session => "session",
            Affinity::Prefix => "prefix",
            Affinity::None => "none",
        }
    }
}

/// Prompt tokens hashed for prefix affinity (and the session fallback).
pub const AFFINITY_PREFIX_TOKENS: usize = 16;

/// Router configuration. The default reproduces the legacy behavior
/// exactly: no affinity, unbounded per-shard queues (never spills, never
/// overflows), round-robin dispatch.
#[derive(Debug, Clone, Copy)]
pub struct RouterConfig {
    /// Dispatch policy when affinity is `None` (and the tie-break order
    /// for spillover).
    pub policy: RoutePolicy,
    pub affinity: Affinity,
    /// Per-shard admission bound: a shard whose live depth reaches this
    /// is saturated (spillover, then overflow). 0 = unbounded.
    pub queue_depth: usize,
    /// Router-level overflow queue capacity; parked submissions wait here
    /// when every shard is saturated. Beyond it, submits fail typed.
    pub overflow_depth: usize,
    /// Deadline stamped on every submission that doesn't carry its own
    /// (`SubmitOptions::deadline_ms`). 0 = no default deadline.
    pub default_deadline_ms: u64,
}

impl Default for RouterConfig {
    fn default() -> Self {
        RouterConfig {
            policy: RoutePolicy::RoundRobin,
            affinity: Affinity::None,
            queue_depth: 0,
            overflow_depth: 256,
            default_deadline_ms: 0,
        }
    }
}

/// Per-submit routing options.
#[derive(Debug, Clone, Default)]
pub struct SubmitOptions {
    /// Session key for affinity routing (None = prompt-prefix fallback).
    pub session: Option<String>,
    pub priority: Option<Priority>,
    pub stop_token: Option<i32>,
    /// Pin to a shard index, bypassing affinity and saturation (A/B
    /// harnesses and tests).
    pub shard: Option<usize>,
    /// Per-request deadline override. `Some(0)` explicitly disables the
    /// router default; `None` inherits `RouterConfig::default_deadline_ms`.
    pub deadline_ms: Option<u64>,
}

/// Typed submission failure — the HTTP layer maps these onto honest
/// status codes (400 / 503) instead of stringly errors.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum SubmitError {
    /// Malformed request: empty prompt, zero token budget, bad shard.
    Invalid(String),
    /// Every shard is at `queue_depth` and the overflow queue is full.
    Saturated { retry_after_ms: u64 },
    /// No shards registered, or the target engine's channel is closed.
    Unavailable(String),
}

impl std::fmt::Display for SubmitError {
    fn fmt(&self, f: &mut std::fmt::Formatter<'_>) -> std::fmt::Result {
        match self {
            SubmitError::Invalid(m) => write!(f, "invalid request: {m}"),
            SubmitError::Saturated { retry_after_ms } => {
                write!(f, "all shards saturated (retry in {retry_after_ms} ms)")
            }
            SubmitError::Unavailable(m) => write!(f, "service unavailable: {m}"),
        }
    }
}

impl std::error::Error for SubmitError {}

/// Router counters (atomics: written on the submit path, read by
/// `/metrics`).
#[derive(Debug, Default)]
pub struct RouterStats {
    pub submitted: AtomicU64,
    /// Requests handed to a shard (directly or via the pump).
    pub dispatched: AtomicU64,
    /// Dispatches that left a saturated home shard for the least-loaded.
    pub spillovers: AtomicU64,
    pub overflow_enqueued: AtomicU64,
    pub overflow_dispatched: AtomicU64,
    /// High-water mark of the overflow queue.
    pub overflow_peak: AtomicU64,
    /// Submits refused with `SubmitError::Saturated`.
    pub rejected_saturated: AtomicU64,
    /// Supervised shard respawns (across all shards).
    pub shard_restarts: AtomicU64,
}

/// Plain-value snapshot of [`RouterStats`].
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct RouterStatsSnapshot {
    pub submitted: u64,
    pub dispatched: u64,
    pub spillovers: u64,
    pub overflow_enqueued: u64,
    pub overflow_dispatched: u64,
    pub overflow_peak: u64,
    pub rejected_saturated: u64,
    pub shard_restarts: u64,
    /// Current overflow queue length.
    pub overflow_len: usize,
}

/// A submission parked in the overflow queue (its `EventTx` keeps the
/// client stream alive; the pump either dispatches or rejects it — a
/// parked stream is never silently dropped).
struct Pending {
    req: Request,
    events: EventTx,
    home: usize,
    /// Home-shard re-checks made by the pump before spilling elsewhere.
    attempts: u32,
}

/// Factory that (re)spawns an engine shard. It receives the shard's
/// long-lived [`Metrics`] and [`ShardHealth`] — both outlive any single
/// engine thread, so counters and restart counts accumulate across
/// respawns — and returns the fresh handle plus the engine thread's join
/// handle. Factories built over `--snapshot-path` re-run snapshot restore
/// on every (re)spawn, so a respawned shard comes back with its warm
/// prefix set.
pub type SpawnedShard = (EngineHandle, std::thread::JoinHandle<()>);

pub type ShardSpawner = Box<dyn Fn(Metrics, Arc<ShardHealth>) -> SpawnedShard + Send + Sync>;

/// One shard slot. The handle is behind a mutex only because the
/// supervisor swaps it on respawn; every reader takes a short lock and
/// clones (an `EngineHandle` is an mpsc sender + metrics handle).
struct Shard {
    name: String,
    handle: Mutex<EngineHandle>,
    /// Shard-lifetime metrics, shared with every engine incarnation.
    metrics: Metrics,
    health: Arc<ShardHealth>,
    /// Present only for supervised shards.
    spawner: Option<ShardSpawner>,
    join: Mutex<Option<std::thread::JoinHandle<()>>>,
}

/// Respawn backoff: `RESPAWN_BASE_MS << attempt` (capped) plus up to 25%
/// deterministic jitter.
const RESPAWN_BASE_MS: u64 = 10;
const RESPAWN_CAP_MS: u64 = 2_000;

pub struct Router {
    shards: Vec<Shard>,
    next_id: AtomicU64,
    rr: Mutex<usize>,
    cfg: RouterConfig,
    overflow: Mutex<VecDeque<Pending>>,
    overflow_cv: Condvar,
    pump_stop: AtomicBool,
    supervisor_stop: AtomicBool,
    stats: RouterStats,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router::with_config(RouterConfig { policy, ..Default::default() })
    }

    pub fn with_config(cfg: RouterConfig) -> Router {
        Router {
            shards: Vec::new(),
            next_id: AtomicU64::new(1),
            rr: Mutex::new(0),
            cfg,
            overflow: Mutex::new(VecDeque::new()),
            overflow_cv: Condvar::new(),
            pump_stop: AtomicBool::new(false),
            supervisor_stop: AtomicBool::new(false),
            stats: RouterStats::default(),
        }
    }

    /// Register an unsupervised shard (legacy path). Its health slot is a
    /// placeholder that always reads `Ok` — engines spawned through
    /// `engine::spawn` keep their own health Arc — so there is no respawn
    /// and no dead-shard traffic gating; use [`Router::add_supervised`]
    /// for both.
    pub fn add_engine(&mut self, name: &str, handle: EngineHandle) {
        let metrics = handle.metrics.clone();
        self.shards.push(Shard {
            name: name.to_string(),
            handle: Mutex::new(handle),
            metrics,
            health: Arc::new(ShardHealth::new()),
            spawner: None,
            join: Mutex::new(None),
        });
    }

    /// Register a supervised shard: the factory is invoked once now and
    /// again by the supervisor after every panic-death.
    pub fn add_supervised(&mut self, name: &str, spawner: ShardSpawner) {
        let metrics = Metrics::new();
        let health = Arc::new(ShardHealth::new());
        let (handle, join) = spawner(metrics.clone(), Arc::clone(&health));
        self.shards.push(Shard {
            name: name.to_string(),
            handle: Mutex::new(handle),
            metrics,
            health,
            spawner: Some(spawner),
            join: Mutex::new(Some(join)),
        });
    }

    pub fn engine_names(&self) -> Vec<&str> {
        self.shards.iter().map(|s| s.name.as_str()).collect()
    }

    pub fn engine(&self, name: &str) -> Option<EngineHandle> {
        self.shards
            .iter()
            .find(|s| s.name == name)
            .map(|s| s.handle.lock().unwrap().clone())
    }

    /// All shards in index order (shard i = i-th registration). Returns
    /// clones: handles can be swapped underneath by the supervisor, so
    /// callers get a point-in-time view.
    pub fn shards(&self) -> Vec<(String, EngineHandle)> {
        self.shards
            .iter()
            .map(|s| (s.name.clone(), s.handle.lock().unwrap().clone()))
            .collect()
    }

    /// Per-shard supervision view for `/metrics`:
    /// (name, watchdog/health state, respawn count).
    pub fn shard_states(&self) -> Vec<(String, ShardState, u64)> {
        self.shards
            .iter()
            .map(|s| (s.name.clone(), s.health.get(), s.health.restarts.load(Ordering::Relaxed)))
            .collect()
    }

    pub fn shard_count(&self) -> usize {
        self.shards.len()
    }

    pub fn config(&self) -> &RouterConfig {
        &self.cfg
    }

    pub fn stats(&self) -> RouterStatsSnapshot {
        let s = &self.stats;
        RouterStatsSnapshot {
            submitted: s.submitted.load(Ordering::Relaxed),
            dispatched: s.dispatched.load(Ordering::Relaxed),
            spillovers: s.spillovers.load(Ordering::Relaxed),
            overflow_enqueued: s.overflow_enqueued.load(Ordering::Relaxed),
            overflow_dispatched: s.overflow_dispatched.load(Ordering::Relaxed),
            overflow_peak: s.overflow_peak.load(Ordering::Relaxed),
            rejected_saturated: s.rejected_saturated.load(Ordering::Relaxed),
            shard_restarts: s.shard_restarts.load(Ordering::Relaxed),
            overflow_len: self.overflow.lock().unwrap().len(),
        }
    }

    pub fn alloc_id(&self) -> RequestId {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn depth(&self, idx: usize) -> usize {
        // Shard-lifetime metrics, not the (swappable) handle: depth stays
        // meaningful across a respawn.
        self.shards[idx].metrics.depth()
    }

    /// A shard takes no new traffic while at its depth bound *or* while
    /// dead/restarting — the latter is how affinity-pinned sessions
    /// re-home through the spillover path during a respawn.
    fn saturated(&self, idx: usize) -> bool {
        match self.shards[idx].health.get() {
            ShardState::Dead | ShardState::Restarting => return true,
            ShardState::Ok | ShardState::Stalled => {}
        }
        self.cfg.queue_depth > 0 && self.depth(idx) >= self.cfg.queue_depth
    }

    /// Policy pick over all shards (the legacy no-affinity path).
    fn pick_index(&self) -> usize {
        let n = self.shards.len();
        let mut rr = self.rr.lock().unwrap();
        let start = *rr % n;
        *rr += 1;
        match self.cfg.policy {
            RoutePolicy::RoundRobin => start,
            // Min current depth; ties broken round-robin so idle shards
            // share load instead of shard 0 absorbing it.
            RoutePolicy::LeastLoaded => (0..n)
                .map(|i| (start + i) % n)
                .min_by_key(|&i| self.depth(i))
                .unwrap_or(start),
        }
    }

    /// Least-loaded shard strictly below `queue_depth` (rotating
    /// tie-break), or None when every shard is saturated.
    fn least_loaded_open(&self) -> Option<usize> {
        let n = self.shards.len();
        let mut rr = self.rr.lock().unwrap();
        let start = *rr % n;
        *rr += 1;
        (0..n)
            .map(|i| (start + i) % n)
            .filter(|&i| !self.saturated(i))
            .min_by_key(|&i| self.depth(i))
    }

    /// Home shard for a (session, prompt) pair under the configured
    /// affinity. Stable across calls and shard-count-independent hashing
    /// (modulo N at the end): the routing contract affinity tests pin.
    pub fn home_shard(&self, session: Option<&str>, prompt: &[i32]) -> usize {
        let n = self.shards.len().max(1);
        let h = match (self.cfg.affinity, session) {
            (Affinity::None, _) => return self.pick_index(),
            (Affinity::Session, Some(s)) => fnv1a(s.as_bytes()),
            // Session affinity without a key, or prefix affinity: hash
            // the prompt prefix.
            _ => {
                let take = prompt.len().min(AFFINITY_PREFIX_TOKENS);
                let mut h = 0xcbf2_9ce4_8422_2325u64;
                for &t in &prompt[..take] {
                    h = (h ^ (t as u32 as u64)).wrapping_mul(0x100_0000_01b3);
                }
                h
            }
        };
        (h % n as u64) as usize
    }

    fn dispatch(&self, idx: usize, req: Request, events: EventTx) -> Result<(), SubmitError> {
        let h = self.shards[idx].handle.lock().unwrap().clone();
        h.submit(req, events).map_err(|e| SubmitError::Unavailable(format!("{e}")))?;
        self.stats.dispatched.fetch_add(1, Ordering::Relaxed);
        Ok(())
    }

    /// Submit with routing options: affinity, spillover, and the bounded
    /// overflow queue. The returned stream always terminates — dispatched
    /// requests finish or are rejected by the engine; parked requests are
    /// dispatched or rejected by the pump.
    pub fn submit_with(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: SamplingParams,
        opts: SubmitOptions,
    ) -> Result<(RequestId, EventRx), SubmitError> {
        if prompt.is_empty() {
            return Err(SubmitError::Invalid("empty prompt".into()));
        }
        if max_new_tokens == 0 {
            return Err(SubmitError::Invalid("max_new_tokens must be >= 1".into()));
        }
        let n = self.shards.len();
        if n == 0 {
            return Err(SubmitError::Unavailable("no engines registered".into()));
        }
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        let id = self.alloc_id();
        let mut req = Request::new(id, prompt, max_new_tokens);
        req.sampling = sampling;
        if let Some(p) = opts.priority {
            req.priority = p;
        }
        req.stop_token = opts.stop_token;
        let deadline_ms = opts.deadline_ms.unwrap_or(self.cfg.default_deadline_ms);
        if deadline_ms > 0 {
            req.deadline = Some(Instant::now() + Duration::from_millis(deadline_ms));
        }
        let (tx, rx) = mpsc::channel::<TokenEvent>();

        if let Some(s) = opts.shard {
            if s >= n {
                return Err(SubmitError::Invalid(format!("shard {s} >= shard count {n}")));
            }
            self.dispatch(s, req, tx)?;
            return Ok((id, rx));
        }

        let home = self.home_shard(opts.session.as_deref(), &req.prompt);
        if !self.saturated(home) {
            self.dispatch(home, req, tx)?;
            return Ok((id, rx));
        }
        // Home saturated: spill to the least-loaded open shard.
        if let Some(alt) = self.least_loaded_open() {
            self.stats.spillovers.fetch_add(1, Ordering::Relaxed);
            self.dispatch(alt, req, tx)?;
            return Ok((id, rx));
        }
        // Every shard saturated: park in the bounded overflow queue.
        // `req.arrival` was stamped above, so queueing delay counts
        // toward the client-observed TTFT.
        let mut q = self.overflow.lock().unwrap();
        if q.len() >= self.cfg.overflow_depth {
            self.stats.rejected_saturated.fetch_add(1, Ordering::Relaxed);
            return Err(SubmitError::Saturated {
                retry_after_ms: self.retry_after_ms(q.len()),
            });
        }
        q.push_back(Pending { req, events: tx, home, attempts: 0 });
        let len = q.len() as u64;
        self.stats.overflow_enqueued.fetch_add(1, Ordering::Relaxed);
        self.stats.overflow_peak.fetch_max(len, Ordering::Relaxed);
        drop(q);
        self.overflow_cv.notify_one();
        Ok((id, rx))
    }

    /// Load-derived backpressure hint: estimate how long the cluster
    /// needs to drain what's ahead of a retry, from live depths and the
    /// slowest shard's observed inter-token p50.
    fn retry_after_ms(&self, backlog: usize) -> u64 {
        let depth_sum: usize = (0..self.shards.len()).map(|i| self.depth(i)).sum();
        let tpot_p50_s = self
            .shards
            .iter()
            .map(|s| s.metrics.snapshot().tpot_p50)
            .fold(0.0f64, f64::max);
        retry_hint_ms(backlog, depth_sum, tpot_p50_s)
    }

    /// Legacy submit: routes via `submit_with` with default options and
    /// adapts the typed error into `anyhow` for existing callers.
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: SamplingParams,
    ) -> Result<(RequestId, EventRx)> {
        self.submit_with(prompt, max_new_tokens, sampling, SubmitOptions::default())
            .map_err(|e| anyhow::anyhow!("{e}"))
    }

    /// Submit to a specific engine by name (A/B harness).
    pub fn submit_to(
        &self,
        engine: &str,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: SamplingParams,
    ) -> Result<(RequestId, EventRx)> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if max_new_tokens == 0 {
            bail!("max_new_tokens must be >= 1");
        }
        let h = self.engine(engine).ok_or_else(|| anyhow::anyhow!("no engine {engine:?}"))?;
        let id = self.alloc_id();
        let mut req = Request::new(id, prompt, max_new_tokens);
        req.sampling = sampling;
        let (tx, rx) = mpsc::channel::<TokenEvent>();
        h.submit(req, tx)?;
        self.stats.submitted.fetch_add(1, Ordering::Relaxed);
        self.stats.dispatched.fetch_add(1, Ordering::Relaxed);
        Ok((id, rx))
    }

    /// Spawn the overflow pump: a background thread that drains the
    /// overflow queue FIFO into whichever shard frees capacity first
    /// (preferring a request's home shard when open). Required whenever
    /// `queue_depth > 0`; call [`Router::stop_pump`] before dropping the
    /// router so parked streams are rejected, not leaked.
    pub fn spawn_pump(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let r = Arc::clone(self);
        std::thread::Builder::new()
            .name("kvq-router-pump".into())
            .spawn(move || r.pump_loop())
            .expect("spawn router pump thread")
    }

    /// Stop the pump; it rejects any still-parked submissions on exit
    /// (their streams terminate with `FinishReason::Rejected`).
    pub fn stop_pump(&self) {
        self.pump_stop.store(true, Ordering::Relaxed);
        self.overflow_cv.notify_all();
    }

    fn pump_loop(&self) {
        let mut q = self.overflow.lock().unwrap();
        loop {
            if self.pump_stop.load(Ordering::Relaxed) {
                break;
            }
            if q.is_empty() {
                let (guard, _) = self
                    .overflow_cv
                    .wait_timeout(q, Duration::from_millis(5))
                    .unwrap();
                q = guard;
                continue;
            }
            // FIFO head-of-line: prefer the home shard (its prefix cache
            // is warm for the session), re-checking it a few times with a
            // capped-doubling backoff before giving up and spilling to
            // the least-loaded open shard.
            let (home, attempts) = q.front().map(|p| (p.home, p.attempts)).unwrap_or((0, 0));
            let target = if !self.saturated(home) {
                Some(home)
            } else if attempts < PUMP_HOME_RETRIES {
                if let Some(p) = q.front_mut() {
                    p.attempts += 1;
                }
                drop(q);
                std::thread::sleep(Duration::from_millis(pump_backoff_ms(attempts)));
                q = self.overflow.lock().unwrap();
                continue;
            } else {
                self.least_loaded_open()
            };
            match target {
                Some(idx) => {
                    let p = q.pop_front().unwrap();
                    drop(q);
                    self.stats.overflow_dispatched.fetch_add(1, Ordering::Relaxed);
                    if idx != p.home {
                        self.stats.spillovers.fetch_add(1, Ordering::Relaxed);
                    }
                    if let Err(e) = self.dispatch(idx, p.req, p.events.clone()) {
                        // Engine died under us: terminate the stream.
                        let _ = p.events.send(TokenEvent::Finished {
                            reason: FinishReason::Rejected(format!("{e}")),
                            tokens: 0,
                            elapsed: 0.0,
                        });
                    }
                    q = self.overflow.lock().unwrap();
                }
                None => {
                    drop(q);
                    std::thread::sleep(Duration::from_millis(1));
                    q = self.overflow.lock().unwrap();
                }
            }
        }
        // No lost streams: reject everything still parked.
        for p in q.drain(..) {
            let _ = p.events.send(TokenEvent::Finished {
                reason: FinishReason::Rejected("router shutting down".into()),
                tokens: 0,
                elapsed: p.req.arrival.elapsed().as_secs_f64(),
            });
        }
    }

    /// Spawn the shard supervisor: a background thread that watches every
    /// supervised shard's health and respawns dead ones under bounded
    /// exponential backoff. Call [`Router::stop_supervisor`] before
    /// tearing the router down (otherwise a deliberately drained shard
    /// is left alone — normal exit keeps health `Ok` — but the thread
    /// itself never stops).
    pub fn spawn_supervisor(self: &Arc<Self>) -> std::thread::JoinHandle<()> {
        let r = Arc::clone(self);
        std::thread::Builder::new()
            .name("kvq-router-supervisor".into())
            .spawn(move || r.supervisor_loop())
            .expect("spawn router supervisor thread")
    }

    pub fn stop_supervisor(&self) {
        self.supervisor_stop.store(true, Ordering::Relaxed);
    }

    fn supervisor_loop(&self) {
        struct RespawnState {
            attempt: u32,
            due: Option<Instant>,
            last_respawn: Option<Instant>,
        }
        let mut state: Vec<RespawnState> = self
            .shards
            .iter()
            .map(|_| RespawnState { attempt: 0, due: None, last_respawn: None })
            .collect();
        // Fixed seed: jitter decorrelates simultaneous respawns without
        // making supervision schedules nondeterministic across runs.
        let mut rng = crate::util::rng::Rng::new(0x5AFE_C0DE);
        while !self.supervisor_stop.load(Ordering::Relaxed) {
            for (i, shard) in self.shards.iter().enumerate() {
                if shard.spawner.is_none() {
                    continue;
                }
                let st = &mut state[i];
                match shard.health.get() {
                    ShardState::Dead => {
                        let now = Instant::now();
                        match st.due {
                            None => {
                                let backoff = RESPAWN_BASE_MS
                                    .checked_shl(st.attempt.min(8))
                                    .unwrap_or(RESPAWN_CAP_MS)
                                    .min(RESPAWN_CAP_MS);
                                let wait = backoff + rng.below(backoff / 4 + 1);
                                st.due = Some(now + Duration::from_millis(wait));
                                crate::warn!(
                                    "shard {} dead; respawning in {}ms (attempt {})",
                                    shard.name,
                                    wait,
                                    st.attempt + 1
                                );
                            }
                            Some(due) if now >= due => {
                                st.due = None;
                                st.attempt = st.attempt.saturating_add(1);
                                st.last_respawn = Some(now);
                                self.respawn(shard);
                            }
                            Some(_) => {}
                        }
                    }
                    ShardState::Ok => {
                        // Healthy for a while after a respawn: reset the
                        // backoff (a fresh engine flips to Ok instantly,
                        // so a crash loop must keep escalating — only
                        // sustained health earns a reset).
                        let settled = match st.last_respawn {
                            Some(t) => t.elapsed() >= Duration::from_secs(1),
                            None => true,
                        };
                        if st.attempt > 0 && settled {
                            st.attempt = 0;
                        }
                    }
                    ShardState::Stalled | ShardState::Restarting => {}
                }
            }
            std::thread::sleep(Duration::from_millis(2));
        }
    }

    fn respawn(&self, shard: &Shard) {
        shard.health.set(ShardState::Restarting);
        let spawner = shard.spawner.as_ref().expect("respawn requires a spawner");
        // Same Metrics and ShardHealth as every prior incarnation: depth
        // and restart accounting survive the swap. The factory re-runs
        // snapshot restore if the engine config carries a snapshot path.
        let (handle, join) = spawner(shard.metrics.clone(), Arc::clone(&shard.health));
        *shard.handle.lock().unwrap() = handle;
        if let Some(old) = shard.join.lock().unwrap().replace(join) {
            // The dead incarnation already unwound; join returns fast.
            let _ = old.join();
        }
        shard.health.restarts.fetch_add(1, Ordering::Relaxed);
        self.stats.shard_restarts.fetch_add(1, Ordering::Relaxed);
        crate::info!(
            "shard {} respawned (restart #{})",
            shard.name,
            shard.health.restarts.load(Ordering::Relaxed)
        );
    }
}

/// Home-shard re-checks before the pump spills a parked request.
const PUMP_HOME_RETRIES: u32 = 4;

/// Capped-doubling wait between the pump's home-shard re-checks:
/// 1, 2, 4, 8, 16 ms — a busy home shard delays a parked request by at
/// most ~31ms total before it spills to another shard.
fn pump_backoff_ms(attempt: u32) -> u64 {
    1u64 << attempt.min(4)
}

/// Load-derived retry hint for `SubmitError::Saturated`: estimated time
/// to drain `backlog` parked submissions plus `depth_sum` in-flight
/// requests, costing each ~[`RETRY_STEPS_PER_REQUEST`] decode steps at
/// the observed inter-token p50 (50ms assumed before any token has been
/// timed). Clamped to [10ms, 30s]: never zero (clients must not
/// busy-spin), never absurd.
fn retry_hint_ms(backlog: usize, depth_sum: usize, tpot_p50_s: f64) -> u64 {
    const FALLBACK_TPOT_S: f64 = 0.05;
    let per_token_s = if tpot_p50_s > 0.0 { tpot_p50_s } else { FALLBACK_TPOT_S };
    let outstanding = (backlog + depth_sum) as f64;
    let est_ms = outstanding * RETRY_STEPS_PER_REQUEST * per_token_s * 1000.0;
    (est_ms as u64).clamp(10, 30_000)
}

/// Decode steps a queued request is assumed to cost in the retry hint.
const RETRY_STEPS_PER_REQUEST: f64 = 8.0;

/// FNV-1a over bytes (session keys).
fn fnv1a(bytes: &[u8]) -> u64 {
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &b in bytes {
        h = (h ^ b as u64).wrapping_mul(0x100_0000_01b3);
    }
    h
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_inputs() {
        let r = Router::new(RoutePolicy::RoundRobin);
        assert!(r.submit(vec![], 4, SamplingParams::default()).is_err());
        assert!(r.submit(vec![1], 0, SamplingParams::default()).is_err());
        // no engines
        assert!(r.submit(vec![1], 1, SamplingParams::default()).is_err());
    }

    #[test]
    fn typed_errors_for_bad_submissions() {
        let r = Router::new(RoutePolicy::RoundRobin);
        let e = r
            .submit_with(vec![], 4, SamplingParams::default(), SubmitOptions::default())
            .unwrap_err();
        assert!(matches!(e, SubmitError::Invalid(_)));
        let e = r
            .submit_with(vec![1], 1, SamplingParams::default(), SubmitOptions::default())
            .unwrap_err();
        assert!(matches!(e, SubmitError::Unavailable(_)));
    }

    #[test]
    fn ids_are_unique_and_monotone() {
        let r = Router::new(RoutePolicy::RoundRobin);
        let a = r.alloc_id();
        let b = r.alloc_id();
        assert!(b > a);
    }

    #[test]
    fn affinity_hash_is_stable_and_session_keyed() {
        let cfg = RouterConfig { affinity: Affinity::Session, ..Default::default() };
        let r = Router::with_config(cfg);
        // The hash never dereferences engine handles; with no shards the
        // modulo clamps to a single slot, and repeated calls are stable.
        assert_eq!(r.home_shard(Some("s"), &[1, 2]), 0);
        assert_eq!(r.home_shard(Some("s"), &[9, 9]), r.home_shard(Some("s"), &[1, 2]));
        assert_eq!(r.home_shard(None, &[1, 2, 3]), r.home_shard(None, &[1, 2, 3]));
    }

    #[test]
    fn affinity_parse_round_trips() {
        for a in [Affinity::Session, Affinity::Prefix, Affinity::None] {
            assert_eq!(Affinity::parse(a.name()), Some(a));
        }
        assert_eq!(Affinity::parse("sticky"), None);
    }

    #[test]
    fn retry_hint_scales_with_load_and_stays_bounded() {
        // Never zero, even with nothing outstanding and no tpot sample:
        // clients must not busy-spin on a Saturated response.
        assert!(retry_hint_ms(0, 0, 0.0) >= 10);
        // Monotone in backlog and in in-flight depth.
        assert!(retry_hint_ms(10, 0, 0.05) > retry_hint_ms(1, 0, 0.05));
        assert!(retry_hint_ms(4, 40, 0.05) > retry_hint_ms(4, 4, 0.05));
        // Slower shards (higher observed tpot) stretch the hint.
        assert!(retry_hint_ms(4, 4, 0.2) > retry_hint_ms(4, 4, 0.01));
        // Hard cap at 30s regardless of load.
        assert_eq!(retry_hint_ms(usize::MAX / 2, 0, 100.0), 30_000);
    }

    #[test]
    fn pump_backoff_doubles_then_caps() {
        assert_eq!(pump_backoff_ms(0), 1);
        assert_eq!(pump_backoff_ms(1), 2);
        assert_eq!(pump_backoff_ms(2), 4);
        assert_eq!(pump_backoff_ms(3), 8);
        assert_eq!(pump_backoff_ms(4), 16);
        assert_eq!(pump_backoff_ms(31), 16, "capped, no overflow");
        // Worst-case home-shard dwell before spilling stays small.
        let total: u64 = (0..PUMP_HOME_RETRIES).map(pump_backoff_ms).sum();
        assert!(total <= 31);
    }

    #[test]
    fn default_deadline_config_round_trips() {
        let cfg = RouterConfig { default_deadline_ms: 250, ..Default::default() };
        let r = Router::with_config(cfg);
        assert_eq!(r.config().default_deadline_ms, 250);
        // Default config stamps no deadline.
        assert_eq!(RouterConfig::default().default_deadline_ms, 0);
    }

    // Sharded dispatch, spillover, overflow, and determinism are
    // exercised with live engines in rust/tests/routing.rs; round-robin
    // and least-loaded dispatch in rust/tests/serving_integration.rs;
    // supervised respawn + typed shard-failure streams in
    // rust/tests/chaos.rs.
}
