//! Request router: the shared front door.
//!
//! Assigns request ids, validates basic shape, and dispatches to one of
//! the registered engines. Routing policies: round-robin or
//! least-loaded (by running+waiting depth from the engine's metrics).
//! With one engine it degenerates to a validator + id allocator; the
//! multi-engine path serves the INT8-vs-FP32 A/B configuration of the e2e
//! bench.

use super::engine::EngineHandle;
use super::request::{EventRx, Request, RequestId, TokenEvent};
use crate::model::sample::SamplingParams;
use anyhow::{bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Mutex;

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum RoutePolicy {
    RoundRobin,
    LeastLoaded,
}

pub struct Router {
    engines: Vec<(String, EngineHandle)>,
    next_id: AtomicU64,
    rr: Mutex<usize>,
    policy: RoutePolicy,
}

impl Router {
    pub fn new(policy: RoutePolicy) -> Router {
        Router { engines: Vec::new(), next_id: AtomicU64::new(1), rr: Mutex::new(0), policy }
    }

    pub fn add_engine(&mut self, name: &str, handle: EngineHandle) {
        self.engines.push((name.to_string(), handle));
    }

    pub fn engine_names(&self) -> Vec<&str> {
        self.engines.iter().map(|(n, _)| n.as_str()).collect()
    }

    pub fn engine(&self, name: &str) -> Option<&EngineHandle> {
        self.engines.iter().find(|(n, _)| n == name).map(|(_, h)| h)
    }

    pub fn alloc_id(&self) -> RequestId {
        self.next_id.fetch_add(1, Ordering::Relaxed)
    }

    fn pick(&self) -> Result<&EngineHandle> {
        if self.engines.is_empty() {
            bail!("no engines registered");
        }
        match self.policy {
            RoutePolicy::RoundRobin => {
                let mut rr = self.rr.lock().unwrap();
                let idx = *rr % self.engines.len();
                *rr += 1;
                Ok(&self.engines[idx].1)
            }
            RoutePolicy::LeastLoaded => {
                // Min current depth; ties broken round-robin so idle
                // engines share load instead of engine 0 absorbing it.
                let mut rr = self.rr.lock().unwrap();
                let n = self.engines.len();
                let start = *rr % n;
                *rr += 1;
                let h = (0..n)
                    .map(|i| &self.engines[(start + i) % n].1)
                    .min_by_key(|h| {
                        let s = h.metrics.snapshot();
                        s.running + s.waiting
                    })
                    .unwrap();
                Ok(h)
            }
        }
    }

    /// Submit a generation request; returns (id, event stream).
    pub fn submit(
        &self,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: SamplingParams,
    ) -> Result<(RequestId, EventRx)> {
        if prompt.is_empty() {
            bail!("empty prompt");
        }
        if max_new_tokens == 0 {
            bail!("max_new_tokens must be >= 1");
        }
        let id = self.alloc_id();
        let mut req = Request::new(id, prompt, max_new_tokens);
        req.sampling = sampling;
        let (tx, rx) = mpsc::channel::<TokenEvent>();
        self.pick()?.submit(req, tx)?;
        Ok((id, rx))
    }

    /// Submit to a specific engine by name (A/B harness).
    pub fn submit_to(
        &self,
        engine: &str,
        prompt: Vec<i32>,
        max_new_tokens: usize,
        sampling: SamplingParams,
    ) -> Result<(RequestId, EventRx)> {
        let h = self.engine(engine).ok_or_else(|| anyhow::anyhow!("no engine {engine:?}"))?;
        let id = self.alloc_id();
        let mut req = Request::new(id, prompt, max_new_tokens);
        req.sampling = sampling;
        let (tx, rx) = mpsc::channel::<TokenEvent>();
        h.submit(req, tx)?;
        Ok((id, rx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn validates_inputs() {
        let r = Router::new(RoutePolicy::RoundRobin);
        assert!(r.submit(vec![], 4, SamplingParams::default()).is_err());
        assert!(r.submit(vec![1], 0, SamplingParams::default()).is_err());
        // no engines
        assert!(r.submit(vec![1], 1, SamplingParams::default()).is_err());
    }

    #[test]
    fn ids_are_unique_and_monotone() {
        let r = Router::new(RoutePolicy::RoundRobin);
        let a = r.alloc_id();
        let b = r.alloc_id();
        assert!(b > a);
    }

    // Round-robin and least-loaded dispatch are exercised with live
    // engines in rust/tests/serving_integration.rs.
}
