//! Request/response types and per-request lifecycle state.

use crate::model::sample::SamplingParams;
use std::sync::mpsc;
use std::time::Instant;

pub type RequestId = u64;

/// Priority class: within a class, FCFS; across classes, higher first.
#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
pub enum Priority {
    Batch = 0,
    Normal = 1,
    Interactive = 2,
}

impl Priority {
    /// Wire spelling used by the HTTP API and trace configs.
    pub fn parse(s: &str) -> Option<Priority> {
        Some(match s {
            "batch" => Priority::Batch,
            "normal" => Priority::Normal,
            "interactive" => Priority::Interactive,
            _ => return None,
        })
    }

    pub fn name(&self) -> &'static str {
        match self {
            Priority::Batch => "batch",
            Priority::Normal => "normal",
            Priority::Interactive => "interactive",
        }
    }
}

/// A generation request as submitted to the router.
#[derive(Debug, Clone)]
pub struct Request {
    pub id: RequestId,
    pub prompt: Vec<i32>,
    pub max_new_tokens: usize,
    pub sampling: SamplingParams,
    pub priority: Priority,
    /// Stop generation when this token is produced (e.g. b'\n'); None = run
    /// to max_new_tokens.
    pub stop_token: Option<i32>,
    pub arrival: Instant,
    /// Absolute wall-clock deadline. Once past it the request is
    /// cancelled wherever it sits (waiting, preempted, or mid-decode)
    /// and its stream finishes with [`FinishReason::DeadlineExceeded`].
    /// None = no deadline.
    pub deadline: Option<Instant>,
}

impl Request {
    pub fn new(id: RequestId, prompt: Vec<i32>, max_new_tokens: usize) -> Request {
        Request {
            id,
            prompt,
            max_new_tokens,
            sampling: SamplingParams::default(),
            priority: Priority::Normal,
            stop_token: None,
            arrival: Instant::now(),
            deadline: None,
        }
    }

    /// Total tokens this request may occupy in the cache.
    pub fn max_total_tokens(&self) -> usize {
        self.prompt.len() + self.max_new_tokens
    }

    /// True once the deadline (if any) has passed.
    pub fn deadline_expired(&self, now: Instant) -> bool {
        self.deadline.is_some_and(|d| now >= d)
    }
}

/// Why a request finished.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum FinishReason {
    /// Hit max_new_tokens.
    Length,
    /// Produced the stop token.
    Stop,
    /// Hit the model's max sequence length.
    CapacityExhausted,
    /// Rejected before any compute (admission/validation), with cause.
    Rejected(String),
    /// Engine error mid-generation.
    Error(String),
    /// The engine shard serving this stream panicked. The request is
    /// safe to re-drive: no partial state survives the shard death, and
    /// determinism guarantees a byte-identical replay.
    ShardFailed,
    /// The request's deadline passed before it finished (per-request
    /// `deadline_ms` or the `--default-deadline-ms` serve knob).
    DeadlineExceeded,
    /// The client dropped its stream receiver mid-generation; the engine
    /// cancelled the sequence and freed its blocks.
    Cancelled,
    /// The watchdog cancelled the stream after no token progress for
    /// twice `--stall-timeout-ms`.
    Stalled,
}

impl FinishReason {
    /// Wire label for metrics / HTTP payloads.
    pub fn label(&self) -> &'static str {
        match self {
            FinishReason::Length => "length",
            FinishReason::Stop => "stop",
            FinishReason::CapacityExhausted => "capacity",
            FinishReason::Rejected(_) => "rejected",
            FinishReason::Error(_) => "error",
            FinishReason::ShardFailed => "shard_failed",
            FinishReason::DeadlineExceeded => "deadline_exceeded",
            FinishReason::Cancelled => "cancelled",
            FinishReason::Stalled => "stalled",
        }
    }
}

/// Streamed events for one request.
#[derive(Debug, Clone)]
pub enum TokenEvent {
    /// First token (prefill output): carries time-to-first-token.
    First { token: i32, ttft: f64 },
    Token(i32),
    Finished { reason: FinishReason, tokens: usize, elapsed: f64 },
}

/// Sending side of a request's event stream.
pub type EventTx = mpsc::Sender<TokenEvent>;
/// Receiving side handed back to the submitter.
pub type EventRx = mpsc::Receiver<TokenEvent>;

/// Collect a full response from an event stream (blocking helper used by
/// examples/tests and the HTTP layer's non-streaming mode).
pub fn collect_response(rx: &EventRx) -> (Vec<i32>, FinishReason, f64, f64) {
    let mut tokens = Vec::new();
    let mut ttft = 0.0;
    loop {
        match rx.recv() {
            Ok(TokenEvent::First { token, ttft: t }) => {
                ttft = t;
                tokens.push(token);
            }
            Ok(TokenEvent::Token(t)) => tokens.push(t),
            Ok(TokenEvent::Finished { reason, elapsed, .. }) => {
                return (tokens, reason, ttft, elapsed)
            }
            Err(_) => {
                return (tokens, FinishReason::Error("stream dropped".into()), ttft, 0.0)
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn request_token_budget() {
        let r = Request::new(1, vec![1, 2, 3], 10);
        assert_eq!(r.max_total_tokens(), 13);
    }

    #[test]
    fn deadline_expiry_is_edge_inclusive() {
        let mut r = Request::new(1, vec![1], 4);
        let now = Instant::now();
        assert!(!r.deadline_expired(now), "no deadline never expires");
        r.deadline = Some(now);
        assert!(r.deadline_expired(now), "at the deadline counts as expired");
        r.deadline = Some(now + std::time::Duration::from_secs(3600));
        assert!(!r.deadline_expired(now));
    }

    #[test]
    fn finish_reason_labels_are_stable() {
        assert_eq!(FinishReason::Length.label(), "length");
        assert_eq!(FinishReason::ShardFailed.label(), "shard_failed");
        assert_eq!(FinishReason::DeadlineExceeded.label(), "deadline_exceeded");
        assert_eq!(FinishReason::Cancelled.label(), "cancelled");
        assert_eq!(FinishReason::Stalled.label(), "stalled");
        assert_eq!(FinishReason::Rejected("x".into()).label(), "rejected");
    }

    #[test]
    fn priority_ordering() {
        assert!(Priority::Interactive > Priority::Normal);
        assert!(Priority::Normal > Priority::Batch);
    }

    #[test]
    fn priority_names_round_trip() {
        for p in [Priority::Batch, Priority::Normal, Priority::Interactive] {
            assert_eq!(Priority::parse(p.name()), Some(p));
        }
        assert_eq!(Priority::parse("vip"), None);
    }

    #[test]
    fn collect_response_drains_stream() {
        let (tx, rx) = mpsc::channel();
        tx.send(TokenEvent::First { token: 5, ttft: 0.1 }).unwrap();
        tx.send(TokenEvent::Token(6)).unwrap();
        tx.send(TokenEvent::Finished {
            reason: FinishReason::Length,
            tokens: 2,
            elapsed: 0.5,
        })
        .unwrap();
        let (tokens, reason, ttft, elapsed) = collect_response(&rx);
        assert_eq!(tokens, vec![5, 6]);
        assert_eq!(reason, FinishReason::Length);
        assert_eq!(ttft, 0.1);
        assert_eq!(elapsed, 0.5);
    }

    #[test]
    fn collect_response_handles_dropped_stream() {
        let (tx, rx) = mpsc::channel::<TokenEvent>();
        tx.send(TokenEvent::Token(1)).unwrap();
        drop(tx);
        let (tokens, reason, _, _) = collect_response(&rx);
        assert_eq!(tokens, vec![1]);
        assert!(matches!(reason, FinishReason::Error(_)));
    }
}
