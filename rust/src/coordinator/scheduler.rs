//! Waiting-queue / running-set / preempted-set bookkeeping.
//!
//! Policy: priority classes with FCFS inside each class (stable order);
//! the batcher decides how many waiting requests to prefill per step and
//! the admission module decides whether they fit. Running sequences are
//! **preemptible**: when a decode step cannot allocate its next block,
//! the batcher names victims — lowest priority class first, then
//! most-recently-admitted within the class — which free their cache
//! blocks and move to the preempted queue. Preempted requests keep their
//! full generation state (tokens, sampling RNG, client stream) and are
//! readmitted ahead of fresh work, rebuilding their cache by re-running
//! prefill and replaying their generated tokens (recompute — bit
//! identical to an uncontended run since every step is deterministic).

use super::request::{Priority, Request, RequestId};
use std::collections::VecDeque;

/// A running sequence's generation state.
#[derive(Debug)]
pub struct Running {
    pub req: Request,
    pub seq: crate::kvcache::manager::SeqId,
    /// Last token fed/produced (input of the next decode step).
    pub last_token: i32,
    /// Tokens generated so far.
    pub generated: usize,
    /// Every generated token in order (`tokens.len() == generated`).
    /// Needed to replay the decode trail on readmission after preemption.
    pub tokens: Vec<i32>,
    /// Per-request sampling RNG.
    pub rng: crate::util::rng::Rng,
    /// Time of first token (set after prefill).
    pub first_token_at: Option<std::time::Instant>,
    /// Monotone admission stamp (victim tie-break: highest = most
    /// recently admitted; refreshed on readmission).
    pub admitted_seq: u64,
    /// Last time this stream made token progress (prefill or decode).
    /// The watchdog cancels streams stuck past the stall timeout.
    pub last_progress: std::time::Instant,
    /// Watchdog escalation state: a stalled stream is logged once before
    /// cancellation.
    pub stall_warned: bool,
    pub events: super::request::EventTx,
}

/// The scheduler state.
#[derive(Default)]
pub struct Scheduler {
    /// One FCFS queue per priority class (index = Priority as usize).
    waiting: [VecDeque<(Request, super::request::EventTx)>; 3],
    pub running: Vec<Running>,
    /// Preempted mid-flight, awaiting readmission (FCFS). The `seq` field
    /// of entries here is stale — their cache blocks are already freed.
    pub preempted: VecDeque<Running>,
    /// Source of `admitted_seq` stamps.
    next_admission: u64,
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler::default()
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.iter().map(|q| q.len()).sum()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn preempted_len(&self) -> usize {
        self.preempted.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting_len() == 0 && self.running.is_empty() && self.preempted.is_empty()
    }

    pub fn enqueue(&mut self, req: Request, events: super::request::EventTx) {
        self.waiting[req.priority as usize].push_back((req, events));
    }

    /// Next waiting request in scheduling order (highest class first,
    /// FCFS within class), without removing it.
    pub fn peek_waiting(&self) -> Option<&Request> {
        for class in [Priority::Interactive, Priority::Normal, Priority::Batch] {
            if let Some((req, _)) = self.waiting[class as usize].front() {
                return Some(req);
            }
        }
        None
    }

    /// Waiting requests in scheduling order (highest class first, FCFS
    /// within class) — read-only; cold-tier prefetch planning peeks the
    /// queue head to stage likely-next promotions.
    pub fn iter_waiting(&self) -> impl Iterator<Item = &Request> {
        [Priority::Interactive, Priority::Normal, Priority::Batch]
            .into_iter()
            .flat_map(|class| self.waiting[class as usize].iter().map(|(req, _)| req))
    }

    /// Remove and return every waiting request whose deadline has passed
    /// (relative order within each class is preserved). The engine
    /// cancels these before planning a step — an expired request must
    /// never reach prefill.
    pub fn take_expired_waiting(
        &mut self,
        now: std::time::Instant,
    ) -> Vec<(Request, super::request::EventTx)> {
        let mut expired = Vec::new();
        for q in &mut self.waiting {
            let mut keep = VecDeque::with_capacity(q.len());
            while let Some((req, events)) = q.pop_front() {
                if req.deadline_expired(now) {
                    expired.push((req, events));
                } else {
                    keep.push_back((req, events));
                }
            }
            *q = keep;
        }
        expired
    }

    /// Pop the request returned by `peek_waiting`.
    pub fn pop_waiting(&mut self) -> Option<(Request, super::request::EventTx)> {
        for class in [Priority::Interactive, Priority::Normal, Priority::Batch] {
            if let Some(item) = self.waiting[class as usize].pop_front() {
                return Some(item);
            }
        }
        None
    }

    /// Fresh admission stamp for a sequence entering the running set.
    pub fn next_admission_stamp(&mut self) -> u64 {
        self.next_admission += 1;
        self.next_admission
    }

    /// Move a request into the running set.
    pub fn start(&mut self, running: Running) {
        self.running.push(running);
    }

    /// Remove a finished sequence; returns it for cleanup.
    pub fn finish(&mut self, id: RequestId) -> Option<Running> {
        let idx = self.running.iter().position(|r| r.req.id == id)?;
        Some(self.running.swap_remove(idx))
    }

    /// Park a (already cache-freed) running state for readmission.
    pub fn park_preempted(&mut self, run: Running) {
        self.preempted.push_back(run);
    }

    /// Preemption victim among the running set, excluding `exclude`:
    /// lowest priority class first, most-recently-admitted within it.
    /// Rationale: recent admits have the least sunk decode work to
    /// recompute, and older requests (closest to finishing and releasing
    /// everything) keep their blocks.
    pub fn select_victim(&self, exclude: &[RequestId]) -> Option<RequestId> {
        self.running
            .iter()
            .filter(|r| !exclude.contains(&r.req.id))
            .min_by_key(|r| (r.req.priority, std::cmp::Reverse(r.admitted_seq)))
            .map(|r| r.req.id)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: RequestId, prio: Priority) -> (Request, super::super::request::EventTx) {
        let mut r = Request::new(id, vec![1], 4);
        r.priority = prio;
        let (tx, _rx) = mpsc::channel();
        // Leak the receiver for test simplicity: sender stays usable.
        std::mem::forget(_rx);
        (r, tx)
    }

    fn running(s: &mut Scheduler, id: RequestId, prio: Priority) -> Running {
        let (r, tx) = req(id, prio);
        Running {
            req: r,
            seq: id,
            last_token: 0,
            generated: 0,
            tokens: Vec::new(),
            rng: crate::util::rng::Rng::new(id),
            first_token_at: None,
            admitted_seq: s.next_admission_stamp(),
            last_progress: std::time::Instant::now(),
            stall_warned: false,
            events: tx,
        }
    }

    #[test]
    fn fcfs_within_class() {
        let mut s = Scheduler::new();
        for id in 1..=3 {
            let (r, tx) = req(id, Priority::Normal);
            s.enqueue(r, tx);
        }
        assert_eq!(s.pop_waiting().unwrap().0.id, 1);
        assert_eq!(s.pop_waiting().unwrap().0.id, 2);
        assert_eq!(s.pop_waiting().unwrap().0.id, 3);
    }

    #[test]
    fn higher_priority_jumps_queue() {
        let mut s = Scheduler::new();
        let (r1, t1) = req(1, Priority::Batch);
        let (r2, t2) = req(2, Priority::Interactive);
        let (r3, t3) = req(3, Priority::Normal);
        s.enqueue(r1, t1);
        s.enqueue(r2, t2);
        s.enqueue(r3, t3);
        assert_eq!(s.peek_waiting().unwrap().id, 2);
        assert_eq!(s.pop_waiting().unwrap().0.id, 2);
        assert_eq!(s.pop_waiting().unwrap().0.id, 3);
        assert_eq!(s.pop_waiting().unwrap().0.id, 1);
    }

    #[test]
    fn counts_track_state() {
        let mut s = Scheduler::new();
        assert!(s.is_idle());
        let (r, tx) = req(1, Priority::Normal);
        s.enqueue(r, tx);
        assert_eq!(s.waiting_len(), 1);
        assert!(!s.is_idle());
    }

    #[test]
    fn finish_removes_from_running() {
        let mut s = Scheduler::new();
        let run = running(&mut s, 9, Priority::Normal);
        s.start(run);
        assert_eq!(s.running_len(), 1);
        assert!(s.finish(9).is_some());
        assert_eq!(s.running_len(), 0);
        assert!(s.finish(9).is_none());
    }

    #[test]
    fn victim_is_lowest_priority_then_most_recent() {
        let mut s = Scheduler::new();
        for (id, prio) in [
            (1, Priority::Interactive),
            (2, Priority::Batch),
            (3, Priority::Normal),
            (4, Priority::Batch), // same class as 2, admitted later
        ] {
            let run = running(&mut s, id, prio);
            s.start(run);
        }
        assert_eq!(s.select_victim(&[]), Some(4), "batch class, most recent");
        assert_eq!(s.select_victim(&[4]), Some(2), "then the older batch");
        assert_eq!(s.select_victim(&[4, 2]), Some(3), "then normal");
        assert_eq!(s.select_victim(&[4, 2, 3]), Some(1));
        assert_eq!(s.select_victim(&[4, 2, 3, 1]), None);
    }

    #[test]
    fn expired_waiting_requests_are_drained() {
        let mut s = Scheduler::new();
        let (mut r1, t1) = req(1, Priority::Normal);
        let (r2, t2) = req(2, Priority::Normal);
        let (mut r3, t3) = req(3, Priority::Interactive);
        let now = std::time::Instant::now();
        r1.deadline = Some(now);
        r3.deadline = Some(now);
        s.enqueue(r1, t1);
        s.enqueue(r2, t2);
        s.enqueue(r3, t3);
        let expired: Vec<_> =
            s.take_expired_waiting(now).into_iter().map(|(r, _)| r.id).collect();
        assert_eq!(expired.len(), 2);
        assert!(expired.contains(&1) && expired.contains(&3));
        assert_eq!(s.waiting_len(), 1);
        assert_eq!(s.pop_waiting().unwrap().0.id, 2);
        // Idempotent: nothing left to expire.
        assert!(s.take_expired_waiting(std::time::Instant::now()).is_empty());
    }

    #[test]
    fn preempted_parks_and_counts() {
        let mut s = Scheduler::new();
        let run = running(&mut s, 5, Priority::Normal);
        s.start(run);
        assert!(!s.is_idle());
        let run = s.finish(5).unwrap();
        s.park_preempted(run);
        assert_eq!(s.running_len(), 0);
        assert_eq!(s.preempted_len(), 1);
        assert!(!s.is_idle(), "preempted work keeps the engine awake");
        let back = s.preempted.pop_front().unwrap();
        assert_eq!(back.req.id, 5);
        assert!(s.is_idle());
    }
}
