//! Waiting-queue + running-set bookkeeping.
//!
//! Policy: priority classes with FCFS inside each class (stable order);
//! the batcher decides how many waiting requests to prefill per step and
//! the admission module decides whether they fit. No preemption: once
//! running, a sequence keeps its cache blocks until it finishes (admission
//! is conservative to make this deadlock-free).

use super::request::{Priority, Request, RequestId};
use std::collections::VecDeque;

/// A running sequence's generation state.
#[derive(Debug)]
pub struct Running {
    pub req: Request,
    pub seq: crate::kvcache::manager::SeqId,
    /// Last token fed/produced (input of the next decode step).
    pub last_token: i32,
    /// Tokens generated so far.
    pub generated: usize,
    /// Per-request sampling RNG.
    pub rng: crate::util::rng::Rng,
    /// Time of first token (set after prefill).
    pub first_token_at: Option<std::time::Instant>,
    pub events: super::request::EventTx,
}

/// The scheduler state.
#[derive(Default)]
pub struct Scheduler {
    /// One FCFS queue per priority class (index = Priority as usize).
    waiting: [VecDeque<(Request, super::request::EventTx)>; 3],
    pub running: Vec<Running>,
}

impl Scheduler {
    pub fn new() -> Scheduler {
        Scheduler::default()
    }

    pub fn waiting_len(&self) -> usize {
        self.waiting.iter().map(|q| q.len()).sum()
    }

    pub fn running_len(&self) -> usize {
        self.running.len()
    }

    pub fn is_idle(&self) -> bool {
        self.waiting_len() == 0 && self.running.is_empty()
    }

    pub fn enqueue(&mut self, req: Request, events: super::request::EventTx) {
        self.waiting[req.priority as usize].push_back((req, events));
    }

    /// Next waiting request in scheduling order (highest class first,
    /// FCFS within class), without removing it.
    pub fn peek_waiting(&self) -> Option<&Request> {
        for class in [Priority::Interactive, Priority::Normal, Priority::Batch] {
            if let Some((req, _)) = self.waiting[class as usize].front() {
                return Some(req);
            }
        }
        None
    }

    /// Pop the request returned by `peek_waiting`.
    pub fn pop_waiting(&mut self) -> Option<(Request, super::request::EventTx)> {
        for class in [Priority::Interactive, Priority::Normal, Priority::Batch] {
            if let Some(item) = self.waiting[class as usize].pop_front() {
                return Some(item);
            }
        }
        None
    }

    /// Move a request into the running set.
    pub fn start(&mut self, running: Running) {
        self.running.push(running);
    }

    /// Remove a finished sequence; returns it for cleanup.
    pub fn finish(&mut self, id: RequestId) -> Option<Running> {
        let idx = self.running.iter().position(|r| r.req.id == id)?;
        Some(self.running.swap_remove(idx))
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::mpsc;

    fn req(id: RequestId, prio: Priority) -> (Request, super::super::request::EventTx) {
        let mut r = Request::new(id, vec![1], 4);
        r.priority = prio;
        let (tx, _rx) = mpsc::channel();
        // Leak the receiver for test simplicity: sender stays usable.
        std::mem::forget(_rx);
        (r, tx)
    }

    #[test]
    fn fcfs_within_class() {
        let mut s = Scheduler::new();
        for id in 1..=3 {
            let (r, tx) = req(id, Priority::Normal);
            s.enqueue(r, tx);
        }
        assert_eq!(s.pop_waiting().unwrap().0.id, 1);
        assert_eq!(s.pop_waiting().unwrap().0.id, 2);
        assert_eq!(s.pop_waiting().unwrap().0.id, 3);
    }

    #[test]
    fn higher_priority_jumps_queue() {
        let mut s = Scheduler::new();
        let (r1, t1) = req(1, Priority::Batch);
        let (r2, t2) = req(2, Priority::Interactive);
        let (r3, t3) = req(3, Priority::Normal);
        s.enqueue(r1, t1);
        s.enqueue(r2, t2);
        s.enqueue(r3, t3);
        assert_eq!(s.peek_waiting().unwrap().id, 2);
        assert_eq!(s.pop_waiting().unwrap().0.id, 2);
        assert_eq!(s.pop_waiting().unwrap().0.id, 3);
        assert_eq!(s.pop_waiting().unwrap().0.id, 1);
    }

    #[test]
    fn counts_track_state() {
        let mut s = Scheduler::new();
        assert!(s.is_idle());
        let (r, tx) = req(1, Priority::Normal);
        s.enqueue(r, tx);
        assert_eq!(s.waiting_len(), 1);
        assert!(!s.is_idle());
    }

    #[test]
    fn finish_removes_from_running() {
        let mut s = Scheduler::new();
        let (r, tx) = req(9, Priority::Normal);
        s.start(Running {
            req: r,
            seq: 1,
            last_token: 0,
            generated: 0,
            rng: crate::util::rng::Rng::new(0),
            first_token_at: None,
            events: tx,
        });
        assert_eq!(s.running_len(), 1);
        assert!(s.finish(9).is_some());
        assert_eq!(s.running_len(), 0);
        assert!(s.finish(9).is_none());
    }
}
