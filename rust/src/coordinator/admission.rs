//! Admission control: decide whether a request may enter the running set.
//!
//! Two policies ([`AdmissionMode`], the `admission_mode` serve knob):
//!
//! * **Optimistic** (default): admit when the *prompt* fits plus the
//!   watermark headroom. Decode growth is not reserved — the scheduler
//!   preempts victims (recompute-on-readmission) when the pool later runs
//!   dry, so the pool runs near-full instead of half-empty on worst-case
//!   reservations. The watermark doubles as the preemption trigger
//!   margin: keeping a slice of the pool free absorbs one step of decode
//!   growth before victims must be named.
//! * **WorstCase**: the conservative legacy policy — admit only when the
//!   full worst-case footprint (prompt + max_new_tokens) fits *and* every
//!   already-running request's unrealized worst-case growth is reserved.
//!   Never needs preemption; wastes capacity under realistic traffic.
//!
//! Shared gates: the running set is bounded by `max_running`, prompts
//! must fit the model, and the waiting queue is bounded (`max_waiting`)
//! after which requests are rejected outright — the "reject fast under
//! overload" discipline.

use super::request::Request;
use crate::kvcache::KvCacheManager;

/// How much of a request's footprint admission demands up front.
#[derive(Debug, Clone, Copy, PartialEq, Eq, Default)]
pub enum AdmissionMode {
    /// Prompt-only check; preemption handles overcommit.
    #[default]
    Optimistic,
    /// Full prompt + max_new_tokens reservation; no preemption needed.
    WorstCase,
}

impl AdmissionMode {
    pub fn parse(s: &str) -> Option<AdmissionMode> {
        Some(match s {
            "optimistic" => AdmissionMode::Optimistic,
            "worst_case" | "worst-case" | "worstcase" => AdmissionMode::WorstCase,
            _ => return None,
        })
    }

    pub fn name(self) -> &'static str {
        match self {
            AdmissionMode::Optimistic => "optimistic",
            AdmissionMode::WorstCase => "worst_case",
        }
    }
}

#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Max concurrently running sequences.
    pub max_running: usize,
    /// Max queued (not yet admitted) requests before hard rejection.
    pub max_waiting: usize,
    /// Keep this fraction of cache capacity free as headroom
    /// (watermark); admission pretends the pool is smaller by this
    /// factor. Applied in bytes ([`KvCacheManager::headroom_bytes`]).
    /// Under optimistic admission this is the preemption trigger margin.
    pub watermark: f64,
    /// Optimistic (prompt-fits) vs worst-case (full-footprint) policy.
    pub mode: AdmissionMode,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig {
            max_running: 8,
            max_waiting: 256,
            watermark: 0.05,
            mode: AdmissionMode::default(),
        }
    }
}

/// Admission verdicts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Verdict {
    Admit,
    /// Keep waiting (would fit eventually).
    Defer,
    /// Will never fit / queue overflow: reject with cause.
    Reject(String),
}

/// Check one waiting request. All accounting is in **physical bytes** at
/// sub-pool widths ([`KvCacheManager::bytes_for_tokens`]) — under a
/// mixed policy a narrow INT4 stream no longer charges the widest
/// stream's padding, and the binding constraint is whichever width class
/// drains first. For uniform policies every quantity is a whole multiple
/// of the legacy block size, so the decisions reduce to the block-count
/// arithmetic exactly. `reserved` is bytes already spoken for by this
/// step's earlier plan decisions (resumes and prefills planned ahead of
/// this request, plus — in worst-case mode — the unrealized growth of
/// the running set); admission sees `free_bytes - reserved`.
pub fn check(
    cfg: &AdmissionConfig,
    req: &Request,
    cache: &KvCacheManager,
    running: usize,
    waiting: usize,
    reserved: u64,
) -> Verdict {
    let total = req.max_total_tokens();
    let cache_cfg = cache.config();
    if req.prompt.is_empty() {
        return Verdict::Reject("empty prompt".into());
    }
    if total > cache_cfg.max_seq {
        return Verdict::Reject(format!(
            "prompt+max_new = {total} exceeds model max_seq {}",
            cache_cfg.max_seq
        ));
    }
    let pool = cache.pool_capacity_bytes();
    let headroom = cache.headroom_bytes(cfg.watermark);
    let usable = pool - headroom;
    // "Can it ever fit" gate: reject now rather than deadlock the queue.
    // Worst-case mode demands the full footprint inside the watermarked
    // pool; optimistic mode only needs the whole pool to cover the
    // worst case when the request eventually runs alone (preemption can
    // clear everything else, but not grow the pool).
    let need_total = cache.bytes_for_tokens(total);
    match cfg.mode {
        AdmissionMode::WorstCase => {
            if need_total > usable {
                return Verdict::Reject(format!(
                    "needs {need_total} bytes, pool has {usable} usable"
                ));
            }
        }
        AdmissionMode::Optimistic => {
            if need_total > pool {
                return Verdict::Reject(format!(
                    "worst case {need_total} bytes exceeds whole pool {pool}"
                ));
            }
            let need_prompt = cache.bytes_for_tokens(req.prompt.len());
            if need_prompt > usable {
                return Verdict::Reject(format!(
                    "prompt alone needs {need_prompt} bytes, pool has {usable} usable"
                ));
            }
        }
    }
    if waiting >= cfg.max_waiting {
        return Verdict::Reject(format!("queue full ({waiting})"));
    }
    if running >= cfg.max_running {
        return Verdict::Defer;
    }
    // Current free-space check (+ watermark headroom).
    let need = match cfg.mode {
        AdmissionMode::WorstCase => need_total,
        AdmissionMode::Optimistic => cache.bytes_for_tokens(req.prompt.len()),
    };
    if need + headroom > cache.free_bytes().saturating_sub(reserved) {
        return Verdict::Defer;
    }
    Verdict::Admit
}

/// Readmission check for a preempted request: `rebuild_tokens` rows of
/// cache must be rematerialized (prompt + already-generated tokens). No
/// watermark here — preempted requests hold live client streams and beat
/// fresh work back into the pool; the absolute-fit gate already ran at
/// first admission. `reclaimable` is byte credit the caller can free on
/// demand (prefix-cache evictions or cold-tier demotions): cached
/// prefixes never starve a preempted request's readmission.
pub fn check_resume(
    cfg: &AdmissionConfig,
    rebuild_tokens: usize,
    cache: &KvCacheManager,
    running: usize,
    reserved: u64,
    reclaimable: u64,
) -> Verdict {
    if running >= cfg.max_running {
        return Verdict::Defer;
    }
    let need = cache.bytes_for_tokens(rebuild_tokens);
    if need > (cache.free_bytes() + reclaimable).saturating_sub(reserved) {
        return Verdict::Defer;
    }
    Verdict::Admit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::CacheConfig;
    use crate::kvcache::{Precision, QuantPolicy};

    fn cache(num_blocks: usize) -> KvCacheManager {
        KvCacheManager::new(
            CacheConfig {
                layers: 2,
                heads: 2,
                head_dim: 8,
                max_seq: 64,
                block_size: 4,
                num_blocks,
                scale_margin: 1.0,
            },
            QuantPolicy::uniform(Precision::Int8, 2, 2),
        )
    }

    fn req(prompt: usize, max_new: usize) -> Request {
        Request::new(1, vec![0; prompt], max_new)
    }

    fn worst_case() -> AdmissionConfig {
        AdmissionConfig { mode: AdmissionMode::WorstCase, ..Default::default() }
    }

    #[test]
    fn admits_when_roomy() {
        let c = cache(1024);
        for cfg in [AdmissionConfig::default(), worst_case()] {
            assert_eq!(check(&cfg, &req(8, 8), &c, 0, 0, 0), Verdict::Admit);
        }
    }

    #[test]
    fn rejects_empty_prompt() {
        let c = cache(1024);
        assert!(matches!(
            check(&AdmissionConfig::default(), &req(0, 8), &c, 0, 0, 0),
            Verdict::Reject(_)
        ));
    }

    #[test]
    fn rejects_over_max_seq() {
        let c = cache(1024);
        assert!(matches!(
            check(&AdmissionConfig::default(), &req(60, 10), &c, 0, 0, 0),
            Verdict::Reject(_)
        ));
    }

    #[test]
    fn rejects_never_fitting() {
        let c = cache(8); // tiny pool
        // 33 tokens -> ceil(33/4)=9 blocks x 2 layers x2 = 36 > 8, in
        // either mode (even alone the worst case exceeds the whole pool).
        for cfg in [AdmissionConfig::default(), worst_case()] {
            assert!(matches!(check(&cfg, &req(30, 3), &c, 0, 0, 0), Verdict::Reject(_)));
        }
    }

    #[test]
    fn optimistic_admits_what_worst_case_defers() {
        // Pool 32; request worst case = 16 tokens -> 4 blocks x4 = 16;
        // two running requests' growth reservations exhaust worst-case
        // capacity but the 1-block prompt sails through optimistically.
        let c = cache(32);
        let opt = AdmissionConfig::default();
        let wc = worst_case();
        assert_eq!(check(&opt, &req(4, 12), &c, 2, 0, 0), Verdict::Admit);
        // Worst-case with 28 blocks (7 spans) of running growth
        // reserved: defer.
        let reserved = 7 * c.span_bytes() as u64;
        assert_eq!(check(&wc, &req(4, 12), &c, 2, 0, reserved), Verdict::Defer);
    }

    #[test]
    fn defers_at_max_running() {
        let c = cache(1024);
        let cfg = AdmissionConfig { max_running: 2, ..Default::default() };
        assert_eq!(check(&cfg, &req(4, 4), &c, 2, 0, 0), Verdict::Defer);
    }

    #[test]
    fn defers_when_pool_temporarily_full() {
        let mut c = cache(16);
        // Occupy most of the pool with a live sequence.
        let id = c.new_sequence();
        let cfgc = *c.config();
        let n = cfgc.layers * cfgc.heads * cfgc.max_seq * cfgc.head_dim;
        let k = vec![0.1f32; n];
        let v = vec![0.1f32; n];
        c.set_prefill(id, &k, &v, 12).unwrap(); // 3 blocks x 4 streams = 12
        for cfg in [AdmissionConfig::default(), worst_case()] {
            assert_eq!(check(&cfg, &req(8, 8), &c, 1, 0, 0), Verdict::Defer);
        }
        c.free(id);
        assert_eq!(check(&AdmissionConfig::default(), &req(8, 8), &c, 0, 0, 0), Verdict::Admit);
    }

    #[test]
    fn reserved_bytes_shrink_effective_free() {
        let c = cache(32);
        let cfg = AdmissionConfig::default();
        // Prompt 8 -> 2 spans (+1 block of headroom); pool is 8 spans.
        assert_eq!(check(&cfg, &req(8, 8), &c, 0, 0, 0), Verdict::Admit);
        let reserved = 6 * c.span_bytes() as u64; // 24 blocks
        assert_eq!(check(&cfg, &req(8, 8), &c, 0, 0, reserved), Verdict::Defer);
    }

    #[test]
    fn queue_overflow_rejects() {
        let c = cache(1024);
        let cfg = AdmissionConfig { max_waiting: 4, ..Default::default() };
        assert!(matches!(check(&cfg, &req(4, 4), &c, 0, 4, 0), Verdict::Reject(_)));
    }

    #[test]
    fn resume_skips_watermark_but_respects_free() {
        let c = cache(16);
        let cfg = AdmissionConfig::default();
        // Rebuild 16 tokens -> 4 spans == whole pool: admissible only
        // because resume ignores the watermark.
        let span = c.span_bytes() as u64;
        assert_eq!(check_resume(&cfg, 16, &c, 0, 0, 0), Verdict::Admit);
        assert_eq!(check_resume(&cfg, 16, &c, 0, span, 0), Verdict::Defer);
        // Prefix-cache reclaim credit closes the same gap.
        assert_eq!(check_resume(&cfg, 16, &c, 0, span, span), Verdict::Admit);
        let capped = AdmissionConfig { max_running: 1, ..Default::default() };
        assert_eq!(check_resume(&capped, 4, &c, 1, 0, 0), Verdict::Defer);
    }

    #[test]
    fn mixed_policy_budgets_use_subpool_widths() {
        use crate::kvcache::PolicySpec;
        let c = KvCacheManager::new(
            CacheConfig {
                layers: 2,
                heads: 2,
                head_dim: 8,
                max_seq: 64,
                block_size: 4,
                num_blocks: 32,
                scale_margin: 1.0,
            },
            PolicySpec::K8V4.resolve(2, 2, 8).unwrap(),
        );
        // k8v4 spans are 2·(64 + 32) = 192 B against the 256 B padded
        // width: same span count, 25% less physical footprint, and every
        // admission quantity is priced at the real sub-pool widths.
        assert_eq!(c.span_bytes(), 192);
        assert_eq!(c.pool_capacity_bytes(), 8 * 192);
        assert!(c.pool_physical_bytes() < c.padded_pool_bytes());
        let cfg = AdmissionConfig::default();
        // Prompt 8 -> 2 spans = 384 B of an 8-span pool; reserving 6
        // spans' worth of k8v4 bytes defers, exactly as span arithmetic
        // predicts.
        assert_eq!(check(&cfg, &req(8, 8), &c, 0, 0, 0), Verdict::Admit);
        assert_eq!(check(&cfg, &req(8, 8), &c, 0, 0, 6 * 192), Verdict::Defer);
    }

    #[test]
    fn mode_parse_roundtrip() {
        assert_eq!(AdmissionMode::parse("optimistic"), Some(AdmissionMode::Optimistic));
        assert_eq!(AdmissionMode::parse("worst_case"), Some(AdmissionMode::WorstCase));
        assert_eq!(AdmissionMode::parse("worst-case"), Some(AdmissionMode::WorstCase));
        assert_eq!(AdmissionMode::parse("nope"), None);
        assert_eq!(AdmissionMode::Optimistic.name(), "optimistic");
        assert_eq!(AdmissionMode::WorstCase.name(), "worst_case");
    }
}
