//! Admission control: decide whether a request may enter the running set.
//!
//! Policy: a request is admitted only if (a) the cache can hold its entire
//! worst-case footprint (prompt + max_new_tokens — no mid-flight
//! preemption in this engine, so admission must be conservative), (b) the
//! running set is below `max_running`, and (c) its prompt fits the model.
//! Backpressure: the scheduler keeps non-admissible requests queued; the
//! queue itself is bounded (`max_waiting`) after which requests are
//! rejected outright — the "reject fast under overload" discipline.

use super::request::Request;
use crate::kvcache::KvCacheManager;

#[derive(Debug, Clone, Copy)]
pub struct AdmissionConfig {
    /// Max concurrently running sequences.
    pub max_running: usize,
    /// Max queued (not yet admitted) requests before hard rejection.
    pub max_waiting: usize,
    /// Keep this fraction of cache blocks free as headroom (watermark);
    /// admission pretends the pool is smaller by this factor.
    pub watermark: f64,
}

impl Default for AdmissionConfig {
    fn default() -> Self {
        AdmissionConfig { max_running: 8, max_waiting: 256, watermark: 0.05 }
    }
}

/// Admission verdicts.
#[derive(Debug, Clone, PartialEq, Eq)]
pub enum Verdict {
    Admit,
    /// Keep waiting (would fit eventually).
    Defer,
    /// Will never fit / queue overflow: reject with cause.
    Reject(String),
}

pub fn check(
    cfg: &AdmissionConfig,
    req: &Request,
    cache: &KvCacheManager,
    running: usize,
    waiting: usize,
) -> Verdict {
    let total = req.max_total_tokens();
    let cache_cfg = cache.config();
    if req.prompt.is_empty() {
        return Verdict::Reject("empty prompt".into());
    }
    if total > cache_cfg.max_seq {
        return Verdict::Reject(format!(
            "prompt+max_new = {total} exceeds model max_seq {}",
            cache_cfg.max_seq
        ));
    }
    // Worst-case block need vs the whole pool (minus watermark): if it can
    // never fit, reject now rather than deadlock the queue.
    let need = cache_cfg.blocks_for_tokens(total);
    let pool = cache_cfg.num_blocks;
    let usable = pool - ((pool as f64 * cfg.watermark) as usize);
    if need > usable {
        return Verdict::Reject(format!("needs {need} blocks, pool has {usable} usable"));
    }
    if waiting >= cfg.max_waiting {
        return Verdict::Reject(format!("queue full ({waiting})"));
    }
    if running >= cfg.max_running {
        return Verdict::Defer;
    }
    // Current free-space check (+ watermark headroom).
    let headroom = (pool as f64 * cfg.watermark) as usize;
    if need + headroom > cache.free_blocks() {
        return Verdict::Defer;
    }
    Verdict::Admit
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::CacheConfig;
    use crate::kvcache::Precision;

    fn cache(num_blocks: usize) -> KvCacheManager {
        KvCacheManager::new(CacheConfig {
            layers: 2,
            heads: 2,
            head_dim: 8,
            max_seq: 64,
            block_size: 4,
            num_blocks,
            precision: Precision::Int8,
            scale_margin: 1.0,
        })
    }

    fn req(prompt: usize, max_new: usize) -> Request {
        Request::new(1, vec![0; prompt], max_new)
    }

    #[test]
    fn admits_when_roomy() {
        let c = cache(1024);
        let v = check(&AdmissionConfig::default(), &req(8, 8), &c, 0, 0);
        assert_eq!(v, Verdict::Admit);
    }

    #[test]
    fn rejects_empty_prompt() {
        let c = cache(1024);
        assert!(matches!(
            check(&AdmissionConfig::default(), &req(0, 8), &c, 0, 0),
            Verdict::Reject(_)
        ));
    }

    #[test]
    fn rejects_over_max_seq() {
        let c = cache(1024);
        assert!(matches!(
            check(&AdmissionConfig::default(), &req(60, 10), &c, 0, 0),
            Verdict::Reject(_)
        ));
    }

    #[test]
    fn rejects_never_fitting() {
        let c = cache(8); // tiny pool
        // 33 tokens -> ceil(33/4)=9 blocks x 2 layers x2 = 36 > 8.
        assert!(matches!(
            check(&AdmissionConfig::default(), &req(30, 3), &c, 0, 0),
            Verdict::Reject(_)
        ));
    }

    #[test]
    fn defers_at_max_running() {
        let c = cache(1024);
        let cfg = AdmissionConfig { max_running: 2, ..Default::default() };
        assert_eq!(check(&cfg, &req(4, 4), &c, 2, 0), Verdict::Defer);
    }

    #[test]
    fn defers_when_pool_temporarily_full() {
        let mut c = cache(16);
        // Occupy most of the pool with a live sequence.
        let id = c.new_sequence();
        let cfgc = *c.config();
        let n = cfgc.layers * cfgc.heads * cfgc.max_seq * cfgc.head_dim;
        let k = vec![0.1f32; n];
        let v = vec![0.1f32; n];
        c.set_prefill(id, &k, &v, 12).unwrap(); // 3 blocks x 4 streams = 12
        let verdict = check(&AdmissionConfig::default(), &req(8, 8), &c, 1, 0);
        assert_eq!(verdict, Verdict::Defer);
        c.free(id);
        assert_eq!(check(&AdmissionConfig::default(), &req(8, 8), &c, 0, 0), Verdict::Admit);
    }

    #[test]
    fn queue_overflow_rejects() {
        let c = cache(1024);
        let cfg = AdmissionConfig { max_waiting: 4, ..Default::default() };
        assert!(matches!(check(&cfg, &req(4, 4), &c, 0, 4), Verdict::Reject(_)));
    }
}
