//! Continuous batcher: decides what one engine iteration executes.
//!
//! vLLM/Orca-style iteration-level scheduling: every step may mix newly
//! admitted prefills with decode steps for all running sequences. Limits:
//!
//! * `max_prefills_per_step` — prefill is long (O(S²) attention), so cap
//!   how many are folded into one iteration to protect decode latency
//!   (TPOT) of already-running requests.
//! * `max_decode_batch` — cap the decode set per iteration; the rest run
//!   next iteration (round-robin fairness via rotation).

use super::admission::{self, AdmissionConfig, Verdict};
use super::request::Request;
use super::scheduler::Scheduler;
use crate::kvcache::KvCacheManager;

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_prefills_per_step: usize,
    pub max_decode_batch: usize,
    pub admission: AdmissionConfig,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_prefills_per_step: 1,
            max_decode_batch: 16,
            admission: AdmissionConfig::default(),
        }
    }
}

/// What one engine iteration should do.
#[derive(Debug, Default)]
pub struct StepPlan {
    /// Requests to prefill this step (already admission-checked).
    pub prefills: Vec<(Request, super::request::EventTx)>,
    /// Indices into `scheduler.running` to decode this step.
    pub decodes: Vec<usize>,
    /// Requests rejected by admission (with cause) — emit and drop.
    pub rejections: Vec<(Request, super::request::EventTx, String)>,
}

/// Round-robin cursor for decode fairness across iterations.
#[derive(Debug, Default)]
pub struct Batcher {
    decode_cursor: usize,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher::default()
    }

    pub fn plan(
        &mut self,
        cfg: &BatcherConfig,
        sched: &mut Scheduler,
        cache: &KvCacheManager,
    ) -> StepPlan {
        let mut plan = StepPlan::default();

        // Admit up to max_prefills_per_step waiting requests.
        while plan.prefills.len() < cfg.max_prefills_per_step {
            let Some(head) = sched.peek_waiting() else { break };
            let verdict = admission::check(
                &cfg.admission,
                head,
                cache,
                sched.running_len() + plan.prefills.len(),
                sched.waiting_len().saturating_sub(1),
            );
            match verdict {
                Verdict::Admit => {
                    let (req, tx) = sched.pop_waiting().unwrap();
                    plan.prefills.push((req, tx));
                }
                Verdict::Defer => break, // FCFS head-of-line blocks its class
                Verdict::Reject(cause) => {
                    let (req, tx) = sched.pop_waiting().unwrap();
                    plan.rejections.push((req, tx, cause));
                }
            }
        }

        // Decode set: all running, rotated, capped.
        let n = sched.running_len();
        if n > 0 {
            let take = n.min(cfg.max_decode_batch);
            self.decode_cursor %= n;
            for i in 0..take {
                plan.decodes.push((self.decode_cursor + i) % n);
            }
            self.decode_cursor = (self.decode_cursor + take) % n;
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::CacheConfig;
    use crate::kvcache::Precision;
    use std::sync::mpsc;

    fn cache() -> KvCacheManager {
        KvCacheManager::new(CacheConfig {
            layers: 2,
            heads: 2,
            head_dim: 8,
            max_seq: 64,
            block_size: 4,
            num_blocks: 64,
            precision: Precision::Int8,
            scale_margin: 1.0,
        })
    }

    fn enqueue(s: &mut Scheduler, id: u64, prompt: usize, max_new: usize) {
        let (tx, rx) = mpsc::channel();
        std::mem::forget(rx);
        s.enqueue(Request::new(id, vec![0; prompt], max_new), tx);
    }

    #[test]
    fn admits_up_to_prefill_cap() {
        let mut s = Scheduler::new();
        for id in 1..=3 {
            enqueue(&mut s, id, 4, 4);
        }
        let c = cache();
        let mut b = Batcher::new();
        let cfg = BatcherConfig { max_prefills_per_step: 2, ..Default::default() };
        let plan = b.plan(&cfg, &mut s, &c);
        assert_eq!(plan.prefills.len(), 2);
        assert_eq!(s.waiting_len(), 1);
        assert!(plan.rejections.is_empty());
    }

    #[test]
    fn rejections_are_surfaced_not_silently_dropped() {
        let mut s = Scheduler::new();
        enqueue(&mut s, 1, 100, 10); // > max_seq -> reject
        enqueue(&mut s, 2, 4, 4); // fine
        let c = cache();
        let mut b = Batcher::new();
        let plan = b.plan(&BatcherConfig::default(), &mut s, &c);
        assert_eq!(plan.rejections.len(), 1);
        assert_eq!(plan.rejections[0].0.id, 1);
        assert_eq!(plan.prefills.len(), 1);
        assert_eq!(plan.prefills[0].0.id, 2);
    }

    #[test]
    fn decode_round_robin_rotates() {
        let mut s = Scheduler::new();
        let c = cache();
        // Fake 3 running entries.
        for id in 1..=3 {
            let (tx, rx) = mpsc::channel();
            std::mem::forget(rx);
            s.start(super::super::scheduler::Running {
                req: Request::new(id, vec![0; 2], 8),
                seq: id,
                last_token: 0,
                generated: 0,
                rng: crate::util::rng::Rng::new(id),
                first_token_at: None,
                events: tx,
            });
        }
        let mut b = Batcher::new();
        let cfg = BatcherConfig { max_decode_batch: 2, ..Default::default() };
        let p1 = b.plan(&cfg, &mut s, &c);
        let p2 = b.plan(&cfg, &mut s, &c);
        assert_eq!(p1.decodes, vec![0, 1]);
        assert_eq!(p2.decodes, vec![2, 0], "cursor rotated");
    }

    #[test]
    fn defer_blocks_head_of_line_only_within_step() {
        // Fill the cache so admission defers; plan must not spin forever.
        let mut s = Scheduler::new();
        enqueue(&mut s, 1, 60, 4); // needs 15 blocks x4 =60 > pool(64)-wm… defer/reject path
        let c = cache();
        let mut b = Batcher::new();
        let plan = b.plan(&BatcherConfig::default(), &mut s, &c);
        // 64 tokens = 16 blocks x 4 streams = 64 blocks > usable (60) -> reject.
        assert_eq!(plan.prefills.len() + plan.rejections.len(), 1);
    }
}
