//! Continuous batcher: decides what one engine iteration executes.
//!
//! vLLM/Orca-style iteration-level scheduling: every step may mix
//! readmitted (previously preempted) requests, newly admitted prefills,
//! decode steps for the running set, and — under pool pressure —
//! preemptions. Limits:
//!
//! * `max_prefills_per_step` — prefill is long (O(S²) attention), so cap
//!   how many resumes+prefills are folded into one iteration to protect
//!   decode latency (TPOT) of already-running requests.
//! * `max_decode_batch` — cap the decode set per iteration; the rest run
//!   next iteration (round-robin fairness via rotation).
//!
//! **Memory planning.** The plan tracks the **physical bytes** each
//! decision commits (resume rebuilds, prefill prompts, decode appends
//! including COW copies) against the pool's span-allocatable free bytes
//! ([`KvCacheManager::free_bytes`]). Byte budgets price every stream at
//! its sub-pool width — under a mixed policy an INT4 append charges half
//! an INT8 one, and the binding constraint is whichever width class
//! drains first (block counts can't see that). When this step's decode
//! appends cannot be covered, the plan first budgets prefix-cache
//! evictions / cold-tier demotions (`want_free`, bytes), then names
//! preemption victims — lowest priority class, most-recently-admitted
//! first — whose refcount-aware reclaimable bytes close the gap. Victims
//! drop out of the decode set and re-enter via the preempted queue.

use super::admission::{self, AdmissionConfig, AdmissionMode, Verdict};
use super::request::{Request, RequestId};
use super::scheduler::{Running, Scheduler};
use crate::kvcache::KvCacheManager;

#[derive(Debug, Clone, Copy)]
pub struct BatcherConfig {
    pub max_prefills_per_step: usize,
    pub max_decode_batch: usize,
    pub admission: AdmissionConfig,
}

impl Default for BatcherConfig {
    fn default() -> Self {
        BatcherConfig {
            max_prefills_per_step: 1,
            max_decode_batch: 16,
            admission: AdmissionConfig::default(),
        }
    }
}

/// What one engine iteration should do, in execution order.
#[derive(Debug, Default)]
pub struct StepPlan {
    /// Free-byte target ([`KvCacheManager::free_bytes`]) the engine
    /// should reach by demoting/evicting prefix-cache entries before
    /// anything else runs (0 = no eviction needed).
    pub want_free: u64,
    /// Victims to preempt before decoding: free their blocks, park them.
    pub preemptions: Vec<RequestId>,
    /// Preempted requests to readmit (rebuild cache + replay) this step.
    pub resumes: Vec<Running>,
    /// Requests to prefill this step (already admission-checked).
    pub prefills: Vec<(Request, super::request::EventTx)>,
    /// Request ids to decode this step (victims already excluded).
    pub decodes: Vec<RequestId>,
    /// Requests rejected by admission (with cause) — emit and drop.
    pub rejections: Vec<(Request, super::request::EventTx, String)>,
}

/// Round-robin cursor for decode fairness across iterations.
#[derive(Debug, Default)]
pub struct Batcher {
    decode_cursor: usize,
}

impl Batcher {
    pub fn new() -> Batcher {
        Batcher::default()
    }

    /// Plan one iteration. `prefix_evictable` is the physical-byte
    /// credit the engine's prefix cache could free on demand (its
    /// reclaimable blocks at sub-pool widths); the plan spends it — via
    /// `want_free` — before naming preemption victims, and resumes may
    /// draw on it too (cached prefixes never starve in-flight requests).
    pub fn plan(
        &mut self,
        cfg: &BatcherConfig,
        sched: &mut Scheduler,
        cache: &KvCacheManager,
        prefix_evictable: u64,
    ) -> StepPlan {
        let mut plan = StepPlan::default();
        let free = cache.free_bytes();
        // Bytes committed to planned resumes + prefills this step. All
        // spending draws on one pot — `free + prefix_evictable` — so the
        // credit cannot be double-counted across decisions.
        let mut committed = 0u64;

        // Worst-case mode reserves every running request's unrealized
        // growth so admission never overcommits (and preemption is never
        // needed). Optimistic mode reserves nothing — that is the point.
        let outstanding: u64 = match cfg.admission.mode {
            AdmissionMode::WorstCase => sched
                .running
                .iter()
                .map(|r| {
                    cache
                        .bytes_for_tokens(r.req.max_total_tokens())
                        .saturating_sub(cache.seq_bytes(r.seq))
                })
                .sum(),
            AdmissionMode::Optimistic => 0,
        };

        // Readmit preempted requests first (FCFS): they hold live client
        // streams and already passed full admission once.
        while plan.resumes.len() + plan.prefills.len() < cfg.max_prefills_per_step {
            let Some(front) = sched.preempted.front() else { break };
            let rebuild_tokens = match cfg.admission.mode {
                // Cache rows to rematerialize (prompt + generated rows
                // already appended before preemption) **plus the row the
                // next decode step appends** — sizing only the rebuild
                // would readmit a boundary-aligned sequence straight into
                // an unfulfillable append, and the most-recently-admitted
                // victim policy would re-preempt it before it generates
                // anything (resume/preempt thrash).
                AdmissionMode::Optimistic => {
                    front.req.prompt.len() + front.generated.saturating_sub(1) + 1
                }
                AdmissionMode::WorstCase => front.req.max_total_tokens(),
            };
            let verdict = admission::check_resume(
                &cfg.admission,
                rebuild_tokens,
                cache,
                sched.running_len() + plan.resumes.len() + plan.prefills.len(),
                committed + outstanding,
                prefix_evictable,
            );
            match verdict {
                Verdict::Admit => {
                    committed += cache.bytes_for_tokens(rebuild_tokens);
                    plan.resumes.push(sched.preempted.pop_front().unwrap());
                }
                _ => break, // FCFS head-of-line within the preempted queue
            }
        }

        // Admit up to the remaining prefill budget from the waiting queue.
        while plan.resumes.len() + plan.prefills.len() < cfg.max_prefills_per_step {
            let Some(head) = sched.peek_waiting() else { break };
            let verdict = admission::check(
                &cfg.admission,
                head,
                cache,
                sched.running_len() + plan.resumes.len() + plan.prefills.len(),
                sched.waiting_len().saturating_sub(1),
                committed + outstanding,
            );
            match verdict {
                Verdict::Admit => {
                    let (req, tx) = sched.pop_waiting().unwrap();
                    committed += match cfg.admission.mode {
                        AdmissionMode::Optimistic => cache.bytes_for_tokens(req.prompt.len()),
                        AdmissionMode::WorstCase => {
                            cache.bytes_for_tokens(req.max_total_tokens())
                        }
                    };
                    plan.prefills.push((req, tx));
                }
                Verdict::Defer => break, // FCFS head-of-line blocks its class
                Verdict::Reject(cause) => {
                    let (req, tx) = sched.pop_waiting().unwrap();
                    plan.rejections.push((req, tx, cause));
                }
            }
        }

        // Decode set: all running, rotated, capped.
        let n = sched.running_len();
        if n > 0 {
            let take = n.min(cfg.max_decode_batch);
            self.decode_cursor %= n;
            for i in 0..take {
                let r = &sched.running[(self.decode_cursor + i) % n];
                plan.decodes.push(r.req.id);
            }
            self.decode_cursor = (self.decode_cursor + take) % n;
        }

        // Pool-pressure resolution for this step's decode appends: spend
        // the prefix-cache credit first, then preempt victims until the
        // remaining appends are covered (or nobody is left to evict).
        let mut decode_need: u64 = plan
            .decodes
            .iter()
            .filter_map(|id| sched.running.iter().find(|r| r.req.id == *id))
            .map(|r| cache.append_need_bytes(r.seq))
            .sum();
        let total_need = committed + decode_need;
        if total_need > free {
            plan.want_free = total_need.min(free + prefix_evictable);
        }
        let mut avail = (free + prefix_evictable).saturating_sub(committed);
        while decode_need > avail {
            let Some(vid) = sched.select_victim(&plan.preemptions) else { break };
            let victim = sched.running.iter().find(|r| r.req.id == vid).unwrap();
            avail += cache.seq_reclaimable_bytes(victim.seq);
            if let Some(pos) = plan.decodes.iter().position(|&d| d == vid) {
                decode_need -= cache.append_need_bytes(victim.seq);
                plan.decodes.remove(pos);
            }
            plan.preemptions.push(vid);
        }

        // Liveness valve: nothing planned, nothing running to free blocks
        // organically, but work is waiting — the pool must be pinned by
        // prefix-cache entries. Evict toward the head request's need so
        // the next step can admit it (a cache serving nobody is worthless
        // next to a stalled queue).
        if plan.resumes.is_empty()
            && plan.prefills.is_empty()
            && plan.decodes.is_empty()
            && plan.preemptions.is_empty()
            && sched.running.is_empty()
            && prefix_evictable > 0
        {
            if let Some(head) = sched.peek_waiting() {
                let headroom = cache.headroom_bytes(cfg.admission.watermark);
                let need = match cfg.admission.mode {
                    AdmissionMode::Optimistic => cache.bytes_for_tokens(head.prompt.len()),
                    AdmissionMode::WorstCase => {
                        cache.bytes_for_tokens(head.max_total_tokens())
                    }
                };
                plan.want_free =
                    plan.want_free.max((need + headroom).min(free + prefix_evictable));
            }
        }
        plan
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::kvcache::manager::CacheConfig;
    use crate::kvcache::{Precision, QuantPolicy};
    use std::sync::mpsc;

    fn cache_with(num_blocks: usize) -> KvCacheManager {
        KvCacheManager::new(
            CacheConfig {
                layers: 2,
                heads: 2,
                head_dim: 8,
                max_seq: 64,
                block_size: 4,
                num_blocks,
                scale_margin: 1.0,
            },
            QuantPolicy::uniform(Precision::Int8, 2, 2),
        )
    }

    fn cache() -> KvCacheManager {
        cache_with(64)
    }

    fn enqueue(s: &mut Scheduler, id: u64, prompt: usize, max_new: usize) {
        let (tx, rx) = mpsc::channel();
        std::mem::forget(rx);
        s.enqueue(Request::new(id, vec![0; prompt], max_new), tx);
    }

    /// Prefill a real sequence and register it as running.
    fn start_running(s: &mut Scheduler, c: &mut KvCacheManager, id: u64, tokens: usize) {
        let ccfg = *c.config();
        let n = ccfg.layers * ccfg.heads * ccfg.max_seq * ccfg.head_dim;
        let seq = c.new_sequence();
        c.set_prefill(seq, &vec![0.1; n], &vec![0.1; n], tokens).unwrap();
        let (tx, rx) = mpsc::channel();
        std::mem::forget(rx);
        let admitted_seq = s.next_admission_stamp();
        s.start(Running {
            req: Request::new(id, vec![0; tokens], 32),
            seq,
            last_token: 0,
            generated: 1,
            tokens: vec![0],
            rng: crate::util::rng::Rng::new(id),
            first_token_at: None,
            admitted_seq,
            last_progress: std::time::Instant::now(),
            stall_warned: false,
            events: tx,
        });
    }

    #[test]
    fn admits_up_to_prefill_cap() {
        let mut s = Scheduler::new();
        for id in 1..=3 {
            enqueue(&mut s, id, 4, 4);
        }
        let c = cache();
        let mut b = Batcher::new();
        let cfg = BatcherConfig { max_prefills_per_step: 2, ..Default::default() };
        let plan = b.plan(&cfg, &mut s, &c, 0);
        assert_eq!(plan.prefills.len(), 2);
        assert_eq!(s.waiting_len(), 1);
        assert!(plan.rejections.is_empty());
        assert!(plan.preemptions.is_empty());
        assert_eq!(plan.want_free, 0);
    }

    #[test]
    fn rejections_are_surfaced_not_silently_dropped() {
        let mut s = Scheduler::new();
        enqueue(&mut s, 1, 100, 10); // > max_seq -> reject
        enqueue(&mut s, 2, 4, 4); // fine
        let c = cache();
        let mut b = Batcher::new();
        let plan = b.plan(&BatcherConfig::default(), &mut s, &c, 0);
        assert_eq!(plan.rejections.len(), 1);
        assert_eq!(plan.rejections[0].0.id, 1);
        assert_eq!(plan.prefills.len(), 1);
        assert_eq!(plan.prefills[0].0.id, 2);
    }

    #[test]
    fn decode_round_robin_rotates() {
        let mut s = Scheduler::new();
        let mut c = cache();
        for id in 1..=3 {
            start_running(&mut s, &mut c, id, 2);
        }
        let mut b = Batcher::new();
        let cfg = BatcherConfig { max_decode_batch: 2, ..Default::default() };
        let p1 = b.plan(&cfg, &mut s, &c, 0);
        let p2 = b.plan(&cfg, &mut s, &c, 0);
        assert_eq!(p1.decodes, vec![1, 2]);
        assert_eq!(p2.decodes, vec![3, 1], "cursor rotated");
    }

    #[test]
    fn defer_blocks_head_of_line_only_within_step() {
        // A request at the edge of the pool: worst-case mode rejects it,
        // optimistic mode admits it (prompt fits; preemption covers the
        // rest). Either way the plan must terminate.
        let mut s = Scheduler::new();
        enqueue(&mut s, 1, 60, 4);
        let c = cache();
        let mut b = Batcher::new();
        let plan = b.plan(&BatcherConfig::default(), &mut s, &c, 0);
        assert_eq!(plan.prefills.len() + plan.rejections.len(), 1);
    }

    #[test]
    fn worst_case_mode_reserves_running_growth() {
        // Pool 64. One running seq at 4 tokens of a (4 + 44 = 48)-token
        // worst case: 48 tokens -> 12 blocks x4 = 48; holds 4 -> reserve
        // 44. A newcomer with worst case 16 blocks sees 64 - 44 = 20 free
        // minus its own 16 + headroom 3 -> defers; optimistic admits.
        let mut s = Scheduler::new();
        let mut c = cache();
        start_running(&mut s, &mut c, 1, 4);
        s.running[0].req.max_new_tokens = 44;
        enqueue(&mut s, 2, 8, 8);
        let mut b = Batcher::new();
        let wc = BatcherConfig {
            admission: AdmissionConfig {
                mode: AdmissionMode::WorstCase,
                ..Default::default()
            },
            ..Default::default()
        };
        let plan = b.plan(&wc, &mut s, &c, 0);
        assert!(plan.prefills.is_empty(), "worst-case defers behind growth reserve");
        let mut b2 = Batcher::new();
        let plan = b2.plan(&BatcherConfig::default(), &mut s, &c, 0);
        assert_eq!(plan.prefills.len(), 1, "optimistic admits the prompt");
    }

    #[test]
    fn names_victims_when_decode_cannot_allocate() {
        // Pool 16, two running seqs each holding 8 blocks (8 tokens, at a
        // block boundary): both decodes want 2L=4 fresh blocks, free = 0.
        // The most recently admitted is preempted; its reclaim (8) covers
        // the survivor's append.
        let mut s = Scheduler::new();
        let mut c = cache_with(16);
        start_running(&mut s, &mut c, 1, 8);
        start_running(&mut s, &mut c, 2, 8);
        assert_eq!(c.free_blocks(), 0);
        let mut b = Batcher::new();
        let plan = b.plan(&BatcherConfig::default(), &mut s, &c, 0);
        assert_eq!(plan.preemptions, vec![2], "most recent admit is the victim");
        assert_eq!(plan.decodes, vec![1], "victim dropped from the decode set");
    }

    #[test]
    fn prefix_credit_spends_before_preempting() {
        // Same pressure as above, but two spans of evictable prefix
        // bytes cover the two appends (one span each): no victims,
        // want_free demands the eviction.
        let mut s = Scheduler::new();
        let mut c = cache_with(16);
        start_running(&mut s, &mut c, 1, 8);
        start_running(&mut s, &mut c, 2, 8);
        let credit = 2 * c.span_bytes() as u64; // 8 blocks at width
        let mut b = Batcher::new();
        let plan = b.plan(&BatcherConfig::default(), &mut s, &c, credit);
        assert!(plan.preemptions.is_empty(), "prefix eviction covers the step");
        assert_eq!(plan.decodes, vec![1, 2]);
        assert_eq!(plan.want_free, credit);
    }

    #[test]
    fn resumes_run_before_new_prefills() {
        let mut s = Scheduler::new();
        let mut c = cache();
        start_running(&mut s, &mut c, 1, 4);
        let mut run = s.finish(1).unwrap();
        c.free(run.seq);
        run.seq = 0;
        s.park_preempted(run);
        enqueue(&mut s, 2, 4, 4);
        let mut b = Batcher::new();
        let plan = b.plan(&BatcherConfig::default(), &mut s, &c, 0);
        assert_eq!(plan.resumes.len(), 1, "preempted request readmits first");
        assert_eq!(plan.resumes[0].req.id, 1);
        assert!(plan.prefills.is_empty(), "prefill budget spent on the resume");
        assert_eq!(s.preempted_len(), 0);
    }
}
