//! Serving metrics: counters + latency histograms, shared via a mutex
//! (engine thread writes, router/HTTP threads read snapshots).
//!
//! Beyond the classic latency set (TTFT/TPOT/e2e), the scheduler's
//! memory behavior is first-class: preemption and recompute counters,
//! prefix-cache hit rate, and true (refcount-aware) pool occupancy, so
//! `GET /metrics` answers "how full is the pool really and what did
//! optimistic admission cost us" directly.

use crate::kvcache::TierStats;
use crate::util::stats::LogHistogram;
use std::sync::{Arc, Mutex};
use std::time::Instant;

/// Point-in-time scheduler/pool gauges recorded each engine step.
#[derive(Debug, Clone, Copy, Default)]
pub struct StepGauges {
    pub running: usize,
    pub waiting: usize,
    pub preempted: usize,
    /// True pool utilization: shared blocks counted once.
    pub cache_utilization: f64,
    pub pool_used_blocks: usize,
    pub pool_total_blocks: usize,
    /// Sum of per-sequence footprints (shared blocks counted per holder);
    /// `pool_logical_blocks - pool_used_blocks` = blocks COW sharing saves.
    pub pool_logical_blocks: usize,
    /// Logical blocks pinned by the prefix cache.
    pub prefix_cache_blocks: usize,
    /// Cumulative prefix-cache lookups/hits, read straight from
    /// [`crate::kvcache::PrefixStats`] — the cache's own counters are the
    /// single source of truth (no parallel bookkeeping to drift).
    pub prefix_lookups: u64,
    pub prefix_hits: u64,
    /// Block-aligned partial hits (suffix prefill only).
    pub prefix_partial_hits: u64,
    /// Prompt tokens served from cached blocks (full + partial hits).
    pub prefix_saved_tokens: u64,
    /// Trie nodes (block-aligned cached chunks) currently held.
    pub prefix_trie_nodes: u64,
    /// Logical payload bytes of live sequences' valid cache rows, broken
    /// down by storage precision (`[fp32, int8, int4]`) — the policy-aware
    /// occupancy view from
    /// [`crate::kvcache::KvCacheManager::payload_bytes_by_precision`].
    /// Pinned alongside the physical gauges below for continuity.
    pub cache_payload_bytes: [u64; 3],
    /// Physical bytes of the blocks live sequences hold, at sub-pool
    /// widths, shared blocks counted once (`[fp32, int8, int4]`) — from
    /// [`crate::kvcache::KvCacheManager::physical_bytes_by_precision`].
    pub cache_physical_bytes: [u64; 3],
    /// Physical bytes the pool's per-precision sub-pool slabs occupy
    /// (Σ per-class `num_blocks × width`). Mixed policies keep this
    /// strictly below the widest-stream padded baseline.
    pub pool_physical_bytes: u64,
    /// Free bytes not allocatable as whole spans right now (sub-pool
    /// class imbalance plus the sub-span remainder).
    pub pool_fragmentation_bytes: u64,
    /// Cold-tier counters, read straight from
    /// [`crate::kvcache::TierStats`] — the tier's own counters are the
    /// single source of truth (no parallel bookkeeping to drift).
    pub tier: TierStats,
}

#[derive(Debug)]
struct Inner {
    started: Instant,
    requests_submitted: u64,
    requests_finished: u64,
    requests_rejected: u64,
    /// Requests torn down by an engine error (decode failure, dead
    /// engine). Terminal like finished/rejected — [`Metrics::depth`]
    /// stays balanced only if every submission books exactly one
    /// terminal event.
    requests_errored: u64,
    /// Streams cancelled because their deadline expired. Terminal.
    deadline_cancels: u64,
    /// Streams cancelled by the no-progress watchdog. Terminal.
    stall_cancels: u64,
    /// Streams cancelled because the client dropped its receiver
    /// mid-generation. Terminal.
    client_cancels: u64,
    /// In-flight streams failed by a shard panic
    /// (`FinishReason::ShardFailed`). Terminal.
    streams_failed: u64,
    tokens_generated: u64,
    prefill_tokens: u64,
    engine_steps: u64,
    preemptions: u64,
    resumes: u64,
    /// Tokens re-materialized by readmissions (prompt + replayed trail).
    recompute_tokens: u64,
    /// Decode steps that reported cache-I/O accounting (incl. replays).
    decode_steps: u64,
    /// Cumulative seconds spent copying caches into staging (zero on the
    /// zero-copy paged path).
    gather_secs: f64,
    /// Cumulative seconds in the backend's attention/decode execution.
    attend_secs: f64,
    /// Cumulative cache payload+scale bytes a decode step touched: the
    /// staging copy volume (O(max_seq)) on the legacy path, the valid
    /// rows actually read in place (O(len)) on the paged path. Batched
    /// multi-query waves book their (dedup-amortized) wave bytes here
    /// once via [`Metrics::on_mq_wave`] instead of per member.
    cache_bytes_read: u64,
    /// Fused multi-query kernel passes executed by batched decode waves
    /// (one per (wave, layer, K|V, head)).
    mq_passes: u64,
    /// Physical blocks dequantized once on behalf of >1 wave member
    /// (Σ over wave groups of members−1) — the COW-sharing dedup win.
    blocks_deduped: u64,
    ttft: LogHistogram,
    tpot: LogHistogram,
    e2e: LogHistogram,
    step_time: LogHistogram,
    gauges: StepGauges,
    /// High-water mark of concurrently running sequences.
    running_peak: usize,
    /// Active quantization policy name (set once at engine init).
    policy: String,
    /// Resolved kernel ISA name (set once at engine init: the concrete
    /// instruction set the `kernel_backend` knob dispatched to).
    kernel_isa: String,
}

/// Cloneable handle.
#[derive(Clone)]
pub struct Metrics(Arc<Mutex<Inner>>);

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics(Arc::new(Mutex::new(Inner {
            started: Instant::now(),
            requests_submitted: 0,
            requests_finished: 0,
            requests_rejected: 0,
            requests_errored: 0,
            deadline_cancels: 0,
            stall_cancels: 0,
            client_cancels: 0,
            streams_failed: 0,
            tokens_generated: 0,
            prefill_tokens: 0,
            engine_steps: 0,
            preemptions: 0,
            resumes: 0,
            recompute_tokens: 0,
            decode_steps: 0,
            gather_secs: 0.0,
            attend_secs: 0.0,
            cache_bytes_read: 0,
            mq_passes: 0,
            blocks_deduped: 0,
            ttft: LogHistogram::latency(),
            tpot: LogHistogram::latency(),
            e2e: LogHistogram::latency(),
            step_time: LogHistogram::latency(),
            gauges: StepGauges::default(),
            running_peak: 0,
            policy: String::new(),
            kernel_isa: String::new(),
        })))
    }

    /// Record the engine's quantization policy (shown at `GET /metrics`).
    pub fn set_policy(&self, name: &str) {
        self.0.lock().unwrap().policy = name.to_string();
    }

    /// Record the resolved kernel ISA (shown at `GET /metrics` as
    /// `kernel_isa` — which instruction set the `kernel_backend` knob
    /// actually selected on this host).
    pub fn set_kernel_isa(&self, name: &str) {
        self.0.lock().unwrap().kernel_isa = name.to_string();
    }

    pub fn on_submit(&self) {
        self.0.lock().unwrap().requests_submitted += 1;
    }

    pub fn on_reject(&self) {
        self.0.lock().unwrap().requests_rejected += 1;
    }

    /// A request terminated on an engine error (no finish/reject booked).
    pub fn on_error(&self) {
        self.0.lock().unwrap().requests_errored += 1;
    }

    /// A stream was cancelled because its deadline expired. Terminal.
    pub fn on_deadline_cancel(&self) {
        self.0.lock().unwrap().deadline_cancels += 1;
    }

    /// A stream was cancelled by the no-progress watchdog. Terminal.
    pub fn on_stall_cancel(&self) {
        self.0.lock().unwrap().stall_cancels += 1;
    }

    /// A stream was cancelled because its client receiver dropped.
    /// Terminal.
    pub fn on_client_cancel(&self) {
        self.0.lock().unwrap().client_cancels += 1;
    }

    /// `n` in-flight streams were failed by a shard panic. Terminal for
    /// each of them.
    pub fn on_shard_failure(&self, n: usize) {
        self.0.lock().unwrap().streams_failed += n as u64;
    }

    /// Live request depth observed through the counters: submissions not
    /// yet terminated (finished, rejected, errored, cancelled, or failed
    /// with the shard). Unlike the step gauges this also counts work
    /// still queued in the engine's command channel, which is exactly
    /// what the router's per-shard admission bound needs. Saturating:
    /// termination of an in-flight submit may be booked a hair before
    /// the submit itself is visible.
    pub fn depth(&self) -> usize {
        let m = self.0.lock().unwrap();
        let terminal = m.requests_finished
            + m.requests_rejected
            + m.requests_errored
            + m.deadline_cancels
            + m.stall_cancels
            + m.client_cancels
            + m.streams_failed;
        m.requests_submitted.saturating_sub(terminal) as usize
    }

    pub fn on_first_token(&self, ttft: f64, prefill_tokens: usize) {
        let mut m = self.0.lock().unwrap();
        m.ttft.record(ttft);
        m.prefill_tokens += prefill_tokens as u64;
        m.tokens_generated += 1;
    }

    pub fn on_token(&self, tpot: f64) {
        let mut m = self.0.lock().unwrap();
        m.tpot.record(tpot);
        m.tokens_generated += 1;
    }

    pub fn on_finish(&self, e2e: f64) {
        let mut m = self.0.lock().unwrap();
        m.e2e.record(e2e);
        m.requests_finished += 1;
    }

    /// Cache-I/O accounting for one decode step (replays included):
    /// seconds gathering into staging, seconds in the backend's fused
    /// attention/decode, and cache bytes touched (see
    /// [`MetricsSnapshot::cache_bytes_read`] semantics).
    pub fn on_decode(&self, gather_secs: f64, attend_secs: f64, cache_bytes: usize) {
        let mut m = self.0.lock().unwrap();
        m.decode_steps += 1;
        m.gather_secs += gather_secs;
        m.attend_secs += attend_secs;
        m.cache_bytes_read += cache_bytes as u64;
    }

    /// Wave-level accounting for one batched multi-query decode wave:
    /// fused kernel passes, physical blocks deduplicated across members,
    /// and the wave's amortized cache traffic (each deduped block's
    /// payload counted once — booked here exactly once per wave, while
    /// the per-member [`Metrics::on_decode`] calls book 0 bytes).
    pub fn on_mq_wave(&self, passes: usize, deduped: usize, wave_bytes: usize) {
        let mut m = self.0.lock().unwrap();
        m.mq_passes += passes as u64;
        m.blocks_deduped += deduped as u64;
        m.cache_bytes_read += wave_bytes as u64;
    }

    /// A running request was preempted (blocks freed, state parked).
    pub fn on_preempt(&self) {
        self.0.lock().unwrap().preemptions += 1;
    }

    /// A preempted request was readmitted after re-materializing
    /// `recompute_tokens` cache rows (prompt + replayed generations).
    pub fn on_resume(&self, recompute_tokens: usize) {
        let mut m = self.0.lock().unwrap();
        m.resumes += 1;
        m.recompute_tokens += recompute_tokens as u64;
    }

    pub fn on_step(&self, secs: f64, gauges: StepGauges) {
        let mut m = self.0.lock().unwrap();
        m.engine_steps += 1;
        m.step_time.record(secs);
        m.running_peak = m.running_peak.max(gauges.running);
        m.gauges = gauges;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.0.lock().unwrap();
        let uptime = m.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            uptime,
            requests_submitted: m.requests_submitted,
            requests_finished: m.requests_finished,
            requests_rejected: m.requests_rejected,
            requests_errored: m.requests_errored,
            deadline_cancels: m.deadline_cancels,
            stall_cancels: m.stall_cancels,
            client_cancels: m.client_cancels,
            streams_failed: m.streams_failed,
            tokens_generated: m.tokens_generated,
            prefill_tokens: m.prefill_tokens,
            engine_steps: m.engine_steps,
            preemptions: m.preemptions,
            resumes: m.resumes,
            recompute_tokens: m.recompute_tokens,
            decode_steps: m.decode_steps,
            gather_secs: m.gather_secs,
            attend_secs: m.attend_secs,
            cache_bytes_read: m.cache_bytes_read,
            mq_passes: m.mq_passes,
            blocks_deduped: m.blocks_deduped,
            prefix_lookups: m.gauges.prefix_lookups,
            prefix_hits: m.gauges.prefix_hits,
            prefix_partial_hits: m.gauges.prefix_partial_hits,
            prefix_saved_tokens: m.gauges.prefix_saved_tokens,
            prefix_trie_nodes: m.gauges.prefix_trie_nodes,
            tokens_per_sec: m.tokens_generated as f64 / uptime.max(1e-9),
            ttft_p50: m.ttft.quantile(0.5),
            ttft_p99: m.ttft.quantile(0.99),
            tpot_p50: m.tpot.quantile(0.5),
            tpot_p99: m.tpot.quantile(0.99),
            e2e_p50: m.e2e.quantile(0.5),
            e2e_p99: m.e2e.quantile(0.99),
            step_p50: m.step_time.quantile(0.5),
            cache_utilization: m.gauges.cache_utilization,
            pool_used_blocks: m.gauges.pool_used_blocks,
            pool_total_blocks: m.gauges.pool_total_blocks,
            pool_logical_blocks: m.gauges.pool_logical_blocks,
            prefix_cache_blocks: m.gauges.prefix_cache_blocks,
            running: m.gauges.running,
            running_peak: m.running_peak,
            waiting: m.gauges.waiting,
            preempted: m.gauges.preempted,
            cache_payload_bytes: m.gauges.cache_payload_bytes,
            cache_physical_bytes: m.gauges.cache_physical_bytes,
            pool_physical_bytes: m.gauges.pool_physical_bytes,
            pool_fragmentation_bytes: m.gauges.pool_fragmentation_bytes,
            tier: m.gauges.tier,
            policy: m.policy.clone(),
            kernel_isa: m.kernel_isa.clone(),
        }
    }
}

/// Point-in-time view (JSON-serializable for the /metrics endpoint).
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub uptime: f64,
    pub requests_submitted: u64,
    pub requests_finished: u64,
    pub requests_rejected: u64,
    pub requests_errored: u64,
    /// Streams cancelled by deadline expiry (schema v5).
    pub deadline_cancels: u64,
    /// Streams cancelled by the no-progress watchdog (schema v5).
    pub stall_cancels: u64,
    /// Streams cancelled by client receiver drop (schema v5).
    pub client_cancels: u64,
    /// In-flight streams failed by a shard panic (schema v5).
    pub streams_failed: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub engine_steps: u64,
    pub preemptions: u64,
    pub resumes: u64,
    pub recompute_tokens: u64,
    pub decode_steps: u64,
    /// Cumulative staging-copy seconds (zero-copy paged decode books 0).
    pub gather_secs: f64,
    /// Cumulative backend attention/decode seconds.
    pub attend_secs: f64,
    /// Cumulative cache bytes a decode step touched: O(max_seq) staging
    /// copies on the legacy path vs O(len) in-place reads on the paged
    /// path — the zero-copy win, numerically. Batched waves contribute
    /// their amortized wave bytes (deduped blocks counted once).
    pub cache_bytes_read: u64,
    /// Fused multi-query kernel passes from batched decode waves.
    pub mq_passes: u64,
    /// Physical blocks whose dequantization was shared across wave
    /// members by batched decode.
    pub blocks_deduped: u64,
    pub prefix_lookups: u64,
    pub prefix_hits: u64,
    /// Block-aligned partial prefix-cache hits (suffix prefill only).
    pub prefix_partial_hits: u64,
    /// Prompt tokens served from cached prefix blocks.
    pub prefix_saved_tokens: u64,
    /// Current prefix-trie node count.
    pub prefix_trie_nodes: u64,
    pub tokens_per_sec: f64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    pub step_p50: f64,
    pub cache_utilization: f64,
    pub pool_used_blocks: usize,
    pub pool_total_blocks: usize,
    pub pool_logical_blocks: usize,
    pub prefix_cache_blocks: usize,
    pub running: usize,
    pub running_peak: usize,
    pub waiting: usize,
    pub preempted: usize,
    /// Live cache payload bytes by precision (`[fp32, int8, int4]`) —
    /// the legacy logical view, pinned for dashboard continuity.
    pub cache_payload_bytes: [u64; 3],
    /// Live physical bytes by precision at sub-pool widths, shared
    /// blocks counted once (`[fp32, int8, int4]`).
    pub cache_physical_bytes: [u64; 3],
    /// Physical bytes the per-precision sub-pool slabs occupy.
    pub pool_physical_bytes: u64,
    /// Free bytes not allocatable as whole spans (class imbalance +
    /// sub-span remainder).
    pub pool_fragmentation_bytes: u64,
    /// Cold-tier counters (schema v4 `tier_*` keys).
    pub tier: TierStats,
    /// Active quantization policy name.
    pub policy: String,
    /// Resolved kernel ISA name (`scalar` | `avx2` | `neon`).
    pub kernel_isa: String,
}

impl MetricsSnapshot {
    /// Prefix-cache hit rate over the engine's lifetime (0 when the cache
    /// is disabled or untouched).
    pub fn prefix_hit_rate(&self) -> f64 {
        self.prefix_hits as f64 / self.prefix_lookups.max(1) as f64
    }

    /// Mean cache bytes touched per decode step.
    pub fn cache_bytes_per_token(&self) -> f64 {
        self.cache_bytes_read as f64 / self.decode_steps.max(1) as f64
    }

    /// Mean decode nanoseconds per token over the cache read + attention
    /// execution (the hot path the zero-copy refactor targets).
    pub fn decode_ns_per_token(&self) -> f64 {
        (self.gather_secs + self.attend_secs) * 1e9 / self.decode_steps.max(1) as f64
    }

    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::obj;
        obj([
            ("uptime_s", self.uptime.into()),
            ("requests_submitted", (self.requests_submitted as usize).into()),
            ("requests_finished", (self.requests_finished as usize).into()),
            ("requests_rejected", (self.requests_rejected as usize).into()),
            ("requests_errored", (self.requests_errored as usize).into()),
            ("deadline_cancels", (self.deadline_cancels as usize).into()),
            ("stall_cancels", (self.stall_cancels as usize).into()),
            ("client_cancels", (self.client_cancels as usize).into()),
            ("streams_failed", (self.streams_failed as usize).into()),
            ("tokens_generated", (self.tokens_generated as usize).into()),
            ("prefill_tokens", (self.prefill_tokens as usize).into()),
            ("engine_steps", (self.engine_steps as usize).into()),
            ("preemptions", (self.preemptions as usize).into()),
            ("resumes", (self.resumes as usize).into()),
            ("recompute_tokens", (self.recompute_tokens as usize).into()),
            ("decode_steps", (self.decode_steps as usize).into()),
            ("gather_secs", self.gather_secs.into()),
            ("attend_secs", self.attend_secs.into()),
            ("cache_bytes_read", (self.cache_bytes_read as usize).into()),
            ("mq_passes", (self.mq_passes as usize).into()),
            ("blocks_deduped", (self.blocks_deduped as usize).into()),
            ("cache_bytes_per_token", self.cache_bytes_per_token().into()),
            ("decode_ns_per_token", self.decode_ns_per_token().into()),
            ("prefix_lookups", (self.prefix_lookups as usize).into()),
            ("prefix_hits", (self.prefix_hits as usize).into()),
            ("prefix_partial_hits", (self.prefix_partial_hits as usize).into()),
            ("prefix_saved_tokens", (self.prefix_saved_tokens as usize).into()),
            ("prefix_trie_nodes", (self.prefix_trie_nodes as usize).into()),
            ("prefix_hit_rate", self.prefix_hit_rate().into()),
            ("tokens_per_sec", self.tokens_per_sec.into()),
            ("ttft_p50_s", self.ttft_p50.into()),
            ("ttft_p99_s", self.ttft_p99.into()),
            ("tpot_p50_s", self.tpot_p50.into()),
            ("tpot_p99_s", self.tpot_p99.into()),
            ("e2e_p50_s", self.e2e_p50.into()),
            ("e2e_p99_s", self.e2e_p99.into()),
            ("step_p50_s", self.step_p50.into()),
            ("cache_utilization", self.cache_utilization.into()),
            ("pool_used_blocks", self.pool_used_blocks.into()),
            ("pool_total_blocks", self.pool_total_blocks.into()),
            ("pool_logical_blocks", self.pool_logical_blocks.into()),
            ("prefix_cache_blocks", self.prefix_cache_blocks.into()),
            ("running", self.running.into()),
            ("running_peak", self.running_peak.into()),
            ("waiting", self.waiting.into()),
            ("preempted", self.preempted.into()),
            ("quant_policy", self.policy.as_str().into()),
            ("kernel_isa", self.kernel_isa.as_str().into()),
            ("cache_bytes_fp32", (self.cache_payload_bytes[0] as usize).into()),
            ("cache_bytes_int8", (self.cache_payload_bytes[1] as usize).into()),
            ("cache_bytes_int4", (self.cache_payload_bytes[2] as usize).into()),
            ("cache_physical_bytes_fp32", (self.cache_physical_bytes[0] as usize).into()),
            ("cache_physical_bytes_int8", (self.cache_physical_bytes[1] as usize).into()),
            ("cache_physical_bytes_int4", (self.cache_physical_bytes[2] as usize).into()),
            ("pool_physical_bytes", (self.pool_physical_bytes as usize).into()),
            ("pool_fragmentation_bytes", (self.pool_fragmentation_bytes as usize).into()),
            ("tier_hot_blocks", self.pool_used_blocks.into()),
            ("tier_cold_blocks", (self.tier.cold_blocks as usize).into()),
            ("tier_cold_entries", (self.tier.cold_entries as usize).into()),
            ("tier_demotions", (self.tier.demotions as usize).into()),
            ("tier_promotions", (self.tier.promotions as usize).into()),
            ("tier_prefetch_hits", (self.tier.prefetch_hits as usize).into()),
            ("tier_prefetch_misses", (self.tier.prefetch_misses as usize).into()),
            ("tier_cold_evictions", (self.tier.cold_evictions as usize).into()),
            (
                "tier_preemptions_avoided",
                (self.tier.preemptions_avoided as usize).into(),
            ),
            ("tier_snapshot_loaded", (self.tier.snapshot_loaded as usize).into()),
            ("tier_snapshot_rejected", (self.tier.snapshot_rejected as usize).into()),
            ("tier_decompress_errors", (self.tier.decompress_errors as usize).into()),
            ("tier_cold_raw_bytes", (self.tier.cold_raw_bytes as usize).into()),
            ("tier_cold_comp_bytes", (self.tier.cold_comp_bytes as usize).into()),
            ("tier_compression_ratio", self.tier.compression_ratio().into()),
            ("tier_demote_secs", self.tier.demote_secs.into()),
            ("tier_promote_secs", self.tier.promote_secs.into()),
            ("tier_decompress_secs", self.tier.decompress_secs.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_first_token(0.1, 8);
        m.on_token(0.02);
        m.on_token(0.03);
        m.on_finish(0.5);
        let s = m.snapshot();
        assert_eq!(s.requests_submitted, 2);
        assert_eq!(s.requests_rejected, 1);
        assert_eq!(s.requests_finished, 1);
        assert_eq!(s.tokens_generated, 3);
        assert_eq!(s.prefill_tokens, 8);
        assert!(s.tokens_per_sec > 0.0);
    }

    #[test]
    fn preemption_and_prefix_counters() {
        let m = Metrics::new();
        m.on_preempt();
        m.on_preempt();
        m.on_resume(12);
        // Prefix counters ride on the step gauges (the cache's own
        // cumulative stats are the single source of truth).
        m.on_step(
            0.01,
            StepGauges {
                prefix_lookups: 3,
                prefix_hits: 2,
                prefix_partial_hits: 1,
                prefix_saved_tokens: 24,
                prefix_trie_nodes: 5,
                ..Default::default()
            },
        );
        let s = m.snapshot();
        assert_eq!(s.preemptions, 2);
        assert_eq!(s.resumes, 1);
        assert_eq!(s.recompute_tokens, 12);
        assert_eq!(s.prefix_lookups, 3);
        assert_eq!(s.prefix_hits, 2);
        assert_eq!(s.prefix_partial_hits, 1);
        assert_eq!(s.prefix_saved_tokens, 24);
        assert_eq!(s.prefix_trie_nodes, 5);
        assert!((s.prefix_hit_rate() - 2.0 / 3.0).abs() < 1e-12);
        let j = s.to_json();
        assert_eq!(j.get("prefix_partial_hits").as_usize(), Some(1));
        assert_eq!(j.get("prefix_saved_tokens").as_usize(), Some(24));
        assert_eq!(j.get("prefix_trie_nodes").as_usize(), Some(5));
    }

    #[test]
    fn decode_io_accounting_accumulates() {
        let m = Metrics::new();
        m.on_decode(0.010, 0.002, 1000);
        m.on_decode(0.0, 0.004, 500);
        let s = m.snapshot();
        assert_eq!(s.decode_steps, 2);
        assert!((s.gather_secs - 0.010).abs() < 1e-12);
        assert!((s.attend_secs - 0.006).abs() < 1e-12);
        assert_eq!(s.cache_bytes_read, 1500);
        assert!((s.cache_bytes_per_token() - 750.0).abs() < 1e-9);
        // Batched-wave accounting: bytes amortized into the same
        // cache_bytes_read stream, passes/dedup as their own gauges.
        m.on_mq_wave(8, 3, 250);
        let s2 = m.snapshot();
        assert_eq!(s2.mq_passes, 8);
        assert_eq!(s2.blocks_deduped, 3);
        assert_eq!(s2.cache_bytes_read, 1750);
        let j2 = s2.to_json();
        assert_eq!(j2.get("mq_passes").as_usize(), Some(8));
        assert_eq!(j2.get("blocks_deduped").as_usize(), Some(3));
        assert!((s.decode_ns_per_token() - 8e6).abs() < 1.0);
        let j = s.to_json();
        assert_eq!(j.get("decode_steps").as_usize(), Some(2));
        assert_eq!(j.get("cache_bytes_read").as_usize(), Some(1500));
        assert!(j.get("attend_secs").as_f64().unwrap() > 0.0);
        assert!(j.get("decode_ns_per_token").as_f64().is_some());
    }

    #[test]
    fn running_peak_is_high_water_mark() {
        let m = Metrics::new();
        let g = |running| StepGauges { running, ..Default::default() };
        m.on_step(0.01, g(3));
        m.on_step(0.01, g(7));
        m.on_step(0.01, g(2));
        let s = m.snapshot();
        assert_eq!(s.running, 2, "gauge is last step");
        assert_eq!(s.running_peak, 7, "peak sticks");
    }

    #[test]
    fn snapshot_serializes() {
        let m = Metrics::new();
        m.set_policy("k8v4");
        m.set_kernel_isa("avx2");
        m.on_step(
            0.01,
            StepGauges {
                running: 2,
                waiting: 3,
                preempted: 1,
                cache_utilization: 0.4,
                pool_used_blocks: 40,
                pool_total_blocks: 100,
                pool_logical_blocks: 52,
                prefix_cache_blocks: 8,
                cache_payload_bytes: [0, 4096, 2048],
                ..Default::default()
            },
        );
        let j = m.snapshot().to_json();
        assert_eq!(j.get("quant_policy").as_str(), Some("k8v4"));
        assert_eq!(j.get("kernel_isa").as_str(), Some("avx2"));
        assert_eq!(j.get("cache_bytes_fp32").as_usize(), Some(0));
        assert_eq!(j.get("cache_bytes_int8").as_usize(), Some(4096));
        assert_eq!(j.get("cache_bytes_int4").as_usize(), Some(2048));
        assert_eq!(j.get("running").as_usize(), Some(2));
        assert_eq!(j.get("waiting").as_usize(), Some(3));
        assert_eq!(j.get("preempted").as_usize(), Some(1));
        assert_eq!(j.get("pool_used_blocks").as_usize(), Some(40));
        assert_eq!(j.get("pool_total_blocks").as_usize(), Some(100));
        assert_eq!(j.get("pool_logical_blocks").as_usize(), Some(52));
        assert_eq!(j.get("prefix_cache_blocks").as_usize(), Some(8));
        assert_eq!(j.get("running_peak").as_usize(), Some(2));
        assert!(j.get("cache_utilization").as_f64().unwrap() > 0.39);
        assert!(j.get("prefix_hit_rate").as_f64().is_some());
    }

    #[test]
    fn tier_and_physical_gauges_serialize() {
        let m = Metrics::new();
        m.on_step(
            0.01,
            StepGauges {
                pool_used_blocks: 12,
                cache_payload_bytes: [0, 4096, 0],
                cache_physical_bytes: [0, 3072, 512],
                pool_physical_bytes: 6144,
                pool_fragmentation_bytes: 128,
                tier: TierStats {
                    demotions: 4,
                    promotions: 3,
                    prefetch_hits: 2,
                    prefetch_misses: 1,
                    cold_evictions: 1,
                    preemptions_avoided: 6,
                    snapshot_loaded: 5,
                    snapshot_rejected: 7,
                    decompress_errors: 9,
                    cold_entries: 2,
                    cold_blocks: 8,
                    cold_raw_bytes: 2048,
                    cold_comp_bytes: 512,
                    demote_secs: 0.001,
                    promote_secs: 0.002,
                    decompress_secs: 0.0005,
                },
                ..Default::default()
            },
        );
        let j = m.snapshot().to_json();
        // Legacy logical gauges stay pinned next to the physical view.
        assert_eq!(j.get("cache_bytes_int8").as_usize(), Some(4096));
        assert_eq!(j.get("cache_physical_bytes_int8").as_usize(), Some(3072));
        assert_eq!(j.get("cache_physical_bytes_int4").as_usize(), Some(512));
        assert_eq!(j.get("pool_physical_bytes").as_usize(), Some(6144));
        assert_eq!(j.get("pool_fragmentation_bytes").as_usize(), Some(128));
        assert_eq!(j.get("tier_hot_blocks").as_usize(), Some(12));
        assert_eq!(j.get("tier_cold_blocks").as_usize(), Some(8));
        assert_eq!(j.get("tier_cold_entries").as_usize(), Some(2));
        assert_eq!(j.get("tier_demotions").as_usize(), Some(4));
        assert_eq!(j.get("tier_promotions").as_usize(), Some(3));
        assert_eq!(j.get("tier_prefetch_hits").as_usize(), Some(2));
        assert_eq!(j.get("tier_prefetch_misses").as_usize(), Some(1));
        assert_eq!(j.get("tier_cold_evictions").as_usize(), Some(1));
        assert_eq!(j.get("tier_preemptions_avoided").as_usize(), Some(6));
        assert_eq!(j.get("tier_snapshot_loaded").as_usize(), Some(5));
        assert_eq!(j.get("tier_snapshot_rejected").as_usize(), Some(7));
        assert_eq!(j.get("tier_decompress_errors").as_usize(), Some(9));
        assert!((j.get("tier_compression_ratio").as_f64().unwrap() - 4.0).abs() < 1e-12);
        assert!(j.get("tier_demote_secs").as_f64().unwrap() > 0.0);
        assert!(j.get("tier_promote_secs").as_f64().unwrap() > 0.0);
        assert!(j.get("tier_decompress_secs").as_f64().unwrap() > 0.0);
    }

    #[test]
    fn depth_balances_over_all_terminations() {
        let m = Metrics::new();
        for _ in 0..9 {
            m.on_submit();
        }
        assert_eq!(m.depth(), 9);
        m.on_finish(0.1);
        m.on_reject();
        m.on_error();
        assert_eq!(m.depth(), 6);
        // Cancellations and shard failures are terminal too — every
        // submission books exactly one terminal event, whatever kind.
        m.on_deadline_cancel();
        m.on_stall_cancel();
        m.on_client_cancel();
        m.on_shard_failure(2);
        assert_eq!(m.depth(), 1);
        let s = m.snapshot();
        assert_eq!(s.requests_errored, 1);
        assert_eq!(s.deadline_cancels, 1);
        assert_eq!(s.stall_cancels, 1);
        assert_eq!(s.client_cancels, 1);
        assert_eq!(s.streams_failed, 2);
        let j = s.to_json();
        assert_eq!(j.get("deadline_cancels").as_usize(), Some(1));
        assert_eq!(j.get("stall_cancels").as_usize(), Some(1));
        assert_eq!(j.get("client_cancels").as_usize(), Some(1));
        assert_eq!(j.get("streams_failed").as_usize(), Some(2));
        // Termination booked before its submit is visible: saturate to 0.
        let m2 = Metrics::new();
        m2.on_finish(0.1);
        assert_eq!(m2.depth(), 0);
    }

    #[test]
    fn shared_across_clones() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.on_submit();
        assert_eq!(m.snapshot().requests_submitted, 1);
    }
}
