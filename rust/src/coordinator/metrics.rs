//! Serving metrics: counters + latency histograms, shared via a mutex
//! (engine thread writes, router/HTTP threads read snapshots).

use crate::util::stats::LogHistogram;
use std::sync::{Arc, Mutex};
use std::time::Instant;

#[derive(Debug)]
struct Inner {
    started: Instant,
    requests_submitted: u64,
    requests_finished: u64,
    requests_rejected: u64,
    tokens_generated: u64,
    prefill_tokens: u64,
    engine_steps: u64,
    ttft: LogHistogram,
    tpot: LogHistogram,
    e2e: LogHistogram,
    step_time: LogHistogram,
    cache_utilization: f64,
    running: usize,
    waiting: usize,
}

/// Cloneable handle.
#[derive(Clone)]
pub struct Metrics(Arc<Mutex<Inner>>);

impl Default for Metrics {
    fn default() -> Self {
        Self::new()
    }
}

impl Metrics {
    pub fn new() -> Metrics {
        Metrics(Arc::new(Mutex::new(Inner {
            started: Instant::now(),
            requests_submitted: 0,
            requests_finished: 0,
            requests_rejected: 0,
            tokens_generated: 0,
            prefill_tokens: 0,
            engine_steps: 0,
            ttft: LogHistogram::latency(),
            tpot: LogHistogram::latency(),
            e2e: LogHistogram::latency(),
            step_time: LogHistogram::latency(),
            cache_utilization: 0.0,
            running: 0,
            waiting: 0,
        })))
    }

    pub fn on_submit(&self) {
        self.0.lock().unwrap().requests_submitted += 1;
    }

    pub fn on_reject(&self) {
        self.0.lock().unwrap().requests_rejected += 1;
    }

    pub fn on_first_token(&self, ttft: f64, prefill_tokens: usize) {
        let mut m = self.0.lock().unwrap();
        m.ttft.record(ttft);
        m.prefill_tokens += prefill_tokens as u64;
        m.tokens_generated += 1;
    }

    pub fn on_token(&self, tpot: f64) {
        let mut m = self.0.lock().unwrap();
        m.tpot.record(tpot);
        m.tokens_generated += 1;
    }

    pub fn on_finish(&self, e2e: f64) {
        let mut m = self.0.lock().unwrap();
        m.e2e.record(e2e);
        m.requests_finished += 1;
    }

    pub fn on_step(&self, secs: f64, running: usize, waiting: usize, cache_util: f64) {
        let mut m = self.0.lock().unwrap();
        m.engine_steps += 1;
        m.step_time.record(secs);
        m.running = running;
        m.waiting = waiting;
        m.cache_utilization = cache_util;
    }

    pub fn snapshot(&self) -> MetricsSnapshot {
        let m = self.0.lock().unwrap();
        let uptime = m.started.elapsed().as_secs_f64();
        MetricsSnapshot {
            uptime,
            requests_submitted: m.requests_submitted,
            requests_finished: m.requests_finished,
            requests_rejected: m.requests_rejected,
            tokens_generated: m.tokens_generated,
            prefill_tokens: m.prefill_tokens,
            engine_steps: m.engine_steps,
            tokens_per_sec: m.tokens_generated as f64 / uptime.max(1e-9),
            ttft_p50: m.ttft.quantile(0.5),
            ttft_p99: m.ttft.quantile(0.99),
            tpot_p50: m.tpot.quantile(0.5),
            tpot_p99: m.tpot.quantile(0.99),
            e2e_p50: m.e2e.quantile(0.5),
            e2e_p99: m.e2e.quantile(0.99),
            step_p50: m.step_time.quantile(0.5),
            cache_utilization: m.cache_utilization,
            running: m.running,
            waiting: m.waiting,
        }
    }
}

/// Point-in-time view (JSON-serializable for the /metrics endpoint).
#[derive(Debug, Clone, PartialEq)]
pub struct MetricsSnapshot {
    pub uptime: f64,
    pub requests_submitted: u64,
    pub requests_finished: u64,
    pub requests_rejected: u64,
    pub tokens_generated: u64,
    pub prefill_tokens: u64,
    pub engine_steps: u64,
    pub tokens_per_sec: f64,
    pub ttft_p50: f64,
    pub ttft_p99: f64,
    pub tpot_p50: f64,
    pub tpot_p99: f64,
    pub e2e_p50: f64,
    pub e2e_p99: f64,
    pub step_p50: f64,
    pub cache_utilization: f64,
    pub running: usize,
    pub waiting: usize,
}

impl MetricsSnapshot {
    pub fn to_json(&self) -> crate::util::json::Json {
        use crate::util::json::obj;
        obj([
            ("uptime_s", self.uptime.into()),
            ("requests_submitted", (self.requests_submitted as usize).into()),
            ("requests_finished", (self.requests_finished as usize).into()),
            ("requests_rejected", (self.requests_rejected as usize).into()),
            ("tokens_generated", (self.tokens_generated as usize).into()),
            ("prefill_tokens", (self.prefill_tokens as usize).into()),
            ("engine_steps", (self.engine_steps as usize).into()),
            ("tokens_per_sec", self.tokens_per_sec.into()),
            ("ttft_p50_s", self.ttft_p50.into()),
            ("ttft_p99_s", self.ttft_p99.into()),
            ("tpot_p50_s", self.tpot_p50.into()),
            ("tpot_p99_s", self.tpot_p99.into()),
            ("e2e_p50_s", self.e2e_p50.into()),
            ("e2e_p99_s", self.e2e_p99.into()),
            ("step_p50_s", self.step_p50.into()),
            ("cache_utilization", self.cache_utilization.into()),
            ("running", self.running.into()),
            ("waiting", self.waiting.into()),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn counters_accumulate() {
        let m = Metrics::new();
        m.on_submit();
        m.on_submit();
        m.on_reject();
        m.on_first_token(0.1, 8);
        m.on_token(0.02);
        m.on_token(0.03);
        m.on_finish(0.5);
        let s = m.snapshot();
        assert_eq!(s.requests_submitted, 2);
        assert_eq!(s.requests_rejected, 1);
        assert_eq!(s.requests_finished, 1);
        assert_eq!(s.tokens_generated, 3);
        assert_eq!(s.prefill_tokens, 8);
        assert!(s.tokens_per_sec > 0.0);
    }

    #[test]
    fn snapshot_serializes() {
        let m = Metrics::new();
        m.on_step(0.01, 2, 3, 0.4);
        let j = m.snapshot().to_json();
        assert_eq!(j.get("running").as_usize(), Some(2));
        assert_eq!(j.get("waiting").as_usize(), Some(3));
        assert!(j.get("cache_utilization").as_f64().unwrap() > 0.39);
    }

    #[test]
    fn shared_across_clones() {
        let m = Metrics::new();
        let m2 = m.clone();
        m2.on_submit();
        assert_eq!(m.snapshot().requests_submitted, 1);
    }
}
