//! The engine: owns a model backend + KV-cache manager on a dedicated
//! thread and runs the continuous-batching step loop.
//!
//! Thread model: the PJRT runtime is not `Send`, so the backend is
//! constructed *inside* the engine thread from a `Send` factory closure.
//! The [`EngineHandle`] is cheap to clone and freely shareable (mpsc
//! sender + metrics handle).
//!
//! **Zero-copy paged decode.** Backends that support it (the CPU oracle;
//! PJRT artifacts consume dense buffers and cannot) decode straight over
//! a borrow-based [`crate::kvcache::CacheView`]: no per-token
//! materialization of the sequence's cache, dequantization fused into the
//! attention kernels (`attention_kernel` knob selects the access-pattern
//! variant — outputs are bit-identical across variants and vs the staged
//! path). Per-token cache traffic drops from O(L·H·max_seq·d) staging
//! copies to O(L·H·len·d) in-place reads, surfaced at `GET /metrics` as
//! `gather_secs`/`attend_secs`/`cache_bytes_read`. `paged_decode: false`
//! forces the legacy staged path (the e2e bench uses it for the
//! before/after decode ns/token comparison).
//!
//! **Decode waves.** With `parallelism > 1` the engine processes the
//! decode batch in waves: up to `parallelism` concurrent sequences have
//! their caches gathered into per-sequence staging slots *in parallel*
//! (the cache side of a staged decode step), then the backend — which is
//! thread-confined — consumes the slots serially. The cache manager's own
//! prefill/gather fan-out uses the same knob. Parallelism never changes
//! generated tokens: gathers are read-only and bit-deterministic, and the
//! backend execution order is unchanged. On the paged path the gather
//! phase is empty (there is nothing to copy), so waves reduce to the
//! serial backend loop.
//!
//! **Preemption + recompute.** Under optimistic admission the pool may
//! run dry mid-decode. The batcher names victims; the engine frees their
//! blocks and parks their full generation state (tokens, RNG, client
//! stream) on the preempted queue. Readmission rebuilds the cache by
//! re-running prefill on the prompt and *replaying* the already-generated
//! tokens through decode steps — scales are re-frozen over the identical
//! prompt and every replayed step is deterministic, so the rebuilt cache
//! and all subsequent tokens are bit-identical to an uncontended run
//! (asserted by `tests/preemption.rs`). A decode append that still fails
//! (plan raced reality) falls back in order: evict prefix-cache entries,
//! preempt a victim, finally preempt the appending sequence itself.
//!
//! **Prefix cache.** With `prefix_cache_blocks > 0`, finished prefills
//! are registered in a block-granular token trie ([`PrefixCache`]). An
//! identical prompt later forks the cached blocks (refcount bump, no
//! re-quantization, no backend prefill) and decodes from the stored
//! first-token logits; a prompt sharing only a block-aligned *prefix*
//! forks the shared span and runs suffix prefill from the first uncached
//! block. Chunk-capable backends (CPU) always prefill block-by-block
//! through [`LmBackend::prefill_chunk`] — cache hit or not — so cached
//! and uncached runs of the same prompt are byte-identical (asserted by
//! `tests/preemption.rs`); PJRT keeps whole-prompt prefill and
//! exact-match-only reuse.

use super::batcher::{Batcher, BatcherConfig, StepPlan};
use super::metrics::{Metrics, StepGauges};
use super::request::{EventTx, FinishReason, Request, RequestId, TokenEvent};
use super::scheduler::{Running, Scheduler};
use crate::kvcache::manager::{CacheConfig, KvCacheManager, SeqId};
use crate::kvcache::{ColdTier, PolicySpec, PrefixCache, PrefixHit, QuantPolicy, StagedKind};
use crate::model::runner::DecodeResult;
use crate::model::sample;
use crate::model::{BatchScratch, LmBackend};
use crate::parallel;
use crate::quant::simd::{Isa, KernelBackend};
use crate::quant::Variant;
use crate::util::rng::Rng;
use anyhow::Result;
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::mpsc;
use std::sync::Arc;
use std::time::Instant;

/// Engine configuration (cache + batching policy).
#[derive(Debug, Clone)]
pub struct EngineConfig {
    /// Cache storage precision policy: a uniform preset (the legacy
    /// `--precision` behavior), `k8v4`, `sink8[:N]`, or a JSON per-layer
    /// table. Resolved against the backend's model spec at init; any
    /// policy without a dense staging ABI (mixed precision, or INT4
    /// anywhere) requires a paged-decode-capable backend.
    pub quant_policy: PolicySpec,
    /// Cache pool size in blocks; None = size for `expected_concurrency`
    /// full-length sequences.
    pub num_blocks: Option<usize>,
    pub expected_concurrency: usize,
    pub scale_margin: f32,
    pub batcher: BatcherConfig,
    /// RNG seed space for per-request sampling.
    pub seed: u64,
    /// Worker count for the parallel quantization runtime (decode-wave
    /// gathers + cache prefill/gather fan-out). 0 = auto
    /// (`available_parallelism`, `KVQ_THREADS` override).
    pub parallelism: usize,
    /// Logical block budget of the cross-request prefix-cache trie
    /// (`0` disables prompt sharing — the default). The
    /// `KVQ_PREFIX_CACHE_BLOCKS` env var overrides the configured value
    /// (the CI cache-off job forces `0` this way).
    pub prefix_cache_blocks: usize,
    /// Fused dequant-attention kernel for the paged decode path
    /// (naive|tiled|coarsened|vectorized). Never changes outputs — all
    /// variants are bit-identical; it only selects the access pattern.
    pub attention_kernel: Variant,
    /// Attend directly over the paged cache when the backend supports it
    /// (default). `false` forces the legacy gather-into-staging path —
    /// kept for PJRT (which requires it regardless) and for before/after
    /// benchmarking.
    pub paged_decode: bool,
    /// Kernel backend for the host-side fused attention and cache
    /// encode/decode hot loops: `auto` (default) picks the best ISA the
    /// CPU reports (AVX2 / NEON), `scalar` forces the legacy kernels
    /// (bit-identical to pre-backend outputs), `simd` requests SIMD and
    /// degrades to scalar when the host has none. Resolved once at init;
    /// the selected ISA is reported at `GET /metrics` (`kernel_isa`).
    /// Same backend + same threads ⇒ byte-identical tokens; scalar vs
    /// SIMD may differ within f32 accumulation error (score-pass sum
    /// order — see `quant::simd`).
    pub kernel_backend: KernelBackend,
    /// Fused multi-query batched decode: `auto` (default) regroups every
    /// paged decode wave wider than one sequence into per-(layer, head)
    /// passes over the wave's deduped physical blocks — a COW-shared
    /// prefix block is dequantized once per wave. `off` keeps the legacy
    /// per-sequence walk. Never changes outputs: batched decode is
    /// byte-identical to the per-sequence path (same backend, same
    /// threads) — pinned by `tests/parallel_consistency.rs`. The
    /// `KVQ_DECODE_BATCHING` env var overrides the configured value.
    pub decode_batching: DecodeBatching,
    /// Compressed cold-tier capacity in blocks: `None` auto-sizes to the
    /// hot pool (`num_blocks`), `Some(0)` disables the tier. The tier is
    /// the prefix trie's second chance — LRU-cold cached prompts demote
    /// into a byte-shuffle + RLE compressed store instead of being
    /// destroyed, and promote back bit-identically — so it only engages
    /// when the prefix cache itself is enabled. The `KVQ_COLD_TIER` env
    /// var overrides (`off`/`0` forces it off for the CI tier-off
    /// reruns).
    pub cold_tier_blocks: Option<usize>,
    /// Persistent prefix snapshot path: on engine exit the hot trie is
    /// demoted into the cold tier and the whole tier is written here
    /// (versioned, checksummed); at startup the file is reloaded so the
    /// warmed corpus survives restarts. A missing, stale, or corrupt
    /// file is skipped with a warning, never an error.
    pub snapshot_path: Option<String>,
    /// Async prefetch ready-map depth: cold entries for the head of the
    /// waiting queue are decompressed on a background thread ahead of
    /// their prefill step. 0 disables the thread — promotions fall back
    /// to synchronous decompression.
    pub prefetch_depth: usize,
    /// Watchdog stall timeout in milliseconds (0 disables). A stream
    /// with no token progress past the timeout is logged once and the
    /// shard health flag flips to `stalled`; past 2× the timeout the
    /// stream is cancelled with [`FinishReason::Stalled`].
    pub stall_timeout_ms: u64,
}

/// The `decode_batching` knob (see [`EngineConfig::decode_batching`]).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum DecodeBatching {
    /// Batch paged decode waves through the fused multi-query path
    /// whenever the backend supports it and the wave has ≥ 2 members.
    Auto,
    /// Always walk the wave per sequence (the legacy path).
    Off,
}

impl DecodeBatching {
    pub fn parse(s: &str) -> Option<DecodeBatching> {
        match s {
            "auto" => Some(DecodeBatching::Auto),
            "off" => Some(DecodeBatching::Off),
            _ => None,
        }
    }

    pub fn name(self) -> &'static str {
        match self {
            DecodeBatching::Auto => "auto",
            DecodeBatching::Off => "off",
        }
    }

    /// Resolve the knob against the `KVQ_DECODE_BATCHING` env override
    /// (the CI legacy-path job forces `off` this way); an unparseable
    /// value is ignored with a one-time warning, mirroring
    /// [`KernelBackend::resolve`].
    pub fn resolve(self) -> DecodeBatching {
        let env = std::env::var("KVQ_DECODE_BATCHING").ok();
        if let Some(v) = env.as_deref() {
            match DecodeBatching::parse(v) {
                Some(b) => return b,
                None => {
                    static WARNED: std::sync::OnceLock<()> = std::sync::OnceLock::new();
                    WARNED.get_or_init(|| {
                        crate::warn!(
                            "ignoring unparseable KVQ_DECODE_BATCHING={v:?} \
                             (expected auto|off); using configured {}",
                            self.name()
                        );
                    });
                }
            }
        }
        self
    }
}

impl Default for EngineConfig {
    fn default() -> Self {
        EngineConfig {
            quant_policy: PolicySpec::uniform(crate::kvcache::Precision::Int8),
            num_blocks: None,
            expected_concurrency: 8,
            scale_margin: 1.0,
            batcher: BatcherConfig::default(),
            seed: 0,
            parallelism: 0,
            prefix_cache_blocks: 0,
            attention_kernel: Variant::Vectorized,
            paged_decode: true,
            kernel_backend: KernelBackend::Auto,
            decode_batching: DecodeBatching::Auto,
            cold_tier_blocks: None,
            snapshot_path: None,
            prefetch_depth: 2,
            stall_timeout_ms: 0,
        }
    }
}

/// Resolve the prefix-cache block budget against the
/// `KVQ_PREFIX_CACHE_BLOCKS` env override (the CI cache-off job forces
/// `0` this way to rerun the sharing suites without reuse); an
/// unparseable value is ignored with a one-time warning, mirroring
/// [`DecodeBatching::resolve`].
fn resolve_prefix_budget(cfg_blocks: usize) -> usize {
    let env = std::env::var("KVQ_PREFIX_CACHE_BLOCKS").ok();
    if let Some(v) = env.as_deref() {
        match v.parse::<usize>() {
            Ok(b) => return b,
            Err(_) => {
                static WARNED: std::sync::OnceLock<()> = std::sync::OnceLock::new();
                WARNED.get_or_init(|| {
                    crate::warn!(
                        "ignoring unparseable KVQ_PREFIX_CACHE_BLOCKS={v:?} \
                         (expected a block count); using configured {cfg_blocks}"
                    );
                });
            }
        }
    }
    cfg_blocks
}

/// Resolve the cold-tier block capacity against the `KVQ_COLD_TIER` env
/// override (the CI tier-off reruns force `off` this way): `off`/`0`
/// disables the tier, `on` keeps the configured capacity, a number sets
/// it. An unparseable value is ignored with a one-time warning,
/// mirroring [`resolve_prefix_budget`].
fn resolve_cold_tier(cfg_blocks: usize) -> usize {
    let env = std::env::var("KVQ_COLD_TIER").ok();
    if let Some(v) = env.as_deref() {
        match v {
            "off" => return 0,
            "on" => return cfg_blocks,
            _ => match v.parse::<usize>() {
                Ok(b) => return b,
                Err(_) => {
                    static WARNED: std::sync::OnceLock<()> = std::sync::OnceLock::new();
                    WARNED.get_or_init(|| {
                        crate::warn!(
                            "ignoring unparseable KVQ_COLD_TIER={v:?} \
                             (expected on|off|<blocks>); using configured {cfg_blocks}"
                        );
                    });
                }
            },
        }
    }
    cfg_blocks
}

enum EngineCmd {
    Submit(Request, EventTx),
    /// Consistency probe: verify cache refcounts and reply with an empty
    /// string (consistent) or the failure message.
    Check(mpsc::Sender<String>),
    /// Stop accepting, drain all work, then exit.
    Drain,
    /// Exit immediately after the current step.
    Shutdown,
}

/// In-flight client streams of one engine, shared between the step loop
/// and the panic handler wrapped around it: every accepted submission is
/// registered here and deregistered at its terminal event, so after a
/// panic the supervisor path can fail every survivor with a typed
/// [`FinishReason::ShardFailed`] instead of letting streams hang.
type StreamRegistry =
    std::sync::Arc<std::sync::Mutex<std::collections::HashMap<RequestId, EventTx>>>;

/// Lock a registry even when the panic that killed the engine poisoned it.
fn lock_registry(
    reg: &StreamRegistry,
) -> std::sync::MutexGuard<'_, std::collections::HashMap<RequestId, EventTx>> {
    reg.lock().unwrap_or_else(|e| e.into_inner())
}

/// Shard lifecycle state, written by the engine (ok/stalled), its panic
/// handler (dead), and the router's supervisor (restarting → ok).
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum ShardState {
    Ok = 0,
    Stalled = 1,
    Dead = 2,
    Restarting = 3,
}

impl ShardState {
    pub fn name(self) -> &'static str {
        match self {
            ShardState::Ok => "ok",
            ShardState::Stalled => "stalled",
            ShardState::Dead => "dead",
            ShardState::Restarting => "restarting",
        }
    }

    fn from_u8(v: u8) -> ShardState {
        match v {
            1 => ShardState::Stalled,
            2 => ShardState::Dead,
            3 => ShardState::Restarting,
            _ => ShardState::Ok,
        }
    }
}

/// Lock-free shard health flag shared by the engine thread, the router,
/// and the supervisor. Survives engine respawns (the supervisor hands
/// the same `Arc` to every incarnation).
#[derive(Debug, Default)]
pub struct ShardHealth {
    state: std::sync::atomic::AtomicU8,
    /// Times the supervisor respawned this shard's engine.
    pub restarts: AtomicU64,
}

impl ShardHealth {
    pub fn new() -> ShardHealth {
        ShardHealth::default()
    }

    pub fn set(&self, s: ShardState) {
        self.state.store(s as u8, Ordering::SeqCst);
    }

    pub fn get(&self) -> ShardState {
        ShardState::from_u8(self.state.load(Ordering::SeqCst))
    }
}

/// Cloneable handle to a running engine.
#[derive(Clone)]
pub struct EngineHandle {
    tx: mpsc::Sender<EngineCmd>,
    pub metrics: Metrics,
}

impl EngineHandle {
    pub fn submit(&self, req: Request, events: EventTx) -> Result<()> {
        self.metrics.on_submit();
        self.tx.send(EngineCmd::Submit(req, events)).map_err(|_| {
            // Balance the submit so depth() doesn't count a request the
            // engine will never see.
            self.metrics.on_reject();
            anyhow::anyhow!("engine is down")
        })
    }

    /// Live request depth: submissions not yet terminated, including
    /// work still queued in the command channel (see [`Metrics::depth`]).
    pub fn depth(&self) -> usize {
        self.metrics.depth()
    }

    /// Stop accepting and finish all queued/running work.
    pub fn drain(&self) {
        let _ = self.tx.send(EngineCmd::Drain);
    }

    pub fn shutdown(&self) {
        let _ = self.tx.send(EngineCmd::Shutdown);
    }

    /// Synchronous consistency probe: ask the engine thread to verify
    /// cache refcounts (pool refs vs block tables + pins). Errors when
    /// the engine is down, unresponsive, or the verification fails —
    /// the chaos suite runs this after cancellation churn to prove
    /// cancelled streams leak nothing.
    pub fn check(&self) -> Result<()> {
        let (tx, rx) = mpsc::channel();
        self.tx
            .send(EngineCmd::Check(tx))
            .map_err(|_| anyhow::anyhow!("engine is down"))?;
        let msg = rx
            .recv_timeout(std::time::Duration::from_secs(60))
            .map_err(|_| anyhow::anyhow!("engine did not answer consistency check"))?;
        if msg.is_empty() {
            Ok(())
        } else {
            anyhow::bail!("refcount check failed: {msg}")
        }
    }
}

/// Spawn an engine thread. `backend_factory` runs on the engine thread
/// (PJRT clients are thread-confined). Returns (handle, join handle).
pub fn spawn<F>(
    cfg: EngineConfig,
    backend_factory: F,
) -> (EngineHandle, std::thread::JoinHandle<()>)
where
    F: FnOnce() -> Result<Box<dyn LmBackend>> + Send + 'static,
{
    // Adapt the one-shot factory to the reusable-factory entry point
    // (`spawn` call sites build exactly one engine from it).
    let cell = std::sync::Mutex::new(Some(backend_factory));
    spawn_with(
        cfg,
        move || (cell.lock().unwrap().take().expect("backend factory already consumed"))(),
        Metrics::new(),
        Arc::new(ShardHealth::new()),
    )
}

/// [`spawn`] with caller-provided metrics and health state, the shard
/// supervisor's entry point: the factory is reusable (`Fn`) so the same
/// spawner can build every respawned incarnation, and metrics/health
/// survive across them (restart counts and terminal-event accounting
/// stay monotone).
///
/// The step loop runs under `catch_unwind`. On a panic — a backend bug,
/// a cache invariant trip, or an injected `panic` fault — the thread
/// fails every registered in-flight stream plus everything still queued
/// in the command channel with [`FinishReason::ShardFailed`], books them
/// as `streams_failed`, flips `health` to [`ShardState::Dead`], and
/// exits. No stream ever hangs on a dead shard.
pub fn spawn_with<F>(
    cfg: EngineConfig,
    backend_factory: F,
    metrics: Metrics,
    health: Arc<ShardHealth>,
) -> (EngineHandle, std::thread::JoinHandle<()>)
where
    F: Fn() -> Result<Box<dyn LmBackend>> + Send + 'static,
{
    let (tx, rx) = mpsc::channel();
    let m2 = metrics.clone();
    let join = std::thread::Builder::new()
        .name("kvq-engine".into())
        .spawn(move || {
            health.set(ShardState::Ok);
            let registry: StreamRegistry = Arc::default();
            // Fail fast: resolve the quantization policy against the
            // model spec and reject impossible configurations here instead
            // of failing every request at its first decode step. Only the
            // uniform int8/fp32 policies have a dense staging ABI — every
            // other policy (mixed precision, or INT4 anywhere) can only
            // serve through paged decode.
            let init = backend_factory().and_then(|b| {
                let spec = b.spec();
                let policy =
                    cfg.quant_policy.resolve(spec.layers, spec.heads, spec.head_dim)?;
                if policy.staged().is_none() && !(cfg.paged_decode && b.supports_paged_decode())
                {
                    anyhow::bail!(
                        "quant policy {} has no dense staging layout and requires a \
                         paged-decode-capable backend (cpu) with paged_decode enabled",
                        policy.name()
                    );
                }
                Ok((b, policy))
            });
            match init {
                Ok((backend, policy)) => {
                    let reg = Arc::clone(&registry);
                    let hlth = Arc::clone(&health);
                    let mtr = m2.clone();
                    // Borrow (not move) the receiver: after a panic the
                    // recovery path below still drains queued commands.
                    let rx_ref = &rx;
                    let run = std::panic::catch_unwind(std::panic::AssertUnwindSafe(move || {
                        Engine::new(cfg, policy, backend, mtr, reg, hlth).run(rx_ref)
                    }));
                    if run.is_err() {
                        health.set(ShardState::Dead);
                        let survivors: Vec<(RequestId, EventTx)> =
                            lock_registry(&registry).drain().collect();
                        let mut failed = survivors.len();
                        for (id, events) in survivors {
                            crate::debug!("failing in-flight stream {id}: shard died");
                            let _ = events.send(TokenEvent::Finished {
                                reason: FinishReason::ShardFailed,
                                tokens: 0,
                                elapsed: 0.0,
                            });
                        }
                        // Work still queued in the command channel was
                        // submitted (and counted) but never registered.
                        while let Ok(cmd) = rx.try_recv() {
                            match cmd {
                                EngineCmd::Submit(_req, events) => {
                                    failed += 1;
                                    let _ = events.send(TokenEvent::Finished {
                                        reason: FinishReason::ShardFailed,
                                        tokens: 0,
                                        elapsed: 0.0,
                                    });
                                }
                                EngineCmd::Check(reply) => {
                                    let _ = reply.send("shard died".into());
                                }
                                EngineCmd::Drain | EngineCmd::Shutdown => {}
                            }
                        }
                        m2.on_shard_failure(failed);
                        crate::error!(
                            "engine thread panicked; failed {failed} in-flight stream(s) \
                             with shard_failed"
                        );
                    }
                }
                Err(e) => {
                    crate::error!("engine backend init failed: {e:#}");
                    // Reject everything that arrives.
                    while let Ok(cmd) = rx.recv() {
                        match cmd {
                            EngineCmd::Submit(_req, events) => {
                                m2.on_reject();
                                let _ = events.send(TokenEvent::Finished {
                                    reason: FinishReason::Rejected(format!(
                                        "backend init failed: {e}"
                                    )),
                                    tokens: 0,
                                    elapsed: 0.0,
                                });
                            }
                            EngineCmd::Check(reply) => {
                                let _ = reply.send(format!("backend init failed: {e}"));
                            }
                            _ => break,
                        }
                    }
                }
            }
        })
        .expect("spawn engine thread");
    (EngineHandle { tx, metrics }, join)
}

/// Per-sequence decode staging: one slot per concurrently gathered
/// sequence in a decode wave. Reused across steps (no allocation on the
/// decode hot path once the wave width is reached).
struct StagingSlot {
    kq: Vec<i8>,
    vq: Vec<i8>,
    ks: Vec<f32>,
    vs: Vec<f32>,
    k32: Vec<f32>,
    v32: Vec<f32>,
    /// Wall-clock seconds this slot's gather took (parallel phase), so
    /// per-token (TPOT) metrics keep including the cache-read cost.
    gather_secs: f64,
    /// Gather error carried from the parallel phase into the serial one.
    err: Option<String>,
}

impl StagingSlot {
    fn new(kind: StagedKind, n: usize, ns: usize) -> StagingSlot {
        let is_int8 = kind == StagedKind::I8;
        StagingSlot {
            kq: if is_int8 { vec![0; n] } else { Vec::new() },
            vq: if is_int8 { vec![0; n] } else { Vec::new() },
            ks: vec![0.0; ns],
            vs: vec![0.0; ns],
            k32: if is_int8 { Vec::new() } else { vec![0.0; n] },
            v32: if is_int8 { Vec::new() } else { vec![0.0; n] },
            gather_secs: 0.0,
            err: None,
        }
    }
}

/// Gather one sequence's full cache (+ scales) into a staging slot.
/// `inner_threads` bounds the manager's own fan-out: waves wider than one
/// sequence pass 1 here so the two parallelism levels never multiply
/// (threads² oversubscription).
fn gather_sequence(
    cache: &KvCacheManager,
    kind: StagedKind,
    seq: SeqId,
    slot: &mut StagingSlot,
    inner_threads: usize,
) -> Result<()> {
    let c = cache.config();
    let (l, h, s, d) = (c.layers, c.heads, c.max_seq, c.head_dim);
    match kind {
        StagedKind::I8 => {
            let b = s.div_ceil(c.block_size);
            for li in 0..l {
                let span = li * h * s * d..(li + 1) * h * s * d;
                cache.gather_i8_with(seq, li, 0, &mut slot.kq[span.clone()], inner_threads)?;
                cache.gather_i8_with(seq, li, 1, &mut slot.vq[span], inner_threads)?;
                // Transpose the manager's block-major per-block scales
                // ([bi][head][ch]) into the staged ABI (L, H, B, d);
                // blocks past the sequence's length stay zero.
                let lbase = li * h * b * d;
                for (kv, dst) in [(0usize, &mut slot.ks), (1, &mut slot.vs)] {
                    let dst = &mut dst[lbase..lbase + h * b * d];
                    dst.fill(0.0);
                    let src = cache.scales(seq, li, kv)?;
                    for bi in 0..src.len() / (h * d) {
                        for head in 0..h {
                            let so = (bi * h + head) * d;
                            let go = (head * b + bi) * d;
                            dst[go..go + d].copy_from_slice(&src[so..so + d]);
                        }
                    }
                }
            }
        }
        StagedKind::F32 => {
            for li in 0..l {
                let span = li * h * s * d..(li + 1) * h * s * d;
                cache.gather_f32_with(seq, li, 0, &mut slot.k32[span.clone()], inner_threads)?;
                cache.gather_f32_with(seq, li, 1, &mut slot.v32[span], inner_threads)?;
            }
        }
    }
    Ok(())
}

struct Engine {
    backend: Box<dyn LmBackend>,
    cache: KvCacheManager,
    /// Dense staging ABI the policy is compatible with (None ⇒ the
    /// policy can only decode over the paged layout; spawn() guarantees
    /// a paged-capable backend in that case).
    staged_kind: Option<StagedKind>,
    prefix: PrefixCache,
    /// Compressed cold tier: demotion sink for LRU-cold prefix entries,
    /// promotion source for repeat prompts, snapshot persistence.
    tier: ColdTier,
    sched: Scheduler,
    batcher: Batcher,
    cfg: EngineConfig,
    metrics: Metrics,
    /// Resolved worker count (>= 1) = decode wave width.
    threads: usize,
    /// Staging slots; grows lazily up to `threads` entries. Empty on the
    /// paged path — zero-copy decode needs no staging.
    staging: Vec<StagingSlot>,
    /// Zero-copy paged decode resolved against the backend's capability.
    paged: bool,
    /// Bytes one staged decode copies out of the pool (payload + scales)
    /// — the O(max_seq) volume the paged path eliminates.
    staged_cache_bytes: usize,
    /// Resolved kernel ISA (`cfg.kernel_backend` + `KVQ_KERNEL_BACKEND`
    /// env override against the host's CPU features).
    isa: Isa,
    /// Fused multi-query batched decode resolved against the knob
    /// (`cfg.decode_batching` + `KVQ_DECODE_BATCHING` env override) and
    /// the backend's capability. Engages on paged waves of ≥ 2 members.
    batching: bool,
    /// Reusable wave-level arenas for the batched path — the multi-query
    /// analog of the staging-slot reuse above: grown once, then no
    /// allocation per (layer, head) pass on the decode hot path.
    batch_scratch: BatchScratch,
    /// In-flight client streams, shared with the panic handler in
    /// [`spawn_with`]: registered at submit, removed at every terminal
    /// event, drained (→ `ShardFailed`) after a panic.
    registry: StreamRegistry,
    /// Shard health flag (ok/stalled here; dead/restarting are written
    /// by the panic handler and the supervisor).
    health: Arc<ShardHealth>,
}

/// Per-request sampling RNG, derived statelessly from the engine seed,
/// the request's sampling seed, and the prompt tokens — never from
/// mutable engine RNG state, the request id, or arrival order. This is
/// the cross-shard determinism contract: the same (engine seed, prompt,
/// sampling) produces the same token stream on any shard of any shard
/// count, so 1-shard and N-shard runs of an affinity-pinned trace are
/// byte-identical (pinned by tests/routing.rs).
fn request_rng(engine_seed: u64, req: &Request) -> Rng {
    // FNV-1a over the prompt, then mix in the sampling seed.
    let mut h = 0xcbf2_9ce4_8422_2325u64;
    for &t in &req.prompt {
        h = (h ^ (t as u32 as u64)).wrapping_mul(0x100_0000_01b3);
    }
    h = (h ^ req.sampling.seed).wrapping_mul(0x100_0000_01b3);
    Rng::new(engine_seed ^ 0xE46 ^ h)
}

impl Engine {
    fn new(
        cfg: EngineConfig,
        policy: QuantPolicy,
        backend: Box<dyn LmBackend>,
        metrics: Metrics,
        registry: StreamRegistry,
        health: Arc<ShardHealth>,
    ) -> Engine {
        let spec = backend.spec().clone();
        let blocks_per_seq = 2 * spec.layers * spec.max_seq.div_ceil(spec.block_size);
        let num_blocks =
            cfg.num_blocks.unwrap_or(blocks_per_seq * cfg.expected_concurrency.max(1));
        let staged_kind = policy.staged();
        // Bytes one staged decode step copies: both K and V payloads at
        // full max_seq stride plus both per-block scale tensors
        // (L, H, B, d) — per-row accounting through the policy, identical
        // to the legacy per-precision formula for the uniform
        // staging-capable policies.
        let scale_blocks = spec.max_seq.div_ceil(spec.block_size);
        let staged_cache_bytes = (policy.payload_bytes(spec.head_dim, spec.max_seq)
            + 2 * (spec.layers * spec.heads * scale_blocks * spec.head_dim * 4) as u64)
            as usize;
        let policy_name = policy.name().to_string();
        let mut cache = KvCacheManager::new(
            CacheConfig {
                layers: spec.layers,
                heads: spec.heads,
                head_dim: spec.head_dim,
                max_seq: spec.max_seq,
                block_size: spec.block_size,
                num_blocks,
                scale_margin: cfg.scale_margin,
            },
            policy,
        );
        let threads = parallel::resolve(cfg.parallelism);
        cache.set_parallelism(threads);
        let isa = cfg.kernel_backend.resolve();
        cache.set_kernel_isa(isa);
        let n = spec.layers * spec.heads * spec.max_seq * spec.head_dim;
        let ns = spec.layers * spec.heads * scale_blocks * spec.head_dim;
        let paged = cfg.paged_decode && backend.supports_paged_decode();
        let batching = cfg.decode_batching.resolve() == DecodeBatching::Auto
            && paged
            && backend.supports_batched_decode();
        metrics.set_policy(&policy_name);
        metrics.set_kernel_isa(isa.name());
        let prefix_budget = resolve_prefix_budget(cfg.prefix_cache_blocks);
        // The cold tier backstops the prefix trie — without prompt
        // sharing there is nothing to demote, so it stays off.
        let cold_blocks = if prefix_budget == 0 {
            0
        } else {
            resolve_cold_tier(cfg.cold_tier_blocks.unwrap_or(num_blocks))
        };
        crate::info!(
            "engine up: model={} policy={} blocks={} cache={:.1} MiB threads={} \
             admission={} prefix_cache_blocks={} cold_tier_blocks={} decode={} kernel={} \
             backend={} isa={} batching={}",
            spec.name,
            policy_name,
            num_blocks,
            cache.storage_bytes() as f64 / (1024.0 * 1024.0),
            threads,
            cfg.batcher.admission.mode.name(),
            cfg.prefix_cache_blocks,
            cold_blocks,
            if paged { "paged" } else { "staged" },
            cfg.attention_kernel.name(),
            cfg.kernel_backend.name(),
            isa.name(),
            if batching { "mq" } else { "off" }
        );
        let mut prefix = PrefixCache::new(prefix_budget);
        // Partial hits require a suffix prefill; backends that can only
        // run whole-prompt prefill (PJRT) keep exact-match-only reuse.
        prefix.set_allow_partial(backend.supports_chunked_prefill());
        let mut tier = ColdTier::new(cold_blocks, cfg.prefetch_depth);
        if let Some(path) = cfg.snapshot_path.as_deref() {
            match tier.load_snapshot(std::path::Path::new(path), &cache) {
                Ok(0) => {}
                Ok(n) => crate::info!("snapshot: restored {n} cold prefix entries from {path}"),
                Err(e) => crate::warn!("snapshot load failed ({path}): {e:#}"),
            }
        }
        Engine {
            backend,
            cache,
            staged_kind,
            prefix,
            tier,
            sched: Scheduler::new(),
            batcher: Batcher::new(),
            metrics,
            threads,
            // Paged decode reads blocks in place; only the staged path
            // preallocates dense staging (spawn() guarantees staged_kind
            // exists whenever paged decode is unavailable).
            staging: match (paged, staged_kind) {
                (false, Some(kind)) => vec![StagingSlot::new(kind, n, ns)],
                _ => Vec::new(),
            },
            paged,
            staged_cache_bytes,
            isa,
            batching,
            batch_scratch: BatchScratch::new(),
            registry,
            health,
            cfg,
        }
    }

    fn run(mut self, rx: &mpsc::Receiver<EngineCmd>) {
        let mut draining = false;
        loop {
            // Ingest commands: block when idle (nothing to step), else drain
            // whatever has arrived without blocking.
            if self.sched.is_idle() {
                if draining {
                    break;
                }
                match rx.recv() {
                    Ok(cmd) => {
                        if self.handle(cmd, &mut draining) {
                            break;
                        }
                    }
                    Err(_) => break,
                }
            }
            let mut hard_stop = false;
            while let Ok(cmd) = rx.try_recv() {
                if self.handle(cmd, &mut draining) {
                    hard_stop = true;
                    break;
                }
            }
            if hard_stop {
                break;
            }
            if !self.sched.is_idle() {
                self.step();
            }
        }
        self.save_snapshot();
        crate::info!("engine exiting ({} steps)", self.metrics.snapshot().engine_steps);
    }

    /// Persist the warmed prefix corpus at exit: demote the entire hot
    /// trie into the cold tier, then write the versioned snapshot. A
    /// failed write warns and exits anyway — snapshots are a warm-start
    /// optimization, never a durability contract.
    fn save_snapshot(&mut self) {
        let Some(path) = self.cfg.snapshot_path.clone() else { return };
        if !self.tier.enabled() {
            return;
        }
        for cap in self.prefix.capture_all(&self.cache) {
            self.tier.admit(&cap, &self.cache);
        }
        match self.tier.save_snapshot(std::path::Path::new(&path), &self.cache) {
            Ok(n) => crate::info!("snapshot: wrote {n} prefix entries to {path}"),
            Err(e) => crate::warn!("snapshot save failed ({path}): {e:#}"),
        }
    }

    /// Returns true on hard shutdown.
    fn handle(&mut self, cmd: EngineCmd, draining: &mut bool) -> bool {
        match cmd {
            EngineCmd::Submit(req, events) => {
                if *draining {
                    self.metrics.on_reject();
                    let _ = events.send(TokenEvent::Finished {
                        reason: FinishReason::Rejected("engine draining".into()),
                        tokens: 0,
                        elapsed: 0.0,
                    });
                } else {
                    lock_registry(&self.registry).insert(req.id, events.clone());
                    self.sched.enqueue(req, events);
                }
                false
            }
            EngineCmd::Check(reply) => {
                // The assert panics on inconsistency; answer the probe
                // with the message instead of dying (a failed probe is a
                // finding, not a fault).
                let res = std::panic::catch_unwind(std::panic::AssertUnwindSafe(|| {
                    self.cache.assert_refcounts_consistent()
                }));
                let msg = match res {
                    Ok(()) => String::new(),
                    Err(p) => p
                        .downcast_ref::<String>()
                        .cloned()
                        .or_else(|| p.downcast_ref::<&str>().map(|s| s.to_string()))
                        .unwrap_or_else(|| "refcount assertion failed".into()),
                };
                let _ = reply.send(msg);
                false
            }
            EngineCmd::Drain => {
                *draining = true;
                false
            }
            EngineCmd::Shutdown => true,
        }
    }

    /// Deregister a stream and send its terminal event. Every terminal
    /// path must route through this (or remove from the registry itself)
    /// so the panic handler never double-finishes a stream.
    fn finish_stream(
        &self,
        id: RequestId,
        events: &EventTx,
        reason: FinishReason,
        tokens: usize,
        elapsed: f64,
    ) {
        lock_registry(&self.registry).remove(&id);
        let _ = events.send(TokenEvent::Finished { reason, tokens, elapsed });
    }

    /// Cancel every expired request — waiting, preempted, or running —
    /// freeing cache blocks and booking `deadline_cancels`. Runs at the
    /// top of each step so an expired stream never gets another token.
    fn expire_deadlines(&mut self) {
        let now = Instant::now();
        for (req, events) in self.sched.take_expired_waiting(now) {
            self.metrics.on_deadline_cancel();
            let elapsed = req.arrival.elapsed().as_secs_f64();
            self.finish_stream(req.id, &events, FinishReason::DeadlineExceeded, 0, elapsed);
        }
        let expired: Vec<RequestId> = self
            .sched
            .preempted
            .iter()
            .chain(self.sched.running.iter())
            .filter(|r| r.req.deadline_expired(now))
            .map(|r| r.req.id)
            .collect();
        for id in expired {
            self.cancel_request(id, FinishReason::DeadlineExceeded);
            self.metrics.on_deadline_cancel();
        }
    }

    /// Remove a running or preempted request mid-flight, free its cache
    /// blocks, and send `reason`. The cancellation paths (deadline,
    /// stall, client drop) all land here; metrics are booked by the
    /// caller (each path has its own counter).
    fn cancel_request(&mut self, id: RequestId, reason: FinishReason) {
        let run = match self.sched.finish(id) {
            Some(run) => {
                self.cache.free(run.seq);
                run
            }
            None => {
                let Some(idx) = self.sched.preempted.iter().position(|r| r.req.id == id)
                else {
                    return;
                };
                // Preempted state holds no cache blocks (seq is stale).
                self.sched.preempted.remove(idx).unwrap()
            }
        };
        crate::debug!("cancel {} ({}): generated {}", id, reason.label(), run.generated);
        let elapsed = run.req.arrival.elapsed().as_secs_f64();
        self.finish_stream(id, &run.events, reason, run.generated, elapsed);
    }

    /// Watchdog: escalate streams with no token progress past the stall
    /// timeout — warn once and flip shard health to `stalled`, then past
    /// 2× the timeout cancel with [`FinishReason::Stalled`]. Watches
    /// running *and* preempted streams (a readmission livelock is
    /// exactly the stall this exists to catch).
    fn watchdog(&mut self) {
        let timeout = self.cfg.stall_timeout_ms;
        if timeout == 0 {
            return;
        }
        let now = Instant::now();
        let mut cancels: Vec<RequestId> = Vec::new();
        let mut any_stalled = false;
        for run in self.sched.running.iter_mut().chain(self.sched.preempted.iter_mut()) {
            let stalled_ms =
                now.saturating_duration_since(run.last_progress).as_millis() as u64;
            if stalled_ms >= 2 * timeout {
                cancels.push(run.req.id);
            } else if stalled_ms >= timeout {
                any_stalled = true;
                if !run.stall_warned {
                    run.stall_warned = true;
                    crate::warn!(
                        "watchdog: stream {} has made no progress for {stalled_ms}ms",
                        run.req.id
                    );
                }
            }
        }
        for id in cancels {
            crate::warn!("watchdog: cancelling stalled stream {id}");
            self.cancel_request(id, FinishReason::Stalled);
            self.metrics.on_stall_cancel();
        }
        match (any_stalled, self.health.get()) {
            (true, ShardState::Ok) => self.health.set(ShardState::Stalled),
            (false, ShardState::Stalled) => self.health.set(ShardState::Ok),
            _ => {}
        }
    }

    fn step(&mut self) {
        let t0 = Instant::now();
        // Cancellation sweep first: an expired or stalled stream must
        // not receive another token or hold blocks through the plan.
        self.expire_deadlines();
        self.watchdog();
        // Stage likely-next promotions: ask the prefetch thread to
        // decompress cold entries for the head of the waiting queue
        // before their prefill step arrives.
        if self.tier.enabled() {
            for req in self.sched.iter_waiting().take(self.tier.prefetch_depth()) {
                self.tier.request_prefetch(&req.prompt);
            }
        }
        let prefix_evictable = self.prefix.evictable_bytes(&self.cache);
        let plan: StepPlan =
            self.batcher.plan(&self.cfg.batcher, &mut self.sched, &self.cache, prefix_evictable);

        for (req, events, cause) in plan.rejections {
            self.metrics.on_reject();
            crate::debug!("reject {}: {}", req.id, cause);
            let elapsed = req.arrival.elapsed().as_secs_f64();
            self.finish_stream(req.id, &events, FinishReason::Rejected(cause), 0, elapsed);
        }

        // Reclaim in plan order: cold-tier demotions first (cached
        // prompts survive compressed, promotable without recompute),
        // plain prefix evictions as the fallback when the tier is off or
        // full coverage wasn't reached, preemptions last (they cost
        // their victims a replay).
        if plan.want_free > 0 {
            self.tier.demote_for(&mut self.prefix, &mut self.cache, plan.want_free);
            self.prefix.evict_for_bytes(&mut self.cache, plan.want_free);
        }
        for id in plan.preemptions {
            self.preempt_request(id);
        }

        for run in plan.resumes {
            self.resume(run);
        }

        for (req, events) in plan.prefills {
            if let Err(e) = self.prefill(req, events) {
                crate::error!("prefill failed: {e:#}");
            }
        }

        // Decode pass, in waves of `threads`: cache gathers run in
        // parallel across the wave, backend execution stays serial (the
        // PJRT runtime is thread-confined). Ids preempted mid-step drop
        // out via the by-id lookup inside the wave.
        let ids = plan.decodes;
        for wave in ids.chunks(self.threads.max(1)) {
            self.decode_wave(wave);
        }

        let pstats = self.prefix.stats();
        self.metrics.on_step(
            t0.elapsed().as_secs_f64(),
            StepGauges {
                running: self.sched.running_len(),
                waiting: self.sched.waiting_len(),
                preempted: self.sched.preempted_len(),
                cache_utilization: self.cache.utilization(),
                pool_used_blocks: self.cache.used_blocks(),
                pool_total_blocks: self.cache.num_blocks(),
                pool_logical_blocks: self.cache.logical_blocks(),
                prefix_cache_blocks: self.prefix.pinned_blocks(),
                prefix_lookups: pstats.lookups,
                prefix_hits: pstats.hits,
                prefix_partial_hits: pstats.partial_hits,
                prefix_saved_tokens: pstats.saved_tokens,
                prefix_trie_nodes: self.prefix.trie_nodes() as u64,
                cache_payload_bytes: self.cache.payload_bytes_by_precision(),
                cache_physical_bytes: self.cache.physical_bytes_by_precision(),
                pool_physical_bytes: self.cache.pool_physical_bytes(),
                pool_fragmentation_bytes: self.cache.fragmentation_bytes(),
                tier: self.tier.stats(),
            },
        );
    }

    /// Materialize a prompt in the cache: full prefix-cache hit (fork
    /// shared blocks, no backend compute), partial hit (fork the shared
    /// block-aligned span, suffix-prefill the rest), or full prefill +
    /// cache registration. Returns the sequence, the prompt's
    /// last-position logits, and how many prompt tokens the backend
    /// actually computed (0 for a full hit) — callers book
    /// prefill/recompute work from that count, never the prompt length.
    fn materialize_prompt(&mut self, prompt: &[i32]) -> Result<(SeqId, Vec<f32>, usize)> {
        let len = prompt.len();
        match self.prefix.lookup(&mut self.cache, prompt) {
            Some(PrefixHit::Full { seq, logits }) => return Ok((seq, logits, 0)),
            Some(PrefixHit::Partial { seq, matched_tokens }) => {
                // An exact cold-tier entry beats the suffix prefill: zero
                // backend compute instead of `len - matched`. Promote it
                // (bit-identical blocks), release the partial fork, and
                // re-pin the promoted sequence in the trie.
                if self.tier.contains(prompt) {
                    if let Some((pseq, logits)) = self.tier.promote(&mut self.cache, prompt) {
                        self.cache.free(seq);
                        self.prefix.insert(&mut self.cache, pseq, prompt, &logits);
                        return Ok((pseq, logits, 0));
                    }
                }
                // Suffix prefill over the adopted span. Partial hits are
                // only returned when the backend can chunk (see new()).
                return match self.prefill_chunks(seq, prompt, matched_tokens) {
                    Ok(logits) => {
                        self.prefix.insert(&mut self.cache, seq, prompt, &logits);
                        Ok((seq, logits, len - matched_tokens))
                    }
                    Err(e) => {
                        self.cache.free(seq);
                        Err(e)
                    }
                };
            }
            None => {
                // Full trie miss: an exact-match cold entry restores the
                // whole prompt without backend compute. Re-pinning it in
                // the trie also revives partial-hit coverage for its
                // descendants.
                if let Some((seq, logits)) = self.tier.promote(&mut self.cache, prompt) {
                    self.prefix.insert(&mut self.cache, seq, prompt, &logits);
                    return Ok((seq, logits, 0));
                }
            }
        }
        if self.backend.supports_chunked_prefill() {
            // Chunk-capable backends ALWAYS prefill block-by-block, cache
            // hit or not, so partial-hit runs are byte-identical to
            // uncached runs of the same prompt.
            let seq = self.cache.new_sequence();
            return match self.prefill_chunks(seq, prompt, 0) {
                Ok(logits) => {
                    self.prefix.insert(&mut self.cache, seq, prompt, &logits);
                    Ok((seq, logits, len))
                }
                Err(e) => {
                    self.cache.free(seq);
                    Err(e)
                }
            };
        }
        let pre = self.backend.prefill(prompt, len)?;
        let seq = self.cache.new_sequence();
        if let Err(e) = self.cache.set_prefill(seq, &pre.k, &pre.v, len) {
            self.cache.free(seq);
            return Err(e);
        }
        self.prefix.insert(&mut self.cache, seq, prompt, &pre.logits);
        Ok((seq, pre.logits, len))
    }

    /// Block-sized chunked prefill of `prompt[start..]` into `seq` (rows
    /// `0..start` must already be cached; `start` must be block-aligned).
    /// Each chunk attends over the quantized history through a cache
    /// view, then its quantize-and-append freezes the chunk's own
    /// per-block scale grids — identical expressions to `set_prefill`.
    /// Returns the last chunk's last-position logits.
    fn prefill_chunks(&mut self, seq: SeqId, prompt: &[i32], start: usize) -> Result<Vec<f32>> {
        let bs = self.cache.config().block_size;
        debug_assert_eq!(start % bs, 0, "suffix prefill must start on a block boundary");
        let mut logits = Vec::new();
        let mut at = start;
        while at < prompt.len() {
            let end = prompt.len().min(at + bs);
            let res = {
                let view = self.cache.view(seq)?;
                self.backend.prefill_chunk(
                    &prompt[at..end],
                    at,
                    &view,
                    self.cfg.attention_kernel,
                    self.isa,
                )?
            };
            self.cache.append_prefill_chunk(seq, &res.k, &res.v, end - at)?;
            logits = res.logits;
            at = end;
        }
        Ok(logits)
    }

    fn prefill(&mut self, req: Request, events: EventTx) -> Result<()> {
        // Vocabulary validation (the admission layer has no model spec).
        let vocab = self.backend.spec().vocab as i32;
        if let Some(&bad) = req.prompt.iter().find(|&&t| t < 0 || t >= vocab) {
            self.metrics.on_reject();
            let elapsed = req.arrival.elapsed().as_secs_f64();
            self.finish_stream(
                req.id,
                &events,
                FinishReason::Rejected(format!("token {bad} outside vocab {vocab}")),
                0,
                elapsed,
            );
            return Ok(());
        }
        let prompt = req.prompt.clone();
        let materialized = crate::util::fault::hit("prefill")
            .and_then(|()| self.materialize_prompt(&prompt));
        let (seq, logits, computed) = match materialized {
            Ok(x) => x,
            Err(e) => {
                // A failed prefill is a terminal, typed event — the
                // stream must never hang waiting for a first token.
                self.metrics.on_error();
                let elapsed = req.arrival.elapsed().as_secs_f64();
                self.finish_stream(
                    req.id,
                    &events,
                    FinishReason::Error(format!("prefill failed: {e}")),
                    0,
                    elapsed,
                );
                return Err(e);
            }
        };
        let mut rng = request_rng(self.cfg.seed, &req);
        let token = sample::sample(&logits, &req.sampling, &mut rng);
        let ttft = req.arrival.elapsed().as_secs_f64();
        // prefill_tokens counts backend prefill work; prefix-cache hits
        // (full or the matched span of a partial) did none.
        self.metrics.on_first_token(ttft, computed);
        if events.send(TokenEvent::First { token, ttft }).is_err() {
            // Client receiver dropped before its first token: cancel
            // instead of decoding into the void.
            crate::debug!("client dropped stream {} before first token", req.id);
            self.metrics.on_client_cancel();
            lock_registry(&self.registry).remove(&req.id);
            self.cache.free(seq);
            return Ok(());
        }

        let admitted_seq = self.sched.next_admission_stamp();
        let mut running = Running {
            req,
            seq,
            last_token: token,
            generated: 1,
            tokens: vec![token],
            rng,
            first_token_at: Some(Instant::now()),
            admitted_seq,
            last_progress: Instant::now(),
            stall_warned: false,
            events,
        };
        if let Some(reason) = finish_reason(&running, self.cache.config().max_seq) {
            self.finalize(&mut running, reason);
            self.cache.free(seq);
            return Ok(());
        }
        self.sched.start(running);
        Ok(())
    }

    /// Preempt a running request: free its cache blocks and park its
    /// generation state for recompute-on-readmission.
    fn preempt_request(&mut self, id: RequestId) {
        if let Some(mut run) = self.sched.finish(id) {
            crate::debug!(
                "preempt {} (generated {}, freeing {} blocks)",
                id,
                run.generated,
                self.cache.seq_reclaimable_blocks(run.seq)
            );
            self.cache.free(run.seq);
            run.seq = 0; // stale until readmission
            self.metrics.on_preempt();
            self.sched.park_preempted(run);
        }
    }

    /// Readmit a preempted request: rebuild the prompt cache (prefix hit
    /// or full prefill — identical scales either way), then replay the
    /// generated-token trail through decode steps. Every replayed step
    /// recreates the exact bytes of the original run; its logits are
    /// discarded (those tokens were already sampled and streamed).
    fn resume(&mut self, mut run: Running) {
        let prompt = run.req.prompt.clone();
        let (seq, _logits, computed) = match self.materialize_prompt(&prompt) {
            Ok(x) => x,
            Err(e) => {
                crate::error!("resume prefill failed for {}: {e:#}", run.req.id);
                self.finalize(&mut run, FinishReason::Error(format!("resume failed: {e}")));
                return;
            }
        };
        let replay: Vec<i32> = run.tokens[..run.generated - 1].to_vec();
        for (i, &tok) in replay.iter().enumerate() {
            let pos = prompt.len() + i;
            if let Err(e) = self.replay_one(seq, tok, pos) {
                // Raced another allocator — back on the preempted queue
                // with state intact; a later step retries.
                crate::debug!("resume replay deferred for {}: {e:#}", run.req.id);
                self.cache.free(seq);
                self.sched.preempted.push_front(run);
                return;
            }
        }
        // recompute_tokens = rows actually re-materialized by the backend:
        // prefix-cache-served prompt spans cost nothing, replayed rows
        // always do.
        self.metrics.on_resume(computed + replay.len());
        run.seq = seq;
        run.admitted_seq = self.sched.next_admission_stamp();
        run.last_progress = Instant::now();
        run.stall_warned = false;
        self.sched.start(run);
    }

    /// One replayed decode step: execute with the known next token,
    /// append its K/V row. Paged backends attend in place; the staged
    /// path uses staging slot 0 (replay runs in the serial phase, never
    /// concurrently with a wave). Cache I/O is booked like any decode.
    fn replay_one(&mut self, seq: SeqId, token: i32, pos: usize) -> Result<()> {
        if self.paged {
            let attend_t0 = Instant::now();
            let (dec, bytes) = {
                let view = self.cache.view(seq)?;
                let bytes = view.attention_bytes();
                (
                    self.backend.decode_paged(
                        token,
                        pos,
                        &view,
                        self.cfg.attention_kernel,
                        self.isa,
                    )?,
                    bytes,
                )
            };
            self.metrics.on_decode(0.0, attend_t0.elapsed().as_secs_f64(), bytes);
            return self.cache.append_row(seq, &dec.k_new, &dec.v_new);
        }
        let kind = self.staged_kind.expect("staged decode without a dense staging ABI");
        let gather_t0 = Instant::now();
        {
            let slot = &mut self.staging[0];
            slot.err = None;
            gather_sequence(&self.cache, kind, seq, slot, self.threads)?;
        }
        let gather_secs = gather_t0.elapsed().as_secs_f64();
        let attend_t0 = Instant::now();
        let dec = match kind {
            StagedKind::I8 => {
                let st = &self.staging[0];
                self.backend.decode_i8(token, pos, &st.kq, &st.ks, &st.vq, &st.vs, self.isa)?
            }
            StagedKind::F32 => {
                let st = &self.staging[0];
                self.backend.decode_f32(token, pos, &st.k32, &st.v32, self.isa)?
            }
        };
        self.metrics.on_decode(
            gather_secs,
            attend_t0.elapsed().as_secs_f64(),
            self.staged_cache_bytes,
        );
        self.cache.append_row(seq, &dec.k_new, &dec.v_new)
    }

    /// Decode a wave of concurrent sequences. Staged path: parallel
    /// gather phase into per-sequence staging slots, then serial backend
    /// execution. Paged path: no gather phase at all — the backend
    /// attends over each sequence's blocks in place, serially.
    fn decode_wave(&mut self, wave: &[u64]) {
        // Resolve (id, seq, token, pos) for every still-running member.
        let metas: Vec<(u64, SeqId, i32, usize)> = wave
            .iter()
            .filter_map(|&id| {
                self.sched.running.iter().find(|r| r.req.id == id).map(|r| {
                    (id, r.seq, r.last_token, self.cache.seq_len(r.seq).unwrap_or(0))
                })
            })
            .collect();
        if metas.is_empty() {
            return;
        }
        // Injected wave fault: `error` fails every member typed (a
        // backend-wide decode failure), `delay` slows the wave (the
        // deadline/watchdog path), `panic` kills the shard (the
        // supervisor path).
        if let Err(e) = crate::util::fault::hit("decode_wave") {
            for &(id, _, _, _) in &metas {
                self.fail_decode(id, anyhow::anyhow!("{e}"));
            }
            return;
        }
        if self.paged {
            if self.batching && metas.len() >= 2 {
                match self.decode_wave_batched(&metas) {
                    Ok(()) => return,
                    // The batch call mutates nothing until it succeeds,
                    // so the per-sequence walk below is a clean retry.
                    Err(e) => crate::debug!("batched decode fell back to per-sequence: {e:#}"),
                }
            }
            for &(id, seq, token, pos) in &metas {
                if let Err(e) = self.decode_one(id, seq, token, pos, None) {
                    self.fail_decode(id, e);
                }
            }
            return;
        }
        let kind = self.staged_kind.expect("staged decode without a dense staging ABI");
        {
            let spec = self.backend.spec();
            let n = spec.layers * spec.heads * spec.max_seq * spec.head_dim;
            let ns = spec.layers
                * spec.heads
                * spec.max_seq.div_ceil(spec.block_size)
                * spec.head_dim;
            while self.staging.len() < metas.len() {
                self.staging.push(StagingSlot::new(kind, n, ns));
            }
        }
        // Parallel gather phase: cache reads + staging writes are
        // per-sequence disjoint; the manager is only read. Single-member
        // waves keep the manager's intra-gather fan-out instead.
        {
            let cache = &self.cache;
            let inner_threads = if metas.len() > 1 { 1 } else { self.threads };
            let slots = &mut self.staging[..metas.len()];
            parallel::parallel_zip(&metas, slots, self.threads, |_, &(_, seq, _, _), slot| {
                let t0 = Instant::now();
                slot.err = None;
                if let Err(e) = gather_sequence(cache, kind, seq, slot, inner_threads) {
                    slot.err = Some(format!("{e:#}"));
                }
                slot.gather_secs = t0.elapsed().as_secs_f64();
            });
        }
        // Serial phase: backend decode, cache append, sampling, events.
        for (i, &(id, seq, token, pos)) in metas.iter().enumerate() {
            if let Err(e) = self.decode_one(id, seq, token, pos, Some(i)) {
                self.fail_decode(id, e);
            }
        }
    }

    /// Tear down a request whose decode step errored. Books the terminal
    /// error so depth accounting (`Metrics::depth`) stays balanced.
    fn fail_decode(&mut self, id: RequestId, e: anyhow::Error) {
        crate::error!("decode failed for {id}: {e:#}");
        if let Some(run) = self.sched.finish(id) {
            self.cache.free(run.seq);
            self.metrics.on_error();
            let elapsed = run.req.arrival.elapsed().as_secs_f64();
            self.finish_stream(
                id,
                &run.events,
                FinishReason::Error(format!("{e}")),
                run.generated,
                elapsed,
            );
        }
    }

    /// One decode step: `slot = Some(i)` consumes pre-gathered staging
    /// slot `i` (legacy path); `slot = None` attends zero-copy over the
    /// paged cache view.
    fn decode_one(
        &mut self,
        id: u64,
        seq: SeqId,
        token: i32,
        pos: usize,
        slot: Option<usize>,
    ) -> Result<()> {
        let t0 = Instant::now();
        // A reclaim earlier in this wave may have preempted this member
        // after its gather: its state is parked, the slot is stale.
        if !self.sched.running.iter().any(|r| r.req.id == id) {
            return Ok(());
        }
        let gather_secs = match slot {
            Some(i) => {
                if let Some(e) = self.staging[i].err.take() {
                    anyhow::bail!("gather failed: {e}");
                }
                self.staging[i].gather_secs
            }
            None => 0.0,
        };
        let attend_t0 = Instant::now();
        let (dec, cache_bytes) = match slot {
            None => {
                let view = self.cache.view(seq)?;
                let bytes = view.attention_bytes();
                let dec = self.backend.decode_paged(
                    token,
                    pos,
                    &view,
                    self.cfg.attention_kernel,
                    self.isa,
                )?;
                (dec, bytes)
            }
            Some(i) => {
                let kind =
                    self.staged_kind.expect("staged decode without a dense staging ABI");
                let dec = match kind {
                    StagedKind::I8 => {
                        let st = &self.staging[i];
                        self.backend
                            .decode_i8(token, pos, &st.kq, &st.ks, &st.vq, &st.vs, self.isa)?
                    }
                    StagedKind::F32 => {
                        let st = &self.staging[i];
                        self.backend.decode_f32(token, pos, &st.k32, &st.v32, self.isa)?
                    }
                };
                (dec, self.staged_cache_bytes)
            }
        };
        self.metrics.on_decode(gather_secs, attend_t0.elapsed().as_secs_f64(), cache_bytes);
        self.apply_decode(id, seq, &dec, gather_secs, t0)
    }

    /// Fused multi-query decode of a whole paged wave: one wave-level
    /// view (physical blocks deduped per (layer, head)), one batched
    /// backend call, then the same per-query tail as [`Self::decode_one`]
    /// (append with reclaim fallback, sample, events). Bit-identity: per
    /// member the batched backend call returns exactly the bytes the
    /// per-sequence call would, and member decodes are data-independent
    /// (each reads only its own sequence's rows), so regrouping the wave
    /// never changes tokens. Errors before any mutation — the caller
    /// falls back to the per-sequence walk.
    fn decode_wave_batched(&mut self, metas: &[(u64, SeqId, i32, usize)]) -> Result<()> {
        let t0 = Instant::now();
        let ids: Vec<SeqId> = metas.iter().map(|&(_, seq, _, _)| seq).collect();
        let queries: Vec<(i32, usize)> = metas.iter().map(|&(_, _, tok, pos)| (tok, pos)).collect();
        let attend_t0 = Instant::now();
        let (decs, wave_bytes, deduped) = {
            let wave = self.cache.wave_view(&ids)?;
            let bytes = wave.attention_bytes();
            let deduped = wave.blocks_deduped();
            let decs = self.backend.decode_paged_batch(
                &queries,
                &wave,
                self.cfg.attention_kernel,
                self.isa,
                &mut self.batch_scratch,
            )?;
            (decs, bytes, deduped)
        };
        let attend_each = attend_t0.elapsed().as_secs_f64() / metas.len() as f64;
        // Wave-level accounting: 2·L·H fused passes (K and V per head per
        // layer), dedup count, and the amortized wave bytes — booked once.
        // Per-member on_decode keeps decode_steps per token correct while
        // contributing 0 bytes (the wave already carried them).
        let spec = self.backend.spec();
        self.metrics.on_mq_wave(2 * spec.layers * spec.heads, deduped, wave_bytes);

        for (&(id, seq, _, _), dec) in metas.iter().zip(&decs) {
            // A reclaim by an earlier member of this wave may have
            // preempted this one: its state is parked, the result is
            // dropped (readmission replays it deterministically).
            if !self.sched.running.iter().any(|r| r.req.id == id) {
                continue;
            }
            self.metrics.on_decode(0.0, attend_each, 0);
            if let Err(e) = self.apply_decode(id, seq, dec, 0.0, t0) {
                self.fail_decode(id, e);
            }
        }
        Ok(())
    }

    /// The post-backend tail of one decode step, shared by the
    /// per-sequence and batched paths: append the new K/V row (with
    /// reclaim / self-preempt fallback), sample, stream, finish.
    fn apply_decode(
        &mut self,
        id: u64,
        seq: SeqId,
        dec: &DecodeResult,
        gather_secs: f64,
        t0: Instant,
    ) -> Result<()> {
        if self.cache.append_row(seq, &dec.k_new, &dec.v_new).is_err() {
            // The plan's accounting raced reality (another sequence's COW,
            // a resume, an unevictable prefix entry). Reclaim and retry;
            // if this sequence itself must yield, park it — the append
            // simply never happened, so its state is already consistent.
            if !self.reclaim_for_append(seq, id) {
                crate::debug!("self-preempting {id}: pool dry after reclaim");
                self.preempt_request(id);
                return Ok(());
            }
            self.cache.append_row(seq, &dec.k_new, &dec.v_new)?;
        }

        let max_seq = self.cache.config().max_seq;
        let run = self.sched.running.iter_mut().find(|r| r.req.id == id).unwrap();
        let next = sample::sample(&dec.logits, &run.req.sampling, &mut run.rng);
        run.last_token = next;
        run.generated += 1;
        run.tokens.push(next);
        run.last_progress = Instant::now();
        run.stall_warned = false;
        // TPOT includes this sequence's own gather cost (measured in the
        // parallel phase) — same semantics as the pre-wave serial path.
        self.metrics.on_token(gather_secs + t0.elapsed().as_secs_f64());
        if run.events.send(TokenEvent::Token(next)).is_err() {
            // Client receiver dropped mid-decode: stop generating, free
            // the blocks, book the cancellation.
            crate::debug!("client dropped stream {id} mid-decode; cancelling");
            self.metrics.on_client_cancel();
            self.cancel_request(id, FinishReason::Cancelled);
            return Ok(());
        }

        if let Some(reason) = finish_reason(run, max_seq) {
            let mut run = self.sched.finish(id).unwrap();
            self.cache.free(run.seq);
            self.finalize(&mut run, reason);
        }
        Ok(())
    }

    /// Free bytes until `seq` can append one row: cold-tier demotions
    /// first (cached prompts survive compressed), plain prefix-cache
    /// evictions next, then preemption victims (never `exclude` itself).
    /// The check is span-quantized (`free_bytes`), so a `true` return
    /// guarantees every sub-pool class can supply its share of the
    /// append. Returns false when the pool still cannot cover it.
    fn reclaim_for_append(&mut self, seq: SeqId, exclude: RequestId) -> bool {
        loop {
            let need = self.cache.append_need_bytes(seq);
            if need <= self.cache.free_bytes() {
                return true;
            }
            if self.tier.demote_for(&mut self.prefix, &mut self.cache, need) > 0 {
                continue;
            }
            if self.prefix.evict_reclaimable_lru(&mut self.cache) {
                continue;
            }
            let Some(victim) = self.sched.select_victim(&[exclude]) else {
                return false;
            };
            self.preempt_request(victim);
        }
    }

    fn finalize(&self, run: &mut Running, reason: FinishReason) {
        let elapsed = run.req.arrival.elapsed().as_secs_f64();
        self.metrics.on_finish(elapsed);
        self.finish_stream(run.req.id, &run.events, reason, run.generated, elapsed);
    }
}

fn finish_reason(run: &Running, max_seq: usize) -> Option<FinishReason> {
    if Some(run.last_token) == run.req.stop_token {
        return Some(FinishReason::Stop);
    }
    if run.generated >= run.req.max_new_tokens {
        return Some(FinishReason::Length);
    }
    if run.req.prompt.len() + run.generated >= max_seq {
        return Some(FinishReason::CapacityExhausted);
    }
    None
}

// Engine behaviour is covered by rust/tests/serving_integration.rs (CPU
// backend) and the e2e bench (PJRT backend).
