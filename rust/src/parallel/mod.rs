//! Shared parallel runtime — the thread-pool substrate every
//! quantization-family hot path routes through (rayon substitute, built on
//! `std::thread::scope`).
//!
//! Promoted from `util::pool` so the quant, kvcache, coordinator, server,
//! and bench layers share one parallelism knob instead of each inventing
//! its own:
//!
//! * knob value `0` = auto: `std::thread::available_parallelism()`,
//!   overridable via the `KVQ_THREADS` env var — see [`resolve`];
//! * knob value `n >= 1` = exactly `n` workers.
//!
//! Every entry point here is **bit-deterministic**: workers own disjoint
//! output regions and no floating-point reduction order depends on the
//! thread count, so the cross-variant consistency tests
//! (`all_variants_identical`, `tests/parallel_consistency.rs`) can assert
//! exact equality between serial and parallel paths at any worker count.
//! On a 1-core testbed everything degrades gracefully to sequential
//! execution.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (available parallelism,
/// overridable via `KVQ_THREADS`).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("KVQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Resolve a configuration knob: `0` means auto ([`default_threads`]),
/// any other value is clamped to at least one worker.
pub fn resolve(threads: usize) -> usize {
    if threads == 0 {
        default_threads()
    } else {
        threads
    }
}

/// The thread sweep the benches report: {1, 2, N_phys}, deduplicated.
pub fn bench_thread_sweep() -> Vec<usize> {
    let mut sweep = vec![1, 2, default_threads()];
    sweep.sort_unstable();
    sweep.dedup();
    sweep
}

/// Run `f(chunk_start, chunk_end)` in parallel over `0..n` split into
/// contiguous chunks, one logical chunk stream per worker (work-stealing
/// via an atomic cursor, chunk size `chunk`).
pub fn parallel_chunks<F>(n: usize, chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n <= chunk {
        let mut i = 0;
        while i < n {
            f(i, (i + chunk).min(n));
            i += chunk;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                f(start, (start + chunk).min(n));
            });
        }
    });
}

/// Parallel map over a slice of items producing a Vec of results in order.
/// Static partition: each worker owns a contiguous (items, out) pair.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Default + Clone,
    F: Fn(&T) -> R + Sync + Send,
{
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    let mut out = vec![R::default(); n];
    if threads <= 1 {
        for (o, it) in out.iter_mut().zip(items) {
            *o = f(it);
        }
        return out;
    }
    let per = n.div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        for (ichunk, ochunk) in items.chunks(per).zip(out.chunks_mut(per)) {
            s.spawn(move || {
                for (o, it) in ochunk.iter_mut().zip(ichunk) {
                    *o = f(it);
                }
            });
        }
    });
    out
}

/// Parallel zip: `f(i, &items[i], &mut outs[i])` across workers, static
/// partition. The coordinator's decode waves use this to gather several
/// sequences' caches into per-sequence staging slots concurrently.
pub fn parallel_zip<T, U, F>(items: &[T], outs: &mut [U], threads: usize, f: F)
where
    T: Sync,
    U: Send,
    F: Fn(usize, &T, &mut U) + Sync,
{
    assert_eq!(items.len(), outs.len(), "parallel_zip length mismatch");
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    if threads <= 1 {
        for (i, (it, o)) in items.iter().zip(outs.iter_mut()).enumerate() {
            f(i, it, o);
        }
        return;
    }
    let per = n.div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        for (ci, (ichunk, ochunk)) in items.chunks(per).zip(outs.chunks_mut(per)).enumerate() {
            s.spawn(move || {
                for (j, (it, o)) in ichunk.iter().zip(ochunk.iter_mut()).enumerate() {
                    f(ci * per + j, it, o);
                }
            });
        }
    });
}

/// Raw-pointer wrapper so workers can write **disjoint** regions of one
/// output buffer from a `Fn` closure. Keeping the pointer behind a method
/// makes closures capture the (Send+Sync) wrapper, not the bare pointer.
///
/// Safety contract: callers must guarantee that concurrently-derived
/// regions never overlap and stay in bounds of the original allocation.
#[derive(Clone, Copy)]
pub struct SendPtr<T>(*mut T);

unsafe impl<T> Send for SendPtr<T> {}
unsafe impl<T> Sync for SendPtr<T> {}

impl<T> SendPtr<T> {
    pub fn new(ptr: *mut T) -> SendPtr<T> {
        SendPtr(ptr)
    }

    /// Offset pointer. Callers build slices with `from_raw_parts_mut` and
    /// own the disjointness proof at the call site.
    ///
    /// # Safety
    /// `off` must be in bounds of the allocation behind the wrapped
    /// pointer.
    pub unsafe fn add(self, off: usize) -> *mut T {
        self.0.add(off)
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(n, 64, 4, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sequential_fallback() {
        let sum = AtomicU64::new(0);
        parallel_chunks(100, 7, 1, |s, e| {
            sum.fetch_add((s..e).map(|i| i as u64).sum(), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn empty_range_is_noop() {
        parallel_chunks(0, 16, 4, |_, _| panic!("should not run"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 4, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn parallel_zip_indices_and_outputs() {
        let items: Vec<usize> = (0..57).collect();
        for threads in [1, 2, 8] {
            let mut outs = vec![0usize; items.len()];
            parallel_zip(&items, &mut outs, threads, |i, &it, o| {
                assert_eq!(i, it);
                *o = it * 3 + 1;
            });
            assert_eq!(outs, (0..57).map(|x| x * 3 + 1).collect::<Vec<_>>());
        }
    }

    #[test]
    #[should_panic(expected = "length mismatch")]
    fn parallel_zip_rejects_mismatched_lengths() {
        let mut outs = vec![0u8; 2];
        parallel_zip(&[1u8; 3], &mut outs, 2, |_, _, _| {});
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }

    #[test]
    fn resolve_zero_is_auto() {
        assert_eq!(resolve(0), default_threads());
        assert_eq!(resolve(3), 3);
    }

    #[test]
    fn sweep_contains_one_and_is_sorted_unique() {
        let s = bench_thread_sweep();
        assert!(s.contains(&1));
        let mut sorted = s.clone();
        sorted.sort_unstable();
        sorted.dedup();
        assert_eq!(s, sorted);
    }

    #[test]
    fn send_ptr_disjoint_writes() {
        let mut buf = vec![0u32; 1024];
        let p = SendPtr::new(buf.as_mut_ptr());
        parallel_chunks(1024, 64, 4, |lo, hi| {
            // SAFETY: [lo, hi) chunks are disjoint across workers.
            let s = unsafe { std::slice::from_raw_parts_mut(p.add(lo), hi - lo) };
            for (k, v) in s.iter_mut().enumerate() {
                *v = (lo + k) as u32;
            }
        });
        assert!(buf.iter().enumerate().all(|(i, &v)| v == i as u32));
    }
}
