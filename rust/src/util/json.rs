//! Minimal JSON parser + writer (RFC 8259 subset sufficient for the
//! artifact manifest, config files, and bench reports).
//!
//! Design: a recursive-descent parser into an owned [`Json`] tree. Numbers
//! are kept as `f64` (the manifest only contains shapes/counts well inside
//! the 2^53 exact-integer range). Escapes: `\" \\ \/ \b \f \n \r \t \uXXXX`
//! (surrogate pairs supported).

use std::collections::BTreeMap;
use std::fmt;

/// A parsed JSON value.
#[derive(Debug, Clone, PartialEq)]
pub enum Json {
    Null,
    Bool(bool),
    Num(f64),
    Str(String),
    Arr(Vec<Json>),
    Obj(BTreeMap<String, Json>),
}

/// Parse error with byte offset context.
#[derive(Debug, Clone, PartialEq)]
pub struct JsonError {
    pub msg: String,
    pub offset: usize,
}

impl fmt::Display for JsonError {
    fn fmt(&self, f: &mut fmt::Formatter<'_>) -> fmt::Result {
        write!(f, "json error at byte {}: {}", self.offset, self.msg)
    }
}

impl std::error::Error for JsonError {}

impl Json {
    pub fn parse(text: &str) -> Result<Json, JsonError> {
        let mut p = Parser { b: text.as_bytes(), i: 0 };
        p.ws();
        let v = p.value()?;
        p.ws();
        if p.i != p.b.len() {
            return Err(p.err("trailing data"));
        }
        Ok(v)
    }

    // -- typed accessors ---------------------------------------------------

    pub fn as_str(&self) -> Option<&str> {
        match self {
            Json::Str(s) => Some(s),
            _ => None,
        }
    }

    pub fn as_f64(&self) -> Option<f64> {
        match self {
            Json::Num(n) => Some(*n),
            _ => None,
        }
    }

    pub fn as_usize(&self) -> Option<usize> {
        self.as_f64().filter(|n| *n >= 0.0 && n.fract() == 0.0).map(|n| n as usize)
    }

    pub fn as_i64(&self) -> Option<i64> {
        self.as_f64().filter(|n| n.fract() == 0.0).map(|n| n as i64)
    }

    pub fn as_bool(&self) -> Option<bool> {
        match self {
            Json::Bool(b) => Some(*b),
            _ => None,
        }
    }

    pub fn as_arr(&self) -> Option<&[Json]> {
        match self {
            Json::Arr(a) => Some(a),
            _ => None,
        }
    }

    pub fn as_obj(&self) -> Option<&BTreeMap<String, Json>> {
        match self {
            Json::Obj(o) => Some(o),
            _ => None,
        }
    }

    /// `obj["key"]` access; returns `Json::Null` for anything missing so
    /// lookups chain without panicking.
    pub fn get(&self, key: &str) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Obj(o) => o.get(key).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    /// Array index access with the same chaining behaviour as [`Json::get`].
    pub fn at(&self, idx: usize) -> &Json {
        static NULL: Json = Json::Null;
        match self {
            Json::Arr(a) => a.get(idx).unwrap_or(&NULL),
            _ => &NULL,
        }
    }

    pub fn is_null(&self) -> bool {
        matches!(self, Json::Null)
    }

    // -- writer ------------------------------------------------------------

    /// Compact serialization.
    pub fn to_string(&self) -> String {
        let mut s = String::new();
        self.write(&mut s);
        s
    }

    fn write(&self, out: &mut String) {
        match self {
            Json::Null => out.push_str("null"),
            Json::Bool(true) => out.push_str("true"),
            Json::Bool(false) => out.push_str("false"),
            Json::Num(n) => write_num(*n, out),
            Json::Str(s) => write_str(s, out),
            Json::Arr(a) => {
                out.push('[');
                for (i, v) in a.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    v.write(out);
                }
                out.push(']');
            }
            Json::Obj(o) => {
                out.push('{');
                for (i, (k, v)) in o.iter().enumerate() {
                    if i > 0 {
                        out.push(',');
                    }
                    write_str(k, out);
                    out.push(':');
                    v.write(out);
                }
                out.push('}');
            }
        }
    }
}

/// Convenience constructors for report emission.
impl From<&str> for Json {
    fn from(s: &str) -> Self {
        Json::Str(s.to_string())
    }
}
impl From<String> for Json {
    fn from(s: String) -> Self {
        Json::Str(s)
    }
}
impl From<f64> for Json {
    fn from(n: f64) -> Self {
        Json::Num(n)
    }
}
impl From<usize> for Json {
    fn from(n: usize) -> Self {
        Json::Num(n as f64)
    }
}
impl From<bool> for Json {
    fn from(b: bool) -> Self {
        Json::Bool(b)
    }
}
impl<T: Into<Json>> From<Vec<T>> for Json {
    fn from(v: Vec<T>) -> Self {
        Json::Arr(v.into_iter().map(Into::into).collect())
    }
}

/// Build an object literal: `obj([("a", 1.into()), ...])`.
pub fn obj(fields: impl IntoIterator<Item = (&'static str, Json)>) -> Json {
    Json::Obj(fields.into_iter().map(|(k, v)| (k.to_string(), v)).collect())
}

fn write_num(n: f64, out: &mut String) {
    if n.fract() == 0.0 && n.abs() < 9.0e15 {
        out.push_str(&format!("{}", n as i64));
    } else {
        out.push_str(&format!("{n}"));
    }
}

fn write_str(s: &str, out: &mut String) {
    out.push('"');
    for c in s.chars() {
        match c {
            '"' => out.push_str("\\\""),
            '\\' => out.push_str("\\\\"),
            '\n' => out.push_str("\\n"),
            '\r' => out.push_str("\\r"),
            '\t' => out.push_str("\\t"),
            '\u{08}' => out.push_str("\\b"),
            '\u{0c}' => out.push_str("\\f"),
            c if (c as u32) < 0x20 => out.push_str(&format!("\\u{:04x}", c as u32)),
            c => out.push(c),
        }
    }
    out.push('"');
}

struct Parser<'a> {
    b: &'a [u8],
    i: usize,
}

impl<'a> Parser<'a> {
    fn err(&self, msg: &str) -> JsonError {
        JsonError { msg: msg.to_string(), offset: self.i }
    }

    fn ws(&mut self) {
        while self.i < self.b.len() && matches!(self.b[self.i], b' ' | b'\t' | b'\n' | b'\r') {
            self.i += 1;
        }
    }

    fn peek(&self) -> Option<u8> {
        self.b.get(self.i).copied()
    }

    fn eat(&mut self, c: u8) -> Result<(), JsonError> {
        if self.peek() == Some(c) {
            self.i += 1;
            Ok(())
        } else {
            Err(self.err(&format!("expected '{}'", c as char)))
        }
    }

    fn lit(&mut self, s: &str, v: Json) -> Result<Json, JsonError> {
        if self.b[self.i..].starts_with(s.as_bytes()) {
            self.i += s.len();
            Ok(v)
        } else {
            Err(self.err("invalid literal"))
        }
    }

    fn value(&mut self) -> Result<Json, JsonError> {
        match self.peek() {
            Some(b'n') => self.lit("null", Json::Null),
            Some(b't') => self.lit("true", Json::Bool(true)),
            Some(b'f') => self.lit("false", Json::Bool(false)),
            Some(b'"') => Ok(Json::Str(self.string()?)),
            Some(b'[') => self.array(),
            Some(b'{') => self.object(),
            Some(c) if c == b'-' || c.is_ascii_digit() => self.number(),
            _ => Err(self.err("expected value")),
        }
    }

    fn array(&mut self) -> Result<Json, JsonError> {
        self.eat(b'[')?;
        let mut out = Vec::new();
        self.ws();
        if self.peek() == Some(b']') {
            self.i += 1;
            return Ok(Json::Arr(out));
        }
        loop {
            self.ws();
            out.push(self.value()?);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b']') => {
                    self.i += 1;
                    return Ok(Json::Arr(out));
                }
                _ => return Err(self.err("expected ',' or ']'")),
            }
        }
    }

    fn object(&mut self) -> Result<Json, JsonError> {
        self.eat(b'{')?;
        let mut out = BTreeMap::new();
        self.ws();
        if self.peek() == Some(b'}') {
            self.i += 1;
            return Ok(Json::Obj(out));
        }
        loop {
            self.ws();
            let k = self.string()?;
            self.ws();
            self.eat(b':')?;
            self.ws();
            let v = self.value()?;
            out.insert(k, v);
            self.ws();
            match self.peek() {
                Some(b',') => self.i += 1,
                Some(b'}') => {
                    self.i += 1;
                    return Ok(Json::Obj(out));
                }
                _ => return Err(self.err("expected ',' or '}'")),
            }
        }
    }

    fn string(&mut self) -> Result<String, JsonError> {
        self.eat(b'"')?;
        let mut s = String::new();
        loop {
            match self.peek() {
                None => return Err(self.err("unterminated string")),
                Some(b'"') => {
                    self.i += 1;
                    return Ok(s);
                }
                Some(b'\\') => {
                    self.i += 1;
                    match self.peek() {
                        Some(b'"') => s.push('"'),
                        Some(b'\\') => s.push('\\'),
                        Some(b'/') => s.push('/'),
                        Some(b'b') => s.push('\u{08}'),
                        Some(b'f') => s.push('\u{0c}'),
                        Some(b'n') => s.push('\n'),
                        Some(b'r') => s.push('\r'),
                        Some(b't') => s.push('\t'),
                        Some(b'u') => {
                            self.i += 1;
                            let hi = self.hex4()?;
                            let c = if (0xD800..0xDC00).contains(&hi) {
                                // surrogate pair
                                if self.b[self.i..].starts_with(b"\\u") {
                                    self.i += 2;
                                    let lo = self.hex4()?;
                                    let cp = 0x10000
                                        + ((hi - 0xD800) << 10)
                                        + (lo.wrapping_sub(0xDC00) & 0x3FF);
                                    char::from_u32(cp)
                                } else {
                                    None
                                }
                            } else {
                                char::from_u32(hi)
                            };
                            s.push(c.ok_or_else(|| self.err("bad \\u escape"))?);
                            continue; // hex4 advanced i already
                        }
                        _ => return Err(self.err("bad escape")),
                    }
                    self.i += 1;
                }
                Some(_) => {
                    // Decode one UTF-8 char.
                    let rest = &self.b[self.i..];
                    let len = utf8_len(rest[0]);
                    let chunk = rest
                        .get(..len)
                        .and_then(|c| std::str::from_utf8(c).ok())
                        .ok_or_else(|| self.err("bad utf8"))?;
                    s.push_str(chunk);
                    self.i += len;
                }
            }
        }
    }

    fn hex4(&mut self) -> Result<u32, JsonError> {
        let chunk = self
            .b
            .get(self.i..self.i + 4)
            .and_then(|c| std::str::from_utf8(c).ok())
            .ok_or_else(|| self.err("bad \\u escape"))?;
        let v = u32::from_str_radix(chunk, 16).map_err(|_| self.err("bad hex"))?;
        self.i += 4;
        Ok(v)
    }

    fn number(&mut self) -> Result<Json, JsonError> {
        let start = self.i;
        if self.peek() == Some(b'-') {
            self.i += 1;
        }
        while self
            .peek()
            .map(|c| c.is_ascii_digit() || matches!(c, b'.' | b'e' | b'E' | b'+' | b'-'))
            .unwrap_or(false)
        {
            self.i += 1;
        }
        let s = std::str::from_utf8(&self.b[start..self.i]).unwrap();
        s.parse::<f64>().map(Json::Num).map_err(|_| self.err("bad number"))
    }
}

fn utf8_len(b: u8) -> usize {
    match b {
        0x00..=0x7F => 1,
        0xC0..=0xDF => 2,
        0xE0..=0xEF => 3,
        _ => 4,
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn parses_scalars() {
        assert_eq!(Json::parse("null").unwrap(), Json::Null);
        assert_eq!(Json::parse("true").unwrap(), Json::Bool(true));
        assert_eq!(Json::parse("false").unwrap(), Json::Bool(false));
        assert_eq!(Json::parse("42").unwrap(), Json::Num(42.0));
        assert_eq!(Json::parse("-1.5e3").unwrap(), Json::Num(-1500.0));
        assert_eq!(Json::parse("\"hi\"").unwrap(), Json::Str("hi".into()));
    }

    #[test]
    fn parses_nested() {
        let v = Json::parse(r#"{"a": [1, 2, {"b": null}], "c": "x"}"#).unwrap();
        assert_eq!(v.get("a").at(0).as_f64(), Some(1.0));
        assert!(v.get("a").at(2).get("b").is_null());
        assert_eq!(v.get("c").as_str(), Some("x"));
    }

    #[test]
    fn missing_keys_chain_to_null() {
        let v = Json::parse("{}").unwrap();
        assert!(v.get("nope").at(3).get("deeper").is_null());
    }

    #[test]
    fn parses_escapes() {
        let v = Json::parse(r#""a\nb\t\"q\" A 😀""#).unwrap();
        assert_eq!(v.as_str(), Some("a\nb\t\"q\" A 😀"));
    }

    #[test]
    fn rejects_garbage() {
        assert!(Json::parse("{").is_err());
        assert!(Json::parse("[1,]").is_err());
        assert!(Json::parse("tru").is_err());
        assert!(Json::parse("1 2").is_err());
        assert!(Json::parse("{\"a\" 1}").is_err());
    }

    #[test]
    fn roundtrip_writer() {
        let src = r#"{"arr":[1,2.5,"s"],"b":true,"n":null,"neg":-3}"#;
        let v = Json::parse(src).unwrap();
        let v2 = Json::parse(&v.to_string()).unwrap();
        assert_eq!(v, v2);
    }

    #[test]
    fn writes_escaped_strings() {
        let v = Json::Str("a\"b\\c\nd".into());
        assert_eq!(v.to_string(), r#""a\"b\\c\nd""#);
        assert_eq!(Json::parse(&v.to_string()).unwrap(), v);
    }

    #[test]
    fn integer_formatting_is_exact() {
        assert_eq!(Json::Num(131072.0).to_string(), "131072");
        assert_eq!(Json::Num(0.5).to_string(), "0.5");
    }

    #[test]
    fn usize_accessor_rejects_fractions() {
        assert_eq!(Json::parse("3.5").unwrap().as_usize(), None);
        assert_eq!(Json::parse("-1").unwrap().as_usize(), None);
        assert_eq!(Json::parse("7").unwrap().as_usize(), Some(7));
    }

    #[test]
    fn obj_builder() {
        let v = obj([("x", 1usize.into()), ("y", "z".into())]);
        assert_eq!(v.to_string(), r#"{"x":1,"y":"z"}"#);
    }

    #[test]
    fn parses_manifest_like_structure() {
        let src = r#"{"version":1,"entries":[{"name":"q","inputs":[{"dtype":"float32","shape":[2048,128]}]}]}"#;
        let v = Json::parse(src).unwrap();
        let e = v.get("entries").at(0);
        assert_eq!(e.get("name").as_str(), Some("q"));
        assert_eq!(e.get("inputs").at(0).get("shape").at(1).as_usize(), Some(128));
    }
}
