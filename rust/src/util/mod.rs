//! From-scratch substrates.
//!
//! The offline build environment provides no general-purpose crates
//! (no serde/clap/rand/rayon/tokio/criterion/proptest), so this module
//! implements the small, well-understood subset of each that the rest of
//! the stack needs. Each submodule is independently unit-tested.
//!
//! The thread-pool substrate (formerly `util::pool`) was promoted to
//! [`crate::parallel`].

pub mod args;
pub mod fault;
pub mod harness;
pub mod json;
pub mod log;
pub mod prop;
pub mod rng;
pub mod stats;
