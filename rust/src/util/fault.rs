//! Deterministic fault injection for chaos testing.
//!
//! A fault spec is a JSON array of rules, installed either from the
//! `KVQ_FAULT` env var / `--fault-spec` flag (inline JSON or a file
//! path) or programmatically in tests — [`install`] for same-thread
//! sites, [`install_global`] when the sites run on spawned engine
//! threads:
//!
//! ```json
//! [{"site":"decode_wave","action":"panic","nth":3,"count":1}]
//! ```
//!
//! * `site`   — named instrumentation point. Current sites: `prefill`,
//!   `decode_wave`, `tier_demote`, `tier_promote`, `tier_decompress`,
//!   `snapshot_load`.
//! * `action` — `panic` (kills the engine thread; the supervisor path),
//!   `error` (typed failure), `delay` (sleep `delay_ms`, default 50 —
//!   the deadline/watchdog path), or `corrupt` (deterministically flip
//!   bytes at [`corrupt`] call sites).
//! * `nth`    — fire on the Nth hit of the site (1-based; default 1).
//! * `count`  — how many consecutive hits fire once armed (default 1;
//!   0 = unlimited).
//!
//! Everything is counter-driven — no clocks, no randomness — so a given
//! spec against a given workload fires at exactly the same operation
//! every run. That is what lets `tests/chaos.rs` re-drive failed
//! requests and demand byte-identical tokens.

use crate::util::json::Json;
use anyhow::{anyhow, bail, Result};
use std::sync::atomic::{AtomicU64, Ordering};
use std::sync::{Mutex, MutexGuard, OnceLock};

#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub enum FaultAction {
    Panic,
    Error,
    Delay,
    Corrupt,
}

impl FaultAction {
    fn parse(s: &str) -> Option<FaultAction> {
        Some(match s {
            "panic" => FaultAction::Panic,
            "error" => FaultAction::Error,
            "delay" => FaultAction::Delay,
            "corrupt" => FaultAction::Corrupt,
            _ => return None,
        })
    }
}

#[derive(Debug, Clone)]
struct Rule {
    site: String,
    action: FaultAction,
    /// Fire on the nth hit (1-based).
    nth: u64,
    /// Consecutive hits that fire once armed (0 = unlimited).
    count: u64,
    delay_ms: u64,
}

#[derive(Debug)]
struct RuleState {
    rule: Rule,
    hits: u64,
    fired: u64,
}

impl RuleState {
    /// Counter bookkeeping for one hit of this rule's site: returns the
    /// action to apply, if the rule fires on this hit.
    fn on_hit(&mut self) -> Option<FaultAction> {
        self.hits += 1;
        if self.hits < self.rule.nth {
            return None;
        }
        if self.rule.count != 0 && self.fired >= self.rule.count {
            return None;
        }
        self.fired += 1;
        Some(self.rule.action)
    }
}

#[derive(Debug, Default)]
struct Plan {
    rules: Vec<RuleState>,
    /// When set, only hits from this thread fire (test-scoped plans from
    /// [`install`]). Serving-path plans (`KVQ_FAULT` / `--fault-spec` /
    /// [`install_global`]) fire process-wide — engine threads included.
    thread: Option<std::thread::ThreadId>,
}

/// Active plan. `None` until something installs a spec; cleared when a
/// test's [`FaultGuard`] drops.
static PLAN: Mutex<Option<Plan>> = Mutex::new(None);
/// Total injected faults across all sites (the `fault_injections` gauge).
static INJECTIONS: AtomicU64 = AtomicU64::new(0);
/// Serializes programmatic installs so concurrent chaos tests can't see
/// each other's faults.
static TEST_LOCK: Mutex<()> = Mutex::new(());

fn parse_rules(spec: &Json) -> Result<Vec<Rule>> {
    let Json::Arr(items) = spec else { bail!("fault spec must be a JSON array of rules") };
    let mut rules = Vec::new();
    for item in items {
        let Json::Obj(map) = item else { bail!("fault rule must be an object") };
        let get = |k: &str| map.get(k);
        let site = get("site")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("fault rule missing \"site\""))?
            .to_string();
        let action_s = get("action")
            .and_then(|v| v.as_str())
            .ok_or_else(|| anyhow!("fault rule missing \"action\""))?;
        let action = FaultAction::parse(action_s)
            .ok_or_else(|| anyhow!("bad fault action {action_s:?} (panic|error|delay|corrupt)"))?;
        let nth = get("nth").and_then(|v| v.as_usize()).unwrap_or(1).max(1) as u64;
        let count = get("count").and_then(|v| v.as_usize()).unwrap_or(1) as u64;
        let delay_ms = get("delay_ms").and_then(|v| v.as_usize()).unwrap_or(50) as u64;
        rules.push(Rule { site, action, nth, count, delay_ms });
    }
    Ok(rules)
}

/// Parse a spec string: inline JSON (starts with `[`) or a file path.
pub fn parse_spec(spec: &str) -> Result<Json> {
    let text = spec.trim();
    if text.starts_with('[') {
        Json::parse(text).map_err(|e| anyhow!("bad fault spec: {e}"))
    } else {
        let body = std::fs::read_to_string(text)
            .map_err(|e| anyhow!("reading fault spec {text:?}: {e}"))?;
        Json::parse(&body).map_err(|e| anyhow!("bad fault spec file {text:?}: {e}"))
    }
}

/// Install a fault plan from a spec string (inline JSON or file path).
/// Replaces any previous plan. Serving-path entry (`--fault-spec`):
/// fires on every thread.
pub fn install_spec(spec: &str) -> Result<()> {
    install_rules(parse_rules(&parse_spec(spec)?)?, None);
    Ok(())
}

fn install_rules(rules: Vec<Rule>, thread: Option<std::thread::ThreadId>) {
    let n = rules.len();
    *PLAN.lock().unwrap() = Some(Plan {
        rules: rules.into_iter().map(|rule| RuleState { rule, hits: 0, fired: 0 }).collect(),
        thread,
    });
    crate::warn!("fault injection armed: {n} rule(s)");
}

/// Unit-test entry: install a plan that fires **only on the calling
/// thread**, and get a guard that clears it on drop. The thread scoping
/// is what lets fault-installing unit tests run inside a parallel test
/// binary without injecting faults into (or having their trigger budget
/// consumed by) unrelated tests on sibling threads. The guard also holds
/// the global fault lock, serializing installers against each other.
pub fn install(spec: &str) -> Result<FaultGuard> {
    let lock = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    install_rules(parse_rules(&parse_spec(spec)?)?, Some(std::thread::current().id()));
    Ok(FaultGuard { _lock: lock })
}

/// Chaos-test entry: like [`install`] but the plan fires on **every**
/// thread — required when the faulted sites run on engine threads the
/// test spawns. Callers must not run concurrently with tests that hit
/// real fault sites; the chaos suite guarantees this by having every
/// test take a guard (the shared lock serializes them) for its entire
/// active phase.
pub fn install_global(spec: &str) -> Result<FaultGuard> {
    let lock = TEST_LOCK.lock().unwrap_or_else(|e| e.into_inner());
    install_spec(spec)?;
    Ok(FaultGuard { _lock: lock })
}

/// Clears the installed plan on drop.
pub struct FaultGuard {
    _lock: MutexGuard<'static, ()>,
}

impl Drop for FaultGuard {
    fn drop(&mut self) {
        *PLAN.lock().unwrap() = None;
    }
}

/// Lazily pick up `KVQ_FAULT` once (env-only path for CI reruns of
/// suites that never call [`install`]).
fn env_install_once() {
    static ONCE: OnceLock<()> = OnceLock::new();
    ONCE.get_or_init(|| {
        if let Ok(spec) = std::env::var("KVQ_FAULT") {
            if !spec.trim().is_empty() {
                if let Err(e) = install_spec(&spec) {
                    crate::warn!("ignoring KVQ_FAULT: {e}");
                }
            }
        }
    });
}

/// True when any fault plan is armed.
pub fn active() -> bool {
    env_install_once();
    PLAN.lock().unwrap().is_some()
}

/// Total faults injected so far (process-wide).
pub fn injections() -> u64 {
    INJECTIONS.load(Ordering::Relaxed)
}

fn fire(site: &str) -> Option<(FaultAction, u64)> {
    env_install_once();
    let mut plan = PLAN.lock().unwrap();
    let plan = plan.as_mut()?;
    if let Some(tid) = plan.thread {
        if std::thread::current().id() != tid {
            return None;
        }
    }
    for st in &mut plan.rules {
        if st.rule.site == site {
            if let Some(action) = st.on_hit() {
                return Some((action, st.rule.delay_ms));
            }
        }
    }
    None
}

/// Hit a named site. May sleep (`delay`), return a typed error
/// (`error`), or panic (`panic` — the shard-supervisor path). `corrupt`
/// rules are ignored here; they only fire at [`corrupt`] call sites.
pub fn hit(site: &str) -> Result<()> {
    let Some((action, delay_ms)) = fire(site) else { return Ok(()) };
    match action {
        FaultAction::Panic => {
            INJECTIONS.fetch_add(1, Ordering::Relaxed);
            crate::warn!("fault injection: panic at {site}");
            panic!("injected fault at {site}");
        }
        FaultAction::Error => {
            INJECTIONS.fetch_add(1, Ordering::Relaxed);
            crate::warn!("fault injection: error at {site}");
            bail!("injected fault at {site}")
        }
        FaultAction::Delay => {
            INJECTIONS.fetch_add(1, Ordering::Relaxed);
            std::thread::sleep(std::time::Duration::from_millis(delay_ms));
            Ok(())
        }
        // A corrupt rule at a hit-only site does nothing (and doesn't
        // burn its trigger budget — on_hit already counted it, which is
        // the documented semantics: counters are per-site-hit).
        FaultAction::Corrupt => Ok(()),
    }
}

/// Deterministically corrupt a byte buffer if a `corrupt` rule fires at
/// this site. Flips a fixed bit pattern at positions derived from the
/// buffer length — same buffer, same corruption, every run. Returns
/// true when the buffer was mutated.
pub fn corrupt(site: &str, bytes: &mut [u8]) -> bool {
    let Some((action, _)) = fire(site) else { return false };
    if action != FaultAction::Corrupt || bytes.is_empty() {
        return false;
    }
    INJECTIONS.fetch_add(1, Ordering::Relaxed);
    let n = bytes.len();
    for k in 0..3usize {
        let idx = (n / 2 + k * 7) % n;
        bytes[idx] ^= 0xA5;
    }
    crate::warn!("fault injection: corrupted {n}-byte buffer at {site}");
    true
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn nth_and_count_gate_firing() {
        let _g =
            install(r#"[{"site":"t_site","action":"error","nth":2,"count":2}]"#).unwrap();
        assert!(hit("t_site").is_ok(), "first hit is before nth");
        assert!(hit("t_site").is_err(), "second hit fires");
        assert!(hit("t_site").is_err(), "count=2: third hit fires too");
        assert!(hit("t_site").is_ok(), "budget exhausted");
        assert!(hit("other_site").is_ok(), "other sites unaffected");
    }

    #[test]
    fn corrupt_is_deterministic_and_site_scoped() {
        let _g = install(
            r#"[{"site":"t_corrupt","action":"corrupt","nth":1,"count":0}]"#,
        )
        .unwrap();
        let mut a = vec![0u8; 32];
        let mut b = vec![0u8; 32];
        assert!(corrupt("t_corrupt", &mut a));
        assert!(corrupt("t_corrupt", &mut b));
        assert_eq!(a, b, "same buffer shape corrupts identically");
        assert_ne!(a, vec![0u8; 32], "bytes actually changed");
        let mut c = vec![0u8; 32];
        assert!(!corrupt("t_other", &mut c), "other sites untouched");
        assert_eq!(c, vec![0u8; 32]);
        // hit() never applies corrupt rules.
        assert!(hit("t_corrupt").is_ok());
    }

    #[test]
    fn guard_clears_plan_and_injections_count() {
        let before = injections();
        {
            let _g = install(r#"[{"site":"t_gone","action":"error"}]"#).unwrap();
            assert!(hit("t_gone").is_err());
        }
        assert!(hit("t_gone").is_ok(), "guard drop must clear the plan");
        assert!(injections() > before, "injection counter advanced");
    }

    #[test]
    fn test_install_is_thread_scoped() {
        let _g = install(r#"[{"site":"t_scoped","action":"error","count":0}]"#).unwrap();
        assert!(hit("t_scoped").is_err(), "installing thread fires");
        let other = std::thread::spawn(|| hit("t_scoped").is_ok());
        assert!(other.join().unwrap(), "sibling threads never see a test-scoped plan");
        // install_global lifts the scoping (new guard replaces the plan).
        drop(_g);
        let _g = install_global(r#"[{"site":"t_scoped","action":"error","count":0}]"#).unwrap();
        let other = std::thread::spawn(|| hit("t_scoped").is_err());
        assert!(other.join().unwrap(), "global plans fire on any thread");
    }

    #[test]
    fn bad_specs_are_typed_errors() {
        assert!(install(r#"{"site":"x"}"#).is_err(), "must be an array");
        assert!(install(r#"[{"action":"panic"}]"#).is_err(), "site required");
        assert!(install(r#"[{"site":"x","action":"meltdown"}]"#).is_err());
        assert!(install("/nonexistent/fault.json").is_err(), "missing file errors");
    }
}
