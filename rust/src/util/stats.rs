//! Summary statistics and latency histograms for benches and metrics.

/// Online summary of a stream of samples (Welford mean/variance + exact
/// percentiles from a retained sorted copy — fine at bench scale).
#[derive(Debug, Clone, Default)]
pub struct Summary {
    samples: Vec<f64>,
}

impl Summary {
    pub fn new() -> Self {
        Self::default()
    }

    pub fn add(&mut self, x: f64) {
        self.samples.push(x);
    }

    pub fn len(&self) -> usize {
        self.samples.len()
    }

    pub fn is_empty(&self) -> bool {
        self.samples.is_empty()
    }

    pub fn mean(&self) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        self.samples.iter().sum::<f64>() / self.samples.len() as f64
    }

    pub fn min(&self) -> f64 {
        self.samples.iter().copied().fold(f64::INFINITY, f64::min)
    }

    pub fn max(&self) -> f64 {
        self.samples.iter().copied().fold(f64::NEG_INFINITY, f64::max)
    }

    pub fn stddev(&self) -> f64 {
        let n = self.samples.len();
        if n < 2 {
            return 0.0;
        }
        let m = self.mean();
        (self.samples.iter().map(|x| (x - m).powi(2)).sum::<f64>() / (n - 1) as f64).sqrt()
    }

    /// Exact percentile by linear interpolation (p in [0, 100]).
    pub fn percentile(&self, p: f64) -> f64 {
        if self.samples.is_empty() {
            return 0.0;
        }
        let mut s = self.samples.clone();
        s.sort_by(|a, b| a.total_cmp(b));
        let rank = (p / 100.0) * (s.len() - 1) as f64;
        let lo = rank.floor() as usize;
        let hi = rank.ceil() as usize;
        if lo == hi {
            s[lo]
        } else {
            s[lo] + (s[hi] - s[lo]) * (rank - lo as f64)
        }
    }

    pub fn median(&self) -> f64 {
        self.percentile(50.0)
    }

    pub fn p99(&self) -> f64 {
        self.percentile(99.0)
    }
}

/// Fixed-bucket log-scale histogram for latency tracking in the serving
/// metrics path (no per-sample retention, O(1) record).
#[derive(Debug, Clone)]
pub struct LogHistogram {
    /// Bucket i counts samples in [base * ratio^i, base * ratio^(i+1)).
    counts: Vec<u64>,
    base: f64,
    log_ratio: f64,
    underflow: u64,
    total: u64,
    sum: f64,
    max: f64,
}

impl LogHistogram {
    /// `base`: lower bound of bucket 0 (e.g. 1 µs); `ratio`: bucket growth
    /// (e.g. 1.3 → ~9% worst-case quantile error); `buckets`: count.
    pub fn new(base: f64, ratio: f64, buckets: usize) -> Self {
        assert!(base > 0.0 && ratio > 1.0 && buckets > 0);
        Self {
            counts: vec![0; buckets],
            base,
            log_ratio: ratio.ln(),
            underflow: 0,
            total: 0,
            sum: 0.0,
            max: 0.0,
        }
    }

    /// Default latency histogram: 1 µs .. ~17 min in seconds.
    pub fn latency() -> Self {
        Self::new(1e-6, 1.3, 80)
    }

    pub fn record(&mut self, x: f64) {
        self.total += 1;
        self.sum += x;
        if x > self.max {
            self.max = x;
        }
        if x < self.base {
            self.underflow += 1;
            return;
        }
        let idx = ((x / self.base).ln() / self.log_ratio) as usize;
        let idx = idx.min(self.counts.len() - 1);
        self.counts[idx] += 1;
    }

    pub fn count(&self) -> u64 {
        self.total
    }

    pub fn mean(&self) -> f64 {
        if self.total == 0 {
            0.0
        } else {
            self.sum / self.total as f64
        }
    }

    pub fn max(&self) -> f64 {
        self.max
    }

    /// Approximate quantile (upper bound of the bucket containing it).
    pub fn quantile(&self, q: f64) -> f64 {
        if self.total == 0 {
            return 0.0;
        }
        let target = (q * self.total as f64).ceil() as u64;
        let mut acc = self.underflow;
        if acc >= target {
            return self.base;
        }
        for (i, c) in self.counts.iter().enumerate() {
            acc += c;
            if acc >= target {
                return self.base * ((i + 1) as f64 * self.log_ratio).exp();
            }
        }
        self.max
    }

    pub fn merge(&mut self, other: &LogHistogram) {
        assert_eq!(self.counts.len(), other.counts.len());
        for (a, b) in self.counts.iter_mut().zip(&other.counts) {
            *a += b;
        }
        self.underflow += other.underflow;
        self.total += other.total;
        self.sum += other.sum;
        self.max = self.max.max(other.max);
    }
}

/// Format seconds in engineering units for reports.
pub fn fmt_duration(secs: f64) -> String {
    if secs < 1e-6 {
        format!("{:.1}ns", secs * 1e9)
    } else if secs < 1e-3 {
        format!("{:.2}µs", secs * 1e6)
    } else if secs < 1.0 {
        format!("{:.2}ms", secs * 1e3)
    } else {
        format!("{:.2}s", secs)
    }
}

/// Format byte counts.
pub fn fmt_bytes(b: f64) -> String {
    const UNITS: [&str; 6] = ["B", "KiB", "MiB", "GiB", "TiB", "PiB"];
    let mut v = b;
    let mut u = 0;
    while v >= 1024.0 && u < UNITS.len() - 1 {
        v /= 1024.0;
        u += 1;
    }
    if u == 0 {
        format!("{v:.0}{}", UNITS[u])
    } else {
        format!("{v:.2}{}", UNITS[u])
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn summary_basic() {
        let mut s = Summary::new();
        for x in [1.0, 2.0, 3.0, 4.0, 5.0] {
            s.add(x);
        }
        assert_eq!(s.mean(), 3.0);
        assert_eq!(s.median(), 3.0);
        assert_eq!(s.min(), 1.0);
        assert_eq!(s.max(), 5.0);
        assert!((s.stddev() - (2.5f64).sqrt()).abs() < 1e-12);
    }

    #[test]
    fn summary_percentile_interpolates() {
        let mut s = Summary::new();
        for x in [0.0, 10.0] {
            s.add(x);
        }
        assert_eq!(s.percentile(25.0), 2.5);
        assert_eq!(s.percentile(100.0), 10.0);
        assert_eq!(s.percentile(0.0), 0.0);
    }

    #[test]
    fn summary_empty_is_safe() {
        let s = Summary::new();
        assert_eq!(s.mean(), 0.0);
        assert_eq!(s.percentile(99.0), 0.0);
    }

    #[test]
    fn histogram_quantiles_bracket_truth() {
        let mut h = LogHistogram::latency();
        // 1000 samples uniform in [1ms, 2ms].
        let mut rng = crate::util::rng::Rng::new(1);
        for _ in 0..1000 {
            h.record(rng.uniform(1e-3, 2e-3) as f64);
        }
        let p50 = h.quantile(0.5);
        assert!(p50 > 1.0e-3 && p50 < 2.2e-3, "p50 {p50}");
        assert_eq!(h.count(), 1000);
        assert!(h.mean() > 1.2e-3 && h.mean() < 1.8e-3);
    }

    #[test]
    fn histogram_underflow_and_max() {
        let mut h = LogHistogram::new(1.0, 2.0, 4);
        h.record(0.5); // underflow
        h.record(100.0); // clamps to last bucket
        assert_eq!(h.count(), 2);
        assert_eq!(h.max(), 100.0);
        assert_eq!(h.quantile(0.01), 1.0); // underflow reports base
    }

    #[test]
    fn histogram_merge() {
        let mut a = LogHistogram::latency();
        let mut b = LogHistogram::latency();
        a.record(1e-3);
        b.record(2e-3);
        a.merge(&b);
        assert_eq!(a.count(), 2);
        assert!(a.mean() > 1e-3 && a.mean() < 2e-3);
    }

    #[test]
    fn formatting() {
        assert_eq!(fmt_duration(0.0035), "3.50ms");
        assert_eq!(fmt_duration(2.0), "2.00s");
        assert_eq!(fmt_bytes(1536.0), "1.50KiB");
        assert_eq!(fmt_bytes(137.0 * 1024.0 * 1024.0 * 1024.0), "137.00GiB");
    }
}
