//! Scoped thread pool + parallel-for (rayon substitute).
//!
//! `scope_chunks` splits an index range across worker threads using
//! `std::thread::scope`, so borrows of stack data work without `Arc`.
//! On this testbed (1 core) it degrades gracefully to sequential execution;
//! the quantizer's `parallel` variants route through it.

use std::sync::atomic::{AtomicUsize, Ordering};

/// Number of worker threads to use by default (available parallelism,
/// overridable via `KVQ_THREADS`).
pub fn default_threads() -> usize {
    if let Ok(v) = std::env::var("KVQ_THREADS") {
        if let Ok(n) = v.parse::<usize>() {
            return n.max(1);
        }
    }
    std::thread::available_parallelism().map(|n| n.get()).unwrap_or(1)
}

/// Run `f(chunk_start, chunk_end)` in parallel over `0..n` split into
/// contiguous chunks, one logical chunk stream per worker (work-stealing
/// via an atomic cursor, chunk size `chunk`).
pub fn parallel_chunks<F>(n: usize, chunk: usize, threads: usize, f: F)
where
    F: Fn(usize, usize) + Sync,
{
    let threads = threads.max(1);
    if threads == 1 || n <= chunk {
        let mut i = 0;
        while i < n {
            f(i, (i + chunk).min(n));
            i += chunk;
        }
        return;
    }
    let cursor = AtomicUsize::new(0);
    std::thread::scope(|s| {
        for _ in 0..threads {
            s.spawn(|| loop {
                let start = cursor.fetch_add(chunk, Ordering::Relaxed);
                if start >= n {
                    break;
                }
                f(start, (start + chunk).min(n));
            });
        }
    });
}

/// Parallel map over a slice of items producing a Vec of results in order.
/// Static partition: each worker owns a contiguous (items, out) pair.
pub fn parallel_map<T, R, F>(items: &[T], threads: usize, f: F) -> Vec<R>
where
    T: Sync,
    R: Send + Default + Clone,
    F: Fn(&T) -> R + Sync + Send,
{
    let n = items.len();
    let threads = threads.clamp(1, n.max(1));
    let mut out = vec![R::default(); n];
    if threads <= 1 {
        for (o, it) in out.iter_mut().zip(items) {
            *o = f(it);
        }
        return out;
    }
    let per = n.div_ceil(threads);
    let f = &f;
    std::thread::scope(|s| {
        for (ichunk, ochunk) in items.chunks(per).zip(out.chunks_mut(per)) {
            s.spawn(move || {
                for (o, it) in ochunk.iter_mut().zip(ichunk) {
                    *o = f(it);
                }
            });
        }
    });
    out
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::AtomicU64;

    #[test]
    fn covers_all_indices_once() {
        let n = 10_000;
        let hits: Vec<AtomicU64> = (0..n).map(|_| AtomicU64::new(0)).collect();
        parallel_chunks(n, 64, 4, |s, e| {
            for i in s..e {
                hits[i].fetch_add(1, Ordering::Relaxed);
            }
        });
        assert!(hits.iter().all(|h| h.load(Ordering::Relaxed) == 1));
    }

    #[test]
    fn sequential_fallback() {
        let sum = AtomicU64::new(0);
        parallel_chunks(100, 7, 1, |s, e| {
            sum.fetch_add((s..e).map(|i| i as u64).sum(), Ordering::Relaxed);
        });
        assert_eq!(sum.load(Ordering::Relaxed), 4950);
    }

    #[test]
    fn empty_range_is_noop() {
        parallel_chunks(0, 16, 4, |_, _| panic!("should not run"));
    }

    #[test]
    fn parallel_map_preserves_order() {
        let items: Vec<usize> = (0..100).collect();
        let out = parallel_map(&items, 4, |x| x * 2);
        assert_eq!(out, (0..100).map(|x| x * 2).collect::<Vec<_>>());
    }

    #[test]
    fn default_threads_at_least_one() {
        assert!(default_threads() >= 1);
    }
}
