//! Benchmark harness (criterion substitute).
//!
//! Warmup + timed repetitions with median/mean/min reporting, adaptive
//! repetition count targeting a wall-clock budget, and aligned-table /
//! CSV emission so each `cargo bench` target prints the same rows as the
//! corresponding paper table or figure.

use super::stats::{fmt_duration, Summary};
use std::time::Instant;

/// One measured cell: repeated timings of a closure.
#[derive(Debug, Clone)]
pub struct Measurement {
    pub label: String,
    pub reps: usize,
    pub secs: Summary,
}

impl Measurement {
    pub fn median(&self) -> f64 {
        self.secs.median()
    }
    pub fn mean(&self) -> f64 {
        self.secs.mean()
    }
    pub fn min(&self) -> f64 {
        self.secs.min()
    }
}

/// Timing policy.
#[derive(Debug, Clone)]
pub struct Bencher {
    /// Minimum repetitions regardless of budget.
    pub min_reps: usize,
    /// Maximum repetitions.
    pub max_reps: usize,
    /// Wall-clock budget per measurement (seconds).
    pub budget: f64,
    /// Warmup runs (not recorded).
    pub warmup: usize,
}

impl Default for Bencher {
    fn default() -> Self {
        Bencher { min_reps: 3, max_reps: 30, budget: 2.0, warmup: 1 }
    }
}

impl Bencher {
    pub fn quick() -> Self {
        Bencher { min_reps: 2, max_reps: 5, budget: 0.5, warmup: 1 }
    }

    /// Measure `f`, which performs one full operation per call.
    pub fn measure<F: FnMut()>(&self, label: &str, mut f: F) -> Measurement {
        for _ in 0..self.warmup {
            f();
        }
        let mut secs = Summary::new();
        let start = Instant::now();
        let mut reps = 0;
        while reps < self.min_reps
            || (reps < self.max_reps && start.elapsed().as_secs_f64() < self.budget)
        {
            let t0 = Instant::now();
            f();
            secs.add(t0.elapsed().as_secs_f64());
            reps += 1;
        }
        Measurement { label: label.to_string(), reps, secs }
    }
}

/// Aligned-column text table, emitted to stdout and optionally CSV.
pub struct Table {
    title: String,
    headers: Vec<String>,
    rows: Vec<Vec<String>>,
}

impl Table {
    pub fn new(title: &str, headers: &[&str]) -> Self {
        Table {
            title: title.to_string(),
            headers: headers.iter().map(|s| s.to_string()).collect(),
            rows: Vec::new(),
        }
    }

    pub fn row(&mut self, cells: &[String]) {
        assert_eq!(cells.len(), self.headers.len(), "row width mismatch");
        self.rows.push(cells.to_vec());
    }

    pub fn print(&self) {
        let mut widths: Vec<usize> = self.headers.iter().map(|h| h.len()).collect();
        for row in &self.rows {
            for (w, c) in widths.iter_mut().zip(row) {
                *w = (*w).max(c.len());
            }
        }
        println!("\n=== {} ===", self.title);
        let hdr: Vec<String> = self
            .headers
            .iter()
            .zip(&widths)
            .map(|(h, w)| format!("{h:>w$}", w = w))
            .collect();
        println!("{}", hdr.join("  "));
        println!("{}", widths.iter().map(|w| "-".repeat(*w)).collect::<Vec<_>>().join("  "));
        for row in &self.rows {
            let line: Vec<String> =
                row.iter().zip(&widths).map(|(c, w)| format!("{c:>w$}", w = w)).collect();
            println!("{}", line.join("  "));
        }
    }

    /// Write CSV alongside the printed table (for plotting).
    pub fn write_csv(&self, path: &str) -> std::io::Result<()> {
        use std::io::Write;
        if let Some(dir) = std::path::Path::new(path).parent() {
            std::fs::create_dir_all(dir)?;
        }
        let mut f = std::fs::File::create(path)?;
        writeln!(f, "{}", self.headers.join(","))?;
        for row in &self.rows {
            let esc: Vec<String> = row
                .iter()
                .map(|c| {
                    if c.contains(',') || c.contains('"') {
                        format!("\"{}\"", c.replace('"', "\"\""))
                    } else {
                        c.clone()
                    }
                })
                .collect();
            writeln!(f, "{}", esc.join(","))?;
        }
        Ok(())
    }
}

/// Convenience cell formatters.
pub fn cell_time(secs: f64) -> String {
    fmt_duration(secs)
}

pub fn cell_speedup(x: f64) -> String {
    if x >= 100.0 {
        format!("{x:.0}x")
    } else {
        format!("{x:.2}x")
    }
}

pub fn cell_f(x: f64, prec: usize) -> String {
    format!("{x:.prec$}")
}

/// Whether benches should run paper-size workloads (`KVQ_BENCH_FULL=1` or
/// `--full` handled by callers).
pub fn full_mode() -> bool {
    std::env::var("KVQ_BENCH_FULL").map(|v| v == "1").unwrap_or(false)
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn measure_runs_at_least_min_reps() {
        let b = Bencher { min_reps: 4, max_reps: 10, budget: 0.0, warmup: 0 };
        let mut n = 0;
        let m = b.measure("x", || n += 1);
        assert_eq!(m.reps, 4);
        assert_eq!(n, 4);
        assert!(m.median() >= 0.0);
    }

    #[test]
    fn measure_respects_budget_cap() {
        let b = Bencher { min_reps: 1, max_reps: 3, budget: 60.0, warmup: 0 };
        let m = b.measure("sleepy", || std::thread::sleep(std::time::Duration::from_millis(1)));
        assert!(m.reps <= 3);
    }

    #[test]
    fn table_prints_and_csvs() {
        let mut t = Table::new("t", &["a", "b"]);
        t.row(&["1".into(), "x,y".into()]);
        t.print();
        let path = std::env::temp_dir().join("kvq_table_test.csv");
        t.write_csv(path.to_str().unwrap()).unwrap();
        let body = std::fs::read_to_string(&path).unwrap();
        assert!(body.contains("a,b"));
        assert!(body.contains("\"x,y\""));
        let _ = std::fs::remove_file(path);
    }

    #[test]
    #[should_panic(expected = "row width mismatch")]
    fn table_rejects_bad_rows() {
        let mut t = Table::new("t", &["a"]);
        t.row(&["1".into(), "2".into()]);
    }

    #[test]
    fn formatters() {
        assert_eq!(cell_speedup(1694.2), "1694x");
        assert_eq!(cell_speedup(3.5), "3.50x");
        assert_eq!(cell_f(0.00394, 5), "0.00394");
    }
}
