//! Tiny CLI argument parser (clap substitute).
//!
//! Supports `--flag`, `--key value`, `--key=value`, positional arguments,
//! and subcommands. Typed accessors with defaults; unknown-flag detection
//! via [`Args::finish`].

use std::collections::BTreeMap;

#[derive(Debug, Clone, Default)]
pub struct Args {
    pub positional: Vec<String>,
    /// value + whether it was greedily taken from the following token
    /// (as opposed to `--k=v` or a bare `--flag`).
    flags: BTreeMap<String, (String, bool)>,
    consumed: std::cell::RefCell<Vec<String>>,
    /// Tokens stolen by a `--flag tok` pair that `bool_or` later decided
    /// were positionals after all (boolean flag followed by a positional).
    restored: std::cell::RefCell<Vec<String>>,
}

impl Args {
    /// Parse from an explicit token list (tests) — `--k v`, `--k=v`, `--flag`.
    pub fn parse_from<I: IntoIterator<Item = String>>(it: I) -> Args {
        let mut positional = Vec::new();
        let mut flags = BTreeMap::new();
        let toks: Vec<String> = it.into_iter().collect();
        let mut i = 0;
        while i < toks.len() {
            let t = &toks[i];
            if let Some(body) = t.strip_prefix("--") {
                if let Some((k, v)) = body.split_once('=') {
                    flags.insert(k.to_string(), (v.to_string(), false));
                } else if i + 1 < toks.len() && !toks[i + 1].starts_with("--") {
                    flags.insert(body.to_string(), (toks[i + 1].clone(), true));
                    i += 1;
                } else {
                    flags.insert(body.to_string(), ("true".to_string(), false));
                }
            } else {
                positional.push(t.clone());
            }
            i += 1;
        }
        Args { positional, flags, consumed: Default::default(), restored: Default::default() }
    }

    /// Parse the process arguments (skipping argv[0]).
    pub fn parse() -> Args {
        Self::parse_from(std::env::args().skip(1))
    }

    /// First positional argument = subcommand; the rest shift down.
    pub fn subcommand(&mut self) -> Option<String> {
        if self.positional.is_empty() {
            None
        } else {
            Some(self.positional.remove(0))
        }
    }

    fn mark(&self, key: &str) {
        self.consumed.borrow_mut().push(key.to_string());
    }

    pub fn has(&self, key: &str) -> bool {
        self.mark(key);
        self.flags.contains_key(key)
    }

    pub fn get(&self, key: &str) -> Option<&str> {
        self.mark(key);
        self.flags.get(key).map(|(s, _)| s.as_str())
    }

    pub fn str_or(&self, key: &str, default: &str) -> String {
        self.get(key).unwrap_or(default).to_string()
    }

    pub fn usize_or(&self, key: &str, default: usize) -> usize {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| bad(key, v))).unwrap_or(default)
    }

    pub fn u64_or(&self, key: &str, default: u64) -> u64 {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| bad(key, v))).unwrap_or(default)
    }

    pub fn f64_or(&self, key: &str, default: f64) -> f64 {
        self.get(key).map(|v| v.parse().unwrap_or_else(|_| bad(key, v))).unwrap_or(default)
    }

    pub fn bool_or(&self, key: &str, default: bool) -> bool {
        self.mark(key);
        match self.flags.get(key) {
            None => default,
            Some((v, _)) if matches!(v.as_str(), "true" | "1" | "yes") => true,
            Some((v, _)) if matches!(v.as_str(), "false" | "0" | "no") => false,
            // `--flag positional`: the greedy parser stole a positional
            // token; give it back and treat the flag as present.
            Some((v, true)) => {
                self.restored.borrow_mut().push(v.clone());
                true
            }
            Some((v, false)) => bad(key, v),
        }
    }

    /// Positionals reclaimed by `bool_or` (call after flag parsing).
    pub fn take_restored(&self) -> Vec<String> {
        std::mem::take(&mut *self.restored.borrow_mut())
    }

    /// Comma-separated list value.
    pub fn list_or(&self, key: &str, default: &[&str]) -> Vec<String> {
        match self.get(key) {
            Some(v) => v.split(',').filter(|s| !s.is_empty()).map(String::from).collect(),
            None => default.iter().map(|s| s.to_string()).collect(),
        }
    }

    /// Error out on any flag that no accessor ever looked at (catches typos
    /// like `--ful` for `--full`).
    pub fn finish(&self) -> Result<(), String> {
        let seen = self.consumed.borrow();
        let unknown: Vec<&String> =
            self.flags.keys().filter(|k| !seen.iter().any(|s| s == *k)).collect();
        if unknown.is_empty() {
            Ok(())
        } else {
            Err(format!(
                "unknown flag(s): {}",
                unknown.iter().map(|s| format!("--{s}")).collect::<Vec<_>>().join(", ")
            ))
        }
    }
}

fn bad(key: &str, v: &str) -> ! {
    eprintln!("invalid value for --{key}: {v:?}");
    std::process::exit(2)
}

#[cfg(test)]
mod tests {
    use super::*;

    fn args(s: &str) -> Args {
        Args::parse_from(s.split_whitespace().map(String::from))
    }

    #[test]
    fn parses_kv_and_flags() {
        let a = args("--n 5 --mode=fast --verbose pos1 pos2");
        assert_eq!(a.usize_or("n", 0), 5);
        assert_eq!(a.str_or("mode", ""), "fast");
        // `--verbose pos1`: the parser greedily pairs them; bool_or
        // resolves the ambiguity and restores pos1.
        assert!(a.bool_or("verbose", false));
        assert_eq!(a.positional, vec!["pos2"]);
        assert_eq!(a.take_restored(), vec!["pos1"]);
    }

    #[test]
    fn subcommand_shifts() {
        let mut a = args("serve --port 8080");
        assert_eq!(a.subcommand().as_deref(), Some("serve"));
        assert_eq!(a.usize_or("port", 0), 8080);
        assert_eq!(a.subcommand(), None);
    }

    #[test]
    fn defaults_apply() {
        let a = args("");
        assert_eq!(a.usize_or("missing", 7), 7);
        assert_eq!(a.str_or("missing", "x"), "x");
        assert!(!a.bool_or("missing", false));
    }

    #[test]
    fn list_values() {
        let a = args("--variants naive,tiled");
        assert_eq!(a.list_or("variants", &[]), vec!["naive", "tiled"]);
        assert_eq!(a.list_or("other", &["a"]), vec!["a"]);
    }

    #[test]
    fn finish_catches_unknown() {
        let a = args("--known 1 --typo 2");
        let _ = a.usize_or("known", 0);
        let err = a.finish().unwrap_err();
        assert!(err.contains("--typo"), "{err}");
    }

    #[test]
    fn finish_ok_when_all_consumed() {
        let a = args("--x 1");
        let _ = a.usize_or("x", 0);
        assert!(a.finish().is_ok());
    }

    #[test]
    fn negative_numbers_as_values() {
        let a = args("--offset=-3");
        assert_eq!(a.f64_or("offset", 0.0), -3.0);
    }
}
