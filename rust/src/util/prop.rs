//! Mini property-testing framework (proptest substitute).
//!
//! A property is a closure over a [`Gen`] (seeded RNG wrapper with size
//! hints). The runner executes N cases with growing size; on failure it
//! re-runs with shrunken size parameters to report a smaller counterexample
//! seed, then panics with a reproduction line.
//!
//! ```ignore
//! check("quantize roundtrip bound", 200, |g| {
//!     let m = g.matrix(1..64, 1..64, -1.0..1.0);
//!     // ... assert invariant, return Ok(()) or Err(msg)
//!     Ok(())
//! });
//! ```

use super::rng::Rng;
use std::ops::Range;

/// Test-case generator: an RNG plus the current size budget.
pub struct Gen {
    pub rng: Rng,
    /// Grows from 0.1→1.0 across the run; generators scale ranges by it so
    /// early cases are small and failures shrink naturally.
    pub size: f64,
    pub case: usize,
}

impl Gen {
    fn scaled(&self, r: &Range<usize>) -> usize {
        let span = (r.end - r.start).max(1);
        let hi = r.start + ((span as f64 * self.size).ceil() as usize).clamp(1, span);
        r.start + (hi - r.start).max(1) - 1
    }

    /// Integer in `[r.start, r.end)`, biased small early in the run.
    pub fn usize_in(&mut self, r: Range<usize>) -> usize {
        assert!(r.start < r.end);
        let hi = self.scaled(&r).max(r.start);
        self.rng.range(r.start as i64, hi as i64) as usize
    }

    pub fn i64_in(&mut self, r: Range<i64>) -> i64 {
        assert!(r.start < r.end);
        self.rng.range(r.start, r.end - 1)
    }

    pub fn f32_in(&mut self, r: Range<f32>) -> f32 {
        self.rng.uniform(r.start, r.end)
    }

    pub fn bool(&mut self) -> bool {
        self.rng.next_u32() & 1 == 1
    }

    /// Pick one of the provided choices.
    pub fn choice<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.rng.below(xs.len() as u64) as usize]
    }

    /// A (rows, cols, data) matrix with values drawn from one of several
    /// distributions (uniform / normal / outlier-heavy / constant / zeros).
    pub fn matrix(
        &mut self,
        rows: Range<usize>,
        cols: Range<usize>,
        mag: f32,
    ) -> (usize, usize, Vec<f32>) {
        let t = self.usize_in(rows);
        let d = self.usize_in(cols);
        let mut data = vec![0.0f32; t * d];
        match self.rng.below(5) {
            0 => self.rng.fill_uniform(&mut data, -mag, mag),
            1 => self.rng.fill_normal(&mut data, mag / 2.0),
            2 => {
                self.rng.fill_normal(&mut data, mag / 2.0);
                // 1% outliers at 100x
                let n = (t * d / 100).max(1);
                for _ in 0..n {
                    let i = self.rng.below((t * d) as u64) as usize;
                    data[i] *= 100.0;
                }
            }
            3 => {
                let c = self.rng.uniform(-mag, mag);
                data.fill(c);
            }
            _ => { /* zeros */ }
        }
        (t, d, data)
    }
}

/// Run `cases` random cases of `prop`. Panics with a seed-reproduction
/// message on the first failure (after size-shrinking retries).
pub fn check<F>(name: &str, cases: usize, prop: F)
where
    F: Fn(&mut Gen) -> Result<(), String>,
{
    let base_seed = match std::env::var("KVQ_PROP_SEED") {
        Ok(s) => s.parse::<u64>().expect("KVQ_PROP_SEED must be u64"),
        Err(_) => 0xC0FFEE,
    };
    for case in 0..cases {
        let seed = base_seed.wrapping_add(case as u64);
        let size = 0.1 + 0.9 * (case as f64 + 1.0) / cases as f64;
        let mut g = Gen { rng: Rng::new(seed), size, case };
        if let Err(msg) = prop(&mut g) {
            // Shrink: retry the failing seed at smaller sizes to find the
            // smallest size that still fails (generators honor g.size).
            let mut smallest = (size, msg.clone());
            let mut lo = 0.05;
            let mut hi = size;
            for _ in 0..8 {
                let mid = (lo + hi) / 2.0;
                let mut g2 = Gen { rng: Rng::new(seed), size: mid, case };
                match prop(&mut g2) {
                    Err(m) => {
                        smallest = (mid, m);
                        hi = mid;
                    }
                    Ok(()) => lo = mid,
                }
            }
            panic!(
                "property '{name}' failed (case {case}, seed {seed}, size {:.2}): {}\n\
                 reproduce with: KVQ_PROP_SEED={seed} (case will differ; seed pins the stream)",
                smallest.0, smallest.1
            );
        }
    }
}

/// Assertion helpers returning `Result` for use inside properties.
pub fn ensure(cond: bool, msg: impl Into<String>) -> Result<(), String> {
    if cond {
        Ok(())
    } else {
        Err(msg.into())
    }
}

pub fn ensure_close(a: f64, b: f64, tol: f64, ctx: &str) -> Result<(), String> {
    if (a - b).abs() <= tol {
        Ok(())
    } else {
        Err(format!("{ctx}: {a} vs {b} (tol {tol})"))
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn passing_property_runs_all_cases() {
        let mut count = 0usize;
        let counter = std::cell::RefCell::new(&mut count);
        check("always ok", 50, |g| {
            let _ = g.usize_in(1..10);
            **counter.borrow_mut() += 1;
            Ok(())
        });
        assert_eq!(count, 50);
    }

    #[test]
    #[should_panic(expected = "property 'always fails' failed")]
    fn failing_property_panics_with_seed() {
        check("always fails", 10, |_g| Err("nope".into()));
    }

    #[test]
    fn generators_respect_ranges() {
        check("ranges", 100, |g| {
            let v = g.usize_in(3..17);
            ensure((3..17).contains(&v), format!("usize_in out of range: {v}"))?;
            let f = g.f32_in(-2.0..2.0);
            ensure((-2.0..2.0).contains(&f), "f32_in out of range")?;
            let (t, d, data) = g.matrix(1..8, 1..8, 1.0);
            ensure(data.len() == t * d, "matrix size")?;
            Ok(())
        });
    }

    #[test]
    fn sizes_grow_across_run() {
        let mut maxes = Vec::new();
        let collector = std::cell::RefCell::new(&mut maxes);
        check("size growth", 100, |g| {
            collector.borrow_mut().push(g.size);
            Ok(())
        });
        assert!(maxes.first().unwrap() < maxes.last().unwrap());
        assert!((maxes.last().unwrap() - 1.0).abs() < 1e-9);
    }

    #[test]
    fn ensure_close_tolerance() {
        assert!(ensure_close(1.0, 1.0005, 0.001, "x").is_ok());
        assert!(ensure_close(1.0, 1.1, 0.001, "x").is_err());
    }
}
