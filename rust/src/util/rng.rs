//! Deterministic pseudo-random number generation.
//!
//! PCG-XSH-RR 64/32 (O'Neill 2014) seeded through SplitMix64. Used for
//! synthetic workloads, model weights, and the property-test framework;
//! everything in the repo that consumes randomness takes an explicit seed
//! so runs are reproducible bit-for-bit.

/// PCG32 generator (64-bit state, 32-bit output).
#[derive(Debug, Clone)]
pub struct Rng {
    state: u64,
    inc: u64,
}

const MULT: u64 = 6364136223846793005;

impl Rng {
    /// Seed via SplitMix64 so nearby seeds give unrelated streams.
    pub fn new(seed: u64) -> Self {
        let mut sm = seed;
        let mut next = || {
            sm = sm.wrapping_add(0x9E3779B97F4A7C15);
            let mut z = sm;
            z = (z ^ (z >> 30)).wrapping_mul(0xBF58476D1CE4E5B9);
            z = (z ^ (z >> 27)).wrapping_mul(0x94D049BB133111EB);
            z ^ (z >> 31)
        };
        let mut rng = Rng { state: next(), inc: next() | 1 };
        rng.next_u32(); // advance past the seed-correlated first output
        rng
    }

    /// Derive an independent child stream (for per-request / per-head RNGs).
    pub fn fork(&mut self, tag: u64) -> Rng {
        Rng::new(self.next_u64() ^ tag.wrapping_mul(0x9E3779B97F4A7C15))
    }

    pub fn next_u32(&mut self) -> u32 {
        let old = self.state;
        self.state = old.wrapping_mul(MULT).wrapping_add(self.inc);
        let xorshifted = (((old >> 18) ^ old) >> 27) as u32;
        let rot = (old >> 59) as u32;
        xorshifted.rotate_right(rot)
    }

    pub fn next_u64(&mut self) -> u64 {
        ((self.next_u32() as u64) << 32) | self.next_u32() as u64
    }

    /// Uniform in `[0, 1)` with 24 bits of mantissa entropy.
    pub fn next_f32(&mut self) -> f32 {
        (self.next_u32() >> 8) as f32 * (1.0 / (1 << 24) as f32)
    }

    /// Uniform in `[0, 1)` with 53 bits.
    pub fn next_f64(&mut self) -> f64 {
        (self.next_u64() >> 11) as f64 * (1.0 / (1u64 << 53) as f64)
    }

    /// Uniform in `[lo, hi)`.
    pub fn uniform(&mut self, lo: f32, hi: f32) -> f32 {
        lo + (hi - lo) * self.next_f32()
    }

    /// Unbiased integer in `[0, n)` (Lemire's multiply-shift with rejection).
    pub fn below(&mut self, n: u64) -> u64 {
        assert!(n > 0, "below(0)");
        let mut x = self.next_u64();
        let mut m = (x as u128) * (n as u128);
        let mut l = m as u64;
        if l < n {
            let t = n.wrapping_neg() % n;
            while l < t {
                x = self.next_u64();
                m = (x as u128) * (n as u128);
                l = m as u64;
            }
        }
        (m >> 64) as u64
    }

    /// Integer in `[lo, hi]` inclusive.
    pub fn range(&mut self, lo: i64, hi: i64) -> i64 {
        assert!(lo <= hi);
        lo + self.below((hi - lo + 1) as u64) as i64
    }

    /// Standard normal via Box–Muller.
    pub fn normal(&mut self) -> f32 {
        loop {
            let u1 = self.next_f64();
            if u1 <= f64::MIN_POSITIVE {
                continue;
            }
            let u2 = self.next_f64();
            let r = (-2.0 * u1.ln()).sqrt();
            return (r * (2.0 * std::f64::consts::PI * u2).cos()) as f32;
        }
    }

    /// Exponential with rate `lambda` (inter-arrival times for the
    /// serving workload generator).
    pub fn exponential(&mut self, lambda: f64) -> f64 {
        let u = 1.0 - self.next_f64();
        -u.ln() / lambda
    }

    /// Fill a buffer with U(lo, hi) floats.
    pub fn fill_uniform(&mut self, buf: &mut [f32], lo: f32, hi: f32) {
        for v in buf {
            *v = self.uniform(lo, hi);
        }
    }

    /// Fill a buffer with N(0, sigma) floats.
    pub fn fill_normal(&mut self, buf: &mut [f32], sigma: f32) {
        for v in buf {
            *v = self.normal() * sigma;
        }
    }

    /// Fisher–Yates shuffle.
    pub fn shuffle<T>(&mut self, xs: &mut [T]) {
        for i in (1..xs.len()).rev() {
            let j = self.below(i as u64 + 1) as usize;
            xs.swap(i, j);
        }
    }

    /// Pick one element by reference.
    pub fn choose<'a, T>(&mut self, xs: &'a [T]) -> &'a T {
        &xs[self.below(xs.len() as u64) as usize]
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn deterministic_per_seed() {
        let mut a = Rng::new(7);
        let mut b = Rng::new(7);
        for _ in 0..100 {
            assert_eq!(a.next_u64(), b.next_u64());
        }
    }

    #[test]
    fn different_seeds_differ() {
        let a: Vec<u32> = (0..8).map(|_| 0).scan(Rng::new(1), |r, _| Some(r.next_u32())).collect();
        let b: Vec<u32> = (0..8).map(|_| 0).scan(Rng::new(2), |r, _| Some(r.next_u32())).collect();
        assert_ne!(a, b);
    }

    #[test]
    fn f32_in_unit_interval() {
        let mut r = Rng::new(3);
        for _ in 0..10_000 {
            let x = r.next_f32();
            assert!((0.0..1.0).contains(&x));
        }
    }

    #[test]
    fn uniform_mean_is_centered() {
        let mut r = Rng::new(4);
        let n = 100_000;
        let mean: f64 = (0..n).map(|_| r.uniform(-1.0, 1.0) as f64).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.01, "mean {mean}");
    }

    #[test]
    fn below_bounds_and_coverage() {
        let mut r = Rng::new(5);
        let mut seen = [false; 10];
        for _ in 0..1000 {
            let v = r.below(10) as usize;
            assert!(v < 10);
            seen[v] = true;
        }
        assert!(seen.iter().all(|&s| s));
    }

    #[test]
    fn normal_moments() {
        let mut r = Rng::new(6);
        let n = 100_000;
        let xs: Vec<f64> = (0..n).map(|_| r.normal() as f64).collect();
        let mean = xs.iter().sum::<f64>() / n as f64;
        let var = xs.iter().map(|x| (x - mean).powi(2)).sum::<f64>() / n as f64;
        assert!(mean.abs() < 0.02, "mean {mean}");
        assert!((var - 1.0).abs() < 0.03, "var {var}");
    }

    #[test]
    fn exponential_mean() {
        let mut r = Rng::new(8);
        let n = 50_000;
        let mean = (0..n).map(|_| r.exponential(2.0)).sum::<f64>() / n as f64;
        assert!((mean - 0.5).abs() < 0.02, "mean {mean}");
    }

    #[test]
    fn shuffle_is_permutation() {
        let mut r = Rng::new(9);
        let mut xs: Vec<u32> = (0..50).collect();
        r.shuffle(&mut xs);
        let mut sorted = xs.clone();
        sorted.sort_unstable();
        assert_eq!(sorted, (0..50).collect::<Vec<_>>());
        assert_ne!(xs, (0..50).collect::<Vec<_>>()); // astronomically unlikely
    }

    #[test]
    fn fork_streams_are_independent() {
        let mut root = Rng::new(10);
        let mut a = root.fork(1);
        let mut b = root.fork(2);
        let av: Vec<u32> = (0..4).map(|_| a.next_u32()).collect();
        let bv: Vec<u32> = (0..4).map(|_| b.next_u32()).collect();
        assert_ne!(av, bv);
    }

    #[test]
    fn range_inclusive() {
        let mut r = Rng::new(11);
        for _ in 0..1000 {
            let v = r.range(-3, 3);
            assert!((-3..=3).contains(&v));
        }
    }
}
