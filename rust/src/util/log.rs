//! Leveled stderr logger (env_logger substitute).
//!
//! Level comes from `KVQ_LOG` (error|warn|info|debug|trace, default info)
//! or [`set_level`]. Timestamps are seconds since process start — stable
//! across runs, cheap, and adequate for a single-process server.

use std::io::Write;
use std::sync::atomic::{AtomicU8, Ordering};
use std::sync::OnceLock;
use std::time::Instant;

#[derive(Debug, Clone, Copy, PartialEq, Eq, PartialOrd, Ord)]
#[repr(u8)]
pub enum Level {
    Error = 0,
    Warn = 1,
    Info = 2,
    Debug = 3,
    Trace = 4,
}

impl Level {
    pub fn from_str(s: &str) -> Option<Level> {
        Some(match s.to_ascii_lowercase().as_str() {
            "error" => Level::Error,
            "warn" => Level::Warn,
            "info" => Level::Info,
            "debug" => Level::Debug,
            "trace" => Level::Trace,
            _ => return None,
        })
    }

    fn tag(self) -> &'static str {
        match self {
            Level::Error => "ERROR",
            Level::Warn => "WARN ",
            Level::Info => "INFO ",
            Level::Debug => "DEBUG",
            Level::Trace => "TRACE",
        }
    }
}

static LEVEL: AtomicU8 = AtomicU8::new(u8::MAX); // MAX = uninitialized
static START: OnceLock<Instant> = OnceLock::new();

fn level() -> u8 {
    let v = LEVEL.load(Ordering::Relaxed);
    if v != u8::MAX {
        return v;
    }
    let init = std::env::var("KVQ_LOG")
        .ok()
        .and_then(|s| Level::from_str(&s))
        .unwrap_or(Level::Info) as u8;
    LEVEL.store(init, Ordering::Relaxed);
    init
}

pub fn set_level(l: Level) {
    LEVEL.store(l as u8, Ordering::Relaxed);
}

pub fn enabled(l: Level) -> bool {
    (l as u8) <= level()
}

pub fn log(l: Level, target: &str, msg: std::fmt::Arguments<'_>) {
    if !enabled(l) {
        return;
    }
    let t = START.get_or_init(Instant::now).elapsed().as_secs_f64();
    let mut err = std::io::stderr().lock();
    let _ = writeln!(err, "[{t:9.3} {} {target}] {msg}", l.tag());
}

#[macro_export]
macro_rules! error {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Error, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! warn {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Warn, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! info {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Info, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! debug {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Debug, module_path!(), format_args!($($arg)*)) };
}
#[macro_export]
macro_rules! trace {
    ($($arg:tt)*) => { $crate::util::log::log($crate::util::log::Level::Trace, module_path!(), format_args!($($arg)*)) };
}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn level_parsing() {
        assert_eq!(Level::from_str("debug"), Some(Level::Debug));
        assert_eq!(Level::from_str("WARN"), Some(Level::Warn));
        assert_eq!(Level::from_str("nope"), None);
    }

    #[test]
    fn level_gating() {
        set_level(Level::Warn);
        assert!(enabled(Level::Error));
        assert!(enabled(Level::Warn));
        assert!(!enabled(Level::Info));
        set_level(Level::Info); // restore default-ish for other tests
    }

    #[test]
    fn macros_compile_and_run() {
        set_level(Level::Error);
        crate::info!("not shown {}", 1);
        crate::error!("shown {}", 2);
        set_level(Level::Info);
    }
}
