//! The paper's three error metrics (§6 "we measure", §7.2, §7.3):
//! L2 (Frobenius) reconstruction error, max absolute error, and the
//! attention-score error |qK^T − qK̂^T| averaged over (query, token)
//! pairs — plus the value/output-side twin |PV − PV̂| (the K-side metric
//! alone says nothing about the second half of the fused attention read,
//! the softmax·V accumulation).

use super::matrix::Fp32Matrix;

/// sqrt(sum((a-b)^2)) in f64 accumulation.
pub fn l2_error(a: &Fp32Matrix, b: &Fp32Matrix) -> f64 {
    assert_shapes(a, b);
    let mut acc = 0.0f64;
    for (x, y) in a.data.iter().zip(&b.data) {
        let d = (*x - *y) as f64;
        acc += d * d;
    }
    acc.sqrt()
}

/// max |a - b| per element.
pub fn max_abs_error(a: &Fp32Matrix, b: &Fp32Matrix) -> f64 {
    assert_shapes(a, b);
    a.data
        .iter()
        .zip(&b.data)
        .map(|(x, y)| ((*x - *y) as f64).abs())
        .fold(0.0, f64::max)
}

/// Mean |q·k − q·k̂| over all (query row, token row) pairs.
///
/// `queries`: (Nq, D); `k`, `k_hat`: (T, D). No 1/sqrt(D) factor — the
/// paper measures raw attention dot products. Blocked matmul keeps this
/// usable at bench sizes; f64 accumulation keeps it stable.
pub fn attention_score_error(queries: &Fp32Matrix, k: &Fp32Matrix, k_hat: &Fp32Matrix) -> f64 {
    assert_shapes(k, k_hat);
    assert_eq!(queries.cols, k.cols, "query/key dim mismatch");
    let (nq, t, d) = (queries.rows, k.rows, k.cols);
    let mut acc = 0.0f64;
    // For each (query, token): |q · (k - k_hat)|. Computing the diff row
    // once per token and dotting against all queries is O(T·D + T·Nq·D)
    // same as two matmuls but with half the memory traffic.
    let mut diff = vec![0.0f32; d];
    for ti in 0..t {
        let kr = k.row(ti);
        let khr = k_hat.row(ti);
        for ((df, &x), &y) in diff.iter_mut().zip(kr).zip(khr) {
            *df = x - y;
        }
        for qi in 0..nq {
            let q = queries.row(qi);
            let mut dot = 0.0f64;
            for (a, b) in q.iter().zip(&diff) {
                dot += (*a as f64) * (*b as f64);
            }
            acc += dot.abs();
        }
    }
    acc / (nq as f64 * t as f64)
}

/// Mean |(P·V)[q,ch] − (P·V̂)[q,ch]| over all (query row, channel) pairs
/// — the value/output-side twin of [`attention_score_error`].
///
/// `probs`: (Nq, T) attention weights (softmax rows, but any weights
/// work); `v`, `v_hat`: (T, D). This measures what V-quantization does to
/// the attention *output* — the half of the error story the K-side metric
/// can't see. f64 accumulation keeps it stable at bench sizes.
pub fn value_output_error(probs: &Fp32Matrix, v: &Fp32Matrix, v_hat: &Fp32Matrix) -> f64 {
    assert_shapes(v, v_hat);
    assert_eq!(probs.cols, v.rows, "probs/value token-count mismatch");
    let (nq, t, d) = (probs.rows, v.rows, v.cols);
    // Accumulate P·(V − V̂) row-by-row over tokens: O(T·D + T·Nq·D), one
    // diff row resident at a time (same structure as the K-side metric).
    let mut acc = vec![0.0f64; nq * d];
    let mut diff = vec![0.0f64; d];
    for ti in 0..t {
        let vr = v.row(ti);
        let vhr = v_hat.row(ti);
        for ((df, &x), &y) in diff.iter_mut().zip(vr).zip(vhr) {
            *df = (x - y) as f64;
        }
        for qi in 0..nq {
            let p = probs.at(qi, ti) as f64;
            if p == 0.0 {
                continue;
            }
            let out = &mut acc[qi * d..(qi + 1) * d];
            for (o, &df) in out.iter_mut().zip(&diff) {
                *o += p * df;
            }
        }
    }
    acc.iter().map(|x| x.abs()).sum::<f64>() / (nq as f64 * d as f64)
}

fn assert_shapes(a: &Fp32Matrix, b: &Fp32Matrix) {
    assert_eq!((a.rows, a.cols), (b.rows, b.cols), "shape mismatch");
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::dequantize::dequantize;
    use crate::quant::quantize::quantize_fused;

    #[test]
    fn identity_errors_are_zero() {
        // Paper §7.5: all metrics evaluate to zero against self.
        let k = Fp32Matrix::random_normal(32, 16, 1.0, 1);
        let q = Fp32Matrix::random_normal(4, 16, 1.0, 2);
        assert_eq!(l2_error(&k, &k), 0.0);
        assert_eq!(max_abs_error(&k, &k), 0.0);
        assert_eq!(attention_score_error(&q, &k, &k), 0.0);
    }

    #[test]
    fn l2_hand_computed() {
        let a = Fp32Matrix::from_vec(1, 2, vec![0.0, 0.0]);
        let b = Fp32Matrix::from_vec(1, 2, vec![3.0, 4.0]);
        assert!((l2_error(&a, &b) - 5.0).abs() < 1e-12);
        assert_eq!(max_abs_error(&a, &b), 4.0);
    }

    #[test]
    fn attention_error_hand_computed() {
        // q = [1, 1]; k - k_hat = [0.5, -0.25] -> |dot| = 0.25.
        let q = Fp32Matrix::from_vec(1, 2, vec![1.0, 1.0]);
        let k = Fp32Matrix::from_vec(1, 2, vec![1.0, 1.0]);
        let kh = Fp32Matrix::from_vec(1, 2, vec![0.5, 1.25]);
        assert!((attention_score_error(&q, &k, &kh) - 0.25).abs() < 1e-9);
    }

    #[test]
    fn value_output_error_hand_computed() {
        // p = [0.5, 0.5]; v - v_hat rows = [2, 0], [0, -4]
        // P·diff = [1, -2] -> mean abs = 1.5.
        let p = Fp32Matrix::from_vec(1, 2, vec![0.5, 0.5]);
        let v = Fp32Matrix::from_vec(2, 2, vec![2.0, 0.0, 0.0, 0.0]);
        let vh = Fp32Matrix::from_vec(2, 2, vec![0.0, 0.0, 0.0, 4.0]);
        assert!((value_output_error(&p, &v, &vh) - 1.5).abs() < 1e-9);
        assert_eq!(value_output_error(&p, &v, &v), 0.0);
    }

    #[test]
    fn value_output_error_bounded_by_quant_step() {
        // Uniform attention weights over T tokens average out the
        // per-element quantization noise: the output error must land far
        // below the raw per-element bound s/2.
        let t = 512;
        let v = Fp32Matrix::random_uniform(t, 32, -1.0, 1.0, 11);
        let rec = dequantize(&quantize_fused(&v));
        let p = Fp32Matrix::from_vec(4, t, vec![1.0 / t as f32; 4 * t]);
        let e = value_output_error(&p, &v, &rec);
        assert!(e > 0.0, "quantization noise must register");
        assert!(e < 1.0 / 254.0, "averaged output error {e} above per-element bound");
    }

    #[test]
    fn uniform_inputs_hit_paper_max_error() {
        // §7.2: U(-1,1) -> max abs error ≈ 1/(2·127) = 0.003937.
        let k = Fp32Matrix::random_uniform(4096, 128, -1.0, 1.0, 7);
        let r = dequantize(&quantize_fused(&k));
        let e = max_abs_error(&k, &r);
        assert!(e <= 1.0 / 254.0 + 1e-7, "max err {e}");
        assert!(e >= 0.0035, "max err suspiciously small: {e}");
    }

    #[test]
    fn l2_grows_with_matrix_size() {
        let mut prev = 0.0;
        for t in [256usize, 1024, 4096] {
            let k = Fp32Matrix::random_uniform(t, 64, -1.0, 1.0, t as u64);
            let r = dequantize(&quantize_fused(&k));
            let e = l2_error(&k, &r);
            assert!(e > prev, "L2 {e} did not grow at T={t}");
            prev = e;
        }
    }

    #[test]
    fn attention_error_grows_sqrt_d() {
        // §7.3: error scales ~sqrt(D).
        let mut errs = Vec::new();
        for d in [64usize, 256, 1024] {
            let k = Fp32Matrix::random_uniform(512, d, -1.0, 1.0, d as u64);
            let q = Fp32Matrix::random_uniform(8, d, -1.0, 1.0, 99);
            let r = dequantize(&quantize_fused(&k));
            errs.push(attention_score_error(&q, &k, &r));
        }
        assert!(errs[0] < errs[1] && errs[1] < errs[2]);
        let r1 = errs[1] / errs[0];
        let r2 = errs[2] / errs[1];
        assert!(r1 > 1.3 && r1 < 3.0, "ratio {r1}");
        assert!(r2 > 1.3 && r2 < 3.0, "ratio {r2}");
    }
}
