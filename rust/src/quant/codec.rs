//! Storage codecs — the per-precision encode/decode/fused-read strategy
//! behind every cache page.
//!
//! Before this layer existed, each precision was a `match` arm scattered
//! across the cache manager (three prefill writers, a per-row append
//! writer), the engine (staging layout, INT4 special cases), and the
//! paged decode path (three slab-read arms). A [`Codec`] collapses all of
//! that into one object per precision:
//!
//! * **byte layout** — [`Codec::bytes_per_row`] is the single source of
//!   truth for row packing (INT4's `ceil(d/2)` nibble rows included), and
//!   [`Codec::qmax`] owns the symmetric scale grid (127 vs 7) that
//!   `kvcache/manager.rs` used to re-derive by hand;
//! * **writers** — [`Codec::encode_row`] quantizes (or copies) one
//!   `(d,)` row into raw page bytes; prefill and decode-append both
//!   route through it;
//! * **readers** — [`Codec::decode_row`] unpacks one row, and the fused
//!   [`Codec::dot_rows`]/[`Codec::accumulate_rows`] attend over a raw
//!   slab **in place**, delegating to the [`super::simd`] dispatch layer
//!   (scalar fallback = the paper's four [`super::attn`] kernel
//!   variants, bit-identical to the pre-codec per-precision arms; AVX2 /
//!   NEON when the resolved `kernel_backend` selects them).
//!
//! Codecs are stateless: the canonical instances live in statics and are
//! handed around as `&'static dyn Codec` (see
//! `kvcache::policy::codec_for`). Precision policies
//! (`kvcache/policy.rs`) map `(layer, head, K|V side) → codec`, which is
//! what makes mixed-precision caches (keys INT8 / values INT4, FP32 sink
//! layers, …) a table lookup instead of a cross-cutting refactor.

use super::int4::Q4MAX;
use super::simd::{self, Isa, MqMember};
use super::Variant;
use crate::QMAX;

/// One storage precision's full strategy: byte layout, scale grid,
/// row encode/decode, and fused in-place attention reads.
///
/// Every method takes the resolved kernel [`Isa`] and dispatches through
/// [`super::simd`] — `Isa::Scalar` is the pre-backend code path, bit for
/// bit.
///
/// **Bit-stability contract (per backend).** Under `Isa::Scalar`,
/// `dot_rows`/`accumulate_rows` compute the identical float expressions
/// in the identical order as the [`super::attn`] kernels (INT8), the
/// dense f32 twins (FP32), or the row-unpack loop (INT4) — swapping a
/// cache between staged and paged access, or between codec dispatch and
/// the old hand-written arms, can never change an output bit. The SIMD
/// backends keep encode/decode/accumulate bit-identical to scalar and
/// reassociate only the score-pass dot (see the [`super::simd`] module
/// docs). Asserted by this module's tests and
/// `tests/parallel_consistency.rs`.
pub trait Codec: Sync {
    /// Short name ("fp32" | "int8" | "int4").
    fn name(&self) -> &'static str;

    /// Symmetric quantization bound — the divisor of the frozen-scale
    /// grid (`scale = abs_max · margin / qmax`). FP32 pages keep the
    /// INT8 grid so their (unused) frozen scales stay bit-identical to
    /// the pre-codec paths.
    fn qmax(&self) -> f32;

    /// Payload bytes of one `d`-channel row. Per-row, not per-slab: an
    /// INT4 row is `ceil(d/2)` bytes even when `d` is odd, so slab
    /// accounting must multiply rows by this instead of flattening the
    /// element count first.
    fn bytes_per_row(&self, d: usize) -> usize;

    /// Whether a dense `(L, H, S, d)` staging layout exists for this
    /// encoding (the artifact/staged-decode ABI). Packed nibbles have
    /// none, which is why any policy touching INT4 needs a paged-capable
    /// backend.
    fn supports_staged(&self) -> bool {
        true
    }

    /// Byte alignment this codec's slabs need inside a block (FP32 reads
    /// its payload as `&[f32]` in place, so mixed-precision stream
    /// layouts must start its head slabs on 4-byte boundaries).
    fn row_align(&self) -> usize {
        1
    }

    /// Encode one row into `bytes_per_row(row.len())` raw page bytes
    /// (quantize for integer codecs, bit-exact copy for FP32).
    /// The emitted bytes never depend on `isa` (per-backend contract:
    /// encode is bit-identical across kernel backends).
    fn encode_row(&self, isa: Isa, row: &[f32], scales: &[f32], out: &mut [u8]);

    /// Decode one row of raw page bytes back to f32.
    /// Bit-identical across kernel backends.
    fn decode_row(&self, isa: Isa, bytes: &[u8], scales: &[f32], out: &mut [f32]);

    /// Fused dequant·dot of `q` against `out.len()` consecutive rows
    /// stored raw in `blk`: `out[r] = Σ_ch q[ch] · roŵ[r][ch]`, channels
    /// ascending. `scratch` is a reusable O(d) buffer for codecs that
    /// must unpack a row before dotting (INT4); others ignore it.
    fn dot_rows(
        &self,
        isa: Isa,
        variant: Variant,
        q: &[f32],
        blk: &[u8],
        scales: &[f32],
        scratch: &mut Vec<f32>,
        out: &mut [f32],
    );

    /// Fused softmax·V accumulation over `w.len()` raw rows:
    /// `acc[ch] += Σ_r w[r] · roŵ[r][ch]`, rows ascending per channel.
    fn accumulate_rows(
        &self,
        isa: Isa,
        variant: Variant,
        w: &[f32],
        blk: &[u8],
        scales: &[f32],
        scratch: &mut Vec<f32>,
        acc: &mut [f32],
    );

    /// Fused **multi-query** dequant·dot: every member's `d`-channel
    /// query (at `q_arena[m.inp..]`) is dotted against the same raw slab
    /// in one pass, scores landing at `out_arena[m.out..]`. The slab is
    /// read (and for integer codecs dequantized) once for the whole
    /// wave; per member the result is bit-identical to a
    /// [`Codec::dot_rows`] call on the same `isa` (the batched-decode
    /// contract — see the [`super::simd`] mq dispatcher docs).
    fn dot_rows_mq(
        &self,
        isa: Isa,
        variant: Variant,
        d: usize,
        q_arena: &[f32],
        blk: &[u8],
        scales: &[f32],
        members: &[MqMember],
        scratch: &mut Vec<f32>,
        out_arena: &mut [f32],
    );

    /// Fused **multi-query** softmax·V accumulation: every member's
    /// `rows` weights (at `w_arena[m.inp..]`) accumulate the same raw
    /// slab into its accumulator (at `acc_arena[m.out..]`), rows
    /// ascending per member. Bit-identical per member to
    /// [`Codec::accumulate_rows`] on the same `isa`.
    fn accumulate_rows_mq(
        &self,
        isa: Isa,
        variant: Variant,
        d: usize,
        w_arena: &[f32],
        blk: &[u8],
        scales: &[f32],
        members: &[MqMember],
        scratch: &mut Vec<f32>,
        acc_arena: &mut [f32],
    );
}

/// FP32 passthrough codec (baseline precision; 4 bytes/element).
pub struct Fp32Codec;
/// Per-channel symmetric INT8 (the paper's core algorithm).
pub struct Int8Codec;
/// Per-channel symmetric INT4, two nibbles per byte (§8.1 extension).
pub struct Int4Codec;

/// The canonical codec instances (stateless — share freely).
pub static FP32: Fp32Codec = Fp32Codec;
pub static INT8: Int8Codec = Int8Codec;
pub static INT4: Int4Codec = Int4Codec;

/// Reinterpret raw page bytes as i8 (alignment-free). Shared with the
/// cache's typed `StreamView` accessors so the unsafe reinterpret logic
/// lives in exactly one place.
#[inline]
pub(crate) fn as_i8(raw: &[u8]) -> &[i8] {
    // SAFETY: i8 and u8 have identical layout and 1-byte alignment.
    unsafe { std::slice::from_raw_parts(raw.as_ptr() as *const i8, raw.len()) }
}

#[inline]
fn as_i8_mut(raw: &mut [u8]) -> &mut [i8] {
    // SAFETY: as above.
    unsafe { std::slice::from_raw_parts_mut(raw.as_mut_ptr() as *mut i8, raw.len()) }
}

/// Reinterpret raw page bytes as f32 rows. Pool blocks are 4-byte
/// multiples for FP32 streams and the slab base comes from a `Vec<u8>`
/// heap allocation, so the pointer is f32-aligned in practice; the
/// debug assert pins that assumption.
#[inline]
pub(crate) fn as_f32(raw: &[u8]) -> &[f32] {
    debug_assert_eq!(raw.len() % 4, 0);
    debug_assert_eq!(raw.as_ptr() as usize % std::mem::align_of::<f32>(), 0);
    // SAFETY: length and alignment checked above; any bit pattern is a
    // valid f32.
    unsafe { std::slice::from_raw_parts(raw.as_ptr() as *const f32, raw.len() / 4) }
}

impl Codec for Fp32Codec {
    fn name(&self) -> &'static str {
        "fp32"
    }

    fn qmax(&self) -> f32 {
        QMAX
    }

    fn bytes_per_row(&self, d: usize) -> usize {
        d * 4
    }

    fn row_align(&self) -> usize {
        4
    }

    fn encode_row(&self, _isa: Isa, row: &[f32], _scales: &[f32], out: &mut [u8]) {
        debug_assert_eq!(out.len(), row.len() * 4);
        for (dst, v) in out.chunks_exact_mut(4).zip(row) {
            dst.copy_from_slice(&v.to_ne_bytes());
        }
    }

    fn decode_row(&self, _isa: Isa, bytes: &[u8], _scales: &[f32], out: &mut [f32]) {
        debug_assert_eq!(bytes.len(), out.len() * 4);
        for (src, v) in bytes.chunks_exact(4).zip(out.iter_mut()) {
            *v = f32::from_ne_bytes([src[0], src[1], src[2], src[3]]);
        }
    }

    fn dot_rows(
        &self,
        isa: Isa,
        _variant: Variant,
        q: &[f32],
        blk: &[u8],
        _scales: &[f32],
        _scratch: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        simd::dot_rows_f32(isa, q, as_f32(blk), out);
    }

    fn accumulate_rows(
        &self,
        isa: Isa,
        _variant: Variant,
        w: &[f32],
        blk: &[u8],
        _scales: &[f32],
        _scratch: &mut Vec<f32>,
        acc: &mut [f32],
    ) {
        simd::accumulate_rows_f32(isa, w, as_f32(blk), acc);
    }

    fn dot_rows_mq(
        &self,
        isa: Isa,
        _variant: Variant,
        d: usize,
        q_arena: &[f32],
        blk: &[u8],
        _scales: &[f32],
        members: &[MqMember],
        _scratch: &mut Vec<f32>,
        out_arena: &mut [f32],
    ) {
        simd::dot_rows_f32_mq(isa, d, q_arena, as_f32(blk), members, out_arena);
    }

    fn accumulate_rows_mq(
        &self,
        isa: Isa,
        _variant: Variant,
        d: usize,
        w_arena: &[f32],
        blk: &[u8],
        _scales: &[f32],
        members: &[MqMember],
        _scratch: &mut Vec<f32>,
        acc_arena: &mut [f32],
    ) {
        simd::accumulate_rows_f32_mq(isa, d, w_arena, as_f32(blk), members, acc_arena);
    }
}

impl Codec for Int8Codec {
    fn name(&self) -> &'static str {
        "int8"
    }

    fn qmax(&self) -> f32 {
        QMAX
    }

    fn bytes_per_row(&self, d: usize) -> usize {
        d
    }

    fn encode_row(&self, isa: Isa, row: &[f32], scales: &[f32], out: &mut [u8]) {
        simd::quantize_row_into(isa, row, scales, as_i8_mut(out));
    }

    fn decode_row(&self, isa: Isa, bytes: &[u8], scales: &[f32], out: &mut [f32]) {
        simd::dequantize_row_into(isa, as_i8(bytes), scales, out);
    }

    fn dot_rows(
        &self,
        isa: Isa,
        variant: Variant,
        q: &[f32],
        blk: &[u8],
        scales: &[f32],
        _scratch: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        simd::dot_rows_i8(isa, variant, q, as_i8(blk), scales, out);
    }

    fn accumulate_rows(
        &self,
        isa: Isa,
        variant: Variant,
        w: &[f32],
        blk: &[u8],
        scales: &[f32],
        _scratch: &mut Vec<f32>,
        acc: &mut [f32],
    ) {
        simd::accumulate_rows_i8(isa, variant, w, as_i8(blk), scales, acc);
    }

    fn dot_rows_mq(
        &self,
        isa: Isa,
        variant: Variant,
        d: usize,
        q_arena: &[f32],
        blk: &[u8],
        scales: &[f32],
        members: &[MqMember],
        scratch: &mut Vec<f32>,
        out_arena: &mut [f32],
    ) {
        simd::dot_rows_i8_mq(
            isa,
            variant,
            d,
            q_arena,
            as_i8(blk),
            scales,
            members,
            scratch,
            out_arena,
        );
    }

    fn accumulate_rows_mq(
        &self,
        isa: Isa,
        variant: Variant,
        d: usize,
        w_arena: &[f32],
        blk: &[u8],
        scales: &[f32],
        members: &[MqMember],
        scratch: &mut Vec<f32>,
        acc_arena: &mut [f32],
    ) {
        simd::accumulate_rows_i8_mq(
            isa,
            variant,
            d,
            w_arena,
            as_i8(blk),
            scales,
            members,
            scratch,
            acc_arena,
        );
    }
}

impl Codec for Int4Codec {
    fn name(&self) -> &'static str {
        "int4"
    }

    fn qmax(&self) -> f32 {
        Q4MAX
    }

    fn bytes_per_row(&self, d: usize) -> usize {
        d.div_ceil(2)
    }

    fn supports_staged(&self) -> bool {
        false
    }

    fn encode_row(&self, isa: Isa, row: &[f32], scales: &[f32], out: &mut [u8]) {
        simd::quantize4_row_into(isa, row, scales, out);
    }

    fn decode_row(&self, isa: Isa, bytes: &[u8], scales: &[f32], out: &mut [f32]) {
        simd::dequantize4_row_into(isa, bytes, scales, out);
    }

    fn dot_rows(
        &self,
        isa: Isa,
        _variant: Variant,
        q: &[f32],
        blk: &[u8],
        scales: &[f32],
        scratch: &mut Vec<f32>,
        out: &mut [f32],
    ) {
        simd::dot_rows_i4(isa, q, blk, scales, scratch, out);
    }

    fn accumulate_rows(
        &self,
        isa: Isa,
        _variant: Variant,
        w: &[f32],
        blk: &[u8],
        scales: &[f32],
        scratch: &mut Vec<f32>,
        acc: &mut [f32],
    ) {
        simd::accumulate_rows_i4(isa, w, blk, scales, scratch, acc);
    }

    fn dot_rows_mq(
        &self,
        isa: Isa,
        _variant: Variant,
        d: usize,
        q_arena: &[f32],
        blk: &[u8],
        scales: &[f32],
        members: &[MqMember],
        scratch: &mut Vec<f32>,
        out_arena: &mut [f32],
    ) {
        simd::dot_rows_i4_mq(isa, d, q_arena, blk, scales, members, scratch, out_arena);
    }

    fn accumulate_rows_mq(
        &self,
        isa: Isa,
        _variant: Variant,
        d: usize,
        w_arena: &[f32],
        blk: &[u8],
        scales: &[f32],
        members: &[MqMember],
        scratch: &mut Vec<f32>,
        acc_arena: &mut [f32],
    ) {
        simd::accumulate_rows_i4_mq(isa, d, w_arena, blk, scales, members, scratch, acc_arena);
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::quant::attn;
    use crate::quant::matrix::Fp32Matrix;
    use crate::quant::quantize::quantize_fused;
    use crate::quant::{int4, scales};
    use crate::util::rng::Rng;

    #[test]
    fn grid_and_layout_are_the_canonical_constants() {
        assert_eq!(INT8.qmax(), crate::QMAX);
        assert_eq!(INT4.qmax(), int4::Q4MAX);
        assert_eq!(FP32.qmax(), crate::QMAX, "fp32 keeps the legacy scale grid");
        assert_eq!(FP32.bytes_per_row(9), 36);
        assert_eq!(INT8.bytes_per_row(9), 9);
        assert_eq!(INT4.bytes_per_row(9), 5, "odd rows pad to a whole byte");
        assert_eq!(INT4.bytes_per_row(8), 4);
        assert!(FP32.supports_staged() && INT8.supports_staged());
        assert!(!INT4.supports_staged(), "packed nibbles have no dense staging ABI");
    }

    #[test]
    fn int8_encode_matches_quantize_row_into() {
        let k = Fp32Matrix::random_uniform(4, 11, -2.0, 2.0, 0xC0);
        let s = scales::compute_scales(&k);
        for t in 0..k.rows {
            let mut raw = vec![0u8; 11];
            INT8.encode_row(Isa::Scalar, k.row(t), &s, &mut raw);
            let mut want = vec![0i8; 11];
            crate::quant::quantize_row_into(k.row(t), &s, &mut want);
            assert_eq!(as_i8(&raw), &want[..]);
            // Round-trip through decode_row hits the same grid.
            let mut rec = vec![0.0f32; 11];
            INT8.decode_row(Isa::Scalar, &raw, &s, &mut rec);
            for (ch, &r) in rec.iter().enumerate() {
                assert_eq!(r.to_bits(), (want[ch] as f32 * s[ch]).to_bits());
            }
        }
    }

    #[test]
    fn fp32_encode_decode_is_bit_exact() {
        let mut rng = Rng::new(9);
        let mut row = vec![0.0f32; 7];
        rng.fill_uniform(&mut row, -10.0, 10.0);
        row[3] = -0.0;
        let mut raw = vec![0u8; 28];
        FP32.encode_row(Isa::Scalar, &row, &[], &mut raw);
        let mut back = vec![0.0f32; 7];
        FP32.decode_row(Isa::Scalar, &raw, &[], &mut back);
        let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        assert_eq!(bits(&row), bits(&back));
    }

    #[test]
    fn int4_encode_decode_round_trips_the_nibble_grid() {
        let k = Fp32Matrix::random_uniform(3, 10, -1.0, 1.0, 0x41);
        let q = int4::quantize4(&k);
        for t in 0..k.rows {
            let mut raw = vec![0u8; 5];
            INT4.encode_row(Isa::Scalar, k.row(t), &q.scales, &mut raw);
            assert_eq!(&raw[..], &q.data[t * 5..(t + 1) * 5], "row {t} packed bytes");
            let mut rec = vec![0.0f32; 10];
            INT4.decode_row(Isa::Scalar, &raw, &q.scales, &mut rec);
            for ch in 0..10 {
                assert!((rec[ch] - k.at(t, ch)).abs() <= q.scales[ch] / 2.0 + 1e-6);
            }
        }
    }

    #[test]
    fn codec_dot_rows_bit_identical_to_attn_kernels() {
        // The dyn dispatch must be a pure delegation: same bits as calling
        // the fused kernels (INT8), the f32 twins, or a decode-then-dot
        // (INT4) directly.
        let (rows, d) = (6usize, 16usize);
        let k = Fp32Matrix::random_normal(rows, d, 1.0, 77);
        let q8 = quantize_fused(&k);
        let mut rng = Rng::new(78);
        let mut q = vec![0.0f32; d];
        rng.fill_uniform(&mut q, -1.0, 1.0);
        let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();

        let raw8: Vec<u8> = q8.data.iter().map(|&v| v as u8).collect();
        let mut scratch = Vec::new();
        for v in Variant::ALL {
            let mut want = vec![0.0f32; rows];
            attn::dot_rows_i8(v, &q, &q8.data, &q8.scales, &mut want);
            let mut got = vec![0.0f32; rows];
            INT8.dot_rows(Isa::Scalar, v, &q, &raw8, &q8.scales, &mut scratch, &mut got);
            assert_eq!(bits(&got), bits(&want), "int8 {v:?}");
        }

        let mut w = vec![0.0f32; rows];
        rng.fill_uniform(&mut w, 0.0, 1.0);
        let mut want_acc = vec![0.0f32; d];
        attn::accumulate_rows_i8(Variant::Vectorized, &w, &q8.data, &q8.scales, &mut want_acc);
        let mut got_acc = vec![0.0f32; d];
        INT8.accumulate_rows(
            Isa::Scalar,
            Variant::Vectorized,
            &w,
            &raw8,
            &q8.scales,
            &mut scratch,
            &mut got_acc,
        );
        assert_eq!(bits(&got_acc), bits(&want_acc));

        // FP32: raw bytes of the float slab.
        let raw32: Vec<u8> = k.data.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let mut want32 = vec![0.0f32; rows];
        attn::dot_rows_f32(&q, &k.data, &mut want32);
        let mut got32 = vec![0.0f32; rows];
        FP32.dot_rows(Isa::Scalar, Variant::Naive, &q, &raw32, &[], &mut scratch, &mut got32);
        assert_eq!(bits(&got32), bits(&want32));

        // INT4: fused == decode_row-then-dot, channel order preserved.
        let q4 = int4::quantize4(&k);
        let mut got4 = vec![0.0f32; rows];
        INT4.dot_rows(
            Isa::Scalar,
            Variant::Naive,
            &q,
            &q4.data,
            &q4.scales,
            &mut scratch,
            &mut got4,
        );
        let mut row = vec![0.0f32; d];
        for r in 0..rows {
            int4::dequantize4_row_into(&q4.data[r * d / 2..(r + 1) * d / 2], &q4.scales, &mut row);
            let mut dot = 0.0f32;
            for ch in 0..d {
                dot += q[ch] * row[ch];
            }
            assert_eq!(got4[r].to_bits(), dot.to_bits(), "int4 row {r}");
        }
    }

    #[test]
    fn codec_mq_bit_identical_to_per_member_dispatch() {
        // Every codec's multi-query methods must give each member exactly
        // the bits of its own single-query dot_rows/accumulate_rows call.
        let (rows, d, n) = (5usize, 8usize, 3usize);
        let k = Fp32Matrix::random_normal(rows, d, 1.0, 0x3A);
        let q8 = quantize_fused(&k);
        let q4 = int4::quantize4(&k);
        let raw8: Vec<u8> = q8.data.iter().map(|&v| v as u8).collect();
        let raw32: Vec<u8> = k.data.iter().flat_map(|v| v.to_ne_bytes()).collect();
        let mut rng = Rng::new(0x3B);
        let mut q_arena = vec![0.0f32; n * d];
        let mut w_arena = vec![0.0f32; n * rows];
        rng.fill_uniform(&mut q_arena, -1.0, 1.0);
        rng.fill_uniform(&mut w_arena, 0.0, 1.0);
        let dot_members: Vec<MqMember> =
            (0..n).map(|i| MqMember { inp: i * d, out: i * rows }).collect();
        let acc_members: Vec<MqMember> =
            (0..n).map(|i| MqMember { inp: i * rows, out: i * d }).collect();
        let bits = |x: &[f32]| x.iter().map(|v| v.to_bits()).collect::<Vec<_>>();
        let mut scratch = Vec::new();
        for (codec, raw, scales) in [
            (&INT8 as &dyn Codec, &raw8, &q8.scales),
            (&FP32 as &dyn Codec, &raw32, &q8.scales),
            (&INT4 as &dyn Codec, &q4.data, &q4.scales),
        ] {
            for v in Variant::ALL {
                let mut out_arena = vec![0.0f32; n * rows];
                codec.dot_rows_mq(
                    Isa::Scalar,
                    v,
                    d,
                    &q_arena,
                    raw,
                    scales,
                    &dot_members,
                    &mut scratch,
                    &mut out_arena,
                );
                let mut acc_arena = vec![0.5f32; n * d];
                codec.accumulate_rows_mq(
                    Isa::Scalar,
                    v,
                    d,
                    &w_arena,
                    raw,
                    scales,
                    &acc_members,
                    &mut scratch,
                    &mut acc_arena,
                );
                for i in 0..n {
                    let mut want = vec![0.0f32; rows];
                    codec.dot_rows(
                        Isa::Scalar,
                        v,
                        &q_arena[i * d..(i + 1) * d],
                        raw,
                        scales,
                        &mut scratch,
                        &mut want,
                    );
                    assert_eq!(
                        bits(&out_arena[i * rows..(i + 1) * rows]),
                        bits(&want),
                        "{} mq dot member {i} {v:?}",
                        codec.name()
                    );
                    let mut want_acc = vec![0.5f32; d];
                    codec.accumulate_rows(
                        Isa::Scalar,
                        v,
                        &w_arena[i * rows..(i + 1) * rows],
                        raw,
                        scales,
                        &mut scratch,
                        &mut want_acc,
                    );
                    assert_eq!(
                        bits(&acc_arena[i * d..(i + 1) * d]),
                        bits(&want_acc),
                        "{} mq accumulate member {i} {v:?}",
                        codec.name()
                    );
                }
            }
        }
    }

    #[test]
    fn int4_scratch_grows_on_demand_and_is_reusable() {
        let k = Fp32Matrix::random_uniform(2, 8, -1.0, 1.0, 5);
        let q4 = int4::quantize4(&k);
        let mut scratch = Vec::new(); // deliberately unsized
        let mut out = vec![0.0f32; 2];
        INT4.dot_rows(
            Isa::Scalar,
            Variant::Naive,
            &[1.0; 8],
            &q4.data,
            &q4.scales,
            &mut scratch,
            &mut out,
        );
        assert!(scratch.len() >= 8);
        let mut acc = vec![0.0f32; 8];
        INT4.accumulate_rows(
            Isa::Scalar,
            Variant::Naive,
            &[0.5, 0.5],
            &q4.data,
            &q4.scales,
            &mut scratch,
            &mut acc,
        );
        assert!(acc.iter().any(|&v| v != 0.0));
    }
}
