//! NEON/ASIMD (aarch64, 128-bit) kernels behind the [`super`] dispatch
//! layer.
//!
//! Safety contract (every `unsafe fn` here): NEON must be available —
//! guaranteed on aarch64, where ASIMD is architecturally mandatory; the
//! dispatchers in [`super`] still re-check the cached [`super::detect`]
//! before calling.
//!
//! Numeric contract: identical to the AVX2 module — encode / decode /
//! accumulate are bit-identical to the scalar kernels (no FMA, exact
//! IEEE ops in the scalar order; `FRINTA` rounds ties away from zero,
//! exactly `f32::round`), while the dot kernels reassociate channel sums
//! into 4-wide lanes (f64-reference tolerance).

#![allow(clippy::missing_safety_doc)] // module-level safety contract above

use core::arch::aarch64::*;

/// Dequantize 8 consecutive int8 channels into two 4-lane vectors.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn dequant8(row: *const i8, scales: *const f32) -> (float32x4_t, float32x4_t) {
    let w16 = vmovl_s8(vld1_s8(row));
    let f0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w16)));
    let f1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w16)));
    (vmulq_f32(f0, vld1q_f32(scales)), vmulq_f32(f1, vld1q_f32(scales.add(4))))
}

#[target_feature(enable = "neon")]
pub unsafe fn dot_rows_i8(q: &[f32], blk: &[i8], scales: &[f32], out: &mut [f32]) {
    let d = q.len();
    debug_assert_eq!(blk.len(), out.len() * d, "slab shape mismatch");
    debug_assert_eq!(scales.len(), d, "scales shape mismatch");
    let mid = d / 8 * 8;
    for (r, o) in out.iter_mut().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let mut acc0 = vdupq_n_f32(0.0);
        let mut acc1 = vdupq_n_f32(0.0);
        let mut ch = 0;
        while ch < mid {
            let (d0, d1) = dequant8(row.as_ptr().add(ch), scales.as_ptr().add(ch));
            acc0 = vaddq_f32(acc0, vmulq_f32(vld1q_f32(q.as_ptr().add(ch)), d0));
            acc1 = vaddq_f32(acc1, vmulq_f32(vld1q_f32(q.as_ptr().add(ch + 4)), d1));
            ch += 8;
        }
        let mut sum = vaddvq_f32(vaddq_f32(acc0, acc1));
        while ch < d {
            sum += q[ch] * (row[ch] as f32 * scales[ch]);
            ch += 1;
        }
        *o = sum;
    }
}

#[target_feature(enable = "neon")]
pub unsafe fn accumulate_rows_i8(w: &[f32], blk: &[i8], scales: &[f32], acc: &mut [f32]) {
    let d = acc.len();
    debug_assert_eq!(blk.len(), w.len() * d, "slab shape mismatch");
    debug_assert_eq!(scales.len(), d, "scales shape mismatch");
    let mid = d / 8 * 8;
    for (r, &wr) in w.iter().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let wv = vdupq_n_f32(wr);
        let mut ch = 0;
        while ch < mid {
            let (d0, d1) = dequant8(row.as_ptr().add(ch), scales.as_ptr().add(ch));
            // mul + add (not FMA) keeps the per-channel op sequence
            // bit-identical to the scalar kernels.
            let a0 = vaddq_f32(vld1q_f32(acc.as_ptr().add(ch)), vmulq_f32(wv, d0));
            let a1 = vaddq_f32(vld1q_f32(acc.as_ptr().add(ch + 4)), vmulq_f32(wv, d1));
            vst1q_f32(acc.as_mut_ptr().add(ch), a0);
            vst1q_f32(acc.as_mut_ptr().add(ch + 4), a1);
            ch += 8;
        }
        while ch < d {
            acc[ch] += wr * (row[ch] as f32 * scales[ch]);
            ch += 1;
        }
    }
}

#[target_feature(enable = "neon")]
pub unsafe fn dot_rows_f32(q: &[f32], blk: &[f32], out: &mut [f32]) {
    let d = q.len();
    debug_assert_eq!(blk.len(), out.len() * d, "slab shape mismatch");
    let mid = d / 4 * 4;
    for (r, o) in out.iter_mut().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let mut acc = vdupq_n_f32(0.0);
        let mut ch = 0;
        while ch < mid {
            let v = vld1q_f32(row.as_ptr().add(ch));
            acc = vaddq_f32(acc, vmulq_f32(vld1q_f32(q.as_ptr().add(ch)), v));
            ch += 4;
        }
        let mut sum = vaddvq_f32(acc);
        while ch < d {
            sum += q[ch] * row[ch];
            ch += 1;
        }
        *o = sum;
    }
}

#[target_feature(enable = "neon")]
pub unsafe fn accumulate_rows_f32(w: &[f32], blk: &[f32], acc: &mut [f32]) {
    let d = acc.len();
    debug_assert_eq!(blk.len(), w.len() * d, "slab shape mismatch");
    let mid = d / 4 * 4;
    for (r, &wr) in w.iter().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let wv = vdupq_n_f32(wr);
        let mut ch = 0;
        while ch < mid {
            let v = vld1q_f32(row.as_ptr().add(ch));
            let a = vaddq_f32(vld1q_f32(acc.as_ptr().add(ch)), vmulq_f32(wv, v));
            vst1q_f32(acc.as_mut_ptr().add(ch), a);
            ch += 4;
        }
        while ch < d {
            acc[ch] += wr * row[ch];
            ch += 1;
        }
    }
}

#[target_feature(enable = "neon")]
pub unsafe fn quantize_row_into(row: &[f32], scales: &[f32], out: &mut [i8]) {
    debug_assert_eq!(row.len(), scales.len());
    debug_assert_eq!(row.len(), out.len());
    let n = row.len();
    let mid = n / 4 * 4;
    let qmax = vdupq_n_f32(crate::QMAX);
    let nqmax = vdupq_n_f32(-crate::QMAX);
    let zero = vdupq_n_f32(0.0);
    let mut ibuf = [0i32; 4];
    let mut ch = 0;
    while ch < mid {
        let v = vld1q_f32(row.as_ptr().add(ch));
        let s = vld1q_f32(scales.as_ptr().add(ch));
        let q = vdivq_f32(v, s);
        // FRINTA rounds ties away from zero — exactly f32::round.
        let r = vrndaq_f32(q);
        let r = vbslq_f32(vceqq_f32(r, r), r, zero); // NaN -> 0
        let r = vminq_f32(vmaxq_f32(r, nqmax), qmax);
        let r = vbslq_f32(vcgtq_f32(s, zero), r, zero); // scale <= 0 -> 0
        vst1q_s32(ibuf.as_mut_ptr(), vcvtq_s32_f32(r));
        out[ch] = ibuf[0] as i8;
        out[ch + 1] = ibuf[1] as i8;
        out[ch + 2] = ibuf[2] as i8;
        out[ch + 3] = ibuf[3] as i8;
        ch += 4;
    }
    while ch < n {
        out[ch] = crate::quant::quantize::quantize_one(row[ch], scales[ch]);
        ch += 1;
    }
}

#[target_feature(enable = "neon")]
pub unsafe fn dequantize_row_into(row: &[i8], scales: &[f32], out: &mut [f32]) {
    debug_assert_eq!(row.len(), out.len());
    debug_assert_eq!(scales.len(), out.len());
    let n = out.len();
    let mid = n / 8 * 8;
    let mut ch = 0;
    while ch < mid {
        let (d0, d1) = dequant8(row.as_ptr().add(ch), scales.as_ptr().add(ch));
        vst1q_f32(out.as_mut_ptr().add(ch), d0);
        vst1q_f32(out.as_mut_ptr().add(ch + 4), d1);
        ch += 8;
    }
    while ch < n {
        out[ch] = row[ch] as f32 * scales[ch];
        ch += 1;
    }
}

#[target_feature(enable = "neon")]
pub unsafe fn quantize4_row_into(row: &[f32], scales: &[f32], out: &mut [u8]) {
    debug_assert_eq!(row.len() % 2, 0, "int4 rows must have even length");
    debug_assert_eq!(row.len(), scales.len());
    debug_assert_eq!(out.len() * 2, row.len());
    let n = row.len();
    let mid = n / 4 * 4;
    let mut qbuf = [0.0f32; 4];
    let mut ch = 0;
    while ch < mid {
        let v = vld1q_f32(row.as_ptr().add(ch));
        let s = vld1q_f32(scales.as_ptr().add(ch));
        vst1q_f32(qbuf.as_mut_ptr(), vdivq_f32(v, s));
        for i in (0..4).step_by(2) {
            let lo = super::code_i4(qbuf[i], scales[ch + i]) as u8 & 0x0F;
            let hi = super::code_i4(qbuf[i + 1], scales[ch + i + 1]) as u8 & 0x0F;
            out[(ch + i) / 2] = lo | (hi << 4);
        }
        ch += 4;
    }
    while ch < n {
        let lo = crate::quant::int4::quantize_one4(row[ch], scales[ch]) as u8 & 0x0F;
        let hi = crate::quant::int4::quantize_one4(row[ch + 1], scales[ch + 1]) as u8 & 0x0F;
        out[ch / 2] = lo | (hi << 4);
        ch += 2;
    }
}

/// Widen 8 signed nibble values (already sign-extended to i8) and store
/// `v[i] * scales[i]` to `out[0..8]`.
#[inline]
#[target_feature(enable = "neon")]
unsafe fn widen_mul_store(v: int8x8_t, scales: *const f32, out: *mut f32) {
    let w16 = vmovl_s8(v);
    let f0 = vcvtq_f32_s32(vmovl_s16(vget_low_s16(w16)));
    let f1 = vcvtq_f32_s32(vmovl_s16(vget_high_s16(w16)));
    vst1q_f32(out, vmulq_f32(f0, vld1q_f32(scales)));
    vst1q_f32(out.add(4), vmulq_f32(f1, vld1q_f32(scales.add(4))));
}

#[target_feature(enable = "neon")]
pub unsafe fn dequantize4_row_into(bytes: &[u8], scales: &[f32], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() * 2, out.len());
    debug_assert_eq!(scales.len(), out.len());
    let nb = bytes.len();
    let main_b = nb / 8 * 8;
    let mut b = 0;
    while b < main_b {
        // 8 packed bytes -> 16 channels: split nibbles, sign-extend each
        // 4-bit value via (v ^ 8) - 8, interleave back to channel order.
        let raw = vld1_u8(bytes.as_ptr().add(b));
        let lo4 = vand_u8(raw, vdup_n_u8(0x0F));
        let hi4 = vshr_n_u8::<4>(raw);
        let k8 = vdup_n_u8(8);
        let sk8 = vreinterpret_s8_u8(k8);
        let lo = vsub_s8(vreinterpret_s8_u8(veor_u8(lo4, k8)), sk8);
        let hi = vsub_s8(vreinterpret_s8_u8(veor_u8(hi4, k8)), sk8);
        let ch = b * 2;
        widen_mul_store(vzip1_s8(lo, hi), scales.as_ptr().add(ch), out.as_mut_ptr().add(ch));
        widen_mul_store(
            vzip2_s8(lo, hi),
            scales.as_ptr().add(ch + 8),
            out.as_mut_ptr().add(ch + 8),
        );
        b += 8;
    }
    while b < nb {
        let byte = bytes[b];
        let lo = ((byte << 4) as i8) >> 4;
        let hi = (byte as i8) >> 4;
        let ch = 2 * b;
        out[ch] = lo as f32 * scales[ch];
        out[ch + 1] = hi as f32 * scales[ch + 1];
        b += 1;
    }
}
