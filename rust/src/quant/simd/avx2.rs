//! AVX2 (x86_64, 256-bit) kernels behind the [`super`] dispatch layer.
//!
//! Safety contract (every `unsafe fn` here): the caller must have
//! verified AVX2 support — [`super::detect`] returning [`super::Isa::Avx2`]
//! — before calling. The dispatchers in [`super`] re-check the cached
//! detection on every call, so these bodies never execute on hosts
//! without the feature.
//!
//! Numeric contract (see the module docs of [`super`]):
//!
//! * encode / decode / accumulate perform the scalar kernels' exact
//!   per-element IEEE operation sequence (no FMA contraction — products
//!   and sums stay separately rounded), so they are **bit-identical** to
//!   the scalar backend;
//! * the dot kernels accumulate channels in 8-wide lanes (two
//!   independent accumulators), reassociating the sum — covered by the
//!   f64-reference tolerance, never bit-compared against scalar.
//!
//! Encode vectorizes the division (IEEE-exact, so quotients match the
//! scalar writer bit for bit) and finishes round/clamp through the
//! shared scalar finisher [`super::code_i8`] / [`super::code_i4`] —
//! sidestepping the subtle mismatch between packed round-to-nearest-even
//! and `f32::round`'s ties-away semantics.

#![allow(clippy::missing_safety_doc)] // module-level safety contract above

use core::arch::x86_64::*;

/// Dequantize 8 consecutive int8 channels: `(i8 as f32) * scale`.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn dequant8(row: *const i8, scales: *const f32) -> __m256 {
    let raw = _mm_loadl_epi64(row as *const __m128i);
    let wide = _mm256_cvtepi8_epi32(raw);
    _mm256_mul_ps(_mm256_cvtepi32_ps(wide), _mm256_loadu_ps(scales))
}

/// Horizontal sum of 8 lanes in a fixed (deterministic) reduction order.
#[inline]
#[target_feature(enable = "avx2")]
unsafe fn hsum8(v: __m256) -> f32 {
    let lo = _mm256_castps256_ps128(v);
    let hi = _mm256_extractf128_ps::<1>(v);
    let s = _mm_add_ps(lo, hi);
    let s = _mm_add_ps(s, _mm_movehl_ps(s, s));
    let s = _mm_add_ss(s, _mm_movehdup_ps(s));
    _mm_cvtss_f32(s)
}

#[target_feature(enable = "avx2")]
pub unsafe fn dot_rows_i8(q: &[f32], blk: &[i8], scales: &[f32], out: &mut [f32]) {
    let d = q.len();
    debug_assert_eq!(blk.len(), out.len() * d, "slab shape mismatch");
    debug_assert_eq!(scales.len(), d, "scales shape mismatch");
    let main = d / 16 * 16;
    let mid = d / 8 * 8;
    for (r, o) in out.iter_mut().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut ch = 0;
        while ch < main {
            let d0 = dequant8(row.as_ptr().add(ch), scales.as_ptr().add(ch));
            let d1 = dequant8(row.as_ptr().add(ch + 8), scales.as_ptr().add(ch + 8));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(q.as_ptr().add(ch)), d0));
            acc1 =
                _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_loadu_ps(q.as_ptr().add(ch + 8)), d1));
            ch += 16;
        }
        if ch < mid {
            let d0 = dequant8(row.as_ptr().add(ch), scales.as_ptr().add(ch));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(q.as_ptr().add(ch)), d0));
            ch += 8;
        }
        let mut sum = hsum8(_mm256_add_ps(acc0, acc1));
        while ch < d {
            sum += q[ch] * (row[ch] as f32 * scales[ch]);
            ch += 1;
        }
        *o = sum;
    }
}

#[target_feature(enable = "avx2")]
pub unsafe fn accumulate_rows_i8(w: &[f32], blk: &[i8], scales: &[f32], acc: &mut [f32]) {
    let d = acc.len();
    debug_assert_eq!(blk.len(), w.len() * d, "slab shape mismatch");
    debug_assert_eq!(scales.len(), d, "scales shape mismatch");
    let mid = d / 8 * 8;
    for (r, &wr) in w.iter().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let wv = _mm256_set1_ps(wr);
        let mut ch = 0;
        while ch < mid {
            let deq = dequant8(row.as_ptr().add(ch), scales.as_ptr().add(ch));
            let a = _mm256_loadu_ps(acc.as_ptr().add(ch));
            // mul + add (not FMA): per-channel arithmetic — convert, ·s,
            // ·w, + — stays bit-identical to the scalar kernels.
            let sum = _mm256_add_ps(a, _mm256_mul_ps(wv, deq));
            _mm256_storeu_ps(acc.as_mut_ptr().add(ch), sum);
            ch += 8;
        }
        while ch < d {
            acc[ch] += wr * (row[ch] as f32 * scales[ch]);
            ch += 1;
        }
    }
}

#[target_feature(enable = "avx2")]
pub unsafe fn dot_rows_f32(q: &[f32], blk: &[f32], out: &mut [f32]) {
    let d = q.len();
    debug_assert_eq!(blk.len(), out.len() * d, "slab shape mismatch");
    let main = d / 16 * 16;
    let mid = d / 8 * 8;
    for (r, o) in out.iter_mut().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let mut acc0 = _mm256_setzero_ps();
        let mut acc1 = _mm256_setzero_ps();
        let mut ch = 0;
        while ch < main {
            let v0 = _mm256_loadu_ps(row.as_ptr().add(ch));
            let v1 = _mm256_loadu_ps(row.as_ptr().add(ch + 8));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(q.as_ptr().add(ch)), v0));
            acc1 =
                _mm256_add_ps(acc1, _mm256_mul_ps(_mm256_loadu_ps(q.as_ptr().add(ch + 8)), v1));
            ch += 16;
        }
        if ch < mid {
            let v0 = _mm256_loadu_ps(row.as_ptr().add(ch));
            acc0 = _mm256_add_ps(acc0, _mm256_mul_ps(_mm256_loadu_ps(q.as_ptr().add(ch)), v0));
            ch += 8;
        }
        let mut sum = hsum8(_mm256_add_ps(acc0, acc1));
        while ch < d {
            sum += q[ch] * row[ch];
            ch += 1;
        }
        *o = sum;
    }
}

#[target_feature(enable = "avx2")]
pub unsafe fn accumulate_rows_f32(w: &[f32], blk: &[f32], acc: &mut [f32]) {
    let d = acc.len();
    debug_assert_eq!(blk.len(), w.len() * d, "slab shape mismatch");
    let mid = d / 8 * 8;
    for (r, &wr) in w.iter().enumerate() {
        let row = &blk[r * d..(r + 1) * d];
        let wv = _mm256_set1_ps(wr);
        let mut ch = 0;
        while ch < mid {
            let v = _mm256_loadu_ps(row.as_ptr().add(ch));
            let a = _mm256_loadu_ps(acc.as_ptr().add(ch));
            let sum = _mm256_add_ps(a, _mm256_mul_ps(wv, v));
            _mm256_storeu_ps(acc.as_mut_ptr().add(ch), sum);
            ch += 8;
        }
        while ch < d {
            acc[ch] += wr * row[ch];
            ch += 1;
        }
    }
}

#[target_feature(enable = "avx2")]
pub unsafe fn quantize_row_into(row: &[f32], scales: &[f32], out: &mut [i8]) {
    debug_assert_eq!(row.len(), scales.len());
    debug_assert_eq!(row.len(), out.len());
    let n = row.len();
    let mid = n / 8 * 8;
    let mut qbuf = [0.0f32; 8];
    let mut ch = 0;
    while ch < mid {
        // Vectorized division (IEEE-exact — quotients match the scalar
        // writer bit for bit); round/clamp/pack finish scalar through the
        // shared code_i8 so the ties-away rounding is pinned.
        let v = _mm256_loadu_ps(row.as_ptr().add(ch));
        let s = _mm256_loadu_ps(scales.as_ptr().add(ch));
        _mm256_storeu_ps(qbuf.as_mut_ptr(), _mm256_div_ps(v, s));
        for (i, &q) in qbuf.iter().enumerate() {
            out[ch + i] = super::code_i8(q, scales[ch + i]);
        }
        ch += 8;
    }
    while ch < n {
        out[ch] = crate::quant::quantize::quantize_one(row[ch], scales[ch]);
        ch += 1;
    }
}

#[target_feature(enable = "avx2")]
pub unsafe fn dequantize_row_into(row: &[i8], scales: &[f32], out: &mut [f32]) {
    debug_assert_eq!(row.len(), out.len());
    debug_assert_eq!(scales.len(), out.len());
    let n = out.len();
    let mid = n / 8 * 8;
    let mut ch = 0;
    while ch < mid {
        let deq = dequant8(row.as_ptr().add(ch), scales.as_ptr().add(ch));
        _mm256_storeu_ps(out.as_mut_ptr().add(ch), deq);
        ch += 8;
    }
    while ch < n {
        out[ch] = row[ch] as f32 * scales[ch];
        ch += 1;
    }
}

#[target_feature(enable = "avx2")]
pub unsafe fn quantize4_row_into(row: &[f32], scales: &[f32], out: &mut [u8]) {
    debug_assert_eq!(row.len() % 2, 0, "int4 rows must have even length");
    debug_assert_eq!(row.len(), scales.len());
    debug_assert_eq!(out.len() * 2, row.len());
    let n = row.len();
    let mid = n / 8 * 8;
    let mut qbuf = [0.0f32; 8];
    let mut ch = 0;
    while ch < mid {
        let v = _mm256_loadu_ps(row.as_ptr().add(ch));
        let s = _mm256_loadu_ps(scales.as_ptr().add(ch));
        _mm256_storeu_ps(qbuf.as_mut_ptr(), _mm256_div_ps(v, s));
        for i in (0..8).step_by(2) {
            let lo = super::code_i4(qbuf[i], scales[ch + i]) as u8 & 0x0F;
            let hi = super::code_i4(qbuf[i + 1], scales[ch + i + 1]) as u8 & 0x0F;
            out[(ch + i) / 2] = lo | (hi << 4);
        }
        ch += 8;
    }
    while ch < n {
        let lo = crate::quant::int4::quantize_one4(row[ch], scales[ch]) as u8 & 0x0F;
        let hi = crate::quant::int4::quantize_one4(row[ch + 1], scales[ch + 1]) as u8 & 0x0F;
        out[ch / 2] = lo | (hi << 4);
        ch += 2;
    }
}

#[target_feature(enable = "avx2")]
pub unsafe fn dequantize4_row_into(bytes: &[u8], scales: &[f32], out: &mut [f32]) {
    debug_assert_eq!(bytes.len() * 2, out.len());
    debug_assert_eq!(scales.len(), out.len());
    let nb = bytes.len();
    let main_b = nb / 8 * 8;
    let mut b = 0;
    while b < main_b {
        // 8 packed bytes -> 16 channels: split nibbles, sign-extend each
        // 4-bit value via (v ^ 8) - 8, interleave back to channel order.
        let raw = _mm_loadl_epi64(bytes.as_ptr().add(b) as *const __m128i);
        let maskf = _mm_set1_epi8(0x0F);
        let lo4 = _mm_and_si128(raw, maskf);
        let hi4 = _mm_and_si128(_mm_srli_epi16::<4>(raw), maskf);
        let k8 = _mm_set1_epi8(8);
        let lo = _mm_sub_epi8(_mm_xor_si128(lo4, k8), k8);
        let hi = _mm_sub_epi8(_mm_xor_si128(hi4, k8), k8);
        let inter = _mm_unpacklo_epi8(lo, hi); // lo0 hi0 lo1 hi1 ...
        let w0 = _mm256_cvtepi8_epi32(inter);
        let w1 = _mm256_cvtepi8_epi32(_mm_srli_si128::<8>(inter));
        let ch = b * 2;
        let d0 =
            _mm256_mul_ps(_mm256_cvtepi32_ps(w0), _mm256_loadu_ps(scales.as_ptr().add(ch)));
        let d1 =
            _mm256_mul_ps(_mm256_cvtepi32_ps(w1), _mm256_loadu_ps(scales.as_ptr().add(ch + 8)));
        _mm256_storeu_ps(out.as_mut_ptr().add(ch), d0);
        _mm256_storeu_ps(out.as_mut_ptr().add(ch + 8), d1);
        b += 8;
    }
    while b < nb {
        let byte = bytes[b];
        let lo = ((byte << 4) as i8) >> 4;
        let hi = (byte as i8) >> 4;
        let ch = 2 * b;
        out[ch] = lo as f32 * scales[ch];
        out[ch + 1] = hi as f32 * scales[ch + 1];
        b += 1;
    }
}
